//! Lightweight property-based testing (proptest is not vendored).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` random
//! inputs drawn through the deterministic [`crate::util::prng::Rng`]; on
//! failure it reports the per-case seed so the exact input can be replayed
//! with `replay(seed, f)`. No shrinking — failing seeds are replayable and
//! our generators draw small structured inputs, which keeps counterexamples
//! readable without it.

use crate::util::prng::Rng;

/// Outcome of a property over one generated case.
pub type PropResult = Result<(), String>;

/// Run `prop` for `cases` seeds derived from the property `name`.
///
/// Panics (test-failure style) with the offending seed on the first failed
/// case. The base seed is derived from the name so adding properties does
/// not perturb existing ones.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed on case {case}/{cases} (replay seed: {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a property on a single seed reported by [`check`].
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

/// FNV-1a hash of the property name → stable base seed.
fn name_seed(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two f32 slices are elementwise close; returns a property error
/// naming the first offending index otherwise.
pub fn assert_close(actual: &[f32], expected: &[f32], atol: f32, rtol: f32) -> PropResult {
    if actual.len() != expected.len() {
        return Err(format!("length mismatch: {} vs {}", actual.len(), expected.len()));
    }
    for (i, (a, e)) in actual.iter().zip(expected.iter()).enumerate() {
        let tol = atol + rtol * e.abs();
        if (a - e).abs() > tol || (a.is_nan() != e.is_nan()) {
            return Err(format!(
                "mismatch at [{i}]: actual={a} expected={e} (|diff|={} > tol={tol})",
                (a - e).abs()
            ));
        }
    }
    Ok(())
}

/// Property-style equality for exact (e.g. permutation) data planes.
pub fn assert_eq_slice<T: PartialEq + std::fmt::Debug>(actual: &[T], expected: &[T]) -> PropResult {
    if actual.len() != expected.len() {
        return Err(format!("length mismatch: {} vs {}", actual.len(), expected.len()));
    }
    for (i, (a, e)) in actual.iter().zip(expected.iter()).enumerate() {
        if a != e {
            return Err(format!("mismatch at [{i}]: actual={a:?} expected={e:?}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", 25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always-false", 5, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_inputs_per_name() {
        let mut first: Vec<u64> = Vec::new();
        check("det", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("det", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn close_helper() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5, 1e-5).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5, 1e-5).is_err());
    }

    #[test]
    fn eq_helper() {
        assert!(assert_eq_slice(&[1, 2], &[1, 2]).is_ok());
        assert!(assert_eq_slice(&[1, 2], &[2, 1]).is_err());
    }
}
