//! Deterministic PRNG (no `rand` crate vendored): SplitMix64 for seeding
//! and Xoshiro256** as the workhorse generator. All stochastic behaviour in
//! the library (synthetic data, sweep sampling, property tests) flows
//! through this so runs are reproducible from a single `u64` seed.

/// SplitMix64 step — used to expand a user seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix64 of any seed
        // cannot produce four zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. Uses rejection sampling to avoid modulo
    /// bias (matters for property tests drawing small ranges billions of
    /// times less than the bias threshold, but correctness is cheap).
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize(0) is meaningless");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.usize(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Pick one element of a slice uniformly.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize(i + 1);
            items.swap(i, j);
        }
    }

    /// Vector of iid U[0,1) f32 values (synthetic activations / tokens).
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }

    /// Fork a child generator (stream-split) — children are independent for
    /// practical purposes because the fork key is mixed through splitmix64.
    pub fn fork(&mut self, key: u64) -> Rng {
        Rng::new(self.next_u64() ^ key.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.usize(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            let v = r.range(2, 4);
            assert!((2..=4).contains(&v));
            lo_seen |= v == 2;
            hi_seen |= v == 4;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forks_diverge() {
        let mut r = Rng::new(9);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
