//! Plain-text / markdown table rendering for bench reports (Table IV/V
//! style output on stdout and in EXPERIMENTS.md).

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder: set headers, push rows, render.
#[derive(Debug, Default, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Left).collect(),
            rows: Vec::new(),
        }
    }

    /// Right-align all columns except the first (typical for numeric tables).
    pub fn numeric(mut self) -> Table {
        for (i, a) in self.aligns.iter_mut().enumerate() {
            *a = if i == 0 { Align::Left } else { Align::Right };
        }
        self
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    fn pad(cell: &str, width: usize, align: Align) -> String {
        let len = cell.chars().count();
        let fill = " ".repeat(width - len);
        match align {
            Align::Left => format!("{cell}{fill}"),
            Align::Right => format!("{fill}{cell}"),
        }
    }

    /// Render as an aligned plain-text table.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| Self::pad(h, w[i], self.aligns[i]))
            .collect();
        out.push_str(&hdr.join("  "));
        out.push('\n');
        out.push_str(&w.iter().map(|n| "-".repeat(*n)).collect::<Vec<_>>().join("  "));
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| Self::pad(c, w[i], self.aligns[i]))
                .collect();
            out.push_str(&cells.join("  "));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        let seps: Vec<&str> = self
            .aligns
            .iter()
            .map(|a| match a {
                Align::Left => "---",
                Align::Right => "---:",
            })
            .collect();
        out.push_str(&format!("| {} |\n", seps.join(" | ")));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Render as CSV (no quoting needed for our numeric/identifier cells,
    /// but commas in cells are escaped defensively).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with an adaptive unit (ns/µs/ms/s).
pub fn fmt_seconds(s: f64) -> String {
    let abs = s.abs();
    if abs >= 1.0 {
        format!("{s:.3} s")
    } else if abs >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a speedup ratio in the paper's style, e.g. `3.06×`.
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}×")
}

/// Format a byte count with binary units.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_text_aligned() {
        let mut t = Table::new(&["name", "value"]).numeric();
        t.row_strs(&["aa", "1.5"]);
        t.row_strs(&["b", "12.25"]);
        let text = t.to_text();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[2].starts_with("aa"));
        assert!(lines[3].ends_with("12.25"));
    }

    #[test]
    fn renders_markdown() {
        let mut t = Table::new(&["a", "b"]).numeric();
        t.row_strs(&["x", "1"]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| --- | ---: |"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a"]);
        t.row_strs(&["x,y"]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_seconds(1.5), "1.500 s");
        assert_eq!(fmt_seconds(0.0015), "1.500 ms");
        assert_eq!(fmt_speedup(3.061), "3.06×");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
    }
}
