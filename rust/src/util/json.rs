//! Minimal JSON value model, parser and writer.
//!
//! Used for cluster profiles, model configs, the artifact manifest emitted
//! by `python/compile/aot.py`, and machine-readable bench reports. Supports
//! the full JSON grammar (RFC 8259) minus `\u` surrogate-pair edge cases
//! beyond the BMP (sufficient: all our documents are ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Numbers are kept as `f64` (all our payloads are
/// configs and metrics; integers up to 2^53 round-trip exactly).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after top-level value"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys so lookups
    /// can be chained without `Option` plumbing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup with the same chaining behaviour as [`Json::get`].
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field helpers used by the config loaders: error messages
    /// name the missing/badly-typed key.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| JsonError::schema(format!("expected number at key `{key}`")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| JsonError::schema(format!("expected non-negative integer at key `{key}`")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .as_str()
            .ok_or_else(|| JsonError::schema(format!("expected string at key `{key}`")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| JsonError::schema(format!("expected array at key `{key}`")))
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Compact single-line encoding.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed encoding with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; metrics code should never emit them, but be
        // defensive rather than producing an unparsable document.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse or schema-validation failure, with byte offset for parse errors.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: Option<usize>,
}

impl JsonError {
    fn schema(msg: String) -> JsonError {
        JsonError { msg, offset: None }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.offset {
            Some(o) => write!(f, "json error at byte {}: {}", o, self.msg),
            None => write!(f, "json schema error: {}", self.msg),
        }
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: Some(self.i) }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("malformed number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape character")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").at(0).as_usize().unwrap(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":[1,2.5,"s"],"b":{"c":true,"d":null}}"#,
            r#"[[],{},"",0,-0.125]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let enc = v.to_string();
            assert_eq!(Json::parse(&enc).unwrap(), v, "case {c}");
            // pretty encoding parses back to the same value too
            assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
        }
    }

    #[test]
    fn escapes_strings() {
        let v = Json::Str("a\"b\\c\n\u{1}".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn req_helpers_name_keys() {
        let v = Json::parse(r#"{"n": 3}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        let err = v.req_str("missing").unwrap_err();
        assert!(err.to_string().contains("missing"));
    }

    #[test]
    fn large_ints_roundtrip() {
        let v = Json::parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_f64().unwrap(), 9007199254740992.0);
    }
}
