//! Tiny CLI argument parser (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Unknown flags are an error; each subcommand declares what it accepts.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Parsed arguments: options by name plus positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Declares an accepted option/flag for parse-time validation + help text.
#[derive(Debug, Clone)]
pub struct Spec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

impl Spec {
    pub const fn opt(name: &'static str, help: &'static str) -> Spec {
        Spec { name, takes_value: true, help, default: None }
    }
    pub const fn opt_default(name: &'static str, default: &'static str, help: &'static str) -> Spec {
        Spec { name, takes_value: true, help, default: Some(default) }
    }
    pub const fn flag(name: &'static str, help: &'static str) -> Spec {
        Spec { name, takes_value: false, help, default: None }
    }
}

impl Args {
    /// Parse `argv` (without the program/subcommand names) against `specs`.
    pub fn parse(argv: &[String], specs: &[Spec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        let find = |name: &str| specs.iter().find(|s| s.name == name);
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = find(&name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    args.opts.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{name} does not take a value")));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        // Apply defaults.
        for s in specs {
            if let Some(d) = s.default {
                args.opts.entry(s.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(args)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name} expects an integer, got `{v}`"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name} expects a number, got `{v}`"))),
        }
    }

    pub fn req(&self, name: &str) -> Result<&str, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("required option --{name} missing")))
    }
}

/// Render a help block for a subcommand.
pub fn render_help(cmd: &str, about: &str, specs: &[Spec]) -> String {
    let mut out = format!("parm {cmd} — {about}\n\noptions:\n");
    for s in specs {
        let head = if s.takes_value {
            format!("  --{} <v>", s.name)
        } else {
            format!("  --{}", s.name)
        };
        let default = s.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        out.push_str(&format!("{head:<26} {}{}\n", s.help, default));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    const SPECS: &[Spec] = &[
        Spec::opt("steps", "number of steps"),
        Spec::opt_default("seed", "42", "prng seed"),
        Spec::flag("verbose", "chatty output"),
    ];

    #[test]
    fn parses_forms() {
        let a = Args::parse(&sv(&["--steps", "10", "--verbose", "pos1"]), SPECS).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), Some(10));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get("seed"), Some("42")); // default applied
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&sv(&["--steps=3"]), SPECS).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), Some(3));
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(&sv(&["--nope"]), SPECS).is_err());
        assert!(Args::parse(&sv(&["--steps"]), SPECS).is_err());
        assert!(Args::parse(&sv(&["--verbose=1"]), SPECS).is_err());
    }

    #[test]
    fn bad_number() {
        let a = Args::parse(&sv(&["--steps", "abc"]), SPECS).unwrap();
        assert!(a.get_usize("steps").is_err());
    }

    #[test]
    fn help_renders() {
        let h = render_help("train", "train a model", SPECS);
        assert!(h.contains("--steps"));
        assert!(h.contains("[default: 42]"));
    }
}
