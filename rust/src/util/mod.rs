//! Small self-contained substrates the coordinator is built on.
//!
//! Nothing in this module knows about MoE or the paper; these are the
//! pieces a production system would normally pull from crates.io
//! (serde/clap/criterion/proptest/rand). This build is fully offline with a
//! minimal vendored crate set, so we implement them here, with tests.

pub mod benchmark;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prng;
pub mod propcheck;
pub mod stats;
pub mod table;
