//! Micro-benchmark harness (criterion is not vendored).
//!
//! `cargo bench` targets are declared with `harness = false` and drive this
//! module directly: warmup, fixed-duration sampling, median/MAD reporting,
//! and an optional JSON report for EXPERIMENTS.md tooling.

use crate::util::stats;
use crate::util::table::fmt_seconds;
use std::time::{Duration, Instant};

/// One benchmark measurement result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Median seconds per iteration.
    pub median: f64,
    /// Median absolute deviation of per-iteration seconds.
    pub mad: f64,
    pub iterations: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10}  ({} iters, {} samples)",
            self.name,
            fmt_seconds(self.median),
            fmt_seconds(self.mad),
            self.iterations,
            self.samples
        )
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Modest defaults: the sweep benches dominate wall-clock, so the
        // micro harness keeps sampling short. Override via PARM_BENCH_FAST=1
        // for CI-style smoke runs.
        let fast = std::env::var("PARM_BENCH_FAST").is_ok();
        Bencher {
            warmup: Duration::from_millis(if fast { 20 } else { 200 }),
            measure: Duration::from_millis(if fast { 80 } else { 800 }),
            min_samples: if fast { 5 } else { 15 },
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new() -> Bencher {
        Bencher::default()
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn bench<F, R>(&mut self, name: &str, mut f: F) -> BenchResult
    where
        F: FnMut() -> R,
    {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let est = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;

        // Choose a batch size so each sample takes ~measure/min_samples.
        let target_sample = self.measure.as_secs_f64() / self.min_samples as f64;
        let batch = ((target_sample / est.max(1e-9)).ceil() as u64).max(1);

        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters: u64 = 0;
        let start = Instant::now();
        while start.elapsed() < self.measure || samples.len() < self.min_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
            if samples.len() > 10_000 {
                break; // pathological fast function; enough signal
            }
        }

        let res = BenchResult {
            name: name.to_string(),
            median: stats::percentile(&samples, 50.0),
            mad: stats::mad(&samples),
            iterations: total_iters,
            samples: samples.len(),
        };
        println!("{}", res.summary());
        self.results.push(res.clone());
        res
    }

    /// All results measured so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render results as a JSON array (for report collection).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::arr(self.results.iter().map(|r| {
            Json::obj(vec![
                ("name", Json::str(&r.name)),
                ("median_s", Json::num(r.median)),
                ("mad_s", Json::num(r.mad)),
                ("iterations", Json::num(r.iterations as f64)),
                ("samples", Json::num(r.samples as f64)),
            ])
        }))
    }
}

/// Standard header printed at the top of every bench binary, so `cargo
/// bench` output is self-describing.
pub fn bench_header(title: &str, paper_ref: &str) {
    println!("\n=== {title} ===");
    println!("reproduces: {paper_ref}");
    println!("{}", "-".repeat(72));
}

/// Shared entry point for the paper-artifact bench binaries: prints the
/// standard header, runs the table/figure generator against the reports
/// directory (`reports/`, overridable via `PARM_REPORTS_DIR`), and prints
/// its rendered output. Every `benches/<name>.rs` paper stub is exactly
/// one call to this.
pub fn run_paper_bench<F>(name: &str, entry: &str, generate: F) -> anyhow::Result<()>
where
    F: FnOnce(&std::path::Path) -> anyhow::Result<String>,
{
    bench_header(name, entry);
    let dir = std::env::var("PARM_REPORTS_DIR").unwrap_or_else(|_| "reports".into());
    let out = generate(std::path::Path::new(&dir))?;
    println!("{out}");
    println!("reports written to {dir}/");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_samples: 3,
            results: Vec::new(),
        };
        let r = b.bench("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.median > 0.0);
        assert!(r.iterations > 0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn json_report_shape() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(5),
            min_samples: 2,
            results: Vec::new(),
        };
        b.bench("x", || 1 + 1);
        let j = b.to_json();
        assert_eq!(j.at(0).get("name").as_str().unwrap(), "x");
        assert!(j.at(0).get("median_s").as_f64().unwrap() >= 0.0);
    }
}
