//! Stable content hashing for cache keys and plan manifests.
//!
//! The std `Hasher` machinery is randomized per process (SipHash keys) and
//! its output is explicitly not stable across Rust versions, so anything
//! written to disk — plan-artifact manifests, the sweep's content-addressed
//! case cache — hashes through this module instead: FNV-1a over bytes,
//! 64-bit, rendered as a fixed-width lowercase hex id. The inputs are
//! always *canonical encodings* (the compact JSON form of a config or
//! topology), so two values hash equal iff their documents are identical.

/// Incremental FNV-1a 64-bit hasher.
#[derive(Debug, Clone, Copy)]
pub struct Fnv64(u64);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(FNV_OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    /// Feed a string plus a `\x1f` unit separator, so concatenated fields
    /// cannot collide by boundary shifting (`"ab" + "c"` vs `"a" + "bc"`).
    pub fn write_str(&mut self, s: &str) {
        self.write(s.as_bytes());
        self.write(&[0x1f]);
    }

    pub fn finish(&self) -> u64 {
        self.0
    }

    /// Fixed-width lowercase hex rendering of the digest.
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// Hash a sequence of string fields (each separator-delimited) to a hex id.
pub fn fnv64_hex(parts: &[&str]) -> String {
    let mut h = Fnv64::new();
    for p in parts {
        h.write_str(p);
    }
    h.hex()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // FNV-1a("") = offset basis; FNV-1a("a") = 0xaf63dc4c8601ec8c.
        assert_eq!(Fnv64::new().finish(), FNV_OFFSET);
        let mut h = Fnv64::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn hex_is_fixed_width() {
        let id = fnv64_hex(&["x"]);
        assert_eq!(id.len(), 16);
        assert!(id.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn field_boundaries_matter() {
        assert_ne!(fnv64_hex(&["ab", "c"]), fnv64_hex(&["a", "bc"]));
        assert_ne!(fnv64_hex(&["ab"]), fnv64_hex(&["ab", ""]));
    }

    #[test]
    fn deterministic_across_calls() {
        assert_eq!(fnv64_hex(&["stable", "key"]), fnv64_hex(&["stable", "key"]));
    }
}
