//! Statistics helpers: summary stats, percentiles, histograms, and the
//! ordinary-least-squares line fit used by the α-β performance model
//! (paper §V-A: "employ a least square fitting method to estimate them").

/// Arithmetic mean. Returns 0 for an empty slice (callers treat empty
/// sample sets as "no signal").
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean — the right average for speedup ratios (Table IV).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = pos - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

/// Median absolute deviation — robust spread estimate for bench timings.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let med = percentile(xs, 50.0);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    percentile(&devs, 50.0)
}

/// Result of an ordinary-least-squares fit `y ≈ intercept + slope * x`.
///
/// In the α-β communication model the intercept is α (startup latency) and
/// the slope is β (per-element transfer time).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub intercept: f64,
    pub slope: f64,
    /// Coefficient of determination (1 = perfect linear fit).
    pub r2: f64,
}

impl LinearFit {
    pub fn predict(&self, x: f64) -> f64 {
        self.intercept + self.slope * x
    }
}

/// Ordinary least squares over (x, y) pairs. Requires ≥ 2 distinct x.
pub fn least_squares(points: &[(f64, f64)]) -> Option<LinearFit> {
    let n = points.len() as f64;
    if points.len() < 2 {
        return None;
    }
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-30 {
        return None; // all x identical — slope undefined
    }
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    let my = sy / n;
    let ss_tot: f64 = points.iter().map(|p| (p.1 - my) * (p.1 - my)).sum();
    let ss_res: f64 = points
        .iter()
        .map(|p| {
            let e = p.1 - (intercept + slope * p.0);
            e * e
        })
        .sum();
    let r2 = if ss_tot < 1e-30 { 1.0 } else { 1.0 - ss_res / ss_tot };
    Some(LinearFit { intercept, slope, r2 })
}

/// Fixed-width histogram over `[lo, hi)` with `bins` buckets; values outside
/// the range are clamped into the terminal buckets (Fig 7 style).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<usize>,
    pub total: usize,
}

impl Histogram {
    pub fn build(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(bins > 0 && hi > lo);
        let mut counts = vec![0usize; bins];
        for &x in xs {
            let t = ((x - lo) / (hi - lo) * bins as f64).floor();
            let idx = (t.max(0.0) as usize).min(bins - 1);
            counts[idx] += 1;
        }
        Histogram { lo, hi, counts, total: xs.len() }
    }

    /// Fraction of samples at or above `threshold` (e.g. "speedup ≥ 4× in
    /// ~89% of cases").
    pub fn frac_at_least(xs: &[f64], threshold: f64) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter().filter(|&&x| x >= threshold).count() as f64 / xs.len() as f64
    }

    /// Bucket boundaries as (lo, hi) pairs.
    pub fn edges(&self) -> Vec<(f64, f64)> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| (self.lo + w * i as f64, self.lo + w * (i + 1) as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn ols_recovers_exact_line() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 3.0 + 0.5 * i as f64)).collect();
        let fit = least_squares(&pts).unwrap();
        assert!((fit.intercept - 3.0).abs() < 1e-9);
        assert!((fit.slope - 0.5).abs() < 1e-9);
        assert!((fit.r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ols_alpha_beta_shape() {
        // Synthetic collective timings: t = 1e-4 + 5e-10 * bytes + noise-free
        let sizes = [1e5, 1e6, 1e7, 1e8];
        let pts: Vec<(f64, f64)> = sizes.iter().map(|&s| (s, 1e-4 + 5e-10 * s)).collect();
        let fit = least_squares(&pts).unwrap();
        assert!((fit.intercept - 1e-4).abs() < 1e-9);
        assert!((fit.slope - 5e-10).abs() < 1e-15);
    }

    #[test]
    fn ols_degenerate() {
        assert!(least_squares(&[(1.0, 2.0)]).is_none());
        assert!(least_squares(&[(1.0, 2.0), (1.0, 3.0)]).is_none());
    }

    #[test]
    fn histogram_clamps_and_counts() {
        let h = Histogram::build(&[-1.0, 0.5, 1.5, 2.5, 99.0], 0.0, 3.0, 3);
        assert_eq!(h.counts, vec![2, 1, 2]);
        assert_eq!(h.total, 5);
        let edges = h.edges();
        assert_eq!(edges[0], (0.0, 1.0));
    }

    #[test]
    fn frac_at_least() {
        assert!((Histogram::frac_at_least(&[1.0, 4.0, 5.0, 3.9], 4.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mad_robust() {
        let xs = [1.0, 1.0, 1.0, 100.0];
        assert_eq!(mad(&xs), 0.0);
    }
}
