//! # Parm — efficient MoE training with dedicated MP+EP+ESP schedules
//!
//! Reproduction of *Parm: Efficient Training of Large Sparsely-Activated
//! Models with Dedicated Schedules* (Pan et al., 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: cluster topology, process
//!   groups, the Baseline/S1/S2/Parm schedules, the fused EP&ESP-AlltoAll
//!   and SAA collectives, the α-β performance model with Algorithm 1
//!   auto-selection, a discrete-event network simulator, a distributed
//!   data-plane executor, and the training driver.
//! * **Layer 2 (python/compile)** — the MoE transformer in JAX, AOT-lowered
//!   to HLO text artifacts loaded here via PJRT (the `runtime` module).
//! * **Layer 1 (python/compile/kernels)** — the expert-FFN Pallas kernel.

pub mod bench;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod control;
pub mod moe;
pub mod perfmodel;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod traffic;
pub mod train;
pub mod util;
