//! Drive-run bench summary: folds a [`DriveOutcome`] into the sweep's
//! `BENCH_sweep.json` perf-trajectory artifact.
//!
//! The sweep writes the document; `parm drive --bench-json` then merges an
//! `online vs. every-static-choice` summary under a `"drive"` key, so one
//! artifact carries both the static-grid throughput and the adaptivity
//! margin. Keys are additive — `ci/bench_regression.py` gates only the
//! sweep throughput fields and ignores unknown keys.

use std::path::Path;

use anyhow::{Context, Result};

use crate::control::DriveOutcome;
use crate::util::json::Json;

/// The compact summary row: totals, the winning static, and the online
/// speedup over it (`> 1` means adaptivity paid for its switch costs).
pub fn drive_summary(outcome: &DriveOutcome) -> Json {
    let (best_kind, best_total) = outcome.best_static();
    Json::obj(vec![
        ("trace", Json::str(&outcome.trace_name)),
        ("cfg", Json::str(&outcome.cfg_id)),
        ("cluster", Json::str(&outcome.cluster_name)),
        ("seed", Json::num(outcome.seed as f64)),
        ("threshold", Json::num(outcome.threshold)),
        ("steps", Json::num(outcome.steps.len() as f64)),
        ("online_total", Json::num(outcome.online_total)),
        ("best_static", Json::str(&best_kind.label())),
        ("best_static_total", Json::num(best_total)),
        ("online_speedup", Json::num(best_total / outcome.online_total)),
        ("switches", Json::num(outcome.switches as f64)),
        ("redecisions", Json::num(outcome.redecisions as f64)),
    ])
}

/// Merge `summary` under `key` in the bench JSON at `path`, creating the
/// document if no producer has written it yet (each producer can run
/// standalone). Existing keys are preserved. The shared merge under
/// `parm drive --bench-json` and `parm lint --bench-json`.
pub fn merge_summary_under(path: &Path, key: &str, summary: &Json) -> Result<()> {
    let mut doc = match std::fs::read_to_string(path) {
        Ok(text) => Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .with_context(|| format!("parsing bench JSON {}", path.display()))?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Json::Obj(Default::default()),
        Err(e) => return Err(e).with_context(|| format!("reading bench JSON {}", path.display())),
    };
    match &mut doc {
        Json::Obj(map) => {
            map.insert(key.to_string(), summary.clone());
        }
        other => anyhow::bail!(
            "bench JSON {} is not an object (found {})",
            path.display(),
            other.to_string()
        ),
    }
    std::fs::write(path, doc.to_pretty())
        .with_context(|| format!("writing bench JSON {}", path.display()))?;
    Ok(())
}

/// Merge `summary` under the `"drive"` key of the bench JSON at `path`.
pub fn merge_drive_summary(path: &Path, summary: &Json) -> Result<()> {
    merge_summary_under(path, "drive", summary)
}

/// Merge `summary` under the `"lint"` key of the bench JSON at `path` —
/// the per-rule finding counts of `parm lint` ride along in
/// `BENCH_sweep.json` next to the sweep and drive summaries.
pub fn merge_lint_summary(path: &Path, summary: &Json) -> Result<()> {
    merge_summary_under(path, "lint", summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::StepDecision;
    use crate::schedule::ScheduleKind;

    fn outcome() -> DriveOutcome {
        DriveOutcome {
            trace_name: "t".into(),
            seed: 7,
            threshold: 0.25,
            switch_frac: 0.5,
            cfg_id: "cfg".into(),
            cluster_name: "cl".into(),
            steps: vec![StepDecision {
                step: 0,
                loads_digest: "d".into(),
                drift: 0.0,
                redecided: false,
                switched: false,
                respan: false,
                kind: ScheduleKind::S1,
                t_iter: 2.0,
                switch_cost: 0.0,
            }],
            statics: vec![(ScheduleKind::S1, 3.0), (ScheduleKind::S2, 2.5)],
            online_total: 2.0,
            switches: 0,
            redecisions: 0,
        }
    }

    #[test]
    fn summary_reports_the_best_static_and_speedup() {
        let s = drive_summary(&outcome());
        assert_eq!(s.get("best_static").as_str().unwrap(), "s2");
        assert_eq!(s.get("best_static_total").as_f64().unwrap(), 2.5);
        assert_eq!(s.get("online_speedup").as_f64().unwrap(), 1.25);
    }

    #[test]
    fn merge_preserves_existing_keys_and_creates_missing_files() {
        let dir = std::env::temp_dir().join(format!("parm_drive_merge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");
        // Fresh file: created as an object with just the drive key.
        let _ = std::fs::remove_file(&path);
        merge_drive_summary(&path, &drive_summary(&outcome())).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("drive").get("trace").as_str().unwrap(), "t");
        // Existing sweep document: untouched except for the new key.
        std::fs::write(&path, r#"{"cases_per_sec_par": 10, "cluster": "x"}"#).unwrap();
        merge_drive_summary(&path, &drive_summary(&outcome())).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("cases_per_sec_par").as_f64().unwrap(), 10.0);
        assert_eq!(doc.get("cluster").as_str().unwrap(), "x");
        assert_eq!(doc.get("drive").get("seed").as_f64().unwrap(), 7.0);
        // Non-object documents are rejected loudly.
        std::fs::write(&path, "[1,2]").unwrap();
        assert!(merge_drive_summary(&path, &Json::Null).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lint_summary_merges_next_to_drive() {
        let dir = std::env::temp_dir().join(format!("parm_lint_merge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");
        let _ = std::fs::remove_file(&path);
        merge_drive_summary(&path, &drive_summary(&outcome())).unwrap();
        let lint = Json::obj(vec![
            ("programs", Json::num(12.0)),
            ("findings", Json::num(0.0)),
        ]);
        merge_lint_summary(&path, &lint).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("drive").get("trace").as_str().unwrap(), "t");
        assert_eq!(doc.get("lint").get("programs").as_f64().unwrap(), 12.0);
        assert_eq!(doc.get("lint").get("findings").as_f64().unwrap(), 0.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
