//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (§VI) from the simulator + perf model + trainer, printing
//! paper-style rows and writing CSV/markdown reports.

pub mod drive;
pub mod paper;
pub mod runner;

pub use drive::{drive_summary, merge_drive_summary, merge_lint_summary, merge_summary_under};
pub use paper::{fig1, fig6, fig7, saa_ablation, selection_accuracy, table4, table5};
pub use runner::{
    case_key, run_sweep, run_sweep_cached, run_sweep_with_threads, sweep_csv, CaseResult,
    ModelCache, SweepCache, SweepOutcome, SweepStats, MAX_SWEEP_THREADS,
};
