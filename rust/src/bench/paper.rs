//! Paper-artifact generators: one function per table/figure of §VI.
//! Each returns rendered text (printed by the bench binaries / CLI) and
//! writes CSV+markdown into `reports/`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::bench::runner::{self, CaseResult};
use crate::config::moe::ParallelDegrees;
use crate::config::{sweep, ClusterTopology, ModelConfig, SweepFilter};
use crate::perfmodel::fit::{measure_collective, CollKind, PerfModel, FIT_SIZES};
use crate::schedule::ScheduleKind;
use crate::train::simtime::model_iteration_time;
use crate::util::stats::{mean, Histogram};
use crate::util::table::{fmt_speedup, Table};

fn write_report(dir: &Path, name: &str, table: &Table) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.csv")), table.to_csv())?;
    std::fs::write(dir.join(format!("{name}.md")), table.to_markdown())?;
    Ok(())
}

/// Fig 1 — communication-time ratio of the baseline schedule over the
/// Table III grid at P = 32 on the 32-GPU cluster (paper: 67.9%–96.0%).
pub fn fig1(reports: &Path) -> Result<String> {
    let cluster = ClusterTopology::testbed_b();
    let configs = sweep::sweep_at_p(&cluster, 32, SweepFilter::Feasible);
    let results = runner::run_sweep(&configs, &cluster, true)?;
    let ratios: Vec<f64> = results.iter().map(|r| r.comm_ratio_baseline * 100.0).collect();

    let mut t = Table::new(&["metric", "value"]).numeric();
    t.row(&["configs".into(), format!("{}", ratios.len())]);
    t.row(&["min comm %".into(), format!("{:.1}", ratios.iter().cloned().fold(f64::MAX, f64::min))]);
    t.row(&["mean comm %".into(), format!("{:.1}", mean(&ratios))]);
    t.row(&["max comm %".into(), format!("{:.1}", ratios.iter().cloned().fold(0.0, f64::max))]);
    let h = Histogram::build(&ratios, 50.0, 100.0, 10);
    for ((lo, hi), n) in h.edges().iter().zip(h.counts.iter()) {
        t.row(&[format!("{lo:.0}–{hi:.0}%"), format!("{n}")]);
    }
    write_report(reports, "fig1_comm_ratio", &t)?;

    // Per-config CSV for plotting.
    let mut detail = Table::new(&["config", "comm_ratio_pct"]).numeric();
    for r in &results {
        detail.row(&[r.cfg.id(), format!("{:.2}", r.comm_ratio_baseline * 100.0)]);
    }
    write_report(reports, "fig1_comm_ratio_detail", &detail)?;
    Ok(format!(
        "Fig 1 — baseline comm-time ratio @32 GPUs (paper: 67.9%–96.0%)\n{}",
        t.to_text()
    ))
}

/// Fig 6 — α-β fits per collective on both testbeds (paper publishes
/// AG_MP: α=6.64e-4/β=5.38e-10 on A; α=1.09e-4/β=7.14e-10 on B).
pub fn fig6(reports: &Path) -> Result<String> {
    let mut t = Table::new(&["testbed", "collective", "alpha (s)", "beta (s/B)", "r²"]).numeric();
    let mut detail = Table::new(&["testbed", "collective", "bytes", "seconds"]).numeric();
    for (cluster, par) in [
        (ClusterTopology::testbed_a(), ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 }),
        (ClusterTopology::testbed_b(), ParallelDegrees { p: 32, n_mp: 4, n_esp: 4 }),
    ] {
        let model = PerfModel::fit(&cluster, par)?;
        for kind in CollKind::ALL {
            let f = model.get(kind);
            t.row(&[
                cluster.name.clone(),
                kind.name().into(),
                format!("{:.3e}", f.intercept),
                format!("{:.3e}", f.slope),
                format!("{:.5}", f.r2),
            ]);
            for &x in &FIT_SIZES {
                let y = measure_collective(&cluster, par, kind, x)?;
                detail.row(&[
                    cluster.name.clone(),
                    kind.name().into(),
                    format!("{x:.0}"),
                    format!("{y:.6e}"),
                ]);
            }
        }
    }
    write_report(reports, "fig6_perf_model", &t)?;
    write_report(reports, "fig6_perf_model_points", &detail)?;
    Ok(format!(
        "Fig 6 — fitted α-β per collective (linear fits, r² ≈ 1)\n{}",
        t.to_text()
    ))
}

fn cell_results<'a>(
    results: &'a [CaseResult],
    n_mp: usize,
    n_esp: usize,
    p: Option<usize>,
) -> Vec<&'a CaseResult> {
    results
        .iter()
        .filter(|r| {
            r.cfg.par.n_mp == n_mp
                && r.cfg.par.n_esp == n_esp
                && p.map(|p| r.cfg.par.p == p).unwrap_or(true)
        })
        .collect()
}

/// Table IV — averaged speedups of S1/S2/SP/SP2/Parm over the baseline
/// per (N_MP, N_ESP) cell, on testbed A and testbed B (8/16/32 GPUs). The
/// SP row extends the paper's table with the chunk-pipelined schedule at
/// its predicted-optimal r; SP-uni is the uniform-span ablation
/// (identical to SP on the paper's uniform-routing grid, and the contrast
/// column for skewed sweeps); SP2 is the chunk-pipelined S2 whose
/// per-chunk combine runs as a chunked SAA (SP × SAA composition).
pub fn table4(reports: &Path) -> Result<String> {
    let tb_a = ClusterTopology::testbed_a();
    let tb_b = ClusterTopology::testbed_b();
    let sweep_a = sweep::sweep_table3(&tb_a, SweepFilter::Feasible);
    let sweep_b = sweep::sweep_table3(&tb_b, SweepFilter::Feasible);
    eprintln!("table4: {} cases on A, {} on B", sweep_a.len(), sweep_b.len());
    let res_a = runner::run_sweep(&sweep_a, &tb_a, true)?;
    let res_b = runner::run_sweep(&sweep_b, &tb_b, true)?;

    let mut t = Table::new(&[
        "Schedule", "N_MP", "N_ESP", "Speedup (T-A)", "T-B 8-GPU", "T-B 16-GPU", "T-B 32-GPU",
    ])
    .numeric();
    let avg = |rs: &[&CaseResult], f: &dyn Fn(&CaseResult) -> f64| -> String {
        if rs.is_empty() {
            "—".into()
        } else {
            fmt_speedup(mean(&rs.iter().map(|r| f(r)).collect::<Vec<_>>()))
        }
    };
    for (sched, f) in [
        ("S1", &CaseResult::speedup_s1 as &dyn Fn(&CaseResult) -> f64),
        ("S2", &CaseResult::speedup_s2),
        ("SP", &CaseResult::speedup_sp),
        ("SP-uni", &CaseResult::speedup_sp_uniform),
        ("SP2", &CaseResult::speedup_sp2),
        ("Parm", &CaseResult::speedup_parm),
    ] {
        for (n_mp, n_esp) in sweep::table4_cells() {
            let a = cell_results(&res_a, n_mp, n_esp, Some(8));
            let b8 = cell_results(&res_b, n_mp, n_esp, Some(8));
            let b16 = cell_results(&res_b, n_mp, n_esp, Some(16));
            let b32 = cell_results(&res_b, n_mp, n_esp, Some(32));
            t.row(&[
                sched.into(),
                format!("{n_mp}"),
                format!("{n_esp}"),
                avg(&a, f),
                avg(&b8, f),
                avg(&b16, f),
                avg(&b32, f),
            ]);
        }
    }
    write_report(reports, "table4_speedups", &t)?;

    // Companion backward-pass table: the same cells, averaged speedup of
    // each family's simulated backward share over the baseline backward
    // (wgrad-AllReduce overlap included). This is the column set the
    // whole-iteration argmin added over the forward-only Table IV.
    let mut tb = Table::new(&[
        "Schedule", "N_MP", "N_ESP", "Bwd speedup (T-A)", "T-B 8-GPU", "T-B 16-GPU", "T-B 32-GPU",
    ])
    .numeric();
    for (sched, f) in [
        ("S1", &(|r: &CaseResult| r.t_bwd_baseline / r.t_bwd_s1) as &dyn Fn(&CaseResult) -> f64),
        ("S2", &|r: &CaseResult| r.t_bwd_baseline / r.t_bwd_s2),
        ("SP", &|r: &CaseResult| r.t_bwd_baseline / r.t_bwd_sp),
        ("SP2", &|r: &CaseResult| r.t_bwd_baseline / r.t_bwd_sp2),
    ] {
        for (n_mp, n_esp) in sweep::table4_cells() {
            let a = cell_results(&res_a, n_mp, n_esp, Some(8));
            let b8 = cell_results(&res_b, n_mp, n_esp, Some(8));
            let b16 = cell_results(&res_b, n_mp, n_esp, Some(16));
            let b32 = cell_results(&res_b, n_mp, n_esp, Some(32));
            tb.row(&[
                sched.into(),
                format!("{n_mp}"),
                format!("{n_esp}"),
                avg(&a, f),
                avg(&b8, f),
                avg(&b16, f),
                avg(&b32, f),
            ]);
        }
    }
    write_report(reports, "table4_backward_speedups", &tb)?;

    // Overall range (the paper's 1.13×–5.77× headline).
    let all: Vec<f64> = res_a
        .iter()
        .chain(res_b.iter())
        .map(|r| r.speedup_parm())
        .collect();
    let lo = all.iter().cloned().fold(f64::MAX, f64::min);
    let hi = all.iter().cloned().fold(0.0, f64::max);
    Ok(format!(
        "Table IV — averaged speedups vs baseline (paper: 1.13×–5.77× overall)\n{}\nbackward-pass speedups (overlapped wgrad-AllReduce)\n{}\noverall Parm speedup range: {:.2}×–{:.2}× over {} cases\n",
        t.to_text(),
        tb.to_text(),
        lo,
        hi,
        all.len()
    ))
}

/// Fig 7 — Parm speedup distribution at P=32, N_MP=N_ESP=4 (paper: avg
/// 4.91×, ≥4× in ~89% of cases).
pub fn fig7(reports: &Path) -> Result<String> {
    let cluster = ClusterTopology::testbed_b();
    let configs: Vec<_> = sweep::sweep_at_p(&cluster, 32, SweepFilter::Feasible)
        .into_iter()
        .filter(|c| c.par.n_mp == 4 && c.par.n_esp == 4)
        .collect();
    let results = runner::run_sweep(&configs, &cluster, true)?;
    let speedups: Vec<f64> = results.iter().map(|r| r.speedup_parm()).collect();

    let h = Histogram::build(&speedups, 1.0, 7.0, 12);
    let mut t = Table::new(&["speedup bucket", "cases", "frac %"]).numeric();
    for ((lo, hi), n) in h.edges().iter().zip(h.counts.iter()) {
        t.row(&[
            format!("{lo:.1}–{hi:.1}×"),
            format!("{n}"),
            format!("{:.1}", 100.0 * *n as f64 / h.total.max(1) as f64),
        ]);
    }
    t.row(&["average".into(), format!("{:.2}×", mean(&speedups)), "".into()]);
    let frac4 = Histogram::frac_at_least(&speedups, 4.0) * 100.0;
    t.row(&["≥ 4×".into(), "".into(), format!("{frac4:.1}")]);
    write_report(reports, "fig7_histogram", &t)?;
    Ok(format!(
        "Fig 7 — Parm speedup @32 GPUs, N_MP=N_ESP=4 (paper: avg 4.91×, ≥4× in ~89%)\n{}",
        t.to_text()
    ))
}

/// Table V — real-world MoE models (BERT-Base / GPT-2), N_MP=N_ESP=4;
/// experts = 2 on testbed A, 8 on testbed B. Paper: ≈3× speedup.
pub fn table5(reports: &Path) -> Result<String> {
    let mut t = Table::new(&[
        "Base Model", "Testbed", "DeepSpeed-MoE (ms)", "Parm (ms)", "Speedup",
    ])
    .numeric();
    let cache = runner::ModelCache::default();
    for (model_ctor, label) in [
        (&ModelConfig::bert_base_moe as &dyn Fn(usize) -> ModelConfig, "BERT-Base"),
        (&ModelConfig::gpt2_moe, "GPT-2"),
    ] {
        for (cluster, experts, tb) in [
            (ClusterTopology::testbed_a(), 2usize, "A"),
            (ClusterTopology::testbed_b(), 8, "B"),
        ] {
            let model = model_ctor(experts);
            let par = ParallelDegrees { p: cluster.total_gpus(), n_mp: 4, n_esp: 4 };
            let layer = model.moe_layer(par);
            let pm = cache.get(&cluster, par)?;
            let choice = crate::perfmodel::choose_schedule(&pm, &layer);
            let base =
                model_iteration_time(&model, par, &cluster, ScheduleKind::Baseline)?;
            let parm = model_iteration_time(&model, par, &cluster, choice)?;
            t.row(&[
                label.into(),
                tb.into(),
                format!("{:.0}", base.total() * 1e3),
                format!("{:.0}", parm.total() * 1e3),
                fmt_speedup(base.total() / parm.total()),
            ]);
        }
    }
    write_report(reports, "table5_realworld", &t)?;
    Ok(format!(
        "Table V — real-world MoE models, N_MP=N_ESP=4 (paper: 2.98×–3.15×)\n{}",
        t.to_text()
    ))
}

/// §VI-C SAA-vs-AAS ablation (paper: SAA ≈ 1.09%/1.12% better).
pub fn saa_ablation(reports: &Path) -> Result<String> {
    let mut t = Table::new(&["testbed", "cases", "mean gain %", "max gain %"]).numeric();
    for cluster in [ClusterTopology::testbed_a(), ClusterTopology::testbed_b()] {
        let configs: Vec<_> = sweep::sweep_table3(&cluster, SweepFilter::Feasible)
            .into_iter()
            .filter(|c| c.par.n_mp >= 2)
            .step_by(7) // decimate: ablation needs a sample, not the grid
            .collect();
        let results = runner::run_sweep(&configs, &cluster, false)?;
        let gains: Vec<f64> = results
            .iter()
            .map(|r| (r.t_s2_aas - r.t_s2) / r.t_s2_aas * 100.0)
            .collect();
        t.row(&[
            cluster.name.clone(),
            format!("{}", gains.len()),
            format!("{:.2}", mean(&gains)),
            format!("{:.2}", gains.iter().cloned().fold(f64::MIN, f64::max)),
        ]);
    }
    write_report(reports, "saa_ablation", &t)?;
    Ok(format!(
        "SAA vs AAS (S2 combine overlap; paper: ~1.1% average gain)\n{}",
        t.to_text()
    ))
}

/// Algorithm-1 selection accuracy (ours): how often the α-β choice agrees
/// with the simulated-best of S1/S2, and the regret when it does not.
pub fn selection_accuracy(reports: &Path) -> Result<String> {
    let mut t =
        Table::new(&["testbed", "cases", "accuracy %", "mean regret %", "max regret %"]).numeric();
    for cluster in [ClusterTopology::testbed_a(), ClusterTopology::testbed_b()] {
        let configs: Vec<_> = sweep::sweep_table3(&cluster, SweepFilter::Feasible)
            .into_iter()
            .filter(|c| c.par.n_mp >= 2)
            .step_by(5)
            .collect();
        let results = runner::run_sweep(&configs, &cluster, false)?;
        let mut correct = 0usize;
        let mut regrets: Vec<f64> = Vec::new();
        for r in &results {
            let best = r.t_s1.min(r.t_s2);
            let got = r.t_parm();
            if (got - best).abs() < 1e-12 {
                correct += 1;
            }
            regrets.push((got - best) / best * 100.0);
        }
        t.row(&[
            cluster.name.clone(),
            format!("{}", results.len()),
            format!("{:.1}", 100.0 * correct as f64 / results.len().max(1) as f64),
            format!("{:.2}", mean(&regrets)),
            format!("{:.2}", regrets.iter().cloned().fold(f64::MIN, f64::max)),
        ]);
    }
    write_report(reports, "selection_accuracy", &t)?;
    Ok(format!(
        "Algorithm 1 selection accuracy (predicted vs simulated best of S1/S2)\n{}",
        t.to_text()
    ))
}

/// Per-(N_MP, N_ESP) breakdown of Parm's choices — which schedule wins
/// where (the §IV-B "not mutually exclusive" claim, quantified).
pub fn choice_breakdown(reports: &Path) -> Result<String> {
    let cluster = ClusterTopology::testbed_b();
    let configs: Vec<_> = sweep::sweep_table3(&cluster, SweepFilter::Feasible)
        .into_iter()
        .filter(|c| c.par.n_mp >= 2)
        .collect();
    let results = runner::run_sweep(&configs, &cluster, true)?;
    let mut counts: BTreeMap<(usize, usize), (usize, usize)> = BTreeMap::new();
    for r in &results {
        let e = counts.entry((r.cfg.par.n_mp, r.cfg.par.n_esp)).or_default();
        let sim_best_s1 = r.t_s1 <= r.t_s2;
        if sim_best_s1 {
            e.0 += 1;
        } else {
            e.1 += 1;
        }
    }
    let mut t = Table::new(&["N_MP", "N_ESP", "S1 wins", "S2 wins"]).numeric();
    for ((n_mp, n_esp), (s1, s2)) in &counts {
        t.row(&[
            format!("{n_mp}"),
            format!("{n_esp}"),
            format!("{s1}"),
            format!("{s2}"),
        ]);
    }
    write_report(reports, "choice_breakdown", &t)?;
    Ok(format!("S1-vs-S2 winner breakdown on {}\n{}", cluster.name, t.to_text()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("parm_bench_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn table5_generates() {
        let out = table5(&tmp()).unwrap();
        assert!(out.contains("BERT-Base"));
        assert!(out.contains("×"));
    }

    #[test]
    fn fig6_generates() {
        let out = fig6(&tmp()).unwrap();
        assert!(out.contains("ag_mp"));
        assert!(out.contains("testbed_a"));
    }
}
