//! Sweep runner: simulate every schedule over a set of MoE layer
//! configurations, with the α-β model (for Parm's choice) fitted once per
//! parallel layout.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::moe::ParallelDegrees;
use crate::config::{ClusterProfile, MoeLayerConfig};
use crate::perfmodel::{choose_schedule, PerfModel};
use crate::schedule::{lowering, ScheduleKind};

/// One configuration's simulated iteration times.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub cfg: MoeLayerConfig,
    pub t_baseline: f64,
    pub t_s1: f64,
    pub t_s2: f64,
    pub t_s2_aas: f64,
    pub parm_choice: ScheduleKind,
    /// Fig 1 quantity: fraction of baseline iteration not covered by
    /// compute.
    pub comm_ratio_baseline: f64,
}

impl CaseResult {
    pub fn t_parm(&self) -> f64 {
        match self.parm_choice {
            ScheduleKind::S1 => self.t_s1,
            _ => self.t_s2,
        }
    }

    pub fn speedup_s1(&self) -> f64 {
        self.t_baseline / self.t_s1
    }

    pub fn speedup_s2(&self) -> f64 {
        self.t_baseline / self.t_s2
    }

    pub fn speedup_parm(&self) -> f64 {
        self.t_baseline / self.t_parm()
    }
}

/// Per-layout α-β model cache (fitting is itself a simulation sweep, so
/// reuse across the hundreds of grid rows sharing a layout).
#[derive(Default)]
pub struct ModelCache {
    map: BTreeMap<(String, usize, usize, usize), PerfModel>,
}

impl ModelCache {
    pub fn get(
        &mut self,
        cluster: &ClusterProfile,
        par: ParallelDegrees,
    ) -> Result<&PerfModel> {
        let key = (cluster.name.clone(), par.p, par.n_mp, par.n_esp);
        if !self.map.contains_key(&key) {
            let m = PerfModel::fit(cluster, par)?;
            self.map.insert(key.clone(), m);
        }
        Ok(&self.map[&key])
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Simulate one configuration under every schedule.
pub fn run_case(
    cfg: &MoeLayerConfig,
    cluster: &ClusterProfile,
    cache: &mut ModelCache,
) -> Result<CaseResult> {
    let base = lowering::simulate_iteration(ScheduleKind::Baseline, cfg, cluster)?;
    let t_s1 = lowering::simulate_iteration(ScheduleKind::S1, cfg, cluster)?.makespan;
    let t_s2 = lowering::simulate_iteration(ScheduleKind::S2, cfg, cluster)?.makespan;
    let t_s2_aas = lowering::simulate_iteration(ScheduleKind::S2Aas, cfg, cluster)?.makespan;
    let model = cache.get(cluster, cfg.par)?;
    let parm_choice = choose_schedule(model, cfg);
    Ok(CaseResult {
        cfg: cfg.clone(),
        t_baseline: base.makespan,
        t_s1,
        t_s2,
        t_s2_aas,
        parm_choice,
        comm_ratio_baseline: base.comm_ratio(),
    })
}

/// Run the whole sweep (progress printed every ~10%).
pub fn run_sweep(
    configs: &[MoeLayerConfig],
    cluster: &ClusterProfile,
    verbose: bool,
) -> Result<Vec<CaseResult>> {
    let mut cache = ModelCache::default();
    let mut out = Vec::with_capacity(configs.len());
    let tick = (configs.len() / 10).max(1);
    for (i, cfg) in configs.iter().enumerate() {
        out.push(run_case(cfg, cluster, &mut cache)?);
        if verbose && (i + 1) % tick == 0 {
            eprintln!("  sweep {}/{} on {}", i + 1, configs.len(), cluster.name);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(p: usize, n_mp: usize, n_esp: usize) -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p, n_mp, n_esp },
            b: 2,
            l: 512,
            e: p / n_esp,
            m: 1024,
            h: 1024,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
        }
    }

    #[test]
    fn case_speedups_exceed_one() {
        let cluster = ClusterProfile::testbed_b_subset(8).unwrap();
        let mut cache = ModelCache::default();
        let r = run_case(&cfg(8, 2, 2), &cluster, &mut cache).unwrap();
        assert!(r.speedup_s1() > 1.0, "{r:?}");
        assert!(r.speedup_s2() > 1.0, "{r:?}");
        assert!(r.speedup_parm() >= r.speedup_s1().min(r.speedup_s2()));
        assert!(r.comm_ratio_baseline > 0.0 && r.comm_ratio_baseline < 1.0);
    }

    #[test]
    fn model_cache_reused() {
        let cluster = ClusterProfile::testbed_b_subset(8).unwrap();
        let mut cache = ModelCache::default();
        run_case(&cfg(8, 2, 2), &cluster, &mut cache).unwrap();
        run_case(&cfg(8, 2, 2), &cluster, &mut cache).unwrap();
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn sweep_runs_small_batch() {
        let cluster = ClusterProfile::testbed_b_subset(8).unwrap();
        let configs = vec![cfg(8, 2, 2), cfg(8, 4, 2), cfg(8, 1, 2)];
        let res = run_sweep(&configs, &cluster, false).unwrap();
        assert_eq!(res.len(), 3);
    }
}
