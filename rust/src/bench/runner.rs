//! Sweep runner: simulate every schedule over a set of MoE layer
//! configurations, with the α-β model (for Parm's choice) fitted once per
//! parallel layout.
//!
//! The sweep parallelizes across `std::thread::scope` workers: each case
//! is an independent deterministic simulation, so workers pull case
//! indices from a shared atomic counter and write into per-index slots —
//! the result vector is byte-identical to the sequential runner's,
//! config-ordered, regardless of thread count or interleaving. The α-β
//! model cache is shared (mutex-guarded map; fitting happens outside the
//! lock, first insert wins).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::config::moe::ParallelDegrees;
use crate::config::{ClusterTopology, MoeLayerConfig};
use crate::perfmodel::{selection, PerfModel};
use crate::schedule::{lowering, ScheduleKind};

/// One configuration's simulated iteration times.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub cfg: MoeLayerConfig,
    pub t_baseline: f64,
    pub t_s1: f64,
    pub t_s2: f64,
    pub t_s2_aas: f64,
    /// Chunk-pipelined schedule at the predicted-optimal `sp_chunks`
    /// (load-aware spans when the config's routing skew is set).
    pub t_sp: f64,
    /// SP with uniform capacity spans at the same chunk count — the
    /// ablation column for the load-aware spans (equals `t_sp` when
    /// `skew == 0`).
    pub t_sp_uniform: f64,
    /// The r* the fitted pipeline model picked for this configuration.
    pub sp_chunks: usize,
    /// Chunk-pipelined S2 (SP × SAA) at the predicted-optimal
    /// `sp2_chunks` — the fourth schedule family.
    pub t_sp2: f64,
    /// The r* the fitted chunked-SAA pipeline model picked.
    pub sp2_chunks: usize,
    /// Generalized Algorithm 1's pick among S1, S2, SP(r*) and SP2(r*).
    pub parm_choice: ScheduleKind,
    /// Fig 1 quantity: fraction of baseline iteration not covered by
    /// compute.
    pub comm_ratio_baseline: f64,
}

impl CaseResult {
    pub fn t_parm(&self) -> f64 {
        match self.parm_choice {
            ScheduleKind::S1 => self.t_s1,
            ScheduleKind::Pipelined { .. } => self.t_sp,
            ScheduleKind::PipelinedS2 { .. } => self.t_sp2,
            _ => self.t_s2,
        }
    }

    pub fn speedup_s1(&self) -> f64 {
        self.t_baseline / self.t_s1
    }

    pub fn speedup_s2(&self) -> f64 {
        self.t_baseline / self.t_s2
    }

    pub fn speedup_sp(&self) -> f64 {
        self.t_baseline / self.t_sp
    }

    pub fn speedup_sp_uniform(&self) -> f64 {
        self.t_baseline / self.t_sp_uniform
    }

    pub fn speedup_sp2(&self) -> f64 {
        self.t_baseline / self.t_sp2
    }

    pub fn speedup_parm(&self) -> f64 {
        self.t_baseline / self.t_parm()
    }
}

/// Render sweep results as the golden-CSV format: config-ordered rows at
/// fixed precision, one per case. Shared verbatim by `parm sweep --csv`
/// and the golden regression test so the CI gate diffs exactly what the
/// runner produced.
pub fn sweep_csv(results: &[CaseResult]) -> String {
    let mut s = String::from(
        "config,t_baseline,t_s1,t_s2,t_s2_aas,t_sp,t_sp_uniform,sp_chunks,t_sp2,sp2_chunks,parm_choice\n",
    );
    for r in results {
        s.push_str(&format!(
            "{},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{},{:.6e},{},{}\n",
            r.cfg.id(),
            r.t_baseline,
            r.t_s1,
            r.t_s2,
            r.t_s2_aas,
            r.t_sp,
            r.t_sp_uniform,
            r.sp_chunks,
            r.t_sp2,
            r.sp2_chunks,
            r.parm_choice.name()
        ));
    }
    s
}

/// Per-layout α-β model cache (fitting is itself a simulation sweep, so
/// reuse across the hundreds of grid rows sharing a layout). Thread-safe:
/// shared by the sweep workers.
#[derive(Default)]
pub struct ModelCache {
    map: Mutex<BTreeMap<(String, usize, usize, usize), PerfModel>>,
}

impl ModelCache {
    /// Fetch (or fit) the model for a layout. Fitting runs outside the
    /// lock — two workers may race to fit the same layout; the first
    /// insert wins and the fit is deterministic, so both see equal models.
    pub fn get(&self, cluster: &ClusterTopology, par: ParallelDegrees) -> Result<PerfModel> {
        let key = (cluster.name.clone(), par.p, par.n_mp, par.n_esp);
        if let Some(m) = self.map.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        let fitted = PerfModel::fit(cluster, par)?;
        let mut map = self.map.lock().unwrap();
        Ok(map.entry(key).or_insert(fitted).clone())
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Simulate one configuration under every schedule (SP at the fitted
/// model's optimal chunk count).
pub fn run_case(
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
    cache: &ModelCache,
) -> Result<CaseResult> {
    let base = lowering::simulate_iteration(ScheduleKind::Baseline, cfg, cluster)?;
    let t_s1 = lowering::simulate_iteration(ScheduleKind::S1, cfg, cluster)?.makespan;
    let t_s2 = lowering::simulate_iteration(ScheduleKind::S2, cfg, cluster)?.makespan;
    let t_s2_aas = lowering::simulate_iteration(ScheduleKind::S2Aas, cfg, cluster)?.makespan;
    let model = cache.get(cluster, cfg.par)?;
    let pred = selection::predict(&model, cfg);
    let sp_chunks = pred.sp_chunks;
    let t_sp = lowering::simulate_iteration(
        ScheduleKind::Pipelined { chunks: sp_chunks },
        cfg,
        cluster,
    )?
    .makespan;
    // Uniform spans only differ from the load-aware ones under skew — skip
    // the extra simulation on the (dominant) uniform grid.
    let t_sp_uniform = if cfg.skew > 0.0 {
        lowering::simulate_iteration(
            ScheduleKind::PipelinedUniform { chunks: sp_chunks },
            cfg,
            cluster,
        )?
        .makespan
    } else {
        t_sp
    };
    let sp2_chunks = pred.sp2_chunks;
    let t_sp2 = lowering::simulate_iteration(
        ScheduleKind::PipelinedS2 { chunks: sp2_chunks },
        cfg,
        cluster,
    )?
    .makespan;
    let parm_choice = pred.best();
    Ok(CaseResult {
        cfg: cfg.clone(),
        t_baseline: base.makespan,
        t_s1,
        t_s2,
        t_s2_aas,
        t_sp,
        t_sp_uniform,
        sp_chunks,
        t_sp2,
        sp2_chunks,
        parm_choice,
        comm_ratio_baseline: base.comm_ratio(),
    })
}

/// Run the whole sweep across all available cores (progress printed every
/// ~10% when `verbose`). Output order is config order — identical to the
/// sequential runner's.
pub fn run_sweep(
    configs: &[MoeLayerConfig],
    cluster: &ClusterTopology,
    verbose: bool,
) -> Result<Vec<CaseResult>> {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_SWEEP_THREADS);
    run_sweep_with_threads(configs, cluster, verbose, threads)
}

/// Hard cap on sweep workers: far above any real machine, low enough that
/// a mistyped `--threads` value errors instead of attempting to spawn an
/// absurd scope.
pub const MAX_SWEEP_THREADS: usize = 1024;

/// Run the sweep on exactly `threads` workers (1 = sequential). Errors on
/// degenerate worker counts (`0`, or beyond [`MAX_SWEEP_THREADS`]) rather
/// than silently clamping them; counts above the case count are reduced
/// to it (extra workers would only spin on an empty queue).
pub fn run_sweep_with_threads(
    configs: &[MoeLayerConfig],
    cluster: &ClusterTopology,
    verbose: bool,
    threads: usize,
) -> Result<Vec<CaseResult>> {
    ensure!(threads >= 1, "sweep needs at least one worker thread (got --threads 0)");
    ensure!(
        threads <= MAX_SWEEP_THREADS,
        "sweep worker count {threads} exceeds the {MAX_SWEEP_THREADS}-thread cap"
    );
    let cache = ModelCache::default();
    let tick = (configs.len() / 10).max(1);
    let threads = threads.min(configs.len().max(1));

    if threads <= 1 {
        let mut out = Vec::with_capacity(configs.len());
        for (i, cfg) in configs.iter().enumerate() {
            out.push(run_case(cfg, cluster, &cache)?);
            if verbose && (i + 1) % tick == 0 {
                eprintln!("  sweep {}/{} on {}", i + 1, configs.len(), cluster.name);
            }
        }
        return Ok(out);
    }

    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<CaseResult>>>> =
        (0..configs.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= configs.len() {
                    break;
                }
                let r = run_case(&configs[i], cluster, &cache);
                *slots[i].lock().unwrap() = Some(r);
                let d = done.fetch_add(1, Ordering::Relaxed) + 1;
                if verbose && d % tick == 0 {
                    eprintln!("  sweep {}/{} on {}", d, configs.len(), cluster.name);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every claimed case completes"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(p: usize, n_mp: usize, n_esp: usize) -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p, n_mp, n_esp },
            b: 2,
            l: 512,
            e: p / n_esp,
            m: 1024,
            h: 1024,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
            skew: 0.0,
        }
    }

    #[test]
    fn case_speedups_exceed_one() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        let r = run_case(&cfg(8, 2, 2), &cluster, &cache).unwrap();
        assert!(r.speedup_s1() > 1.0, "{r:?}");
        assert!(r.speedup_s2() > 1.0, "{r:?}");
        assert!(r.t_sp > 0.0 && r.sp_chunks >= 1, "{r:?}");
        assert!(r.t_sp2 > 0.0 && r.sp2_chunks >= 1, "{r:?}");
        assert!(
            r.speedup_parm()
                >= r.speedup_s1().min(r.speedup_s2()).min(r.speedup_sp()).min(r.speedup_sp2()),
            "{r:?}"
        );
        assert!(r.comm_ratio_baseline > 0.0 && r.comm_ratio_baseline < 1.0);
    }

    #[test]
    fn sweep_csv_shape_is_stable() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        let r = run_case(&cfg(8, 2, 2), &cluster, &cache).unwrap();
        let csv = sweep_csv(&[r]);
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "config,t_baseline,t_s1,t_s2,t_s2_aas,t_sp,t_sp_uniform,sp_chunks,t_sp2,sp2_chunks,parm_choice"
        );
        let row = lines.next().unwrap();
        assert_eq!(row.split(',').count(), 11, "{row}");
        assert!(row.starts_with("p8_mp2_esp2_"), "{row}");
    }

    #[test]
    fn skewed_case_carries_the_uniform_span_column() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        let mut c = cfg(8, 2, 2);
        let uniform = run_case(&c, &cluster, &cache).unwrap();
        assert_eq!(uniform.t_sp_uniform, uniform.t_sp, "no skew ⇒ identical spans");
        c.skew = 1.5;
        let skewed = run_case(&c, &cluster, &cache).unwrap();
        assert!(skewed.t_sp_uniform > 0.0 && skewed.t_sp > 0.0);
        assert!(skewed.cfg.id().ends_with("_s1.5"));
        // The CSV row carries both SP variants.
        let csv = sweep_csv(&[skewed]);
        assert!(csv.lines().nth(1).unwrap().contains("_s1.5,"));
    }

    #[test]
    fn model_cache_reused() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        run_case(&cfg(8, 2, 2), &cluster, &cache).unwrap();
        run_case(&cfg(8, 2, 2), &cluster, &cache).unwrap();
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn sweep_runs_small_batch() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let configs = vec![cfg(8, 2, 2), cfg(8, 4, 2), cfg(8, 1, 2)];
        let res = run_sweep(&configs, &cluster, false).unwrap();
        assert_eq!(res.len(), 3);
    }

    #[test]
    fn rejects_degenerate_worker_counts() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let configs = vec![cfg(8, 2, 2)];
        let err = run_sweep_with_threads(&configs, &cluster, false, 0).unwrap_err();
        assert!(err.to_string().contains("worker"), "{err}");
        assert!(run_sweep_with_threads(&configs, &cluster, false, MAX_SWEEP_THREADS + 1).is_err());
        // Counts above the case count still run (reduced to the queue).
        assert_eq!(run_sweep_with_threads(&configs, &cluster, false, 64).unwrap().len(), 1);
    }

    #[test]
    fn parallel_sweep_matches_sequential_byte_for_byte() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let configs = vec![cfg(8, 2, 2), cfg(8, 4, 2), cfg(8, 1, 2), cfg(8, 2, 4), cfg(8, 4, 4)];
        let seq = run_sweep_with_threads(&configs, &cluster, false, 1).unwrap();
        for threads in [2usize, 4] {
            let par = run_sweep_with_threads(&configs, &cluster, false, threads).unwrap();
            assert_eq!(
                format!("{seq:?}"),
                format!("{par:?}"),
                "parallel sweep diverged at {threads} threads"
            );
        }
    }
}
