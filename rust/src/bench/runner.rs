//! Sweep runner: simulate every schedule over a set of MoE layer
//! configurations, with the α-β model (for Parm's choice) fitted once per
//! parallel layout.
//!
//! The sweep parallelizes across `std::thread::scope` workers: each case
//! is an independent deterministic simulation, so workers pull case
//! indices from a shared atomic counter and write into per-index slots —
//! the result vector is byte-identical to the sequential runner's,
//! config-ordered, regardless of thread count or interleaving. The α-β
//! model cache is shared: one slot per layout, with the fit running under
//! the slot's own lock so concurrent requests for the same layout
//! coalesce into a single fit.
//!
//! ## Incremental re-runs (`--cache-dir`)
//!
//! With a [`SweepCache`], results persist across invocations as
//! content-addressed JSONL: each case is keyed by [`case_key`] — the
//! stable FNV-1a of the plan schema version, the topology's canonical
//! JSON, and the configuration's canonical JSON — so editing one knob
//! only re-simulates the cases whose keys changed, and any topology or
//! schema change invalidates everything at once. Floats round-trip
//! bit-exactly through the JSON layer (shortest-representation printing),
//! so a warm sweep's CSV is byte-identical to the cold run's. The shared
//! fit cache persists through the same directory (`models.jsonl`), keyed
//! the same way.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use crate::config::moe::ParallelDegrees;
use crate::config::{ClusterTopology, MoeLayerConfig};
use crate::perfmodel::{selection, PerfModel, PLAN_SCHEMA_VERSION};
use crate::schedule::{lowering, ScheduleKind};
use crate::util::hash::fnv64_hex;
use crate::util::json::Json;

/// One configuration's simulated iteration times.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub cfg: MoeLayerConfig,
    pub t_baseline: f64,
    pub t_s1: f64,
    pub t_s2: f64,
    pub t_s2_aas: f64,
    /// Chunk-pipelined schedule at the predicted-optimal `sp_chunks`
    /// (load-aware spans when the config's routing skew is set).
    pub t_sp: f64,
    /// SP with uniform capacity spans at the same chunk count — the
    /// ablation column for the load-aware spans (equals `t_sp` when
    /// `skew == 0`).
    pub t_sp_uniform: f64,
    /// The r* the fitted pipeline model picked for this configuration.
    pub sp_chunks: usize,
    /// Chunk-pipelined S2 (SP × SAA) at the predicted-optimal
    /// `sp2_chunks` — the fourth schedule family.
    pub t_sp2: f64,
    /// The r* the fitted chunked-SAA pipeline model picked.
    pub sp2_chunks: usize,
    /// Simulated backward-pass time per family (iteration minus forward):
    /// the overlapped wgrad-AllReduce backward programs the whole-iteration
    /// argmin compares.
    pub t_bwd_baseline: f64,
    pub t_bwd_s1: f64,
    pub t_bwd_s2: f64,
    /// Backward share of SP at `sp_chunks`.
    pub t_bwd_sp: f64,
    /// Backward share of SP2 at `sp2_chunks`.
    pub t_bwd_sp2: f64,
    /// Generalized Algorithm 1's pick among S1, S2, SP(r*) and SP2(r*).
    pub parm_choice: ScheduleKind,
    /// Fig 1 quantity: fraction of baseline iteration not covered by
    /// compute.
    pub comm_ratio_baseline: f64,
}

/// Serialize a schedule kind as `{"kind", "chunks"}` — the family name
/// and the chunk count as separate fields, because the concatenated
/// string form is ambiguous (`"sp23"` parses as the sp2 family at r = 3,
/// not SP at r = 23).
fn kind_to_json(k: ScheduleKind) -> Json {
    let chunks = match k {
        ScheduleKind::Pipelined { chunks }
        | ScheduleKind::PipelinedUniform { chunks }
        | ScheduleKind::PipelinedS2 { chunks } => chunks,
        _ => 0,
    };
    Json::obj(vec![("kind", Json::str(k.name())), ("chunks", Json::num(chunks as f64))])
}

fn kind_from_json(j: &Json) -> Result<ScheduleKind> {
    let name = j.req_str("kind")?;
    let chunks = j.req_usize("chunks")?;
    let kind =
        ScheduleKind::parse(name).ok_or_else(|| anyhow!("unknown schedule kind `{name}`"))?;
    Ok(match kind {
        ScheduleKind::Pipelined { .. } => ScheduleKind::Pipelined { chunks },
        ScheduleKind::PipelinedUniform { .. } => ScheduleKind::PipelinedUniform { chunks },
        ScheduleKind::PipelinedS2 { .. } => ScheduleKind::PipelinedS2 { chunks },
        k => k,
    })
}

impl CaseResult {
    pub fn t_parm(&self) -> f64 {
        match self.parm_choice {
            ScheduleKind::S1 => self.t_s1,
            ScheduleKind::Pipelined { .. } => self.t_sp,
            ScheduleKind::PipelinedS2 { .. } => self.t_sp2,
            _ => self.t_s2,
        }
    }

    pub fn speedup_s1(&self) -> f64 {
        self.t_baseline / self.t_s1
    }

    pub fn speedup_s2(&self) -> f64 {
        self.t_baseline / self.t_s2
    }

    pub fn speedup_sp(&self) -> f64 {
        self.t_baseline / self.t_sp
    }

    pub fn speedup_sp_uniform(&self) -> f64 {
        self.t_baseline / self.t_sp_uniform
    }

    pub fn speedup_sp2(&self) -> f64 {
        self.t_baseline / self.t_sp2
    }

    pub fn speedup_parm(&self) -> f64 {
        self.t_baseline / self.t_parm()
    }

    /// Serialize for the on-disk case cache. Every float survives the
    /// roundtrip bit-exactly, so a cached case renders the same CSV row
    /// as the simulation that produced it.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cfg", self.cfg.to_json()),
            ("t_baseline", Json::num(self.t_baseline)),
            ("t_s1", Json::num(self.t_s1)),
            ("t_s2", Json::num(self.t_s2)),
            ("t_s2_aas", Json::num(self.t_s2_aas)),
            ("t_sp", Json::num(self.t_sp)),
            ("t_sp_uniform", Json::num(self.t_sp_uniform)),
            ("sp_chunks", Json::num(self.sp_chunks as f64)),
            ("t_sp2", Json::num(self.t_sp2)),
            ("sp2_chunks", Json::num(self.sp2_chunks as f64)),
            ("t_bwd_baseline", Json::num(self.t_bwd_baseline)),
            ("t_bwd_s1", Json::num(self.t_bwd_s1)),
            ("t_bwd_s2", Json::num(self.t_bwd_s2)),
            ("t_bwd_sp", Json::num(self.t_bwd_sp)),
            ("t_bwd_sp2", Json::num(self.t_bwd_sp2)),
            ("parm_choice", kind_to_json(self.parm_choice)),
            ("comm_ratio_baseline", Json::num(self.comm_ratio_baseline)),
        ])
    }

    /// Inverse of [`CaseResult::to_json`].
    pub fn from_json(j: &Json) -> Result<CaseResult> {
        Ok(CaseResult {
            cfg: MoeLayerConfig::from_json(j.get("cfg"))?,
            t_baseline: j.req_f64("t_baseline")?,
            t_s1: j.req_f64("t_s1")?,
            t_s2: j.req_f64("t_s2")?,
            t_s2_aas: j.req_f64("t_s2_aas")?,
            t_sp: j.req_f64("t_sp")?,
            t_sp_uniform: j.req_f64("t_sp_uniform")?,
            sp_chunks: j.req_usize("sp_chunks")?,
            t_sp2: j.req_f64("t_sp2")?,
            sp2_chunks: j.req_usize("sp2_chunks")?,
            t_bwd_baseline: j.req_f64("t_bwd_baseline")?,
            t_bwd_s1: j.req_f64("t_bwd_s1")?,
            t_bwd_s2: j.req_f64("t_bwd_s2")?,
            t_bwd_sp: j.req_f64("t_bwd_sp")?,
            t_bwd_sp2: j.req_f64("t_bwd_sp2")?,
            parm_choice: kind_from_json(j.get("parm_choice"))?,
            comm_ratio_baseline: j.req_f64("comm_ratio_baseline")?,
        })
    }
}

/// Render sweep results as the golden-CSV format: config-ordered rows at
/// fixed precision, one per case. Shared verbatim by `parm sweep --csv`
/// and the golden regression test so the CI gate diffs exactly what the
/// runner produced.
pub fn sweep_csv(results: &[CaseResult]) -> String {
    let mut s = String::from(
        "config,t_baseline,t_s1,t_s2,t_s2_aas,t_sp,t_sp_uniform,sp_chunks,t_sp2,sp2_chunks,t_bwd_baseline,t_bwd_s1,t_bwd_s2,t_bwd_sp,t_bwd_sp2,parm_choice\n",
    );
    for r in results {
        s.push_str(&format!(
            "{},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{},{:.6e},{},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{}\n",
            r.cfg.id(),
            r.t_baseline,
            r.t_s1,
            r.t_s2,
            r.t_s2_aas,
            r.t_sp,
            r.t_sp_uniform,
            r.sp_chunks,
            r.t_sp2,
            r.sp2_chunks,
            r.t_bwd_baseline,
            r.t_bwd_s1,
            r.t_bwd_s2,
            r.t_bwd_sp,
            r.t_bwd_sp2,
            r.parm_choice.name()
        ));
    }
    s
}

/// Content-addressed cache key for one sweep case: FNV-1a over the plan
/// schema version, the topology's content hash, and the configuration's
/// canonical JSON. Any schema bump, topology edit, or config change moves
/// the key — a cache can go stale only by *missing*, never by lying.
pub fn case_key(cluster_hash: &str, cfg: &MoeLayerConfig) -> String {
    let version = format!("parmcase.v{PLAN_SCHEMA_VERSION}");
    fnv64_hex(&[&version, cluster_hash, &cfg.to_json().to_string()])
}

/// Cache key for one persisted α-β fit (same derivation as [`case_key`],
/// over the parallel layout instead of a full layer config).
fn fit_key(cluster_hash: &str, par: ParallelDegrees) -> String {
    let version = format!("parmfit.v{PLAN_SCHEMA_VERSION}");
    let layout = format!("p{}_mp{}_esp{}", par.p, par.n_mp, par.n_esp);
    fnv64_hex(&[&version, cluster_hash, &layout])
}

type ModelSlot = Arc<Mutex<Option<PerfModel>>>;

/// Per-layout α-β model cache (fitting is itself a simulation sweep, so
/// reuse across the hundreds of grid rows sharing a layout). Thread-safe:
/// shared by the sweep workers.
#[derive(Default)]
pub struct ModelCache {
    map: Mutex<BTreeMap<(String, usize, usize, usize), ModelSlot>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    fit_nanos: AtomicU64,
}

impl ModelCache {
    /// Fetch (or fit) the model for a layout. The map lock is held only
    /// long enough to clone the layout's slot; the fit runs under the
    /// slot's own lock, so concurrent requests for the *same* layout
    /// coalesce into one fit (latecomers block on the slot and reuse it)
    /// while distinct layouts still fit in parallel.
    pub fn get(&self, cluster: &ClusterTopology, par: ParallelDegrees) -> Result<PerfModel> {
        let key = (cluster.name.clone(), par.p, par.n_mp, par.n_esp);
        let slot = Arc::clone(self.map.lock().unwrap().entry(key).or_default());
        let mut resolved = slot.lock().unwrap();
        if let Some(m) = resolved.as_ref() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(m.clone());
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let start = Instant::now();
        let fitted = PerfModel::fit(cluster, par)?;
        self.fit_nanos.fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        *resolved = Some(fitted.clone());
        Ok(fitted)
    }

    /// Pre-populate a layout's slot with an already-fitted model (from a
    /// plan artifact or the persisted fit cache). A model someone already
    /// fitted wins over the seed — they are equal anyway (fitting is
    /// deterministic) and the resolved slot must never change.
    pub fn seed(&self, model: PerfModel) {
        let key = (model.cluster_name.clone(), model.par.p, model.par.n_mp, model.par.n_esp);
        let slot = Arc::clone(self.map.lock().unwrap().entry(key).or_default());
        let mut resolved = slot.lock().unwrap();
        if resolved.is_none() {
            *resolved = Some(model);
        }
    }

    /// Snapshot of every resolved model, in key order.
    pub fn models(&self) -> Vec<PerfModel> {
        let map = self.map.lock().unwrap();
        map.values().filter_map(|s| s.lock().unwrap().clone()).collect()
    }

    /// Number of layouts with a resolved (fitted or seeded) model.
    pub fn len(&self) -> usize {
        let map = self.map.lock().unwrap();
        map.values().filter(|s| s.lock().unwrap().is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups answered from a resolved slot.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to fit (seeding counts as neither).
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Total time spent inside [`PerfModel::fit`], in seconds — summed
    /// over workers, so it can exceed the wall time of a parallel sweep.
    pub fn fit_seconds(&self) -> f64 {
        self.fit_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }
}

/// File names inside a `--cache-dir`.
pub const CASES_FILE: &str = "cases.jsonl";
pub const MODELS_FILE: &str = "models.jsonl";

/// The on-disk, content-addressed sweep cache behind `--cache-dir`:
/// `cases.jsonl` holds one simulated [`CaseResult`] per line under its
/// [`case_key`]; `models.jsonl` persists the shared fit cache the same
/// way. See the module doc for the invalidation story.
pub struct SweepCache {
    dir: PathBuf,
    cases: BTreeMap<String, CaseResult>,
}

impl SweepCache {
    /// Open a cache directory (creating it if needed) and load any prior
    /// case entries. A malformed line is a hard error naming the file and
    /// line — a corrupt cache should be deleted, never half-trusted.
    pub fn open(dir: &Path) -> Result<SweepCache> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        let mut cases = BTreeMap::new();
        let path = dir.join(CASES_FILE);
        if path.exists() {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            for (lineno, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let bad = |e: &dyn std::fmt::Display| {
                    anyhow!(
                        "{}:{}: {e} — delete the cache dir to rebuild",
                        path.display(),
                        lineno + 1
                    )
                };
                let j = Json::parse(line).map_err(|e| bad(&e))?;
                let key = j.req_str("key").map_err(|e| bad(&e))?.to_string();
                cases.insert(key, CaseResult::from_json(j.get("case")).map_err(|e| bad(&e))?);
            }
        }
        Ok(SweepCache { dir: dir.to_path_buf(), cases })
    }

    pub fn lookup(&self, key: &str) -> Option<&CaseResult> {
        self.cases.get(key)
    }

    pub fn len(&self) -> usize {
        self.cases.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cases.is_empty()
    }

    /// Append newly simulated cases. The sweep appends its misses in grid
    /// order from the coordinating thread, so the file stays deterministic
    /// for a given history of runs.
    pub fn append_cases(&mut self, entries: &[(String, CaseResult)]) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        let mut buf = String::new();
        for (key, case) in entries {
            let line = Json::obj(vec![("key", Json::str(key)), ("case", case.to_json())]);
            buf.push_str(&line.to_string());
            buf.push('\n');
            self.cases.insert(key.clone(), case.clone());
        }
        let path = self.dir.join(CASES_FILE);
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        f.write_all(buf.as_bytes()).with_context(|| format!("appending {}", path.display()))
    }

    /// Seed `cache` with persisted fits whose keys still match the current
    /// topology. A topology edit changes the expected key, so stale models
    /// are skipped (they'll be refitted and rewritten), never trusted.
    /// Returns how many models were seeded.
    pub fn seed_models(&self, cluster: &ClusterTopology, cache: &ModelCache) -> Result<usize> {
        let path = self.dir.join(MODELS_FILE);
        if !path.exists() {
            return Ok(0);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let hash = cluster.content_hash();
        let mut seeded = 0;
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line)
                .map_err(|e| anyhow!("{}:{}: {e}", path.display(), lineno + 1))?;
            let key = j.req_str("key")?.to_string();
            let model = PerfModel::from_json(j.get("model"))
                .map_err(|e| anyhow!("{}:{}: {e}", path.display(), lineno + 1))?;
            if key == fit_key(&hash, model.par) {
                cache.seed(model);
                seeded += 1;
            }
        }
        Ok(seeded)
    }

    /// Rewrite the persisted fit cache from the in-memory one (whole-file:
    /// the model set is small and BTreeMap order keeps it deterministic).
    pub fn store_models(&self, cluster: &ClusterTopology, cache: &ModelCache) -> Result<()> {
        let hash = cluster.content_hash();
        let mut buf = String::new();
        for m in cache.models() {
            let key = fit_key(&hash, m.par);
            let line = Json::obj(vec![("key", Json::str(&key)), ("model", m.to_json())]);
            buf.push_str(&line.to_string());
            buf.push('\n');
        }
        let path = self.dir.join(MODELS_FILE);
        std::fs::write(&path, buf).with_context(|| format!("writing {}", path.display()))
    }
}

/// Cache-effectiveness counters and the fit/sim timing breakdown a sweep
/// reports (`parm sweep` prints these; `BENCH_sweep.json` carries them).
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepStats {
    /// Cases answered from the on-disk cache (0 when it is disabled).
    pub case_hits: usize,
    /// Cases that had to be simulated.
    pub case_misses: usize,
    /// α-β model lookups answered from the in-memory cache.
    pub fit_hits: usize,
    /// α-β model lookups that had to fit.
    pub fit_misses: usize,
    /// Models pre-seeded from a plan artifact or the persisted fit cache.
    pub seeded_models: usize,
    /// Time inside [`PerfModel::fit`], seconds (summed over workers).
    pub fit_seconds: f64,
    /// Wall time of the simulate phase — cache misses only, fitting
    /// included (fits happen lazily inside the first case of a layout).
    pub sim_seconds: f64,
}

/// A sweep's results plus the counters describing how they were obtained.
pub struct SweepOutcome {
    /// Config-ordered case results — byte-identical CSV regardless of
    /// thread count or cache state.
    pub results: Vec<CaseResult>,
    pub stats: SweepStats,
}

/// Run the whole sweep across all available cores (progress printed every
/// ~10% when `verbose`). Output order is config order — identical to the
/// sequential runner's.
pub fn run_sweep(
    configs: &[MoeLayerConfig],
    cluster: &ClusterTopology,
    verbose: bool,
) -> Result<Vec<CaseResult>> {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_SWEEP_THREADS);
    run_sweep_with_threads(configs, cluster, verbose, threads)
}

/// Hard cap on sweep workers: far above any real machine, low enough that
/// a mistyped `--threads` value errors instead of attempting to spawn an
/// absurd scope.
pub const MAX_SWEEP_THREADS: usize = 1024;

/// Run the sweep on exactly `threads` workers (1 = sequential), with no
/// on-disk cache. Errors on degenerate worker counts (`0`, or beyond
/// [`MAX_SWEEP_THREADS`]) rather than silently clamping them; counts
/// above the case count are reduced to it.
pub fn run_sweep_with_threads(
    configs: &[MoeLayerConfig],
    cluster: &ClusterTopology,
    verbose: bool,
    threads: usize,
) -> Result<Vec<CaseResult>> {
    Ok(run_sweep_cached(configs, cluster, verbose, threads, None, &[])?.results)
}

/// Simulate one configuration under every schedule (SP at the fitted
/// model's optimal chunk count).
pub fn run_case(
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
    cache: &ModelCache,
) -> Result<CaseResult> {
    let base = lowering::simulate_iteration(ScheduleKind::Baseline, cfg, cluster)?;
    let t_s1 = lowering::simulate_iteration(ScheduleKind::S1, cfg, cluster)?.makespan;
    let t_s2 = lowering::simulate_iteration(ScheduleKind::S2, cfg, cluster)?.makespan;
    let t_s2_aas = lowering::simulate_iteration(ScheduleKind::S2Aas, cfg, cluster)?.makespan;
    // Backward share per family: iteration minus the forward-only makespan
    // of the same schedule. This is the simulated ground truth the
    // whole-iteration argmin (and its closed forms) is judged against.
    let fwd_of = |kind| Ok::<f64, anyhow::Error>(lowering::simulate_forward(kind, cfg, cluster)?.makespan);
    let t_bwd_baseline = base.makespan - fwd_of(ScheduleKind::Baseline)?;
    let t_bwd_s1 = t_s1 - fwd_of(ScheduleKind::S1)?;
    let t_bwd_s2 = t_s2 - fwd_of(ScheduleKind::S2)?;
    let model = cache.get(cluster, cfg.par)?;
    let pred = selection::predict(&model, cfg);
    let sp_chunks = pred.sp_chunks;
    let t_sp = lowering::simulate_iteration(
        ScheduleKind::Pipelined { chunks: sp_chunks },
        cfg,
        cluster,
    )?
    .makespan;
    // Uniform spans only differ from the load-aware ones under skew — skip
    // the extra simulation on the (dominant) uniform grid.
    let t_sp_uniform = if cfg.skew > 0.0 {
        lowering::simulate_iteration(
            ScheduleKind::PipelinedUniform { chunks: sp_chunks },
            cfg,
            cluster,
        )?
        .makespan
    } else {
        t_sp
    };
    let sp2_chunks = pred.sp2_chunks;
    let t_sp2 = lowering::simulate_iteration(
        ScheduleKind::PipelinedS2 { chunks: sp2_chunks },
        cfg,
        cluster,
    )?
    .makespan;
    let t_bwd_sp = t_sp - fwd_of(ScheduleKind::Pipelined { chunks: sp_chunks })?;
    let t_bwd_sp2 = t_sp2 - fwd_of(ScheduleKind::PipelinedS2 { chunks: sp2_chunks })?;
    let parm_choice = pred.best();
    Ok(CaseResult {
        cfg: cfg.clone(),
        t_baseline: base.makespan,
        t_s1,
        t_s2,
        t_s2_aas,
        t_sp,
        t_sp_uniform,
        sp_chunks,
        t_sp2,
        sp2_chunks,
        t_bwd_baseline,
        t_bwd_s1,
        t_bwd_s2,
        t_bwd_sp,
        t_bwd_sp2,
        parm_choice,
        comm_ratio_baseline: base.comm_ratio(),
    })
}

/// The full incremental sweep: resolve what the on-disk cache already
/// knows, simulate only the misses (on `threads` workers), persist the
/// new cases and fitted models, and report hit/miss + timing counters.
/// `seed_models` pre-populates the fit cache (e.g. from a plan artifact)
/// so those layouts are never refitted.
pub fn run_sweep_cached(
    configs: &[MoeLayerConfig],
    cluster: &ClusterTopology,
    verbose: bool,
    threads: usize,
    cache_dir: Option<&Path>,
    seed_models: &[PerfModel],
) -> Result<SweepOutcome> {
    ensure!(threads >= 1, "sweep needs at least one worker thread (got --threads 0)");
    ensure!(
        threads <= MAX_SWEEP_THREADS,
        "sweep worker count {threads} exceeds the {MAX_SWEEP_THREADS}-thread cap"
    );
    let cache = ModelCache::default();
    for m in seed_models {
        cache.seed(m.clone());
    }
    let mut seeded = seed_models.len();
    let mut disk = cache_dir.map(SweepCache::open).transpose()?;
    if let Some(d) = &disk {
        seeded += d.seed_models(cluster, &cache)?;
    }

    // Resolve hits up front; the workers only ever see the miss list.
    let cluster_hash = cluster.content_hash();
    let keys: Vec<String> = configs.iter().map(|c| case_key(&cluster_hash, c)).collect();
    let mut slots: Vec<Option<CaseResult>> = keys
        .iter()
        .map(|k| disk.as_ref().and_then(|d| d.lookup(k)).cloned())
        .collect();
    let misses: Vec<usize> = (0..configs.len()).filter(|&i| slots[i].is_none()).collect();
    let case_hits = configs.len() - misses.len();

    let sim_start = Instant::now();
    let workers = threads.min(misses.len().max(1));
    let tick = (misses.len() / 10).max(1);
    if workers <= 1 {
        for (done, &i) in misses.iter().enumerate() {
            slots[i] = Some(run_case(&configs[i], cluster, &cache)?);
            if verbose && (done + 1) % tick == 0 {
                eprintln!("  sweep {}/{} on {}", done + 1, misses.len(), cluster.name);
            }
        }
    } else {
        let next = AtomicUsize::new(0);
        let done = AtomicUsize::new(0);
        let sim_slots: Vec<Mutex<Option<Result<CaseResult>>>> =
            misses.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let j = next.fetch_add(1, Ordering::Relaxed);
                    if j >= misses.len() {
                        break;
                    }
                    let r = run_case(&configs[misses[j]], cluster, &cache);
                    *sim_slots[j].lock().unwrap() = Some(r);
                    let d = done.fetch_add(1, Ordering::Relaxed) + 1;
                    if verbose && d % tick == 0 {
                        eprintln!("  sweep {}/{} on {}", d, misses.len(), cluster.name);
                    }
                });
            }
        });
        for (j, slot) in sim_slots.into_iter().enumerate() {
            let r = slot.into_inner().unwrap().expect("every claimed case completes")?;
            slots[misses[j]] = Some(r);
        }
    }
    let sim_seconds = sim_start.elapsed().as_secs_f64();

    if let Some(d) = &mut disk {
        let fresh: Vec<(String, CaseResult)> = misses
            .iter()
            .map(|&i| (keys[i].clone(), slots[i].clone().expect("miss was simulated")))
            .collect();
        d.append_cases(&fresh)?;
        d.store_models(cluster, &cache)?;
    }

    let stats = SweepStats {
        case_hits,
        case_misses: misses.len(),
        fit_hits: cache.hits(),
        fit_misses: cache.misses(),
        seeded_models: seeded,
        fit_seconds: cache.fit_seconds(),
        sim_seconds,
    };
    let results = slots.into_iter().map(|s| s.expect("every slot resolved")).collect();
    Ok(SweepOutcome { results, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(p: usize, n_mp: usize, n_esp: usize) -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p, n_mp, n_esp },
            b: 2,
            l: 512,
            e: p / n_esp,
            m: 1024,
            h: 1024,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        }
    }

    fn temp_cache_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("parm_runner_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn case_speedups_exceed_one() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        let r = run_case(&cfg(8, 2, 2), &cluster, &cache).unwrap();
        assert!(r.speedup_s1() > 1.0, "{r:?}");
        assert!(r.speedup_s2() > 1.0, "{r:?}");
        assert!(r.t_sp > 0.0 && r.sp_chunks >= 1, "{r:?}");
        assert!(r.t_sp2 > 0.0 && r.sp2_chunks >= 1, "{r:?}");
        // Backward dominates forward (dgrad + wgrad ≈ 2× the flops, plus
        // the adjoint AllGathers), so every backward column is positive
        // and at least the family's forward share.
        for (t_iter, t_bwd) in [
            (r.t_baseline, r.t_bwd_baseline),
            (r.t_s1, r.t_bwd_s1),
            (r.t_s2, r.t_bwd_s2),
            (r.t_sp, r.t_bwd_sp),
            (r.t_sp2, r.t_bwd_sp2),
        ] {
            assert!(t_bwd > 0.0 && t_bwd < t_iter, "{r:?}");
            assert!(t_bwd >= t_iter - t_bwd, "backward should dominate: {r:?}");
        }
        assert!(
            r.speedup_parm()
                >= r.speedup_s1().min(r.speedup_s2()).min(r.speedup_sp()).min(r.speedup_sp2()),
            "{r:?}"
        );
        assert!(r.comm_ratio_baseline > 0.0 && r.comm_ratio_baseline < 1.0);
    }

    #[test]
    fn sweep_csv_shape_is_stable() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        let r = run_case(&cfg(8, 2, 2), &cluster, &cache).unwrap();
        let csv = sweep_csv(&[r]);
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "config,t_baseline,t_s1,t_s2,t_s2_aas,t_sp,t_sp_uniform,sp_chunks,t_sp2,sp2_chunks,t_bwd_baseline,t_bwd_s1,t_bwd_s2,t_bwd_sp,t_bwd_sp2,parm_choice"
        );
        let row = lines.next().unwrap();
        assert_eq!(row.split(',').count(), 16, "{row}");
        assert!(row.starts_with("p8_mp2_esp2_"), "{row}");
    }

    #[test]
    fn skewed_case_carries_the_uniform_span_column() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        let mut c = cfg(8, 2, 2);
        let uniform = run_case(&c, &cluster, &cache).unwrap();
        assert_eq!(uniform.t_sp_uniform, uniform.t_sp, "no skew ⇒ identical spans");
        c.skew = 1.5;
        let skewed = run_case(&c, &cluster, &cache).unwrap();
        assert!(skewed.t_sp_uniform > 0.0 && skewed.t_sp > 0.0);
        assert!(skewed.cfg.id().ends_with("_s1.5"));
        // The CSV row carries both SP variants.
        let csv = sweep_csv(&[skewed]);
        assert!(csv.lines().nth(1).unwrap().contains("_s1.5,"));
    }

    #[test]
    fn model_cache_reused() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        run_case(&cfg(8, 2, 2), &cluster, &cache).unwrap();
        run_case(&cfg(8, 2, 2), &cluster, &cache).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert!(cache.fit_seconds() > 0.0);
    }

    #[test]
    fn model_cache_coalesces_concurrent_fits() {
        // Four workers race for the same layout: exactly one fit happens,
        // the rest block on the slot and reuse it.
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| cache.get(&cluster, par).unwrap());
            }
        });
        assert_eq!(cache.misses(), 1, "duplicate in-flight fits must coalesce");
        assert_eq!(cache.hits(), 3);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn seeded_model_is_a_hit_not_a_fit() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let fitted = PerfModel::fit(&cluster, par).unwrap();
        let cache = ModelCache::default();
        cache.seed(fitted);
        assert_eq!(cache.len(), 1);
        cache.get(&cluster, par).unwrap();
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 0);
    }

    #[test]
    fn case_result_json_roundtrip_is_exact() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let cache = ModelCache::default();
        let mut c = cfg(8, 2, 2);
        c.skew = 1.5; // exercise the skew field and load-aware columns
        let r = run_case(&c, &cluster, &cache).unwrap();
        let back = CaseResult::from_json(&r.to_json()).unwrap();
        assert_eq!(format!("{back:?}"), format!("{r:?}"));
        // Bit-exact floats ⇒ identical CSV bytes, the cache's contract.
        assert_eq!(sweep_csv(&[back]), sweep_csv(&[r]));
    }

    #[test]
    fn schedule_kind_json_disambiguates_chunked_families() {
        // "sp" at r = 23 and "sp2" at r = 3 collide in the concatenated
        // string form; the {kind, chunks} object keeps them distinct.
        for k in [
            ScheduleKind::Pipelined { chunks: 23 },
            ScheduleKind::PipelinedS2 { chunks: 3 },
            ScheduleKind::PipelinedUniform { chunks: 4 },
            ScheduleKind::S1,
            ScheduleKind::S2,
        ] {
            assert_eq!(kind_from_json(&kind_to_json(k)).unwrap(), k, "{k:?}");
        }
    }

    #[test]
    fn sweep_runs_small_batch() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let configs = vec![cfg(8, 2, 2), cfg(8, 4, 2), cfg(8, 1, 2)];
        let res = run_sweep(&configs, &cluster, false).unwrap();
        assert_eq!(res.len(), 3);
    }

    #[test]
    fn rejects_degenerate_worker_counts() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let configs = vec![cfg(8, 2, 2)];
        let err = run_sweep_with_threads(&configs, &cluster, false, 0).unwrap_err();
        assert!(err.to_string().contains("worker"), "{err}");
        assert!(run_sweep_with_threads(&configs, &cluster, false, MAX_SWEEP_THREADS + 1).is_err());
        // Counts above the case count still run (reduced to the queue).
        assert_eq!(run_sweep_with_threads(&configs, &cluster, false, 64).unwrap().len(), 1);
    }

    #[test]
    fn parallel_sweep_matches_sequential_byte_for_byte() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let configs = vec![cfg(8, 2, 2), cfg(8, 4, 2), cfg(8, 1, 2), cfg(8, 2, 4), cfg(8, 4, 4)];
        let seq = run_sweep_with_threads(&configs, &cluster, false, 1).unwrap();
        for threads in [2usize, 4] {
            let par = run_sweep_with_threads(&configs, &cluster, false, threads).unwrap();
            assert_eq!(
                format!("{seq:?}"),
                format!("{par:?}"),
                "parallel sweep diverged at {threads} threads"
            );
        }
    }

    #[test]
    fn warm_cache_sweep_is_all_hits_and_byte_identical() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let configs = vec![cfg(8, 2, 2), cfg(8, 4, 2), cfg(8, 2, 4)];
        let dir = temp_cache_dir("warm");
        let cold = run_sweep_cached(&configs, &cluster, false, 2, Some(&dir), &[]).unwrap();
        assert_eq!(cold.stats.case_hits, 0);
        assert_eq!(cold.stats.case_misses, 3);
        let warm = run_sweep_cached(&configs, &cluster, false, 2, Some(&dir), &[]).unwrap();
        assert_eq!(warm.stats.case_hits, 3);
        assert_eq!(warm.stats.case_misses, 0);
        assert_eq!(warm.stats.fit_misses, 0, "persisted fits must seed the model cache");
        assert_eq!(sweep_csv(&warm.results), sweep_csv(&cold.results));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cache_key_tracks_schema_topology_and_config() {
        let a = ClusterTopology::testbed_b_subset(8).unwrap();
        let b = ClusterTopology::testbed_b_subset(16).unwrap();
        let c1 = cfg(8, 2, 2);
        let mut c2 = cfg(8, 2, 2);
        c2.b *= 2;
        assert_eq!(case_key(&a.content_hash(), &c1), case_key(&a.content_hash(), &c1));
        assert_ne!(case_key(&a.content_hash(), &c1), case_key(&b.content_hash(), &c1));
        assert_ne!(case_key(&a.content_hash(), &c1), case_key(&a.content_hash(), &c2));
    }

    #[test]
    fn partial_cache_only_simulates_the_new_cases() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let dir = temp_cache_dir("partial");
        let first = vec![cfg(8, 2, 2), cfg(8, 4, 2)];
        run_sweep_cached(&first, &cluster, false, 1, Some(&dir), &[]).unwrap();
        // One knob edited ⇒ exactly one new key misses.
        let mut edited = first.clone();
        edited[1].b *= 2;
        let second = run_sweep_cached(&edited, &cluster, false, 1, Some(&dir), &[]).unwrap();
        assert_eq!(second.stats.case_hits, 1);
        assert_eq!(second.stats.case_misses, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
