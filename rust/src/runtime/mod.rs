//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the hot path.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only place the compiled graphs are touched at run time. Artifacts are
//! described by `artifacts/manifest.json` (name, file, input/output
//! shapes) and compiled lazily, cached per name.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactSpec, Manifest};
pub use client::{HostTensor, Runtime};

use anyhow::Result;

/// Smoke helper used by `parm doctor`: bring up the PJRT CPU client.
pub fn smoke() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(format!("{} x{}", client.platform_name(), client.device_count()))
}
