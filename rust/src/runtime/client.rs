//! PJRT client wrapper: HLO-text → compiled executable (cached) → execute.
//!
//! Follows the /opt/xla-example `load_hlo` recipe: HLO **text** is the
//! interchange format (jax ≥ 0.5 emits 64-bit-id protos this XLA build
//! rejects; the text parser reassigns ids). Computations are lowered with
//! `return_tuple=True`, so every execution returns a tuple literal.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifact::Manifest;

/// A host-side tensor: f32 data plus dims, the only dtype crossing the
/// runtime boundary (artifacts compute in f32; bf16 is an L1 concern).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("shape {dims:?} wants {n} elements, got {}", data.len());
        }
        Ok(HostTensor { dims, data })
    }

    pub fn zeros(dims: Vec<usize>) -> HostTensor {
        let n = dims.iter().product();
        HostTensor { dims, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> HostTensor {
        HostTensor { dims: vec![], data: vec![v] }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(HostTensor { dims, data })
    }
}

/// The runtime: one PJRT CPU client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load the manifest under `artifacts_dir` and create the PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the named artifact.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.manifest.hlo_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact `{name}`"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` on `inputs`; returns the tuple elements.
    /// Input shapes are validated against the manifest before dispatch.
    pub fn exec(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.get(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact `{name}` takes {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, want)) in inputs.iter().zip(spec.inputs.iter()).enumerate() {
            if &t.dims != want {
                bail!(
                    "artifact `{name}` input {i}: expected shape {want:?}, got {:?}",
                    t.dims
                );
            }
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let out: Vec<HostTensor> =
            parts.iter().map(HostTensor::from_literal).collect::<Result<_>>()?;
        if out.len() != spec.outputs.len() {
            bail!(
                "artifact `{name}` declared {} outputs, produced {}",
                spec.outputs.len(),
                out.len()
            );
        }
        Ok(out)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checked() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert_eq!(HostTensor::zeros(vec![2, 2]).data.len(), 4);
        assert_eq!(HostTensor::scalar(3.0).dims.len(), 0);
    }

    // Round-trip execution tests live in rust/tests/runtime_pjrt.rs (they
    // need `make artifacts` to have run).
}
