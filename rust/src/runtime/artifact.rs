//! The artifact manifest: what `python/compile/aot.py` built.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One AOT artifact (an HLO-text file plus its signature).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// File name relative to the artifacts directory.
    pub file: String,
    /// Input shapes, row-major dims per argument (f32 unless noted).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (the computation returns a tuple of these).
    pub outputs: Vec<Vec<usize>>,
    /// Free-form metadata (config echo from the Python side).
    pub meta: Json,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<ArtifactSpec> {
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
            j.req_arr(key)
                .map_err(|e| anyhow!("{e}"))?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .ok_or_else(|| anyhow!("{key}: expected array of dims"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("{key}: bad dim")))
                        .collect()
                })
                .collect()
        };
        Ok(ArtifactSpec {
            name: j.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string(),
            file: j.req_str("file").map_err(|e| anyhow!("{e}"))?.to_string(),
            inputs: shapes("inputs")?,
            outputs: shapes("outputs")?,
            meta: j.get("meta").clone(),
        })
    }
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let artifacts = j
            .req_arr("artifacts")
            .map_err(|e| anyhow!("{e}"))?
            .iter()
            .map(ArtifactSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name).ok_or_else(|| {
            anyhow!(
                "artifact `{name}` not in manifest (have: {})",
                self.artifacts
                    .iter()
                    .map(|a| a.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let spec = self.get(name)?;
        let p = self.dir.join(&spec.file);
        if !p.exists() {
            bail!("artifact file {p:?} missing — re-run `make artifacts`");
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join(format!("parm_manifest_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_manifest(
            &dir,
            r#"{"artifacts":[{"name":"f","file":"f.hlo.txt",
                "inputs":[[2,3]],"outputs":[[2,3]],"meta":{"k":1}}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("f").unwrap();
        assert_eq!(a.inputs, vec![vec![2, 3]]);
        assert_eq!(a.meta.get("k").as_usize(), Some(1));
        assert!(m.get("nope").is_err());
        // hlo_path errors until the file exists.
        assert!(m.hlo_path("f").is_err());
        std::fs::write(dir.join("f.hlo.txt"), "x").unwrap();
        assert!(m.hlo_path("f").is_ok());
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
