//! GShard-style gating: softmax router, top-k selection, capacity-limited
//! slot assignment, dense (E, C, M) dispatch construction and the inverse
//! combine (un-gate).
//!
//! Determinism contract: token order is preserved through top-k and slot
//! assignment (first-come-first-served per expert, ties broken by expert
//! index), so identical inputs produce identical routing on every rank —
//! the property the baseline/S1/S2 equivalence rests on.

use crate::moe::linalg;

/// Routing decisions for one gate invocation over `n_tokens` tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchInfo {
    pub n_tokens: usize,
    pub e: usize,
    /// Capacity per expert for this invocation.
    pub capacity: usize,
    /// (token, expert, slot, combine-weight), in assignment order.
    pub assignments: Vec<(usize, usize, usize, f32)>,
    /// Tokens whose k-th choice overflowed an expert's capacity.
    pub dropped: usize,
    /// Per-expert load statistics: slots actually filled in each expert's
    /// capacity block (`expert_loads[j] ≤ capacity`). This is the gate-side
    /// signal the load-aware SP chunk spans consume — under skewed routing
    /// the filled prefixes are unequal, and spans balanced on these counts
    /// recover the dispatch/compute overlap uniform spans lose.
    pub expert_loads: Vec<usize>,
}

impl DispatchInfo {
    /// Largest per-expert load divided by the mean load — 1.0 for perfectly
    /// balanced routing, `E` when one expert takes everything.
    pub fn load_imbalance(&self) -> f64 {
        let max = self.expert_loads.iter().copied().max().unwrap_or(0);
        let total: usize = self.expert_loads.iter().sum();
        if total == 0 {
            return 1.0;
        }
        max as f64 * self.e as f64 / total as f64
    }
}

/// Capacity per expert: `C = ceil(k·f·n/E)`, floored at 1, optionally
/// rounded up to a multiple of `multiple_of` (S2 splits capacity across
/// the MP group, so it must divide evenly).
pub fn capacity(n_tokens: usize, e: usize, k: usize, f: f64, multiple_of: usize) -> usize {
    let c = (k as f64 * f * n_tokens as f64 / e as f64).ceil() as usize;
    let c = c.max(1);
    c.div_ceil(multiple_of) * multiple_of
}

/// Zipf-style router bias for a skew exponent: expert `j` gets
/// `-skew·ln(j+1)` added to its logit before the softmax, so expert
/// popularity follows `(j+1)^{-skew}` (expert 0 hottest). `None` for
/// `skew == 0` — the unbiased router. Shared by the data plane and the
/// dense reference so every schedule routes identically under skew.
pub fn skew_bias(e: usize, skew: f64) -> Option<Vec<f32>> {
    if skew <= 0.0 {
        return None;
    }
    Some((0..e).map(|j| (-skew * ((j + 1) as f64).ln()) as f32).collect())
}

/// Route `tokens` ((n, m) row-major) through the gate `wg` ((m, e)).
pub fn gate(
    tokens: &[f32],
    wg: &[f32],
    n: usize,
    m: usize,
    e: usize,
    k: usize,
    cap: usize,
) -> DispatchInfo {
    gate_biased(tokens, wg, None, n, m, e, k, cap)
}

/// [`gate`] with an optional per-expert logit bias (the routing-skew knob;
/// see [`skew_bias`]).
#[allow(clippy::too_many_arguments)]
pub fn gate_biased(
    tokens: &[f32],
    wg: &[f32],
    bias: Option<&[f32]>,
    n: usize,
    m: usize,
    e: usize,
    k: usize,
    cap: usize,
) -> DispatchInfo {
    assert!(k <= e, "top-{k} of {e} experts");
    let mut logits = linalg::matmul(tokens, wg, n, m, e);
    if let Some(b) = bias {
        assert_eq!(b.len(), e, "one bias per expert");
        for t in 0..n {
            for (j, &bj) in b.iter().enumerate() {
                logits[t * e + j] += bj;
            }
        }
    }
    linalg::softmax_rows(&mut logits, n, e);

    let mut counts = vec![0usize; e];
    let mut assignments = Vec::with_capacity(n * k);
    let mut dropped = 0usize;
    // Scratch for the partial top-k selection (alloc-free per token):
    // taken[j] marks experts already chosen for this token.
    let mut taken = vec![false; e];
    for t in 0..n {
        let probs = &logits[t * e..(t + 1) * e];
        // Top-k by k max-scans (k ≤ 2 in practice; O(k·E), no sort, no
        // per-token allocation). Strict `>` keeps the lowest index among
        // ties — same order the previous sort-based selection produced.
        taken.iter_mut().for_each(|x| *x = false);
        for _ in 0..k {
            let mut best = usize::MAX;
            let mut best_p = f32::NEG_INFINITY;
            for (expert, &p) in probs.iter().enumerate() {
                if !taken[expert] && p > best_p {
                    best = expert;
                    best_p = p;
                }
            }
            // NaN logits compare false against NEG_INFINITY, so the scan
            // can finish with no winner. Route such tokens to the
            // lowest-index untaken expert with zero combine weight instead
            // of indexing `taken[usize::MAX]`.
            let (expert, w) = if best == usize::MAX {
                let fallback = taken
                    .iter()
                    .position(|t| !*t)
                    .expect("k ≤ e leaves an untaken expert");
                (fallback, 0.0)
            } else {
                (best, probs[best])
            };
            taken[expert] = true;
            if counts[expert] < cap {
                assignments.push((t, expert, counts[expert], w));
                counts[expert] += 1;
            } else {
                dropped += 1;
            }
        }
    }
    DispatchInfo { n_tokens: n, e, capacity: cap, assignments, dropped, expert_loads: counts }
}

/// Build the dense (E, C, M) dispatch tensor (zero-padded).
pub fn build_dispatch(info: &DispatchInfo, tokens: &[f32], m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; info.e * info.capacity * m];
    for &(t, expert, slot, _w) in &info.assignments {
        let dst = (expert * info.capacity + slot) * m;
        let src = t * m;
        out[dst..dst + m].copy_from_slice(&tokens[src..src + m]);
    }
    out
}

/// Un-gate: scatter expert outputs ((E, C, M)) back to token order with
/// combine weights: `y[t] = Σ w·expert_out[e, slot]` over t's assignments.
pub fn combine(info: &DispatchInfo, expert_out: &[f32], m: usize) -> Vec<f32> {
    assert_eq!(expert_out.len(), info.e * info.capacity * m);
    let mut y = vec![0.0f32; info.n_tokens * m];
    for &(t, expert, slot, w) in &info.assignments {
        let src = (expert * info.capacity + slot) * m;
        let dst = t * m;
        for i in 0..m {
            y[dst + i] += w * expert_out[src + i];
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::propcheck::check;

    #[test]
    fn capacity_formula() {
        assert_eq!(capacity(128, 4, 2, 1.2, 1), 77); // ceil(2·1.2·128/4)
        assert_eq!(capacity(128, 4, 2, 1.2, 4), 80); // rounded to ×4
        assert_eq!(capacity(1, 64, 1, 1.0, 1), 1); // floor at 1
    }

    #[test]
    fn gate_routes_to_topk() {
        // Identity-ish gate: 2 tokens, 2 experts, strongly separated.
        let tokens = vec![10.0, 0.0, 0.0, 10.0]; // (2, 2)
        let wg = vec![1.0, 0.0, 0.0, 1.0]; // (2, 2) identity
        let info = gate(&tokens, &wg, 2, 2, 2, 1, 4);
        assert_eq!(info.assignments.len(), 2);
        assert_eq!(info.assignments[0].1, 0); // token 0 → expert 0
        assert_eq!(info.assignments[1].1, 1); // token 1 → expert 1
        assert_eq!(info.dropped, 0);
        for &(_, _, _, w) in &info.assignments {
            assert!(w > 0.99); // softmax saturated
        }
    }

    #[test]
    fn capacity_drops_overflow() {
        // Every token prefers expert 0; capacity 1 forces drops.
        let tokens = vec![5.0, 0.0, 5.0, 0.0, 5.0, 0.0]; // 3 tokens
        let wg = vec![1.0, 0.0, 0.0, 1.0];
        let info = gate(&tokens, &wg, 3, 2, 2, 1, 1);
        assert_eq!(info.dropped, 2);
        // First token won the slot.
        assert_eq!(info.assignments[0].0, 0);
    }

    #[test]
    fn dispatch_combine_roundtrip_identity_experts() {
        // With identity experts and top-1 saturated routing, combine ∘
        // dispatch ≈ identity (weight ≈ 1).
        let tokens = vec![10.0, 0.0, 0.0, 10.0];
        let wg = vec![1.0, 0.0, 0.0, 1.0];
        let info = gate(&tokens, &wg, 2, 2, 2, 1, 2);
        let d = build_dispatch(&info, &tokens, 2);
        let y = combine(&info, &d, 2);
        for (a, b) in y.iter().zip(tokens.iter()) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn prop_no_drops_with_generous_capacity() {
        check("gate-generous-capacity", 30, |rng| {
            let n = rng.range(1, 16);
            let m = rng.range(1, 8);
            let e = rng.range(1, 6);
            let k = rng.range(1, e.min(3));
            let tokens = rng.f32_vec(n * m);
            let wg = rng.f32_vec(m * e);
            let info = gate(&tokens, &wg, n, m, e, k, n.max(1) * k);
            if info.dropped != 0 {
                return Err(format!("dropped {} with cap ≥ n·k", info.dropped));
            }
            if info.assignments.len() != n * k {
                return Err("not all tokens assigned".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_slots_unique_per_expert() {
        check("gate-slots-unique", 30, |rng| {
            let n = rng.range(1, 20);
            let m = rng.range(1, 6);
            let e = rng.range(2, 6);
            let tokens = rng.f32_vec(n * m);
            let wg = rng.f32_vec(m * e);
            let cap = rng.range(1, 8);
            let info = gate(&tokens, &wg, n, m, e, 2.min(e), cap);
            let mut seen = std::collections::HashSet::new();
            for &(_, expert, slot, _) in &info.assignments {
                if slot >= cap {
                    return Err(format!("slot {slot} ≥ cap {cap}"));
                }
                if !seen.insert((expert, slot)) {
                    return Err(format!("duplicate slot ({expert},{slot})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_gate_deterministic() {
        check("gate-deterministic", 10, |rng| {
            let n = 8;
            let m = 4;
            let e = 4;
            let tokens = rng.f32_vec(n * m);
            let wg = rng.f32_vec(m * e);
            let a = gate(&tokens, &wg, n, m, e, 2, 6);
            let b = gate(&tokens, &wg, n, m, e, 2, 6);
            if a != b {
                return Err("gate not deterministic".into());
            }
            Ok(())
        });
    }

    #[test]
    fn nan_logits_fall_back_instead_of_panicking() {
        // Regression: NaN router logits compare false against
        // NEG_INFINITY, leaving `best == usize::MAX` and panicking with an
        // index out of bounds. NaN tokens must route to the lowest-index
        // untaken experts with zero weight.
        let tokens = vec![f32::NAN, 1.0, 0.5, f32::NAN]; // (2, 2); token 0 NaN
        let wg = vec![1.0, 0.0, 0.0, 1.0];
        let info = gate(&tokens, &wg, 2, 2, 2, 2, 4);
        assert_eq!(info.assignments.len(), 4);
        // NaN tokens take experts 0 then 1 (lowest untaken first), weight 0.
        let t0: Vec<(usize, f32)> = info
            .assignments
            .iter()
            .filter(|(t, ..)| *t == 0)
            .map(|&(_, e, _, w)| (e, w))
            .collect();
        assert_eq!(t0, vec![(0, 0.0), (1, 0.0)]);
        // The finite token still routes normally with finite weights.
        assert!(info
            .assignments
            .iter()
            .filter(|(t, ..)| *t == 1)
            .all(|&(_, _, _, w)| w.is_finite()));
        assert_eq!(info.dropped, 0);
    }

    #[test]
    fn nan_logits_respect_capacity() {
        // All-NaN tokens all fall back to expert 0 first; capacity still
        // limits the slots and counts drops as usual.
        let tokens = vec![f32::NAN; 3 * 2];
        let wg = vec![1.0, 0.0, 0.0, 1.0];
        let info = gate(&tokens, &wg, 3, 2, 2, 1, 1);
        assert_eq!(info.dropped, 2);
        assert_eq!(info.expert_loads, vec![1, 0]);
    }

    #[test]
    fn expert_loads_count_filled_slots() {
        // Every token prefers expert 0; capacity 2 fills two slots there.
        let tokens = vec![5.0, 0.0, 5.0, 0.0, 5.0, 0.0];
        let wg = vec![1.0, 0.0, 0.0, 1.0];
        let info = gate(&tokens, &wg, 3, 2, 2, 1, 2);
        assert_eq!(info.expert_loads, vec![2, 0]);
        assert_eq!(info.dropped, 1);
        assert!((info.load_imbalance() - 2.0).abs() < 1e-12);
        // Loads always agree with the assignment multiset.
        let mut counts = vec![0usize; 2];
        for &(_, e, ..) in &info.assignments {
            counts[e] += 1;
        }
        assert_eq!(counts, info.expert_loads);
    }

    #[test]
    fn skew_bias_concentrates_routing_on_low_experts() {
        let mut rng = Rng::new(7);
        let (n, m, e) = (64usize, 8usize, 4usize);
        let tokens = rng.f32_vec(n * m);
        // Weak random router: the bias dominates.
        let wg: Vec<f32> = rng.f32_vec(m * e).iter().map(|v| v * 0.01).collect();
        let bias = skew_bias(e, 2.0).unwrap();
        let info = gate_biased(&tokens, &wg, Some(&bias), n, m, e, 1, n);
        // Expert 0 is the Zipf head: it must take the majority of tokens.
        assert!(
            info.expert_loads[0] > n / 2,
            "expected skewed routing, loads {:?}",
            info.expert_loads
        );
        assert!(info.load_imbalance() > 1.5);
        // skew = 0 means no bias.
        assert!(skew_bias(e, 0.0).is_none());
    }

    #[test]
    fn deterministic_across_token_grouping() {
        // Gating a concatenation assigns the same experts per token as
        // gating the halves separately (weights identical; slots differ).
        let mut rng = Rng::new(42);
        let m = 4;
        let e = 4;
        let a = rng.f32_vec(4 * m);
        let b = rng.f32_vec(4 * m);
        let wg = rng.f32_vec(m * e);
        let mut cat = a.clone();
        cat.extend_from_slice(&b);
        let info_cat = gate(&cat, &wg, 8, m, e, 2, 16);
        let info_a = gate(&a, &wg, 4, m, e, 2, 16);
        let info_b = gate(&b, &wg, 4, m, e, 2, 16);
        let experts_of = |info: &DispatchInfo, t: usize| {
            let mut v: Vec<(usize, u32)> = info
                .assignments
                .iter()
                .filter(|(tok, ..)| *tok == t)
                .map(|&(_, e, _, w)| (e, w.to_bits()))
                .collect();
            v.sort_unstable();
            v
        };
        for t in 0..4 {
            assert_eq!(experts_of(&info_cat, t), experts_of(&info_a, t));
            assert_eq!(experts_of(&info_cat, t + 4), experts_of(&info_b, t));
        }
    }
}
