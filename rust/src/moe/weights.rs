//! Expert/gate weights: a single global parameter set, plus the per-rank
//! shard views the parallel layout induces (EP distributes experts over
//! ESP blocks; ESP splits each expert's hidden dimension).

use crate::cluster::ProcessGroups;
use crate::config::MoeLayerConfig;
use crate::util::prng::Rng;

/// The full (unsharded) MoE layer parameters.
#[derive(Debug, Clone)]
pub struct GlobalWeights {
    /// Gate: (M, E), row-major.
    pub wg: Vec<f32>,
    /// Per expert: W1 (M, H).
    pub w1: Vec<Vec<f32>>,
    /// Per expert: W2 (H, M).
    pub w2: Vec<Vec<f32>>,
}

impl GlobalWeights {
    /// Random init, scaled ~1/sqrt(fan-in) so activations stay O(1).
    pub fn random(c: &MoeLayerConfig, seed: u64) -> GlobalWeights {
        let mut rng = Rng::new(seed);
        let scale_g = 1.0 / (c.m as f32).sqrt();
        let scale1 = 1.0 / (c.m as f32).sqrt();
        let scale2 = 1.0 / (c.h as f32).sqrt();
        let randn = |rng: &mut Rng, n: usize, s: f32| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * s).collect()
        };
        GlobalWeights {
            wg: randn(&mut rng, c.m * c.e, scale_g),
            w1: (0..c.e).map(|_| randn(&mut rng, c.m * c.h, scale1)).collect(),
            w2: (0..c.e).map(|_| randn(&mut rng, c.h * c.m, scale2)).collect(),
        }
    }

    /// Rank `r`'s expert shard: for each local expert of its EP slot, the
    /// H-columns `[s·Hs, (s+1)·Hs)` of W1 and matching rows of W2, where
    /// `s` is the rank's ESP shard index. Returns (w1_shards, w2_shards)
    /// each `experts_per_rank` long; w1 shard is (M, Hs), w2 shard (Hs, M).
    pub fn shard_for_rank(
        &self,
        c: &MoeLayerConfig,
        groups: &ProcessGroups,
        rank: usize,
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let hs = c.h / c.par.n_esp;
        let s = groups.esp_shard(rank);
        let slot = groups.ep_slot(rank);
        let mut w1s = Vec::new();
        let mut w2s = Vec::new();
        for e in groups.experts_of_slot(slot, c.e) {
            // W1 (M, H): take columns [s·hs, (s+1)·hs).
            let mut w1 = Vec::with_capacity(c.m * hs);
            for row in 0..c.m {
                let base = row * c.h + s * hs;
                w1.extend_from_slice(&self.w1[e][base..base + hs]);
            }
            // W2 (H, M): take rows [s·hs, (s+1)·hs).
            let w2 = self.w2[e][s * hs * c.m..(s + 1) * hs * c.m].to_vec();
            w1s.push(w1);
            w2s.push(w2);
        }
        (w1s, w2s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::moe::ParallelDegrees;
    use crate::moe::linalg;

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p: 4, n_mp: 2, n_esp: 2 },
            b: 1,
            l: 8,
            e: 2,
            m: 6,
            h: 8,
            k: 1,
            f: 2.0,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        }
    }

    #[test]
    fn shard_shapes() {
        let c = cfg();
        let g = ProcessGroups::new(c.par).unwrap();
        let w = GlobalWeights::random(&c, 1);
        for r in 0..4 {
            let (w1s, w2s) = w.shard_for_rank(&c, &g, r);
            assert_eq!(w1s.len(), c.experts_per_rank());
            assert_eq!(w1s[0].len(), c.m * c.h / c.par.n_esp);
            assert_eq!(w2s[0].len(), c.h / c.par.n_esp * c.m);
        }
    }

    #[test]
    fn shards_reassemble_full_expert() {
        // Summing the shard partials reproduces the full FFN: for input x,
        // Σ_s relu(x @ W1_s) @ W2_s == relu(x @ W1) @ W2.
        let c = cfg();
        let g = ProcessGroups::new(c.par).unwrap();
        let w = GlobalWeights::random(&c, 7);
        let hs = c.h / c.par.n_esp;
        let x: Vec<f32> = (0..c.m).map(|i| (i as f32 - 2.0) * 0.3).collect();

        // Full expert 0.
        let mut h_full = linalg::matmul(&x, &w.w1[0], 1, c.m, c.h);
        linalg::relu(&mut h_full);
        let y_full = linalg::matmul(&h_full, &w.w2[0], 1, c.h, c.m);

        // Expert 0 lives in EP slot 0 = ranks {0, 1} (shards 0, 1).
        let mut y_sum = vec![0.0f32; c.m];
        for r in [0usize, 1] {
            let (w1s, w2s) = w.shard_for_rank(&c, &g, r);
            let mut h = linalg::matmul(&x, &w1s[0], 1, c.m, hs);
            linalg::relu(&mut h);
            let y = linalg::matmul(&h, &w2s[0], 1, hs, c.m);
            for (a, b) in y_sum.iter_mut().zip(y.iter()) {
                *a += b;
            }
        }
        for (a, b) in y_sum.iter().zip(y_full.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn different_shards_differ() {
        let c = cfg();
        let g = ProcessGroups::new(c.par).unwrap();
        let w = GlobalWeights::random(&c, 3);
        let (a, _) = w.shard_for_rank(&c, &g, 0);
        let (b, _) = w.shard_for_rank(&c, &g, 1);
        assert_ne!(a, b);
        // Ranks 0 and 2 host different experts.
        let (c0, _) = w.shard_for_rank(&c, &g, 0);
        let (c2, _) = w.shard_for_rank(&c, &g, 2);
        assert_ne!(c0, c2);
    }
}
