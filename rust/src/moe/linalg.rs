//! Minimal dense linear algebra for the data plane: row-major f32 GEMM
//! (cache-blocked), ReLU, and a numerically-stable softmax.
//!
//! These back the *native* compute backend used by correctness tests; the
//! PJRT backend runs the same math through the AOT-compiled Pallas/XLA
//! artifacts.

/// `c += a @ b` where a: (m, k), b: (k, n), c: (m, n), all row-major.
///
/// i-k-j loop order with a register-carried `a[i][l]` gives contiguous
/// access to both `b` and `c` rows — memory-friendly without needing a
/// full tiling framework for the sizes tests use.
pub fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(c.len(), m * n, "c shape");
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // dispatch tensors are zero-padded; skip dead rows
            }
            let brow = &b[l * n..(l + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

/// `a @ b`, fresh output.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_acc(a, b, &mut c, m, k, n);
    c
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Row-wise softmax over a (rows, cols) row-major matrix, in place.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols);
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_2x2() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = matmul(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rect() {
        // (1,3) @ (3,2)
        let c = matmul(&[1.0, 0.0, -1.0], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 1, 3, 2);
        assert_eq!(c, vec![-4.0, -4.0]);
    }

    #[test]
    fn matmul_acc_accumulates() {
        let mut c = vec![1.0f32; 1];
        matmul_acc(&[2.0], &[3.0], &mut c, 1, 1, 1);
        assert_eq!(c, vec![7.0]);
    }

    #[test]
    fn relu_clamps() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut x = vec![0.0, 0.0, 1000.0, 1000.0];
        softmax_rows(&mut x, 2, 2);
        for r in 0..2 {
            let s: f32 = x[r * 2..(r + 1) * 2].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!((x[r * 2] - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut x = vec![1e30f32, 0.0];
        softmax_rows(&mut x, 1, 2);
        assert!((x[0] - 1.0).abs() < 1e-6 && x[1].abs() < 1e-6);
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
