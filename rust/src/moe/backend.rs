//! Expert-FFN compute backends.
//!
//! The data-plane executor calls `expert_ffn` for every (source, local
//! expert) block. [`NativeBackend`] computes in-process (pure Rust — the
//! correctness anchor); [`PjrtExpertBackend`] runs the AOT-compiled
//! Pallas kernel through PJRT — the production path, verified against the
//! native backend in `rust/tests/`.

use anyhow::{bail, Result};

use crate::moe::linalg;
use crate::runtime::{HostTensor, Runtime};

/// Computes `y = relu(x @ w1) @ w2` with x (n, m), w1 (m, hs), w2 (hs, m).
pub trait ExpertBackend {
    fn expert_ffn(
        &mut self,
        x: &[f32],
        w1: &[f32],
        w2: &[f32],
        n: usize,
        m: usize,
        hs: usize,
    ) -> Result<Vec<f32>>;
}

/// Pure-Rust reference backend.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl ExpertBackend for NativeBackend {
    fn expert_ffn(
        &mut self,
        x: &[f32],
        w1: &[f32],
        w2: &[f32],
        n: usize,
        m: usize,
        hs: usize,
    ) -> Result<Vec<f32>> {
        let mut h = linalg::matmul(x, w1, n, m, hs);
        linalg::relu(&mut h);
        Ok(linalg::matmul(&h, w2, n, hs, m))
    }
}

/// PJRT backend: executes the `expert_ffn` artifact (the Pallas kernel
/// lowered through JAX). The artifact is compiled for fixed (n, m, hs);
/// calls with other shapes are an error (the executor arranges fixed
/// capacity-padded shapes).
pub struct PjrtExpertBackend {
    rt: Runtime,
    artifact: String,
    n: usize,
    m: usize,
    hs: usize,
}

impl PjrtExpertBackend {
    /// Wrap `runtime` for the named artifact; shapes are read from the
    /// manifest (inputs: x (n,m), w1 (m,hs), w2 (hs,m)).
    pub fn new(rt: Runtime, artifact: &str) -> Result<PjrtExpertBackend> {
        let spec = rt.manifest().get(artifact)?.clone();
        if spec.inputs.len() != 3 {
            bail!("artifact `{artifact}` should take (x, w1, w2)");
        }
        let (x, w1, w2) = (&spec.inputs[0], &spec.inputs[1], &spec.inputs[2]);
        if x.len() != 2 || w1.len() != 2 || w2.len() != 2 || x[1] != w1[0] || w1[1] != w2[0] {
            bail!("artifact `{artifact}` has inconsistent shapes: {:?}", spec.inputs);
        }
        Ok(PjrtExpertBackend {
            rt,
            artifact: artifact.to_string(),
            n: x[0],
            m: x[1],
            hs: w1[1],
        })
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.n, self.m, self.hs)
    }
}

impl ExpertBackend for PjrtExpertBackend {
    fn expert_ffn(
        &mut self,
        x: &[f32],
        w1: &[f32],
        w2: &[f32],
        n: usize,
        m: usize,
        hs: usize,
    ) -> Result<Vec<f32>> {
        if (n, m, hs) != (self.n, self.m, self.hs) {
            bail!(
                "PJRT expert backend compiled for {:?}, called with {:?}",
                (self.n, self.m, self.hs),
                (n, m, hs)
            );
        }
        let out = self.rt.exec(
            &self.artifact,
            &[
                HostTensor::new(vec![n, m], x.to_vec())?,
                HostTensor::new(vec![m, hs], w1.to_vec())?,
                HostTensor::new(vec![hs, m], w2.to_vec())?,
            ],
        )?;
        Ok(out.into_iter().next().expect("one output").data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_matches_hand_computation() {
        let mut b = NativeBackend;
        // x = [1, -1], w1 = [[1, 0], [0, 1]] → h = relu([1, -1]) = [1, 0]
        // w2 = [[2, 0], [0, 2]] → y = [2, 0]
        let y = b
            .expert_ffn(&[1.0, -1.0], &[1.0, 0.0, 0.0, 1.0], &[2.0, 0.0, 0.0, 2.0], 1, 2, 2)
            .unwrap();
        assert_eq!(y, vec![2.0, 0.0]);
    }

    #[test]
    fn native_zero_rows_stay_zero() {
        let mut b = NativeBackend;
        let y = b
            .expert_ffn(&[0.0; 4], &[1.0; 4], &[1.0; 4], 2, 2, 2)
            .unwrap();
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
