//! The MoE layer itself: gating, expert weights, the distributed
//! data-plane executor (numerics of each schedule over real rank buffers),
//! and the single-device reference the schedules are verified against.
//!
//! The gate carries the imbalanced-traffic axis: an optional Zipf logit
//! bias ([`gating::skew_bias`], driven by
//! [`crate::config::MoeLayerConfig::skew`]) skews expert popularity
//! identically in every schedule AND the dense reference, and
//! [`gating::DispatchInfo::expert_loads`] reports the per-expert slot
//! fills the load-aware SP chunk spans are built from.

pub mod backend;
pub mod exec;
pub mod gating;
pub mod linalg;
pub mod reference;
pub mod weights;

pub use backend::{ExpertBackend, NativeBackend, PjrtExpertBackend};
pub use exec::{measure_expert_loads, run_schedule, run_schedule_measured, LayerState};
pub use gating::{gate, DispatchInfo};
pub use reference::reference_forward;
pub use weights::GlobalWeights;
