//! The MoE layer itself: gating, expert weights, the distributed
//! data-plane executor (numerics of each schedule over real rank buffers),
//! and the single-device reference the schedules are verified against.

pub mod backend;
pub mod exec;
pub mod gating;
pub mod linalg;
pub mod reference;
pub mod weights;

pub use backend::{ExpertBackend, NativeBackend, PjrtExpertBackend};
pub use exec::{run_schedule, LayerState};
pub use gating::{gate, DispatchInfo};
pub use reference::reference_forward;
pub use weights::GlobalWeights;
