//! Single-device dense reference of the MoE layer forward: no
//! parallelism, full experts, same gating code. Every distributed
//! schedule must reproduce this bit-for-bit up to f32 summation order.

use anyhow::Result;

use crate::config::MoeLayerConfig;
use crate::moe::backend::ExpertBackend;
use crate::moe::gating;
use crate::moe::weights::GlobalWeights;

/// Forward one rank's tokens ((n, M) row-major) through the dense layer.
/// `cap` is the per-expert capacity to emulate (schedules differ here);
/// pass a generous value for drop-free comparison. Honors the config's
/// routing-skew knob with the same gate bias the distributed schedules
/// apply, so skewed routing stays reference-checkable.
pub fn reference_forward(
    c: &MoeLayerConfig,
    w: &GlobalWeights,
    tokens: &[f32],
    n: usize,
    cap: usize,
    backend: &mut dyn ExpertBackend,
) -> Result<Vec<f32>> {
    let bias = gating::skew_bias(c.e, c.skew);
    let info = gating::gate_biased(tokens, &w.wg, bias.as_deref(), n, c.m, c.e, c.k, cap);
    let dispatch = gating::build_dispatch(&info, tokens, c.m);
    let mut expert_out = vec![0.0f32; c.e * cap * c.m];
    for e in 0..c.e {
        let x = &dispatch[e * cap * c.m..(e + 1) * cap * c.m];
        let y = backend.expert_ffn(x, &w.w1[e], &w.w2[e], cap, c.m, c.h)?;
        expert_out[e * cap * c.m..(e + 1) * cap * c.m].copy_from_slice(&y);
    }
    Ok(gating::combine(&info, &expert_out, c.m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::moe::ParallelDegrees;
    use crate::moe::backend::NativeBackend;
    use crate::util::prng::Rng;

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p: 1, n_mp: 1, n_esp: 1 },
            b: 1,
            l: 8,
            e: 4,
            m: 6,
            h: 8,
            k: 2,
            f: 4.0,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        }
    }

    #[test]
    fn output_shape_and_finiteness() {
        let c = cfg();
        let w = GlobalWeights::random(&c, 1);
        let mut rng = Rng::new(2);
        let tokens = rng.f32_vec(8 * c.m);
        let y =
            reference_forward(&c, &w, &tokens, 8, 16, &mut NativeBackend).unwrap();
        assert_eq!(y.len(), 8 * c.m);
        assert!(y.iter().all(|v| v.is_finite()));
        assert!(y.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn capacity_one_drops_most_tokens() {
        let c = cfg();
        let w = GlobalWeights::random(&c, 1);
        let mut rng = Rng::new(2);
        let tokens = rng.f32_vec(8 * c.m);
        let generous =
            reference_forward(&c, &w, &tokens, 8, 16, &mut NativeBackend).unwrap();
        let starved =
            reference_forward(&c, &w, &tokens, 8, 1, &mut NativeBackend).unwrap();
        assert_ne!(generous, starved);
    }
}
