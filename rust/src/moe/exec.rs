//! Data plane of the unified interpreter: run one MoE layer forward under
//! any schedule over P in-process ranks with *real* tensor data.
//!
//! There is no per-schedule executor here. The SAME Op-program walker that
//! the simulator times ([`crate::schedule::interp::run_program`]) drives a
//! [`DataMachine`]: communication ops execute through the one-source
//! collective algorithms over a [`DataTransport`] (real `f32` chunks), and
//! the rank-local ops (gate, expert FFN, local combine, un-gate, splits)
//! are defined once per op — a small abstract machine over the layer's
//! staged tensors, so Baseline/S1/S2 differ only in the op sequence their
//! builders emit. Timing/numerics agreement is structural: the wire log
//! the transport records carries the same tags and byte totals as the
//! transfer DAG the engine schedules.
//!
//! This is the semantics-preservation proof the paper asserts implicitly:
//! all schedules (and the single-device reference) must produce the same
//! outputs for drop-free capacities.

use anyhow::{bail, ensure, Result};

use crate::cluster::ProcessGroups;
use crate::comm::transport::{split_chunks, DataTransport};
use crate::config::MoeLayerConfig;
use crate::moe::backend::ExpertBackend;
use crate::moe::gating::{self, DispatchInfo};
use crate::moe::weights::GlobalWeights;
use crate::schedule::builders::forward_ops_measured;
use crate::schedule::interp::{run_program, Machine};
use crate::schedule::verify;
use crate::schedule::{forward_ops, Op, ScheduleKind};
use crate::util::prng::Rng;

/// The world's state entering a MoE layer.
#[derive(Debug, Clone)]
pub struct LayerState {
    pub cfg: MoeLayerConfig,
    pub groups: ProcessGroups,
    pub weights: GlobalWeights,
    /// Per-rank tokens, (B·L, M) row-major; MP groups carry duplicates.
    pub tokens: Vec<Vec<f32>>,
}

impl LayerState {
    /// Random state: one distinct token set per MP group, duplicated to
    /// members (the MP invariant at a MoE layer boundary).
    pub fn random(cfg: &MoeLayerConfig, seed: u64) -> Result<LayerState> {
        cfg.validate()?;
        let groups = ProcessGroups::new(cfg.par)?;
        let weights = GlobalWeights::random(cfg, seed);
        let mut rng = Rng::new(seed ^ 0xD15A);
        let n = cfg.tokens() * cfg.m;
        let mut tokens: Vec<Vec<f32>> = vec![Vec::new(); cfg.par.p];
        for r in 0..cfg.par.p {
            if groups.mp_index(r) == 0 {
                tokens[r] = rng.f32_vec(n);
            }
        }
        for r in 0..cfg.par.p {
            if groups.mp_index(r) != 0 {
                let leader = groups.mp_group(r)[0];
                tokens[r] = tokens[leader].clone();
            }
        }
        Ok(LayerState { cfg: cfg.clone(), groups, weights, tokens })
    }
}

/// Result of running a schedule on the data plane.
#[derive(Debug, Clone)]
pub struct ExecResult {
    /// Per-rank layer outputs, (B·L, M) — same shape/meaning as inputs.
    pub outputs: Vec<Vec<f32>>,
    /// Wire log: aggregated `(tag, total bytes)` across all ranks, in
    /// first-touch order, using the canonical [`crate::comm::tags`]
    /// constants — directly comparable to
    /// [`crate::sim::dag::SimDag::comm_log`] of the lowered program.
    pub comm_log: Vec<(&'static str, f64)>,
    /// Tokens dropped by capacity limits (0 for generous `f`).
    pub dropped: usize,
}

/// Execute one forward pass of the layer under `kind`.
///
/// S2 and S2-AAS share numerics (the overlap changes timing, not bytes or
/// values — the generic SAA algorithm computes identical outputs either
/// way), so both resolve to the same op semantics here.
pub fn run_schedule(
    kind: ScheduleKind,
    state: &LayerState,
    backend: &mut dyn ExpertBackend,
) -> Result<ExecResult> {
    let ops = forward_ops(resolved(kind)?, &state.cfg);
    run_ops(kind, &ops, state, backend)
}

/// Two-pass variant of [`run_schedule`]: first run ONLY the gate to
/// measure the actual per-expert loads ([`measure_expert_loads`]), then
/// execute the schedule with chunk spans re-balanced from that
/// measurement ([`crate::schedule::ops::sp_spans_measured`]) — covering
/// organic, non-Zipf imbalance. Numerics are unaffected (spans only move
/// chunk boundaries); only the SP family's pipelining changes.
pub fn run_schedule_measured(
    kind: ScheduleKind,
    state: &LayerState,
    backend: &mut dyn ExpertBackend,
) -> Result<ExecResult> {
    let measured = measure_expert_loads(state);
    let ops = forward_ops_measured(resolved(kind)?, &state.cfg, Some(&measured[..]));
    run_ops(kind, &ops, state, backend)
}

fn resolved(kind: ScheduleKind) -> Result<ScheduleKind> {
    match kind {
        ScheduleKind::Parm => bail!("resolve Parm to a concrete schedule via the perf model first"),
        ScheduleKind::Pipelined { chunks: 0 }
        | ScheduleKind::PipelinedUniform { chunks: 0 }
        | ScheduleKind::PipelinedS2 { chunks: 0 } => {
            bail!("resolve SP's chunk count r via the perf model first")
        }
        k => Ok(k),
    }
}

fn run_ops(
    kind: ScheduleKind,
    ops: &[Op],
    state: &LayerState,
    backend: &mut dyn ExpertBackend,
) -> Result<ExecResult> {
    // Plane-capability pre-scan (always on): a backward op in a data-plane
    // program is a structured verifier diagnostic naming the op index and
    // family, not a mid-walk bail from whichever machine arm sees it
    // first. The per-op bail arms below remain as the backstop.
    if let Some(f) = verify::plane_findings(ops, verify::Plane::Data).into_iter().next() {
        bail!("schedule {kind:?} is not executable on the data plane: {f}");
    }
    let mut transport = DataTransport::with_wire(state.cfg.wire);
    let mut machine = DataMachine::new(state, backend, ops);
    run_program(ops, &state.groups, &mut transport, &mut machine)?;
    ensure!(
        matches!(machine.stage, Stage::Tokens),
        "schedule {kind:?} did not return to token stage"
    );
    Ok(ExecResult {
        outputs: machine.buf,
        comm_log: transport.into_log(),
        dropped: machine.dropped,
    })
}

/// Run ONLY the gate pass of the PauseMP schedules (each rank gates its
/// MP-split token slice at the capacity the SP builders assume) and
/// return the per-expert loads, **max-aggregated over ranks** — the
/// conservative profile for a global span policy: a row is hot if any
/// rank fills it. This is the measurement half of the two-pass span
/// selection (`--spans measured`).
pub fn measure_expert_loads(state: &LayerState) -> Vec<usize> {
    let c = &state.cfg;
    let n_local = c.tokens() / c.par.n_mp;
    let m = c.m;
    let cap = gating::capacity(n_local, c.e, c.k, c.f, 1);
    let bias = gating::skew_bias(c.e, c.skew);
    let mut max_loads = vec![0usize; c.e];
    for r in 0..c.par.p {
        let mi = state.groups.mp_index(r);
        let slice = &state.tokens[r][mi * n_local * m..(mi + 1) * n_local * m];
        let info = gating::gate_biased(
            slice,
            &state.weights.wg,
            bias.as_deref(),
            n_local,
            m,
            c.e,
            c.k,
            cap,
        );
        for (mx, &l) in max_loads.iter_mut().zip(&info.expert_loads) {
            *mx = (*mx).max(l);
        }
    }
    max_loads
}

/// Where the layer's per-rank primary tensor currently lives in the
/// forward pipeline. Each [`Op`] has ONE data semantic, keyed off the
/// stage — the schedules differ only in the op order their builders emit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Stage {
    /// (n_tok, M) token-major activations.
    Tokens,
    /// (E, cap, M) dense dispatch tensor (post-gate).
    Dispatch,
    /// (sources, E_local, cap, M) received expert inputs.
    Recv,
    /// (sources, E_local, cap, M) computed expert outputs.
    ExpertOut,
    /// (sources, E_local, cap, M) per-source partials returned by the
    /// combine AlltoAll (awaiting the local partial-sum combine).
    Returned,
    /// MP-peer-major concatenation of every peer's returned partials
    /// (the SAA AllGather result, awaiting combine + interleave).
    Gathered,
    /// (E, cap, M) combined expert outputs in expert order.
    Combined,
}

/// Chunk-indexed staging of the SP pipelined region: the primary tensor
/// stays at [`Stage::Dispatch`] while each capacity chunk moves through
/// its own dispatch → FFN → combine lane; the last combine interleaves the
/// returned chunks back into the full (P, E_local, cap, M) block.
///
/// Spans are NOT re-derived from a span policy here: each `sp.dispatch`
/// op's byte field is decoded back into its row count (exactly — the
/// fields are integer products), so the data plane pipelines on literally
/// the spans the builder emitted, load-weighted or uniform alike. Every
/// span is clamped against the gate's **actual** capacity: when the
/// builder's capacity estimate exceeds it, the overhanging spans shrink
/// (possibly to zero width) instead of slicing out of bounds, and empty
/// spans stage empty chunks whose AlltoAlls put nothing on the wire.
struct SpStage {
    /// Capacity spans chunk k covers, filled as its dispatch arrives.
    spans: Vec<(usize, usize)>,
    /// Whether chunk k's dispatch has been staged.
    seen: Vec<bool>,
    /// Capacity rows the program claimed so far (pre-clamp) — the next
    /// chunk's span start in the builder's estimated capacity.
    claimed_rows: usize,
    /// Received dispatch chunks, `[chunk][rank]` → (P, E_local, rows, M).
    recv: Vec<Vec<Vec<f32>>>,
    /// Expert outputs per chunk per rank (same shape as `recv`).
    out: Vec<Vec<Vec<f32>>>,
    /// Returned combine partials per chunk per rank: the (P, E_local,
    /// rows, M) returned block for plain SP, the MP-peer-major
    /// (N_MP, P, E_local, rows, M) gathered block for SP2 (each chunk's
    /// SAA already all-gathered it).
    ret: Vec<Vec<Vec<f32>>>,
    /// Combines accepted so far; the region assembles at the last one.
    combines_done: usize,
    /// Whether this is an SP2 (chunked-SAA) region — assembly then lands
    /// at [`Stage::Gathered`] instead of [`Stage::Returned`].
    saa: bool,
}

impl SpStage {
    fn new(chunks: usize, p: usize, saa: bool) -> SpStage {
        SpStage {
            spans: vec![(0, 0); chunks],
            seen: vec![false; chunks],
            claimed_rows: 0,
            recv: vec![vec![Vec::new(); p]; chunks],
            out: vec![vec![Vec::new(); p]; chunks],
            ret: vec![vec![Vec::new(); p]; chunks],
            combines_done: 0,
            saa,
        }
    }
}

/// The data plane's [`Machine`]: rank buffers, gating state, and the
/// per-op tensor semantics.
struct DataMachine<'a> {
    cfg: &'a MoeLayerConfig,
    groups: &'a ProcessGroups,
    weights: &'a GlobalWeights,
    backend: &'a mut dyn ExpertBackend,
    /// Per-rank primary buffer (layout per `stage`).
    buf: Vec<Vec<f32>>,
    /// Tokens currently represented per rank (token-stage layouts).
    n_tok: usize,
    /// Routing decisions, one per rank, once `Gate` has run.
    infos: Vec<DispatchInfo>,
    /// Current capacity per expert (cap_full / N_MP after an S2 MpSplit).
    cap: usize,
    /// Capacity at gate time (what `infos` were built with).
    cap_full: usize,
    /// Capacity alignment for the gate: N_MP when an MpSplit follows the
    /// gate in the program (S2 splits the capacity dimension, which must
    /// divide evenly), else 1.
    gate_cap_multiple: usize,
    /// Source blocks in the (sources, E_local, cap, M) layouts: N_EP for
    /// the EP AlltoAll, P for the fused product-group AlltoAll.
    sources: usize,
    /// In-flight SP pipelined region, if any.
    sp: Option<SpStage>,
    stage: Stage,
    dropped: usize,
}

impl<'a> DataMachine<'a> {
    fn new(state: &'a LayerState, backend: &'a mut dyn ExpertBackend, ops: &[Op]) -> Self {
        // Structural inference of the gate's capacity alignment: if the
        // program pauses MP *after* gating (S2), capacity must split
        // evenly across the MP group.
        let gate_at = ops.iter().position(|o| matches!(o, Op::Gate { .. }));
        let split_after_gate = gate_at
            .map(|g| ops[g + 1..].iter().any(|o| matches!(o, Op::MpSplit { .. })))
            .unwrap_or(false);
        DataMachine {
            cfg: &state.cfg,
            groups: &state.groups,
            weights: &state.weights,
            backend,
            buf: state.tokens.clone(),
            n_tok: state.cfg.tokens(),
            infos: Vec::new(),
            cap: 0,
            cap_full: 0,
            gate_cap_multiple: if split_after_gate { state.cfg.par.n_mp } else { 1 },
            sources: 0,
            sp: None,
            stage: Stage::Tokens,
            dropped: 0,
        }
    }

    /// Split `buf` into `g` equal chunks (chunk-addressed collectives need
    /// the uniform partition; divisibility is a semantic requirement).
    fn equal_chunks(buf: &[f32], g: usize, what: &str) -> Result<Vec<Vec<f32>>> {
        ensure!(buf.len() % g == 0, "{what}: buffer {} not divisible by {g}", buf.len());
        Ok(split_chunks(buf, g))
    }

    /// Per-destination chunks of the fused EP&ESP AlltoAll dispatch: the
    /// Dump duplicates each expert block's slice to all N_ESP holders of
    /// its EP slot (destination rank `q` receives the experts of `q`'s
    /// slot).
    fn fused_dispatch_chunks(&self, rank: usize) -> Vec<Vec<f32>> {
        self.fused_dispatch_chunks_span(rank, 0, self.cap)
    }

    /// [`Self::fused_dispatch_chunks`] restricted to the capacity rows
    /// `[start, start + rows)` of every expert block — one SP chunk's
    /// dispatch payload.
    fn fused_dispatch_chunks_span(&self, rank: usize, start: usize, rows: usize) -> Vec<Vec<f32>> {
        let (e, cap, m) = (self.cfg.e, self.cap, self.cfg.m);
        let d = &self.buf[rank];
        (0..self.cfg.par.p)
            .map(|dst| {
                let slot = self.groups.ep_slot(dst);
                let mut out = Vec::new();
                for ex in self.groups.experts_of_slot(slot, e) {
                    let base = (ex * cap + start) * m;
                    out.extend_from_slice(&d[base..base + rows * m]);
                }
                out
            })
            .collect()
    }

    /// Inverse of the Dump: sum the per-source partial copies of one
    /// returned (sources, E_local, cap, M) block into an (E, cap, M)
    /// tensor in expert order.
    fn fused_combine(&self, recv: &[f32]) -> Vec<f32> {
        let (e, cap, m) = (self.cfg.e, self.cap, self.cfg.m);
        let p = self.cfg.par.p;
        let e_local = self.cfg.experts_per_rank();
        let chunk = e_local * cap * m;
        assert_eq!(recv.len(), p * chunk, "returned block shape");
        let mut out = vec![0.0f32; e * cap * m];
        for q in 0..p {
            let slot = self.groups.ep_slot(q);
            for (i, ex) in self.groups.experts_of_slot(slot, e).enumerate() {
                let src = q * chunk + i * cap * m;
                let dst = ex * cap * m;
                for j in 0..cap * m {
                    out[dst + j] += recv[src + j];
                }
            }
        }
        out
    }

    /// Gate the current token buffers into dense dispatch tensors (the
    /// router bias realizes the config's routing-skew knob).
    fn gate(&mut self) -> Result<()> {
        ensure!(self.stage == Stage::Tokens, "gate expects token stage, got {:?}", self.stage);
        let c = self.cfg;
        let cap = gating::capacity(self.n_tok, c.e, c.k, c.f, self.gate_cap_multiple);
        let bias = gating::skew_bias(c.e, c.skew);
        let mut infos = Vec::with_capacity(c.par.p);
        for r in 0..c.par.p {
            let info = gating::gate_biased(
                &self.buf[r],
                &self.weights.wg,
                bias.as_deref(),
                self.n_tok,
                c.m,
                c.e,
                c.k,
                cap,
            );
            let dispatch = gating::build_dispatch(&info, &self.buf[r], c.m);
            self.buf[r] = dispatch;
            infos.push(info);
        }
        self.dropped += infos.iter().map(|i| i.dropped).sum::<usize>();
        self.infos = infos;
        self.cap = cap;
        self.cap_full = cap;
        self.stage = Stage::Dispatch;
        Ok(())
    }

    /// Expert FFN over one rank's received (sources, E_local, cap, M)
    /// block, batched per local expert over all source blocks. `cap` may
    /// be a single SP chunk's row count.
    fn ffn_block(&mut self, r: usize, recv: &[f32], sources: usize, cap: usize) -> Result<Vec<f32>> {
        let c = self.cfg;
        let m = c.m;
        let hs = c.h / c.par.n_esp;
        let e_local = c.experts_per_rank();
        let block = e_local * cap * m;
        ensure!(recv.len() == sources * block, "expert input shape");
        let (w1s, w2s) = self.weights.shard_for_rank(c, self.groups, r);
        let mut out = vec![0.0f32; recv.len()];
        for le in 0..e_local {
            // Gather rows of local expert `le` from every source chunk.
            let mut x = Vec::with_capacity(sources * cap * m);
            for src in 0..sources {
                let base = src * block + le * cap * m;
                x.extend_from_slice(&recv[base..base + cap * m]);
            }
            let y = self.backend.expert_ffn(&x, &w1s[le], &w2s[le], sources * cap, m, hs)?;
            for src in 0..sources {
                let base = src * block + le * cap * m;
                out[base..base + cap * m].copy_from_slice(&y[src * cap * m..(src + 1) * cap * m]);
            }
        }
        Ok(out)
    }

    /// Expert FFN shards over the full received block of every rank.
    fn expert_ffn(&mut self) -> Result<()> {
        ensure!(self.stage == Stage::Recv, "expert ffn expects received dispatch");
        let sources = self.sources;
        let cap = self.cap;
        for r in 0..self.cfg.par.p {
            let recv = std::mem::take(&mut self.buf[r]);
            self.buf[r] = self.ffn_block(r, &recv, sources, cap)?;
        }
        self.stage = Stage::ExpertOut;
        Ok(())
    }

    /// SP expert FFN over chunk `index`'s received span on every rank.
    fn sp_expert_ffn(&mut self, index: usize) -> Result<()> {
        ensure!(
            self.stage == Stage::Dispatch,
            "sp.ffn expects an in-flight pipelined region, got {:?}",
            self.stage
        );
        let p = self.cfg.par.p;
        let (rows, recv_all) = {
            let sp = self
                .sp
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("sp.ffn before any sp.dispatch"))?;
            ensure!(index < sp.spans.len(), "sp.ffn chunk {index} out of range");
            ensure!(sp.seen[index], "sp.ffn chunk {index} before its dispatch");
            (sp.spans[index].1, std::mem::take(&mut sp.recv[index]))
        };
        ensure!(recv_all.len() == p, "sp.ffn expects one received block per rank");
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(p);
        for r in 0..p {
            let out = if rows == 0 {
                Vec::new()
            } else {
                self.ffn_block(r, &recv_all[r], p, rows)?
            };
            outs.push(out);
        }
        let sp = self.sp.as_mut().expect("sp stage checked above");
        sp.out[index] = outs;
        Ok(())
    }

    /// Interleave the returned SP chunks back into the full
    /// (P, E_local, cap, M) returned block on every rank and leave the
    /// machine exactly where a monolithic fused combine would have.
    fn sp_assemble(&mut self) -> Result<()> {
        let sp = self
            .sp
            .take()
            .ok_or_else(|| anyhow::anyhow!("sp assembly without a pipelined region"))?;
        ensure!(!sp.saa, "plain SP assembly on a chunked-SAA region");
        let c = self.cfg;
        let (p, m, cap) = (c.par.p, c.m, self.cap);
        ensure!(
            sp.claimed_rows >= cap,
            "SP program covers {} capacity rows but the gate produced {cap}",
            sp.claimed_rows
        );
        let e_local = c.experts_per_rank();
        for r in 0..p {
            let mut full = vec![0.0f32; p * e_local * cap * m];
            for (k, &(start, rows)) in sp.spans.iter().enumerate() {
                if rows == 0 {
                    continue;
                }
                let part = &sp.ret[k][r];
                ensure!(part.len() == p * e_local * rows * m, "sp returned chunk shape");
                for blk in 0..p * e_local {
                    let sbase = blk * rows * m;
                    let dbase = (blk * cap + start) * m;
                    full[dbase..dbase + rows * m].copy_from_slice(&part[sbase..sbase + rows * m]);
                }
            }
            self.buf[r] = full;
        }
        self.sources = p;
        self.stage = Stage::Returned;
        Ok(())
    }

    /// Interleave the per-chunk gathered SAA blocks back into the full
    /// MP-peer-major (N_MP, P, E_local, cap, M) buffer on every rank and
    /// leave the machine exactly where a monolithic SAA combine would
    /// have — at [`Stage::Gathered`], ready for S2's LocalCombine.
    fn sp2_assemble(&mut self) -> Result<()> {
        let sp = self
            .sp
            .take()
            .ok_or_else(|| anyhow::anyhow!("sp2 assembly without a pipelined region"))?;
        ensure!(sp.saa, "sp2 assembly on a plain SP region");
        let c = self.cfg;
        let (p, m, cap) = (c.par.p, c.m, self.cap);
        ensure!(
            sp.claimed_rows >= cap,
            "SP2 program covers {} capacity rows but the gate produced {cap}",
            sp.claimed_rows
        );
        let e_local = c.experts_per_rank();
        // Blocks of `rows·M` per chunk: one per (MP peer, source rank,
        // local expert) triple, in that (MP-peer-major) order.
        let blocks = c.par.n_mp * p * e_local;
        for r in 0..p {
            let mut full = vec![0.0f32; blocks * cap * m];
            for (k, &(start, rows)) in sp.spans.iter().enumerate() {
                if rows == 0 {
                    continue;
                }
                let part = &sp.ret[k][r];
                ensure!(part.len() == blocks * rows * m, "sp2 gathered chunk shape");
                for blk in 0..blocks {
                    let sbase = blk * rows * m;
                    let dbase = (blk * cap + start) * m;
                    full[dbase..dbase + rows * m].copy_from_slice(&part[sbase..sbase + rows * m]);
                }
            }
            self.buf[r] = full;
        }
        self.sources = p;
        self.stage = Stage::Gathered;
        Ok(())
    }

    /// MP-Split: on tokens, each rank keeps its 1/N_MP token slice (S1);
    /// on a dispatch tensor, each rank keeps its 1/N_MP capacity-slot
    /// slice of every expert (S2).
    fn mp_split(&mut self) -> Result<()> {
        let c = self.cfg;
        let n_mp = c.par.n_mp;
        match self.stage {
            Stage::Tokens => {
                ensure!(self.n_tok % n_mp == 0, "B·L must divide N_MP");
                let n_local = self.n_tok / n_mp;
                let m = c.m;
                for r in 0..c.par.p {
                    let mi = self.groups.mp_index(r);
                    let slice = self.buf[r][mi * n_local * m..(mi + 1) * n_local * m].to_vec();
                    self.buf[r] = slice;
                }
                self.n_tok = n_local;
            }
            Stage::Dispatch => {
                ensure!(self.cap % n_mp == 0, "capacity must divide N_MP");
                let cap_local = self.cap / n_mp;
                let (e, cap, m) = (c.e, self.cap, c.m);
                for r in 0..c.par.p {
                    let mi = self.groups.mp_index(r);
                    let full = &self.buf[r];
                    let mut part = Vec::with_capacity(e * cap_local * m);
                    for ex in 0..e {
                        let base = (ex * cap + mi * cap_local) * m;
                        part.extend_from_slice(&full[base..base + cap_local * m]);
                    }
                    self.buf[r] = part;
                }
                self.cap = cap_local;
            }
            other => bail!("mp.split has no semantic at stage {other:?}"),
        }
        Ok(())
    }

    /// Local partial-sum combine of the returned shard copies: directly on
    /// this rank's returned block (S1), or on every MP peer's gathered
    /// block followed by the capacity-slot interleave back to the full
    /// (E, cap_full, M) order (S2 after the SAA/AAS combine).
    fn local_combine(&mut self) -> Result<()> {
        let c = self.cfg;
        match self.stage {
            Stage::Returned => {
                for r in 0..c.par.p {
                    let recv = std::mem::take(&mut self.buf[r]);
                    let combined = self.fused_combine(&recv);
                    self.buf[r] = combined;
                }
            }
            Stage::Gathered => {
                let (e, m) = (c.e, c.m);
                let cap_local = self.cap;
                let cap_full = self.cap_full;
                let n_mp = c.par.n_mp;
                let blk = c.par.p * c.experts_per_rank() * cap_local * m;
                for r in 0..c.par.p {
                    let gathered = std::mem::take(&mut self.buf[r]);
                    ensure!(gathered.len() == n_mp * blk, "gathered combine shape");
                    let mut full = vec![0.0f32; e * cap_full * m];
                    for mi in 0..n_mp {
                        let combined = self.fused_combine(&gathered[mi * blk..(mi + 1) * blk]);
                        for ex in 0..e {
                            let src = ex * cap_local * m;
                            let dst = (ex * cap_full + mi * cap_local) * m;
                            full[dst..dst + cap_local * m]
                                .copy_from_slice(&combined[src..src + cap_local * m]);
                        }
                    }
                    self.buf[r] = full;
                }
                self.cap = cap_full;
            }
            other => bail!("local.combine has no semantic at stage {other:?}"),
        }
        self.stage = Stage::Combined;
        Ok(())
    }

    /// Un-gate: scatter combined expert outputs back to token order.
    fn ungate(&mut self) -> Result<()> {
        ensure!(self.stage == Stage::Combined, "ungate expects combined outputs");
        for r in 0..self.cfg.par.p {
            let y = gating::combine(&self.infos[r], &self.buf[r], self.cfg.m);
            self.buf[r] = y;
        }
        self.n_tok = self.infos[0].n_tokens;
        self.stage = Stage::Tokens;
        Ok(())
    }

    /// ESP-Split: each rank keeps its own 1/N_ESP token rows (baseline
    /// epilogue — the gathered-token order splits back per shard).
    fn esp_split(&mut self) -> Result<()> {
        ensure!(self.stage == Stage::Tokens, "esp.split expects token stage");
        let c = self.cfg;
        let n_esp = c.par.n_esp;
        ensure!(self.n_tok % n_esp == 0, "token count must divide N_ESP");
        let t_local = self.n_tok / n_esp;
        let m = c.m;
        for r in 0..c.par.p {
            let shard = self.groups.esp_shard(r);
            let slice = self.buf[r][shard * t_local * m..(shard + 1) * t_local * m].to_vec();
            self.buf[r] = slice;
        }
        self.n_tok = t_local;
        Ok(())
    }
}

impl Machine<DataTransport> for DataMachine<'_> {
    fn inputs(&mut self, op: &Op, grp: &[usize]) -> Result<Vec<Vec<Vec<f32>>>> {
        let g = grp.len();
        match *op {
            Op::EspAllGather { .. } | Op::MpAllGather { .. } => {
                ensure!(self.stage == Stage::Tokens, "allgather expects token stage");
                Ok(grp.iter().map(|&r| vec![self.buf[r].clone()]).collect())
            }
            Op::EspAllReduce { .. } => {
                ensure!(self.stage == Stage::ExpertOut, "esp.allreduce expects expert outputs");
                // AllReduce tolerates a ragged partition (the result is
                // consumed re-concatenated), so no divisibility demand —
                // the old per-schedule executor accepted these configs too.
                Ok(grp.iter().map(|&r| split_chunks(&self.buf[r], g)).collect())
            }
            Op::EpAlltoAll { .. } => match self.stage {
                Stage::Dispatch | Stage::ExpertOut => grp
                    .iter()
                    .map(|&r| Self::equal_chunks(&self.buf[r], g, "ep.alltoall"))
                    .collect(),
                other => bail!("ep.alltoall has no semantic at stage {other:?}"),
            },
            Op::FusedAlltoAll { .. } | Op::SaaCombine { .. } | Op::AasCombine { .. } => {
                match self.stage {
                    // Dispatch direction: Dump + product-group AlltoAll.
                    Stage::Dispatch => {
                        Ok(grp.iter().map(|&r| self.fused_dispatch_chunks(r)).collect())
                    }
                    // Combine direction: the (P, E_local, cap, M) expert
                    // outputs are already source-block ordered.
                    Stage::ExpertOut => grp
                        .iter()
                        .map(|&r| Self::equal_chunks(&self.buf[r], g, "fused combine"))
                        .collect(),
                    other => bail!("fused alltoall has no semantic at stage {other:?}"),
                }
            }
            Op::SpDispatch { index, of, bytes_per_pair }
            | Op::Sp2Dispatch { index, of, bytes_per_pair } => {
                ensure!(
                    self.stage == Stage::Dispatch,
                    "sp dispatch has no semantic at stage {:?}",
                    self.stage
                );
                if self.sp.is_none() {
                    let saa = matches!(op, Op::Sp2Dispatch { .. });
                    self.sp = Some(SpStage::new(of, self.cfg.par.p, saa));
                }
                let (start, rows) = {
                    let cap = self.cap;
                    // Exact decode: the op field is the integer product
                    // experts_per_rank · rows · M · dtype_bytes as f64.
                    let row_bytes =
                        (self.cfg.experts_per_rank() * self.cfg.m * self.cfg.dtype_bytes) as f64;
                    let sp = self.sp.as_mut().expect("sp stage initialized above");
                    ensure!(
                        index < of && sp.spans.len() == of,
                        "sp.dispatch chunk {index} of {of} does not fit the region"
                    );
                    ensure!(!sp.seen[index], "sp.dispatch chunk {index} staged twice");
                    ensure!(
                        index == 0 || sp.seen[index - 1],
                        "sp.dispatch chunk {index} arrived before chunk {}",
                        index - 1
                    );
                    let claimed = (bytes_per_pair / row_bytes).round() as usize;
                    // Clamp the builder's capacity-estimate span against
                    // the gate's ACTUAL capacity: overhanging spans shrink
                    // (to zero width at the tail) instead of slicing the
                    // dispatch tensor out of bounds.
                    let start = sp.claimed_rows.min(cap);
                    let rows = claimed.min(cap - start);
                    sp.claimed_rows += claimed;
                    sp.seen[index] = true;
                    sp.spans[index] = (start, rows);
                    (start, rows)
                };
                Ok(grp
                    .iter()
                    .map(|&r| self.fused_dispatch_chunks_span(r, start, rows))
                    .collect())
            }
            Op::SpCombine { index, .. } | Op::Sp2Saa { index, .. } => {
                ensure!(
                    self.stage == Stage::Dispatch,
                    "sp combine has no semantic at stage {:?}",
                    self.stage
                );
                let outs = {
                    let sp = self
                        .sp
                        .as_mut()
                        .ok_or_else(|| anyhow::anyhow!("sp combine before any dispatch"))?;
                    ensure!(index < sp.out.len(), "sp combine chunk {index} out of range");
                    std::mem::take(&mut sp.out[index])
                };
                ensure!(outs.len() == self.cfg.par.p, "sp combine expects a computed chunk");
                let mut ins = Vec::with_capacity(g);
                for &r in grp {
                    ins.push(Self::equal_chunks(&outs[r], g, op.tag())?);
                }
                Ok(ins)
            }
            Op::EspReduceScatter { .. }
            | Op::MpReduceScatter { .. }
            | Op::BwdEpAlltoAll { .. }
            | Op::BwdFusedAlltoAll { .. }
            | Op::BwdWgradAllReduce { .. }
            | Op::BwdSpDispatch { .. }
            | Op::BwdSpCombine { .. }
            | Op::BwdSp2Dispatch { .. }
            | Op::BwdSp2Combine { .. } => {
                bail!("backward op {op:?} is not executed on the data plane")
            }
            _ => bail!("non-communication op has no chunk inputs: {op:?}"),
        }
    }

    fn accept(&mut self, op: &Op, grp: &[usize], outputs: Vec<Vec<Vec<f32>>>) -> Result<()> {
        match *op {
            Op::EspAllGather { .. }
            | Op::MpAllGather { .. }
            | Op::EspAllReduce { .. }
            | Op::EpAlltoAll { .. }
            | Op::FusedAlltoAll { .. }
            | Op::SaaCombine { .. }
            | Op::AasCombine { .. } => {
                for (out, &r) in outputs.into_iter().zip(grp.iter()) {
                    self.buf[r] = out.concat();
                }
                Ok(())
            }
            // SP/SP2 chunks land in their chunk-indexed staging slots, not
            // the primary buffer (which still holds the dispatch tensor).
            Op::SpDispatch { index, .. } | Op::Sp2Dispatch { index, .. } => {
                let sp = self
                    .sp
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("sp dispatch accepted without a region"))?;
                for (out, &r) in outputs.into_iter().zip(grp.iter()) {
                    sp.recv[index][r] = out.concat();
                }
                Ok(())
            }
            // For Sp2Saa the accepted block is the interpreter's MP-peer-
            // major flattening of the chunked SAA's AllGather result —
            // (N_MP, P, E_local, rows, M) — stored as-is for assembly.
            Op::SpCombine { index, .. } | Op::Sp2Saa { index, .. } => {
                let sp = self
                    .sp
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("sp combine accepted without a region"))?;
                for (out, &r) in outputs.into_iter().zip(grp.iter()) {
                    sp.ret[index][r] = out.concat();
                }
                Ok(())
            }
            _ => bail!("non-communication op has no outputs to accept: {op:?}"),
        }
    }

    fn apply_local(&mut self, op: &Op) -> Result<()> {
        match *op {
            Op::Gate { .. } => self.gate(),
            Op::ExpertFfn { .. } => self.expert_ffn(),
            Op::SpExpertFfn { index, .. } | Op::Sp2ExpertFfn { index, .. } => {
                self.sp_expert_ffn(index)
            }
            Op::MpSplit { .. } => self.mp_split(),
            Op::EspSplit { .. } => self.esp_split(),
            Op::LocalCombine { .. } => self.local_combine(),
            Op::Ungate { .. } => self.ungate(),
            Op::BwdExpertDgrad { .. }
            | Op::BwdExpertWgrad { .. }
            | Op::BwdSpDgrad { .. }
            | Op::BwdSpWgrad { .. }
            | Op::BwdSp2Dgrad { .. }
            | Op::BwdSp2Wgrad { .. } => {
                bail!("backward op {op:?} is not executed on the data plane")
            }
            _ => bail!("communication op {op:?} reached apply_local"),
        }
    }

    fn finish(&mut self, op: &Op) -> Result<()> {
        match *op {
            Op::EspAllGather { .. } | Op::MpAllGather { .. } => {
                // Gather grew the token dimension.
                self.n_tok = self.buf[0].len() / self.cfg.m;
            }
            Op::EspAllReduce { .. } => {} // shape unchanged
            Op::EpAlltoAll { .. } => {
                self.stage = match self.stage {
                    Stage::Dispatch => {
                        self.sources = self.cfg.par.n_ep();
                        Stage::Recv
                    }
                    Stage::ExpertOut => Stage::Combined,
                    other => bail!("ep.alltoall finished at stage {other:?}"),
                };
            }
            Op::FusedAlltoAll { .. } => {
                self.stage = match self.stage {
                    Stage::Dispatch => {
                        self.sources = self.cfg.par.p;
                        Stage::Recv
                    }
                    Stage::ExpertOut => Stage::Returned,
                    other => bail!("fused.alltoall finished at stage {other:?}"),
                };
            }
            Op::SaaCombine { .. } | Op::AasCombine { .. } => {
                ensure!(self.stage == Stage::ExpertOut, "saa/aas combine after experts");
                self.stage = Stage::Gathered;
            }
            Op::SpCombine { of, .. } | Op::Sp2Saa { of, .. } => {
                ensure!(
                    self.stage == Stage::Dispatch,
                    "sp combine finished outside the pipelined region"
                );
                let done = {
                    let sp = self
                        .sp
                        .as_mut()
                        .ok_or_else(|| anyhow::anyhow!("sp combine finished without a region"))?;
                    sp.combines_done += 1;
                    sp.combines_done == of
                };
                if done {
                    if matches!(*op, Op::Sp2Saa { .. }) {
                        self.sp2_assemble()?;
                    } else {
                        self.sp_assemble()?;
                    }
                }
            }
            _ => {}
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::moe::ParallelDegrees;
    use crate::moe::backend::NativeBackend;
    use crate::moe::reference::reference_forward;
    use crate::util::propcheck::assert_close;

    /// Drop-free config: generous capacity factor.
    fn cfg(p: usize, n_mp: usize, n_esp: usize) -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p, n_mp, n_esp },
            b: 1,
            l: 16,
            e: (p / n_esp).max(2),
            m: 8,
            h: 8 * n_esp, // divisible by n_esp
            k: 2,
            f: 64.0, // generous: no drops anywhere
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        }
    }

    fn check_all_schedules_match_reference(c: &MoeLayerConfig, seed: u64) {
        let state = LayerState::random(c, seed).unwrap();
        let mut backend = NativeBackend;

        // Reference output per rank (dense, no parallelism).
        let cap_ref = c.tokens() * c.k; // generous
        let refs: Vec<Vec<f32>> = (0..c.par.p)
            .map(|r| {
                reference_forward(c, &state.weights, &state.tokens[r], c.tokens(), cap_ref, &mut backend)
                    .unwrap()
            })
            .collect();

        for kind in [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            // SP with an even and a ragged chunking — numerics must not
            // depend on how the capacity dimension is pipelined.
            ScheduleKind::Pipelined { chunks: 2 },
            ScheduleKind::Pipelined { chunks: 3 },
            // SP2: the chunked-SAA composition must be just as invisible
            // to the numerics, even and ragged alike.
            ScheduleKind::PipelinedS2 { chunks: 2 },
            ScheduleKind::PipelinedS2 { chunks: 3 },
        ] {
            let res = run_schedule(kind, &state, &mut backend).unwrap();
            assert_eq!(res.dropped, 0, "{kind:?} dropped tokens");
            for r in 0..c.par.p {
                assert_close(&res.outputs[r], &refs[r], 1e-4, 1e-3).unwrap_or_else(|e| {
                    panic!("{kind:?} rank {r} mismatch: {e}");
                });
            }
        }
    }

    #[test]
    fn schedules_match_reference_p4() {
        check_all_schedules_match_reference(&cfg(4, 2, 2), 11);
    }

    #[test]
    fn schedules_match_reference_p8_mp2_esp2() {
        check_all_schedules_match_reference(&cfg(8, 2, 2), 12);
    }

    #[test]
    fn schedules_match_reference_p8_mp4_esp2() {
        check_all_schedules_match_reference(&cfg(8, 4, 2), 13);
    }

    #[test]
    fn schedules_match_reference_p8_mp2_esp4() {
        check_all_schedules_match_reference(&cfg(8, 2, 4), 14);
    }

    #[test]
    fn schedules_match_reference_no_mp() {
        check_all_schedules_match_reference(&cfg(4, 1, 2), 15);
    }

    #[test]
    fn schedules_match_reference_no_esp() {
        check_all_schedules_match_reference(&cfg(4, 2, 1), 16);
    }

    #[test]
    fn comm_log_uses_canonical_tags_in_program_order() {
        use crate::comm::tags;
        let c = cfg(8, 2, 2);
        let state = LayerState::random(&c, 3).unwrap();
        let mut backend = NativeBackend;

        let res = run_schedule(ScheduleKind::Baseline, &state, &mut backend).unwrap();
        let tags_seen: Vec<&str> = res.comm_log.iter().map(|(t, _)| *t).collect();
        assert_eq!(
            tags_seen,
            vec![tags::ESP_ALLGATHER, tags::EP_ALLTOALL, tags::ESP_ALLREDUCE]
        );
        assert!(res.comm_log.iter().all(|(_, b)| *b > 0.0));

        let res = run_schedule(ScheduleKind::S2, &state, &mut backend).unwrap();
        let tags_seen: Vec<&str> = res.comm_log.iter().map(|(t, _)| *t).collect();
        assert_eq!(
            tags_seen,
            vec![tags::FUSED_ALLTOALL, tags::SAA_COMBINE, tags::MP_ALLGATHER]
        );

        // SP: one wire-log entry per chunk per direction, in emission
        // order (D_0, D_1, C_0, C_1), then the MP-AllGather epilogue.
        let res =
            run_schedule(ScheduleKind::Pipelined { chunks: 2 }, &state, &mut backend).unwrap();
        let tags_seen: Vec<&str> = res.comm_log.iter().map(|(t, _)| *t).collect();
        assert_eq!(
            tags_seen,
            vec![
                "sp.dispatch.0",
                "sp.dispatch.1",
                "sp.combine.0",
                "sp.combine.1",
                tags::MP_ALLGATHER
            ]
        );

        // SP2: per-chunk dispatch and SAA entries in emission order; every
        // chunk's MP forwards aggregate under the one mp.allgather tag,
        // first touched by chunk 0's SAA.
        let res =
            run_schedule(ScheduleKind::PipelinedS2 { chunks: 2 }, &state, &mut backend).unwrap();
        let tags_seen: Vec<&str> = res.comm_log.iter().map(|(t, _)| *t).collect();
        assert_eq!(
            tags_seen,
            vec![
                "sp2.dispatch.0",
                "sp2.dispatch.1",
                "sp2.saa.0",
                tags::MP_ALLGATHER,
                "sp2.saa.1"
            ]
        );
    }

    #[test]
    fn skewed_routing_matches_reference_on_every_sp_variant() {
        // The routing-skew knob biases the gate identically in the dense
        // reference and every schedule, so equivalence still holds under
        // imbalanced traffic — including the load-weighted spans (which
        // differ from uniform ones precisely because of the skew).
        let mut c = cfg(8, 2, 2);
        c.skew = 1.5;
        let state = LayerState::random(&c, 21).unwrap();
        let mut backend = NativeBackend;
        let cap_ref = c.tokens() * c.k;
        let refs: Vec<Vec<f32>> = (0..c.par.p)
            .map(|r| {
                let toks = &state.tokens[r];
                reference_forward(&c, &state.weights, toks, c.tokens(), cap_ref, &mut backend)
                    .unwrap()
            })
            .collect();
        for kind in [
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::Pipelined { chunks: 2 },
            ScheduleKind::Pipelined { chunks: 4 },
            ScheduleKind::PipelinedUniform { chunks: 4 },
            ScheduleKind::PipelinedS2 { chunks: 3 },
        ] {
            let res = run_schedule(kind, &state, &mut backend).unwrap();
            assert_eq!(res.dropped, 0, "{kind:?} dropped under generous capacity");
            for r in 0..c.par.p {
                assert_close(&res.outputs[r], &refs[r], 1e-4, 1e-3).unwrap_or_else(|e| {
                    panic!("{kind:?} rank {r} mismatch under skew: {e}");
                });
            }
        }
    }

    #[test]
    fn sp_program_clamps_spans_to_actual_capacity() {
        // Regression: `sp_clamp_chunks` clamps on the builder's capacity
        // ESTIMATE; a program whose estimate exceeds the gate's actual
        // capacity used to stage empty chunks and emit zero-byte
        // AlltoAlls. The data plane must clamp every span against the
        // actual capacity, keep the overhanging chunks off the wire, and
        // still produce the exact schedule outputs.
        use crate::comm::transport::DataTransport;
        use crate::schedule::interp::run_program;
        use crate::schedule::ops;

        let c = MoeLayerConfig {
            par: ParallelDegrees { p: 4, n_mp: 1, n_esp: 1 },
            b: 1,
            l: 8,
            e: 4,
            m: 4,
            h: 4,
            k: 1,
            f: 1.0,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        };
        c.validate().unwrap();
        assert_eq!(c.t_pausemp(), 2, "actual gate capacity for this layout");
        let state = LayerState::random(&c, 33).unwrap();
        let mut backend = NativeBackend;
        // Ground truth from the builder's (correctly clamped) 2-chunk
        // program: same routing, same spans [ (0,1), (1,1) ].
        let want = run_schedule(ScheduleKind::Pipelined { chunks: 2 }, &state, &mut backend)
            .unwrap()
            .outputs;

        // Hand-built program claiming FOUR one-row chunks (capacity
        // estimate 4 > actual 2), in the builder's emission order.
        let row1 = ops::bytes_sp_chunk_per_pair(&c, 1);
        let d = |index| Op::SpDispatch { bytes_per_pair: row1, index, of: 4 };
        let f = |index| Op::SpExpertFfn { flops_per_rank: 1.0, index, of: 4 };
        let cb = |index| Op::SpCombine { bytes_per_pair: row1, index, of: 4 };
        let prog = vec![
            Op::MpSplit { bytes_per_rank: 0.0 },
            Op::Gate { flops_per_rank: 1.0 },
            d(0),
            d(1),
            f(0),
            cb(0),
            d(2),
            f(1),
            cb(1),
            d(3),
            f(2),
            cb(2),
            f(3),
            cb(3),
            Op::LocalCombine { flops_per_rank: 1.0 },
            Op::Ungate { flops_per_rank: 1.0 },
            Op::MpAllGather { bytes_per_rank: 0.0 },
        ];
        let mut transport = DataTransport::new();
        let mut machine = DataMachine::new(&state, &mut backend, &prog);
        run_program(&prog, &state.groups, &mut transport, &mut machine).unwrap();
        assert!(matches!(machine.stage, Stage::Tokens));
        for r in 0..c.par.p {
            assert_close(&machine.buf[r], &want[r], 1e-6, 1e-5).unwrap_or_else(|e| {
                panic!("clamped program rank {r} diverged: {e}");
            });
        }
        // The overhanging chunks moved nothing: no zero-byte wire entries,
        // no tags for the empty spans.
        let log = transport.log();
        assert!(log.iter().all(|(_, b)| *b > 0.0), "zero-byte wire entries: {log:?}");
        let tags: Vec<&str> = log.iter().map(|(t, _)| *t).collect();
        assert!(tags.contains(&"sp.dispatch.0") && tags.contains(&"sp.combine.1"), "{tags:?}");
        assert!(
            !tags.contains(&"sp.dispatch.2") && !tags.contains(&"sp.dispatch.3"),
            "empty spans must stay off the wire: {tags:?}"
        );
        assert!(
            !tags.contains(&"sp.combine.2") && !tags.contains(&"sp.combine.3"),
            "empty combines must stay off the wire: {tags:?}"
        );
    }

    #[test]
    fn measured_spans_preserve_schedule_numerics() {
        // Two-pass span selection moves chunk boundaries from the gate's
        // MEASURED loads (organic imbalance — no skew knob), which must
        // not change any output value.
        let c = cfg(8, 2, 2);
        let state = LayerState::random(&c, 29).unwrap();
        let mut backend = NativeBackend;
        let loads = measure_expert_loads(&state);
        assert_eq!(loads.len(), c.e);
        let cap = gating::capacity(c.tokens() / c.par.n_mp, c.e, c.k, c.f, 1);
        assert!(loads.iter().all(|&l| l <= cap), "{loads:?} vs cap {cap}");
        assert!(loads.iter().sum::<usize>() > 0, "gate routed nothing");
        for kind in [
            ScheduleKind::S1,
            ScheduleKind::Pipelined { chunks: 2 },
            ScheduleKind::Pipelined { chunks: 3 },
            ScheduleKind::PipelinedS2 { chunks: 3 },
        ] {
            let plain = run_schedule(kind, &state, &mut backend).unwrap();
            let measured = run_schedule_measured(kind, &state, &mut backend).unwrap();
            assert_eq!(measured.dropped, plain.dropped, "{kind:?}");
            for r in 0..c.par.p {
                assert_close(&measured.outputs[r], &plain.outputs[r], 1e-5, 1e-4)
                    .unwrap_or_else(|e| panic!("{kind:?} rank {r}: {e}"));
            }
        }
    }

    #[test]
    fn sp_requires_resolved_chunk_count() {
        let c = cfg(4, 2, 2);
        let state = LayerState::random(&c, 2).unwrap();
        assert!(run_schedule(
            ScheduleKind::Pipelined { chunks: 0 },
            &state,
            &mut NativeBackend
        )
        .is_err());
    }

    #[test]
    fn tight_capacity_drops_consistently() {
        let mut c = cfg(4, 2, 2);
        c.f = 0.5; // starved capacity
        let state = LayerState::random(&c, 9).unwrap();
        let mut backend = NativeBackend;
        for kind in [ScheduleKind::Baseline, ScheduleKind::S1, ScheduleKind::S2] {
            let res = run_schedule(kind, &state, &mut backend).unwrap();
            assert!(res.dropped > 0, "{kind:?} should drop under f=0.5");
            for out in &res.outputs {
                assert!(out.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn parm_requires_resolution() {
        let c = cfg(4, 2, 2);
        let state = LayerState::random(&c, 1).unwrap();
        assert!(run_schedule(ScheduleKind::Parm, &state, &mut NativeBackend).is_err());
    }

    /// Worst element error of `a` vs `b`, normalized by `max(|b|, 1)` —
    /// one combined abs/rel metric for the wire-precision bands.
    fn max_err(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs() / y.abs().max(1.0)).fold(0.0, f32::max)
    }

    #[test]
    fn reduced_wire_stays_within_tolerance_bands() {
        // Reference equivalence is tolerance-banded at reduced wire
        // precision: every schedule family quantizes its collective
        // inputs to the wire dtype, keeps f32 accumulation, and must land
        // within a band set by the format's relative error (bf16 ≈ 2⁻⁸,
        // fp8 e4m3 ≈ 2⁻⁴) across the ~3 quantizing hops of a forward
        // pass. At f32 wire the outputs stay bit-exact.
        use crate::config::{WireDtype, WirePrecision};
        let c = cfg(8, 2, 2);
        let mut backend = NativeBackend;
        let exact = LayerState::random(&c, 33).unwrap();
        for kind in [
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::Pipelined { chunks: 3 },
            ScheduleKind::PipelinedS2 { chunks: 3 },
        ] {
            let base = run_schedule(kind, &exact, &mut backend).unwrap();
            // Explicit uniform f32 is the identity — bit-for-bit.
            let mut cf = c.clone();
            cf.wire = WirePrecision::uniform(WireDtype::F32);
            let state = LayerState::random(&cf, 33).unwrap();
            let res = run_schedule(kind, &state, &mut backend).unwrap();
            for r in 0..c.par.p {
                assert!(
                    res.outputs[r]
                        .iter()
                        .zip(&base.outputs[r])
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{kind:?} rank {r}: f32 wire must be bit-exact"
                );
            }
            // Narrowed wires: quantization must actually happen, and the
            // error must stay inside the documented band.
            for (dtype, band) in [(WireDtype::Bf16, 5e-2f32), (WireDtype::Fp8, 5e-1f32)] {
                let mut cq = c.clone();
                cq.wire = WirePrecision::uniform(dtype);
                let state = LayerState::random(&cq, 33).unwrap();
                let res = run_schedule(kind, &state, &mut backend).unwrap();
                assert_eq!(res.dropped, 0, "{kind:?} {dtype:?}: routing must not change");
                let mut worst = 0.0f32;
                for r in 0..c.par.p {
                    worst = worst.max(max_err(&res.outputs[r], &base.outputs[r]));
                }
                assert!(
                    worst > 0.0,
                    "{kind:?} {dtype:?}: outputs identical — wire quantization never ran"
                );
                assert!(
                    worst <= band,
                    "{kind:?} {dtype:?}: worst error {worst} exceeds band {band}"
                );
            }
        }
    }

    #[test]
    fn compressed_wire_log_scales_bytes_tag_for_tag() {
        // The data plane's wire log reports COMPRESSED bytes: a uniform
        // bf16 policy halves every entry of every schedule family's log
        // (f32 payloads priced at 2 of 4 bytes per element), tag for tag,
        // without adding or dropping entries.
        use crate::config::{WireDtype, WirePrecision};
        let c = cfg(8, 2, 2);
        let mut backend = NativeBackend;
        let wide = LayerState::random(&c, 7).unwrap();
        let mut ch = c.clone();
        ch.wire = WirePrecision::uniform(WireDtype::Bf16);
        let half = LayerState::random(&ch, 7).unwrap();
        for kind in [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::Pipelined { chunks: 2 },
            ScheduleKind::PipelinedS2 { chunks: 2 },
        ] {
            let log_f32 = run_schedule(kind, &wide, &mut backend).unwrap().comm_log;
            let log_bf16 = run_schedule(kind, &half, &mut backend).unwrap().comm_log;
            assert_eq!(log_f32.len(), log_bf16.len(), "{kind:?}: entry counts diverged");
            for ((t4, b4), (t2, b2)) in log_f32.iter().zip(&log_bf16) {
                assert_eq!(t4, t2, "{kind:?}: tag order diverged");
                assert_eq!(*b2, 0.5 * *b4, "{kind:?} {t4}: expected half of {b4}, got {b2}");
            }
        }
    }
}
