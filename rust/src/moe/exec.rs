//! Distributed data-plane executor: runs one MoE layer forward under the
//! Baseline / S1 / S2 schedule over P in-process ranks with *real* tensor
//! data and the real collective semantics of [`crate::comm::data`].
//!
//! This is the semantics-preservation proof the paper asserts implicitly:
//! all three schedules (and the single-device reference) must produce the
//! same outputs for drop-free capacities. The executor also emits a
//! communication log whose (tag, volume) entries are cross-checked in
//! tests against the schedule IR the simulator times — the thing we time
//! is the thing we verified.

use anyhow::{ensure, Result};

use crate::cluster::{GroupKind, ProcessGroups};
use crate::comm::data;
use crate::config::MoeLayerConfig;
use crate::moe::backend::ExpertBackend;
use crate::moe::gating::{self, DispatchInfo};
use crate::moe::weights::GlobalWeights;
use crate::schedule::ScheduleKind;
use crate::util::prng::Rng;

/// The world's state entering a MoE layer.
#[derive(Debug, Clone)]
pub struct LayerState {
    pub cfg: MoeLayerConfig,
    pub groups: ProcessGroups,
    pub weights: GlobalWeights,
    /// Per-rank tokens, (B·L, M) row-major; MP groups carry duplicates.
    pub tokens: Vec<Vec<f32>>,
}

impl LayerState {
    /// Random state: one distinct token set per MP group, duplicated to
    /// members (the MP invariant at a MoE layer boundary).
    pub fn random(cfg: &MoeLayerConfig, seed: u64) -> Result<LayerState> {
        cfg.validate()?;
        let groups = ProcessGroups::new(cfg.par)?;
        let weights = GlobalWeights::random(cfg, seed);
        let mut rng = Rng::new(seed ^ 0xD15A);
        let n = cfg.tokens() * cfg.m;
        let mut tokens: Vec<Vec<f32>> = vec![Vec::new(); cfg.par.p];
        for r in 0..cfg.par.p {
            if groups.mp_index(r) == 0 {
                tokens[r] = rng.f32_vec(n);
            }
        }
        for r in 0..cfg.par.p {
            if groups.mp_index(r) != 0 {
                let leader = groups.mp_group(r)[0];
                tokens[r] = tokens[leader].clone();
            }
        }
        Ok(LayerState { cfg: cfg.clone(), groups, weights, tokens })
    }
}

/// Result of running a schedule on the data plane.
#[derive(Debug, Clone)]
pub struct ExecResult {
    /// Per-rank layer outputs, (B·L, M) — same shape/meaning as inputs.
    pub outputs: Vec<Vec<f32>>,
    /// (tag, per-rank bytes) per collective executed, for IR cross-check.
    pub comm_log: Vec<(String, f64)>,
    /// Tokens dropped by capacity limits (0 for generous `f`).
    pub dropped: usize,
}

/// Execute one forward pass of the layer under `kind`.
pub fn run_schedule(
    kind: ScheduleKind,
    state: &LayerState,
    backend: &mut dyn ExpertBackend,
) -> Result<ExecResult> {
    match kind {
        ScheduleKind::Baseline => baseline_forward(state, backend),
        ScheduleKind::S1 => s1_forward(state, backend),
        // S2 and S2Aas share the data plane (SAA changes timing, not
        // bytes — saa_data == saa_reference is proven in comm::saa).
        ScheduleKind::S2 | ScheduleKind::S2Aas => s2_forward(state, backend),
        ScheduleKind::Parm => {
            anyhow::bail!("resolve Parm to S1/S2 via the perf model first")
        }
    }
}

const FB: f64 = 4.0; // f32 bytes

// ---------------------------------------------------------------------
// Baseline (Fig 3a): ESP-AllGather → Gate → EP-AlltoAll → experts →
// ESP-AllReduce → EP-AlltoAll → un-gate → ESP-Split.
// ---------------------------------------------------------------------
fn baseline_forward(
    state: &LayerState,
    backend: &mut dyn ExpertBackend,
) -> Result<ExecResult> {
    let c = &state.cfg;
    let g = &state.groups;
    let p = c.par.p;
    let m = c.m;
    let hs = c.h / c.par.n_esp;
    let e_local = c.experts_per_rank();
    let n_ep = c.par.n_ep();
    let mut log = Vec::new();

    // 1. ESP-AllGather of the tokens.
    let mut world: Vec<Vec<f32>> = state.tokens.clone();
    for grp in g.all_groups(GroupKind::Esp) {
        data::allgather(&mut world, &grp);
    }
    log.push(("esp.allgather".to_string(), (c.tokens() * m) as f64 * FB));

    // 2. Gate the gathered tokens (identical within each ESP group).
    let n_gathered = c.tokens() * c.par.n_esp;
    let cap = gating::capacity(n_gathered, c.e, c.k, c.f, 1);
    let mut infos: Vec<DispatchInfo> = Vec::with_capacity(p);
    let mut dispatch: Vec<Vec<f32>> = Vec::with_capacity(p);
    for r in 0..p {
        let info = gating::gate(&world[r], &state.weights.wg, n_gathered, m, c.e, c.k, cap);
        dispatch.push(gating::build_dispatch(&info, &world[r], m));
        infos.push(info);
    }
    let dropped = infos.iter().map(|i| i.dropped).sum();

    // 3. EP-AlltoAll dispatch: chunk j of the (E, cap, M) tensor = the
    // experts of EP slot j (contiguous rows).
    let mut world = dispatch;
    for grp in g.all_groups(GroupKind::Ep) {
        data::alltoall(&mut world, &grp);
    }
    log.push(("ep.alltoall".to_string(), (e_local * cap * m) as f64 * FB));
    // Rank now holds (N_EP srcs, E_local, cap, M).

    // 4. Expert shards: per (src, local expert) block, batched per expert.
    let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); p];
    for r in 0..p {
        let (w1s, w2s) = state.weights.shard_for_rank(c, g, r);
        let recv = &world[r];
        let mut out = vec![0.0f32; recv.len()];
        let block = e_local * cap * m;
        for le in 0..e_local {
            // Gather rows of local expert `le` from every source chunk.
            let mut x = Vec::with_capacity(n_ep * cap * m);
            for src in 0..n_ep {
                let base = src * block + le * cap * m;
                x.extend_from_slice(&recv[base..base + cap * m]);
            }
            let y = backend.expert_ffn(&x, &w1s[le], &w2s[le], n_ep * cap, m, hs)?;
            for src in 0..n_ep {
                let base = src * block + le * cap * m;
                out[base..base + cap * m]
                    .copy_from_slice(&y[src * cap * m..(src + 1) * cap * m]);
            }
        }
        outputs[r] = out;
    }

    // 5. ESP-AllReduce of the partial expert outputs.
    let mut world = outputs;
    for grp in g.all_groups(GroupKind::Esp) {
        data::allreduce(&mut world, &grp);
    }
    log.push(("esp.allreduce".to_string(), (n_ep * e_local * cap * m) as f64 * FB));

    // 6. EP-AlltoAll combine (chunk j = outputs computed for source j).
    for grp in g.all_groups(GroupKind::Ep) {
        data::alltoall(&mut world, &grp);
    }
    log.push(("ep.alltoall".to_string(), (e_local * cap * m) as f64 * FB));
    // Rank holds (N_EP blocks, E_local, cap, M) = (E, cap, M) in expert
    // order — exactly its dispatch tensor's outputs.

    // 7. Un-gate to gathered-token order, then ESP-Split keeps own rows.
    let mut final_out: Vec<Vec<f32>> = vec![Vec::new(); p];
    for r in 0..p {
        let y = gating::combine(&infos[r], &world[r], m);
        let shard = g.esp_shard(r);
        let start = shard * c.tokens() * m;
        final_out[r] = y[start..start + c.tokens() * m].to_vec();
    }
    log.push(("esp.split".to_string(), 0.0));

    Ok(ExecResult { outputs: final_out, comm_log: log, dropped })
}

// ---------------------------------------------------------------------
// PauseMP common pieces (S1/S2): fused dispatch / combine over the
// EP×ESP product group with local Dump / local Combine.
// ---------------------------------------------------------------------

/// Build the fused-AlltoAll send buffer from a (E, cap, M) dispatch
/// tensor: for each destination rank (block j, shard s) append the rows of
/// block j's experts — the Dump duplicates each block's slice to its
/// N_ESP shard holders.
fn fused_send_buffer(
    d: &[f32],
    g: &ProcessGroups,
    e: usize,
    cap: usize,
    m: usize,
) -> Vec<f32> {
    let p = g.par.p;
    let mut out = Vec::with_capacity(p * (e / g.par.n_ep()).max(1) * cap * m);
    for dst in 0..p {
        let slot = g.ep_slot(dst);
        for ex in g.experts_of_slot(slot, e) {
            out.extend_from_slice(&d[ex * cap * m..(ex + 1) * cap * m]);
        }
    }
    out
}

/// Inverse of the Dump: sum the per-shard partial copies returned by the
/// combine AlltoAll into a (E, cap, M) tensor.
fn fused_combine_buffer(
    recv: &[f32],
    g: &ProcessGroups,
    e: usize,
    cap: usize,
    m: usize,
) -> Vec<f32> {
    let p = g.par.p;
    let e_local = (e / g.par.n_ep()).max(1);
    let chunk = e_local * cap * m;
    assert_eq!(recv.len(), p * chunk);
    let mut out = vec![0.0f32; e * cap * m];
    for q in 0..p {
        let slot = g.ep_slot(q);
        for (i, ex) in g.experts_of_slot(slot, e).enumerate() {
            let src = q * chunk + i * cap * m;
            let dst = ex * cap * m;
            for j in 0..cap * m {
                out[dst + j] += recv[src + j];
            }
        }
    }
    out
}

/// Shared S1/S2 middle: fused dispatch → expert shards → fused combine →
/// local combine. Takes each rank's (E, cap, M) dispatch tensor; returns
/// each rank's (E, cap, M) expert outputs.
fn pausemp_expert_phase(
    state: &LayerState,
    dispatch: Vec<Vec<f32>>,
    cap: usize,
    backend: &mut dyn ExpertBackend,
    log: &mut Vec<(String, f64)>,
) -> Result<Vec<Vec<f32>>> {
    let c = &state.cfg;
    let g = &state.groups;
    let p = c.par.p;
    let m = c.m;
    let hs = c.h / c.par.n_esp;
    let e_local = c.experts_per_rank();
    let world_group: Vec<usize> = g.world();

    // Dump + fused AlltoAll dispatch.
    let mut world: Vec<Vec<f32>> = dispatch
        .iter()
        .map(|d| fused_send_buffer(d, g, c.e, cap, m))
        .collect();
    data::alltoall(&mut world, &world_group);
    log.push(("fused.alltoall".to_string(), (e_local * cap * m) as f64 * FB));
    // Rank holds (P srcs, E_local, cap, M).

    // Expert shards, batched per local expert over all P sources.
    let block = e_local * cap * m;
    for r in 0..p {
        let (w1s, w2s) = state.weights.shard_for_rank(c, g, r);
        let recv = std::mem::take(&mut world[r]);
        let mut out = vec![0.0f32; recv.len()];
        for le in 0..e_local {
            let mut x = Vec::with_capacity(p * cap * m);
            for src in 0..p {
                let base = src * block + le * cap * m;
                x.extend_from_slice(&recv[base..base + cap * m]);
            }
            let y = backend.expert_ffn(&x, &w1s[le], &w2s[le], p * cap, m, hs)?;
            for src in 0..p {
                let base = src * block + le * cap * m;
                out[base..base + cap * m]
                    .copy_from_slice(&y[src * cap * m..(src + 1) * cap * m]);
            }
        }
        world[r] = out;
    }

    // Fused AlltoAll combine (send buffer already ordered by source).
    data::alltoall(&mut world, &world_group);
    log.push(("fused.alltoall".to_string(), (e_local * cap * m) as f64 * FB));

    // Local combine: sum shard partials per expert block.
    let out = world
        .iter()
        .map(|recv| fused_combine_buffer(recv, g, c.e, cap, m))
        .collect();
    log.push(("local.combine".to_string(), 0.0));
    Ok(out)
}

// ---------------------------------------------------------------------
// S1 (Fig 3b): MP-Split → Gate → fused dispatch/experts/combine →
// un-gate → MP-AllGather.
// ---------------------------------------------------------------------
fn s1_forward(state: &LayerState, backend: &mut dyn ExpertBackend) -> Result<ExecResult> {
    let c = &state.cfg;
    let g = &state.groups;
    let p = c.par.p;
    let m = c.m;
    ensure!(c.tokens() % c.par.n_mp == 0, "B·L must divide N_MP");
    let n_local = c.tokens() / c.par.n_mp;
    let mut log = Vec::new();

    // 1. MP-Split: each rank keeps its 1/N_MP token slice.
    let slices: Vec<Vec<f32>> = (0..p)
        .map(|r| {
            let mi = g.mp_index(r);
            state.tokens[r][mi * n_local * m..(mi + 1) * n_local * m].to_vec()
        })
        .collect();
    log.push(("mp.split".to_string(), 0.0));

    // 2. Gate the local slice.
    let cap = gating::capacity(n_local, c.e, c.k, c.f, 1);
    let mut infos = Vec::with_capacity(p);
    let mut dispatch = Vec::with_capacity(p);
    for r in 0..p {
        let info = gating::gate(&slices[r], &state.weights.wg, n_local, m, c.e, c.k, cap);
        dispatch.push(gating::build_dispatch(&info, &slices[r], m));
        infos.push(info);
    }
    let dropped = infos.iter().map(|i| i.dropped).sum();

    // 3-6. Fused dispatch → experts → fused combine → local combine.
    let expert_out = pausemp_expert_phase(state, dispatch, cap, backend, &mut log)?;

    // 7. Un-gate to local token order.
    let mut world: Vec<Vec<f32>> = (0..p)
        .map(|r| gating::combine(&infos[r], &expert_out[r], m))
        .collect();

    // 8. MP-AllGather restores the full (B·L, M) tokens.
    for grp in g.all_groups(GroupKind::Mp) {
        data::allgather(&mut world, &grp);
    }
    log.push(("mp.allgather".to_string(), (n_local * m) as f64 * FB));

    Ok(ExecResult { outputs: world, comm_log: log, dropped })
}

// ---------------------------------------------------------------------
// S2 (Fig 3c): Gate (full tokens) → MP-Split of capacity slots → fused
// dispatch/experts/combine → MP-AllGather of the (E, C, M) outputs
// (overlapped with the combine via SAA on the wire) → un-gate.
// ---------------------------------------------------------------------
fn s2_forward(state: &LayerState, backend: &mut dyn ExpertBackend) -> Result<ExecResult> {
    let c = &state.cfg;
    let g = &state.groups;
    let p = c.par.p;
    let m = c.m;
    let n = c.tokens();
    let mut log = Vec::new();

    // 1. Gate on the full (MP-duplicated) tokens; capacity divisible by
    // N_MP so the slot split is even.
    let cap = gating::capacity(n, c.e, c.k, c.f, c.par.n_mp);
    let cap_local = cap / c.par.n_mp;
    let mut infos = Vec::with_capacity(p);
    let mut dispatch_full = Vec::with_capacity(p);
    for r in 0..p {
        let info = gating::gate(&state.tokens[r], &state.weights.wg, n, m, c.e, c.k, cap);
        dispatch_full.push(gating::build_dispatch(&info, &state.tokens[r], m));
        infos.push(info);
    }
    let dropped = infos.iter().map(|i| i.dropped).sum();

    // 2. MP-Split of the capacity dimension: member i keeps slots
    // [i·cap_local, (i+1)·cap_local) of every expert.
    let mut dispatch = Vec::with_capacity(p);
    for r in 0..p {
        let mi = g.mp_index(r);
        let full = &dispatch_full[r];
        let mut part = Vec::with_capacity(c.e * cap_local * m);
        for ex in 0..c.e {
            let base = (ex * cap + mi * cap_local) * m;
            part.extend_from_slice(&full[base..base + cap_local * m]);
        }
        dispatch.push(part);
    }
    log.push(("mp.split".to_string(), 0.0));

    // 3-6. Fused dispatch → experts → fused combine → local combine.
    let expert_out = pausemp_expert_phase(state, dispatch, cap_local, backend, &mut log)?;

    // 7. MP-AllGather of the (E, cap_local, M) outputs; on the wire this
    // is the SAA-overlapped combine (see comm::saa for the equivalence
    // proof). Gathered chunks interleave back into (E, cap, M) slot order.
    let mut world = expert_out;
    for grp in g.all_groups(GroupKind::Mp) {
        data::allgather(&mut world, &grp);
    }
    log.push(("mp.allgather".to_string(), (c.e * cap_local * m) as f64 * FB));

    let mut outputs = Vec::with_capacity(p);
    for r in 0..p {
        let gathered = &world[r]; // (N_MP, E, cap_local, M) in MP order
        let mut full = vec![0.0f32; c.e * cap * m];
        let chunk = c.e * cap_local * m;
        for mi in 0..c.par.n_mp {
            for ex in 0..c.e {
                let src = mi * chunk + ex * cap_local * m;
                let dst = (ex * cap + mi * cap_local) * m;
                full[dst..dst + cap_local * m]
                    .copy_from_slice(&gathered[src..src + cap_local * m]);
            }
        }
        // 8. Un-gate.
        outputs.push(gating::combine(&infos[r], &full, m));
    }

    Ok(ExecResult { outputs, comm_log: log, dropped })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::moe::ParallelDegrees;
    use crate::moe::backend::NativeBackend;
    use crate::moe::reference::reference_forward;
    use crate::util::propcheck::assert_close;

    /// Drop-free config: generous capacity factor.
    fn cfg(p: usize, n_mp: usize, n_esp: usize) -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p, n_mp, n_esp },
            b: 1,
            l: 16,
            e: (p / n_esp).max(2),
            m: 8,
            h: 8 * n_esp, // divisible by n_esp
            k: 2,
            f: 64.0, // generous: no drops anywhere
            dtype_bytes: 4,
        }
    }

    fn check_all_schedules_match_reference(c: &MoeLayerConfig, seed: u64) {
        let state = LayerState::random(c, seed).unwrap();
        let mut backend = NativeBackend;

        // Reference output per rank (dense, no parallelism).
        let cap_ref = c.tokens() * c.k; // generous
        let refs: Vec<Vec<f32>> = (0..c.par.p)
            .map(|r| {
                reference_forward(c, &state.weights, &state.tokens[r], c.tokens(), cap_ref, &mut backend)
                    .unwrap()
            })
            .collect();

        for kind in [ScheduleKind::Baseline, ScheduleKind::S1, ScheduleKind::S2] {
            let res = run_schedule(kind, &state, &mut backend).unwrap();
            assert_eq!(res.dropped, 0, "{kind:?} dropped tokens");
            for r in 0..c.par.p {
                assert_close(&res.outputs[r], &refs[r], 1e-4, 1e-3).unwrap_or_else(|e| {
                    panic!("{kind:?} rank {r} mismatch: {e}");
                });
            }
        }
    }

    #[test]
    fn schedules_match_reference_p4() {
        check_all_schedules_match_reference(&cfg(4, 2, 2), 11);
    }

    #[test]
    fn schedules_match_reference_p8_mp2_esp2() {
        check_all_schedules_match_reference(&cfg(8, 2, 2), 12);
    }

    #[test]
    fn schedules_match_reference_p8_mp4_esp2() {
        check_all_schedules_match_reference(&cfg(8, 4, 2), 13);
    }

    #[test]
    fn schedules_match_reference_p8_mp2_esp4() {
        check_all_schedules_match_reference(&cfg(8, 2, 4), 14);
    }

    #[test]
    fn schedules_match_reference_no_mp() {
        check_all_schedules_match_reference(&cfg(4, 1, 2), 15);
    }

    #[test]
    fn schedules_match_reference_no_esp() {
        check_all_schedules_match_reference(&cfg(4, 2, 1), 16);
    }

    #[test]
    fn comm_log_matches_schedule_ir() {
        // The data plane's collective volumes must agree with the op
        // program the simulator times (within capacity-rounding).
        use crate::schedule::{forward_ops, Op};
        let c = cfg(8, 2, 2);
        let state = LayerState::random(&c, 3).unwrap();
        let mut backend = NativeBackend;
        for kind in [ScheduleKind::Baseline, ScheduleKind::S1, ScheduleKind::S2] {
            let res = run_schedule(kind, &state, &mut backend).unwrap();
            let ops = forward_ops(kind, &c);
            let mut ir_comm: Vec<(&str, f64)> = Vec::new();
            for o in &ops {
                match *o {
                    Op::EspAllGather { bytes_per_rank } => {
                        ir_comm.push(("esp.allgather", bytes_per_rank))
                    }
                    Op::EpAlltoAll { bytes_per_pair } => {
                        ir_comm.push(("ep.alltoall", bytes_per_pair))
                    }
                    Op::EspAllReduce { total_bytes } => {
                        ir_comm.push(("esp.allreduce", total_bytes))
                    }
                    Op::FusedAlltoAll { bytes_per_pair } => {
                        ir_comm.push(("fused.alltoall", bytes_per_pair))
                    }
                    // SAA/AAS = fused combine + MP-AllGather on the wire.
                    Op::SaaCombine { bytes_per_pair } | Op::AasCombine { bytes_per_pair } => {
                        ir_comm.push(("fused.alltoall", bytes_per_pair));
                        ir_comm.push((
                            "mp.allgather",
                            crate::schedule::ops::bytes_mp_ag_s2_per_rank(&c),
                        ));
                    }
                    Op::MpAllGather { bytes_per_rank } => {
                        ir_comm.push(("mp.allgather", bytes_per_rank))
                    }
                    _ => {}
                }
            }
            let exec_comm: Vec<(&str, f64)> = res
                .comm_log
                .iter()
                .filter(|(_, b)| *b > 0.0)
                .map(|(t, b)| (t.as_str(), *b))
                .collect();
            assert_eq!(
                ir_comm.len(),
                exec_comm.len(),
                "{kind:?}: IR {ir_comm:?} vs exec {exec_comm:?}"
            );
            for ((it, ib), (et, eb)) in ir_comm.iter().zip(exec_comm.iter()) {
                assert_eq!(it, et, "{kind:?} op order");
                let rel = (ib - eb).abs() / ib.max(*eb);
                assert!(
                    rel < 0.15,
                    "{kind:?} {it}: IR {ib} vs exec {eb} (rel {rel})"
                );
            }
        }
    }

    #[test]
    fn tight_capacity_drops_consistently() {
        let mut c = cfg(4, 2, 2);
        c.f = 0.5; // starved capacity
        let state = LayerState::random(&c, 9).unwrap();
        let mut backend = NativeBackend;
        for kind in [ScheduleKind::Baseline, ScheduleKind::S1, ScheduleKind::S2] {
            let res = run_schedule(kind, &state, &mut backend).unwrap();
            assert!(res.dropped > 0, "{kind:?} should drop under f=0.5");
            for out in &res.outputs {
                assert!(out.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn parm_requires_resolution() {
        let c = cfg(4, 2, 2);
        let state = LayerState::random(&c, 1).unwrap();
        assert!(run_schedule(ScheduleKind::Parm, &state, &mut NativeBackend).is_err());
    }
}
