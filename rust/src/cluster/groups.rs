//! Process-group construction for MP + EP + ESP (paper §II-B, Fig 2).
//!
//! Rank layout (matching DeepSpeed-MoE's contiguous placement, which the
//! paper's observations assume):
//!
//! * **ESP blocks**: ranks `[i·N_ESP, (i+1)·N_ESP)` form ESP group `i`.
//!   Block `i` collectively hosts the experts of EP slot `i`, each expert
//!   sharded `N_ESP` ways across the block. Placed intra-node whenever
//!   `N_ESP ≤ gpus_per_node` (Observation 1: "intra-node ESP-AllGather").
//! * **EP groups**: ranks with equal offset within their ESP block —
//!   `{ off + j·N_ESP : j ∈ 0..N_EP }` — stride across blocks (and nodes;
//!   Observation 1: "inter-node EP-AlltoAll").
//! * **MP groups**: `N_MP` consecutive ranks; activations entering the MoE
//!   layer are duplicated within an MP group.
//! * **EP&ESP product group**: all `P = N_EP · N_ESP` ranks — the domain of
//!   Parm's fused AlltoAll (§III-C).
//!
//! In Fig 2's example (`N_MP = N_EP = N_ESP = 2`, two nodes × two GPUs):
//! ESP groups {0,1},{2,3}; EP groups {0,2},{1,3}; MP groups {0,1},{2,3} —
//! which this module reproduces (see tests).

use anyhow::Result;

use crate::config::moe::ParallelDegrees;
use crate::config::ClusterTopology;

/// The collective-communication domains used by the schedules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GroupKind {
    Mp,
    Ep,
    Esp,
    /// The fused EP×ESP product group (all ranks of the layer).
    EpEsp,
}

/// Materialized rank sets for every group of a parallel layout.
#[derive(Debug, Clone, PartialEq)]
pub struct ProcessGroups {
    pub par: ParallelDegrees,
}

impl ProcessGroups {
    pub fn new(par: ParallelDegrees) -> Result<ProcessGroups> {
        par.validate()?;
        Ok(ProcessGroups { par })
    }

    pub fn world(&self) -> Vec<usize> {
        (0..self.par.p).collect()
    }

    /// ESP group (rank set) containing `rank`.
    pub fn esp_group(&self, rank: usize) -> Vec<usize> {
        let block = rank / self.par.n_esp;
        (block * self.par.n_esp..(block + 1) * self.par.n_esp).collect()
    }

    /// EP group containing `rank`: equal offsets across ESP blocks.
    pub fn ep_group(&self, rank: usize) -> Vec<usize> {
        let off = rank % self.par.n_esp;
        (0..self.par.n_ep()).map(|j| off + j * self.par.n_esp).collect()
    }

    /// MP group containing `rank`: consecutive block of `n_mp`.
    pub fn mp_group(&self, rank: usize) -> Vec<usize> {
        let block = rank / self.par.n_mp;
        (block * self.par.n_mp..(block + 1) * self.par.n_mp).collect()
    }

    /// Group of `kind` containing `rank`.
    pub fn group(&self, kind: GroupKind, rank: usize) -> Vec<usize> {
        match kind {
            GroupKind::Mp => self.mp_group(rank),
            GroupKind::Ep => self.ep_group(rank),
            GroupKind::Esp => self.esp_group(rank),
            GroupKind::EpEsp => self.world(),
        }
    }

    /// All distinct groups of a kind (each rank appears in exactly one).
    pub fn all_groups(&self, kind: GroupKind) -> Vec<Vec<usize>> {
        let mut seen = vec![false; self.par.p];
        let mut out = Vec::new();
        for r in 0..self.par.p {
            if !seen[r] {
                let g = self.group(kind, r);
                for &m in &g {
                    seen[m] = true;
                }
                out.push(g);
            }
        }
        out
    }

    /// EP slot (== ESP block index) of `rank`.
    pub fn ep_slot(&self, rank: usize) -> usize {
        rank / self.par.n_esp
    }

    /// Offset of `rank` within its ESP block (its shard index).
    pub fn esp_shard(&self, rank: usize) -> usize {
        rank % self.par.n_esp
    }

    /// Rank's index within its MP group (0 = MP leader).
    pub fn mp_index(&self, rank: usize) -> usize {
        rank % self.par.n_mp
    }

    /// EP slot hosting `expert` when `e` experts are distributed round-robin
    /// blocks over `n_ep` slots (contiguous: slot = expert / (e / n_ep)).
    pub fn slot_of_expert(&self, expert: usize, e: usize) -> usize {
        let n_ep = self.par.n_ep();
        if e >= n_ep {
            expert / (e / n_ep)
        } else {
            // Fewer experts than slots: experts replicated? No — slots
            // beyond `e` idle; expert i lives in slot i.
            expert
        }
    }

    /// Experts hosted by `slot` (empty if the slot is idle).
    pub fn experts_of_slot(&self, slot: usize, e: usize) -> std::ops::Range<usize> {
        let n_ep = self.par.n_ep();
        if e >= n_ep {
            let per = e / n_ep;
            slot * per..(slot + 1) * per
        } else if slot < e {
            slot..slot + 1
        } else {
            0..0
        }
    }

    /// True when every rank of the group lies on one node of `cluster`.
    pub fn group_intra_node(&self, kind: GroupKind, rank: usize, cluster: &ClusterTopology) -> bool {
        let g = self.group(kind, rank);
        let first = cluster.node_of(g[0]);
        g.iter().all(|&r| cluster.node_of(r) == first)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pg(p: usize, n_mp: usize, n_esp: usize) -> ProcessGroups {
        ProcessGroups::new(ParallelDegrees { p, n_mp, n_esp }).unwrap()
    }

    #[test]
    fn fig2_layout() {
        // N_MP = N_EP = N_ESP = 2, P = 4 (two nodes × two GPUs).
        let g = pg(4, 2, 2);
        assert_eq!(g.esp_group(0), vec![0, 1]);
        assert_eq!(g.esp_group(3), vec![2, 3]);
        assert_eq!(g.ep_group(0), vec![0, 2]);
        assert_eq!(g.ep_group(1), vec![1, 3]);
        assert_eq!(g.mp_group(0), vec![0, 1]);
        assert_eq!(g.mp_group(2), vec![2, 3]);
        assert_eq!(g.group(GroupKind::EpEsp, 0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn groups_partition_world() {
        for (p, n_mp, n_esp) in [(8, 2, 2), (8, 4, 2), (16, 2, 4), (32, 4, 4), (8, 1, 1)] {
            let g = pg(p, n_mp, n_esp);
            for kind in [GroupKind::Mp, GroupKind::Ep, GroupKind::Esp] {
                let groups = g.all_groups(kind);
                let mut all: Vec<usize> = groups.concat();
                all.sort_unstable();
                assert_eq!(all, (0..p).collect::<Vec<_>>(), "{kind:?} p={p}");
            }
        }
    }

    #[test]
    fn group_membership_consistent() {
        let g = pg(16, 2, 4);
        for r in 0..16 {
            for kind in [GroupKind::Mp, GroupKind::Ep, GroupKind::Esp] {
                let grp = g.group(kind, r);
                assert!(grp.contains(&r), "{kind:?} group of {r} = {grp:?}");
                // Every member's group is identical.
                for &m in &grp {
                    assert_eq!(g.group(kind, m), grp);
                }
            }
        }
    }

    #[test]
    fn ep_esp_cross_section() {
        // EP and ESP groups of a rank intersect exactly in that rank.
        let g = pg(32, 4, 4);
        for r in 0..32 {
            let ep = g.ep_group(r);
            let esp = g.esp_group(r);
            let inter: Vec<usize> = ep.iter().filter(|x| esp.contains(x)).cloned().collect();
            assert_eq!(inter, vec![r]);
        }
    }

    #[test]
    fn expert_slots() {
        let g = pg(8, 1, 2); // n_ep = 4
        // 8 experts over 4 slots: 2 per slot.
        assert_eq!(g.slot_of_expert(0, 8), 0);
        assert_eq!(g.slot_of_expert(3, 8), 1);
        assert_eq!(g.experts_of_slot(2, 8), 4..6);
        // 2 experts over 4 slots: slots 2,3 idle.
        assert_eq!(g.slot_of_expert(1, 2), 1);
        assert_eq!(g.experts_of_slot(3, 2), 0..0);
    }

    #[test]
    fn intra_node_detection() {
        let cluster = ClusterTopology::testbed_b(); // 4 GPUs/node
        let g = pg(32, 4, 4);
        for r in 0..32 {
            assert!(g.group_intra_node(GroupKind::Esp, r, &cluster));
            assert!(g.group_intra_node(GroupKind::Mp, r, &cluster));
            assert!(!g.group_intra_node(GroupKind::Ep, r, &cluster));
        }
    }

    #[test]
    fn shard_and_slot_indices() {
        let g = pg(8, 2, 4);
        assert_eq!(g.ep_slot(5), 1);
        assert_eq!(g.esp_shard(5), 1);
        assert_eq!(g.mp_index(5), 1);
    }
}
