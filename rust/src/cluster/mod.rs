//! Cluster-side abstractions: process-group construction for the hybrid
//! MP+EP+ESP parallelism and placement reasoning over a [`ClusterProfile`].

pub mod groups;

pub use groups::{GroupKind, ProcessGroups};
