//! Cluster-side abstractions: process-group construction for the hybrid
//! MP+EP+ESP parallelism and placement reasoning over a
//! [`crate::config::ClusterTopology`].
//!
//! The topology object owns the hardware facts — per-node GPU counts,
//! per-GPU throughput/memory ([`crate::config::NodeSpec`]) and the
//! per-link α-β lookup ([`crate::config::ClusterTopology::link`], with
//! stable [`crate::config::LinkClass`] identities for fitting and
//! reporting). This module owns the *logical* side: which ranks form the
//! MP/EP/ESP/EP&ESP groups ([`ProcessGroups`]), and placement predicates
//! such as [`ProcessGroups::group_intra_node`] that the sweep feasibility
//! filter and the schedules' §IV assumptions (ESP and MP groups
//! intra-node) are checked against — per group against the actual
//! topology, so mixed per-node GPU counts are handled, not just a uniform
//! `gpus_per_node` bound.

pub mod groups;

pub use groups::{GroupKind, ProcessGroups};
