//! Compiled plan artifacts: the planner's output as a versioned,
//! deployable document.
//!
//! A [`Plan`] captures everything `parm sim/choose/sweep` would otherwise
//! recompute on every invocation: the fitted per-collective and
//! per-[`crate::config::LinkClass`] α-β tables and per-node throughputs of
//! every parallel layout in a sweep grid ([`PerfModel`]), plus the
//! per-configuration Algorithm-1 decision ([`Prediction`] — the closed-form
//! times, both pipelined chunk counts, and the bottleneck node). Building
//! the plan is the expensive step (`parm plan build`); loading one is pure
//! deserialization, so a `--plan` run never refits.
//!
//! ## Schema (version [`PLAN_SCHEMA_VERSION`])
//!
//! ```text
//! { "schema":       2,
//!   "cluster_hash": "<fnv64 hex of the topology's canonical JSON>",
//!   "grid_hash":    "<fnv64 hex over each config's canonical JSON, in order>",
//!   "cluster":      { ... ClusterTopology::to_json ... },
//!   "models":       [ { ... PerfModel::to_json ... }, ... ],   // one per layout
//!   "decisions":    [ { "config": {...}, "prediction": {...} }, ... ] }
//! ```
//!
//! All hashes are the stable FNV-1a of [`crate::util::hash`] over
//! *canonical encodings* — the compact JSON the structs themselves emit —
//! so a plan matches a topology iff their documents are identical, and any
//! edit (a node's flops, a link constant, a rename) changes the hash.
//! Loading verifies the schema version and, via [`Plan::load_checked`],
//! the topology hash: a mismatch is a hard error naming both hashes,
//! never a silent stale read. The same `(schema, cluster_hash, config)`
//! triple keys the sweep's on-disk case cache in
//! [`crate::bench::runner`], so plan artifacts and warm caches invalidate
//! together.
//!
//! Floats survive the roundtrip bit-exactly (Rust's `Display` prints the
//! shortest representation that reparses to the same f64), which is what
//! lets a plan-seeded or cache-warm sweep reproduce its CSV byte for byte.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::moe::ParallelDegrees;
use crate::config::{ClusterTopology, MoeLayerConfig};
use crate::util::hash::Fnv64;
use crate::util::json::Json;

use super::fit::PerfModel;
use super::selection::{self, Prediction};

/// Bumped whenever the plan document or anything it embeds changes shape;
/// also part of the sweep case-cache key, so caches invalidate with it.
/// v2: [`Prediction`] gained the backward fields (`t_wgrad_ar`,
/// `t_iter_s1`, `t_iter_s2`) and the sweep's cached cases the `t_bwd_*`
/// columns — v1 artifacts fail loudly instead of deserializing stale
/// forward-only decisions.
/// v3: wire precision became a first-class axis — configs may carry a
/// per-leg `wire` policy and every prediction prices compressed volumes,
/// so v2 artifacts (which could not express the axis) fail loudly rather
/// than replay decisions that ignore it.
pub const PLAN_SCHEMA_VERSION: u64 = 3;

/// Stable content hash of a sweep grid: FNV-1a over each configuration's
/// canonical JSON, in grid order — reordering or editing any config
/// changes it.
pub fn grid_hash(configs: &[MoeLayerConfig]) -> String {
    let mut h = Fnv64::new();
    h.write_str("grid");
    for c in configs {
        h.write_str(&c.to_json().to_string());
    }
    h.hex()
}

type LayoutKey = (usize, usize, usize);

fn layout_key(par: ParallelDegrees) -> LayoutKey {
    (par.p, par.n_mp, par.n_esp)
}

/// A compiled plan: fitted models for every layout of a grid plus the
/// per-config Algorithm-1 decisions. See the module doc for the schema.
#[derive(Debug, Clone)]
pub struct Plan {
    /// The topology the plan was fitted on (embedded whole, so a plan is
    /// self-describing even off the machine it was built on).
    pub cluster: ClusterTopology,
    /// [`ClusterTopology::content_hash`] at build time.
    pub cluster_hash: String,
    /// [`grid_hash`] of the grid the decisions cover.
    pub grid_hash: String,
    models: BTreeMap<LayoutKey, PerfModel>,
    decisions: Vec<(MoeLayerConfig, Prediction)>,
    /// Canonical config JSON → index into `decisions`.
    index: BTreeMap<String, usize>,
}

impl Plan {
    /// Fit every distinct layout of `configs` on `cluster` and predict
    /// each configuration — the expensive step `parm plan build` runs
    /// once so `--plan` consumers never have to.
    pub fn build(cluster: &ClusterTopology, configs: &[MoeLayerConfig]) -> Result<Plan> {
        let mut models: BTreeMap<LayoutKey, PerfModel> = BTreeMap::new();
        let mut decisions = Vec::with_capacity(configs.len());
        let mut index = BTreeMap::new();
        for c in configs {
            let key = layout_key(c.par);
            if !models.contains_key(&key) {
                models.insert(key, PerfModel::fit(cluster, c.par)?);
            }
            let pred = selection::predict(&models[&key], c);
            index.insert(c.to_json().to_string(), decisions.len());
            decisions.push((c.clone(), pred));
        }
        Ok(Plan {
            cluster: cluster.clone(),
            cluster_hash: cluster.content_hash(),
            grid_hash: grid_hash(configs),
            models,
            decisions,
            index,
        })
    }

    /// The fitted model for one parallel layout, if the plan covers it.
    pub fn model_for(&self, par: ParallelDegrees) -> Option<&PerfModel> {
        self.models.get(&layout_key(par))
    }

    /// All fitted models, in layout order.
    pub fn models(&self) -> impl Iterator<Item = &PerfModel> {
        self.models.values()
    }

    pub fn num_models(&self) -> usize {
        self.models.len()
    }

    /// The per-config decisions, in grid order.
    pub fn decisions(&self) -> &[(MoeLayerConfig, Prediction)] {
        &self.decisions
    }

    /// The stored decision for exactly this configuration (matched by
    /// canonical JSON), if it was on the plan's grid.
    pub fn prediction_for(&self, c: &MoeLayerConfig) -> Option<Prediction> {
        self.index.get(&c.to_json().to_string()).map(|&i| self.decisions[i].1)
    }

    /// Predict `c` from the plan without refitting: the stored decision
    /// when `c` was on the grid, else a fresh closed-form evaluation
    /// against the stored model for `c`'s layout. Errors when the plan
    /// has no model for that layout — the caller must rebuild, never
    /// silently refit.
    pub fn predict(&self, c: &MoeLayerConfig) -> Result<Prediction> {
        if let Some(p) = self.prediction_for(c) {
            return Ok(p);
        }
        let model = self.model_for(c.par).ok_or_else(|| {
            anyhow!(
                "plan has no fitted model for layout p={} n_mp={} n_esp={} — \
                 rebuild it with `parm plan build` over a grid that includes this layout",
                c.par.p,
                c.par.n_mp,
                c.par.n_esp
            )
        })?;
        Ok(selection::predict(model, c))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::num(PLAN_SCHEMA_VERSION as f64)),
            ("cluster_hash", Json::str(&self.cluster_hash)),
            ("grid_hash", Json::str(&self.grid_hash)),
            ("cluster", self.cluster.to_json()),
            ("models", Json::arr(self.models.values().map(|m| m.to_json()))),
            (
                "decisions",
                Json::arr(self.decisions.iter().map(|(c, p)| {
                    Json::obj(vec![("config", c.to_json()), ("prediction", p.to_json())])
                })),
            ),
        ])
    }

    /// Parse a plan document, rejecting unknown schema versions and
    /// internally inconsistent artifacts (embedded topology not matching
    /// its recorded hash — a hand-edited or corrupted file).
    pub fn from_json(j: &Json) -> Result<Plan> {
        let schema = j.req_usize("schema")?;
        if schema as u64 != PLAN_SCHEMA_VERSION {
            bail!(
                "plan schema v{schema} unsupported (this build reads v{PLAN_SCHEMA_VERSION}) \
                 — rebuild the artifact with `parm plan build`"
            );
        }
        let cluster = ClusterTopology::from_json(j.get("cluster"))?;
        let cluster_hash = j.req_str("cluster_hash")?.to_string();
        if cluster.content_hash() != cluster_hash {
            bail!(
                "plan artifact is corrupt: embedded topology `{}` hashes to {} but the \
                 document records {cluster_hash}",
                cluster.name,
                cluster.content_hash()
            );
        }
        let grid_hash = j.req_str("grid_hash")?.to_string();
        let mut models = BTreeMap::new();
        for m in j.req_arr("models")? {
            let model = PerfModel::from_json(m)?;
            models.insert(layout_key(model.par), model);
        }
        let mut decisions = Vec::new();
        let mut index = BTreeMap::new();
        for d in j.req_arr("decisions")? {
            let cfg = MoeLayerConfig::from_json(d.get("config"))?;
            let pred = Prediction::from_json(d.get("prediction"))?;
            index.insert(cfg.to_json().to_string(), decisions.len());
            decisions.push((cfg, pred));
        }
        Ok(Plan { cluster, cluster_hash, grid_hash, models, decisions, index })
    }

    /// Write the compact document (a plan can hold 10⁵+ decisions; the
    /// pretty form would triple the size for no reader).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing plan artifact {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Plan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan artifact {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("plan artifact {}: {e}", path.display()))?;
        Plan::from_json(&j).with_context(|| format!("loading plan artifact {}", path.display()))
    }

    /// Load and verify the plan was built for *this* topology — a hash
    /// mismatch is a hard error naming both hashes, never a silent stale
    /// read.
    pub fn load_checked(path: &Path, cluster: &ClusterTopology) -> Result<Plan> {
        let plan = Plan::load(path)?;
        let want = cluster.content_hash();
        if plan.cluster_hash != want {
            bail!(
                "plan artifact {} was built for topology `{}` (hash {}) but the current \
                 topology `{}` hashes to {want} — rebuild it with `parm plan build`",
                path.display(),
                plan.cluster.name,
                plan.cluster_hash,
                cluster.name
            );
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> Vec<MoeLayerConfig> {
        let base = MoeLayerConfig::test_default();
        [(2usize, 2usize), (2, 4), (4, 2)]
            .into_iter()
            .map(|(n_mp, b)| {
                let mut c = base.clone();
                c.par.n_mp = n_mp;
                c.b = b;
                c
            })
            .collect()
    }

    #[test]
    fn build_fits_each_layout_once_and_roundtrips() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let configs = grid();
        let plan = Plan::build(&cluster, &configs).unwrap();
        // Two distinct layouts (n_mp 2 and 4) across three configs.
        assert_eq!(plan.num_models(), 2);
        assert_eq!(plan.decisions().len(), 3);
        let doc = plan.to_json();
        let back = Plan::from_json(&doc).unwrap();
        assert_eq!(back.to_json().to_string(), doc.to_string());
        for c in &configs {
            let a = plan.prediction_for(c).unwrap();
            let b = back.prediction_for(c).unwrap();
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "{}", c.id());
        }
    }

    #[test]
    fn predict_off_grid_uses_stored_model_without_refit() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let configs = grid();
        let plan = Plan::build(&cluster, &configs).unwrap();
        // Same layout as the grid, different batch: not a stored decision,
        // but predictable from the stored model — and bit-identical to a
        // fresh fit because fitting is deterministic.
        let mut off = configs[0].clone();
        off.b = 16;
        assert!(plan.prediction_for(&off).is_none());
        let from_plan = plan.predict(&off).unwrap();
        let fresh = PerfModel::fit(&cluster, off.par).unwrap();
        let direct = selection::predict(&fresh, &off);
        assert_eq!(format!("{from_plan:?}"), format!("{direct:?}"));
        // Unknown layout: hard error, not a silent refit.
        let mut alien = configs[0].clone();
        alien.par.n_mp = 8;
        let err = plan.predict(&alien).unwrap_err().to_string();
        assert!(err.contains("no fitted model"), "{err}");
    }

    #[test]
    fn grid_hash_tracks_order_and_content() {
        let configs = grid();
        let mut reordered = configs.clone();
        reordered.swap(0, 1);
        let mut edited = configs.clone();
        edited[0].b *= 2;
        assert_eq!(grid_hash(&configs), grid_hash(&configs));
        assert_ne!(grid_hash(&configs), grid_hash(&reordered));
        assert_ne!(grid_hash(&configs), grid_hash(&edited));
    }

    #[test]
    fn schema_and_hash_mismatches_are_rejected() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let plan = Plan::build(&cluster, &grid()).unwrap();
        // Wrong schema version.
        let mut doc = plan.to_json();
        if let Json::Obj(o) = &mut doc {
            o.insert("schema".into(), Json::num(99.0));
        }
        let err = Plan::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("schema v99"), "{err}");
        // Corrupt artifact: embedded topology edited after hashing.
        let mut doc = plan.to_json();
        if let Json::Obj(o) = &mut doc {
            let tampered = ClusterTopology::testbed_b_subset(16).unwrap();
            o.insert("cluster".into(), tampered.to_json());
        }
        let err = Plan::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
    }

    #[test]
    fn load_checked_rejects_a_different_topology() {
        let built_on = ClusterTopology::testbed_b_subset(8).unwrap();
        let plan = Plan::build(&built_on, &grid()).unwrap();
        let dir = std::env::temp_dir().join(format!("parm_plan_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        plan.save(&path).unwrap();
        // Same topology: loads and reproduces the decisions.
        let loaded = Plan::load_checked(&path, &built_on).unwrap();
        assert_eq!(loaded.grid_hash, plan.grid_hash);
        // Different topology: clear error naming the rebuild command.
        let other = ClusterTopology::testbed_b_subset(16).unwrap();
        let err = Plan::load_checked(&path, &other).unwrap_err().to_string();
        assert!(err.contains("parm plan build"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
