//! Algorithm 1: find the best schedule from S1, S2, SP(r) and SP2(r)
//! (paper §V-B, generalized to the chunk-pipelined families — SP2 is the
//! SP × SAA composition whose per-chunk combine overlaps the
//! MP-AllGather).
//!
//! With the fitted α-β models, the closed forms are
//!
//! ```text
//! t_B  = AG_ESP(BLM·N_ESP·d) + AR_ESP(ar_total) + 2·A2A_EP(ETM·N_ESP·d)      (Eq. 1)
//! t_D1 = 2·A2A_fused(ETM·N_ESP/N_MP·d) + AG_MP(BLM·d)                        (Eq. 13)
//! t_D2 =   A2A_fused(ETM·N_ESP/N_MP·d) + SAA(ETM·N_ESP/N_MP·d)               (Eq. 14)
//! t_SP(r)  = pipeline(A2A_fused(·/r), FFN/r) + AG_MP(BLM·d)
//! t_SP2(r) = pipeline(A2A_fused(·/r) ∥ SAA(·/r), FFN/r)
//! ```
//!
//! where SAA(x) is the fitted model of the *overlapped* combine (the
//! paper's `Overlap(x) + AG_MP(ETM)` pair, measured as one collective so
//! its α_o/β_o are grounded in the same engine the schedules run on), and
//! `pipeline` is the O(r) recurrence of
//! [`crate::perfmodel::closedform::t_sp`] evaluated with fitted per-chunk
//! AlltoAll times. `t_SP` is compute-inclusive (the pipeline's value is
//! hiding communication behind the FFN), so the generalized comparison
//! adds the common PauseMP FFN term to `t_D1`/`t_D2`. The generalized
//! Algorithm 1 ([`Prediction::best`]) argmins **full-iteration**
//! estimates: each family's forward plus its true backward (adjoint
//! communication, doubled gradient FFN, and the exposed share of the
//! overlapped expert wgrad AllReduce). Volumes come from
//! [`crate::schedule::ops`], so predictions and the simulated/executed
//! schedules always agree on sizes.

use anyhow::Result;

use crate::config::{MoeLayerConfig, WireLeg};
use crate::schedule::ops::{self, wire_factor, ScheduleKind};
use crate::util::json::Json;

use super::fit::{CollKind, PerfModel};

/// Predicted times for each schedule: `t_baseline`, `t_d1`, `t_d2` are
/// forward communication only (the paper's Eqs. 1/13/14); `t_ffn` is the
/// PauseMP expert compute those share, at the bottleneck node; `t_sp` is
/// the compute-inclusive pipelined *forward* estimate at the chosen chunk
/// count, and `t_sp_iter` the per-iteration estimate (forward pipeline
/// plus the true backward term — adjoint comm, doubled gradient FFN,
/// exposed wgrad-AllReduce share) the generalized Algorithm 1 actually
/// compares. On a
/// heterogeneous topology each compute-inclusive term is the max over the
/// layer's nodes, and `bottleneck_node` names the node that set it — the
/// straggler whose per-node r* the fleet-level `sp_chunks` optimizes for.
#[derive(Debug, Clone, Copy)]
pub struct Prediction {
    pub t_baseline: f64,
    pub t_d1: f64,
    pub t_d2: f64,
    pub t_ffn: f64,
    /// Fitted ESP-AllReduce time of the expert weight gradients
    /// ([`ops::bytes_wgrad_per_rank`]) — the backward synchronization every
    /// family pays; overlapped, so only its exposed share (the excess over
    /// the backward tail it defers across) enters the iteration terms.
    pub t_wgrad_ar: f64,
    /// Full-iteration S1 estimate: `t_d1 + t_ffn` forward plus the true
    /// backward term (adjoint comm, doubled FFN, exposed wgrad AR).
    pub t_iter_s1: f64,
    /// Full-iteration S2 estimate (see [`Prediction::t_iter_s1`]).
    pub t_iter_s2: f64,
    pub t_sp: f64,
    pub t_sp_iter: f64,
    pub sp_chunks: usize,
    /// Compute-inclusive pipelined-S2 (SP × SAA) *forward* estimate at
    /// `sp2_chunks` — the chunked-SAA combine folds the MP-AllGather into
    /// the region, so there is no AG epilogue term.
    pub t_sp2: f64,
    /// Per-iteration SP2 estimate the generalized Algorithm 1 compares.
    pub t_sp2_iter: f64,
    /// The r* the fitted chunked-SAA pipeline model picked.
    pub sp2_chunks: usize,
    /// Node whose per-iteration estimate paces the fleet (0 on a
    /// homogeneous cluster).
    pub bottleneck_node: usize,
}

impl Prediction {
    /// Algorithm 1 lines 6-9 (paper form): the smaller of t_D1/t_D2.
    pub fn better(&self) -> ScheduleKind {
        if self.t_d1 <= self.t_d2 {
            ScheduleKind::S1
        } else {
            ScheduleKind::S2
        }
    }

    /// Generalized Algorithm 1: [`super::closedform::decide`] over
    /// **full-iteration** estimates — the true per-family backward terms
    /// (`t_iter_s1`/`t_iter_s2`, and the SP/SP2 iteration terms with
    /// their exposed wgrad-AllReduce shares) replace the former
    /// `2·t_D* + 3·t_FFN` doubling heuristic — the argmin over the
    /// four-member family {S1, S2, SP(r*), SP2(r*)}.
    pub fn best(&self) -> ScheduleKind {
        super::closedform::decide(
            self.t_iter_s1,
            self.t_iter_s2,
            self.sp_chunks,
            self.t_sp_iter,
            self.sp2_chunks,
            self.t_sp2_iter,
        )
        .0
    }

    /// The pick a **forward-only** objective would make: [`decide`] over
    /// `t_D* + t_FFN` and the compute-inclusive forward pipeline
    /// estimates. The acceptance tests pin a configuration where this
    /// disagrees with [`Prediction::best`] and the full-iteration pick
    /// wins in simulation — the reason `best` argmins the whole
    /// iteration.
    ///
    /// [`decide`]: super::closedform::decide
    pub fn best_forward_only(&self) -> ScheduleKind {
        super::closedform::decide(
            self.t_d1 + self.t_ffn,
            self.t_d2 + self.t_ffn,
            self.sp_chunks,
            self.t_sp,
            self.sp2_chunks,
            self.t_sp2,
        )
        .0
    }

    /// Serialize the prediction for a plan artifact. Every field is a raw
    /// f64/usize and Rust's float Display round-trips exactly, so
    /// [`Prediction::from_json`] reconstructs a bit-identical value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("t_baseline", Json::num(self.t_baseline)),
            ("t_d1", Json::num(self.t_d1)),
            ("t_d2", Json::num(self.t_d2)),
            ("t_ffn", Json::num(self.t_ffn)),
            ("t_wgrad_ar", Json::num(self.t_wgrad_ar)),
            ("t_iter_s1", Json::num(self.t_iter_s1)),
            ("t_iter_s2", Json::num(self.t_iter_s2)),
            ("t_sp", Json::num(self.t_sp)),
            ("t_sp_iter", Json::num(self.t_sp_iter)),
            ("sp_chunks", Json::num(self.sp_chunks as f64)),
            ("t_sp2", Json::num(self.t_sp2)),
            ("t_sp2_iter", Json::num(self.t_sp2_iter)),
            ("sp2_chunks", Json::num(self.sp2_chunks as f64)),
            ("bottleneck_node", Json::num(self.bottleneck_node as f64)),
        ])
    }

    /// Inverse of [`Prediction::to_json`].
    pub fn from_json(j: &Json) -> Result<Prediction> {
        Ok(Prediction {
            t_baseline: j.req_f64("t_baseline")?,
            t_d1: j.req_f64("t_d1")?,
            t_d2: j.req_f64("t_d2")?,
            t_ffn: j.req_f64("t_ffn")?,
            t_wgrad_ar: j.req_f64("t_wgrad_ar")?,
            t_iter_s1: j.req_f64("t_iter_s1")?,
            t_iter_s2: j.req_f64("t_iter_s2")?,
            t_sp: j.req_f64("t_sp")?,
            t_sp_iter: j.req_f64("t_sp_iter")?,
            sp_chunks: j.req_usize("sp_chunks")?,
            t_sp2: j.req_f64("t_sp2")?,
            t_sp2_iter: j.req_f64("t_sp2_iter")?,
            sp2_chunks: j.req_usize("sp2_chunks")?,
            bottleneck_node: j.req_usize("bottleneck_node")?,
        })
    }
}

/// Fitted SP pipeline region (no AG epilogue): the closed-form recurrence
/// with each chunk's fused AlltoAll costed by the fitted `A2aFused` model
/// (argument = that chunk's per-member send volume) and the chunk FFNs
/// scaled by `ffn_scale` (1.0 forward, 2.0 backward) at `gpu_flops` —
/// the caller picks whose node's throughput to evaluate.
fn sp_pipeline_fitted(
    model: &PerfModel,
    c: &MoeLayerConfig,
    chunks: usize,
    ffn_scale: f64,
    gpu_flops: f64,
    loads: Option<&[usize]>,
) -> f64 {
    let spans = policy_spans(c, chunks, loads);
    // Each direction is priced at its own wire leg's compressed volume.
    let leg = |span: (usize, usize), leg: WireLeg| {
        model.predict(
            CollKind::A2aFused,
            ops::bytes_sp_chunk_per_pair(c, span.1) * c.par.p as f64 * wire_factor(c, leg),
        )
    };
    let dispatch = |span: (usize, usize)| leg(span, WireLeg::Dispatch);
    let combine = |span: (usize, usize)| leg(span, WireLeg::Combine);
    let ffn = |span: (usize, usize)| ffn_scale * policy_flops(c, span, loads) / gpu_flops;
    super::closedform::pipeline_makespan_asym(&spans, dispatch, combine, ffn)
}

/// The span policy the fitted pipeline estimates share with the builders:
/// measured loads when the re-decide entry supplied them, the expected
/// profile otherwise — so a warm re-run of Algorithm 1 evaluates exactly
/// the spans the online controller would lower next step.
fn policy_spans(c: &MoeLayerConfig, chunks: usize, loads: Option<&[usize]>) -> Vec<(usize, usize)> {
    let cap = c.t_pausemp();
    let clamped = ops::sp_clamp_chunks(c, chunks);
    match loads {
        Some(l) => ops::sp_spans_measured(cap, clamped, l),
        None => ops::sp_spans(c, cap, clamped),
    }
}

/// The matching per-chunk FFN pricing (see [`policy_spans`]).
fn policy_flops(c: &MoeLayerConfig, span: (usize, usize), loads: Option<&[usize]>) -> f64 {
    let cap = c.t_pausemp();
    match loads {
        Some(l) => ops::sp_chunk_flops_measured(c, cap, span, l),
        None => ops::sp_chunk_flops_span(c, cap, span),
    }
}

/// Fitted SP2 pipeline region: the asymmetric recurrence with each chunk's
/// dispatch costed by the fitted `A2aFused` model and its combine leg by
/// the fitted `SaaS2` model (the overlapped AlltoAll + MP-AllGather,
/// measured as one collective at that chunk's per-member send volume) —
/// so the fitted SP2 estimate inherits exactly the overlap behaviour the
/// engine showed at fit time. No AG epilogue: the chunked SAAs carry it.
fn sp2_pipeline_fitted(
    model: &PerfModel,
    c: &MoeLayerConfig,
    chunks: usize,
    ffn_scale: f64,
    gpu_flops: f64,
    loads: Option<&[usize]>,
) -> f64 {
    let spans = policy_spans(c, chunks, loads);
    let dispatch = |span: (usize, usize)| {
        model.predict(
            CollKind::A2aFused,
            ops::bytes_sp_chunk_per_pair(c, span.1)
                * c.par.p as f64
                * wire_factor(c, WireLeg::Dispatch),
        )
    };
    // The chunked SAA rides the combine leg — AlltoAll and AllGather
    // forwards alike (the interpreter sets the leg once per SAA op).
    let combine = |span: (usize, usize)| {
        model.predict(
            CollKind::SaaS2,
            ops::bytes_sp_chunk_per_pair(c, span.1)
                * c.par.p as f64
                * wire_factor(c, WireLeg::Combine),
        )
    };
    let ffn = |span: (usize, usize)| ffn_scale * policy_flops(c, span, loads) / gpu_flops;
    super::closedform::pipeline_makespan_asym(&spans, &dispatch, &combine, ffn)
}

/// Evaluate the closed forms for one configuration.
pub fn predict(model: &PerfModel, c: &MoeLayerConfig) -> Prediction {
    predict_with_loads(model, c, None)
}

/// The online controller's warm re-decide entry point: Algorithm 1 with
/// the pipelined families' spans and every FFN term priced at a measured
/// per-expert load vector instead of the expected `--skew` profile. The
/// fitted collective models are reused as-is (warm fits — no re-fit per
/// step), so a re-decision costs only closed-form evaluation. `None` or an
/// all-zero vector (a step that routed no tokens) falls back to the
/// expected profile, making `predict_with_loads(m, c, None)` bit-identical
/// to [`predict`].
pub fn predict_with_loads(
    model: &PerfModel,
    c: &MoeLayerConfig,
    loads: Option<&[usize]>,
) -> Prediction {
    debug_assert_eq!(model.par, c.par, "model fitted for different degrees");
    let loads = loads.filter(|l| l.iter().sum::<usize>() > 0);
    // Per-member volumes (bytes), shared with the schedule builders.
    let x_ag_esp = ops::bytes_esp_ag_per_rank(c) * c.par.n_esp as f64; // gathered output
    let x_ar_esp = ops::bytes_esp_ar_total(c);
    let x_a2a_ep = ops::bytes_ep_a2a_per_pair(c) * c.par.n_ep() as f64; // per-member send
    let x_fused = ops::bytes_fused_a2a_per_pair(c) * c.par.p as f64;
    let x_ag_mp_s1 = ops::bytes_mp_ag_s1_per_rank(c) * c.par.n_mp as f64; // gathered = BLM·d

    // Per-leg wire factors: each collective's volume argument is scaled
    // to its leg's compressed width, so the fitted α-β curves are read at
    // the bytes the engine would actually move (all 1.0 at f32 wire).
    let w_d = wire_factor(c, WireLeg::Dispatch);
    let w_c = wire_factor(c, WireLeg::Combine);
    let w_g = wire_factor(c, WireLeg::AllGather);

    let t_baseline = model.predict(CollKind::AgEsp, x_ag_esp * w_g)
        + model.predict(CollKind::ArEsp, x_ar_esp * w_g)
        + model.predict(CollKind::A2aEp, x_a2a_ep * w_d)
        + model.predict(CollKind::A2aEp, x_a2a_ep * w_c);
    let fused_d = model.predict(CollKind::A2aFused, x_fused * w_d);
    let fused_c = model.predict(CollKind::A2aFused, x_fused * w_c);
    let t_d1 = fused_d + fused_c + model.predict(CollKind::AgMp, x_ag_mp_s1 * w_g);
    // The SAA's AlltoAll + AllGather forwards all ride the combine leg.
    let t_d2 = fused_d + model.predict(CollKind::SaaS2, x_fused * w_c);
    // Bottleneck-node FFN: `model.gpu_flops` is the min over used nodes.
    let ffn_scale = match loads {
        Some(l) => ops::ffn_load_scale_measured(c, c.t_pausemp(), l),
        None => ops::ffn_load_scale(c, c.t_pausemp()),
    };
    let t_ffn =
        ops::expert_flops(c, ops::expert_tokens_per_rank(c, true)) * ffn_scale / model.gpu_flops;

    let ag = model.predict(CollKind::AgMp, x_ag_mp_s1 * w_g);
    let x_ag_mp_s2 = ops::bytes_mp_ag_s2_per_rank(c) * c.par.n_mp as f64;
    let ag2 = model.predict(CollKind::AgMp, x_ag_mp_s2 * w_g);
    // Fitted backward terms: the wgrad AllReduce is an ESP-group ring
    // AllReduce of the expert weight-gradient shard, priced by the same
    // fitted model as the baseline's activation AllReduce — at the wgrad
    // leg's compressed volume. Its exposed share is what survives the
    // deferred-completion overlap.
    let t_wgrad_ar = model.predict(
        CollKind::ArEsp,
        ops::bytes_wgrad_per_rank(c) * wire_factor(c, WireLeg::Wgrad),
    );
    let exposed = super::closedform::exposed_wgrad_ar;
    // True t_bwd per unchunked family (see closedform::t_bwd_d1_on):
    // adjoint comm (RS + 2 transposed fused AlltoAlls + adjoint-of-split
    // AG), doubled gradient FFN, exposed wgrad AR — the hiding tail is
    // the combine-leg transposed AlltoAll plus the final AllGather.
    let t_bwd_s1 = fused_d + fused_c + 2.0 * ag + 2.0 * t_ffn + exposed(t_wgrad_ar, fused_c + ag);
    let t_bwd_s2 =
        fused_d + fused_c + 2.0 * ag2 + 2.0 * t_ffn + exposed(t_wgrad_ar, fused_c + ag2);
    let t_iter_s1 = t_d1 + t_ffn + t_bwd_s1;
    let t_iter_s2 = t_d2 + t_ffn + t_bwd_s2;
    // The AlltoAll chunks are global collectives (one fitted model) and
    // the pipeline recurrence is monotone in the FFN durations, so the
    // fleet pays exactly the slowest-GPU node's estimate — evaluate that
    // node once instead of scanning the fleet per chunk count.
    let mut bottleneck = model.node_flops()[0];
    for &(node, flops) in model.node_flops() {
        if flops < bottleneck.1 {
            bottleneck = (node, flops);
        }
    }
    // SP iteration: forward pipeline + AG epilogue, backward RS prologue
    // + transposed region at 2× compute + adjoint-of-split AG, and the
    // exposed wgrad-AR share (deferred across the final AG).
    let sp_iter_at = |r: usize| {
        sp_pipeline_fitted(model, c, r, 1.0, bottleneck.1, loads)
            + sp_pipeline_fitted(model, c, r, 2.0, bottleneck.1, loads)
            + 3.0 * ag
            + exposed(t_wgrad_ar, ag)
    };
    let (sp_chunks, t_sp_iter) = super::closedform::argmin_chunks(c, sp_iter_at);
    let t_sp = sp_pipeline_fitted(model, c, sp_chunks, 1.0, bottleneck.1, loads) + ag;

    // SP2: same bottleneck-node argument — the chunked SAAs are global
    // collectives, so the slowest-GPU node's estimate is the fleet max.
    // Backward is structurally an SP region (plain transposed AlltoAlls,
    // no SAA) bracketed by the capacity-volume MP-ReduceScatter/AllGather.
    let sp2_iter_at = |r: usize| {
        sp2_pipeline_fitted(model, c, r, 1.0, bottleneck.1, loads)
            + sp_pipeline_fitted(model, c, r, 2.0, bottleneck.1, loads)
            + 2.0 * ag2
            + exposed(t_wgrad_ar, ag2)
    };
    let (sp2_chunks, t_sp2_iter) = super::closedform::argmin_chunks(c, sp2_iter_at);
    let t_sp2 = sp2_pipeline_fitted(model, c, sp2_chunks, 1.0, bottleneck.1, loads);

    Prediction {
        t_baseline,
        t_d1,
        t_d2,
        t_ffn,
        t_wgrad_ar,
        t_iter_s1,
        t_iter_s2,
        t_sp,
        t_sp_iter,
        sp_chunks,
        t_sp2,
        t_sp2_iter,
        sp2_chunks,
        bottleneck_node: bottleneck.0,
    }
}

/// Algorithm 1 entry point (paper form): choose S1 or S2 for `c`.
pub fn choose_schedule(model: &PerfModel, c: &MoeLayerConfig) -> ScheduleKind {
    predict(model, c).better()
}

/// Generalized Algorithm 1: choose among S1, S2, SP(r*) and SP2(r*) for
/// `c`.
pub fn choose_schedule_extended(model: &PerfModel, c: &MoeLayerConfig) -> ScheduleKind {
    predict(model, c).best()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::moe::ParallelDegrees;
    use crate::config::ClusterTopology;

    fn cfg(p: usize, n_mp: usize, n_esp: usize, l: usize, f: f64) -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p, n_mp, n_esp },
            b: 4,
            l,
            e: p / n_esp,
            m: 1024,
            h: 2048,
            k: 2,
            f,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        }
    }

    #[test]
    fn heterogeneous_prediction_reports_the_straggler_node() {
        use crate::config::cluster::NodeSpec;
        let homo = ClusterTopology::testbed_b_subset(8).unwrap();
        let fast = homo.node_specs()[0];
        let slow = NodeSpec { gpu_flops: fast.gpu_flops / 4.0, ..fast };
        let het = ClusterTopology::new("het8", vec![fast, slow]).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let m_homo = PerfModel::fit(&homo, par).unwrap();
        let m_het = PerfModel::fit(&het, par).unwrap();
        // Compute-heavy shape so the FFN term is load-bearing.
        let mut c = cfg(8, 2, 2, 2048, 1.2);
        c.b = 8;
        c.h = 32768;
        let p_homo = predict(&m_homo, &c);
        let p_het = predict(&m_het, &c);
        assert_eq!(p_homo.bottleneck_node, 0, "{p_homo:?}");
        assert_eq!(p_het.bottleneck_node, 1, "{p_het:?}");
        assert!(p_het.t_ffn > p_homo.t_ffn, "straggler FFN must be slower");
        assert!(p_het.t_sp_iter > p_homo.t_sp_iter);
    }

    #[test]
    fn dedicated_schedules_predicted_faster_than_baseline() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let model = PerfModel::fit(&cluster, par).unwrap();
        let c = cfg(8, 2, 2, 1024, 1.2);
        let pred = predict(&model, &c);
        assert!(pred.t_d1 < pred.t_baseline, "{pred:?}");
        assert!(pred.t_d2 < pred.t_baseline, "{pred:?}");
    }

    #[test]
    fn capacity_extremes_flip_the_choice() {
        // §IV-B: T → 0 favors S2 (t_D2 → 0 while t_D1 keeps AG_MP(BLM));
        // T → ∞ favors S1 (AG_MP(BLM) is constant in T).
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 4, n_esp: 2 };
        let model = PerfModel::fit(&cluster, par).unwrap();

        // Tiny capacity: f small ⇒ T ≈ 0.
        let tiny = cfg(8, 4, 2, 2048, 0.01);
        let p_tiny = predict(&model, &tiny);
        // Huge capacity: f large ⇒ T ≫ BL.
        let huge = cfg(8, 4, 2, 2048, 64.0);
        let p_huge = predict(&model, &huge);

        assert_eq!(p_tiny.better(), ScheduleKind::S2, "{p_tiny:?}");
        assert_eq!(p_huge.better(), ScheduleKind::S1, "{p_huge:?}");
    }

    #[test]
    fn extended_prediction_is_well_formed() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let model = PerfModel::fit(&cluster, par).unwrap();
        let c = cfg(8, 2, 2, 1024, 1.2);
        let pred = predict(&model, &c);
        assert!(pred.t_ffn > 0.0 && pred.t_sp > 0.0 && pred.t_sp_iter > pred.t_sp, "{pred:?}");
        assert!(pred.sp_chunks >= 1 && pred.sp_chunks <= crate::comm::tags::SP_MAX_CHUNKS);
        // SP2 terms are well-formed too: positive, iteration > forward,
        // chunk count representable.
        assert!(pred.t_sp2 > 0.0 && pred.t_sp2_iter > pred.t_sp2, "{pred:?}");
        assert!(pred.sp2_chunks >= 1 && pred.sp2_chunks <= crate::comm::tags::SP_MAX_CHUNKS);
        // Backward terms are well-formed: a positive wgrad AR (N_ESP > 1)
        // and full-iteration estimates above their forward halves.
        assert!(pred.t_wgrad_ar > 0.0, "{pred:?}");
        assert!(pred.t_iter_s1 > pred.t_d1 + pred.t_ffn, "{pred:?}");
        assert!(pred.t_iter_s2 > pred.t_d2 + pred.t_ffn, "{pred:?}");
        // The SP iteration argmin never exceeds its r = 1 degeneration,
        // which is exactly S1's full-iteration structure.
        assert!(pred.t_sp_iter <= pred.t_iter_s1 + 1e-12, "{pred:?}");
        // best() only ever improves on better() at iteration scale.
        let base = match pred.better() {
            ScheduleKind::S1 => pred.t_iter_s1,
            _ => pred.t_iter_s2,
        };
        let best_t = match pred.best() {
            ScheduleKind::Pipelined { .. } => pred.t_sp_iter,
            ScheduleKind::PipelinedS2 { .. } => pred.t_sp2_iter,
            ScheduleKind::S1 => pred.t_iter_s1,
            _ => pred.t_iter_s2,
        };
        assert!(best_t <= base + 1e-12, "{pred:?}");
    }

    #[test]
    fn full_iteration_pick_beats_forward_only_pick_where_they_differ() {
        // The acceptance case for the full-iteration argmin: the S2
        // family's backward pays the capacity-volume MP collectives
        // (AG_S2 ≈ f·k × AG_S1) twice with no SAA to hide them, so at
        // moderate capacity factors the forward-only objective still
        // picks an S2-family schedule (S2 or SP2) while the whole
        // iteration favors the S1 family (S1 or SP) — and the simulator
        // agrees the full-iteration pick is the faster schedule. The
        // closed-form mirror flips at every point of this bracket
        // (SP2(2) → SP(2), 2.5–4.5% iteration margin); sweep it and
        // require a flip with a strict simulated win.
        use crate::schedule::lowering::simulate_iteration;
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let model = PerfModel::fit(&cluster, par).unwrap();
        let mut found: Option<(String, f64, f64)> = None;
        'outer: for l in [512usize, 1024, 2048] {
            for f in [1.0f64, 1.2, 1.6] {
                let c = cfg(8, 2, 2, l, f);
                let pred = predict(&model, &c);
                let fwd_pick = pred.best_forward_only();
                let full_pick = pred.best();
                if fwd_pick == full_pick {
                    continue;
                }
                let t_full = simulate_iteration(full_pick, &c, &cluster).unwrap().makespan;
                let t_fwd = simulate_iteration(fwd_pick, &c, &cluster).unwrap().makespan;
                if t_full < t_fwd {
                    found = Some((c.id(), t_full, t_fwd));
                    break 'outer;
                }
            }
        }
        let (id, t_full, t_fwd) = found.expect(
            "no pinned config where the forward-only and full-iteration picks \
             differ with the full-iteration pick winning in simulation",
        );
        eprintln!("full-iteration pick wins at {id}: {t_full:.6}s vs {t_fwd:.6}s");
        assert!(t_full < t_fwd);
    }

    #[test]
    fn prediction_json_roundtrip_is_bit_exact() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let model = PerfModel::fit(&cluster, par).unwrap();
        let pred = predict(&model, &cfg(8, 2, 2, 1024, 1.2));
        let back = Prediction::from_json(&pred.to_json()).unwrap();
        // Copy struct of plain floats/usizes: field-by-field bit equality.
        assert_eq!(format!("{back:?}"), format!("{pred:?}"));
        assert_eq!(back.best(), pred.best());
        assert_eq!(back.to_json().to_string(), pred.to_json().to_string());
    }

    #[test]
    fn extended_choice_picks_sp_on_compute_heavy_config() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let model = PerfModel::fit(&cluster, par).unwrap();
        let mut c = cfg(8, 2, 2, 2048, 1.2);
        c.b = 8;
        c.h = 32768;
        let pick = choose_schedule_extended(&model, &c);
        assert!(
            matches!(pick, ScheduleKind::Pipelined { chunks } if chunks > 1)
                || matches!(pick, ScheduleKind::PipelinedS2 { chunks } if chunks > 1),
            "expected a pipelined family on compute-heavy config, got {pick:?}"
        );
    }

    #[test]
    fn warm_redecide_matches_predict_without_loads_and_reacts_to_skewed_loads() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let model = PerfModel::fit(&cluster, par).unwrap();
        let mut c = cfg(8, 2, 2, 2048, 1.2);
        c.b = 8;
        c.h = 32768;
        let base = predict(&model, &c);
        // None and all-zero loads both fall back to the expected profile
        // bit-for-bit.
        let none = predict_with_loads(&model, &c, None);
        assert_eq!(format!("{none:?}"), format!("{base:?}"));
        let zeros = vec![0usize; c.e];
        let z = predict_with_loads(&model, &c, Some(&zeros));
        assert_eq!(format!("{z:?}"), format!("{base:?}"));
        // A head-heavy measurement (one saturated expert, the rest cold)
        // concentrates compute below the dense profile, so the measured
        // FFN term drops and the pipelined iteration estimate moves.
        let cap = c.t_pausemp();
        let mut hot = vec![cap / 8; c.e];
        hot[0] = cap;
        let skewed = predict_with_loads(&model, &c, Some(&hot));
        let want = ops::ffn_load_scale_measured(&c, cap, &hot)
            * ops::expert_flops(&c, ops::expert_tokens_per_rank(&c, true))
            / model.gpu_flops;
        assert!((skewed.t_ffn - want).abs() < 1e-12, "{skewed:?}");
        assert!(skewed.t_ffn < base.t_ffn, "{skewed:?} vs {base:?}");
        assert!(skewed.t_sp_iter != base.t_sp_iter, "{skewed:?} vs {base:?}");
    }

    #[test]
    fn choice_agrees_with_simulation_on_forward_comm() {
        // The selector should usually pick the schedule the simulator also
        // finds faster (selection accuracy; the bench quantifies this over
        // the whole grid).
        use crate::schedule::lowering::simulate_iteration;
        let cluster = ClusterTopology::testbed_b_subset(16).unwrap();
        let par = ParallelDegrees { p: 16, n_mp: 2, n_esp: 4 };
        let model = PerfModel::fit(&cluster, par).unwrap();
        let mut agree = 0;
        let mut total = 0;
        for l in [512usize, 2048] {
            for f in [1.2, 2.4] {
                let c = cfg(16, 2, 4, l, f);
                let choice = choose_schedule(&model, &c);
                let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
                let t2 = simulate_iteration(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
                let sim_best = if t1 <= t2 { ScheduleKind::S1 } else { ScheduleKind::S2 };
                total += 1;
                if choice == sim_best || (t1 - t2).abs() / t1.max(t2) < 0.03 {
                    agree += 1;
                }
            }
        }
        assert!(agree >= total - 1, "selector agreed on {agree}/{total}");
    }
}
