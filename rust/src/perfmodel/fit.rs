//! Measuring collectives and fitting α-β models (paper §V-A, Fig 6).
//!
//! A measurement runs the *same lowering the schedules use*, over all
//! groups of the kind concurrently (as they execute in a real layer), and
//! records the makespan. The model argument `x` is the **per-member send
//! volume in bytes** for AlltoAll-likes, the **gathered output volume**
//! for AllGathers, and the **per-member buffer volume** for AllReduce —
//! one convention, used identically at fit time and at prediction time,
//! so Algorithm 1's inputs are self-consistent.
//!
//! Besides the per-collective fits, a [`PerfModel`] carries one α-β pair
//! **per [`LinkClass`]** of the topology (fitted from single-transfer
//! measurements over a representative rank pair of each class) and the
//! per-node GPU throughputs of the layout — replacing the two global
//! scalar pairs and the single `gpu_flops` the flat profile used to
//! supply, so a fitted model is as topology-aware as the simulator it
//! was measured on.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::cluster::{GroupKind, ProcessGroups};
use crate::comm::{lower, saa};
use crate::config::moe::ParallelDegrees;
use crate::config::{ClusterTopology, LinkClass};
use crate::sim::dag::SimDag;
use crate::sim::engine::Simulator;
use crate::util::json::Json;
use crate::util::stats::{least_squares, LinearFit};

/// The collectives Algorithm 1 needs models for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CollKind {
    /// MP-group AllGather (x = gathered output bytes) — α/β of Eq. (12).
    AgMp,
    /// ESP-group AllGather (x = gathered output bytes).
    AgEsp,
    /// ESP-group AllReduce (x = per-member buffer bytes). Prices both
    /// the baseline's activation AllReduce and — since the whole-iteration
    /// argmin — every family's expert wgrad-gradient AllReduce
    /// ([`crate::schedule::ops::bytes_wgrad_per_rank`] feeds it in
    /// [`super::selection`]); only the *exposed* share of the latter ends
    /// up in a backward term, mirroring the deferred-completion overlap
    /// the interpreter schedules.
    ArEsp,
    /// EP-group AlltoAll (x = per-member send bytes).
    A2aEp,
    /// Fused EP&ESP AlltoAll over the product group (x = per-member send
    /// bytes).
    A2aFused,
    /// S2's overlapped combine: fused AlltoAll + MP-AllGather via SAA
    /// (x = per-member AlltoAll send bytes; the AllGather volume is
    /// implied by the MP layout). Covers Eq. (14)'s Overlap + AG_MP terms.
    SaaS2,
}

impl CollKind {
    pub const ALL: [CollKind; 6] = [
        CollKind::AgMp,
        CollKind::AgEsp,
        CollKind::ArEsp,
        CollKind::A2aEp,
        CollKind::A2aFused,
        CollKind::SaaS2,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            CollKind::AgMp => "ag_mp",
            CollKind::AgEsp => "ag_esp",
            CollKind::ArEsp => "ar_esp",
            CollKind::A2aEp => "a2a_ep",
            CollKind::A2aFused => "a2a_fused",
            CollKind::SaaS2 => "saa_s2",
        }
    }

    /// Inverse of [`CollKind::name`] — used when loading fitted models out
    /// of plan artifacts and the persisted fit cache.
    pub fn parse(name: &str) -> Option<CollKind> {
        CollKind::ALL.into_iter().find(|k| k.name() == name)
    }
}

/// Build the measurement DAG for one collective kind at argument `x`
/// (bytes, per the convention above) and return its simulated makespan.
pub fn measure_collective(
    cluster: &ClusterTopology,
    par: ParallelDegrees,
    kind: CollKind,
    x: f64,
) -> Result<f64> {
    let groups = ProcessGroups::new(par)?;
    let mut dag = SimDag::new();
    match kind {
        CollKind::AgMp => {
            let per_rank = x / par.n_mp as f64;
            for grp in groups.all_groups(GroupKind::Mp) {
                lower::ring_allgather(&mut dag, cluster, &grp, per_rank, &[], "m");
            }
        }
        CollKind::AgEsp => {
            let per_rank = x / par.n_esp as f64;
            for grp in groups.all_groups(GroupKind::Esp) {
                lower::ring_allgather(&mut dag, cluster, &grp, per_rank, &[], "m");
            }
        }
        CollKind::ArEsp => {
            for grp in groups.all_groups(GroupKind::Esp) {
                lower::ring_allreduce(&mut dag, cluster, &grp, x, &[], "m");
            }
        }
        CollKind::A2aEp => {
            let per_pair = x / par.n_ep() as f64;
            for grp in groups.all_groups(GroupKind::Ep) {
                lower::pairwise_alltoall(&mut dag, cluster, &grp, per_pair, &[], "m");
            }
        }
        CollKind::A2aFused => {
            let per_pair = x / par.p as f64;
            let world = groups.world();
            lower::pairwise_alltoall(&mut dag, cluster, &world, per_pair, &[], "m");
        }
        CollKind::SaaS2 => {
            let per_pair = x / par.p as f64;
            let world = groups.world();
            let mp_groups = groups.all_groups(GroupKind::Mp);
            saa::saa_lower(&mut dag, cluster, &world, &mp_groups, per_pair, &[], "m", "g")?;
        }
    }
    Ok(Simulator::new(cluster).run(&dag).makespan)
}

/// Fitted α-β models for one (cluster, parallel-degrees) pair.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub cluster_name: String,
    pub par: ParallelDegrees,
    /// Bottleneck (slowest) per-GPU throughput over the ranks this layout
    /// uses (FLOP/s) — what a synchronous step effectively computes at,
    /// carried from the topology so compute-inclusive predictions (SP's
    /// pipeline, the `+ t_FFN` terms of the generalized Algorithm 1) need
    /// no second argument.
    pub gpu_flops: f64,
    /// Per-node `(node id, per-GPU FLOP/s)` over the nodes hosting ranks
    /// `0..par.p` — the per-node axis the selection layer scans to find
    /// the bottleneck node and its r*.
    node_flops: Vec<(usize, f64)>,
    fits: BTreeMap<CollKind, LinearFit>,
    /// One α-β pair per realizable [`LinkClass`] of the topology, fitted
    /// from single-transfer measurements over a representative pair.
    link_fits: BTreeMap<LinkClass, LinearFit>,
}

/// Message sizes used for fitting (bytes): 64 KiB … 64 MiB, ×4 steps —
/// the Fig 6 sweep range.
pub const FIT_SIZES: [f64; 6] = [65536.0, 262144.0, 1048576.0, 4194304.0, 16777216.0, 67108864.0];

impl PerfModel {
    /// Fit all collective models for `par` on `cluster` (paper §V-A:
    /// "measure the elapsed time over various message sizes … least
    /// square fitting").
    pub fn fit(cluster: &ClusterTopology, par: ParallelDegrees) -> Result<PerfModel> {
        let mut fits = BTreeMap::new();
        for kind in CollKind::ALL {
            let mut points = Vec::with_capacity(FIT_SIZES.len());
            for &x in &FIT_SIZES {
                points.push((x, measure_collective(cluster, par, kind, x)?));
            }
            let fit = least_squares(&points)
                .ok_or_else(|| anyhow!("degenerate fit for {}", kind.name()))?;
            fits.insert(kind, fit);
        }
        let node_flops: Vec<(usize, f64)> = cluster
            .nodes_for(par.p)
            .map(|n| (n, cluster.node(n).gpu_flops))
            .collect();
        Ok(PerfModel {
            cluster_name: cluster.name.clone(),
            par,
            gpu_flops: cluster.min_flops(par.p),
            node_flops,
            fits,
            link_fits: fit_link_classes(cluster)?,
        })
    }

    pub fn get(&self, kind: CollKind) -> &LinearFit {
        &self.fits[&kind]
    }

    /// Predicted time of collective `kind` at argument `x` bytes.
    pub fn predict(&self, kind: CollKind, x: f64) -> f64 {
        self.get(kind).predict(x)
    }

    /// Per-node `(node id, per-GPU FLOP/s)` over the fitted layout's
    /// ranks.
    pub fn node_flops(&self) -> &[(usize, f64)] {
        &self.node_flops
    }

    /// The fitted α-β of one link class (`None` when the class is not
    /// realizable on the fitted topology).
    pub fn link_fit(&self, class: LinkClass) -> Option<&LinearFit> {
        self.link_fits.get(&class)
    }

    /// All per-link-class fits, keyed by [`LinkClass`].
    pub fn link_fits(&self) -> &BTreeMap<LinkClass, LinearFit> {
        &self.link_fits
    }

    pub fn to_json(&self) -> Json {
        let fit_obj = |f: &LinearFit| {
            Json::obj(vec![
                ("alpha", Json::num(f.intercept)),
                ("beta", Json::num(f.slope)),
                ("r2", Json::num(f.r2)),
            ])
        };
        Json::obj(vec![
            ("cluster", Json::str(&self.cluster_name)),
            ("p", Json::num(self.par.p as f64)),
            ("n_mp", Json::num(self.par.n_mp as f64)),
            ("n_esp", Json::num(self.par.n_esp as f64)),
            ("gpu_flops", Json::num(self.gpu_flops)),
            (
                "node_flops",
                Json::arr(
                    self.node_flops
                        .iter()
                        .map(|&(n, f)| Json::arr([Json::num(n as f64), Json::num(f)])),
                ),
            ),
            (
                "fits",
                Json::Obj(
                    self.fits
                        .iter()
                        .map(|(k, f)| (k.name().to_string(), fit_obj(f)))
                        .collect(),
                ),
            ),
            (
                "link_fits",
                Json::Obj(
                    self.link_fits
                        .iter()
                        .map(|(class, f)| (class.id(), fit_obj(f)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Reconstruct a fitted model from its [`PerfModel::to_json`] document
    /// — the load path plan artifacts and the persisted fit cache go
    /// through, so `--plan` / warm-cache runs never refit. Rejects
    /// documents missing any of [`CollKind::ALL`]'s fits.
    pub fn from_json(j: &Json) -> Result<PerfModel> {
        let fit_from = |f: &Json, what: &str| -> Result<LinearFit> {
            let field = |key: &str| f.req_f64(key).map_err(|e| anyhow!("fit `{what}`: {e}"));
            Ok(LinearFit { intercept: field("alpha")?, slope: field("beta")?, r2: field("r2")? })
        };
        let mut fits = BTreeMap::new();
        for kind in CollKind::ALL {
            fits.insert(kind, fit_from(j.get("fits").get(kind.name()), kind.name())?);
        }
        let mut link_fits = BTreeMap::new();
        let link_obj = j
            .get("link_fits")
            .as_obj()
            .ok_or_else(|| anyhow!("model document lacks a `link_fits` object"))?;
        for (id, f) in link_obj {
            let class = LinkClass::parse(id)
                .ok_or_else(|| anyhow!("unrecognized link-class id `{id}` in model document"))?;
            link_fits.insert(class, fit_from(f, id)?);
        }
        let mut node_flops = Vec::new();
        for entry in j.req_arr("node_flops")? {
            let pair = entry.at(0).as_usize().zip(entry.at(1).as_f64());
            let (node, flops) =
                pair.ok_or_else(|| anyhow!("node_flops entries must be [node, flops] pairs"))?;
            node_flops.push((node, flops));
        }
        if node_flops.is_empty() {
            return Err(anyhow!("model document lists no node_flops"));
        }
        let par = ParallelDegrees {
            p: j.req_usize("p")?,
            n_mp: j.req_usize("n_mp")?,
            n_esp: j.req_usize("n_esp")?,
        };
        par.validate()?;
        Ok(PerfModel {
            cluster_name: j.req_str("cluster")?.to_string(),
            par,
            gpu_flops: j.req_f64("gpu_flops")?,
            node_flops,
            fits,
            link_fits,
        })
    }
}

/// Fit one α-β pair per realizable [`LinkClass`]: measure a single
/// point-to-point transfer over a representative rank pair of each class
/// at the Fig 6 sizes and least-square it. On the simulator these recover
/// the topology's own link constants (r² = 1) — the self-consistency the
/// tests pin; on a real harness the same procedure would regress measured
/// wire times.
fn fit_link_classes(cluster: &ClusterTopology) -> Result<BTreeMap<LinkClass, LinearFit>> {
    let mut out = BTreeMap::new();
    for class in cluster.link_classes() {
        let (src, dst) = cluster
            .representative_pair(class)
            .expect("link_classes only lists realizable classes");
        let mut points = Vec::with_capacity(FIT_SIZES.len());
        for &x in &FIT_SIZES {
            let mut dag = SimDag::new();
            dag.transfer(src, dst, x, &[], "fit.link");
            points.push((x, Simulator::new(cluster).run(&dag).makespan));
        }
        let fit = least_squares(&points)
            .ok_or_else(|| anyhow!("degenerate link fit for {}", class.id()))?;
        out.insert(class, fit);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn par() -> ParallelDegrees {
        ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 }
    }

    #[test]
    fn measurement_monotone_in_size() {
        let c = ClusterTopology::testbed_b_subset(8).unwrap();
        for kind in CollKind::ALL {
            let small = measure_collective(&c, par(), kind, 1e5).unwrap();
            let large = measure_collective(&c, par(), kind, 1e7).unwrap();
            assert!(large > small, "{}: {large} !> {small}", kind.name());
        }
    }

    #[test]
    fn fits_are_linear_with_high_r2() {
        // The simulated collectives are α-β by construction, so the fit
        // must be near-perfect — this is the Fig 6 "linear model well
        // fits" observation.
        let c = ClusterTopology::testbed_b_subset(8).unwrap();
        let m = PerfModel::fit(&c, par()).unwrap();
        for kind in CollKind::ALL {
            let f = m.get(kind);
            assert!(f.r2 > 0.999, "{} r2 = {}", kind.name(), f.r2);
            assert!(f.slope > 0.0, "{} slope = {}", kind.name(), f.slope);
            assert!(f.intercept >= 0.0, "{} alpha = {}", kind.name(), f.intercept);
        }
    }

    #[test]
    fn prediction_matches_direct_measurement() {
        let c = ClusterTopology::testbed_b_subset(8).unwrap();
        let m = PerfModel::fit(&c, par()).unwrap();
        for kind in [CollKind::AgMp, CollKind::A2aFused] {
            let x = 2.5e6; // off the fit grid
            let direct = measure_collective(&c, par(), kind, x).unwrap();
            let predicted = m.predict(kind, x);
            let rel = (direct - predicted).abs() / direct;
            assert!(rel < 0.05, "{}: rel err {rel}", kind.name());
        }
    }

    #[test]
    fn fused_cheaper_than_ag_plus_a2a_bandwidth_regime() {
        // Eq. (3): A2A_fused(x) ≤ AG_ESP(x) + A2A_EP(x). The paper's §IV
        // analysis is a bandwidth (β) argument; in the latency-bound
        // regime (x ≲ 100 KiB here) the fused collective's (P-1) messages
        // per rank cost more α than the baseline's (N_EP-1)+(N_ESP-1), so
        // we assert the inequality where the analysis applies — the
        // bandwidth-dominated sizes real MoE layers use (≥ 1 MiB).
        let c = ClusterTopology::testbed_b_subset(8).unwrap();
        let m = PerfModel::fit(&c, par()).unwrap();
        for &x in FIT_SIZES.iter().filter(|&&x| x >= 1048576.0) {
            let fused = m.predict(CollKind::A2aFused, x);
            let seq = m.predict(CollKind::AgEsp, x) + m.predict(CollKind::A2aEp, x);
            assert!(fused <= seq * 1.001, "x={x}: fused {fused} vs seq {seq}");
        }
        // And the β (slope) comparison holds unconditionally.
        let beta_fused = m.get(CollKind::A2aFused).slope;
        let beta_seq = m.get(CollKind::AgEsp).slope + m.get(CollKind::A2aEp).slope;
        assert!(beta_fused < beta_seq);
    }

    #[test]
    fn json_report_has_all_fits() {
        let c = ClusterTopology::testbed_b_subset(8).unwrap();
        let m = PerfModel::fit(&c, par()).unwrap();
        let j = m.to_json();
        for kind in CollKind::ALL {
            assert!(j.get("fits").get(kind.name()).get("beta").as_f64().unwrap() > 0.0);
        }
        // Link-class fits are reported under their stable ids.
        assert!(j.get("link_fits").get("intra.c0").get("beta").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        // Rust's f64 Display prints the shortest round-trip representation,
        // so serialize → parse → serialize must be a fixed point — the
        // property the plan artifact and fit cache rely on.
        let c = ClusterTopology::testbed_b_subset(8).unwrap();
        let m = PerfModel::fit(&c, par()).unwrap();
        let doc = m.to_json();
        let back = PerfModel::from_json(&doc).unwrap();
        assert_eq!(back.to_json().to_string(), doc.to_string());
        assert_eq!(back.gpu_flops, m.gpu_flops);
        assert_eq!(back.node_flops(), m.node_flops());
        for kind in CollKind::ALL {
            assert_eq!(back.get(kind), m.get(kind), "{}", kind.name());
        }
        assert_eq!(back.link_fits(), m.link_fits());
    }

    #[test]
    fn from_json_rejects_incomplete_documents() {
        let c = ClusterTopology::testbed_b_subset(8).unwrap();
        let m = PerfModel::fit(&c, par()).unwrap();
        let mut doc = m.to_json();
        if let Json::Obj(o) = &mut doc {
            let Some(Json::Obj(fits)) = o.get_mut("fits") else { panic!("fits object") };
            fits.remove("saa_s2");
        }
        let err = PerfModel::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("saa_s2"), "{err}");
    }

    #[test]
    fn coll_kind_parse_roundtrips() {
        for kind in CollKind::ALL {
            assert_eq!(CollKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(CollKind::parse("nope"), None);
    }

    #[test]
    fn link_class_fits_recover_topology_constants() {
        // On the simulator a single transfer costs exactly α + x·β of its
        // link, so the per-class regression must recover the topology's
        // own constants (r² = 1) — one pair per LinkClass, not two global
        // scalars.
        let c = ClusterTopology::testbed_b_subset(8).unwrap();
        let m = PerfModel::fit(&c, par()).unwrap();
        assert_eq!(m.link_fits().len(), c.link_classes().len());
        for class in c.link_classes() {
            let fit = m.link_fit(class).unwrap();
            let link = c.link_of_class(class).unwrap();
            assert!(fit.r2 > 0.999999, "{}: r2 {}", class.id(), fit.r2);
            assert!(
                (fit.intercept - link.alpha).abs() / link.alpha < 1e-9,
                "{}: α {} vs {}",
                class.id(),
                fit.intercept,
                link.alpha
            );
            assert!(
                (fit.slope - link.beta).abs() / link.beta < 1e-9,
                "{}: β {} vs {}",
                class.id(),
                fit.slope,
                link.beta
            );
        }
        assert!(m.link_fit(crate::config::LinkClass::Intra(7)).is_none());
    }

    #[test]
    fn heterogeneous_model_carries_per_class_and_per_node_axes() {
        use crate::config::cluster::NodeSpec;
        let homo = ClusterTopology::testbed_b_subset(8).unwrap();
        let fast = homo.node_specs()[0];
        let slow = NodeSpec {
            gpu_flops: fast.gpu_flops / 2.0,
            inter: crate::config::AlphaBeta::new(fast.inter.alpha * 4.0, fast.inter.beta * 4.0),
            ..fast
        };
        let het = ClusterTopology::new("het", vec![fast, slow]).unwrap();
        let m = PerfModel::fit(&het, par()).unwrap();
        // Bottleneck flops = the slow node's; both nodes reported.
        assert_eq!(m.gpu_flops, slow.gpu_flops);
        assert_eq!(m.node_flops(), &[(0, fast.gpu_flops), (1, slow.gpu_flops)]);
        // Three link classes: two intra kinds + the mixed inter pair, each
        // recovering its own constants (the inter pair at the bottleneck
        // NIC, i.e. the slow node's).
        assert_eq!(m.link_fits().len(), 3);
        let inter = m.link_fit(crate::config::LinkClass::Inter(0, 1)).unwrap();
        assert!((inter.slope - slow.inter.beta).abs() / slow.inter.beta < 1e-9);
    }
}
