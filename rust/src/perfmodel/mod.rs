//! α-β performance models and the automatic schedule selection
//! (paper §V, Algorithm 1, generalized to the SP family).
//!
//! Each collective, in the process-group layout a configuration induces,
//! is measured in the simulator over a range of message sizes; ordinary
//! least squares recovers `t(x) = α + β·x` (§V-A / Fig 6). The closed
//! forms `t_B`, `t_D1`, `t_D2` (Eqs. 1, 13, 14) plus the pipelined
//! `t_SP(r)` recurrence are then compared online to pick S1, S2 or SP(r*)
//! — SP's chunk count is itself chosen in closed form (argmin over
//! `1..=SP_MAX_CHUNKS`).

pub mod closedform;
pub mod fit;
pub mod selection;

pub use fit::{measure_collective, CollKind, PerfModel};
pub use selection::{choose_schedule, choose_schedule_extended};
