//! α-β performance models and the automatic schedule selection
//! (paper §V, Algorithm 1, generalized to the chunk-pipelined SP/SP2
//! families and to heterogeneous topologies).
//!
//! Each collective, in the process-group layout a configuration induces,
//! is measured in the simulator over a range of message sizes; ordinary
//! least squares recovers `t(x) = α + β·x` (§V-A / Fig 6). The fitted
//! [`PerfModel`] is **topology-aware**: besides the per-collective fits
//! it carries one α-β pair per [`crate::config::LinkClass`] of the
//! cluster (fitted from single-transfer measurements over a
//! representative pair of each class — not two global scalars) and the
//! per-node GPU throughputs of the layout.
//!
//! The closed forms `t_B`, `t_D1`, `t_D2` (Eqs. 1, 13, 14) plus the
//! pipelined `t_SP(r)` and `t_SP2(r)` recurrences (the latter with an
//! asymmetric combine leg — the chunked SAA's AlltoAll plus its exposed
//! MP-AllGather tail) are then compared online to pick S1, S2, SP(r*) or
//! SP2(r*) — each pipelined family's chunk count is itself chosen in
//! closed form (argmin over `1..=SP_MAX_CHUNKS`), and the comparison is
//! over **whole iterations**, not forward passes: each family carries a
//! true backward term (`closedform::t_bwd_d1`/`t_bwd_d2` — transposed
//! AlltoAlls, dgrad + wgrad FFN, the adjoint AllGathers of the forward's
//! free splits) plus the exposed tail of the expert wgrad AllReduce
//! after overlap (`closedform::exposed_wgrad_ar`), mirroring the
//! backward op programs the simulator runs
//! ([`crate::schedule::builders::backward_ops`]).
//! [`selection::Prediction::best_forward_only`] keeps the old
//! forward-only pick as the ablation. On a mixed fleet the compute-inclusive
//! terms are evaluated **per node** (the collectives are global, the FFN
//! runs at each node's own throughput): the fleet-level pick minimizes
//! the worst node's estimate, [`selection::Prediction`] reports which
//! node that is (`bottleneck_node`), and the `*_on` variants in
//! [`closedform`] expose the per-node view — where r* and even the
//! Algorithm 1 pick can genuinely differ between a fast node and a
//! straggler.
//!
//! Fitting is the expensive step, so its products are deployable: a
//! [`plan::Plan`] artifact freezes the fitted tables and per-config
//! decisions behind content hashes (`parm plan build` writes one,
//! `--plan` consumers load it without refitting).

pub mod closedform;
pub mod fit;
pub mod plan;
pub mod selection;

pub use fit::{measure_collective, CollKind, PerfModel};
pub use plan::{Plan, PLAN_SCHEMA_VERSION};
pub use selection::{choose_schedule, choose_schedule_extended};
