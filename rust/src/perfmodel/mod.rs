//! α-β performance models and the automatic schedule selection
//! (paper §V, Algorithm 1).
//!
//! Each collective, in the process-group layout a configuration induces,
//! is measured in the simulator over a range of message sizes; ordinary
//! least squares recovers `t(x) = α + β·x` (§V-A / Fig 6). The closed
//! forms `t_B`, `t_D1`, `t_D2` (Eqs. 1, 13, 14) are then compared online
//! to pick S1 or S2.

pub mod closedform;
pub mod fit;
pub mod selection;

pub use fit::{measure_collective, CollKind, PerfModel};
pub use selection::choose_schedule;
