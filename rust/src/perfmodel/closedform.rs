//! Closed-form collective cost model — the paper's §IV analysis made
//! executable, straight from cluster constants (no fitting, no
//! simulation).
//!
//! Where [`super::fit`] *measures* the simulator and regresses α-β, this
//! module derives the same costs analytically from the ring/pairwise
//! algorithms' structure:
//!
//! ```text
//! AG_ring(g, x)  = (g-1) · (α_link + (x/g)·β_link)         x = gathered output
//! RS_ring(g, x)  = (g-1) · (α_link + (x/g)·β_link)         x = per-member buffer
//! AR_ring(g, x)  = 2 · RS_ring(g, x)                        (RS ∘ AG, [21,22])
//! A2A_pair(g, x) = bottleneck-class chain over x/g chunks   x = per-member send
//! ```
//!
//! For AlltoAlls whose group straddles nodes, the bottleneck is the NIC:
//! each node's NIC carries `(members on node) × (members elsewhere)`
//! chunks each way. The tests pin this model to the discrete-event
//! simulator within a small tolerance — the "theory matches practice"
//! check the paper argues informally in §IV.

use crate::cluster::{GroupKind, ProcessGroups};
use crate::config::{ClusterProfile, MoeLayerConfig};
use crate::schedule::ops;

/// Ring AllGather over an intra-node group: `x` = gathered output bytes.
pub fn ag_ring(cluster: &ClusterProfile, g: usize, x: f64) -> f64 {
    if g <= 1 {
        return 0.0;
    }
    (g - 1) as f64 * (cluster.alpha_intra + x / g as f64 * cluster.beta_intra)
}

/// Ring AllReduce over an intra-node group: `x` = per-member buffer bytes.
pub fn ar_ring(cluster: &ClusterProfile, g: usize, x: f64) -> f64 {
    if g <= 1 {
        return 0.0;
    }
    2.0 * (g - 1) as f64 * (cluster.alpha_intra + x / g as f64 * cluster.beta_intra)
}

/// Pairwise AlltoAll over a (possibly multi-node) group.
///
/// `group` carries physical rank ids; `per_pair` is one (src,dst) chunk in
/// bytes. The cost is the max of (a) the slowest member's per-class send
/// chains and (b) the busiest NIC, the two serialization sources in the
/// simulator's resource model.
pub fn a2a_pairwise(cluster: &ClusterProfile, group: &[usize], per_pair: f64) -> f64 {
    a2a_pairwise_concurrent(cluster, group, per_pair, 1)
}

/// Pairwise AlltoAll when `concurrency` identical groups run at once
/// (the baseline schedule runs all `N_ESP` EP-group AlltoAlls
/// simultaneously, multiplying every NIC's load — the §III-A
/// inefficiency the fused collective removes).
pub fn a2a_pairwise_concurrent(
    cluster: &ClusterProfile,
    group: &[usize],
    per_pair: f64,
    concurrency: usize,
) -> f64 {
    let g = group.len();
    if g <= 1 {
        return 0.0;
    }
    let intra_chunk = cluster.alpha_intra + per_pair * cluster.beta_intra;
    let inter_chunk = cluster.alpha_inter + per_pair * cluster.beta_inter;

    // (a) per-member chains: intra sends and inter sends progress on
    // independent classes; the member finishes when the slower chain does.
    let mut member_worst: f64 = 0.0;
    // (b) NIC load: inter-node chunks traversing each node's NIC (tx).
    let mut nic_chunks: std::collections::BTreeMap<usize, usize> = Default::default();
    for &src in group {
        let mut intra = 0usize;
        let mut inter = 0usize;
        for &dst in group {
            if dst == src {
                continue;
            }
            if cluster.same_node(src, dst) {
                intra += 1;
            } else {
                inter += 1;
            }
        }
        member_worst = member_worst
            .max(intra as f64 * intra_chunk)
            .max(inter as f64 * inter_chunk);
        *nic_chunks.entry(cluster.node_of(src)).or_default() += inter;
    }
    let nic_worst = nic_chunks
        .values()
        .map(|&n| (n * concurrency) as f64 * inter_chunk)
        .fold(0.0, f64::max);
    member_worst.max(nic_worst)
}

/// Analytical `t_B` (Eq. 1): baseline communication per forward pass.
pub fn t_baseline(cluster: &ClusterProfile, c: &MoeLayerConfig) -> f64 {
    let par = c.par;
    let groups = ProcessGroups::new(par).expect("valid degrees");
    let ep_group = groups.group(GroupKind::Ep, 0);
    let ag = ag_ring(cluster, par.n_esp, ops::bytes_esp_ag_per_rank(c) * par.n_esp as f64);
    let ar = ar_ring(cluster, par.n_esp, ops::bytes_esp_ar_total(c));
    // All N_ESP EP-group AlltoAlls fire at once, sharing every NIC.
    let a2a = a2a_pairwise_concurrent(
        cluster,
        &ep_group,
        ops::bytes_ep_a2a_per_pair(c),
        par.n_esp,
    );
    ag + ar + 2.0 * a2a
}

/// Analytical `t_D1` (Eq. 13).
pub fn t_d1(cluster: &ClusterProfile, c: &MoeLayerConfig) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    let world = groups.world();
    let fused = a2a_pairwise(cluster, &world, ops::bytes_fused_a2a_per_pair(c));
    let ag = ag_ring(cluster, c.par.n_mp, ops::bytes_mp_ag_s1_per_rank(c) * c.par.n_mp as f64);
    2.0 * fused + ag
}

/// Analytical `t_D2` (Eq. 14): dispatch AlltoAll + overlapped combine.
/// The overlap term is bounded below by the fused AlltoAll alone and
/// above by the AAS sequence; we take the paper's assumption that the
/// AllGather hides except for its non-overlappable tail on single-node
/// groups (where SAA degrades to AAS — see `comm::saa`).
pub fn t_d2(cluster: &ClusterProfile, c: &MoeLayerConfig) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    let world = groups.world();
    let fused = a2a_pairwise(cluster, &world, ops::bytes_fused_a2a_per_pair(c));
    let ag = ag_ring(cluster, c.par.n_mp, ops::bytes_mp_ag_s2_per_rank(c) * c.par.n_mp as f64);
    let single_node = world
        .iter()
        .all(|&r| cluster.node_of(r) == cluster.node_of(world[0]));
    if single_node {
        // No second link class: combine = fused A2A then AG (AAS).
        2.0 * fused + ag
    } else {
        // AG overlaps the inter-dominant combine; only the last phase's
        // forwards are exposed (1/SAA_PHASES of the AG).
        2.0 * fused + ag / crate::comm::saa::SAA_PHASES as f64
    }
}

/// Closed-form Algorithm 1: no fitting, no simulation.
pub fn choose(cluster: &ClusterProfile, c: &MoeLayerConfig) -> crate::schedule::ScheduleKind {
    if t_d1(cluster, c) <= t_d2(cluster, c) {
        crate::schedule::ScheduleKind::S1
    } else {
        crate::schedule::ScheduleKind::S2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::moe::ParallelDegrees;
    use crate::perfmodel::fit::{measure_collective, CollKind};
    use crate::schedule::{lowering, ScheduleKind};

    fn par() -> ParallelDegrees {
        ParallelDegrees { p: 32, n_mp: 4, n_esp: 4 }
    }

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig {
            par: par(),
            b: 4,
            l: 1024,
            e: 8,
            m: 1024,
            h: 2048,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
        }
    }

    #[test]
    fn ag_matches_simulator() {
        let cluster = ClusterProfile::testbed_b();
        for x in [1e6, 1e7, 6e7] {
            let sim = measure_collective(&cluster, par(), CollKind::AgMp, x).unwrap();
            let cf = ag_ring(&cluster, 4, x);
            let rel = (sim - cf).abs() / sim;
            assert!(rel < 0.02, "x={x}: sim {sim} vs closed-form {cf}");
        }
    }

    #[test]
    fn ar_matches_simulator() {
        let cluster = ClusterProfile::testbed_b();
        for x in [1e6, 1e7] {
            let sim = measure_collective(&cluster, par(), CollKind::ArEsp, x).unwrap();
            let cf = ar_ring(&cluster, 4, x);
            let rel = (sim - cf).abs() / sim;
            assert!(rel < 0.05, "x={x}: sim {sim} vs closed-form {cf}");
        }
    }

    #[test]
    fn a2a_matches_simulator() {
        // Fused AlltoAll over the full 32-rank world (8 nodes × 4).
        let cluster = ClusterProfile::testbed_b();
        let groups = ProcessGroups::new(par()).unwrap();
        let world = groups.world();
        for x in [1e6, 1e7, 6e7] {
            let sim = measure_collective(&cluster, par(), CollKind::A2aFused, x).unwrap();
            let cf = a2a_pairwise(&cluster, &world, x / 32.0);
            let rel = (sim - cf).abs() / sim;
            assert!(rel < 0.15, "x={x}: sim {sim} vs closed-form {cf} (rel {rel})");
        }
    }

    #[test]
    fn closed_form_ranks_schedules_like_simulator() {
        let cluster = ClusterProfile::testbed_b();
        let c = cfg();
        // Closed forms are forward-comm only; the simulator runs fwd+bwd
        // with compute. Compare *ratios*, which is what Algorithm 1 uses.
        let cf_gain = t_baseline(&cluster, &c) / t_d1(&cluster, &c);
        let t_base =
            lowering::simulate_iteration(ScheduleKind::Baseline, &c, &cluster).unwrap().makespan;
        let t_s1 = lowering::simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
        let sim_gain = t_base / t_s1;
        let rel = (cf_gain - sim_gain).abs() / sim_gain;
        assert!(
            rel < 0.35,
            "closed-form speedup {cf_gain:.2} vs simulated {sim_gain:.2}"
        );
        assert!(cf_gain > 1.0 && sim_gain > 1.0);
    }

    #[test]
    fn closed_form_choice_tracks_capacity_extremes() {
        // §IV-B: T → 0 favors S2, T → ∞ favors S1 — same flip the fitted
        // selector shows, now derivable with zero measurements.
        let cluster = ClusterProfile::testbed_b();
        let mut tiny = cfg();
        tiny.f = 0.01;
        let mut huge = cfg();
        huge.f = 64.0;
        assert_eq!(choose(&cluster, &tiny), ScheduleKind::S2);
        assert_eq!(choose(&cluster, &huge), ScheduleKind::S1);
    }

    #[test]
    fn degenerate_groups_cost_nothing() {
        let cluster = ClusterProfile::testbed_b();
        assert_eq!(ag_ring(&cluster, 1, 1e9), 0.0);
        assert_eq!(ar_ring(&cluster, 1, 1e9), 0.0);
        assert_eq!(a2a_pairwise(&cluster, &[3], 1e9), 0.0);
    }
}
