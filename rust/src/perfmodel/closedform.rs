//! Closed-form collective cost model — the paper's §IV analysis made
//! executable, straight from cluster constants (no fitting, no
//! simulation).
//!
//! Where [`super::fit`] *measures* the simulator and regresses α-β, this
//! module derives the same costs analytically from the ring/pairwise
//! algorithms' structure:
//!
//! ```text
//! AG_ring(G, x)  = (|G|-1) · max_step(α_link + (x/|G|)·β_link)    x = gathered output
//! RS_ring(G, x)  = (|G|-1) · max_step(α_link + (x/|G|)·β_link)    x = per-member buffer
//! AR_ring(G, x)  = 2 · RS_ring(G, x)                              (RS ∘ AG, [21,22])
//! A2A_pair(G, x) = bottleneck-class chain over x/|G| chunks       x = per-member send
//! ```
//!
//! Every term is priced over the **actual endpoint pairs** of the group
//! via [`ClusterTopology::link`] — not two global scalars — so mixed
//! fleets (straggler nodes, asymmetric NICs) cost what the engine would
//! charge. For AlltoAlls whose group straddles nodes, the bottleneck is
//! the busiest NIC: each node's NIC carries that node's members'
//! cross-node chunks each way.
//!
//! Compute-inclusive terms come in two forms: the fleet-level functions
//! (`t_ffn_pausemp`, `sp_pipeline`, `sp2_pipeline`, `optimal_chunks`,
//! `optimal_chunks_sp2`, `choose_extended`) evaluate the **bottleneck
//! node** (max over the nodes hosting the layer), and `*_on`-suffixed
//! variants evaluate one node — on a heterogeneous fleet the chunk counts
//! r* and even Algorithm 1's pick can differ per node, which the per-node
//! API exposes ([`optimal_chunks_on`], [`optimal_chunks_sp2_on`],
//! [`choose_extended_on`], [`t_bwd_d1_on`], [`t_iter_s1_on`],
//! [`sp_bottleneck_node`]). Algorithm 1 is the **full-iteration** argmin
//! over the four-member family {S1, S2, SP(r*), SP2(r*)} — SP2 being the
//! chunk-pipelined S2 whose per-chunk combine is a chunked SAA. Each
//! family carries a true `t_bwd` term (adjoint communication, doubled
//! gradient FFN, and the exposed share of the overlapped wgrad
//! AllReduce — [`t_wgrad_ar`], [`exposed_wgrad_ar`]) instead of the old
//! double-the-forward heuristic.
//! The tests pin this model to the discrete-event simulator within a
//! small tolerance — the "theory matches practice" check the paper argues
//! informally in §IV.

use crate::cluster::{GroupKind, ProcessGroups};
use crate::config::{ClusterTopology, MoeLayerConfig, WireLeg};
use crate::schedule::ops;
use crate::schedule::ops::wire_factor;

/// Ring AllGather over a group: `x` = gathered output bytes. Each of the
/// `|G|-1` steps moves one `x/|G|` chunk along every ring edge at once, so
/// a step lasts as long as the slowest edge.
pub fn ag_ring(cluster: &ClusterTopology, group: &[usize], x: f64) -> f64 {
    let g = group.len();
    if g <= 1 {
        return 0.0;
    }
    let chunk = x / g as f64;
    let step = group
        .iter()
        .enumerate()
        .map(|(i, &src)| cluster.link(src, group[(i + 1) % g]).seconds(chunk))
        .fold(0.0, f64::max);
    (g - 1) as f64 * step
}

/// Ring AllReduce over a group: `x` = per-member buffer bytes
/// (ReduceScatter ∘ AllGather — exactly twice the AllGather's steps).
pub fn ar_ring(cluster: &ClusterTopology, group: &[usize], x: f64) -> f64 {
    2.0 * ag_ring(cluster, group, x)
}

/// Pairwise AlltoAll over a (possibly multi-node) group.
///
/// `group` carries physical rank ids; `per_pair` is one (src,dst) chunk in
/// bytes. The cost is the max of (a) the slowest member's per-class send
/// chains and (b) the busiest NIC, the two serialization sources in the
/// simulator's resource model.
pub fn a2a_pairwise(cluster: &ClusterTopology, group: &[usize], per_pair: f64) -> f64 {
    a2a_pairwise_concurrent(cluster, group, per_pair, 1)
}

/// Pairwise AlltoAll when `concurrency` identical groups run at once
/// (the baseline schedule runs all `N_ESP` EP-group AlltoAlls
/// simultaneously, multiplying every NIC's load — the §III-A
/// inefficiency the fused collective removes).
pub fn a2a_pairwise_concurrent(
    cluster: &ClusterTopology,
    group: &[usize],
    per_pair: f64,
    concurrency: usize,
) -> f64 {
    let mut worst = 0.0f64;
    let mut seen: Vec<usize> = Vec::new();
    for &r in group {
        let n = cluster.node_of(r);
        if !seen.contains(&n) {
            seen.push(n);
            worst = worst.max(a2a_pairwise_on_node(cluster, group, per_pair, concurrency, n));
        }
    }
    worst
}

/// The AlltoAll bottleneck as seen from one node: the slowest send chain
/// among that node's members and the node's own NIC serialization. The
/// fleet-level [`a2a_pairwise_concurrent`] is the max of this over the
/// nodes with members.
pub fn a2a_pairwise_on_node(
    cluster: &ClusterTopology,
    group: &[usize],
    per_pair: f64,
    concurrency: usize,
    node: usize,
) -> f64 {
    if group.len() <= 1 {
        return 0.0;
    }
    // (a) per-member chains: intra sends and inter sends progress on
    // independent classes; the member finishes when the slower chain does.
    let mut member_worst = 0.0f64;
    // (b) NIC load: cross-node chunk seconds traversing this node's NIC
    // (tx) — per-link costs, so a slow peer NIC lengthens the chain.
    let mut nic_secs = 0.0f64;
    for &src in group {
        if cluster.node_of(src) != node {
            continue;
        }
        let mut intra = 0.0f64;
        let mut inter = 0.0f64;
        for &dst in group {
            if dst == src {
                continue;
            }
            let t = cluster.link(src, dst).seconds(per_pair);
            if cluster.same_node(src, dst) {
                intra += t;
            } else {
                inter += t;
            }
        }
        member_worst = member_worst.max(intra).max(inter);
        nic_secs += inter;
    }
    member_worst.max(nic_secs * concurrency as f64)
}

/// Worst cost over the groups of one kind — the synchronous-layer view: a
/// collective step finishes when its slowest group does.
fn worst_group(groups: &[Vec<usize>], cost: impl Fn(&[usize]) -> f64) -> f64 {
    groups.iter().map(|g| cost(g)).fold(0.0, f64::max)
}

/// Analytical `t_B` (Eq. 1): baseline communication per forward pass.
/// Every collective prices its wire leg's compressed volume
/// ([`ops::wire_factor`]): the token AllGather/AllReduce ride the
/// AllGather leg, and the two EP AlltoAlls split into a dispatch-priced
/// and a combine-priced direction.
pub fn t_baseline(cluster: &ClusterTopology, c: &MoeLayerConfig) -> f64 {
    let par = c.par;
    let groups = ProcessGroups::new(par).expect("valid degrees");
    let w_g = wire_factor(c, WireLeg::AllGather);
    let esp = groups.all_groups(GroupKind::Esp);
    let ag = worst_group(&esp, |g| {
        ag_ring(cluster, g, ops::bytes_esp_ag_per_rank(c) * par.n_esp as f64 * w_g)
    });
    let ar = worst_group(&esp, |g| ar_ring(cluster, g, ops::bytes_esp_ar_total(c) * w_g));
    // All N_ESP EP-group AlltoAlls fire at once, sharing every NIC.
    let ep = groups.all_groups(GroupKind::Ep);
    let a2a_leg = |leg: WireLeg| {
        worst_group(&ep, |g| {
            a2a_pairwise_concurrent(
                cluster,
                g,
                ops::bytes_ep_a2a_per_pair(c) * wire_factor(c, leg),
                par.n_esp,
            )
        })
    };
    ag + ar + a2a_leg(WireLeg::Dispatch) + a2a_leg(WireLeg::Combine)
}

/// Worst MP-group AllGather of `x` gathered bytes over the layer.
fn ag_mp(cluster: &ClusterTopology, c: &MoeLayerConfig, x: f64) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    worst_group(&groups.all_groups(GroupKind::Mp), |g| ag_ring(cluster, g, x))
}

/// The fused AlltoAll priced at one wire leg's compressed per-pair volume.
fn fused_a2a_leg(cluster: &ClusterTopology, c: &MoeLayerConfig, world: &[usize], leg: WireLeg) -> f64 {
    a2a_pairwise(cluster, world, ops::bytes_fused_a2a_per_pair(c) * wire_factor(c, leg))
}

/// Analytical `t_D1` (Eq. 13): dispatch- plus combine-priced fused
/// AlltoAlls and the AllGather-leg MP epilogue.
pub fn t_d1(cluster: &ClusterTopology, c: &MoeLayerConfig) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    let world = groups.world();
    let fused_d = fused_a2a_leg(cluster, c, &world, WireLeg::Dispatch);
    let fused_c = fused_a2a_leg(cluster, c, &world, WireLeg::Combine);
    let ag = ag_mp(
        cluster,
        c,
        ops::bytes_mp_ag_s1_per_rank(c) * c.par.n_mp as f64 * wire_factor(c, WireLeg::AllGather),
    );
    fused_d + fused_c + ag
}

/// Exposed fraction of an SAA-overlapped MP-AllGather: on a single-node
/// group there is no second link class (SAA degrades to AAS — see
/// `comm::saa`) so the whole AllGather is exposed; across nodes only the
/// last phase's forwards are (1/[`crate::comm::saa::SAA_PHASES`]). Shared
/// by `t_D2` and the chunked-SAA terms of `t_SP2` so the monolithic and
/// pipelined S2 estimates cannot diverge on the overlap assumption.
fn saa_exposed_fraction(cluster: &ClusterTopology, world: &[usize]) -> f64 {
    let single_node = world
        .iter()
        .all(|&r| cluster.node_of(r) == cluster.node_of(world[0]));
    if single_node {
        1.0
    } else {
        1.0 / crate::comm::saa::SAA_PHASES as f64
    }
}

/// Analytical `t_D2` (Eq. 14): dispatch AlltoAll + overlapped combine.
/// The overlap term is bounded below by the fused AlltoAll alone and
/// above by the AAS sequence; we take the paper's assumption that the
/// AllGather hides except for its non-overlappable tail
/// ([`saa_exposed_fraction`]).
pub fn t_d2(cluster: &ClusterTopology, c: &MoeLayerConfig) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    let world = groups.world();
    let fused_d = fused_a2a_leg(cluster, c, &world, WireLeg::Dispatch);
    let fused_c = fused_a2a_leg(cluster, c, &world, WireLeg::Combine);
    // The SAA's internal AllGather forwards ride the combine leg on both
    // planes (the leg is set once per SAA op), so its exposed tail is
    // priced at the combine width, not the standalone-AllGather width.
    let ag = ag_mp(
        cluster,
        c,
        ops::bytes_mp_ag_s2_per_rank(c) * c.par.n_mp as f64 * wire_factor(c, WireLeg::Combine),
    );
    fused_d + fused_c + saa_exposed_fraction(cluster, &world) * ag
}

/// Closed-form Algorithm 1: no fitting, no simulation.
pub fn choose(cluster: &ClusterTopology, c: &MoeLayerConfig) -> crate::schedule::ScheduleKind {
    if t_d1(cluster, c) <= t_d2(cluster, c) {
        crate::schedule::ScheduleKind::S1
    } else {
        crate::schedule::ScheduleKind::S2
    }
}

/// ESP-group ring AllReduce of the expert weight gradients
/// ([`ops::bytes_wgrad_per_rank`]) — the backward synchronization every
/// family pays: the N_ESP replicas of each expert shard compute wgrads
/// from different token slices and must agree before the optimizer step.
pub fn t_wgrad_ar(cluster: &ClusterTopology, c: &MoeLayerConfig) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    let w_r = wire_factor(c, WireLeg::Wgrad);
    worst_group(&groups.all_groups(GroupKind::Esp), |g| {
        ar_ring(cluster, g, ops::bytes_wgrad_per_rank(c) * w_r)
    })
}

/// Exposed seconds of the overlapped wgrad AllReduce: the deferred
/// completion lowering rides the reduction under `tail` seconds of
/// remaining backward work, so only the excess lands on the critical
/// path. The non-overlapped ablation pays the full `ar` instead.
pub fn exposed_wgrad_ar(ar: f64, tail: f64) -> f64 {
    (ar - tail).max(0.0)
}

/// Analytical backward time of S1 at one node — the **true** `t_bwd`
/// term (the former model doubled the forward): adjoint communication
/// (MP-ReduceScatter of the token AllGather, two transposed fused
/// AlltoAlls, the adjoint-of-split MP-AllGather), the doubled gradient
/// FFN (dgrad + wgrad), and the exposed share of the wgrad AllReduce —
/// its hiding tail is the transposed combine AlltoAll plus the final
/// MP-AllGather it is deferred across.
pub fn t_bwd_d1_on(cluster: &ClusterTopology, c: &MoeLayerConfig, node: usize) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    let world = groups.world();
    let fused_d = fused_a2a_leg(cluster, c, &world, WireLeg::Dispatch);
    let fused_c = fused_a2a_leg(cluster, c, &world, WireLeg::Combine);
    let ag = ag_mp(
        cluster,
        c,
        ops::bytes_mp_ag_s1_per_rank(c) * c.par.n_mp as f64 * wire_factor(c, WireLeg::AllGather),
    );
    // The wgrad AllReduce hides behind the transposed combine AlltoAll and
    // the final MP-AllGather — both priced at their own wire legs.
    fused_d
        + fused_c
        + 2.0 * ag
        + 2.0 * t_ffn_pausemp_on(cluster, c, node)
        + exposed_wgrad_ar(t_wgrad_ar(cluster, c), fused_c + ag)
}

/// [`t_bwd_d1_on`] at the bottleneck node.
pub fn t_bwd_d1(cluster: &ClusterTopology, c: &MoeLayerConfig) -> f64 {
    t_bwd_d1_on(cluster, c, sp_bottleneck_node(cluster, c))
}

/// Analytical backward time of S2 at one node (see [`t_bwd_d1_on`]).
/// Both MP collectives are the capacity-based (E, T/N_MP, M) volume and
/// both are fully exposed — the backward has no SAA to hide the restore
/// behind (its adjoint is the up-front ReduceScatter).
pub fn t_bwd_d2_on(cluster: &ClusterTopology, c: &MoeLayerConfig, node: usize) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    let world = groups.world();
    let fused_d = fused_a2a_leg(cluster, c, &world, WireLeg::Dispatch);
    let fused_c = fused_a2a_leg(cluster, c, &world, WireLeg::Combine);
    let ag = ag_mp(
        cluster,
        c,
        ops::bytes_mp_ag_s2_per_rank(c) * c.par.n_mp as f64 * wire_factor(c, WireLeg::AllGather),
    );
    fused_d
        + fused_c
        + 2.0 * ag
        + 2.0 * t_ffn_pausemp_on(cluster, c, node)
        + exposed_wgrad_ar(t_wgrad_ar(cluster, c), fused_c + ag)
}

/// [`t_bwd_d2_on`] at the bottleneck node.
pub fn t_bwd_d2(cluster: &ClusterTopology, c: &MoeLayerConfig) -> f64 {
    t_bwd_d2_on(cluster, c, sp_bottleneck_node(cluster, c))
}

/// Full-iteration S1 estimate at one node: forward (`t_D1` + FFN) plus
/// the true backward term.
pub fn t_iter_s1_on(cluster: &ClusterTopology, c: &MoeLayerConfig, node: usize) -> f64 {
    t_d1(cluster, c) + t_ffn_pausemp_on(cluster, c, node) + t_bwd_d1_on(cluster, c, node)
}

/// [`t_iter_s1_on`] at the bottleneck node.
pub fn t_iter_s1(cluster: &ClusterTopology, c: &MoeLayerConfig) -> f64 {
    t_iter_s1_on(cluster, c, sp_bottleneck_node(cluster, c))
}

/// Full-iteration S2 estimate at one node: forward (`t_D2` + FFN) plus
/// the true backward term.
pub fn t_iter_s2_on(cluster: &ClusterTopology, c: &MoeLayerConfig, node: usize) -> f64 {
    t_d2(cluster, c) + t_ffn_pausemp_on(cluster, c, node) + t_bwd_d2_on(cluster, c, node)
}

/// [`t_iter_s2_on`] at the bottleneck node.
pub fn t_iter_s2(cluster: &ClusterTopology, c: &MoeLayerConfig) -> f64 {
    t_iter_s2_on(cluster, c, sp_bottleneck_node(cluster, c))
}

/// Expert-FFN seconds per rank under PauseMP on one node's GPUs — the
/// compute term shared by S1, S2 and SP (the baseline duplicates it N_MP
/// times instead). Scaled by the routing-load model
/// ([`ops::ffn_load_scale`]) so skewed configs price only the
/// actually-routed tokens (zero padding does no FFN work), matching the
/// builders.
pub fn t_ffn_pausemp_on(cluster: &ClusterTopology, c: &MoeLayerConfig, node: usize) -> f64 {
    ops::expert_flops(c, ops::expert_tokens_per_rank(c, true))
        * ops::ffn_load_scale(c, c.t_pausemp())
        / cluster.node(node).gpu_flops
}

/// [`t_ffn_pausemp_on`] at the layer's bottleneck (slowest) node — what a
/// synchronous step waits for.
pub fn t_ffn_pausemp(cluster: &ClusterTopology, c: &MoeLayerConfig) -> f64 {
    ops::expert_flops(c, ops::expert_tokens_per_rank(c, true))
        * ops::ffn_load_scale(c, c.t_pausemp())
        / cluster.min_flops(c.par.p)
}

/// Analytical `t_SP(r)`: the chunk-pipelined dispatch→compute→combine
/// region plus S1's MP-AllGather epilogue, at the bottleneck node.
///
/// The region is evaluated by a closed O(r) recurrence over the builder's
/// emission order (`D_0`, then per chunk k: `[D_{k+1}], F_k, C_k`): the
/// chunked AlltoAlls serialize on one comm stream, the chunked FFNs on one
/// compute stream, `F_k` waits for `D_k`, and `C_k` waits for `F_k` —
/// exactly the dependency structure the interpreter lowers, with each
/// chunk's AlltoAll costed by the same bottleneck model as [`a2a_pairwise`].
/// Unlike `t_D1`/`t_D2`, the result is compute-inclusive (the pipeline's
/// value is hiding communication behind the FFN), so compare it against
/// `t_D* + t_ffn_pausemp`.
pub fn t_sp(cluster: &ClusterTopology, c: &MoeLayerConfig, chunks: usize) -> f64 {
    let ag = ag_mp(
        cluster,
        c,
        ops::bytes_mp_ag_s1_per_rank(c) * c.par.n_mp as f64 * wire_factor(c, WireLeg::AllGather),
    );
    sp_pipeline(cluster, c, chunks, 1.0) + ag
}

/// The SP region alone (no AG epilogue) at the bottleneck node, with the
/// chunk FFNs scaled by `ffn_scale` — `1.0` for the forward pass, `2.0`
/// for backward (dgrad + wgrad), whose doubled compute is exactly what
/// makes pipelining pay off earlier there.
///
/// Evaluating the bottleneck node alone IS the fleet max: the chunk
/// AlltoAlls are global (identical for every node) and the pipeline
/// recurrence is monotone in the FFN durations, so the slowest-GPU node
/// dominates every other node's estimate.
pub fn sp_pipeline(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
    chunks: usize,
    ffn_scale: f64,
) -> f64 {
    sp_pipeline_on(cluster, c, chunks, ffn_scale, sp_bottleneck_node(cluster, c))
}

/// The SP region as one node experiences it: every chunk's AlltoAll is the
/// *global* collective (all ranks synchronize on it), but the chunk FFNs
/// run at this node's per-GPU throughput — on a mixed fleet a straggler
/// node's deeper compute makes more chunks worthwhile there.
pub fn sp_pipeline_on(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
    chunks: usize,
    ffn_scale: f64,
    node: usize,
) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    let world = groups.world();
    let cap = c.t_pausemp();
    let spans = ops::sp_spans(c, cap, ops::sp_clamp_chunks(c, chunks));
    let flops = cluster.node(node).gpu_flops;
    // The chunked AlltoAll is structurally symmetric, but its two
    // directions ride different wire legs, so each is priced at its own
    // compressed volume.
    let a2a_leg = |span: (usize, usize), leg: WireLeg| {
        a2a_pairwise(
            cluster,
            &world,
            ops::bytes_sp_chunk_per_pair(c, span.1) * wire_factor(c, leg),
        )
    };
    let dispatch = |span: (usize, usize)| a2a_leg(span, WireLeg::Dispatch);
    let combine = |span: (usize, usize)| a2a_leg(span, WireLeg::Combine);
    let ffn =
        |span: (usize, usize)| ffn_scale * ops::sp_chunk_flops_span(c, cap, span) / flops;
    pipeline_makespan_asym(&spans, dispatch, combine, ffn)
}

/// The ONE pipeline recurrence, over the builder's emission order (`D_0`,
/// then per chunk k: `[D_{k+1}], F_k, C_k`) — parameterized by per-chunk
/// comm/FFN cost functions over the full `(start, rows)` span (per-chunk
/// row counts AND offsets, so load-aware evaluators can weight each chunk
/// by its filled rows) so the α-β-constant evaluator ([`sp_pipeline`]) and
/// the fitted evaluator ([`crate::perfmodel::selection`]) cannot diverge
/// structurally.
pub fn pipeline_makespan(
    spans: &[(usize, usize)],
    comm: impl Fn((usize, usize)) -> f64,
    ffn: impl Fn((usize, usize)) -> f64,
) -> f64 {
    pipeline_makespan_asym(spans, &comm, &comm, ffn)
}

/// [`pipeline_makespan`] with *asymmetric* per-chunk communication costs:
/// `dispatch` prices chunk k's dispatch AlltoAll and `combine` its return
/// leg. SP uses one cost for both (the fused AlltoAll is symmetric); SP2's
/// combine leg is the chunked SAA — the AlltoAll plus its exposed
/// MP-AllGather tail — so the two directions genuinely differ there.
pub fn pipeline_makespan_asym(
    spans: &[(usize, usize)],
    dispatch: impl Fn((usize, usize)) -> f64,
    combine: impl Fn((usize, usize)) -> f64,
    ffn: impl Fn((usize, usize)) -> f64,
) -> f64 {
    let r = spans.len();
    if r == 0 {
        return 0.0;
    }
    let mut disp_done = vec![0.0f64; r];
    let mut comm_t = dispatch(spans[0]);
    disp_done[0] = comm_t;
    let mut comp_t = 0.0f64;
    for k in 0..r {
        if k + 1 < r {
            comm_t += dispatch(spans[k + 1]);
            disp_done[k + 1] = comm_t;
        }
        comp_t = comp_t.max(disp_done[k]) + ffn(spans[k]);
        comm_t = comm_t.max(comp_t) + combine(spans[k]);
    }
    comm_t.max(comp_t)
}

/// Analytical `t_SP2(r)`: the chunk-pipelined S2 region — dispatch,
/// compute and *chunked-SAA* combine — at the bottleneck node. Unlike
/// [`t_sp`] there is no AG epilogue: each chunk's SAA already forwards its
/// combine output into the MP-AllGather, so the only AllGather cost is the
/// per-chunk exposed tail ([`saa_exposed_fraction`]). At `r = 1` this is
/// exactly `t_D2 + t_FFN` — SP2(1) is S2's structure with the compute
/// term made explicit.
pub fn t_sp2(cluster: &ClusterTopology, c: &MoeLayerConfig, chunks: usize) -> f64 {
    sp2_pipeline(cluster, c, chunks, 1.0)
}

/// The SP2 region at the bottleneck node (see [`sp_pipeline`] for why one
/// node suffices).
pub fn sp2_pipeline(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
    chunks: usize,
    ffn_scale: f64,
) -> f64 {
    sp2_pipeline_on(cluster, c, chunks, ffn_scale, sp_bottleneck_node(cluster, c))
}

/// The SP2 region as one node experiences it: the chunk AlltoAlls and SAA
/// forwards are global collectives, the chunk FFNs run at this node's
/// throughput. Each chunk's combine leg is priced as its AlltoAll plus
/// the exposed fraction of its MP-AllGather slice (the chunk's share of
/// S2's AG volume, α included per chunk — phased forwards hide the rest
/// on the second link class).
pub fn sp2_pipeline_on(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
    chunks: usize,
    ffn_scale: f64,
    node: usize,
) -> f64 {
    let groups = ProcessGroups::new(c.par).expect("valid degrees");
    let world = groups.world();
    let cap = c.t_pausemp();
    let spans = ops::sp_spans(c, cap, ops::sp_clamp_chunks(c, chunks));
    let flops = cluster.node(node).gpu_flops;
    let frac = saa_exposed_fraction(cluster, &world);
    // The chunked SAA — AlltoAll and its AllGather forwards alike — rides
    // the combine leg, matching the interpreter's per-op leg assignment.
    let x_ag_full =
        ops::bytes_mp_ag_s2_per_rank(c) * c.par.n_mp as f64 * wire_factor(c, WireLeg::Combine);
    let a2a_leg = |span: (usize, usize), leg: WireLeg| {
        a2a_pairwise(
            cluster,
            &world,
            ops::bytes_sp_chunk_per_pair(c, span.1) * wire_factor(c, leg),
        )
    };
    let dispatch = |span: (usize, usize)| a2a_leg(span, WireLeg::Dispatch);
    let combine = |span: (usize, usize)| {
        let ag_chunk = ag_mp(cluster, c, x_ag_full * span.1 as f64 / cap.max(1) as f64);
        a2a_leg(span, WireLeg::Combine) + frac * ag_chunk
    };
    let ffn =
        |span: (usize, usize)| ffn_scale * ops::sp_chunk_flops_span(c, cap, span) / flops;
    pipeline_makespan_asym(&spans, &dispatch, &combine, ffn)
}

/// Per-iteration (fwd + bwd) SP2 estimate at one node: the forward
/// chunked-SAA pipeline, then the true backward — an up-front
/// MP-ReduceScatter (the adjoint of the aggregated SAA AllGather
/// forwards), the transposed region with **plain** per-chunk AlltoAlls
/// at 2× compute (structurally an SP region — the backward has no SAA),
/// the adjoint-of-split MP-AllGather, and the exposed share of the wgrad
/// AllReduce deferred across that AllGather.
pub fn t_sp2_iteration_on(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
    chunks: usize,
    node: usize,
) -> f64 {
    let ag = ag_mp(
        cluster,
        c,
        ops::bytes_mp_ag_s2_per_rank(c) * c.par.n_mp as f64 * wire_factor(c, WireLeg::AllGather),
    );
    sp2_pipeline_on(cluster, c, chunks, 1.0, node)
        + sp_pipeline_on(cluster, c, chunks, 2.0, node)
        + 2.0 * ag
        + exposed_wgrad_ar(t_wgrad_ar(cluster, c), ag)
}

/// [`t_sp2_iteration_on`] at the bottleneck node.
pub fn t_sp2_iteration(cluster: &ClusterTopology, c: &MoeLayerConfig, chunks: usize) -> f64 {
    t_sp2_iteration_on(cluster, c, chunks, sp_bottleneck_node(cluster, c))
}

/// Closed-form optimal SP2 chunk count for the fleet: argmin of
/// [`t_sp2_iteration`] over `1..=SP_MAX_CHUNKS`. Returns
/// `(r*, t_SP2_iter(r*))`.
pub fn optimal_chunks_sp2(cluster: &ClusterTopology, c: &MoeLayerConfig) -> (usize, f64) {
    argmin_chunks(c, |r| t_sp2_iteration(cluster, c, r))
}

/// Per-node optimal SP2 chunk count — the `*_on` variant of
/// [`optimal_chunks_sp2`], mirroring [`optimal_chunks_on`].
pub fn optimal_chunks_sp2_on(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
    node: usize,
) -> (usize, f64) {
    argmin_chunks(c, |r| t_sp2_iteration_on(cluster, c, r, node))
}

/// Per-iteration (fwd + bwd) SP estimate at one node: that node's forward
/// pipeline and AG epilogue, then the true backward — the MP-ReduceScatter
/// prologue (ring RS costs exactly what ring AG does), the transposed
/// region at 2× compute (dgrad + wgrad), the adjoint-of-split
/// MP-AllGather, and the exposed share of the wgrad AllReduce deferred
/// across that AllGather.
pub fn t_sp_iteration_on(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
    chunks: usize,
    node: usize,
) -> f64 {
    let ag = ag_mp(
        cluster,
        c,
        ops::bytes_mp_ag_s1_per_rank(c) * c.par.n_mp as f64 * wire_factor(c, WireLeg::AllGather),
    );
    sp_pipeline_on(cluster, c, chunks, 1.0, node)
        + sp_pipeline_on(cluster, c, chunks, 2.0, node)
        + 3.0 * ag
        + exposed_wgrad_ar(t_wgrad_ar(cluster, c), ag)
}

/// [`t_sp_iteration_on`] at the bottleneck node — the fleet-level
/// per-iteration SP estimate (see [`sp_pipeline`] for why one node
/// suffices).
pub fn t_sp_iteration(cluster: &ClusterTopology, c: &MoeLayerConfig, chunks: usize) -> f64 {
    t_sp_iteration_on(cluster, c, chunks, sp_bottleneck_node(cluster, c))
}

/// Argmin of a per-iteration SP estimate over the representable chunk
/// counts `1..=sp_clamp_chunks(c, SP_MAX_CHUNKS)` — the ONE chunk-search
/// loop, shared by the α-β-constant and fitted evaluators.
pub fn argmin_chunks(c: &MoeLayerConfig, estimate: impl Fn(usize) -> f64) -> (usize, f64) {
    let max_r = ops::sp_clamp_chunks(c, crate::comm::tags::SP_MAX_CHUNKS);
    let mut best = (1usize, estimate(1));
    for r in 2..=max_r {
        let t = estimate(r);
        if t < best.1 {
            best = (r, t);
        }
    }
    best
}

/// The ONE generalized Algorithm-1 decision rule, over per-iteration
/// estimates for S1, S2, SP(r*) and SP2(r*): a pipelined family wins only
/// when strictly better than every unchunked candidate and genuinely
/// pipelined (r* > 1 — SP(1)/SP2(1) are S1/S2's structures with no
/// overlap); among the two pipelined winners the faster takes it, SP on a
/// tie; otherwise the paper's t1 ≤ t2 tie-break. Shared by the
/// closed-form and fitted selectors so they cannot diverge.
pub fn decide(
    t1: f64,
    t2: f64,
    r_sp: usize,
    t_sp_iter: f64,
    r_sp2: usize,
    t_sp2_iter: f64,
) -> (crate::schedule::ScheduleKind, f64) {
    use crate::schedule::ScheduleKind;
    let mut best = if t1 <= t2 { (ScheduleKind::S1, t1) } else { (ScheduleKind::S2, t2) };
    if r_sp > 1 && t_sp_iter < best.1 {
        best = (ScheduleKind::Pipelined { chunks: r_sp }, t_sp_iter);
    }
    if r_sp2 > 1 && t_sp2_iter < best.1 {
        best = (ScheduleKind::PipelinedS2 { chunks: r_sp2 }, t_sp2_iter);
    }
    best
}

/// Closed-form optimal chunk count for the fleet: argmin of
/// [`t_sp_iteration`] (bottleneck-node estimate) over `1..=SP_MAX_CHUNKS`
/// — the objective is per-iteration time, since the backward pass's
/// doubled compute shifts the optimum relative to forward-only. Returns
/// `(r*, t_SP_iter(r*))`.
pub fn optimal_chunks(cluster: &ClusterTopology, c: &MoeLayerConfig) -> (usize, f64) {
    argmin_chunks(c, |r| t_sp_iteration(cluster, c, r))
}

/// Per-node optimal chunk count: what r* would be if `node`'s compute
/// throughput paced the whole pipeline. On a homogeneous fleet every node
/// returns [`optimal_chunks`]; on a mixed fleet a straggler node's deeper
/// effective compute typically wants more chunks.
pub fn optimal_chunks_on(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
    node: usize,
) -> (usize, f64) {
    argmin_chunks(c, |r| t_sp_iteration_on(cluster, c, r, node))
}

/// The straggler node that paces the fleet: the first slowest-GPU node
/// among the layer's nodes (node 0 on a homogeneous cluster). Because
/// communication terms are global, this node maximizes every per-node
/// compute-inclusive estimate (when compute is fully hidden the
/// estimates tie and the choice is nominal).
pub fn sp_bottleneck_node(cluster: &ClusterTopology, c: &MoeLayerConfig) -> usize {
    let mut best = (0usize, f64::INFINITY);
    for n in cluster.nodes_for(c.par.p) {
        let flops = cluster.node(n).gpu_flops;
        if flops < best.1 {
            best = (n, flops);
        }
    }
    best.0
}

/// Algorithm 1 generalized (closed-form): [`decide`] over fleet-level
/// **full-iteration** estimates — the true per-family backward terms
/// ([`t_iter_s1`], [`t_iter_s2`], and the SP/SP2 iteration forms with
/// their exposed wgrad-AllReduce shares) replace the former
/// `2·t_D* + 3·t_FFN` doubling heuristic. Returns the pick and its
/// estimated per-iteration time.
pub fn choose_extended(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
) -> (crate::schedule::ScheduleKind, f64) {
    let t1 = t_iter_s1(cluster, c);
    let t2 = t_iter_s2(cluster, c);
    let (r, tsp) = optimal_chunks(cluster, c);
    let (r2, tsp2) = optimal_chunks_sp2(cluster, c);
    decide(t1, t2, r, tsp, r2, tsp2)
}

/// Algorithm 1 as one node would run it: same communication terms (the
/// collectives are global), that node's compute. On a mixed fleet the
/// pick can genuinely differ per node — e.g. a straggler node's higher
/// compute share makes SP(r) win where the fast nodes' pick is S1.
pub fn choose_extended_on(
    cluster: &ClusterTopology,
    c: &MoeLayerConfig,
    node: usize,
) -> (crate::schedule::ScheduleKind, f64) {
    let t1 = t_iter_s1_on(cluster, c, node);
    let t2 = t_iter_s2_on(cluster, c, node);
    let (r, tsp) = optimal_chunks_on(cluster, c, node);
    let (r2, tsp2) = optimal_chunks_sp2_on(cluster, c, node);
    decide(t1, t2, r, tsp, r2, tsp2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::cluster::NodeSpec;
    use crate::config::moe::ParallelDegrees;
    use crate::perfmodel::fit::{measure_collective, CollKind};
    use crate::schedule::{lowering, ScheduleKind};

    fn par() -> ParallelDegrees {
        ParallelDegrees { p: 32, n_mp: 4, n_esp: 4 }
    }

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig {
            par: par(),
            b: 4,
            l: 1024,
            e: 8,
            m: 1024,
            h: 2048,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        }
    }

    #[test]
    fn ag_matches_simulator() {
        let cluster = ClusterTopology::testbed_b();
        for x in [1e6, 1e7, 6e7] {
            let sim = measure_collective(&cluster, par(), CollKind::AgMp, x).unwrap();
            let cf = ag_ring(&cluster, &[0, 1, 2, 3], x);
            let rel = (sim - cf).abs() / sim;
            assert!(rel < 0.02, "x={x}: sim {sim} vs closed-form {cf}");
        }
    }

    #[test]
    fn ar_matches_simulator() {
        let cluster = ClusterTopology::testbed_b();
        for x in [1e6, 1e7] {
            let sim = measure_collective(&cluster, par(), CollKind::ArEsp, x).unwrap();
            let cf = ar_ring(&cluster, &[0, 1, 2, 3], x);
            let rel = (sim - cf).abs() / sim;
            assert!(rel < 0.05, "x={x}: sim {sim} vs closed-form {cf}");
        }
    }

    #[test]
    fn a2a_matches_simulator() {
        // Fused AlltoAll over the full 32-rank world (8 nodes × 4).
        let cluster = ClusterTopology::testbed_b();
        let groups = ProcessGroups::new(par()).unwrap();
        let world = groups.world();
        for x in [1e6, 1e7, 6e7] {
            let sim = measure_collective(&cluster, par(), CollKind::A2aFused, x).unwrap();
            let cf = a2a_pairwise(&cluster, &world, x / 32.0);
            let rel = (sim - cf).abs() / sim;
            assert!(rel < 0.15, "x={x}: sim {sim} vs closed-form {cf} (rel {rel})");
        }
    }

    #[test]
    fn closed_form_ranks_schedules_like_simulator() {
        let cluster = ClusterTopology::testbed_b();
        let c = cfg();
        // Closed forms are forward-comm only; the simulator runs fwd+bwd
        // with compute. Compare *ratios*, which is what Algorithm 1 uses.
        let cf_gain = t_baseline(&cluster, &c) / t_d1(&cluster, &c);
        let t_base =
            lowering::simulate_iteration(ScheduleKind::Baseline, &c, &cluster).unwrap().makespan;
        let t_s1 = lowering::simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
        let sim_gain = t_base / t_s1;
        let rel = (cf_gain - sim_gain).abs() / sim_gain;
        assert!(
            rel < 0.35,
            "closed-form speedup {cf_gain:.2} vs simulated {sim_gain:.2}"
        );
        assert!(cf_gain > 1.0 && sim_gain > 1.0);
    }

    #[test]
    fn closed_form_choice_tracks_capacity_extremes() {
        // §IV-B: T → 0 favors S2, T → ∞ favors S1 — same flip the fitted
        // selector shows, now derivable with zero measurements.
        let cluster = ClusterTopology::testbed_b();
        let mut tiny = cfg();
        tiny.f = 0.01;
        let mut huge = cfg();
        huge.f = 64.0;
        assert_eq!(choose(&cluster, &tiny), ScheduleKind::S2);
        assert_eq!(choose(&cluster, &huge), ScheduleKind::S1);
    }

    #[test]
    fn degenerate_groups_cost_nothing() {
        let cluster = ClusterTopology::testbed_b();
        assert_eq!(ag_ring(&cluster, &[3], 1e9), 0.0);
        assert_eq!(ar_ring(&cluster, &[3], 1e9), 0.0);
        assert_eq!(a2a_pairwise(&cluster, &[3], 1e9), 0.0);
    }

    #[test]
    fn t_sp_with_one_chunk_equals_t_d1_plus_ffn() {
        // SP(1) = dispatch, FFN, combine, AG — exactly Eq. 13's structure
        // with the compute term made explicit.
        let cluster = ClusterTopology::testbed_b();
        let c = cfg();
        let lhs = t_sp(&cluster, &c, 1);
        let rhs = t_d1(&cluster, &c) + t_ffn_pausemp(&cluster, &c);
        assert!((lhs - rhs).abs() / rhs < 1e-12, "{lhs} vs {rhs}");
    }

    #[test]
    fn t_sp2_with_one_chunk_equals_t_d2_plus_ffn() {
        // SP2(1) = dispatch, FFN, SAA combine — exactly Eq. 14's structure
        // with the compute term made explicit (the exposed-AG assumption
        // is shared through `saa_exposed_fraction`).
        let cluster = ClusterTopology::testbed_b();
        let c = cfg();
        let lhs = t_sp2(&cluster, &c, 1);
        let rhs = t_d2(&cluster, &c) + t_ffn_pausemp(&cluster, &c);
        assert!((lhs - rhs).abs() / rhs < 1e-12, "{lhs} vs {rhs}");
    }

    #[test]
    fn backward_terms_extend_the_forward_forms() {
        let cluster = ClusterTopology::testbed_b();
        let c = cfg();
        let f = t_ffn_pausemp(&cluster, &c);
        let ar = t_wgrad_ar(&cluster, &c);
        assert!(ar > 0.0, "N_ESP > 1 must cost a wgrad AllReduce");
        // Overlap clamps the exposure to the excess over the hiding tail.
        assert_eq!(exposed_wgrad_ar(ar, ar + 1.0), 0.0);
        assert!((exposed_wgrad_ar(2.0 * ar, ar) - ar).abs() <= 1e-15 * ar);
        // The true backward is never cheaper than the old double-the-
        // forward heuristic's backward half: it adds the adjoint-of-split
        // AllGather and the exposed AR on top of mirrored comm + 2×FFN.
        assert!(t_bwd_d1(&cluster, &c) >= t_d1(&cluster, &c) + 2.0 * f);
        assert!(t_bwd_d2(&cluster, &c) >= t_d2(&cluster, &c) + 2.0 * f);
        // And the iteration forms decompose exactly as fwd + bwd.
        assert_eq!(t_iter_s1(&cluster, &c), t_d1(&cluster, &c) + f + t_bwd_d1(&cluster, &c));
        assert_eq!(t_iter_s2(&cluster, &c), t_d2(&cluster, &c) + f + t_bwd_d2(&cluster, &c));
    }

    #[test]
    fn wgrad_ar_exposure_is_chunk_invariant_for_sp() {
        // The SP iteration's AR exposure does not depend on r (the AR
        // launches after the region either way), so it shifts every
        // t_SP(r) equally and cannot move the argmin.
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let c = MoeLayerConfig {
            par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
            ..cfg()
        };
        let ag = ag_ring(
            &cluster,
            &ProcessGroups::new(c.par).unwrap().all_groups(GroupKind::Mp)[0],
            ops::bytes_mp_ag_s1_per_rank(&c) * c.par.n_mp as f64,
        );
        let exposed = exposed_wgrad_ar(t_wgrad_ar(&cluster, &c), ag);
        for r in [1usize, 2, 4] {
            let with = t_sp_iteration(&cluster, &c, r);
            let without = sp_pipeline(&cluster, &c, r, 1.0)
                + sp_pipeline(&cluster, &c, r, 2.0)
                + 3.0 * ag_mp(&cluster, &c, ops::bytes_mp_ag_s1_per_rank(&c) * c.par.n_mp as f64);
            assert!((with - without - exposed).abs() <= 1e-12 * with, "r={r}");
        }
    }

    #[test]
    fn sp2_per_node_terms_reduce_on_homogeneous_fleet() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let c = MoeLayerConfig {
            par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
            ..cfg()
        };
        let fleet = (t_sp2_iteration(&cluster, &c, 3), optimal_chunks_sp2(&cluster, &c));
        for node in cluster.nodes_for(8) {
            assert_eq!(t_sp2_iteration_on(&cluster, &c, 3, node), fleet.0);
            assert_eq!(optimal_chunks_sp2_on(&cluster, &c, node), fleet.1);
        }
        // The SP2 iteration argmin never exceeds SP2(1) = t_D2-structured.
        let (r2, t2) = optimal_chunks_sp2(&cluster, &c);
        assert!(r2 >= 1 && r2 <= crate::comm::tags::SP_MAX_CHUNKS);
        assert!(t2 <= t_sp2_iteration(&cluster, &c, 1) + 1e-12);
    }

    #[test]
    fn chunk_choice_tracks_compute_intensity() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        // Compute-heavy: huge expert hidden size ⇒ pipelining pays, r* > 1
        // and the extended Algorithm 1 picks SP.
        let heavy = MoeLayerConfig {
            par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
            b: 8,
            l: 2048,
            e: 4,
            m: 1024,
            h: 32768,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        };
        let (r_heavy, t_heavy) = optimal_chunks(&cluster, &heavy);
        assert!(r_heavy > 1, "compute-heavy config should pipeline, got r={r_heavy}");
        assert!(t_heavy < t_sp_iteration(&cluster, &heavy, 1));
        // With SP2 in the candidate set the pick may be either pipelined
        // family — what matters here is that a chunked schedule wins.
        let (pick, _) = choose_extended(&cluster, &heavy);
        assert!(
            matches!(
                pick,
                ScheduleKind::Pipelined { chunks } if chunks == r_heavy
            ) || matches!(pick, ScheduleKind::PipelinedS2 { chunks } if chunks > 1),
            "expected a pipelined pick, got {pick:?}"
        );

        // Comm-heavy with tiny FFN: the per-chunk α overhead dominates any
        // overlap, r* = 1, and the pick falls back to S1/S2.
        let light = MoeLayerConfig {
            par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
            b: 2,
            l: 256,
            e: 4,
            m: 1024,
            h: 1024,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        };
        let (r_light, _) = optimal_chunks(&cluster, &light);
        assert_eq!(r_light, 1, "comm-heavy config should not pipeline");
        let (pick, _) = choose_extended(&cluster, &light);
        assert!(
            !matches!(
                pick,
                ScheduleKind::Pipelined { .. } | ScheduleKind::PipelinedS2 { .. }
            ),
            "got {pick:?}"
        );
    }

    #[test]
    fn bf16_wire_flips_the_algorithm1_pick_with_sim_agreement() {
        // The acceptance bracket for wire precision as a decision axis:
        // narrowing every leg to bf16 halves the β-dominated communication
        // terms while the FFN term stands still, so somewhere on a
        // capacity/hidden-size bracket Algorithm 1's pick (or its r*)
        // must move — and the discrete-event simulator, whose timing
        // plane prices the same compressed lumps, must agree the
        // re-decided schedule is strictly faster on the bf16 config than
        // the f32-wire pick would have been.
        use crate::config::{WireDtype, WirePrecision};
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let base = MoeLayerConfig {
            par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
            ..cfg()
        };
        let mut found: Option<(String, ScheduleKind, ScheduleKind, f64, f64)> = None;
        'outer: for h in [2048usize, 4096, 8192, 16384, 32768] {
            for l in [512usize, 1024, 2048] {
                let mut c32 = base.clone();
                c32.h = h;
                c32.l = l;
                let mut c16 = c32.clone();
                c16.wire = WirePrecision::uniform(WireDtype::Bf16);
                let (pick32, _) = choose_extended(&cluster, &c32);
                let (pick16, _) = choose_extended(&cluster, &c16);
                if pick32 == pick16 {
                    continue;
                }
                // Both schedules simulated ON the bf16 config: the wire
                // pick must win where the decision actually applies.
                let t16 = lowering::simulate_iteration(pick16, &c16, &cluster)
                    .unwrap()
                    .makespan;
                let t32 = lowering::simulate_iteration(pick32, &c16, &cluster)
                    .unwrap()
                    .makespan;
                if t16 < t32 {
                    found = Some((c16.id(), pick32, pick16, t16, t32));
                    break 'outer;
                }
            }
        }
        let (id, pick32, pick16, t16, t32) = found.expect(
            "no pinned config where bf16 wire moves the Algorithm-1 pick (or r*) \
             with the simulator confirming the re-decided schedule wins",
        );
        eprintln!(
            "bf16 wire re-decides at {id}: {} → {} ({t16:.6}s vs {t32:.6}s)",
            pick32.label(),
            pick16.label()
        );
        assert!(t16 < t32);
    }

    #[test]
    fn wire_factors_scale_the_closed_forms_consistently() {
        // Sanity on the factored terms: a uniform bf16 policy prices every
        // pure-communication closed form strictly cheaper, and never below
        // half (each collective's volume scales by 1/2; the per-step α
        // latency does not shrink). SP(1)/SP2(1) keep their structural
        // identities at any policy because both sides share the factored
        // volumes.
        use crate::config::{WireDtype, WirePrecision};
        let cluster = ClusterTopology::testbed_b();
        let c32 = cfg();
        let mut c16 = cfg();
        c16.wire = WirePrecision::uniform(WireDtype::Bf16);
        // Communication-only forms shrink, and never below half.
        for (f32_t, bf16_t) in [
            (t_baseline(&cluster, &c32), t_baseline(&cluster, &c16)),
            (t_d1(&cluster, &c32), t_d1(&cluster, &c16)),
            (t_d2(&cluster, &c32), t_d2(&cluster, &c16)),
            (t_wgrad_ar(&cluster, &c32), t_wgrad_ar(&cluster, &c16)),
        ] {
            assert!(bf16_t < f32_t, "{bf16_t} !< {f32_t}");
            assert!(bf16_t >= 0.5 * f32_t - 1e-15, "{bf16_t} below half of {f32_t}");
        }
        // A mixed policy only touches its own legs: narrowing wgrad alone
        // moves t_wgrad_ar and nothing forward-side.
        let mut cw = cfg();
        cw.wire = WirePrecision::default().with_leg(WireLeg::Wgrad, WireDtype::Fp8);
        assert_eq!(t_d1(&cluster, &cw), t_d1(&cluster, &c32));
        assert_eq!(t_d2(&cluster, &cw), t_d2(&cluster, &c32));
        assert_eq!(t_baseline(&cluster, &cw), t_baseline(&cluster, &c32));
        assert!(t_wgrad_ar(&cluster, &cw) < t_wgrad_ar(&cluster, &c32));
        // The SP(1)/SP2(1) identities hold at reduced precision too.
        for c in [&c16, &cw] {
            let lhs = t_sp(&cluster, c, 1);
            let rhs = t_d1(&cluster, c) + t_ffn_pausemp(&cluster, c);
            assert!((lhs - rhs).abs() / rhs < 1e-12, "SP(1): {lhs} vs {rhs}");
            let lhs2 = t_sp2(&cluster, c, 1);
            let rhs2 = t_d2(&cluster, c) + t_ffn_pausemp(&cluster, c);
            assert!((lhs2 - rhs2).abs() / rhs2 < 1e-12, "SP2(1): {lhs2} vs {rhs2}");
        }
    }

    /// testbed-B-subset(8)'s shape with node 1 slowed down by `factor`.
    fn hetero_b8(factor: f64) -> ClusterTopology {
        let homo = ClusterTopology::testbed_b_subset(8).unwrap();
        let fast = homo.node_specs()[0];
        let slow = NodeSpec { gpu_flops: fast.gpu_flops / factor, ..fast };
        ClusterTopology::new("testbed_b_8gpu_hetero", vec![fast, slow]).unwrap()
    }

    #[test]
    fn per_node_terms_reduce_to_fleet_terms_when_homogeneous() {
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let c = MoeLayerConfig {
            par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
            ..cfg()
        };
        for node in cluster.nodes_for(8) {
            assert_eq!(t_ffn_pausemp_on(&cluster, &c, node), t_ffn_pausemp(&cluster, &c));
            assert_eq!(
                t_sp_iteration_on(&cluster, &c, 3, node),
                t_sp_iteration(&cluster, &c, 3)
            );
            assert_eq!(optimal_chunks_on(&cluster, &c, node), optimal_chunks(&cluster, &c));
        }
        assert_eq!(sp_bottleneck_node(&cluster, &c), 0);
    }

    #[test]
    fn straggler_node_dominates_fleet_estimates() {
        let het = hetero_b8(4.0);
        // Compute-heavy shape: the FFN term is on the critical path, so a
        // straggler node's slower compute must show up in the estimate.
        let c = MoeLayerConfig {
            par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
            b: 8,
            l: 2048,
            e: 4,
            m: 1024,
            h: 32768,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        };
        // The fleet estimate equals the slow node's, exceeds the fast one's.
        let fast = t_sp_iteration_on(&het, &c, 2, 0);
        let slow = t_sp_iteration_on(&het, &c, 2, 1);
        assert!(slow > fast);
        assert_eq!(t_sp_iteration(&het, &c, 2), slow);
        assert_eq!(sp_bottleneck_node(&het, &c), 1);
        // And the fast node's view equals the homogeneous cluster's.
        let homo = ClusterTopology::testbed_b_subset(8).unwrap();
        assert_eq!(fast, t_sp_iteration(&homo, &c, 2));
    }
}
