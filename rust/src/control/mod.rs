//! Online adaptive control plane: hysteresis-driven schedule switching
//! over a drifting-traffic trace.
//!
//! Algorithm 1 picks one schedule per static configuration; this module
//! makes that decision *online*. [`drive`] walks an N-iteration simulated
//! run from a [`TraceSpec`]: each step it re-spans the chunk-pipelined
//! schedules from the **previous** step's measured expert loads
//! (amortizing away the second gate pass — the spans are ready before the
//! step starts), computes a total-variation [`drift`] between the latest
//! loads and the loads at the last decision, and only when that drift
//! crosses the [`Hysteresis`] band re-runs Algorithm 1 with warm fits
//! ([`predict_with_loads`] — no collective re-measurement) and switches
//! schedule mid-run. A switch is charged `switch_frac × t_iter` (regroup
//! barriers, buffer re-registration), so the controller cannot flap for
//! free; `threshold = 0` degrades to re-deciding every step — the
//! ablation that shows why the band exists.
//!
//! The outcome carries a per-step decision log (step, loads digest,
//! drift, pick, simulated iteration time) in a byte-stable text form —
//! two runs with the same seed, trace, and cluster produce identical
//! logs at any `threads` count, because every randomized input comes
//! from stateless per-step streams and the only parallelism (the static
//! baselines) merges results by index. `online vs. every-static-choice`
//! totals quantify the win: the statics run the same trace with the same
//! measured FLOP pricing but expected (capacity) spans, so the online
//! margin is pure adaptivity, not accounting.

use anyhow::Result;

use crate::config::trace::TraceSpec;
use crate::config::{ClusterTopology, MoeLayerConfig};
use crate::perfmodel::selection::{predict_with_loads, Prediction};
use crate::perfmodel::PerfModel;
use crate::schedule::lowering::simulate_iteration_traffic_with_dag;
use crate::schedule::ops::ScheduleKind;
use crate::traffic::{self, TrafficStep};
use crate::util::hash::fnv64_hex;
use crate::util::json::Json;

/// Total-variation distance `½·Σ|p̂−q̂|` between two load vectors viewed
/// as distributions (each normalized by its own mass). Symmetric and
/// bounded in `[0, 1]`; an all-zero vector (a step that routed nothing)
/// is read as the uniform distribution so comparisons stay defined.
pub fn drift(p: &[usize], q: &[usize]) -> f64 {
    let n = p.len().max(q.len());
    if n == 0 {
        return 0.0;
    }
    let norm = |v: &[usize]| -> Vec<f64> {
        let total: usize = v.iter().sum();
        match total {
            0 => vec![1.0 / n as f64; n],
            t => (0..n).map(|i| v.get(i).copied().unwrap_or(0) as f64 / t as f64).collect(),
        }
    };
    let (pn, qn) = (norm(p), norm(q));
    0.5 * pn.iter().zip(&qn).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

/// The decision band: re-run Algorithm 1 only when the load distribution
/// has drifted at least `threshold` (total variation) from the
/// distribution anchored at the last decision. The first observation
/// always decides (there is nothing to be anchored to yet), and
/// `threshold = 0` decides every step.
#[derive(Debug, Clone)]
pub struct Hysteresis {
    pub threshold: f64,
    anchor: Option<Vec<usize>>,
}

impl Hysteresis {
    pub fn new(threshold: f64) -> Hysteresis {
        Hysteresis { threshold, anchor: None }
    }

    /// Feed the latest measured loads; returns `(redecide, drift)` where
    /// `drift` is measured against the anchor. On `redecide` the anchor
    /// moves to `loads`.
    pub fn observe(&mut self, loads: &[usize]) -> (bool, f64) {
        match &self.anchor {
            None => {
                self.anchor = Some(loads.to_vec());
                (true, 0.0)
            }
            Some(anchor) => {
                let d = drift(loads, anchor);
                if d >= self.threshold {
                    self.anchor = Some(loads.to_vec());
                    (true, d)
                } else {
                    (false, d)
                }
            }
        }
    }
}

/// Knobs for one [`drive`] run.
#[derive(Debug, Clone, Copy)]
pub struct DriveOptions {
    /// Hysteresis band (total-variation units); 0 re-decides every step.
    pub threshold: f64,
    /// Switch cost as a fraction of the switching step's iteration time.
    pub switch_frac: f64,
    /// Worker threads for the static baselines (the online loop is
    /// inherently sequential). Any value produces identical output.
    pub threads: usize,
    /// Override for the trace's own seed (CLI `--seed` wins over spec).
    pub seed: Option<u64>,
}

impl Default for DriveOptions {
    fn default() -> DriveOptions {
        DriveOptions { threshold: 0.25, switch_frac: 0.5, threads: 1, seed: None }
    }
}

/// One row of the decision log.
#[derive(Debug, Clone)]
pub struct StepDecision {
    pub step: usize,
    /// FNV-1a digest of this step's measured loads (the trace's output,
    /// available to the controller only from the *next* step on).
    pub loads_digest: String,
    /// Drift of the previous step's loads against the hysteresis anchor
    /// (0 at step 0, where nothing has been measured yet).
    pub drift: f64,
    /// Did Algorithm 1 re-run this step?
    pub redecided: bool,
    /// Did the schedule actually change?
    pub switched: bool,
    /// Were the chunk spans rebuilt from measured loads (a chunked
    /// schedule running on a step with usable previous-step loads)?
    pub respan: bool,
    pub kind: ScheduleKind,
    /// Simulated iteration time of this step under `kind`.
    pub t_iter: f64,
    /// Charged switch cost (0 unless `switched`).
    pub switch_cost: f64,
}

/// Everything one [`drive`] run produced.
#[derive(Debug, Clone)]
pub struct DriveOutcome {
    pub trace_name: String,
    pub seed: u64,
    pub threshold: f64,
    pub switch_frac: f64,
    pub cfg_id: String,
    pub cluster_name: String,
    pub steps: Vec<StepDecision>,
    /// Total simulated time of each static candidate over the same trace
    /// (same jittered clusters, same measured FLOP pricing, expected
    /// spans, no switch costs).
    pub statics: Vec<(ScheduleKind, f64)>,
    /// Online total including switch costs.
    pub online_total: f64,
    pub switches: usize,
    pub redecisions: usize,
}

impl DriveOutcome {
    /// The best single static (schedule, span) choice — the bar the
    /// online controller has to clear.
    pub fn best_static(&self) -> (ScheduleKind, f64) {
        self.statics
            .iter()
            .cloned()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("drive ran with at least one static candidate")
    }

    /// Byte-stable per-step decision log (the golden/CI artifact). Fixed
    /// float widths, no ambient state: identical runs render identically.
    pub fn decision_log(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "# parm drive trace={} seed={} threshold={:.6} switch_frac={:.6} cfg={} cluster={}\n",
            self.trace_name, self.seed, self.threshold, self.switch_frac, self.cfg_id,
            self.cluster_name
        ));
        for d in &self.steps {
            out.push_str(&format!(
                "step={} digest={} drift={:.6} redecide={} switch={} respan={} pick={} \
                 t_iter={:.9e} cost={:.9e}\n",
                d.step,
                d.loads_digest,
                d.drift,
                d.redecided as u8,
                d.switched as u8,
                d.respan as u8,
                d.kind.label(),
                d.t_iter,
                d.switch_cost
            ));
        }
        for (kind, total) in &self.statics {
            out.push_str(&format!("static pick={} total={:.9e}\n", kind.label(), total));
        }
        let (bk, bt) = self.best_static();
        out.push_str(&format!(
            "online total={:.9e} switches={} redecisions={} best_static={} \
             best_static_total={:.9e}\n",
            self.online_total,
            self.switches,
            self.redecisions,
            bk.label(),
            bt
        ));
        out
    }

    /// JSON form for `--json` and the bench summary.
    pub fn to_json(&self) -> Json {
        let steps = self
            .steps
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("step", Json::num(d.step as f64)),
                    ("digest", Json::str(&d.loads_digest)),
                    ("drift", Json::num(d.drift)),
                    ("redecided", Json::Bool(d.redecided)),
                    ("switched", Json::Bool(d.switched)),
                    ("respan", Json::Bool(d.respan)),
                    ("pick", Json::str(&d.kind.label())),
                    ("t_iter", Json::num(d.t_iter)),
                    ("switch_cost", Json::num(d.switch_cost)),
                ])
            })
            .collect::<Vec<_>>();
        let statics = self
            .statics
            .iter()
            .map(|(k, t)| {
                Json::obj(vec![("pick", Json::str(&k.label())), ("total", Json::num(*t))])
            })
            .collect::<Vec<_>>();
        let (bk, bt) = self.best_static();
        Json::obj(vec![
            ("trace", Json::str(&self.trace_name)),
            ("seed", Json::num(self.seed as f64)),
            ("threshold", Json::num(self.threshold)),
            ("switch_frac", Json::num(self.switch_frac)),
            ("cfg", Json::str(&self.cfg_id)),
            ("cluster", Json::str(&self.cluster_name)),
            ("steps", Json::Arr(steps)),
            ("statics", Json::Arr(statics)),
            ("online_total", Json::num(self.online_total)),
            ("switches", Json::num(self.switches as f64)),
            ("redecisions", Json::num(self.redecisions as f64)),
            ("best_static", Json::str(&bk.label())),
            ("best_static_total", Json::num(bt)),
            ("online_speedup", Json::num(bt / self.online_total)),
        ])
    }
}

/// The static candidate set the drive compares against: the unchunked
/// family plus the pipelined members at the chunk counts Algorithm 1
/// chose from the expected profile.
pub fn default_candidates(pred: &Prediction) -> Vec<ScheduleKind> {
    vec![
        ScheduleKind::Baseline,
        ScheduleKind::S1,
        ScheduleKind::S2,
        ScheduleKind::Pipelined { chunks: pred.sp_chunks },
        ScheduleKind::PipelinedUniform { chunks: pred.sp_chunks },
        ScheduleKind::PipelinedS2 { chunks: pred.sp2_chunks },
    ]
}

fn is_chunked(kind: ScheduleKind) -> bool {
    matches!(
        kind,
        ScheduleKind::Pipelined { .. } | ScheduleKind::PipelinedS2 { .. } | ScheduleKind::Parm
    )
}

fn digest_loads(loads: &[usize]) -> String {
    let joined = loads.iter().map(|l| l.to_string()).collect::<Vec<_>>().join(",");
    fnv64_hex(&[&joined])
}

/// Run the online controller over a trace. See the module docs for the
/// loop structure; `model` must be fitted for `cfg.par` on `base` (the
/// warm fits re-decisions reuse — pass a plan-loaded model to skip
/// fitting entirely).
pub fn drive(
    spec: &TraceSpec,
    cfg: &MoeLayerConfig,
    base: &ClusterTopology,
    model: &PerfModel,
    candidates: &[ScheduleKind],
    opts: &DriveOptions,
) -> Result<DriveOutcome> {
    let mut spec = spec.clone();
    if let Some(seed) = opts.seed {
        spec.seed = seed;
    }
    let steps = traffic::materialize(&spec, cfg, base)?;

    // ---- online loop (sequential: step t needs step t-1's measurement).
    let mut current = predict_with_loads(model, cfg, None).best();
    let mut hyst = Hysteresis::new(opts.threshold);
    let mut decisions = Vec::with_capacity(steps.len());
    let mut online_total = 0.0;
    let mut switches = 0;
    let mut redecisions = 0;
    let mut prev: Option<&[usize]> = None;
    for (t, st) in steps.iter().enumerate() {
        let (redecided, drift_v) = match prev {
            None => (false, 0.0),
            Some(loads) => hyst.observe(loads),
        };
        let mut switched = false;
        if redecided {
            let pick = predict_with_loads(model, cfg, prev).best();
            redecisions += 1;
            if pick != current {
                current = pick;
                switched = true;
                switches += 1;
            }
        }
        let usable_prev = prev.is_some_and(|l| l.iter().sum::<usize>() > 0);
        let respan = usable_prev && is_chunked(current);
        let (report, _) =
            simulate_iteration_traffic_with_dag(current, cfg, &st.cluster, prev, Some(&st.loads))?;
        let t_iter = report.makespan;
        let switch_cost = if switched { opts.switch_frac * t_iter } else { 0.0 };
        online_total += t_iter + switch_cost;
        decisions.push(StepDecision {
            step: t,
            loads_digest: digest_loads(&st.loads),
            drift: drift_v,
            redecided,
            switched,
            respan,
            kind: current,
            t_iter,
            switch_cost,
        });
        prev = Some(&st.loads);
    }

    // ---- static baselines: every (candidate × step) simulation is pure,
    // so they fan out over worker threads and merge by job index — the
    // totals are bit-identical at any thread count.
    let totals = static_totals(cfg, &steps, candidates, opts.threads.max(1))?;
    let statics = candidates.iter().cloned().zip(totals).collect();

    Ok(DriveOutcome {
        trace_name: spec.name.clone(),
        seed: spec.seed,
        threshold: opts.threshold,
        switch_frac: opts.switch_frac,
        cfg_id: cfg.id(),
        cluster_name: base.name.clone(),
        steps: decisions,
        statics,
        online_total,
        switches,
        redecisions,
    })
}

fn static_totals(
    cfg: &MoeLayerConfig,
    steps: &[TrafficStep],
    candidates: &[ScheduleKind],
    threads: usize,
) -> Result<Vec<f64>> {
    let jobs: Vec<(usize, usize)> = (0..candidates.len())
        .flat_map(|ci| (0..steps.len()).map(move |t| (ci, t)))
        .collect();
    let run = |&(ci, t): &(usize, usize)| -> Result<f64> {
        let st = &steps[t];
        let (report, _) = simulate_iteration_traffic_with_dag(
            candidates[ci],
            cfg,
            &st.cluster,
            None,
            Some(&st.loads),
        )?;
        Ok(report.makespan)
    };
    let mut times = vec![0.0f64; jobs.len()];
    if threads <= 1 {
        for (idx, job) in jobs.iter().enumerate() {
            times[idx] = run(job)?;
        }
    } else {
        let chunks: Vec<Vec<(usize, Result<f64>)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|w| {
                    let jobs = &jobs;
                    let run = &run;
                    scope.spawn(move || {
                        jobs.iter()
                            .enumerate()
                            .skip(w)
                            .step_by(threads)
                            .map(|(idx, job)| (idx, run(job)))
                            .collect()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("static worker panicked")).collect()
        });
        for (idx, r) in chunks.into_iter().flatten() {
            times[idx] = r?;
        }
    }
    let mut totals = vec![0.0f64; candidates.len()];
    // Accumulate in (candidate, step) order — fixed regardless of which
    // worker produced each value.
    for (idx, &(ci, _)) in jobs.iter().enumerate() {
        totals[ci] += times[idx];
    }
    Ok(totals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::moe::ParallelDegrees;
    use crate::util::prng::Rng;

    #[test]
    fn drift_is_symmetric_bounded_and_zero_on_identical() {
        let mut rng = Rng::new(0xd21f7);
        for case in 0..200 {
            let n = rng.range(1, 8);
            let p: Vec<usize> = (0..n).map(|_| rng.usize(100)).collect();
            let q: Vec<usize> = (0..n).map(|_| rng.usize(100)).collect();
            let d = drift(&p, &q);
            assert_eq!(d, drift(&q, &p), "symmetry, case {case}: {p:?} {q:?}");
            assert!((0.0..=1.0).contains(&d), "bounds, case {case}: {d} {p:?} {q:?}");
            assert_eq!(drift(&p, &p), 0.0, "identity, case {case}");
        }
        // All-zero reads as uniform: zero drift against an even vector,
        // maximal-ish against a fully concentrated one.
        assert_eq!(drift(&[0, 0, 0], &[5, 5, 5]), 0.0);
        let concentrated = drift(&[0, 0, 0, 0], &[9, 0, 0, 0]);
        assert!((concentrated - 0.75).abs() < 1e-12, "{concentrated}");
        // Disjoint supports are maximally far apart.
        assert_eq!(drift(&[7, 0], &[0, 3]), 1.0);
        assert_eq!(drift(&[], &[]), 0.0);
    }

    #[test]
    fn hysteresis_holds_on_constant_traces_and_converges_after_regime_change() {
        let mut rng = Rng::new(0x4b1d);
        for case in 0..50 {
            let n = rng.range(2, 8);
            let a: Vec<usize> = (0..n).map(|_| 1 + rng.usize(50)).collect();
            // A genuinely different regime: rotate and concentrate.
            let mut b = vec![0usize; n];
            b[case % n] = 100 * n;
            if drift(&a, &b) < 0.3 {
                continue;
            }
            let mut h = Hysteresis::new(0.25);
            assert!(h.observe(&a).0, "first observation always decides");
            for _ in 0..10 {
                let (re, d) = h.observe(&a);
                assert!(!re && d == 0.0, "constant trace must never re-decide, case {case}");
            }
            // Sustained regime change: the very next observation crosses
            // the band, re-anchors, and the new regime is then stable.
            let (re, d) = h.observe(&b);
            assert!(re && d >= 0.25, "regime change must re-decide, case {case} ({d})");
            for _ in 0..10 {
                assert!(!h.observe(&b).0, "converged regime must hold, case {case}");
            }
        }
        // threshold = 0: every observation re-decides.
        let mut h0 = Hysteresis::new(0.0);
        for _ in 0..5 {
            assert!(h0.observe(&[3, 3, 3]).0);
        }
    }

    fn drive_fixture() -> (MoeLayerConfig, ClusterTopology, PerfModel) {
        let cfg = MoeLayerConfig::test_default();
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let model = PerfModel::fit(&cluster, par).unwrap();
        (cfg, cluster, model)
    }

    fn constant_spec(steps: usize) -> TraceSpec {
        use crate::util::json::Json;
        TraceSpec::from_json(
            &Json::parse(&format!(r#"{{"name": "const", "steps": {steps}, "seed": 7}}"#)).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn constant_uniform_trace_never_switches() {
        let (cfg, cluster, model) = drive_fixture();
        let spec = constant_spec(5);
        let cands = default_candidates(&predict_with_loads(&model, &cfg, None));
        let out =
            drive(&spec, &cfg, &cluster, &model, &cands, &DriveOptions::default()).unwrap();
        // Only the anchor-setting first observation decides; after that
        // warm-up alignment the schedule must hold dead steady (flap
        // protection is the whole point of the band).
        assert_eq!(out.redecisions, 1, "{}", out.decision_log());
        assert!(out.switches <= 1, "{}", out.decision_log());
        let held = out.steps[1].kind;
        assert!(out.steps.iter().skip(1).all(|d| d.kind == held), "{}", out.decision_log());
        assert!(out.steps.iter().skip(1).all(|d| d.drift == 0.0));
        assert_eq!(out.statics.len(), cands.len());
        assert!(out.online_total > 0.0);
    }

    #[test]
    fn threshold_zero_redecides_every_step_and_logs_are_thread_invariant() {
        let (cfg, cluster, model) = drive_fixture();
        let spec = constant_spec(4);
        let cands = default_candidates(&predict_with_loads(&model, &cfg, None));
        let opts = DriveOptions { threshold: 0.0, threads: 1, ..Default::default() };
        let a = drive(&spec, &cfg, &cluster, &model, &cands, &opts).unwrap();
        assert!(a.steps.iter().skip(1).all(|d| d.redecided), "{}", a.decision_log());
        assert_eq!(a.redecisions, spec.steps - 1);
        // Same inputs → byte-identical logs, at any thread count.
        let b = drive(&spec, &cfg, &cluster, &model, &cands, &opts).unwrap();
        assert_eq!(a.decision_log(), b.decision_log());
        let opts4 = DriveOptions { threads: 4, ..opts };
        let c = drive(&spec, &cfg, &cluster, &model, &cands, &opts4).unwrap();
        assert_eq!(a.decision_log(), c.decision_log());
        // The log round-trips its own shape: one header, a row per step,
        // a row per static, one summary.
        assert_eq!(a.decision_log().lines().count(), 1 + spec.steps + cands.len() + 1);
    }
}
