//! `parm` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   doctor      PJRT + artifact sanity check
//!   train       end-to-end MoE LM training through the PJRT artifact
//!   sim         simulate one MoE layer config under a schedule
//!   fit         fit and print the α-β performance models (Fig 6 style)
//!   choose      Algorithm 1: pick S1 or S2 for a config
//!   plan        compile a plan artifact (fitted models + decisions)
//!   sweep       Table III sweep on a cluster; summary per schedule
//!   bench       regenerate paper tables/figures (fig1|fig6|table4|fig7|
//!               table5|saa|selection|choices|all)
//!   trace       emit a Chrome trace of one simulated schedule (or of a
//!               `drive` run via `--drive outcome.json`)
//!   drive       online adaptive control: run a drifting-traffic trace,
//!               re-spanning each step and switching schedule under a
//!               hysteresis band
//!   lint        statically verify every builder op program over the sweep
//!               grid (volume conservation, span discipline, frontier
//!               safety, tag discipline, plane capability, group validity)
//!
//! `sim`, `choose`, `sweep` and `drive` accept `--plan <file>` to load a
//! compiled plan instead of refitting; `sweep` accepts `--cache-dir` for
//! content-addressed incremental re-runs and `--scale K` to densify the
//! grid. Every stochastic verb takes `--seed` (0 is a valid seed, not
//! "auto"; the documented default is 42).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{anyhow, bail, Result};

use parm::bench::paper;
use parm::bench::{CaseResult, SweepStats};
use parm::config::moe::ParallelDegrees;
use parm::config::{sweep as sweepcfg, ClusterTopology, MoeLayerConfig, SweepFilter, WirePrecision};
use parm::perfmodel::{closedform, selection, PerfModel, Plan};
use parm::schedule::{lowering, ScheduleKind};
use parm::sim::trace::chrome_trace;
use parm::train::{train_lm, TrainOptions};
use parm::util::cli::{render_help, Args, Spec};
use parm::util::stats::mean;
use parm::util::table::{fmt_seconds, Table};

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print_usage();
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd {
        "doctor" => cmd_doctor(&rest),
        "train" => cmd_train(&rest),
        "sim" => cmd_sim(&rest),
        "fit" => cmd_fit(&rest),
        "choose" => cmd_choose(&rest),
        "plan" => cmd_plan(&rest),
        "sweep" => cmd_sweep(&rest),
        "bench" => cmd_bench(&rest),
        "trace" => cmd_trace(&rest),
        "drive" => cmd_drive(&rest),
        "lint" => cmd_lint(&rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(anyhow!("unknown command `{other}` (try `parm help`)")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "parm — efficient MoE training with dedicated MP+EP+ESP schedules\n\n\
         usage: parm <command> [options]\n\n\
         commands:\n  \
         doctor   PJRT + artifact sanity check\n  \
         train    end-to-end MoE LM training (PJRT artifact)\n  \
         sim      simulate one MoE layer under a schedule\n  \
         fit      fit α-β performance models (Fig 6)\n  \
         choose   Algorithm 1 schedule selection for a config\n  \
         plan     compile a plan artifact (parm plan build)\n  \
         sweep    Table III sweep summary on a cluster\n  \
         bench    regenerate paper tables/figures\n  \
         trace    emit Chrome trace of a simulated schedule or drive run\n  \
         drive    online adaptive control over a drifting-traffic trace\n  \
         lint     statically verify every builder op program on the grid\n\n\
         run `parm <command> --help` for options"
    );
}

// ---- shared option groups ------------------------------------------------

const LAYER_SPECS: &[Spec] = &[
    Spec::opt_default("cluster", "testbed_b", "cluster name or JSON path"),
    Spec::opt(
        "cluster-json",
        "cluster topology JSON (per-node specs for mixed fleets; overrides --cluster)",
    ),
    Spec::opt_default("p", "8", "total GPUs for the layer"),
    Spec::opt_default("mp", "2", "N_MP (model-parallel degree)"),
    Spec::opt_default("esp", "2", "N_ESP (expert-sharding degree)"),
    Spec::opt_default("b", "4", "local batch size B"),
    Spec::opt_default("l", "1024", "sequence length L"),
    Spec::opt_default("m", "1024", "embedding size M"),
    Spec::opt_default("hidden", "2048", "expert hidden size H"),
    Spec::opt_default("k", "2", "top-k"),
    Spec::opt_default("f", "1.2", "capacity factor"),
    Spec::opt_default("skew", "0", "Zipf routing-skew exponent (0 = uniform routing)"),
    Spec::opt_default("dtype-bytes", "4", "model element width in bytes (all volumes scale with it)"),
    Spec::opt_default(
        "wire",
        "f32",
        "wire precision: f32|bf16|fp8 (uniform), or per-leg JSON like \
         {\"dispatch\":\"fp8\",\"combine\":\"bf16\"} (legs: dispatch, combine, allgather, wgrad; \
         unnamed legs stay f32)",
    ),
    Spec::opt("e", "number of experts (default: P / N_ESP)"),
    Spec::opt(
        "plan",
        "compiled plan artifact (`parm plan build`); predictions load without refitting",
    ),
    Spec::opt_default("seed", "42", "PRNG seed (0 is a valid seed, not \"auto\")"),
    Spec::flag("help", "show help"),
];

/// Resolve the cluster topology from `--cluster-json` (explicit per-node
/// topology document) or `--cluster` (builtin name / legacy JSON path).
fn cluster_from(a: &Args) -> Result<ClusterTopology> {
    match a.get("cluster-json") {
        Some(path) => ClusterTopology::from_json_file(path),
        None => ClusterTopology::load(a.req("cluster")?),
    }
}

/// Parse a `--wire` value: a uniform dtype name (`f32|bf16|fp8`) or a
/// per-leg JSON object (`{"dispatch":"fp8","combine":"bf16"}`; unnamed
/// legs stay f32).
fn parse_wire(spec: &str) -> Result<WirePrecision> {
    use parm::util::json::Json;
    if spec.trim_start().starts_with('{') {
        WirePrecision::from_json(&Json::parse(spec)?)
    } else {
        WirePrecision::from_json(&Json::str(spec))
    }
}

fn layer_from(a: &Args) -> Result<(MoeLayerConfig, ClusterTopology)> {
    let cluster = cluster_from(a)?;
    let p = a.get_usize("p")?.unwrap();
    let n_esp = a.get_usize("esp")?.unwrap();
    let cfg = MoeLayerConfig {
        par: ParallelDegrees { p, n_mp: a.get_usize("mp")?.unwrap(), n_esp },
        b: a.get_usize("b")?.unwrap(),
        l: a.get_usize("l")?.unwrap(),
        e: a.get_usize("e")?.unwrap_or(p / n_esp),
        m: a.get_usize("m")?.unwrap(),
        h: a.get_usize("hidden")?.unwrap(),
        k: a.get_usize("k")?.unwrap(),
        f: a.get_f64("f")?.unwrap(),
        dtype_bytes: a.get_usize("dtype-bytes")?.unwrap(),
        skew: a.get_f64("skew")?.unwrap(),
        wire: parse_wire(a.req("wire")?)?,
    };
    cfg.validate()?;
    anyhow::ensure!(
        cfg.par.p <= cluster.total_gpus(),
        "layer needs {} GPUs but cluster {} has {}",
        cfg.par.p,
        cluster.name,
        cluster.total_gpus()
    );
    Ok((cfg, cluster))
}

/// Load `--plan` (hash-checked against the resolved topology) when given.
fn plan_from(a: &Args, cluster: &ClusterTopology) -> Result<Option<Plan>> {
    match a.get("plan") {
        Some(path) => Ok(Some(Plan::load_checked(Path::new(path), cluster)?)),
        None => Ok(None),
    }
}

/// The sweep/plan grid options: `--scale` densifies Table III, `--p`
/// restricts the layout axis, `--limit` truncates, `--skew` sets the
/// routing-skew knob on every retained config.
fn sweep_configs(a: &Args, cluster: &ClusterTopology) -> Result<Vec<MoeLayerConfig>> {
    let scale = a.get_usize("scale")?.unwrap_or(1);
    let mut configs = sweepcfg::sweep_table3_scaled(cluster, SweepFilter::Feasible, scale);
    if let Some(p) = a.get_usize("p")? {
        configs.retain(|c| c.par.p == p);
    }
    if let Some(limit) = a.get_usize("limit")? {
        configs.truncate(limit);
    }
    if let Some(skew) = a.get_f64("skew")? {
        if !skew.is_finite() || skew < 0.0 {
            bail!("routing skew must be finite and ≥ 0, got {skew}");
        }
        // Skewed-routing workload family: the same grid under imbalanced
        // traffic (Zipf gate bias); SP's spans become load-aware and the
        // SP-uniform column shows what uniform chunking would have cost.
        for c in &mut configs {
            c.skew = skew;
        }
    }
    if let Some(dtype_bytes) = a.get_usize("dtype-bytes")? {
        if dtype_bytes == 0 {
            bail!("--dtype-bytes must be ≥ 1");
        }
        for c in &mut configs {
            c.dtype_bytes = dtype_bytes;
        }
    }
    if let Some(spec) = a.get("wire") {
        // Compressed-wire workload family: the same grid with narrowed
        // collective legs; every volume-driven term (and so Algorithm 1's
        // pick and r*) re-decides at the compressed sizes.
        let wire = parse_wire(spec)?;
        for c in &mut configs {
            c.wire = wire;
        }
    }
    Ok(configs)
}

const GRID_SPECS: &[Spec] = &[
    Spec::opt("p", "restrict to one P"),
    Spec::opt("limit", "only run the first N configs"),
    Spec::opt("skew", "run the grid with a Zipf routing-skew exponent (imbalanced traffic)"),
    Spec::opt("scale", "grid multiplier K: densify the Table III axes to ≥ K× the rows"),
    Spec::opt("dtype-bytes", "override the model element width (bytes) on every retained config"),
    Spec::opt(
        "wire",
        "wire precision on every retained config: f32|bf16|fp8 or per-leg JSON \
         (legs: dispatch, combine, allgather, wgrad)",
    ),
    Spec::opt_default("seed", "42", "PRNG seed (0 is a valid seed, not \"auto\")"),
];

fn help_guard(a: &Args, cmd: &str, about: &str, specs: &[Spec]) -> bool {
    if a.has_flag("help") {
        print!("{}", render_help(cmd, about, specs));
        true
    } else {
        false
    }
}

// ---- commands --------------------------------------------------------------

fn cmd_doctor(rest: &[String]) -> Result<()> {
    const SPECS: &[Spec] = &[
        Spec::opt_default("artifacts", "artifacts", "artifacts directory"),
        Spec::flag("help", "show help"),
    ];
    let a = Args::parse(rest, SPECS)?;
    if help_guard(&a, "doctor", "sanity-check the runtime", SPECS) {
        return Ok(());
    }
    println!("PJRT: {}", parm::runtime::smoke()?);
    let dir = Path::new(a.req("artifacts")?);
    match parm::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for art in &m.artifacts {
                let status = if m.hlo_path(&art.name).is_ok() { "ok" } else { "MISSING" };
                println!("  {:<24} {status}", art.name);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e:#})"),
    }
    println!("doctor OK");
    Ok(())
}

fn cmd_train(rest: &[String]) -> Result<()> {
    const SPECS: &[Spec] = &[
        Spec::opt_default("artifacts", "artifacts", "artifacts directory"),
        Spec::opt_default("steps", "200", "training steps"),
        Spec::opt_default("lr", "0.05", "learning rate"),
        Spec::opt_default("seed", "42", "PRNG seed"),
        Spec::opt_default("log-every", "10", "print every N steps"),
        Spec::opt("log", "JSONL loss log path"),
        Spec::flag("help", "show help"),
    ];
    let a = Args::parse(rest, SPECS)?;
    if help_guard(&a, "train", "train the tiny MoE LM end-to-end", SPECS) {
        return Ok(());
    }
    let opts = TrainOptions {
        artifacts_dir: PathBuf::from(a.req("artifacts")?),
        steps: a.get_usize("steps")?.unwrap(),
        lr: a.get_f64("lr")?.unwrap() as f32,
        seed: a.get_usize("seed")?.unwrap() as u64,
        log_every: a.get_usize("log-every")?.unwrap(),
        log_path: a.get("log").map(PathBuf::from),
        reset_every: 12,
    };
    let report = train_lm(&opts)?;
    println!(
        "\ntrained {} params for {} steps in {:.1}s ({:.2} s/step)",
        report.param_count,
        report.steps,
        report.wall_seconds,
        report.wall_seconds / report.steps.max(1) as f64
    );
    println!(
        "loss: {:.4} → {:.4} (synthetic-corpus entropy floor {:.3})",
        report.first_loss(),
        report.last_loss(),
        report.entropy_floor
    );
    Ok(())
}

fn cmd_sim(rest: &[String]) -> Result<()> {
    let mut specs = LAYER_SPECS.to_vec();
    specs.push(Spec::opt_default(
        "schedule",
        "parm",
        "baseline|s1|s2|s2-aas|sp|spN|spuN|sp2|sp2N|parm (sp = pipelined, N pins the chunk count, spu = uniform spans, sp2 = pipelined S2 with chunked-SAA combines)",
    ));
    specs.push(Spec::opt_default(
        "spans",
        "expected",
        "SP chunk-span source: expected (load model) | measured (two-pass: run the real gate once, re-balance spans on its measured expert loads)",
    ));
    let a = Args::parse(rest, &specs)?;
    if help_guard(&a, "sim", "simulate one MoE layer iteration", &specs) {
        return Ok(());
    }
    let (cfg, cluster) = layer_from(&a)?;
    let plan = plan_from(&a, &cluster)?;
    let kind = ScheduleKind::parse(a.req("schedule")?).ok_or_else(|| anyhow!("bad --schedule"))?;
    let kind = resolve(kind, &cfg, &cluster, plan.as_ref())?;
    let measured: Option<Vec<usize>> = match a.req("spans")? {
        "expected" => None,
        "measured" => {
            // Two-pass span selection: run the data-plane gate once on a
            // synthetic batch and feed its measured per-expert loads back
            // into the span policy (covers organic, non-Zipf imbalance).
            let seed = a.get_usize("seed")?.unwrap() as u64;
            let state = parm::moe::exec::LayerState::random(&cfg, seed)?;
            let loads = parm::moe::exec::measure_expert_loads(&state);
            eprintln!("measured expert loads (max over ranks): {loads:?}");
            Some(loads)
        }
        other => bail!("--spans must be `expected` or `measured`, got `{other}`"),
    };
    let (report, dag) =
        lowering::simulate_iteration_measured_with_dag(kind, &cfg, &cluster, measured.as_deref())?;
    println!("config   : {}", cfg.id());
    println!("cluster  : {}", cluster.name);
    println!("schedule : {}", kind.label());
    println!("iteration: {}", fmt_seconds(report.makespan));
    println!("comm %   : {:.1}", report.comm_ratio() * 100.0);
    // Comm/compute overlap — the quantity the pipelined schedules exist to
    // create, and what skewed routing erodes without load-aware spans.
    let overlap = report.overlap_seconds(&dag);
    println!(
        "overlap  : {} ({:.1}% of iteration)",
        fmt_seconds(overlap),
        overlap / report.makespan.max(1e-30) * 100.0
    );
    Ok(())
}

fn resolve(
    kind: ScheduleKind,
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
    plan: Option<&Plan>,
) -> Result<ScheduleKind> {
    match kind {
        // Generalized Algorithm 1 — from the plan artifact when given
        // (no refit), else over freshly fitted α-β models.
        ScheduleKind::Parm => match plan {
            Some(p) => Ok(p.predict(cfg)?.best()),
            None => {
                let model = PerfModel::fit(cluster, cfg.par)?;
                Ok(selection::choose_schedule_extended(&model, cfg))
            }
        },
        // `sp` with no pinned r: closed-form optimal chunk count.
        ScheduleKind::Pipelined { chunks: 0 } => {
            let (r, _) = closedform::optimal_chunks(cluster, cfg);
            Ok(ScheduleKind::Pipelined { chunks: r })
        }
        ScheduleKind::PipelinedUniform { chunks: 0 } => {
            let (r, _) = closedform::optimal_chunks(cluster, cfg);
            Ok(ScheduleKind::PipelinedUniform { chunks: r })
        }
        // `sp2` with no pinned r: closed-form optimal chunked-SAA count.
        ScheduleKind::PipelinedS2 { chunks: 0 } => {
            let (r, _) = closedform::optimal_chunks_sp2(cluster, cfg);
            Ok(ScheduleKind::PipelinedS2 { chunks: r })
        }
        k => Ok(k),
    }
}

fn cmd_fit(rest: &[String]) -> Result<()> {
    const SPECS: &[Spec] = &[
        Spec::opt_default("cluster", "testbed_b", "cluster name or JSON path"),
        Spec::opt("cluster-json", "cluster topology JSON (overrides --cluster)"),
        Spec::opt_default("p", "32", "total GPUs"),
        Spec::opt_default("mp", "4", "N_MP"),
        Spec::opt_default("esp", "4", "N_ESP"),
        Spec::flag("json", "print JSON instead of a table"),
        Spec::flag("help", "show help"),
    ];
    let a = Args::parse(rest, SPECS)?;
    if help_guard(&a, "fit", "fit α-β models for a layout", SPECS) {
        return Ok(());
    }
    let cluster = cluster_from(&a)?;
    let par = ParallelDegrees {
        p: a.get_usize("p")?.unwrap(),
        n_mp: a.get_usize("mp")?.unwrap(),
        n_esp: a.get_usize("esp")?.unwrap(),
    };
    anyhow::ensure!(
        par.p <= cluster.total_gpus(),
        "layout needs {} GPUs but cluster {} has {}",
        par.p,
        cluster.name,
        cluster.total_gpus()
    );
    let model = PerfModel::fit(&cluster, par)?;
    if a.has_flag("json") {
        println!("{}", model.to_json().to_pretty());
    } else {
        use parm::perfmodel::fit::CollKind;
        let mut t = Table::new(&["collective", "alpha (s)", "beta (s/B)", "r²"]).numeric();
        for kind in CollKind::ALL {
            let f = model.get(kind);
            t.row(&[
                kind.name().into(),
                format!("{:.3e}", f.intercept),
                format!("{:.3e}", f.slope),
                format!("{:.6}", f.r2),
            ]);
        }
        print!("{}", t.to_text());
        // One α-β pair per link class of the topology (all of them on a
        // mixed fleet; two on a homogeneous multi-node cluster).
        let mut lt = Table::new(&["link class", "alpha (s)", "beta (s/B)", "r²"]).numeric();
        for (class, f) in model.link_fits() {
            lt.row(&[
                class.id(),
                format!("{:.3e}", f.intercept),
                format!("{:.3e}", f.slope),
                format!("{:.6}", f.r2),
            ]);
        }
        print!("{}", lt.to_text());
    }
    Ok(())
}

fn cmd_choose(rest: &[String]) -> Result<()> {
    let a = Args::parse(rest, LAYER_SPECS)?;
    if help_guard(&a, "choose", "Algorithm 1: pick S1, S2 or SP(r*)", LAYER_SPECS) {
        return Ok(());
    }
    let (cfg, cluster) = layer_from(&a)?;
    let pred = match plan_from(&a, &cluster)? {
        // From the artifact: the stored decision (or the stored layout
        // model for an off-grid config) — no fitting happens.
        Some(plan) => plan.predict(&cfg)?,
        None => selection::predict(&PerfModel::fit(&cluster, cfg.par)?, &cfg),
    };
    println!("t_baseline (predicted): {}", fmt_seconds(pred.t_baseline));
    println!("t_D1 (S1, predicted)  : {}", fmt_seconds(pred.t_d1));
    println!("t_D2 (S2, predicted)  : {}", fmt_seconds(pred.t_d2));
    println!("t_FFN (PauseMP exp.)  : {}", fmt_seconds(pred.t_ffn));
    println!(
        "t_SP(r*={}) (pred.)    : {} (compute-inclusive)",
        pred.sp_chunks,
        fmt_seconds(pred.t_sp)
    );
    println!(
        "t_SP2(r*={}) (pred.)   : {} (compute-inclusive, chunked-SAA combine)",
        pred.sp2_chunks,
        fmt_seconds(pred.t_sp2)
    );
    // Whole-iteration terms (schema v2): the argmin compares these, not
    // the forward-only dispatch times above.
    println!("t_wgradAR (predicted) : {}", fmt_seconds(pred.t_wgrad_ar));
    println!("t_iter S1 (predicted) : {}", fmt_seconds(pred.t_iter_s1));
    println!("t_iter S2 (predicted) : {}", fmt_seconds(pred.t_iter_s2));
    println!("t_iter SP (predicted) : {}", fmt_seconds(pred.t_sp_iter));
    println!("t_iter SP2 (pred.)    : {}", fmt_seconds(pred.t_sp2_iter));
    if !cluster.is_homogeneous() {
        // Per-node view: on a mixed fleet the straggler paces the fleet
        // and its r* (even its pick) can differ from the fast nodes'.
        println!("bottleneck node       : {}", pred.bottleneck_node);
        for node in cluster.nodes_for(cfg.par.p) {
            let (pick, t) = closedform::choose_extended_on(&cluster, &cfg, node);
            println!(
                "  node {node}: closed-form pick {} ({}/iter)",
                pick.label(),
                fmt_seconds(t)
            );
        }
    }
    println!("Algorithm 1 chooses   : {}", pred.best().label());
    Ok(())
}

fn cmd_plan(rest: &[String]) -> Result<()> {
    let mut specs = vec![
        Spec::opt_default("cluster", "testbed_b", "cluster name or JSON path"),
        Spec::opt("cluster-json", "cluster topology JSON (overrides --cluster)"),
    ];
    specs.extend_from_slice(GRID_SPECS);
    specs.push(Spec::opt_default("out", "plan.json", "plan artifact output path"));
    specs.push(Spec::flag("help", "show help"));
    let a = Args::parse(rest, &specs)?;
    if help_guard(
        &a,
        "plan",
        "compile a plan artifact: fitted α-β models + Algorithm-1 decisions (parm plan build)",
        &specs,
    ) {
        return Ok(());
    }
    match a.positional.first().map(|s| s.as_str()) {
        Some("build") => {}
        Some(other) => bail!("unknown plan action `{other}` (try `parm plan build`)"),
        None => bail!("usage: parm plan build [options] --out plan.json"),
    }
    let cluster = cluster_from(&a)?;
    let configs = sweep_configs(&a, &cluster)?;
    anyhow::ensure!(!configs.is_empty(), "no feasible configs to plan on {}", cluster.name);
    let t0 = std::time::Instant::now();
    let plan = Plan::build(&cluster, &configs)?;
    let path = Path::new(a.req("out")?);
    plan.save(path)?;
    println!(
        "plan: {} decisions over {} fitted layouts on {} in {:.3}s → {}",
        plan.decisions().len(),
        plan.num_models(),
        cluster.name,
        t0.elapsed().as_secs_f64(),
        path.display()
    );
    println!("cluster hash {} · grid hash {}", plan.cluster_hash, plan.grid_hash);
    Ok(())
}

/// The fixed-format cache/timing trailer `parm sweep` always prints (the
/// CI cache-reuse job greps these lines verbatim).
fn print_sweep_stats(stats: &SweepStats, cache_enabled: bool) {
    println!("sweep timing: fit {:.3}s · sim {:.3}s", stats.fit_seconds, stats.sim_seconds);
    println!(
        "fit cache: {} hits / {} misses ({} seeded)",
        stats.fit_hits, stats.fit_misses, stats.seeded_models
    );
    if cache_enabled {
        println!("case cache: {} hits / {} misses", stats.case_hits, stats.case_misses);
    } else {
        println!("case cache: disabled (no --cache-dir)");
    }
}

fn cmd_sweep(rest: &[String]) -> Result<()> {
    let mut specs = vec![
        Spec::opt_default("cluster", "testbed_b", "cluster name or JSON path"),
        Spec::opt("cluster-json", "cluster topology JSON (overrides --cluster)"),
    ];
    specs.extend_from_slice(GRID_SPECS);
    specs.extend_from_slice(&[
        Spec::opt("threads", "sweep worker threads, 1..=1024 (default: all cores)"),
        Spec::opt("plan", "compiled plan artifact: seed every fit from it, never refit"),
        Spec::opt("cache-dir", "content-addressed case/fit cache dir (incremental re-runs)"),
        Spec::opt("csv", "write per-case results CSV to PATH (golden-gate format)"),
        Spec::opt(
            "bench-json",
            "write sweep throughput + per-schedule mean makespans to PATH (times a sequential re-run of up to 64 cases)",
        ),
        Spec::flag("help", "show help"),
    ]);
    let a = Args::parse(rest, &specs)?;
    if help_guard(&a, "sweep", "Table III sweep summary", &specs) {
        return Ok(());
    }
    let cluster = cluster_from(&a)?;
    let configs = sweep_configs(&a, &cluster)?;
    println!("{} feasible configs on {}", configs.len(), cluster.name);
    // The `--plan` contract is "no refitting": every layout of the grid
    // must be covered by the artifact, or the run fails up front.
    let seed_models: Vec<PerfModel> = match plan_from(&a, &cluster)? {
        Some(plan) => {
            let mut layouts: Vec<_> =
                configs.iter().map(|c| (c.par.p, c.par.n_mp, c.par.n_esp)).collect();
            layouts.sort_unstable();
            layouts.dedup();
            for &(p, n_mp, n_esp) in &layouts {
                let par = ParallelDegrees { p, n_mp, n_esp };
                if plan.model_for(par).is_none() {
                    bail!(
                        "--plan artifact lacks a fitted model for layout p={p} mp={n_mp} \
                         esp={n_esp} — rebuild it with `parm plan build` over this grid"
                    );
                }
            }
            plan.models().cloned().collect()
        }
        None => Vec::new(),
    };
    let cache_dir = a.get("cache-dir").map(PathBuf::from);
    let threads = match a.get_usize("threads")? {
        Some(t) => t,
        None => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(parm::bench::MAX_SWEEP_THREADS),
    };
    let t_run = std::time::Instant::now();
    let outcome = parm::bench::run_sweep_cached(
        &configs,
        &cluster,
        true,
        threads,
        cache_dir.as_deref(),
        &seed_models,
    )?;
    let run_secs = t_run.elapsed().as_secs_f64();
    let stats = outcome.stats;
    let results = outcome.results;
    let s1: Vec<f64> = results.iter().map(|r| r.speedup_s1()).collect();
    let s2: Vec<f64> = results.iter().map(|r| r.speedup_s2()).collect();
    let sp: Vec<f64> = results.iter().map(|r| r.speedup_sp()).collect();
    let spu: Vec<f64> = results.iter().map(|r| r.speedup_sp_uniform()).collect();
    let sp2: Vec<f64> = results.iter().map(|r| r.speedup_sp2()).collect();
    let pm: Vec<f64> = results.iter().map(|r| r.speedup_parm()).collect();
    let mut t = Table::new(&["schedule", "mean speedup", "min", "max"]).numeric();
    let rows =
        [("S1", &s1), ("S2", &s2), ("SP", &sp), ("SP-uni", &spu), ("SP2", &sp2), ("Parm", &pm)];
    for (name, v) in rows {
        t.row(&[
            name.into(),
            format!("{:.2}×", mean(v)),
            format!("{:.2}×", v.iter().cloned().fold(f64::MAX, f64::min)),
            format!("{:.2}×", v.iter().cloned().fold(0.0, f64::max)),
        ]);
    }
    print!("{}", t.to_text());
    print_sweep_stats(&stats, cache_dir.is_some());
    if let Some(path) = a.get("csv") {
        std::fs::write(path, parm::bench::sweep_csv(&results))?;
        eprintln!("wrote per-case CSV to {path}");
    }
    if let Some(path) = a.get("bench-json") {
        let seed = a.get_usize("seed")?.unwrap() as u64;
        write_sweep_bench_json(
            path,
            &configs,
            &cluster,
            &results,
            threads,
            run_secs,
            &stats,
            seed,
        )?;
    }
    Ok(())
}

/// `BENCH_sweep.json`: cases/sec sequential vs parallel plus per-schedule
/// mean makespans — the perf-trajectory artifact CI uploads per run. The
/// parallel measurement reuses the already-timed main run (`par_s`); the
/// sequential throughput is measured on a bounded prefix sample (≤ 64
/// cases) so `--bench-json` never multiplies a large grid's runtime, and
/// its output is cross-checked against the main run's rows (the full
/// determinism property lives in the sweep tests).
#[allow(clippy::too_many_arguments)]
fn write_sweep_bench_json(
    path: &str,
    configs: &[MoeLayerConfig],
    cluster: &ClusterTopology,
    results: &[CaseResult],
    threads: usize,
    par_s: f64,
    stats: &SweepStats,
    seed: u64,
) -> Result<()> {
    use parm::util::json::Json;
    let sample = configs.len().min(64);
    let t0 = std::time::Instant::now();
    let seq = parm::bench::run_sweep_with_threads(&configs[..sample], cluster, false, 1)?;
    let seq_s = t0.elapsed().as_secs_f64();
    if parm::bench::sweep_csv(&seq) != parm::bench::sweep_csv(&results[..sample]) {
        bail!("sequential re-run diverged from the sweep's output");
    }
    let mean_of = |f: &dyn Fn(&CaseResult) -> f64| -> f64 {
        mean(&results.iter().map(|r| f(r)).collect::<Vec<f64>>())
    };
    let cases = configs.len() as f64;
    // Wire-precision annotation so baselines from different wire runs are
    // never compared silently ("f32" for the default lossless policy).
    let wire_id =
        configs.first().map(|c| c.wire.id_suffix()).unwrap_or_else(|| "f32".to_string());
    let j = Json::obj(vec![
        ("cluster", Json::str(&cluster.name)),
        ("wire", Json::str(&wire_id)),
        ("seed", Json::num(seed as f64)),
        ("cases", Json::num(cases)),
        ("threads", Json::num(threads as f64)),
        ("seq_sample_cases", Json::num(sample as f64)),
        ("seq_sample_seconds", Json::num(seq_s)),
        ("par_seconds", Json::num(par_s)),
        ("cases_per_sec_seq", Json::num(sample as f64 / seq_s.max(1e-9))),
        ("cases_per_sec_par", Json::num(cases / par_s.max(1e-9))),
        ("case_cache_hits", Json::num(stats.case_hits as f64)),
        ("case_cache_misses", Json::num(stats.case_misses as f64)),
        ("fit_cache_hits", Json::num(stats.fit_hits as f64)),
        ("fit_cache_misses", Json::num(stats.fit_misses as f64)),
        ("fit_seconds", Json::num(stats.fit_seconds)),
        ("sim_seconds", Json::num(stats.sim_seconds)),
        (
            "mean_makespan",
            Json::obj(vec![
                ("baseline", Json::num(mean_of(&|r| r.t_baseline))),
                ("s1", Json::num(mean_of(&|r| r.t_s1))),
                ("s2", Json::num(mean_of(&|r| r.t_s2))),
                ("s2_aas", Json::num(mean_of(&|r| r.t_s2_aas))),
                ("sp", Json::num(mean_of(&|r| r.t_sp))),
                ("sp_uniform", Json::num(mean_of(&|r| r.t_sp_uniform))),
                ("sp2", Json::num(mean_of(&|r| r.t_sp2))),
                ("parm", Json::num(mean_of(&|r| r.t_parm()))),
            ]),
        ),
        // Backward share per family (iteration minus forward) — the
        // columns the whole-iteration argmin added in plan schema v2.
        (
            "mean_backward",
            Json::obj(vec![
                ("baseline", Json::num(mean_of(&|r| r.t_bwd_baseline))),
                ("s1", Json::num(mean_of(&|r| r.t_bwd_s1))),
                ("s2", Json::num(mean_of(&|r| r.t_bwd_s2))),
                ("sp", Json::num(mean_of(&|r| r.t_bwd_sp))),
                ("sp2", Json::num(mean_of(&|r| r.t_bwd_sp2))),
            ]),
        ),
    ]);
    std::fs::write(path, j.to_pretty())?;
    eprintln!("wrote sweep bench JSON to {path}");
    Ok(())
}

fn cmd_bench(rest: &[String]) -> Result<()> {
    const SPECS: &[Spec] = &[
        Spec::opt_default("reports", "reports", "output directory"),
        Spec::flag("help", "show help"),
    ];
    let a = Args::parse(rest, SPECS)?;
    if help_guard(
        &a,
        "bench",
        "regenerate paper artifacts: fig1|fig6|table4|fig7|table5|saa|selection|choices|all",
        SPECS,
    ) {
        return Ok(());
    }
    let which = a.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let reports = PathBuf::from(a.req("reports")?);
    let run = |name: &str| -> Result<()> {
        let out = match name {
            "fig1" => paper::fig1(&reports)?,
            "fig6" => paper::fig6(&reports)?,
            "table4" => paper::table4(&reports)?,
            "fig7" => paper::fig7(&reports)?,
            "table5" => paper::table5(&reports)?,
            "saa" => paper::saa_ablation(&reports)?,
            "selection" => paper::selection_accuracy(&reports)?,
            "choices" => paper::choice_breakdown(&reports)?,
            other => bail!("unknown bench `{other}`"),
        };
        println!("\n{out}");
        Ok(())
    };
    if which == "all" {
        for name in ["fig1", "fig6", "table4", "fig7", "table5", "saa", "selection", "choices"] {
            run(name)?;
        }
    } else {
        run(which)?;
    }
    println!("reports written to {}", reports.display());
    Ok(())
}

fn cmd_trace(rest: &[String]) -> Result<()> {
    let mut specs = LAYER_SPECS.to_vec();
    specs.push(Spec::opt_default("schedule", "s2", "schedule to trace"));
    specs.push(Spec::opt_default("out", "trace.json", "Chrome trace output path"));
    specs.push(Spec::opt(
        "drive",
        "render a `parm drive --json` outcome instead: one span per step, with instant \
         markers on schedule-switch and re-span events",
    ));
    let a = Args::parse(rest, &specs)?;
    if help_guard(&a, "trace", "emit a Chrome trace of one iteration", &specs) {
        return Ok(());
    }
    if let Some(path) = a.get("drive") {
        // Drive-run rendering: the outcome JSON already carries every
        // per-step decision, so no re-simulation happens here.
        use parm::util::json::Json;
        let text = std::fs::read_to_string(path)?;
        let outcome = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let trace = parm::sim::trace::chrome_drive_trace(&outcome)?;
        std::fs::write(a.req("out")?, trace.to_string())?;
        let steps = outcome.req_arr("steps")?;
        let switches = steps.iter().filter(|s| s.get("switched") == &Json::Bool(true)).count();
        let respans = steps.iter().filter(|s| s.get("respan") == &Json::Bool(true)).count();
        println!(
            "{} drive steps ({switches} switch markers, {respans} re-span markers) → {}",
            steps.len(),
            a.req("out")?
        );
        return Ok(());
    }
    let (cfg, cluster) = layer_from(&a)?;
    let plan = plan_from(&a, &cluster)?;
    let kind = ScheduleKind::parse(a.req("schedule")?).ok_or_else(|| anyhow!("bad --schedule"))?;
    let kind = resolve(kind, &cfg, &cluster, plan.as_ref())?;
    let (report, dag) = lowering::simulate_iteration_with_dag(kind, &cfg, &cluster)?;
    // The trace covers the whole iteration: the backward region's
    // transposed AlltoAlls and dgrad/wgrad lanes carry `bwd.*` tags. An
    // iteration program without them means the backward builder was
    // bypassed — fail loudly rather than emit a forward-only trace.
    use parm::sim::TaskKind;
    let bwd_comm = dag
        .tasks
        .iter()
        .filter(|t| t.tag.starts_with("bwd.") && matches!(t.kind, TaskKind::Transfer { .. }))
        .count();
    let bwd_compute = dag
        .tasks
        .iter()
        .filter(|t| t.tag.starts_with("bwd.") && matches!(t.kind, TaskKind::Compute { .. }))
        .count();
    anyhow::ensure!(
        bwd_comm + bwd_compute > 0,
        "iteration trace has no bwd.* tasks — backward program missing"
    );
    let trace = chrome_trace(&dag, &report);
    std::fs::write(a.req("out")?, trace.to_string())?;
    println!(
        "{} tasks, makespan {} → {}",
        dag.len(),
        fmt_seconds(report.makespan),
        a.req("out")?
    );
    println!("backward region: {bwd_comm} comm + {bwd_compute} compute bwd.* tasks");
    Ok(())
}

fn cmd_drive(rest: &[String]) -> Result<()> {
    let mut specs = LAYER_SPECS.to_vec();
    // The layer group's `--seed` has a default; drive's contract is "absent
    // means the trace spec's own seed", so re-declare it defaultless.
    specs.retain(|s| s.name != "seed");
    specs.extend_from_slice(&[
        Spec::opt("trace", "trace spec JSON (required; see examples/trace_*.json)"),
        Spec::opt("steps", "override the trace's step count"),
        Spec::opt_default(
            "threshold",
            "0.25",
            "hysteresis band in total-variation units (0 = re-decide every step)",
        ),
        Spec::opt_default(
            "switch-cost",
            "0.5",
            "schedule-switch cost as a fraction of the switching step's iteration time",
        ),
        Spec::opt("seed", "override the trace spec's seed (0 is a valid seed, not \"auto\")"),
        Spec::opt_default("threads", "1", "worker threads for the static baselines"),
        Spec::opt("log", "write the per-step decision log to PATH"),
        Spec::opt("json", "write the full outcome JSON to PATH (feeds `parm trace --drive`)"),
        Spec::opt(
            "bench-json",
            "merge the online-vs-static summary into the sweep bench JSON at PATH",
        ),
    ]);
    let a = Args::parse(rest, &specs)?;
    if help_guard(
        &a,
        "drive",
        "online adaptive control: re-span every step, switch schedule under a hysteresis band",
        &specs,
    ) {
        return Ok(());
    }
    let (cfg, cluster) = layer_from(&a)?;
    let mut spec = parm::config::TraceSpec::load(a.req("trace")?)?;
    if let Some(steps) = a.get_usize("steps")? {
        anyhow::ensure!(steps >= 1, "--steps must be ≥ 1");
        spec.steps = steps;
        spec.zero_steps.retain(|&s| s < steps);
    }
    // Plan-aware warm fits: with `--plan` no fitting happens at all — the
    // controller re-decides from the artifact's frozen α-β tables.
    let model = match plan_from(&a, &cluster)? {
        Some(plan) => plan.model_for(cfg.par).cloned().ok_or_else(|| {
            anyhow!(
                "--plan artifact lacks a fitted model for layout p={} mp={} esp={} — rebuild \
                 it with `parm plan build` over this grid",
                cfg.par.p,
                cfg.par.n_mp,
                cfg.par.n_esp
            )
        })?,
        None => PerfModel::fit(&cluster, cfg.par)?,
    };
    let threshold = a.get_f64("threshold")?.unwrap();
    let switch_frac = a.get_f64("switch-cost")?.unwrap();
    anyhow::ensure!(threshold >= 0.0, "--threshold must be ≥ 0");
    anyhow::ensure!(switch_frac >= 0.0, "--switch-cost must be ≥ 0");
    let threads = a.get_usize("threads")?.unwrap();
    anyhow::ensure!((1..=1024).contains(&threads), "--threads must be in 1..=1024");
    let opts = parm::control::DriveOptions {
        threshold,
        switch_frac,
        threads,
        seed: a.get_usize("seed")?.map(|s| s as u64),
    };
    let pred0 = selection::predict_with_loads(&model, &cfg, None);
    let candidates = parm::control::default_candidates(&pred0);
    let outcome = parm::control::drive(&spec, &cfg, &cluster, &model, &candidates, &opts)?;
    let log = outcome.decision_log();
    print!("{log}");
    let (best_kind, best_total) = outcome.best_static();
    println!(
        "online {} vs best static {} ({}): {:.3}× · {} switches · {} re-decisions over {} steps",
        fmt_seconds(outcome.online_total),
        fmt_seconds(best_total),
        best_kind.label(),
        best_total / outcome.online_total,
        outcome.switches,
        outcome.redecisions,
        outcome.steps.len()
    );
    if let Some(path) = a.get("log") {
        std::fs::write(path, &log)?;
        eprintln!("wrote decision log to {path}");
    }
    if let Some(path) = a.get("json") {
        std::fs::write(path, outcome.to_json().to_pretty())?;
        eprintln!("wrote drive outcome JSON to {path}");
    }
    if let Some(path) = a.get("bench-json") {
        parm::bench::merge_drive_summary(Path::new(path), &parm::bench::drive_summary(&outcome))?;
        eprintln!("merged drive summary into {path}");
    }
    Ok(())
}

/// `parm lint`: run the static schedule verifier over every builder
/// program of the sweep grid — all schedule families × forward/backward/
/// iteration × uniform and skewed load profiles — without executing any
/// of them. The `N programs verified, F findings` summary line is grepped
/// verbatim by CI's lint-schedules job; exit is nonzero on any finding.
fn cmd_lint(rest: &[String]) -> Result<()> {
    use parm::schedule::{builders, ops, verify};
    use parm::util::json::Json;
    let mut specs = vec![
        Spec::opt_default("cluster", "testbed_b", "cluster name or JSON path"),
        Spec::opt("cluster-json", "cluster topology JSON (overrides --cluster)"),
    ];
    specs.extend_from_slice(GRID_SPECS);
    specs.extend_from_slice(&[
        Spec::opt("json", "write the full findings report JSON to PATH"),
        Spec::opt(
            "bench-json",
            "merge program/finding counts (per rule) into the sweep bench JSON at PATH",
        ),
        Spec::flag("help", "show help"),
    ]);
    let a = Args::parse(rest, &specs)?;
    if help_guard(
        &a,
        "lint",
        "statically verify every builder op program over the sweep grid",
        &specs,
    ) {
        return Ok(());
    }
    let cluster = cluster_from(&a)?;
    let configs = sweep_configs(&a, &cluster)?;
    anyhow::ensure!(!configs.is_empty(), "no feasible configs to lint on {}", cluster.name);
    let mut programs = 0usize;
    let mut all: Vec<parm::schedule::VerifyError> = Vec::new();
    let mut reports: Vec<Json> = Vec::new();
    for cfg in &configs {
        let (r, _) = closedform::optimal_chunks(&cluster, cfg);
        let (r2, _) = closedform::optimal_chunks_sp2(&cluster, cfg);
        let kinds = [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::S2Aas,
            ScheduleKind::Pipelined { chunks: r },
            ScheduleKind::PipelinedUniform { chunks: r },
            ScheduleKind::PipelinedS2 { chunks: r2 },
        ];
        // The skewed profile exercises the load-aware span policy:
        // harmonic routing weights through the same gate model the
        // traffic layer uses for drifting traces.
        let w: Vec<f64> = (0..cfg.e).map(|i| 1.0 / (i + 1) as f64).collect();
        let skewed = ops::loads_from_weights(cfg, cfg.t_pausemp(), &w);
        for kind in kinds {
            for loads in [None, Some(skewed.as_slice())] {
                let built = [
                    ("forward", builders::forward_ops_measured(kind, cfg, loads)),
                    ("backward", builders::backward_ops_measured(kind, cfg, loads)),
                    ("iteration", builders::iteration_ops_measured(kind, cfg, loads)),
                ];
                for (dir, program) in built {
                    programs += 1;
                    let mut findings =
                        verify::verify_program(&program, cfg, &cluster, verify::Plane::Timing);
                    if dir == "forward" {
                        // Forward programs also run on the data plane —
                        // prove they carry no backward-only ops.
                        findings.extend(verify::plane_findings(&program, verify::Plane::Data));
                    }
                    for f in &findings {
                        reports.push(Json::obj(vec![
                            ("cfg", Json::str(&cfg.id())),
                            ("schedule", Json::str(&kind.label())),
                            ("direction", Json::str(dir)),
                            (
                                "loads",
                                Json::str(if loads.is_some() { "skewed" } else { "uniform" }),
                            ),
                            ("rule", Json::str(f.rule.id())),
                            ("op", f.op_index.map(|i| Json::num(i as f64)).unwrap_or(Json::Null)),
                            ("message", Json::str(&f.message)),
                        ]));
                    }
                    all.extend(findings);
                }
            }
        }
    }
    let counts = parm::schedule::rule_counts(&all);
    // CI greps this line verbatim — keep the format stable.
    println!("{programs} programs verified, {} findings", all.len());
    for (rule, n) in &counts {
        println!("  {rule:<20} {n}");
    }
    for r in &reports {
        eprintln!(
            "finding: {} {} {} ({}): [{}] {}",
            r.get("cfg").as_str().unwrap_or("?"),
            r.get("schedule").as_str().unwrap_or("?"),
            r.get("direction").as_str().unwrap_or("?"),
            r.get("loads").as_str().unwrap_or("?"),
            r.get("rule").as_str().unwrap_or("?"),
            r.get("message").as_str().unwrap_or("?"),
        );
    }
    let per_rule =
        Json::Obj(counts.iter().map(|(k, v)| (k.to_string(), Json::num(*v as f64))).collect());
    if let Some(path) = a.get("json") {
        let doc = Json::obj(vec![
            ("cluster", Json::str(&cluster.name)),
            ("configs", Json::num(configs.len() as f64)),
            ("programs", Json::num(programs as f64)),
            ("findings", Json::num(all.len() as f64)),
            ("per_rule", per_rule.clone()),
            ("reports", Json::Arr(reports)),
        ]);
        std::fs::write(path, doc.to_pretty())?;
        eprintln!("wrote lint report JSON to {path}");
    }
    if let Some(path) = a.get("bench-json") {
        let summary = Json::obj(vec![
            ("cluster", Json::str(&cluster.name)),
            ("programs", Json::num(programs as f64)),
            ("findings", Json::num(all.len() as f64)),
            ("per_rule", per_rule),
        ]);
        parm::bench::merge_lint_summary(Path::new(path), &summary)?;
        eprintln!("merged lint summary into {path}");
    }
    anyhow::ensure!(all.is_empty(), "schedule lint failed: {} findings", all.len());
    Ok(())
}
