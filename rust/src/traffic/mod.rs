//! Drifting-traffic scenario engine: turns a [`TraceSpec`] into per-step
//! expert-load vectors and per-step (jittered) clusters.
//!
//! The online control plane needs iteration-varying routing statistics the
//! static `--skew` knob cannot express: diurnal load curves, bursty
//! hot-expert flips, a Zipf skew that drifts over the run, and stragglers
//! appearing on nodes and links. This module generates them — every step
//! is a **pure function** of `(spec, step)`, with randomness drawn from a
//! stateless per-step stream ([`stream`]) so two runs with the same seed
//! produce identical traces at any thread count and steps can be
//! materialized in any order.
//!
//! Composition at step `t`: the Zipf carrier at `spec.skew_at(t)` (drift
//! ramp + diurnal term), the burst seat's weight boosted and rotated to
//! the front, multiplicative per-expert noise, then
//! [`ops::loads_from_weights`] converts routing weights into the
//! per-expert load vector the span/pricing plumbing consumes. `zero_steps`
//! short-circuit to an all-zero vector (the all-zero→expected fallback's
//! trigger). Jitter rebuilds the cluster with slowed nodes/links; node 0
//! is never slowed so the bottleneck can genuinely move.

use anyhow::Result;

use crate::config::trace::TraceSpec;
use crate::config::{AlphaBeta, ClusterTopology, MoeLayerConfig, NodeSpec};
use crate::schedule::ops;
use crate::util::prng::{splitmix64, Rng};

/// Salt for the per-expert weight-noise stream.
const SALT_NOISE: u64 = 0x6e6f697365; // "noise"
/// Salt for the node/link jitter stream.
const SALT_JITTER: u64 = 0x6a697474; // "jitt"

/// Stateless per-step RNG: `(seed, step, salt)` are mixed through
/// SplitMix64 into a fresh Xoshiro state, so stream `t` never depends on
/// how many draws stream `t-1` made — the determinism the byte-identical
/// decision-log guarantee rests on.
pub fn stream(seed: u64, step: usize, salt: u64) -> Rng {
    let mut s = seed;
    let base = splitmix64(&mut s);
    let mut mix = base ^ (step as u64).wrapping_mul(0xA24BAED4963EE407) ^ salt;
    Rng::new(splitmix64(&mut mix))
}

/// Per-expert routing weights at `step` (before capacity conversion):
/// Zipf carrier, burst rotation/boost, multiplicative noise.
pub fn step_weights(spec: &TraceSpec, c: &MoeLayerConfig, step: usize) -> Vec<f64> {
    let skew = spec.skew_at(step);
    let zipf: Vec<f64> = (0..c.e).map(|j| ((j + 1) as f64).powf(-skew)).collect();
    let mut w = vec![0.0f64; c.e];
    let hot = match spec.burst_at(step) {
        Some((seat, _)) => seat % c.e,
        None => 0,
    };
    // Rotate the curve so the burst seat takes the head rank; outside a
    // burst window `hot == 0` and this is the identity.
    for (j, &z) in zipf.iter().enumerate() {
        w[(hot + j) % c.e] = z;
    }
    if let Some((_, boost)) = spec.burst_at(step) {
        w[hot] *= boost;
    }
    if spec.noise > 0.0 {
        let mut rng = stream(spec.seed, step, SALT_NOISE);
        for wj in w.iter_mut() {
            *wj *= 1.0 + spec.noise * (2.0 * rng.f64() - 1.0);
        }
    }
    w
}

/// The measured-style per-expert load vector at `step`: all zeros on a
/// `zero_steps` entry, otherwise the step weights pushed through the
/// shared top-k fill model at the PauseMP capacity.
pub fn step_loads(spec: &TraceSpec, c: &MoeLayerConfig, step: usize) -> Vec<usize> {
    if spec.zero_steps.contains(&step) {
        return vec![0; c.e];
    }
    let w = step_weights(spec, c, step);
    ops::loads_from_weights(c, c.t_pausemp(), &w)
}

/// The cluster in effect at `step`: the base topology with this step's
/// straggler draws applied. Without a jitter clause (or with both factors
/// zero) the base is cloned untouched. Node `i > 0` divides its FLOPs by
/// `1 + node·u` and scales both of its links' α/β by `1 + link·u`
/// (uniform per-node λ, preserving the intra ≤ inter validation).
pub fn step_cluster(
    spec: &TraceSpec,
    base: &ClusterTopology,
    step: usize,
) -> Result<ClusterTopology> {
    let jit = match spec.jitter {
        Some(j) if j.node > 0.0 || j.link > 0.0 => j,
        _ => return Ok(base.clone()),
    };
    let mut rng = stream(spec.seed, step, SALT_JITTER);
    let nodes: Vec<NodeSpec> = base
        .node_specs()
        .iter()
        .enumerate()
        .map(|(i, ns)| {
            // Fixed draw order (node slow, then link λ) per node keeps the
            // stream layout independent of which factors are enabled.
            let slow = 1.0 + jit.node * rng.f64();
            let lambda = 1.0 + jit.link * rng.f64();
            if i == 0 {
                return *ns;
            }
            let scale = |ab: AlphaBeta| AlphaBeta::new(ab.alpha * lambda, ab.beta * lambda);
            NodeSpec {
                gpu_flops: ns.gpu_flops / slow,
                intra: scale(ns.intra),
                inter: scale(ns.inter),
                ..*ns
            }
        })
        .collect();
    ClusterTopology::new(&base.name, nodes)
}

/// One materialized trace step: the loads the router produced and the
/// cluster the iteration ran on.
#[derive(Debug, Clone)]
pub struct TrafficStep {
    pub loads: Vec<usize>,
    pub cluster: ClusterTopology,
}

/// Materialize the whole trace up front (steps are independent, so this
/// is just a map; the control loop and the static baselines index into
/// one shared copy).
pub fn materialize(
    spec: &TraceSpec,
    c: &MoeLayerConfig,
    base: &ClusterTopology,
) -> Result<Vec<TrafficStep>> {
    (0..spec.steps)
        .map(|t| {
            Ok(TrafficStep { loads: step_loads(spec, c, t), cluster: step_cluster(spec, base, t)? })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::trace::{Bursty, Jitter};
    use crate::util::json::Json;

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig::test_default()
    }

    fn spec(text: &str) -> TraceSpec {
        TraceSpec::from_json(&Json::parse(text).unwrap()).unwrap()
    }

    #[test]
    fn streams_are_stateless_and_salted() {
        let mut a = stream(7, 3, SALT_NOISE);
        let mut b = stream(7, 3, SALT_NOISE);
        assert_eq!(a.next_u64(), b.next_u64(), "same (seed, step, salt) → same stream");
        let mut c = stream(7, 4, SALT_NOISE);
        let mut d = stream(7, 3, SALT_JITTER);
        let v = stream(7, 3, SALT_NOISE).next_u64();
        assert_ne!(v, c.next_u64(), "steps diverge");
        assert_ne!(v, d.next_u64(), "salts diverge");
    }

    #[test]
    fn drifting_trace_is_deterministic_and_tracks_skew() {
        let s = spec(
            r#"{"name": "d", "steps": 6, "seed": 11,
                "drift": {"from": 0.2, "to": 2.5}, "noise": 0.05}"#,
        );
        let c = cfg();
        let a: Vec<Vec<usize>> = (0..s.steps).map(|t| step_loads(&s, &c, t)).collect();
        let b: Vec<Vec<usize>> = (0..s.steps).map(|t| step_loads(&s, &c, t)).collect();
        assert_eq!(a, b, "same spec → identical trace");
        // Rising skew concentrates routing: the tail expert's load shrinks
        // from the first to the last step.
        let e = c.e;
        assert!(a[s.steps - 1][e - 1] < a[0][e - 1], "{a:?}");
        // And total routed mass shrinks with concentration.
        let sum = |v: &Vec<usize>| v.iter().sum::<usize>();
        assert!(sum(&a[s.steps - 1]) < sum(&a[0]), "{a:?}");
    }

    #[test]
    fn burst_rotates_the_hot_seat() {
        let mut s = spec(r#"{"name": "b", "steps": 12, "base_skew": 1.0}"#);
        s.bursty = Some(Bursty { every: 4, hold: 2, boost: 4.0 });
        let c = cfg();
        // Step 1 is inside window 0 (hot = 0), step 5 inside window 1
        // (hot = 1): the argmax load follows the seat.
        let argmax = |v: &[usize]| {
            v.iter().enumerate().max_by_key(|&(i, &l)| (l, std::cmp::Reverse(i))).unwrap().0
        };
        assert_eq!(argmax(&step_loads(&s, &c, 1)), 0);
        assert_eq!(argmax(&step_loads(&s, &c, 5)), 1);
        // Outside the window the plain Zipf head leads again.
        assert_eq!(argmax(&step_loads(&s, &c, 3)), 0);
    }

    #[test]
    fn zero_steps_produce_all_zero_loads() {
        let s = spec(
            r#"{"name": "z", "steps": 4, "base_skew": 1.0, "zero_steps": [2]}"#,
        );
        let c = cfg();
        assert!(step_loads(&s, &c, 2).iter().all(|&l| l == 0));
        assert!(step_loads(&s, &c, 1).iter().sum::<usize>() > 0);
    }

    #[test]
    fn jitter_slows_nodes_but_spares_node_zero() {
        let base = ClusterTopology::testbed_b_subset(8).unwrap();
        let mut s = spec(r#"{"name": "j", "steps": 3, "seed": 5}"#);
        s.jitter = Some(Jitter { node: 0.5, link: 0.5 });
        let jit = step_cluster(&s, &base, 1).unwrap();
        assert_eq!(jit.node_specs().len(), base.node_specs().len());
        let b0 = base.node_specs()[0];
        let j0 = jit.node_specs()[0];
        assert_eq!(j0, b0, "node 0 is never slowed");
        for (i, (j, b)) in jit.node_specs().iter().zip(base.node_specs()).enumerate().skip(1) {
            assert!(j.gpu_flops < b.gpu_flops, "node {i} flops");
            assert!(j.inter.beta >= b.inter.beta, "node {i} link");
            assert!(j.intra.beta <= j.inter.beta, "node {i} keeps link ordering");
        }
        // Determinism and per-step divergence.
        let again = step_cluster(&s, &base, 1).unwrap();
        assert_eq!(again.node_specs(), jit.node_specs());
        let other = step_cluster(&s, &base, 2).unwrap();
        assert_ne!(other.node_specs()[1].gpu_flops, jit.node_specs()[1].gpu_flops);
        // No jitter clause → the base comes back untouched.
        let plain = spec(r#"{"name": "p", "steps": 3}"#);
        assert_eq!(step_cluster(&plain, &base, 1).unwrap().node_specs(), base.node_specs());
    }

    #[test]
    fn materialize_covers_every_step() {
        let base = ClusterTopology::testbed_b_subset(8).unwrap();
        let s = spec(r#"{"name": "m", "steps": 5, "drift": {"from": 0.5, "to": 1.5}}"#);
        let c = cfg();
        let steps = materialize(&s, &c, &base).unwrap();
        assert_eq!(steps.len(), 5);
        for (t, st) in steps.iter().enumerate() {
            assert_eq!(st.loads, step_loads(&s, &c, t));
            assert_eq!(st.loads.len(), c.e);
        }
    }
}
