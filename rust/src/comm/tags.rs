//! Canonical tag constants — the ONE vocabulary every plane uses to label
//! communication and compute:
//!
//! * the schedule IR ([`crate::schedule::ops::Op::tag`]),
//! * the generic collective algorithms ([`crate::comm::algo`]) via the tags
//!   threaded through [`crate::comm::transport::Transport::send`],
//! * the simulator's per-tag accounting
//!   ([`crate::sim::engine::SimReport::seconds_for_tag`]), and
//! * the data-plane communication log
//!   ([`crate::moe::exec::ExecResult::comm_log`]).
//!
//! Because both transports run the *same* algorithm source with the *same*
//! constants, a sweep report's tag breakdown and an executor's comm log can
//! be diffed mechanically — no string re-derivation on either side.

/// ESP-group AllGather of the layer input (baseline Fig 3a step 1).
pub const ESP_ALLGATHER: &str = "esp.allgather";
/// EP-group pairwise AlltoAll (baseline dispatch/combine).
pub const EP_ALLTOALL: &str = "ep.alltoall";
/// ESP-group AllReduce of shard-partial expert outputs (baseline).
pub const ESP_ALLREDUCE: &str = "esp.allreduce";
/// ESP-group ReduceScatter (backward of the ESP-AllGather).
pub const ESP_REDUCESCATTER: &str = "esp.reducescatter";
/// MP-group ReduceScatter (backward of the MP-AllGather).
pub const MP_REDUCESCATTER: &str = "mp.reducescatter";
/// Local ESP split (free forward; AllGather in backward).
pub const ESP_SPLIT: &str = "esp.split";
/// Local MP split — PauseMP's entry point (free forward).
pub const MP_SPLIT: &str = "mp.split";
/// MP-group ring AllGather (S1's token restore / S2's capacity restore).
pub const MP_ALLGATHER: &str = "mp.allgather";
/// Parm's fused EP&ESP-AlltoAll over the product group (§III-C).
pub const FUSED_ALLTOALL: &str = "fused.alltoall";
/// S2's SAA-overlapped combine (fused AlltoAll + MP-AllGather, §III-D).
pub const SAA_COMBINE: &str = "saa.combine";
/// The sequential (non-overlapped) combine — the AAS ablation (§VI-C).
pub const AAS_COMBINE: &str = "aas.combine";
/// Upper bound on SP pipeline chunks. Bounded so every chunk keeps a
/// distinct `'static` tag (the whole tag vocabulary stays allocation-free)
/// and so the closed-form chunk search in
/// [`crate::perfmodel::closedform::optimal_chunks`] is a fixed small scan.
pub const SP_MAX_CHUNKS: usize = 8;
/// SP dispatch AlltoAll of chunk k (`sp.dispatch.k`) — the fused
/// EP&ESP-AlltoAll restricted to one capacity span of the pipelined
/// schedule.
pub const SP_DISPATCH: [&str; SP_MAX_CHUNKS] = [
    "sp.dispatch.0",
    "sp.dispatch.1",
    "sp.dispatch.2",
    "sp.dispatch.3",
    "sp.dispatch.4",
    "sp.dispatch.5",
    "sp.dispatch.6",
    "sp.dispatch.7",
];
/// SP expert-FFN compute of chunk k (`sp.ffn.k`).
pub const SP_FFN: [&str; SP_MAX_CHUNKS] = [
    "sp.ffn.0",
    "sp.ffn.1",
    "sp.ffn.2",
    "sp.ffn.3",
    "sp.ffn.4",
    "sp.ffn.5",
    "sp.ffn.6",
    "sp.ffn.7",
];
/// SP combine AlltoAll of chunk k (`sp.combine.k`).
pub const SP_COMBINE: [&str; SP_MAX_CHUNKS] = [
    "sp.combine.0",
    "sp.combine.1",
    "sp.combine.2",
    "sp.combine.3",
    "sp.combine.4",
    "sp.combine.5",
    "sp.combine.6",
    "sp.combine.7",
];
/// SP2 dispatch AlltoAll of chunk k (`sp2.dispatch.k`) — the pipelined-S2
/// schedule's fused EP&ESP-AlltoAll restricted to one capacity span of the
/// MP-split dispatch tensor.
pub const SP2_DISPATCH: [&str; SP_MAX_CHUNKS] = [
    "sp2.dispatch.0",
    "sp2.dispatch.1",
    "sp2.dispatch.2",
    "sp2.dispatch.3",
    "sp2.dispatch.4",
    "sp2.dispatch.5",
    "sp2.dispatch.6",
    "sp2.dispatch.7",
];
/// SP2 expert-FFN compute of chunk k (`sp2.ffn.k`).
pub const SP2_FFN: [&str; SP_MAX_CHUNKS] = [
    "sp2.ffn.0",
    "sp2.ffn.1",
    "sp2.ffn.2",
    "sp2.ffn.3",
    "sp2.ffn.4",
    "sp2.ffn.5",
    "sp2.ffn.6",
    "sp2.ffn.7",
];
/// SP2 chunked-SAA combine of chunk k (`sp2.saa.k`): the chunk's combine
/// AlltoAll, whose phases forward into the MP-AllGather (the forwards are
/// logged under [`MP_ALLGATHER`], exactly like the monolithic SAA).
pub const SP2_SAA: [&str; SP_MAX_CHUNKS] = [
    "sp2.saa.0",
    "sp2.saa.1",
    "sp2.saa.2",
    "sp2.saa.3",
    "sp2.saa.4",
    "sp2.saa.5",
    "sp2.saa.6",
    "sp2.saa.7",
];
/// Backward EP-group AlltoAll, dispatch direction (`bwd.ep.dispatch`):
/// the transpose of the baseline's forward *combine* AlltoAll, carrying
/// the output gradients dY back to the expert-hosting ranks. Same
/// per-pair volume as its forward counterpart — transposition reverses
/// direction, not bytes.
pub const BWD_EP_DISPATCH: &str = "bwd.ep.dispatch";
/// Backward EP-group AlltoAll, combine direction (`bwd.ep.combine`): the
/// transpose of the baseline's forward *dispatch* AlltoAll, returning the
/// input gradients dX to the token-owning ranks.
pub const BWD_EP_COMBINE: &str = "bwd.ep.combine";
/// Backward fused EP&ESP-AlltoAll, dispatch direction — the transpose of
/// S1/S2's forward combine leg (carries dY to the experts).
pub const BWD_FUSED_DISPATCH: &str = "bwd.fused.dispatch";
/// Backward fused EP&ESP-AlltoAll, combine direction — the transpose of
/// S1/S2's forward dispatch leg (returns dX).
pub const BWD_FUSED_COMBINE: &str = "bwd.fused.combine";
/// Expert FFN activation-gradient (dgrad) compute of the backward pass.
pub const BWD_EXPERT_DGRAD: &str = "bwd.expert.dgrad";
/// Expert FFN weight-gradient (wgrad) compute of the backward pass.
pub const BWD_EXPERT_WGRAD: &str = "bwd.expert.wgrad";
/// ESP-group AllReduce of the expert weight gradients. Scheduled to
/// overlap the remaining backward ops (the deferred-completion path in
/// [`crate::schedule::interp`]) unless the builder asked for the
/// non-overlapped lowering.
pub const BWD_WGRAD_ALLREDUCE: &str = "bwd.wgrad.allreduce";
/// Backward SP dispatch AlltoAll of chunk k (`bwd.sp.dispatch.k`) — the
/// transpose of forward `sp.combine.k`, carrying that chunk's dY.
pub const BWD_SP_DISPATCH: [&str; SP_MAX_CHUNKS] = [
    "bwd.sp.dispatch.0",
    "bwd.sp.dispatch.1",
    "bwd.sp.dispatch.2",
    "bwd.sp.dispatch.3",
    "bwd.sp.dispatch.4",
    "bwd.sp.dispatch.5",
    "bwd.sp.dispatch.6",
    "bwd.sp.dispatch.7",
];
/// Backward SP dgrad compute of chunk k (`bwd.sp.dgrad.k`).
pub const BWD_SP_DGRAD: [&str; SP_MAX_CHUNKS] = [
    "bwd.sp.dgrad.0",
    "bwd.sp.dgrad.1",
    "bwd.sp.dgrad.2",
    "bwd.sp.dgrad.3",
    "bwd.sp.dgrad.4",
    "bwd.sp.dgrad.5",
    "bwd.sp.dgrad.6",
    "bwd.sp.dgrad.7",
];
/// Backward SP wgrad compute of chunk k (`bwd.sp.wgrad.k`) — chains the
/// compute stream only; the chunk's combine does not wait on it.
pub const BWD_SP_WGRAD: [&str; SP_MAX_CHUNKS] = [
    "bwd.sp.wgrad.0",
    "bwd.sp.wgrad.1",
    "bwd.sp.wgrad.2",
    "bwd.sp.wgrad.3",
    "bwd.sp.wgrad.4",
    "bwd.sp.wgrad.5",
    "bwd.sp.wgrad.6",
    "bwd.sp.wgrad.7",
];
/// Backward SP combine AlltoAll of chunk k (`bwd.sp.combine.k`) — the
/// transpose of forward `sp.dispatch.k`, returning that chunk's dX.
pub const BWD_SP_COMBINE: [&str; SP_MAX_CHUNKS] = [
    "bwd.sp.combine.0",
    "bwd.sp.combine.1",
    "bwd.sp.combine.2",
    "bwd.sp.combine.3",
    "bwd.sp.combine.4",
    "bwd.sp.combine.5",
    "bwd.sp.combine.6",
    "bwd.sp.combine.7",
];
/// Backward SP2 dispatch AlltoAll of chunk k — the transpose of forward
/// `sp2.saa.k`'s AlltoAll phase (the SAA's MP-AllGather adjoint runs once
/// up front as an MP-ReduceScatter).
pub const BWD_SP2_DISPATCH: [&str; SP_MAX_CHUNKS] = [
    "bwd.sp2.dispatch.0",
    "bwd.sp2.dispatch.1",
    "bwd.sp2.dispatch.2",
    "bwd.sp2.dispatch.3",
    "bwd.sp2.dispatch.4",
    "bwd.sp2.dispatch.5",
    "bwd.sp2.dispatch.6",
    "bwd.sp2.dispatch.7",
];
/// Backward SP2 dgrad compute of chunk k (`bwd.sp2.dgrad.k`).
pub const BWD_SP2_DGRAD: [&str; SP_MAX_CHUNKS] = [
    "bwd.sp2.dgrad.0",
    "bwd.sp2.dgrad.1",
    "bwd.sp2.dgrad.2",
    "bwd.sp2.dgrad.3",
    "bwd.sp2.dgrad.4",
    "bwd.sp2.dgrad.5",
    "bwd.sp2.dgrad.6",
    "bwd.sp2.dgrad.7",
];
/// Backward SP2 wgrad compute of chunk k (`bwd.sp2.wgrad.k`).
pub const BWD_SP2_WGRAD: [&str; SP_MAX_CHUNKS] = [
    "bwd.sp2.wgrad.0",
    "bwd.sp2.wgrad.1",
    "bwd.sp2.wgrad.2",
    "bwd.sp2.wgrad.3",
    "bwd.sp2.wgrad.4",
    "bwd.sp2.wgrad.5",
    "bwd.sp2.wgrad.6",
    "bwd.sp2.wgrad.7",
];
/// Backward SP2 combine AlltoAll of chunk k — the transpose of forward
/// `sp2.dispatch.k`.
pub const BWD_SP2_COMBINE: [&str; SP_MAX_CHUNKS] = [
    "bwd.sp2.combine.0",
    "bwd.sp2.combine.1",
    "bwd.sp2.combine.2",
    "bwd.sp2.combine.3",
    "bwd.sp2.combine.4",
    "bwd.sp2.combine.5",
    "bwd.sp2.combine.6",
    "bwd.sp2.combine.7",
];
/// Gating network + top-k routing (compute).
pub const GATE: &str = "gate";
/// The complete tag vocabulary, scalar constants first, then every
/// per-chunk array in declaration order. The schedule verifier
/// ([`crate::schedule::verify`]) checks each emitted tag against this
/// list — a new tag constant must be added here to be considered
/// well-formed.
pub fn all() -> Vec<&'static str> {
    let mut v = vec![
        ESP_ALLGATHER,
        EP_ALLTOALL,
        ESP_ALLREDUCE,
        ESP_REDUCESCATTER,
        MP_REDUCESCATTER,
        ESP_SPLIT,
        MP_SPLIT,
        MP_ALLGATHER,
        FUSED_ALLTOALL,
        SAA_COMBINE,
        AAS_COMBINE,
        BWD_EP_DISPATCH,
        BWD_EP_COMBINE,
        BWD_FUSED_DISPATCH,
        BWD_FUSED_COMBINE,
        BWD_EXPERT_DGRAD,
        BWD_EXPERT_WGRAD,
        BWD_WGRAD_ALLREDUCE,
        GATE,
        EXPERT_FFN,
        LOCAL_COMBINE,
        UNGATE,
    ];
    for arr in [
        &SP_DISPATCH,
        &SP_FFN,
        &SP_COMBINE,
        &SP2_DISPATCH,
        &SP2_FFN,
        &SP2_SAA,
        &BWD_SP_DISPATCH,
        &BWD_SP_DGRAD,
        &BWD_SP_WGRAD,
        &BWD_SP_COMBINE,
        &BWD_SP2_DISPATCH,
        &BWD_SP2_DGRAD,
        &BWD_SP2_WGRAD,
        &BWD_SP2_COMBINE,
    ] {
        v.extend(arr.iter().copied());
    }
    v
}
/// Expert FFN shards (compute).
pub const EXPERT_FFN: &str = "expert.ffn";
/// Local partial-sum combine of the N_ESP returned copies (compute).
pub const LOCAL_COMBINE: &str = "local.combine";
/// Scatter combined outputs back into token order (compute).
pub const UNGATE: &str = "ungate";
