//! Data-plane collectives over in-process rank buffers.
//!
//! `world[r]` is rank `r`'s local buffer. A collective takes the world and
//! a *group* (an ordered list of distinct rank ids); only group members'
//! buffers are touched. Semantics follow NCCL/MPI conventions:
//!
//! * `allgather`  — every member ends with the concatenation of all
//!   members' inputs, in group order.
//! * `reduce_scatter` — inputs (equal length, divisible by g) are summed
//!   elementwise; member `j` keeps the `j`-th 1/g chunk of the sum.
//! * `allreduce` — elementwise sum, everyone gets the full result
//!   (implemented as reduce-scatter ∘ allgather, as in [21,22] of the
//!   paper — the identity Eq. (6) relies on).
//! * `alltoall` — member `i`'s input is split into g chunks; chunk `j`
//!   goes to member `j`; member `j` ends with `[chunk_j of member 0, …,
//!   chunk_j of member g-1]`. An involution when chunk sizes are uniform.
//! * `split` — local: member `j` keeps its `j`-th 1/g chunk (the ESP-Split
//!   of Fig 3a; communication-free in forward).

/// Validate a group: non-empty, distinct, in range.
fn check_group(world_len: usize, group: &[usize]) {
    assert!(!group.is_empty(), "empty group");
    for (i, &r) in group.iter().enumerate() {
        assert!(r < world_len, "rank {r} outside world of {world_len}");
        assert!(!group[..i].contains(&r), "duplicate rank {r} in group");
    }
}

fn check_equal_lengths(world: &[Vec<f32>], group: &[usize]) -> usize {
    let n = world[group[0]].len();
    for &r in group {
        assert_eq!(world[r].len(), n, "buffer length mismatch within group");
    }
    n
}

/// AllGather within `group` (in-place on the world).
pub fn allgather(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let n = check_equal_lengths(world, group);
    let mut gathered = Vec::with_capacity(n * group.len());
    for &r in group {
        gathered.extend_from_slice(&world[r]);
    }
    for &r in group {
        world[r] = gathered.clone();
    }
}

/// ReduceScatter (sum) within `group`.
pub fn reduce_scatter(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let n = check_equal_lengths(world, group);
    let g = group.len();
    assert_eq!(n % g, 0, "reduce_scatter needs length divisible by group size");
    let chunk = n / g;
    let mut sum = vec![0.0f32; n];
    for &r in group {
        for (s, v) in sum.iter_mut().zip(world[r].iter()) {
            *s += v;
        }
    }
    for (j, &r) in group.iter().enumerate() {
        world[r] = sum[j * chunk..(j + 1) * chunk].to_vec();
    }
}

/// AllReduce (sum) within `group` = ReduceScatter ∘ AllGather.
pub fn allreduce(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let n = check_equal_lengths(world, group);
    let g = group.len();
    if n % g == 0 && n > 0 {
        reduce_scatter(world, group);
        allgather(world, group);
    } else {
        // Lengths not divisible by g: direct elementwise sum (semantically
        // identical; the RS∘AG decomposition is a wire-level detail).
        let mut sum = vec![0.0f32; n];
        for &r in group {
            for (s, v) in sum.iter_mut().zip(world[r].iter()) {
                *s += v;
            }
        }
        for &r in group {
            world[r] = sum.clone();
        }
    }
}

/// AlltoAll within `group`.
pub fn alltoall(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let n = check_equal_lengths(world, group);
    let g = group.len();
    assert_eq!(n % g, 0, "alltoall needs length divisible by group size");
    let chunk = n / g;
    let mut outputs: Vec<Vec<f32>> = vec![Vec::with_capacity(n); g];
    for (j, out) in outputs.iter_mut().enumerate() {
        for &ri in group.iter() {
            out.extend_from_slice(&world[ri][j * chunk..(j + 1) * chunk]);
        }
    }
    for (j, &r) in group.iter().enumerate() {
        world[r] = std::mem::take(&mut outputs[j]);
    }
}

/// Local Split: member `j` keeps its `j`-th 1/g chunk (no communication in
/// forward; its backward is an AllGather — handled by the schedules).
pub fn split(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let n = check_equal_lengths(world, group);
    let g = group.len();
    assert_eq!(n % g, 0, "split needs length divisible by group size");
    let chunk = n / g;
    for (j, &r) in group.iter().enumerate() {
        world[r] = world[r][j * chunk..(j + 1) * chunk].to_vec();
    }
}

/// Broadcast member 0's buffer to the whole group (used to set up the
/// MP-duplicated activations entering a MoE layer in tests).
pub fn broadcast(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let src = world[group[0]].clone();
    for &r in &group[1..] {
        world[r] = src.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{assert_close, assert_eq_slice, check};

    fn world_of(bufs: &[&[f32]]) -> Vec<Vec<f32>> {
        bufs.iter().map(|b| b.to_vec()).collect()
    }

    #[test]
    fn allgather_concats_in_group_order() {
        let mut w = world_of(&[&[1.0, 2.0], &[3.0, 4.0], &[9.0, 9.0]]);
        allgather(&mut w, &[1, 0]);
        assert_eq!(w[1], vec![3.0, 4.0, 1.0, 2.0]);
        assert_eq!(w[0], vec![3.0, 4.0, 1.0, 2.0]);
        assert_eq!(w[2], vec![9.0, 9.0]); // untouched
    }

    #[test]
    fn reduce_scatter_sums_and_scatters() {
        let mut w = world_of(&[&[1.0, 2.0, 3.0, 4.0], &[10.0, 20.0, 30.0, 40.0]]);
        reduce_scatter(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![11.0, 22.0]);
        assert_eq!(w[1], vec![33.0, 44.0]);
    }

    #[test]
    fn allreduce_everyone_gets_sum() {
        let mut w = world_of(&[&[1.0, 2.0], &[3.0, 5.0]]);
        allreduce(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![4.0, 7.0]);
        assert_eq!(w[1], vec![4.0, 7.0]);
    }

    #[test]
    fn allreduce_odd_length() {
        let mut w = world_of(&[&[1.0, 2.0, 3.0], &[1.0, 1.0, 1.0]]);
        allreduce(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn alltoall_is_block_transpose() {
        let mut w = world_of(&[&[1.0, 2.0], &[3.0, 4.0]]);
        alltoall(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![1.0, 3.0]);
        assert_eq!(w[1], vec![2.0, 4.0]);
    }

    #[test]
    fn split_keeps_own_chunk() {
        let mut w = world_of(&[&[1.0, 2.0, 3.0, 4.0], &[1.0, 2.0, 3.0, 4.0]]);
        split(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![1.0, 2.0]);
        assert_eq!(w[1], vec![3.0, 4.0]);
    }

    #[test]
    fn broadcast_duplicates_leader() {
        let mut w = world_of(&[&[7.0], &[0.0], &[0.0]]);
        broadcast(&mut w, &[0, 2]);
        assert_eq!(w[2], vec![7.0]);
        assert_eq!(w[1], vec![0.0]);
    }

    // ---- property tests ---------------------------------------------------

    fn random_world(rng: &mut crate::util::prng::Rng, g: usize, per: usize) -> Vec<Vec<f32>> {
        (0..g).map(|_| rng.f32_vec(per)).collect()
    }

    #[test]
    fn prop_alltoall_involution() {
        check("alltoall-involution", 50, |rng| {
            let g = rng.range(1, 6);
            let chunk = rng.range(1, 8);
            let mut w = random_world(rng, g, g * chunk);
            let orig = w.clone();
            let group: Vec<usize> = (0..g).collect();
            alltoall(&mut w, &group);
            alltoall(&mut w, &group);
            for r in 0..g {
                assert_eq_slice(&w[r], &orig[r])?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_allreduce_equals_rs_then_ag() {
        check("allreduce-rs-ag", 50, |rng| {
            let g = rng.range(1, 6);
            let chunk = rng.range(1, 8);
            let group: Vec<usize> = (0..g).collect();
            let w0 = random_world(rng, g, g * chunk);
            let mut a = w0.clone();
            allreduce(&mut a, &group);
            let mut b = w0.clone();
            reduce_scatter(&mut b, &group);
            allgather(&mut b, &group);
            for r in 0..g {
                assert_close(&a[r], &b[r], 1e-5, 1e-5)?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_allgather_then_split_identity() {
        check("ag-split-id", 50, |rng| {
            let g = rng.range(1, 6);
            let per = rng.range(1, 10);
            let group: Vec<usize> = (0..g).collect();
            let w0 = random_world(rng, g, per);
            let mut w = w0.clone();
            allgather(&mut w, &group);
            split(&mut w, &group);
            for r in 0..g {
                assert_eq_slice(&w[r], &w0[r])?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_alltoall_conserves_multiset() {
        check("alltoall-conserves", 30, |rng| {
            let g = rng.range(1, 5);
            let chunk = rng.range(1, 6);
            let group: Vec<usize> = (0..g).collect();
            let w0 = random_world(rng, g, g * chunk);
            let mut w = w0.clone();
            alltoall(&mut w, &group);
            let mut before: Vec<u32> = w0.iter().flatten().map(|f| f.to_bits()).collect();
            let mut after: Vec<u32> = w.iter().flatten().map(|f| f.to_bits()).collect();
            before.sort_unstable();
            after.sort_unstable();
            assert_eq_slice(&after, &before)
        });
    }

    #[test]
    fn prop_groups_are_order_sensitive_but_consistent() {
        // AllGather with a permuted group concatenates in that order.
        check("ag-order", 30, |rng| {
            let g = rng.range(2, 5);
            let per = rng.range(1, 5);
            let mut group: Vec<usize> = (0..g).collect();
            rng.shuffle(&mut group);
            let w0 = random_world(rng, g, per);
            let mut w = w0.clone();
            allgather(&mut w, &group);
            let expect: Vec<f32> = group.iter().flat_map(|&r| w0[r].clone()).collect();
            for &r in &group {
                assert_eq_slice(&w[r], &expect)?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "duplicate rank")]
    fn duplicate_group_rejected() {
        let mut w = world_of(&[&[1.0], &[2.0]]);
        allgather(&mut w, &[0, 0]);
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn alltoall_divisibility_checked() {
        let mut w = world_of(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        alltoall(&mut w, &[0, 1]);
    }
}
