//! Data-plane adapters: collectives over in-process rank buffers.
//!
//! `world[r]` is rank `r`'s local buffer. A collective takes the world and
//! a *group* (an ordered list of distinct rank ids); only group members'
//! buffers are touched. Semantics follow NCCL/MPI conventions:
//!
//! * `allgather`  — every member ends with the concatenation of all
//!   members' inputs, in group order.
//! * `reduce_scatter` — inputs (equal length, divisible by g) are summed
//!   elementwise; member `j` keeps the `j`-th 1/g chunk of the sum.
//! * `allreduce` — elementwise sum, everyone gets the full result
//!   (implemented as reduce-scatter ∘ allgather, as in [21,22] of the
//!   paper — the identity Eq. (6) relies on).
//! * `alltoall` — member `i`'s input is split into g chunks; chunk `j`
//!   goes to member `j`; member `j` ends with `[chunk_j of member 0, …,
//!   chunk_j of member g-1]`. An involution when chunk sizes are uniform.
//! * `split` — local: member `j` keeps its `j`-th 1/g chunk (the ESP-Split
//!   of Fig 3a; communication-free in forward).
//!
//! Every wire-touching collective here instantiates the one-source
//! algorithms of [`crate::comm::algo`] with a [`DataTransport`] — the same
//! ring/pairwise code the simulator times. Only the purely local ops
//! (`split`, `broadcast`) are implemented directly.

use super::algo;
use super::transport::{split_chunks, DataTransport};

/// Validate a group: non-empty, distinct, in range.
fn check_group(world_len: usize, group: &[usize]) {
    assert!(!group.is_empty(), "empty group");
    for (i, &r) in group.iter().enumerate() {
        assert!(r < world_len, "rank {r} outside world of {world_len}");
        assert!(!group[..i].contains(&r), "duplicate rank {r} in group");
    }
}

fn check_equal_lengths(world: &[Vec<f32>], group: &[usize]) -> usize {
    let n = world[group[0]].len();
    for &r in group {
        assert_eq!(world[r].len(), n, "buffer length mismatch within group");
    }
    n
}

/// AllGather within `group` (in-place on the world). Member buffers may
/// have unequal lengths (the ring is payload-opaque): every member ends
/// with the group-order concatenation of whatever each member held —
/// which is what a ragged SAA's MP-AllGather of unequal AlltoAll outputs
/// needs.
pub fn allgather(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let mut t = DataTransport::new();
    let inputs: Vec<Vec<f32>> = group.iter().map(|&r| world[r].clone()).collect();
    let (outs, _) = algo::ring_allgather(&mut t, group, &inputs, &[], "allgather");
    for (out, &r) in outs.into_iter().zip(group.iter()) {
        world[r] = out.concat();
    }
}

/// ReduceScatter (sum) within `group`.
pub fn reduce_scatter(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let n = check_equal_lengths(world, group);
    let g = group.len();
    assert_eq!(n % g, 0, "reduce_scatter needs length divisible by group size");
    let mut t = DataTransport::new();
    let inputs: Vec<Vec<Vec<f32>>> = group.iter().map(|&r| split_chunks(&world[r], g)).collect();
    let (reduced, _) = algo::ring_reduce_scatter(&mut t, group, &inputs, &[], "reducescatter");
    for (out, &r) in reduced.into_iter().zip(group.iter()) {
        world[r] = out;
    }
}

/// AllReduce (sum) within `group` = ReduceScatter ∘ AllGather. Lengths
/// need not divide the group size: the ring runs on a ragged chunk
/// partition (sizes differ by at most one; the result is only ever
/// consumed re-concatenated, so chunk boundaries are a wire detail).
pub fn allreduce(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    check_equal_lengths(world, group);
    let g = group.len();
    let mut t = DataTransport::new();
    let inputs: Vec<Vec<Vec<f32>>> = group.iter().map(|&r| split_chunks(&world[r], g)).collect();
    let (outs, _) = algo::ring_allreduce(&mut t, group, &inputs, &[], "allreduce");
    for (out, &r) in outs.into_iter().zip(group.iter()) {
        world[r] = out.concat();
    }
}

/// AlltoAll within `group`. Buffers need not divide the group size: the
/// split is ragged (chunk sizes differ by at most one element, the first
/// `n % g` chunks one longer — [`split_chunks`]), zero-byte chunks stay
/// off the wire inside [`algo::pairwise_alltoall`], and member `j` ends
/// with `g` copies of chunk-`j`-sized data (an involution only when the
/// chunk sizes are uniform).
pub fn alltoall(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    check_equal_lengths(world, group);
    let g = group.len();
    let mut t = DataTransport::new();
    let inputs: Vec<Vec<Vec<f32>>> = group.iter().map(|&r| split_chunks(&world[r], g)).collect();
    let (outs, _) = algo::pairwise_alltoall(&mut t, group, &inputs, &[], "alltoall");
    for (out, &r) in outs.into_iter().zip(group.iter()) {
        world[r] = out.concat();
    }
}

/// Local Split: member `j` keeps its `j`-th 1/g chunk (no communication in
/// forward; its backward is an AllGather — handled by the schedules).
pub fn split(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let n = check_equal_lengths(world, group);
    let g = group.len();
    assert_eq!(n % g, 0, "split needs length divisible by group size");
    let chunk = n / g;
    for (j, &r) in group.iter().enumerate() {
        world[r] = world[r][j * chunk..(j + 1) * chunk].to_vec();
    }
}

/// Broadcast member 0's buffer to the whole group (used to set up the
/// MP-duplicated activations entering a MoE layer in tests).
pub fn broadcast(world: &mut [Vec<f32>], group: &[usize]) {
    check_group(world.len(), group);
    let src = world[group[0]].clone();
    for &r in &group[1..] {
        world[r] = src.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{assert_close, assert_eq_slice, check};

    fn world_of(bufs: &[&[f32]]) -> Vec<Vec<f32>> {
        bufs.iter().map(|b| b.to_vec()).collect()
    }

    #[test]
    fn allgather_concats_in_group_order() {
        let mut w = world_of(&[&[1.0, 2.0], &[3.0, 4.0], &[9.0, 9.0]]);
        allgather(&mut w, &[1, 0]);
        assert_eq!(w[1], vec![3.0, 4.0, 1.0, 2.0]);
        assert_eq!(w[0], vec![3.0, 4.0, 1.0, 2.0]);
        assert_eq!(w[2], vec![9.0, 9.0]); // untouched
    }

    #[test]
    fn reduce_scatter_sums_and_scatters() {
        let mut w = world_of(&[&[1.0, 2.0, 3.0, 4.0], &[10.0, 20.0, 30.0, 40.0]]);
        reduce_scatter(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![11.0, 22.0]);
        assert_eq!(w[1], vec![33.0, 44.0]);
    }

    #[test]
    fn allreduce_everyone_gets_sum() {
        let mut w = world_of(&[&[1.0, 2.0], &[3.0, 5.0]]);
        allreduce(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![4.0, 7.0]);
        assert_eq!(w[1], vec![4.0, 7.0]);
    }

    #[test]
    fn allreduce_odd_length() {
        let mut w = world_of(&[&[1.0, 2.0, 3.0], &[1.0, 1.0, 1.0]]);
        allreduce(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn alltoall_is_block_transpose() {
        let mut w = world_of(&[&[1.0, 2.0], &[3.0, 4.0]]);
        alltoall(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![1.0, 3.0]);
        assert_eq!(w[1], vec![2.0, 4.0]);
    }

    #[test]
    fn split_keeps_own_chunk() {
        let mut w = world_of(&[&[1.0, 2.0, 3.0, 4.0], &[1.0, 2.0, 3.0, 4.0]]);
        split(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![1.0, 2.0]);
        assert_eq!(w[1], vec![3.0, 4.0]);
    }

    #[test]
    fn broadcast_duplicates_leader() {
        let mut w = world_of(&[&[7.0], &[0.0], &[0.0]]);
        broadcast(&mut w, &[0, 2]);
        assert_eq!(w[2], vec![7.0]);
        assert_eq!(w[1], vec![0.0]);
    }

    // ---- property tests ---------------------------------------------------

    fn random_world(rng: &mut crate::util::prng::Rng, g: usize, per: usize) -> Vec<Vec<f32>> {
        (0..g).map(|_| rng.f32_vec(per)).collect()
    }

    #[test]
    fn prop_alltoall_involution() {
        check("alltoall-involution", 50, |rng| {
            let g = rng.range(1, 6);
            let chunk = rng.range(1, 8);
            let mut w = random_world(rng, g, g * chunk);
            let orig = w.clone();
            let group: Vec<usize> = (0..g).collect();
            alltoall(&mut w, &group);
            alltoall(&mut w, &group);
            for r in 0..g {
                assert_eq_slice(&w[r], &orig[r])?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_allreduce_equals_rs_then_ag() {
        check("allreduce-rs-ag", 50, |rng| {
            let g = rng.range(1, 6);
            let chunk = rng.range(1, 8);
            let group: Vec<usize> = (0..g).collect();
            let w0 = random_world(rng, g, g * chunk);
            let mut a = w0.clone();
            allreduce(&mut a, &group);
            let mut b = w0.clone();
            reduce_scatter(&mut b, &group);
            allgather(&mut b, &group);
            for r in 0..g {
                assert_close(&a[r], &b[r], 1e-5, 1e-5)?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_allgather_then_split_identity() {
        check("ag-split-id", 50, |rng| {
            let g = rng.range(1, 6);
            let per = rng.range(1, 10);
            let group: Vec<usize> = (0..g).collect();
            let w0 = random_world(rng, g, per);
            let mut w = w0.clone();
            allgather(&mut w, &group);
            split(&mut w, &group);
            for r in 0..g {
                assert_eq_slice(&w[r], &w0[r])?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_alltoall_conserves_multiset() {
        check("alltoall-conserves", 30, |rng| {
            let g = rng.range(1, 5);
            let chunk = rng.range(1, 6);
            let group: Vec<usize> = (0..g).collect();
            let w0 = random_world(rng, g, g * chunk);
            let mut w = w0.clone();
            alltoall(&mut w, &group);
            let mut before: Vec<u32> = w0.iter().flatten().map(|f| f.to_bits()).collect();
            let mut after: Vec<u32> = w.iter().flatten().map(|f| f.to_bits()).collect();
            before.sort_unstable();
            after.sort_unstable();
            assert_eq_slice(&after, &before)
        });
    }

    #[test]
    fn prop_groups_are_order_sensitive_but_consistent() {
        // AllGather with a permuted group concatenates in that order.
        check("ag-order", 30, |rng| {
            let g = rng.range(2, 5);
            let per = rng.range(1, 5);
            let mut group: Vec<usize> = (0..g).collect();
            rng.shuffle(&mut group);
            let w0 = random_world(rng, g, per);
            let mut w = w0.clone();
            allgather(&mut w, &group);
            let expect: Vec<f32> = group.iter().flat_map(|&r| w0[r].clone()).collect();
            for &r in &group {
                assert_eq_slice(&w[r], &expect)?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "duplicate rank")]
    fn duplicate_group_rejected() {
        let mut w = world_of(&[&[1.0], &[2.0]]);
        allgather(&mut w, &[0, 0]);
    }

    #[test]
    fn alltoall_supports_ragged_buffers() {
        // n = 3, g = 2: ragged split [2, 1] — member 0 collects the two
        // 2-element head chunks, member 1 the two 1-element tail chunks.
        let mut w = world_of(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        alltoall(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![1.0, 2.0, 4.0, 5.0]);
        assert_eq!(w[1], vec![3.0, 6.0]);
    }

    #[test]
    fn allgather_supports_unequal_member_buffers() {
        let mut w = world_of(&[&[1.0, 2.0], &[9.0]]);
        allgather(&mut w, &[0, 1]);
        assert_eq!(w[0], vec![1.0, 2.0, 9.0]);
        assert_eq!(w[1], vec![1.0, 2.0, 9.0]);
    }
}
