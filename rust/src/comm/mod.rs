//! Collective communication — ONE algorithm source, two planes.
//!
//! * [`algo`] — every collective (ring AllGather / ReduceScatter,
//!   AllReduce as RS ∘ AG, pairwise AlltoAll — which is also the fused
//!   EP&ESP-AlltoAll over the product group — and the SAA/AAS overlapped
//!   combine) is written exactly once, generic over a transport.
//! * [`transport`] — the [`transport::Transport`] trait and its two
//!   implementations: [`transport::DagTransport`] emits transfer DAGs for
//!   the discrete-event engine (**timing plane**), and
//!   [`transport::DataTransport`] moves real `f32` chunks between
//!   in-process rank buffers (**data plane**) while logging wire volumes.
//!   Because both planes execute the same algorithm source, the schedule
//!   we time is structurally the schedule whose numerics we verify — the
//!   paper's implicit semantics-preservation claim, made a type-level
//!   property instead of a cross-check test.
//! * [`tags`] — the canonical tag constants shared by the schedule IR, the
//!   simulator's per-tag accounting and the data-plane comm log.
//! * [`lower`] / [`data`] / [`saa`] — thin plane-specific adapters kept as
//!   the stable public API (and as regression tests pinning the ring
//!   timings and NCCL/MPI data semantics).

pub mod algo;
pub mod data;
pub mod lower;
pub mod saa;
pub mod tags;
pub mod transport;

pub use transport::{Chunk, DagTransport, DataTransport, Lump, Transport};
