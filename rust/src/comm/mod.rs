//! Collective communication, in two coupled forms:
//!
//! * **data plane** ([`data`]) — collectives over real in-process rank
//!   buffers (`Vec<f32>` per rank). This is how correctness is proved: the
//!   MoE layer executed under every schedule must produce identical numbers
//!   (paper's implicit semantics-preservation claim).
//! * **sim lowering** ([`lower`]) — the same collectives decomposed into
//!   point-to-point transfer DAGs for the discrete-event engine. This is
//!   how time is measured.
//!
//! [`saa`] implements the paper's Simultaneous-AlltoAll-and-AllGather
//! (§III-D, Fig 5) in both forms.

pub mod data;
pub mod lower;
pub mod saa;
