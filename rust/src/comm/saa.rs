//! SAA — Simultaneous AlltoAll and AllGather (paper §III-D, Fig 5):
//! plane-specific adapters over the one-source algorithm
//! [`algo::saa`].
//!
//! In the S2 schedule the second EP&ESP-AlltoAll (inter-node dominant) is
//! followed by an MP-AllGather (intra-node). SAA phases the AlltoAll so the
//! slice received in phase `p` is forwarded to the MP peers during phase
//! `p+1`, overlapping the two collectives on their distinct link classes.
//!
//! There is exactly one implementation of the phased algorithm (in
//! [`crate::comm::algo`]); [`saa_data`] instantiates it over real rank
//! buffers, [`saa_lower`]/[`aas_lower`] over the simulator's transfer DAG.
//! The data result must equal `alltoall(group)` followed by
//! `allgather(mp_group)` — [`saa_reference`] — which the tests assert.
//!
//! # Phase structure, monolithic and chunked
//!
//! The phased algorithm groups the AlltoAll's `g-1` pairwise rounds into
//! at most [`SAA_PHASES`] contiguous phases; when a member has received
//! every slice of a phase it forwards the accumulated block to its MP
//! peers, so the forwards (intra-node class) run concurrently with the
//! next phase's AlltoAll rounds (inter-node dominant class). Buffers need
//! NOT divide the group size: chunk partitions may be ragged (and a
//! zero-byte slice stays off the wire, exactly like
//! [`algo::pairwise_alltoall`]'s empty-chunk rule) — which is what lets
//! chunked and load-skewed capacity spans compose with SAA.
//!
//! The SP2 schedule ([`crate::schedule::ops::ScheduleKind::PipelinedS2`])
//! runs this same algorithm once per capacity chunk (`sp2.saa.k`): each
//! chunk's combine AlltoAll phases forward into the MP-AllGather while
//! the next chunk's expert FFN computes, composing the intra/inter
//! link-class overlap with SP's compute/comm pipeline. The per-chunk SAA
//! is the ONE algorithm below — the interpreter merely calls it with a
//! chunk-sized payload and the pipelined region's frontiers.
//!
//! Every entry point validates that `mp_groups` PARTITIONS `a2a_group`
//! ([`validate_mp_partition`]): an overlapping or incomplete partition
//! would silently corrupt data-plane buffers (a rank would receive a
//! peer's block twice, or never), so it is refused up front as a typed
//! [`VerifyError`] (rule `group-validity` — the same check the static
//! schedule verifier runs), surfaced to the CLI as a clean error instead
//! of a panic.

use crate::config::ClusterTopology;
use crate::schedule::verify::{self, VerifyError};
use crate::sim::dag::{SimDag, TaskId};

use super::algo;
pub use super::algo::SAA_PHASES;
use super::data;
use super::transport::{split_chunks, DagTransport, DataTransport, Lump};

/// Check that `mp_groups` is a partition of `a2a_group`: every member of
/// `a2a_group` appears in exactly one MP group, and no MP group contains a
/// rank outside `a2a_group`. Anything else would corrupt the data plane
/// (double-received or never-received AllGather blocks), so the SAA entry
/// points refuse it up front. Delegates to the static schedule verifier's
/// [`verify::validate_partition`] — ONE partition check for both the
/// lowering and the lint pass — and returns its typed error (rule
/// `group-validity`).
pub fn validate_mp_partition(
    a2a_group: &[usize],
    mp_groups: &[Vec<usize>],
) -> Result<(), VerifyError> {
    verify::validate_partition(a2a_group, mp_groups)
}

/// Data-plane SAA: the phased algorithm over real buffers. The result
/// equals `alltoall(a2a_group)` then `allgather(mp_group)` for every
/// member.
///
/// `mp_groups` must partition `a2a_group` (validated — each member appears
/// in exactly one group). Buffers need not divide the group size: the
/// chunk split is ragged ([`split_chunks`] — sizes differ by at most one
/// element), matching [`data::alltoall`]'s convention, and zero-byte
/// chunks stay off the wire.
pub fn saa_data(
    world: &mut [Vec<f32>],
    a2a_group: &[usize],
    mp_groups: &[Vec<usize>],
) -> Result<(), VerifyError> {
    let g = a2a_group.len();
    assert!(g > 0);
    validate_mp_partition(a2a_group, mp_groups)?;
    let n = world[a2a_group[0]].len();
    assert!(a2a_group.iter().all(|&r| world[r].len() == n));

    let mut t = DataTransport::new();
    let inputs: Vec<Vec<Vec<f32>>> =
        a2a_group.iter().map(|&r| split_chunks(&world[r], g)).collect();
    let (outs, _) = algo::saa(&mut t, a2a_group, mp_groups, &inputs, &[], "saa.a2a", "saa.ag", true);
    for (out, &r) in outs.into_iter().zip(a2a_group.iter()) {
        // out = per MP peer (MP order), that peer's AlltoAll output chunks.
        let mut buf = Vec::with_capacity(out.len() * n);
        for peer_chunks in out {
            for c in peer_chunks {
                buf.extend_from_slice(&c);
            }
        }
        world[r] = buf;
    }
    Ok(())
}

/// Reference semantics for SAA: compose the two collectives.
pub fn saa_reference(world: &mut [Vec<f32>], a2a_group: &[usize], mp_groups: &[Vec<usize>]) {
    data::alltoall(world, a2a_group);
    for grp in mp_groups {
        data::allgather(world, grp);
    }
}

/// Transfer-DAG lowering of SAA (phase-overlapped combine).
///
/// Returns one completion task per member of `a2a_group`.
#[allow(clippy::too_many_arguments)]
pub fn saa_lower(
    dag: &mut SimDag,
    cluster: &ClusterTopology,
    a2a_group: &[usize],
    mp_groups: &[Vec<usize>],
    bytes_per_pair: f64,
    deps: &[TaskId],
    tag_a2a: &'static str,
    tag_ag: &'static str,
) -> Result<Vec<TaskId>, VerifyError> {
    validate_mp_partition(a2a_group, mp_groups)?;
    let mut t = DagTransport::new(dag, cluster);
    let g = a2a_group.len();
    let inputs = vec![vec![Lump(bytes_per_pair); g]; g];
    Ok(algo::saa(&mut t, a2a_group, mp_groups, &inputs, deps, tag_a2a, tag_ag, true).1)
}

/// AAS — the non-overlapped ablation: AlltoAll to completion, then a ring
/// MP-AllGather of the full output.
#[allow(clippy::too_many_arguments)]
pub fn aas_lower(
    dag: &mut SimDag,
    cluster: &ClusterTopology,
    a2a_group: &[usize],
    mp_groups: &[Vec<usize>],
    bytes_per_pair: f64,
    deps: &[TaskId],
    tag_a2a: &'static str,
    tag_ag: &'static str,
) -> Result<Vec<TaskId>, VerifyError> {
    validate_mp_partition(a2a_group, mp_groups)?;
    let mut t = DagTransport::new(dag, cluster);
    let g = a2a_group.len();
    let inputs = vec![vec![Lump(bytes_per_pair); g]; g];
    Ok(algo::saa(&mut t, a2a_group, mp_groups, &inputs, deps, tag_a2a, tag_ag, false).1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterTopology;
    use crate::sim::engine::Simulator;
    use crate::util::propcheck::{assert_close, check};

    #[test]
    fn saa_data_matches_reference() {
        check("saa-equals-a2a-then-ag", 40, |rng| {
            // a2a group = 0..g with MP partition into blocks of m | g.
            let m = *rng.choice(&[1usize, 2]);
            let blocks = rng.range(1, 3);
            let g = m * blocks * rng.range(1, 2).max(1);
            let chunk = rng.range(1, 6);
            let n = g * chunk;
            let world0: Vec<Vec<f32>> = (0..g).map(|_| rng.f32_vec(n)).collect();
            let a2a_group: Vec<usize> = (0..g).collect();
            let mp_groups: Vec<Vec<usize>> =
                (0..g / m).map(|b| (b * m..(b + 1) * m).collect()).collect();

            let mut via_saa = world0.clone();
            saa_data(&mut via_saa, &a2a_group, &mp_groups).unwrap();
            let mut via_ref = world0.clone();
            saa_reference(&mut via_ref, &a2a_group, &mp_groups);
            for r in 0..g {
                assert_close(&via_saa[r], &via_ref[r], 0.0, 0.0)?;
            }
            Ok(())
        });
    }

    #[test]
    fn saa_data_supports_indivisible_buffers() {
        // Regression: buffers NOT divisible by the AlltoAll group used to
        // hard-panic (`assert_eq!(n % g, 0)`). The ragged split must still
        // equal the composed reference collectives (which share the same
        // ragged chunk convention) for every member.
        for (g, n, m) in [(4usize, 7usize, 2usize), (4, 3, 2), (2, 5, 1), (4, 10, 4)] {
            let world0: Vec<Vec<f32>> =
                (0..g).map(|i| (0..n).map(|j| (i * 100 + j) as f32).collect()).collect();
            let a2a_group: Vec<usize> = (0..g).collect();
            let mp_groups: Vec<Vec<usize>> =
                (0..g / m).map(|b| (b * m..(b + 1) * m).collect()).collect();
            let mut via_saa = world0.clone();
            saa_data(&mut via_saa, &a2a_group, &mp_groups).unwrap();
            let mut via_ref = world0.clone();
            saa_reference(&mut via_ref, &a2a_group, &mp_groups);
            for r in 0..g {
                assert_eq!(via_saa[r], via_ref[r], "g={g} n={n} m={m} rank {r}");
            }
        }
    }

    #[test]
    fn saa_all_empty_chunks_keep_completions_chained() {
        // Zero-byte chunks stay off the wire (phased path, multi-node
        // DAG), but an all-empty member's completion must still carry the
        // caller's deps — a follow-up task chained on it cannot start
        // before them (no detached frontier). This is the clamped-away
        // SP2 tail chunk's shape.
        let c = two_node_cluster();
        let mut dag = SimDag::new();
        let root = dag.transfer(0, 1, 1.0e6, &[], "seed");
        let a2a: Vec<usize> = (0..8).collect();
        let mp: Vec<Vec<usize>> = (0..4).map(|b| vec![2 * b, 2 * b + 1]).collect();
        let done = {
            let mut t = DagTransport::new(&mut dag, &c);
            let inputs = vec![vec![Lump(0.0); 8]; 8];
            algo::saa(&mut t, &a2a, &mp, &inputs, &[root], "a2a", "ag", true).1
        };
        assert_eq!(done.len(), 8);
        // Follow-up on a DIFFERENT link so only the dependency (not link
        // contention) can serialize it behind the seed transfer.
        dag.transfer(2, 3, 1.0e6, &[done[0]], "after");
        let log = dag.comm_log();
        assert!(
            log.iter().all(|(tag, _)| *tag == "seed" || *tag == "after"),
            "empty SAA chunks must stay off the wire: {log:?}"
        );
        let r = Simulator::new(&c).run(&dag);
        let mut solo = SimDag::new();
        solo.transfer(0, 1, 1.0e6, &[], "seed");
        let t_one = Simulator::new(&c).run(&solo).makespan;
        assert!(
            (r.makespan - 2.0 * t_one).abs() < 1e-12,
            "all-empty SAA completion detached from its deps: {} vs {}",
            r.makespan,
            2.0 * t_one
        );
    }

    #[test]
    fn mp_partition_validation() {
        use crate::schedule::verify::Rule;
        let grp = [0usize, 1, 2, 3];
        // Valid partitions.
        assert!(validate_mp_partition(&grp, &[vec![0, 1], vec![2, 3]]).is_ok());
        assert!(validate_mp_partition(&grp, &[vec![0], vec![1], vec![2], vec![3]]).is_ok());
        // Overlapping: rank 1 in two groups.
        let err = validate_mp_partition(&grp, &[vec![0, 1], vec![1, 2, 3]]).unwrap_err();
        assert_eq!(err.rule, Rule::GroupValidity);
        assert!(err.to_string().contains("overlapping"), "{err}");
        // Duplicate within one group is also an overlap.
        assert!(validate_mp_partition(&grp, &[vec![0, 0], vec![1, 2, 3]]).is_err());
        // Incomplete: rank 3 uncovered.
        let err = validate_mp_partition(&grp, &[vec![0, 1], vec![2]]).unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
        // Foreign rank: 9 is not in the a2a group.
        let err = validate_mp_partition(&grp, &[vec![0, 1], vec![2, 3, 9]]).unwrap_err();
        assert!(err.to_string().contains("not in the a2a group"), "{err}");
    }

    #[test]
    fn saa_data_rejects_overlapping_partition() {
        let mut world: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 4]).collect();
        let err =
            saa_data(&mut world, &[0, 1, 2, 3], &[vec![0, 1], vec![1, 2, 3]]).unwrap_err();
        assert!(err.to_string().contains("overlapping partition"), "{err}");
    }

    #[test]
    fn saa_lower_rejects_incomplete_partition() {
        let c = two_node_cluster();
        let mut dag = SimDag::new();
        let err = saa_lower(&mut dag, &c, &[0, 1, 2, 3], &[vec![0, 1]], 8.0, &[], "a2a", "ag")
            .unwrap_err();
        assert!(err.to_string().contains("incomplete partition"), "{err}");
    }

    #[test]
    fn aas_lower_rejects_foreign_rank() {
        let c = two_node_cluster();
        let mut dag = SimDag::new();
        let err = aas_lower(&mut dag, &c, &[0, 1], &[vec![0, 1, 5]], 8.0, &[], "a2a", "ag")
            .unwrap_err();
        assert!(err.to_string().contains("not in the a2a group"), "{err}");
    }

    fn two_node_cluster_with_inter(inter: crate::config::AlphaBeta) -> ClusterTopology {
        ClusterTopology::homogeneous(
            "t",
            2,
            4,
            crate::config::AlphaBeta::new(1e-5, 1e-9),
            inter,
            1e12,
            1 << 30,
        )
    }

    fn two_node_cluster() -> ClusterTopology {
        two_node_cluster_with_inter(crate::config::AlphaBeta::new(1e-4, 1e-8))
    }

    fn saa_vs_aas_on(c: &ClusterTopology, mp_size: usize, bytes: f64) -> (f64, f64) {
        let a2a: Vec<usize> = (0..8).collect();
        let mp: Vec<Vec<usize>> = (0..8 / mp_size)
            .map(|b| (b * mp_size..(b + 1) * mp_size).collect())
            .collect();
        let mut d1 = SimDag::new();
        saa_lower(&mut d1, c, &a2a, &mp, bytes, &[], "a2a", "ag").unwrap();
        let t_saa = Simulator::new(c).run(&d1).makespan;
        let mut d2 = SimDag::new();
        aas_lower(&mut d2, c, &a2a, &mp, bytes, &[], "a2a", "ag").unwrap();
        let t_aas = Simulator::new(c).run(&d2).makespan;
        (t_saa, t_aas)
    }

    fn saa_vs_aas(mp_size: usize, bytes: f64) -> (f64, f64) {
        let c = two_node_cluster();
        saa_vs_aas_on(&c, mp_size, bytes)
    }

    #[test]
    fn saa_wins_when_alltoall_is_inter_dominant() {
        // When the inter-node class is much slower than intra (NIC-bound
        // AlltoAll), the MP forwards hide entirely inside NIC gaps while
        // AAS pays its full AllGather after the AlltoAll completes.
        // Inter β = 1e-7: 100× slower than intra.
        let c = two_node_cluster_with_inter(crate::config::AlphaBeta::new(1e-4, 1e-7));
        let (t_saa, t_aas) = saa_vs_aas_on(&c, 4, 2.0e5);
        assert!(
            t_saa < t_aas,
            "SAA ({t_saa}) should beat AAS ({t_aas}) in the inter-dominant regime"
        );
    }

    #[test]
    fn saa_near_parity_in_balanced_regime() {
        // With only a 10× intra/inter gap the tail forwards contend with
        // the AlltoAll's final intra phases and the gain shrinks — the
        // paper itself reports just ~1.1% average SAA improvement (§VI-C).
        // Accept parity within 5% in both MP sizes.
        for mp_size in [2usize, 4] {
            let (t_saa, t_aas) = saa_vs_aas(mp_size, 2.0e5);
            assert!(
                t_saa <= t_aas * 1.05,
                "SAA ({t_saa}) should be within 5% of AAS ({t_aas}) at mp={mp_size}"
            );
        }
    }

    #[test]
    fn saa_moves_same_bytes_as_aas() {
        // The overlap must not change total wire volume (only placement in
        // time). AAS's ring AG moves (m-1)·out per member — identical to
        // SAA's (m-1) forwards of each of the g slices.
        let a2a: Vec<usize> = (0..4).collect();
        let mp: Vec<Vec<usize>> = vec![vec![0, 1], vec![2, 3]];
        let bytes = 1.0e5;

        let mut d1 = SimDag::new();
        let c = two_node_cluster();
        saa_lower(&mut d1, &c, &a2a, &mp, bytes, &[], "a2a", "ag").unwrap();
        let mut d2 = SimDag::new();
        aas_lower(&mut d2, &c, &a2a, &mp, bytes, &[], "a2a", "ag").unwrap();
        assert!((d1.total_network_bytes() - d2.total_network_bytes()).abs() < 1e-6);
    }

    #[test]
    fn saa_singleton_mp_degenerates_to_alltoall() {
        // With MP groups of size 1 there are no forwards: same cost as a2a.
        let c = two_node_cluster();
        let a2a: Vec<usize> = (0..8).collect();
        let mp: Vec<Vec<usize>> = (0..8).map(|r| vec![r]).collect();
        let bytes = 2.0e5;

        let mut d1 = SimDag::new();
        saa_lower(&mut d1, &c, &a2a, &mp, bytes, &[], "a2a", "ag").unwrap();
        let t_saa = Simulator::new(&c).run(&d1).makespan;

        let mut d2 = SimDag::new();
        crate::comm::lower::pairwise_alltoall(&mut d2, &c, &a2a, bytes, &[], "a2a");
        let t_a2a = Simulator::new(&c).run(&d2).makespan;

        assert!((t_saa - t_a2a).abs() < 1e-12);
    }

    #[test]
    fn saa_dag_log_totals_match_aas_per_tag() {
        // Same wire volume per tag whichever form runs — the phased
        // forwards only move the AllGather's bytes earlier in time.
        let c = two_node_cluster();
        let a2a: Vec<usize> = (0..8).collect();
        let mp: Vec<Vec<usize>> = vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]];
        let bytes = 3.0e4;
        let mut d1 = SimDag::new();
        saa_lower(&mut d1, &c, &a2a, &mp, bytes, &[], "a2a", "ag").unwrap();
        let mut d2 = SimDag::new();
        aas_lower(&mut d2, &c, &a2a, &mp, bytes, &[], "a2a", "ag").unwrap();
        let l1 = d1.comm_log();
        let l2 = d2.comm_log();
        assert_eq!(l1.len(), l2.len());
        for ((t1, b1), (t2, b2)) in l1.iter().zip(l2.iter()) {
            assert_eq!(t1, t2);
            assert!((b1 - b2).abs() < 1e-6, "{t1}: {b1} vs {b2}");
        }
    }
}
