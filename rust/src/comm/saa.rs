//! SAA — Simultaneous AlltoAll and AllGather (paper §III-D, Fig 5).
//!
//! In the S2 schedule the second EP&ESP-AlltoAll (inter-node dominant) is
//! followed by an MP-AllGather (intra-node). SAA phases the AlltoAll so the
//! slice received in phase `p` is forwarded to the MP peers during phase
//! `p+1`, overlapping the two collectives on their distinct link classes.
//!
//! Two implementations, verified against each other:
//! * [`saa_data`] — data plane: produces exactly the bytes of
//!   `alltoall(group)` followed by `allgather(mp_group)` (tested).
//! * [`saa_lower`] — transfer DAG with the phase-overlap structure for the
//!   simulator; the AAS (sequential) variant [`aas_lower`] is the ablation
//!   baseline (§VI-C reports SAA ≈ 1.1% faster than AAS).

use crate::config::ClusterProfile;
use crate::sim::dag::{SimDag, TaskId};

use super::data;
use super::lower;

/// Data-plane SAA: phased implementation whose result must equal
/// `alltoall(a2a_group)` then `allgather(mp_group)` for every member.
///
/// `mp_groups` partitions `a2a_group` (each member appears in exactly one).
pub fn saa_data(world: &mut [Vec<f32>], a2a_group: &[usize], mp_groups: &[Vec<usize>]) {
    let g = a2a_group.len();
    assert!(g > 0);
    let n = world[a2a_group[0]].len();
    assert!(a2a_group.iter().all(|&r| world[r].len() == n));
    assert_eq!(n % g, 0, "saa needs buffer divisible by a2a group size");
    let chunk = n / g;

    let mp_of = |rank: usize| -> &Vec<usize> {
        mp_groups
            .iter()
            .find(|grp| grp.contains(&rank))
            .expect("rank missing from mp partition")
    };

    // slices[i][j] = chunk destined to member i, originating at member j.
    // Phase p delivers slices[i][(i - p) mod g] to member i; the forward of
    // that slice to i's MP peers happens in phase p+1 (overlap). Because
    // the data plane is sequential in-process, phases only affect *when*
    // a slice becomes available for forwarding — the final bytes assembled
    // here are what the phased algorithm delivers on the wire.
    let pos_in = |grp: &[usize], r: usize| grp.iter().position(|&x| x == r).unwrap();

    // a2a_out[i] = member i's AlltoAll output, assembled slice by slice.
    let mut a2a_out: Vec<Vec<f32>> = vec![vec![0.0; n]; g];
    for p in 0..g {
        for (i, _) in a2a_group.iter().enumerate() {
            let j = (i + g - p) % g; // source member for this phase
            let src_rank = a2a_group[j];
            let slice = &world[src_rank][i * chunk..(i + 1) * chunk];
            a2a_out[i][j * chunk..(j + 1) * chunk].copy_from_slice(slice);
        }
    }

    // MP-AllGather of the assembled outputs (the forwards): member r ends
    // with the concatenation of its MP group members' a2a outputs.
    let mut finals: Vec<(usize, Vec<f32>)> = Vec::with_capacity(g);
    for &r in a2a_group {
        let grp = mp_of(r);
        let mut out = Vec::with_capacity(n * grp.len());
        for &q in grp {
            let qi = pos_in(a2a_group, q);
            out.extend_from_slice(&a2a_out[qi]);
        }
        finals.push((r, out));
    }
    for (r, buf) in finals {
        world[r] = buf;
    }
}

/// Reference semantics for SAA: compose the two collectives.
pub fn saa_reference(world: &mut [Vec<f32>], a2a_group: &[usize], mp_groups: &[Vec<usize>]) {
    data::alltoall(world, a2a_group);
    for grp in mp_groups {
        data::allgather(world, grp);
    }
}

/// Number of SAA phases: the AlltoAll's rounds are grouped into at most
/// this many phases; each member forwards one *accumulated* block to its
/// MP peers per phase (Fig 5's phase granularity). Coarsening keeps the
/// per-message α cost of the forwards at ring-AllGather scale instead of
/// paying α on every slice.
pub const SAA_PHASES: usize = 4;

/// Transfer-DAG lowering of SAA.
///
/// * AlltoAll rounds `p = 1..g-1` are chained per (sender, link class) as
///   in [`lower::pairwise_alltoall`].
/// * Rounds are grouped into [`SAA_PHASES`] phases; when member `i` has
///   received every slice of a phase (own slice counts toward the first),
///   it forwards the accumulated block to each MP peer. Forwards depend
///   only on that phase's receives — they run concurrently with the next
///   phase's AlltoAll rounds (distinct link classes when MP is intra-node
///   and the AlltoAll is inter-node dominant).
///
/// Returns one completion task per member of `a2a_group`.
pub fn saa_lower(
    dag: &mut SimDag,
    cluster: &ClusterProfile,
    a2a_group: &[usize],
    mp_groups: &[Vec<usize>],
    bytes_per_pair: f64,
    deps: &[TaskId],
    tag_a2a: &'static str,
    tag_ag: &'static str,
) -> Vec<TaskId> {
    let g = a2a_group.len();
    // SAA exists to overlap the inter-node-dominant AlltoAll with the
    // intra-node AllGather. If the whole group lives on one node there is
    // no second link class — the phased forwards would only contend with
    // the AlltoAll on the same ports — so degrade to the sequential form.
    let single_node = a2a_group
        .iter()
        .all(|&r| cluster.node_of(r) == cluster.node_of(a2a_group[0]));
    if single_node && g > 1 {
        return aas_lower(
            dag,
            cluster,
            a2a_group,
            mp_groups,
            bytes_per_pair,
            deps,
            tag_a2a,
            tag_ag,
        );
    }
    let mp_of = |rank: usize| -> Vec<usize> {
        mp_groups
            .iter()
            .find(|grp| grp.contains(&rank))
            .expect("rank missing from mp partition")
            .clone()
    };

    let mut incident: Vec<Vec<TaskId>> = vec![Vec::new(); g];
    // Forward an accumulated block of `slices` slices held by member `i`
    // (ready after `ready`) to its MP peers.
    let forward = |dag: &mut SimDag,
                   incident: &mut Vec<Vec<TaskId>>,
                   i: usize,
                   slices: usize,
                   ready: &[TaskId]| {
        if slices == 0 {
            return;
        }
        let me = a2a_group[i];
        for peer in mp_of(me) {
            if peer == me {
                continue;
            }
            let t = dag.transfer(me, peer, slices as f64 * bytes_per_pair, ready, tag_ag);
            incident[i].push(t);
            if let Some(pi) = a2a_group.iter().position(|&x| x == peer) {
                incident[pi].push(t);
            }
        }
    };

    // Partition rounds 1..g-1 into SAA_PHASES contiguous groups; the own
    // slice (round 0) joins the first phase.
    let rounds = g - 1;
    let n_phases = SAA_PHASES.min(rounds.max(1));
    let mut prev_intra: Vec<Option<TaskId>> = vec![None; g];
    let mut prev_inter: Vec<Option<TaskId>> = vec![None; g];
    if rounds == 0 {
        // Degenerate single-member AlltoAll: forward the own slice only.
        for i in 0..g {
            forward(dag, &mut incident, i, 1, deps);
        }
    }
    let mut round = 1usize;
    for phase in 0..n_phases {
        let remaining_phases = n_phases - phase;
        let remaining_rounds = rounds + 1 - round;
        let in_phase = remaining_rounds / remaining_phases
            + usize::from(remaining_rounds % remaining_phases != 0);
        // Receives of this phase, per receiving member.
        let mut phase_recv: Vec<Vec<TaskId>> = vec![Vec::new(); g];
        for p in round..round + in_phase {
            for i in 0..g {
                let dst = (i + p) % g;
                let intra = cluster.same_node(a2a_group[i], a2a_group[dst]);
                let prev = if intra { &mut prev_intra } else { &mut prev_inter };
                let dep: Vec<TaskId> = match prev[i] {
                    None => deps.to_vec(),
                    Some(t) => vec![t],
                };
                let t =
                    dag.transfer(a2a_group[i], a2a_group[dst], bytes_per_pair, &dep, tag_a2a);
                prev[i] = Some(t);
                incident[i].push(t);
                incident[dst].push(t);
                phase_recv[dst].push(t);
            }
        }
        round += in_phase;
        // Forward the accumulated block (+ own slice in the first phase).
        let own = usize::from(phase == 0);
        for (i, recvs) in phase_recv.iter().enumerate() {
            forward(dag, &mut incident, i, recvs.len() + own, recvs);
        }
    }

    (0..g).map(|i| dag.join(&incident[i], tag_a2a)).collect()
}

/// AAS — the non-overlapped ablation: AlltoAll to completion, then a ring
/// MP-AllGather of the full output.
pub fn aas_lower(
    dag: &mut SimDag,
    cluster: &ClusterProfile,
    a2a_group: &[usize],
    mp_groups: &[Vec<usize>],
    bytes_per_pair: f64,
    deps: &[TaskId],
    tag_a2a: &'static str,
    tag_ag: &'static str,
) -> Vec<TaskId> {
    let g = a2a_group.len();
    let a2a_ends = lower::pairwise_alltoall(dag, cluster, a2a_group, bytes_per_pair, deps, tag_a2a);
    let j = dag.join(&a2a_ends, tag_a2a);
    // Full a2a output per member = g × bytes_per_pair.
    let out_bytes = g as f64 * bytes_per_pair;
    let mut completion: Vec<TaskId> = vec![0; g];
    for grp in mp_groups {
        let ends = lower::ring_allgather(dag, grp, out_bytes, &[j], tag_ag);
        for (gi, &r) in grp.iter().enumerate() {
            if let Some(pi) = a2a_group.iter().position(|&x| x == r) {
                completion[pi] = ends[gi];
            }
        }
    }
    completion
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterProfile;
    use crate::sim::engine::Simulator;
    use crate::util::propcheck::{assert_close, check};

    #[test]
    fn saa_data_matches_reference() {
        check("saa-equals-a2a-then-ag", 40, |rng| {
            // a2a group = 0..g with MP partition into blocks of m | g.
            let m = *rng.choice(&[1usize, 2]);
            let blocks = rng.range(1, 3);
            let g = m * blocks * rng.range(1, 2).max(1);
            let chunk = rng.range(1, 6);
            let n = g * chunk;
            let world0: Vec<Vec<f32>> = (0..g).map(|_| rng.f32_vec(n)).collect();
            let a2a_group: Vec<usize> = (0..g).collect();
            let mp_groups: Vec<Vec<usize>> =
                (0..g / m).map(|b| (b * m..(b + 1) * m).collect()).collect();

            let mut via_saa = world0.clone();
            saa_data(&mut via_saa, &a2a_group, &mp_groups);
            let mut via_ref = world0.clone();
            saa_reference(&mut via_ref, &a2a_group, &mp_groups);
            for r in 0..g {
                assert_close(&via_saa[r], &via_ref[r], 0.0, 0.0)?;
            }
            Ok(())
        });
    }

    fn two_node_cluster() -> ClusterProfile {
        ClusterProfile {
            name: "t".into(),
            nodes: 2,
            gpus_per_node: 4,
            alpha_intra: 1e-5,
            beta_intra: 1e-9,
            alpha_inter: 1e-4,
            beta_inter: 1e-8,
            gpu_flops: 1e12,
            gpu_mem_bytes: 1 << 30,
        }
    }

    fn saa_vs_aas_on(c: &ClusterProfile, mp_size: usize, bytes: f64) -> (f64, f64) {
        let a2a: Vec<usize> = (0..8).collect();
        let mp: Vec<Vec<usize>> = (0..8 / mp_size)
            .map(|b| (b * mp_size..(b + 1) * mp_size).collect())
            .collect();
        let mut d1 = SimDag::new();
        saa_lower(&mut d1, c, &a2a, &mp, bytes, &[], "a2a", "ag");
        let t_saa = Simulator::new(c).run(&d1).makespan;
        let mut d2 = SimDag::new();
        aas_lower(&mut d2, c, &a2a, &mp, bytes, &[], "a2a", "ag");
        let t_aas = Simulator::new(c).run(&d2).makespan;
        (t_saa, t_aas)
    }

    fn saa_vs_aas(mp_size: usize, bytes: f64) -> (f64, f64) {
        let c = two_node_cluster();
        saa_vs_aas_on(&c, mp_size, bytes)
    }

    #[test]
    fn saa_wins_when_alltoall_is_inter_dominant() {
        // When the inter-node class is much slower than intra (NIC-bound
        // AlltoAll), the MP forwards hide entirely inside NIC gaps while
        // AAS pays its full AllGather after the AlltoAll completes.
        let mut c = two_node_cluster();
        c.beta_inter = 1e-7; // 100× slower than intra
        let (t_saa, t_aas) = saa_vs_aas_on(&c, 4, 2.0e5);
        assert!(
            t_saa < t_aas,
            "SAA ({t_saa}) should beat AAS ({t_aas}) in the inter-dominant regime"
        );
    }

    #[test]
    fn saa_near_parity_in_balanced_regime() {
        // With only a 10× intra/inter gap the tail forwards contend with
        // the AlltoAll's final intra phases and the gain shrinks — the
        // paper itself reports just ~1.1% average SAA improvement (§VI-C).
        // Accept parity within 5% in both MP sizes.
        for mp_size in [2usize, 4] {
            let (t_saa, t_aas) = saa_vs_aas(mp_size, 2.0e5);
            assert!(
                t_saa <= t_aas * 1.05,
                "SAA ({t_saa}) should be within 5% of AAS ({t_aas}) at mp={mp_size}"
            );
        }
    }

    #[test]
    fn saa_moves_same_bytes_as_aas() {
        // The overlap must not change total wire volume (only placement in
        // time). AAS's ring AG moves (m-1)·out per member — identical to
        // SAA's (m-1) forwards of each of the g slices.
        let a2a: Vec<usize> = (0..4).collect();
        let mp: Vec<Vec<usize>> = vec![vec![0, 1], vec![2, 3]];
        let bytes = 1.0e5;

        let mut d1 = SimDag::new();
        let c = two_node_cluster();
        saa_lower(&mut d1, &c, &a2a, &mp, bytes, &[], "a2a", "ag");
        let mut d2 = SimDag::new();
        aas_lower(&mut d2, &c, &a2a, &mp, bytes, &[], "a2a", "ag");
        assert!((d1.total_network_bytes() - d2.total_network_bytes()).abs() < 1e-6);
    }

    #[test]
    fn saa_singleton_mp_degenerates_to_alltoall() {
        // With MP groups of size 1 there are no forwards: same cost as a2a.
        let c = two_node_cluster();
        let a2a: Vec<usize> = (0..8).collect();
        let mp: Vec<Vec<usize>> = (0..8).map(|r| vec![r]).collect();
        let bytes = 2.0e5;

        let mut d1 = SimDag::new();
        saa_lower(&mut d1, &c, &a2a, &mp, bytes, &[], "a2a", "ag");
        let t_saa = Simulator::new(&c).run(&d1).makespan;

        let mut d2 = SimDag::new();
        lower::pairwise_alltoall(&mut d2, &c, &a2a, bytes, &[], "a2a");
        let t_a2a = Simulator::new(&c).run(&d2).makespan;

        assert!((t_saa - t_a2a).abs() < 1e-12);
    }
}
