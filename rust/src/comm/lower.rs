//! Timing-plane adapters: lower collectives to point-to-point transfer
//! DAGs for the discrete-event engine.
//!
//! These are thin wrappers that instantiate the one-source algorithms of
//! [`crate::comm::algo`] with a [`DagTransport`] — chunk payloads are byte
//! counts ([`Lump`]), and every `send` becomes a [`SimDag`] transfer. No
//! collective loop is written here; the ring/pairwise structure lives in
//! `algo` only.
//!
//! Each lowering returns one completion `TaskId` per group member (group
//! order), so schedules can chain per-rank dependencies without global
//! barriers.

use crate::config::ClusterTopology;
use crate::sim::dag::{SimDag, TaskId};

use super::algo;
use super::transport::{DagTransport, Lump};

/// Ring AllGather: `bytes_per_rank` is each member's input size (every
/// step moves one such chunk).
pub fn ring_allgather(
    dag: &mut SimDag,
    cluster: &ClusterTopology,
    group: &[usize],
    bytes_per_rank: f64,
    deps: &[TaskId],
    tag: &'static str,
) -> Vec<TaskId> {
    let mut t = DagTransport::new(dag, cluster);
    let inputs = vec![Lump(bytes_per_rank); group.len()];
    algo::ring_allgather(&mut t, group, &inputs, deps, tag).1
}

/// Ring ReduceScatter: each step moves one reduced chunk of `chunk_bytes`
/// (= total bytes / g).
pub fn ring_reduce_scatter(
    dag: &mut SimDag,
    cluster: &ClusterTopology,
    group: &[usize],
    chunk_bytes: f64,
    deps: &[TaskId],
    tag: &'static str,
) -> Vec<TaskId> {
    let mut t = DagTransport::new(dag, cluster);
    let g = group.len();
    let inputs = vec![vec![Lump(chunk_bytes); g]; g];
    algo::ring_reduce_scatter(&mut t, group, &inputs, deps, tag).1
}

/// AllReduce = ReduceScatter ∘ AllGather over `total_bytes` per member.
pub fn ring_allreduce(
    dag: &mut SimDag,
    cluster: &ClusterTopology,
    group: &[usize],
    total_bytes: f64,
    deps: &[TaskId],
    tag: &'static str,
) -> Vec<TaskId> {
    let mut t = DagTransport::new(dag, cluster);
    let g = group.len();
    let inputs = vec![vec![Lump(total_bytes / g as f64); g]; g];
    algo::ring_allreduce(&mut t, group, &inputs, deps, tag).1
}

/// Pairwise-exchange AlltoAll; `bytes_per_pair` is the chunk size for one
/// (src, dst) pair. Sends chain per (sender, link class) — see
/// [`algo::pairwise_alltoall`].
pub fn pairwise_alltoall(
    dag: &mut SimDag,
    cluster: &ClusterTopology,
    group: &[usize],
    bytes_per_pair: f64,
    deps: &[TaskId],
    tag: &'static str,
) -> Vec<TaskId> {
    let mut t = DagTransport::new(dag, cluster);
    let g = group.len();
    let inputs = vec![vec![Lump(bytes_per_pair); g]; g];
    algo::pairwise_alltoall(&mut t, group, &inputs, deps, tag).1
}

/// Per-rank transfer DAG statistics used in tests: number of p2p transfers
/// a lowering emits.
pub fn transfer_count(dag: &SimDag) -> usize {
    dag.tasks
        .iter()
        .filter(|t| matches!(t.kind, crate::sim::dag::TaskKind::Transfer { src, dst, .. } if src != dst))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterTopology;
    use crate::sim::engine::Simulator;

    fn cluster(nodes: usize, gpn: usize) -> ClusterTopology {
        ClusterTopology::homogeneous(
            "t",
            nodes,
            gpn,
            crate::config::AlphaBeta::new(1e-5, 1e-9),
            crate::config::AlphaBeta::new(1e-4, 1e-8),
            1e12,
            1 << 30,
        )
    }

    #[test]
    fn allgather_ring_step_count() {
        let c = cluster(1, 4);
        let mut d = SimDag::new();
        let ends = ring_allgather(&mut d, &c, &[0, 1, 2, 3], 1e6, &[], "ag");
        assert_eq!(ends.len(), 4);
        assert_eq!(transfer_count(&d), 4 * 3); // g·(g-1) sends
    }

    #[test]
    fn allgather_singleton_free() {
        let c = cluster(1, 4);
        let mut d = SimDag::new();
        let ends = ring_allgather(&mut d, &c, &[2], 1e6, &[], "ag");
        let r = Simulator::new(&c).run(&d);
        assert_eq!(r.makespan, 0.0);
        assert_eq!(ends.len(), 1);
    }

    #[test]
    fn allgather_time_matches_ring_model() {
        // Intra-node 4-ring: (g-1) sequential steps of (α + n·β) on the
        // critical path.
        let c = cluster(1, 4);
        let mut d = SimDag::new();
        ring_allgather(&mut d, &c, &[0, 1, 2, 3], 1e6, &[], "ag");
        let r = Simulator::new(&c).run(&d);
        let expect = 3.0 * (1e-5 + 1e6 * 1e-9);
        assert!((r.makespan - expect).abs() < 1e-9, "{} vs {expect}", r.makespan);
    }

    #[test]
    fn reduce_scatter_time_matches_ring_model() {
        let c = cluster(1, 4);
        let mut d = SimDag::new();
        // total 4 MB per rank → 1 MB chunks.
        ring_reduce_scatter(&mut d, &c, &[0, 1, 2, 3], 1e6, &[], "rs");
        let r = Simulator::new(&c).run(&d);
        let expect = 3.0 * (1e-5 + 1e6 * 1e-9);
        assert!((r.makespan - expect).abs() < 1e-9);
    }

    #[test]
    fn allreduce_is_two_phases() {
        let c = cluster(1, 4);
        let mut d = SimDag::new();
        ring_allreduce(&mut d, &c, &[0, 1, 2, 3], 4e6, &[], "ar");
        let r = Simulator::new(&c).run(&d);
        let expect = 2.0 * 3.0 * (1e-5 + 1e6 * 1e-9);
        assert!((r.makespan - expect).abs() < 1e-9, "{}", r.makespan);
    }

    #[test]
    fn alltoall_rounds_serialize_on_ports() {
        let c = cluster(1, 4);
        let mut d = SimDag::new();
        pairwise_alltoall(&mut d, &c, &[0, 1, 2, 3], 1e6, &[], "a2a");
        let r = Simulator::new(&c).run(&d);
        // Each rank sends g-1 chunks through its tx port sequentially.
        let expect = 3.0 * (1e-5 + 1e6 * 1e-9);
        assert!((r.makespan - expect).abs() < 1e-9, "{}", r.makespan);
        assert_eq!(transfer_count(&d), 12);
    }

    #[test]
    fn inter_node_alltoall_bottlenecked_by_nic() {
        // 2 nodes × 2 GPUs; cross-node pairs share the NICs.
        let c = cluster(2, 2);
        let mut d = SimDag::new();
        pairwise_alltoall(&mut d, &c, &[0, 1, 2, 3], 1e6, &[], "a2a");
        let r = Simulator::new(&c).run(&d);
        // 8 of 12 transfers are inter-node; each NIC carries 4 (tx) of
        // them at (α_inter + n·β_inter) each ⇒ NIC busy ≥ 4 × that.
        let inter_one = 1e-4 + 1e6 * 1e-8;
        assert!(r.makespan >= 4.0 * inter_one);
        // And intra transfers did not add to the critical path beyond it.
        assert!(r.makespan < 4.0 * inter_one + 2.0 * (1e-5 + 1e6 * 1e-9) + 1e-6);
    }

    #[test]
    fn fused_vs_sequential_observation1() {
        // Paper Eq. (3): A2A_{EP&ESP}(x) ≤ AG_ESP(x) + A2A_EP(x).
        // Layout: 2 nodes × 2 GPUs; ESP groups intra-node {0,1},{2,3};
        // EP groups inter-node {0,2},{1,3}.
        let c = cluster(2, 2);
        let elem_bytes = 4.0e5; // x bytes per pair unit

        // Baseline: ESP-AllGather(x) then EP-AlltoAll(x) per EP group.
        let mut base = SimDag::new();
        let mut ag_ends = Vec::new();
        for grp in [[0usize, 1], [2, 3]] {
            ag_ends.extend(ring_allgather(&mut base, &c, &grp, elem_bytes, &[], "ag"));
        }
        let j = base.join(&ag_ends, "sync");
        for grp in [[0usize, 2], [1, 3]] {
            pairwise_alltoall(&mut base, &c, &grp, elem_bytes, &[j], "a2a");
        }
        let t_base = Simulator::new(&c).run(&base).makespan;

        // Fused: one AlltoAll over all 4 ranks; per-pair bytes x/2 keeps
        // per-rank received volume equal (each rank receives from 3 peers
        // instead of 1, carrying the ESP duplication).
        let mut fused = SimDag::new();
        pairwise_alltoall(&mut fused, &c, &[0, 1, 2, 3], elem_bytes / 2.0, &[], "fused");
        let t_fused = Simulator::new(&c).run(&fused).makespan;

        assert!(
            t_fused <= t_base + 1e-12,
            "fused {t_fused} should not exceed sequential {t_base}"
        );
    }
}
