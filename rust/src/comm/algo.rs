//! The ONE source of every collective algorithm, generic over
//! [`Transport`].
//!
//! Ring AllGather, ring ReduceScatter, AllReduce (RS ∘ AG), the pairwise
//! AlltoAll (which is also Parm's fused EP&ESP-AlltoAll when run over the
//! product group, §III-C), and the SAA/AAS overlapped combine (§III-D,
//! Fig 5) are each written exactly once here. Instantiated with
//! [`crate::comm::transport::DagTransport`] they emit the transfer DAGs the
//! discrete-event engine times; with
//! [`crate::comm::transport::DataTransport`] they move real `f32` chunks —
//! identical per-tag wire volumes on both planes by construction.
//!
//! Algorithms match what NCCL uses on the paper's testbeds (no
//! NVLink/NVSwitch): **ring** AllGather / ReduceScatter (AllReduce as
//! RS ∘ AG, [21,22]) and **pairwise-exchange** AlltoAll. Each returns one
//! completion handle per group member (group order), so schedules can
//! chain per-rank dependencies without global barriers.
//!
//! Payloads are opaque [`Chunk`] values: the algorithms never inspect
//! sizes, so uneven chunk partitions work wherever the collective's
//! semantics allow them.

use super::transport::{Chunk, Transport};

/// If a group has one member, a collective is a no-op; we still emit a join
/// so callers always get a dependable handle per member.
fn singleton<T: Transport>(t: &mut T, deps: &[T::Handle], tag: &'static str) -> Vec<T::Handle> {
    vec![t.join(deps, tag)]
}

/// Ring AllGather: `g-1` steps; at step `s`, member `i` forwards the chunk
/// it received at step `s-1` (initially its own, `inputs[i]`) to member
/// `i+1`. Every member ends with all chunks; member `j`'s output is
/// `inputs` in group order. Completion of member `i` = its final receive.
pub fn ring_allgather<T: Transport>(
    t: &mut T,
    group: &[usize],
    inputs: &[T::Chunk],
    deps: &[T::Handle],
    tag: &'static str,
) -> (Vec<Vec<T::Chunk>>, Vec<T::Handle>) {
    let g = group.len();
    assert_eq!(inputs.len(), g, "one input chunk per group member");
    let outputs: Vec<Vec<T::Chunk>> = (0..g).map(|_| inputs.to_vec()).collect();
    if g == 1 {
        return (outputs, singleton(t, deps, tag));
    }
    let mut prev: Vec<T::Handle> = Vec::new();
    let mut last_recv: Vec<Option<T::Handle>> = vec![None; g];
    for s in 0..g - 1 {
        let mut cur = Vec::with_capacity(g);
        for i in 0..g {
            let dst = (i + 1) % g;
            let dep: Vec<T::Handle> = if s == 0 {
                deps.to_vec()
            } else {
                vec![prev[(i + g - 1) % g].clone()]
            };
            // The chunk member i holds for forwarding at step s originated
            // at member (i - s) mod g.
            let h = t.send(group[i], group[dst], &inputs[(i + g - s) % g], &dep, tag);
            last_recv[dst] = Some(h.clone());
            cur.push(h);
        }
        prev = cur;
    }
    let done = last_recv.into_iter().map(|h| h.expect("every member receives")).collect();
    (outputs, done)
}

/// Ring ReduceScatter: same ring pattern; `inputs[i]` is member `i`'s `g`
/// chunks. At step `s` member `i` forwards the partial of chunk
/// `(i - s - 1) mod g`; the receiver folds in its own contribution. After
/// `g-1` steps member `j` holds the fully-reduced chunk `j`. Completion of
/// member `j` = receive of its fully-reduced chunk.
pub fn ring_reduce_scatter<T: Transport>(
    t: &mut T,
    group: &[usize],
    inputs: &[Vec<T::Chunk>],
    deps: &[T::Handle],
    tag: &'static str,
) -> (Vec<T::Chunk>, Vec<T::Handle>) {
    let g = group.len();
    assert_eq!(inputs.len(), g, "one chunk list per group member");
    assert!(inputs.iter().all(|c| c.len() == g), "g chunks per member");
    if g == 1 {
        return (vec![inputs[0][0].clone()], singleton(t, deps, tag));
    }
    // partial[i] = the accumulated chunk member i forwards next.
    let mut partial: Vec<T::Chunk> = (0..g).map(|i| inputs[i][(i + g - 1) % g].clone()).collect();
    let mut prev: Vec<T::Handle> = Vec::new();
    let mut reduced: Vec<Option<T::Chunk>> = vec![None; g];
    let mut done: Vec<Option<T::Handle>> = vec![None; g];
    for s in 0..g - 1 {
        let mut cur = Vec::with_capacity(g);
        let mut next_partial: Vec<Option<T::Chunk>> = vec![None; g];
        for i in 0..g {
            let dst = (i + 1) % g;
            let dep: Vec<T::Handle> = if s == 0 {
                deps.to_vec()
            } else {
                vec![prev[(i + g - 1) % g].clone()]
            };
            let h = t.send(group[i], group[dst], &partial[i], &dep, tag);
            // Chunk id travelling on this edge; the receiver folds in its
            // own contribution before forwarding (or keeping) it.
            let j = (i + g - 1 - s) % g;
            let mut acc = partial[i].clone();
            acc.reduce_add(&inputs[dst][j]);
            if s == g - 2 {
                reduced[dst] = Some(acc);
                done[dst] = Some(h.clone());
            } else {
                next_partial[dst] = Some(acc);
            }
            cur.push(h);
        }
        if s < g - 2 {
            partial = next_partial.into_iter().map(|c| c.expect("ring covers all")).collect();
        }
        prev = cur;
    }
    (
        reduced.into_iter().map(|c| c.expect("every member reduced")).collect(),
        done.into_iter().map(|h| h.expect("every member receives")).collect(),
    )
}

/// AllReduce = ReduceScatter ∘ AllGather over each member's `g` chunks.
/// Member `j` ends with all `g` reduced chunks (group order — concatenate
/// for the full sum). The RS completions fan in through a join before the
/// AG phase (the RS chunks all complete within α of each other on a ring,
/// so the join loses nothing material).
pub fn ring_allreduce<T: Transport>(
    t: &mut T,
    group: &[usize],
    inputs: &[Vec<T::Chunk>],
    deps: &[T::Handle],
    tag: &'static str,
) -> (Vec<Vec<T::Chunk>>, Vec<T::Handle>) {
    let (reduced, rs_done) = ring_reduce_scatter(t, group, inputs, deps, tag);
    let j = t.join(&rs_done, tag);
    ring_allgather(t, group, &reduced, &[j], tag)
}

/// Pairwise-exchange AlltoAll: rounds `r = 1..g-1`; in round `r` member `i`
/// sends `inputs[i][(i+r) mod g]` to member `(i+r) mod g`. Member `j` ends
/// with `outputs[j][i] = inputs[i][j]` (its own chunk never touches the
/// wire). Completion per member: all its sends and receives done.
///
/// Sends are chained per *(sender, link class)* via
/// [`Transport::same_node`]: a sender's intra-node sends form one queue and
/// its inter-node sends another, progressing concurrently (NCCL uses
/// distinct channels for P2P over PCIe vs the NIC). This is the property
/// §III-C's fused EP&ESP-AlltoAll exploits — intra-node ESP traffic
/// proceeds while inter-node EP traffic drains.
pub fn pairwise_alltoall<T: Transport>(
    t: &mut T,
    group: &[usize],
    inputs: &[Vec<T::Chunk>],
    deps: &[T::Handle],
    tag: &'static str,
) -> (Vec<Vec<T::Chunk>>, Vec<T::Handle>) {
    let g = group.len();
    assert_eq!(inputs.len(), g, "one chunk list per group member");
    assert!(inputs.iter().all(|c| c.len() == g), "g chunks per member");
    let outputs: Vec<Vec<T::Chunk>> =
        (0..g).map(|j| (0..g).map(|i| inputs[i][j].clone()).collect()).collect();
    if g == 1 {
        return (outputs, singleton(t, deps, tag));
    }
    let mut prev_intra: Vec<Option<T::Handle>> = vec![None; g];
    let mut prev_inter: Vec<Option<T::Handle>> = vec![None; g];
    let mut incident: Vec<Vec<T::Handle>> = vec![Vec::new(); g];
    for r in 1..g {
        for i in 0..g {
            let dst = (i + r) % g;
            // Empty chunks (a zero-width SP capacity span) put nothing on
            // the wire on either plane: no transfer task, no log entry, no
            // per-message α cost.
            if inputs[i][dst].bytes() == 0.0 {
                continue;
            }
            let intra = t.same_node(group[i], group[dst]);
            let prev = if intra { &mut prev_intra } else { &mut prev_inter };
            let dep: Vec<T::Handle> = match &prev[i] {
                None => deps.to_vec(),
                Some(h) => vec![h.clone()],
            };
            let h = t.send(group[i], group[dst], &inputs[i][dst], &dep, tag);
            prev[i] = Some(h.clone());
            incident[i].push(h.clone());
            incident[dst].push(h);
        }
    }
    let done = (0..g)
        .map(|i| {
            // A member whose chunks were ALL empty sent and received
            // nothing — its completion must still carry the caller's
            // deps, or the frontier would detach from the comm stream.
            if incident[i].is_empty() {
                t.join(deps, tag)
            } else {
                t.join(&incident[i], tag)
            }
        })
        .collect();
    (outputs, done)
}

/// Number of SAA phases: the AlltoAll's rounds are grouped into at most
/// this many phases; each member forwards one *accumulated* block to its
/// MP peers per phase (Fig 5's phase granularity). Coarsening keeps the
/// per-message α cost of the forwards at ring-AllGather scale instead of
/// paying α on every slice.
pub const SAA_PHASES: usize = 4;

/// Forward `block` (an accumulated slice block held by `a2a_group[i]`,
/// ready after `ready`) to `i`'s MP peers.
#[allow(clippy::too_many_arguments)]
fn saa_forward<T: Transport>(
    t: &mut T,
    a2a_group: &[usize],
    mp_groups: &[Vec<usize>],
    incident: &mut [Vec<T::Handle>],
    i: usize,
    block: &[T::Chunk],
    ready: &[T::Handle],
    tag_ag: &'static str,
) {
    if block.is_empty() {
        return;
    }
    let me = a2a_group[i];
    let grp = mp_groups
        .iter()
        .find(|grp| grp.contains(&me))
        .expect("rank missing from mp partition");
    let payload = T::Chunk::concat(block);
    // A fully-empty accumulated block (every slice zero bytes — a ragged
    // or clamped-away SP2 chunk) stays off the wire, matching
    // `pairwise_alltoall`'s empty-chunk rule.
    if payload.bytes() == 0.0 {
        return;
    }
    for &peer in grp {
        if peer == me {
            continue;
        }
        let h = t.send(me, peer, &payload, ready, tag_ag);
        incident[i].push(h.clone());
        if let Some(pi) = a2a_group.iter().position(|&x| x == peer) {
            incident[pi].push(h);
        }
    }
}

/// SAA — Simultaneous AlltoAll and AllGather (§III-D, Fig 5): the pairwise
/// AlltoAll over `a2a_group` immediately composed with an AllGather of each
/// member's AlltoAll output within its `mp_groups` partition.
///
/// With `overlap = true`, the AlltoAll's rounds are grouped into at most
/// [`SAA_PHASES`] phases; when member `i` has received every slice of a
/// phase (its own slice counts toward the first), it forwards the
/// accumulated block to each MP peer. Forwards depend only on that phase's
/// receives — they run concurrently with the next phase's AlltoAll rounds
/// (distinct link classes when MP is intra-node and the AlltoAll is
/// inter-node dominant). With `overlap = false` this is AAS, the §VI-C
/// ablation: AlltoAll to completion, then a ring MP-AllGather of the full
/// output. SAA also degrades to AAS when the whole group shares one node —
/// there is no second link class, so the phased forwards would only contend
/// with the AlltoAll on the same ports.
///
/// Returns per member of `a2a_group`: its AllGather result as one chunk
/// list per MP peer (MP-group order; each peer's list is that peer's
/// AlltoAll output in source order), plus one completion handle.
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
pub fn saa<T: Transport>(
    t: &mut T,
    a2a_group: &[usize],
    mp_groups: &[Vec<usize>],
    inputs: &[Vec<T::Chunk>],
    deps: &[T::Handle],
    tag_a2a: &'static str,
    tag_ag: &'static str,
    overlap: bool,
) -> (Vec<Vec<Vec<T::Chunk>>>, Vec<T::Handle>) {
    let g = a2a_group.len();
    assert!(g > 0, "empty a2a group");
    assert_eq!(inputs.len(), g, "one chunk list per group member");
    assert!(inputs.iter().all(|c| c.len() == g), "g chunks per member");

    // a2a_out[j] = member j's AlltoAll output, in source order.
    let a2a_out: Vec<Vec<T::Chunk>> =
        (0..g).map(|j| (0..g).map(|i| inputs[i][j].clone()).collect()).collect();
    // Final value per member: each MP peer's AlltoAll output.
    let outputs: Vec<Vec<Vec<T::Chunk>>> = a2a_group
        .iter()
        .map(|&r| {
            let grp = mp_groups
                .iter()
                .find(|grp| grp.contains(&r))
                .expect("rank missing from mp partition");
            grp.iter()
                .map(|&q| {
                    let qi = a2a_group.iter().position(|&x| x == q).expect("mp peer in group");
                    a2a_out[qi].clone()
                })
                .collect()
        })
        .collect();

    let single_node = a2a_group.iter().all(|&r| t.same_node(r, a2a_group[0]));
    if !overlap || (single_node && g > 1) {
        // AAS: AlltoAll to completion, then ring-AllGather the full output
        // (each member contributes its g chunks as one block).
        let (_, a2a_done) = pairwise_alltoall(t, a2a_group, inputs, deps, tag_a2a);
        let j = t.join(&a2a_done, tag_a2a);
        let mut done: Vec<Option<T::Handle>> = vec![None; g];
        for grp in mp_groups {
            let contribs: Vec<T::Chunk> = grp
                .iter()
                .map(|&q| {
                    let qi = a2a_group.iter().position(|&x| x == q).expect("mp peer in group");
                    T::Chunk::concat(&a2a_out[qi])
                })
                .collect();
            let (_, ends) = ring_allgather(t, grp, &contribs, &[j.clone()], tag_ag);
            for (gi, &r) in grp.iter().enumerate() {
                if let Some(pi) = a2a_group.iter().position(|&x| x == r) {
                    done[pi] = Some(ends[gi].clone());
                }
            }
        }
        let done = done.into_iter().map(|h| h.expect("mp partition covers group")).collect();
        return (outputs, done);
    }

    let mut incident: Vec<Vec<T::Handle>> = vec![Vec::new(); g];
    let rounds = g - 1;
    if rounds == 0 {
        // Degenerate single-member AlltoAll: forward the own slice only.
        for i in 0..g {
            let own = [inputs[i][i].clone()];
            saa_forward(t, a2a_group, mp_groups, &mut incident, i, &own, deps, tag_ag);
        }
        let done = (0..g)
            .map(|i| {
                if incident[i].is_empty() {
                    t.join(deps, tag_a2a)
                } else {
                    t.join(&incident[i], tag_a2a)
                }
            })
            .collect();
        return (outputs, done);
    }

    // Partition rounds 1..g-1 into SAA_PHASES contiguous phases; the own
    // slice (round 0) joins the first phase's forward.
    let n_phases = SAA_PHASES.min(rounds);
    let mut prev_intra: Vec<Option<T::Handle>> = vec![None; g];
    let mut prev_inter: Vec<Option<T::Handle>> = vec![None; g];
    let mut round = 1usize;
    for phase in 0..n_phases {
        let remaining_phases = n_phases - phase;
        let remaining_rounds = rounds + 1 - round;
        let in_phase = remaining_rounds / remaining_phases
            + usize::from(remaining_rounds % remaining_phases != 0);
        // Receives of this phase, per receiving member.
        let mut phase_recv: Vec<Vec<T::Handle>> = vec![Vec::new(); g];
        let mut phase_chunks: Vec<Vec<T::Chunk>> = vec![Vec::new(); g];
        for p in round..round + in_phase {
            for i in 0..g {
                let dst = (i + p) % g;
                // Ragged chunk partitions can carry zero-byte slices
                // (buffers smaller than the group, clamped SP2 spans) —
                // keep them off the wire like `pairwise_alltoall` does.
                // The slice still joins the receiver's forward block (it
                // contributes nothing to the payload) so the AllGather
                // semantics are unchanged.
                if inputs[i][dst].bytes() == 0.0 {
                    phase_chunks[dst].push(inputs[i][dst].clone());
                    continue;
                }
                let intra = t.same_node(a2a_group[i], a2a_group[dst]);
                let prev = if intra { &mut prev_intra } else { &mut prev_inter };
                let dep: Vec<T::Handle> = match &prev[i] {
                    None => deps.to_vec(),
                    Some(h) => vec![h.clone()],
                };
                let h = t.send(a2a_group[i], a2a_group[dst], &inputs[i][dst], &dep, tag_a2a);
                prev[i] = Some(h.clone());
                incident[i].push(h.clone());
                incident[dst].push(h.clone());
                phase_recv[dst].push(h);
                phase_chunks[dst].push(inputs[i][dst].clone());
            }
        }
        round += in_phase;
        // Forward the accumulated block (+ own slice in the first phase).
        // When every receive of the phase was an off-the-wire empty slice,
        // the forward falls back to the caller's deps so it cannot detach
        // from the comm frontier.
        for i in 0..g {
            let mut block = std::mem::take(&mut phase_chunks[i]);
            if phase == 0 {
                block.insert(0, inputs[i][i].clone());
            }
            let ready = std::mem::take(&mut phase_recv[i]);
            if ready.is_empty() {
                saa_forward(t, a2a_group, mp_groups, &mut incident, i, &block, deps, tag_ag);
            } else {
                saa_forward(t, a2a_group, mp_groups, &mut incident, i, &block, &ready, tag_ag);
            }
        }
    }

    let done = (0..g)
        .map(|i| {
            // A member that touched no wire at all (every chunk empty)
            // still carries the caller's deps, exactly like
            // `pairwise_alltoall`'s all-empty completion.
            if incident[i].is_empty() {
                t.join(deps, tag_a2a)
            } else {
                t.join(&incident[i], tag_a2a)
            }
        })
        .collect();
    (outputs, done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::DataTransport;

    fn world(g: usize, per: usize) -> Vec<Vec<f32>> {
        (0..g).map(|i| (0..per).map(|j| (i * per + j) as f32).collect()).collect()
    }

    #[test]
    fn generic_allgather_orders_chunks() {
        let mut t = DataTransport::new();
        let inputs = world(3, 2);
        let (outs, done) = ring_allgather(&mut t, &[5, 6, 7], &inputs, &[], "ag");
        assert_eq!(done.len(), 3);
        for out in &outs {
            assert_eq!(out.len(), 3);
            assert_eq!(out[0], inputs[0]);
            assert_eq!(out[2], inputs[2]);
        }
        // g·(g-1) messages of 2 floats each.
        assert_eq!(t.log(), &[("ag", (3 * 2 * 2 * 4) as f64)]);
    }

    #[test]
    fn generic_reduce_scatter_sums() {
        let mut t = DataTransport::new();
        // inputs[i][j]: member i's chunk j.
        let inputs: Vec<Vec<Vec<f32>>> = (0..3)
            .map(|i| (0..3).map(|j| vec![(10 * i + j) as f32]).collect())
            .collect();
        let (reduced, done) = ring_reduce_scatter(&mut t, &[0, 1, 2], &inputs, &[], "rs");
        assert_eq!(done.len(), 3);
        for (j, r) in reduced.iter().enumerate() {
            // Σ_i (10i + j) = 30 + 3j.
            assert_eq!(r, &vec![(30 + 3 * j) as f32]);
        }
    }

    #[test]
    fn generic_allreduce_full_sum_everywhere() {
        let mut t = DataTransport::new();
        let inputs: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|i| (0..4).map(|j| vec![i as f32, j as f32]).collect())
            .collect();
        let (outs, _) = ring_allreduce(&mut t, &[0, 1, 2, 3], &inputs, &[], "ar");
        for out in &outs {
            for (j, c) in out.iter().enumerate() {
                assert_eq!(c, &vec![6.0, 4.0 * j as f32]);
            }
        }
    }

    #[test]
    fn generic_alltoall_transposes() {
        let mut t = DataTransport::new();
        let inputs: Vec<Vec<Vec<f32>>> = (0..3)
            .map(|i| (0..3).map(|j| vec![(i * 10 + j) as f32]).collect())
            .collect();
        let (outs, _) = pairwise_alltoall(&mut t, &[0, 1, 2], &inputs, &[], "a2a");
        for (j, out) in outs.iter().enumerate() {
            for (i, c) in out.iter().enumerate() {
                assert_eq!(c, &vec![(i * 10 + j) as f32]);
            }
        }
        // Own chunks stay local: 3·2 messages of one f32.
        assert_eq!(t.log(), &[("a2a", (3 * 2 * 4) as f64)]);
    }

    #[test]
    fn generic_saa_equals_a2a_then_allgather() {
        // Data semantics of SAA must equal the composed collectives —
        // regardless of the overlap flag.
        let inputs: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|i| (0..4).map(|j| vec![(i * 10 + j) as f32; 2]).collect())
            .collect();
        let mp: Vec<Vec<usize>> = vec![vec![0, 1], vec![2, 3]];
        for overlap in [false, true] {
            let mut t = DataTransport::new();
            let (outs, done) =
                saa(&mut t, &[0, 1, 2, 3], &mp, &inputs, &[], "a2a", "ag", overlap);
            assert_eq!(done.len(), 4);
            for (pi, out) in outs.iter().enumerate() {
                let grp = &mp[pi / 2];
                assert_eq!(out.len(), 2);
                for (k, &peer) in grp.iter().enumerate() {
                    for (i, c) in out[k].iter().enumerate() {
                        assert_eq!(c, &vec![(i * 10 + peer) as f32; 2]);
                    }
                }
            }
            // Wire totals identical across the two forms.
            let total: f64 = t.log().iter().map(|(_, b)| b).sum();
            // A2A: 4·3 chunks of 2 f32; AG: each member forwards its 4-chunk
            // output to 1 peer = 4·4·2 f32.
            assert_eq!(total, (12 * 2 * 4 + 4 * 4 * 2 * 4) as f64);
        }
    }

    #[test]
    fn singleton_groups_are_free() {
        let mut t = DataTransport::new();
        let (outs, done) = ring_allgather(&mut t, &[3], &world(1, 4), &[], "ag");
        assert_eq!(outs[0].len(), 1);
        assert_eq!(done.len(), 1);
        assert!(t.log().is_empty());
    }
}
