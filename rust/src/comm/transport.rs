//! The transport abstraction under the one-source collective core.
//!
//! A collective algorithm in [`crate::comm::algo`] is written ONCE, generic
//! over a [`Transport`]. The transport decides what a point-to-point
//! message *is*:
//!
//! * [`DagTransport`] — the **timing plane**: every `send` appends a
//!   transfer task to a [`SimDag`] for the discrete-event engine; payloads
//!   are byte counts ([`Lump`]) and dependency handles are [`TaskId`]s, so
//!   the algorithm's chaining structure becomes the DAG's critical path.
//! * [`DataTransport`] — the **data plane**: payloads are real `f32`
//!   chunks; the algorithm's value bookkeeping IS the data movement, and
//!   the transport records a `(tag, bytes)` wire log whose per-tag totals
//!   must equal the timing plane's (this is what makes timing/numerics
//!   agreement structural rather than test-enforced).
//!
//! The same algorithm source + the same tag constants
//! ([`crate::comm::tags`]) means the schedule we time is — by construction,
//! not by cross-check — the schedule we execute.

use crate::config::{ClusterTopology, WireDtype, WireLeg, WirePrecision};
use crate::sim::dag::{SimDag, TaskId};

/// Payload of one point-to-point message inside a generic collective.
pub trait Chunk: Clone {
    /// Wire size of this chunk in bytes.
    fn bytes(&self) -> f64;
    /// Elementwise-accumulate `rhs` into `self` (ReduceScatter/AllReduce
    /// partials). Reduction must not change the wire size.
    fn reduce_add(&mut self, rhs: &Self);
    /// Concatenate `parts` into one block (SAA's phased forwards send
    /// several accumulated slices as a single message).
    fn concat(parts: &[Self]) -> Self;
    /// Simulate narrowing this payload to `dtype` on the wire. Byte counts
    /// (`Lump`) carry no values to round — the timing plane prices the
    /// narrowing in its transport instead — so the default is a no-op.
    fn quantize(&mut self, _dtype: WireDtype) {}
}

/// Timing-plane payload: a byte count, no data.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Lump(pub f64);

impl Chunk for Lump {
    fn bytes(&self) -> f64 {
        self.0
    }

    fn reduce_add(&mut self, _rhs: &Self) {
        // A reduced partial has the same wire size as its inputs.
    }

    fn concat(parts: &[Self]) -> Self {
        Lump(parts.iter().map(|c| c.0).sum())
    }
}

/// Data-plane payload: a real slice of rank-local `f32` state.
impl Chunk for Vec<f32> {
    fn bytes(&self) -> f64 {
        (self.len() * 4) as f64
    }

    fn reduce_add(&mut self, rhs: &Self) {
        assert_eq!(self.len(), rhs.len(), "reduce over unequal chunks");
        for (a, b) in self.iter_mut().zip(rhs.iter()) {
            *a += b;
        }
    }

    fn concat(parts: &[Self]) -> Self {
        let mut out = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
        for p in parts {
            out.extend_from_slice(p);
        }
        out
    }

    fn quantize(&mut self, dtype: WireDtype) {
        if dtype == WireDtype::F32 {
            return;
        }
        for v in self.iter_mut() {
            *v = dtype.quantize(*v);
        }
    }
}

/// Split a data buffer into `g` contiguous chunks whose sizes differ by at
/// most one element (the first `len % g` chunks are one longer). With
/// `len % g == 0` this is the uniform split the chunk-addressed
/// collectives (AlltoAll, ReduceScatter) require; reductions whose result
/// is only ever consumed re-concatenated (AllReduce) tolerate the ragged
/// form — the generic ring algorithms never inspect chunk sizes.
pub fn split_chunks(buf: &[f32], g: usize) -> Vec<Vec<f32>> {
    let base = buf.len() / g;
    let rem = buf.len() % g;
    let mut out = Vec::with_capacity(g);
    let mut start = 0;
    for j in 0..g {
        let len = base + usize::from(j < rem);
        out.push(buf[start..start + len].to_vec());
        start += len;
    }
    out
}

/// What a collective algorithm needs from the world: point-to-point sends,
/// per-rank compute, dependency joins, and the link-class oracle that
/// drives per-(sender, class) chaining.
pub trait Transport {
    /// Dependency token: [`TaskId`] on the timing plane, `()` on the data
    /// plane (in-process execution is already sequential).
    type Handle: Clone;
    /// Message payload: [`Lump`] (bytes) or `Vec<f32>` (data).
    type Chunk: Chunk;

    /// Move `chunk` from rank `src` to rank `dst` after `deps`.
    fn send(
        &mut self,
        src: usize,
        dst: usize,
        chunk: &Self::Chunk,
        deps: &[Self::Handle],
        tag: &'static str,
    ) -> Self::Handle;

    /// Run `flops` of compute on `rank` after `deps`.
    fn compute(
        &mut self,
        rank: usize,
        flops: f64,
        deps: &[Self::Handle],
        tag: &'static str,
    ) -> Self::Handle;

    /// Zero-cost fan-in over `deps`.
    fn join(&mut self, deps: &[Self::Handle], tag: &'static str) -> Self::Handle;

    /// True when `a` and `b` share a node (same link class). Decides the
    /// per-(sender, link-class) send chaining of the pairwise AlltoAll and
    /// whether SAA has a second link class to overlap onto.
    fn same_node(&self, a: usize, b: usize) -> bool;

    /// Select which [`WireLeg`] subsequent sends belong to. The
    /// interpreter calls this before each collective; wire-precision-aware
    /// transports price (timing plane) or log (data plane) sends at that
    /// leg's dtype. The default ignores legs — an unconfigured transport
    /// behaves exactly as the f32 wire.
    fn set_wire_leg(&mut self, _leg: WireLeg) {}

    /// Wire dtype of the currently selected leg (`F32` unless a policy was
    /// installed). The interpreter quantizes marshalled data payloads with
    /// this before handing them to the collective algorithms.
    fn wire_dtype(&self) -> WireDtype {
        WireDtype::F32
    }
}

/// Timing plane: emit the collective as transfer/compute tasks of a
/// [`SimDag`], classified against a [`ClusterTopology`] topology. With a
/// wire-precision policy installed, every transfer is priced at the
/// current leg's compressed volume (`wire_bytes / dtype_bytes` of the
/// op's model-width bytes).
pub struct DagTransport<'a> {
    dag: &'a mut SimDag,
    cluster: &'a ClusterTopology,
    wire: WirePrecision,
    /// Bytes per model element — the width the op byte fields were
    /// derived at, i.e. the denominator of the compression factor.
    model_bytes: f64,
    leg: WireLeg,
}

impl<'a> DagTransport<'a> {
    /// An f32-wire transport: prices exactly the op byte fields.
    pub fn new(dag: &'a mut SimDag, cluster: &'a ClusterTopology) -> DagTransport<'a> {
        DagTransport::with_wire(dag, cluster, WirePrecision::default(), 4)
    }

    /// A transport pricing each leg at `wire`'s dtype, relative to a model
    /// dtype of `dtype_bytes` per element.
    pub fn with_wire(
        dag: &'a mut SimDag,
        cluster: &'a ClusterTopology,
        wire: WirePrecision,
        dtype_bytes: usize,
    ) -> DagTransport<'a> {
        DagTransport {
            dag,
            cluster,
            wire,
            model_bytes: dtype_bytes as f64,
            leg: WireLeg::Dispatch,
        }
    }
}

impl Transport for DagTransport<'_> {
    type Handle = TaskId;
    type Chunk = Lump;

    fn send(
        &mut self,
        src: usize,
        dst: usize,
        chunk: &Lump,
        deps: &[TaskId],
        tag: &'static str,
    ) -> TaskId {
        let scale = self.wire.dtype(self.leg).bytes() as f64 / self.model_bytes;
        self.dag.transfer(src, dst, chunk.0 * scale, deps, tag)
    }

    fn compute(&mut self, rank: usize, flops: f64, deps: &[TaskId], tag: &'static str) -> TaskId {
        self.dag.compute(rank, flops, deps, tag)
    }

    fn join(&mut self, deps: &[TaskId], tag: &'static str) -> TaskId {
        self.dag.join(deps, tag)
    }

    fn same_node(&self, a: usize, b: usize) -> bool {
        self.cluster.same_node(a, b)
    }

    fn set_wire_leg(&mut self, leg: WireLeg) {
        self.leg = leg;
    }

    fn wire_dtype(&self) -> WireDtype {
        self.wire.dtype(self.leg)
    }
}

/// Data plane: chunks are real `f32` vectors that the algorithms move by
/// value; the transport's job is the wire log. All ranks live in one
/// process (`same_node` is uniformly true), so SAA degrades to its
/// sequential form — per-tag volumes are identical either way. With a
/// wire-precision policy, the log reports COMPRESSED byte counts (the
/// buffers stay `f32` in memory; the interpreter rounds their values via
/// [`Chunk::quantize`] before the send).
#[derive(Debug, Default)]
pub struct DataTransport {
    /// Aggregated `(tag, total bytes)` in first-touch order.
    log: Vec<(&'static str, f64)>,
    wire: WirePrecision,
    leg: Option<WireLeg>,
}

impl DataTransport {
    pub fn new() -> DataTransport {
        DataTransport::default()
    }

    /// A transport logging each leg's sends at `wire`'s compressed width.
    pub fn with_wire(wire: WirePrecision) -> DataTransport {
        DataTransport { log: Vec::new(), wire, leg: None }
    }

    /// The wire log accumulated so far.
    pub fn log(&self) -> &[(&'static str, f64)] {
        &self.log
    }

    /// Consume the transport, returning its wire log.
    pub fn into_log(self) -> Vec<(&'static str, f64)> {
        self.log
    }
}

impl Transport for DataTransport {
    type Handle = ();
    type Chunk = Vec<f32>;

    fn send(
        &mut self,
        _src: usize,
        _dst: usize,
        chunk: &Vec<f32>,
        _deps: &[()],
        tag: &'static str,
    ) {
        // `bytes()` reports the in-memory f32 size; the wire carries the
        // current leg's dtype.
        let bytes = chunk.bytes() * self.wire_dtype().bytes() as f64 / 4.0;
        match self.log.iter_mut().find(|(t, _)| *t == tag) {
            Some((_, b)) => *b += bytes,
            None => self.log.push((tag, bytes)),
        }
    }

    fn compute(&mut self, _rank: usize, _flops: f64, _deps: &[()], _tag: &'static str) {}

    fn join(&mut self, _deps: &[()], _tag: &'static str) {}

    fn same_node(&self, _a: usize, _b: usize) -> bool {
        true
    }

    fn set_wire_leg(&mut self, leg: WireLeg) {
        self.leg = Some(leg);
    }

    fn wire_dtype(&self) -> WireDtype {
        self.leg.map_or(WireDtype::F32, |leg| self.wire.dtype(leg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lump_chunk_arithmetic() {
        let mut a = Lump(64.0);
        a.reduce_add(&Lump(64.0));
        assert_eq!(a.bytes(), 64.0); // reduction keeps wire size
        let c = Lump::concat(&[Lump(8.0), Lump(24.0)]);
        assert_eq!(c.bytes(), 32.0);
    }

    #[test]
    fn data_chunk_arithmetic() {
        let mut a = vec![1.0f32, 2.0];
        a.reduce_add(&vec![10.0, 20.0]);
        assert_eq!(a, vec![11.0, 22.0]);
        assert_eq!(a.bytes(), 8.0);
        let c = <Vec<f32> as Chunk>::concat(&[vec![1.0], vec![2.0, 3.0]]);
        assert_eq!(c, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn dag_transport_emits_tasks() {
        let cluster = ClusterTopology::testbed_a();
        let mut dag = SimDag::new();
        let mut t = DagTransport::new(&mut dag, &cluster);
        let a = t.send(0, 1, &Lump(100.0), &[], "x");
        let b = t.compute(1, 5.0, &[a], "c");
        t.join(&[b], "j");
        assert_eq!(dag.len(), 3);
        assert_eq!(dag.total_network_bytes(), 100.0);
    }

    #[test]
    fn split_chunks_covers_ragged_lengths() {
        let buf: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let chunks = split_chunks(&buf, 3);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0], vec![0.0, 1.0, 2.0]); // 7 = 3 + 2 + 2
        assert_eq!(chunks[1], vec![3.0, 4.0]);
        assert_eq!(chunks[2], vec![5.0, 6.0]);
        let uniform = split_chunks(&buf[..6], 3);
        assert!(uniform.iter().all(|c| c.len() == 2));
    }

    #[test]
    fn data_transport_aggregates_log_first_touch() {
        let mut t = DataTransport::new();
        t.send(0, 1, &vec![0.0f32; 4], &[], "a");
        t.send(1, 0, &vec![0.0f32; 2], &[], "b");
        t.send(0, 1, &vec![0.0f32; 4], &[], "a");
        assert_eq!(t.log(), &[("a", 32.0), ("b", 8.0)]);
    }

    #[test]
    fn dag_transport_prices_compressed_legs() {
        let cluster = ClusterTopology::testbed_a();
        let wire = WirePrecision::uniform(WireDtype::Bf16).with_leg(WireLeg::Wgrad, WireDtype::F32);
        let mut dag = SimDag::new();
        let mut t = DagTransport::with_wire(&mut dag, &cluster, wire, 4);
        t.set_wire_leg(WireLeg::Dispatch);
        t.send(0, 1, &Lump(100.0), &[], "d");
        t.set_wire_leg(WireLeg::Wgrad);
        t.send(0, 1, &Lump(100.0), &[], "w");
        // bf16 dispatch at half volume, f32 wgrad at full.
        assert_eq!(dag.total_network_bytes(), 50.0 + 100.0);
    }

    #[test]
    fn data_transport_logs_compressed_bytes() {
        let mut t = DataTransport::with_wire(WirePrecision::uniform(WireDtype::Fp8));
        // Before any leg is selected, sends log at f32 width.
        t.send(0, 1, &vec![0.0f32; 4], &[], "pre");
        t.set_wire_leg(WireLeg::Combine);
        assert_eq!(t.wire_dtype(), WireDtype::Fp8);
        t.send(0, 1, &vec![0.0f32; 4], &[], "c");
        assert_eq!(t.log(), &[("pre", 16.0), ("c", 4.0)]);
    }

    #[test]
    fn data_chunk_quantize_rounds_in_place() {
        let mut v = vec![1.0f32, 3.14159, -271.828];
        let exact = v.clone();
        v.quantize(WireDtype::F32);
        assert_eq!(v, exact);
        v.quantize(WireDtype::Bf16);
        assert_eq!(v[0], 1.0);
        for (q, x) in v.iter().zip(&exact) {
            assert!(((q - x) / x).abs() <= 2.0f32.powi(-8), "{x} -> {q}");
        }
    }
}
