//! The transport abstraction under the one-source collective core.
//!
//! A collective algorithm in [`crate::comm::algo`] is written ONCE, generic
//! over a [`Transport`]. The transport decides what a point-to-point
//! message *is*:
//!
//! * [`DagTransport`] — the **timing plane**: every `send` appends a
//!   transfer task to a [`SimDag`] for the discrete-event engine; payloads
//!   are byte counts ([`Lump`]) and dependency handles are [`TaskId`]s, so
//!   the algorithm's chaining structure becomes the DAG's critical path.
//! * [`DataTransport`] — the **data plane**: payloads are real `f32`
//!   chunks; the algorithm's value bookkeeping IS the data movement, and
//!   the transport records a `(tag, bytes)` wire log whose per-tag totals
//!   must equal the timing plane's (this is what makes timing/numerics
//!   agreement structural rather than test-enforced).
//!
//! The same algorithm source + the same tag constants
//! ([`crate::comm::tags`]) means the schedule we time is — by construction,
//! not by cross-check — the schedule we execute.

use crate::config::ClusterTopology;
use crate::sim::dag::{SimDag, TaskId};

/// Payload of one point-to-point message inside a generic collective.
pub trait Chunk: Clone {
    /// Wire size of this chunk in bytes.
    fn bytes(&self) -> f64;
    /// Elementwise-accumulate `rhs` into `self` (ReduceScatter/AllReduce
    /// partials). Reduction must not change the wire size.
    fn reduce_add(&mut self, rhs: &Self);
    /// Concatenate `parts` into one block (SAA's phased forwards send
    /// several accumulated slices as a single message).
    fn concat(parts: &[Self]) -> Self;
}

/// Timing-plane payload: a byte count, no data.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Lump(pub f64);

impl Chunk for Lump {
    fn bytes(&self) -> f64 {
        self.0
    }

    fn reduce_add(&mut self, _rhs: &Self) {
        // A reduced partial has the same wire size as its inputs.
    }

    fn concat(parts: &[Self]) -> Self {
        Lump(parts.iter().map(|c| c.0).sum())
    }
}

/// Data-plane payload: a real slice of rank-local `f32` state.
impl Chunk for Vec<f32> {
    fn bytes(&self) -> f64 {
        (self.len() * 4) as f64
    }

    fn reduce_add(&mut self, rhs: &Self) {
        assert_eq!(self.len(), rhs.len(), "reduce over unequal chunks");
        for (a, b) in self.iter_mut().zip(rhs.iter()) {
            *a += b;
        }
    }

    fn concat(parts: &[Self]) -> Self {
        let mut out = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
        for p in parts {
            out.extend_from_slice(p);
        }
        out
    }
}

/// Split a data buffer into `g` contiguous chunks whose sizes differ by at
/// most one element (the first `len % g` chunks are one longer). With
/// `len % g == 0` this is the uniform split the chunk-addressed
/// collectives (AlltoAll, ReduceScatter) require; reductions whose result
/// is only ever consumed re-concatenated (AllReduce) tolerate the ragged
/// form — the generic ring algorithms never inspect chunk sizes.
pub fn split_chunks(buf: &[f32], g: usize) -> Vec<Vec<f32>> {
    let base = buf.len() / g;
    let rem = buf.len() % g;
    let mut out = Vec::with_capacity(g);
    let mut start = 0;
    for j in 0..g {
        let len = base + usize::from(j < rem);
        out.push(buf[start..start + len].to_vec());
        start += len;
    }
    out
}

/// What a collective algorithm needs from the world: point-to-point sends,
/// per-rank compute, dependency joins, and the link-class oracle that
/// drives per-(sender, class) chaining.
pub trait Transport {
    /// Dependency token: [`TaskId`] on the timing plane, `()` on the data
    /// plane (in-process execution is already sequential).
    type Handle: Clone;
    /// Message payload: [`Lump`] (bytes) or `Vec<f32>` (data).
    type Chunk: Chunk;

    /// Move `chunk` from rank `src` to rank `dst` after `deps`.
    fn send(
        &mut self,
        src: usize,
        dst: usize,
        chunk: &Self::Chunk,
        deps: &[Self::Handle],
        tag: &'static str,
    ) -> Self::Handle;

    /// Run `flops` of compute on `rank` after `deps`.
    fn compute(
        &mut self,
        rank: usize,
        flops: f64,
        deps: &[Self::Handle],
        tag: &'static str,
    ) -> Self::Handle;

    /// Zero-cost fan-in over `deps`.
    fn join(&mut self, deps: &[Self::Handle], tag: &'static str) -> Self::Handle;

    /// True when `a` and `b` share a node (same link class). Decides the
    /// per-(sender, link-class) send chaining of the pairwise AlltoAll and
    /// whether SAA has a second link class to overlap onto.
    fn same_node(&self, a: usize, b: usize) -> bool;
}

/// Timing plane: emit the collective as transfer/compute tasks of a
/// [`SimDag`], classified against a [`ClusterTopology`] topology.
pub struct DagTransport<'a> {
    dag: &'a mut SimDag,
    cluster: &'a ClusterTopology,
}

impl<'a> DagTransport<'a> {
    pub fn new(dag: &'a mut SimDag, cluster: &'a ClusterTopology) -> DagTransport<'a> {
        DagTransport { dag, cluster }
    }
}

impl Transport for DagTransport<'_> {
    type Handle = TaskId;
    type Chunk = Lump;

    fn send(
        &mut self,
        src: usize,
        dst: usize,
        chunk: &Lump,
        deps: &[TaskId],
        tag: &'static str,
    ) -> TaskId {
        self.dag.transfer(src, dst, chunk.0, deps, tag)
    }

    fn compute(&mut self, rank: usize, flops: f64, deps: &[TaskId], tag: &'static str) -> TaskId {
        self.dag.compute(rank, flops, deps, tag)
    }

    fn join(&mut self, deps: &[TaskId], tag: &'static str) -> TaskId {
        self.dag.join(deps, tag)
    }

    fn same_node(&self, a: usize, b: usize) -> bool {
        self.cluster.same_node(a, b)
    }
}

/// Data plane: chunks are real `f32` vectors that the algorithms move by
/// value; the transport's job is the wire log. All ranks live in one
/// process (`same_node` is uniformly true), so SAA degrades to its
/// sequential form — per-tag volumes are identical either way.
#[derive(Debug, Default)]
pub struct DataTransport {
    /// Aggregated `(tag, total bytes)` in first-touch order.
    log: Vec<(&'static str, f64)>,
}

impl DataTransport {
    pub fn new() -> DataTransport {
        DataTransport::default()
    }

    /// The wire log accumulated so far.
    pub fn log(&self) -> &[(&'static str, f64)] {
        &self.log
    }

    /// Consume the transport, returning its wire log.
    pub fn into_log(self) -> Vec<(&'static str, f64)> {
        self.log
    }
}

impl Transport for DataTransport {
    type Handle = ();
    type Chunk = Vec<f32>;

    fn send(
        &mut self,
        _src: usize,
        _dst: usize,
        chunk: &Vec<f32>,
        _deps: &[()],
        tag: &'static str,
    ) {
        let bytes = chunk.bytes();
        match self.log.iter_mut().find(|(t, _)| *t == tag) {
            Some((_, b)) => *b += bytes,
            None => self.log.push((tag, bytes)),
        }
    }

    fn compute(&mut self, _rank: usize, _flops: f64, _deps: &[()], _tag: &'static str) {}

    fn join(&mut self, _deps: &[()], _tag: &'static str) {}

    fn same_node(&self, _a: usize, _b: usize) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lump_chunk_arithmetic() {
        let mut a = Lump(64.0);
        a.reduce_add(&Lump(64.0));
        assert_eq!(a.bytes(), 64.0); // reduction keeps wire size
        let c = Lump::concat(&[Lump(8.0), Lump(24.0)]);
        assert_eq!(c.bytes(), 32.0);
    }

    #[test]
    fn data_chunk_arithmetic() {
        let mut a = vec![1.0f32, 2.0];
        a.reduce_add(&vec![10.0, 20.0]);
        assert_eq!(a, vec![11.0, 22.0]);
        assert_eq!(a.bytes(), 8.0);
        let c = <Vec<f32> as Chunk>::concat(&[vec![1.0], vec![2.0, 3.0]]);
        assert_eq!(c, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn dag_transport_emits_tasks() {
        let cluster = ClusterTopology::testbed_a();
        let mut dag = SimDag::new();
        let mut t = DagTransport::new(&mut dag, &cluster);
        let a = t.send(0, 1, &Lump(100.0), &[], "x");
        let b = t.compute(1, 5.0, &[a], "c");
        t.join(&[b], "j");
        assert_eq!(dag.len(), 3);
        assert_eq!(dag.total_network_bytes(), 100.0);
    }

    #[test]
    fn split_chunks_covers_ragged_lengths() {
        let buf: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let chunks = split_chunks(&buf, 3);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0], vec![0.0, 1.0, 2.0]); // 7 = 3 + 2 + 2
        assert_eq!(chunks[1], vec![3.0, 4.0]);
        assert_eq!(chunks[2], vec![5.0, 6.0]);
        let uniform = split_chunks(&buf[..6], 3);
        assert!(uniform.iter().all(|c| c.len() == 2));
    }

    #[test]
    fn data_transport_aggregates_log_first_touch() {
        let mut t = DataTransport::new();
        t.send(0, 1, &vec![0.0f32; 4], &[], "a");
        t.send(1, 0, &vec![0.0f32; 2], &[], "b");
        t.send(0, 1, &vec![0.0f32; 4], &[], "a");
        assert_eq!(t.log(), &[("a", 32.0), ("b", 8.0)]);
    }
}
