//! Configuration layer: MoE layer hyper-parameters, cluster topologies
//! (per-node hardware + per-link α-β), real-world model descriptions,
//! the Table III sweep grid, and drifting-traffic trace specs.

pub mod cluster;
pub mod model;
pub mod moe;
pub mod precision;
pub mod sweep;
pub mod trace;

pub use cluster::{AlphaBeta, ClusterTopology, LinkClass, NodeSpec};
pub use model::ModelConfig;
pub use moe::{MoeLayerConfig, ParallelDegrees};
pub use precision::{WireDtype, WireLeg, WirePrecision};
pub use sweep::{sweep_table3, sweep_table3_scaled, GridAxes, SweepFilter};
pub use trace::TraceSpec;
