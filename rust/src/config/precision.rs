//! Wire precision as a first-class axis: which dtype each communication
//! *leg* of a schedule rides at, independent of the model dtype.
//!
//! Parm's schedule picks are driven by β-dominated communication terms,
//! and production MoE systems compress exactly those wires: dispatch and
//! combine AlltoAlls in bf16/fp8 with f32 accumulation, while parameter
//! state stays wide. A [`WirePrecision`] names a [`WireDtype`] per
//! [`WireLeg`]; the op programs keep carrying MODEL-width byte fields
//! (elements × `dtype_bytes`), and the two transports scale / quantize at
//! the edge:
//!
//! * the timing plane prices every send at `wire_bytes / dtype_bytes` of
//!   the op volume, so `t_d1/t_d2/t_sp/t_sp2`, the backward terms, and
//!   Algorithm 1 all re-decide per precision;
//! * the data plane rounds the real `f32` payloads to the wire dtype on
//!   send ([`WireDtype::quantize`]) and logs compressed byte counts,
//!   keeping f32 accumulation in every reduce step.
//!
//! The default policy is all-f32, which prices and rounds to exactly the
//! current behaviour — configs, cache keys, goldens, and plan artifacts
//! are byte-identical unless a leg is narrowed.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// A wire dtype a communication leg can ride at. `quantize` simulates the
/// narrowing on real `f32` values (round-trip through the narrow format);
/// storage stays `f32`, so "dequantize on receive" is the identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum WireDtype {
    /// 4-byte IEEE single — the lossless default.
    F32,
    /// 2-byte bfloat16: f32's exponent range, 8-bit significand.
    Bf16,
    /// 1-byte OCP e4m3: 4-bit exponent, 3-bit mantissa, max normal 448.
    Fp8,
}

impl WireDtype {
    /// Bytes per element on the wire.
    pub fn bytes(self) -> usize {
        match self {
            WireDtype::F32 => 4,
            WireDtype::Bf16 => 2,
            WireDtype::Fp8 => 1,
        }
    }

    /// Canonical lowercase name (CLI / JSON spelling).
    pub fn name(self) -> &'static str {
        match self {
            WireDtype::F32 => "f32",
            WireDtype::Bf16 => "bf16",
            WireDtype::Fp8 => "fp8",
        }
    }

    /// Parse the canonical spelling.
    pub fn parse(s: &str) -> Result<WireDtype> {
        match s {
            "f32" => Ok(WireDtype::F32),
            "bf16" => Ok(WireDtype::Bf16),
            "fp8" => Ok(WireDtype::Fp8),
            other => bail!("unknown wire dtype {other:?} (expected f32, bf16, or fp8)"),
        }
    }

    /// Round-trip `v` through this wire format: the value a receiver would
    /// dequantize after the sender narrowed it. Round-to-nearest-even for
    /// the normal ranges; NaN and zero pass through unchanged.
    pub fn quantize(self, v: f32) -> f32 {
        match self {
            WireDtype::F32 => v,
            WireDtype::Bf16 => {
                if v.is_nan() {
                    return v;
                }
                // RNE to the top 16 bits: add half-ulp plus the tie-break
                // bit, then truncate the low mantissa.
                let bits = v.to_bits();
                let round = 0x7fff + ((bits >> 16) & 1);
                f32::from_bits(bits.wrapping_add(round) & 0xffff_0000)
            }
            WireDtype::Fp8 => {
                if v.is_nan() || v == 0.0 {
                    return v;
                }
                // e4m3 (OCP): max normal ±448, min normal 2^-6, subnormal
                // grid multiples of 2^-9.
                let clamped = v.clamp(-448.0, 448.0);
                let a = clamped.abs();
                if a < 0.015625 {
                    // 2^-6: below the normal range, snap to the 2^-9 grid.
                    let q = (a * 512.0).round() / 512.0;
                    return if clamped < 0.0 { -q } else { q };
                }
                // Normal range: RNE the f32 mantissa down to 3 bits.
                let bits = clamped.to_bits();
                let round = 0x0007_ffff + ((bits >> 20) & 1);
                let q = f32::from_bits(bits.wrapping_add(round) & 0xfff0_0000);
                // Mantissa carry at the top binade can overshoot the format.
                q.clamp(-448.0, 448.0)
            }
        }
    }
}

/// The four independently narrowable communication legs of a schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireLeg {
    /// Token dispatch AlltoAll (EP or fused EP×ESP), monolithic or chunked,
    /// forward and its backward adjoint.
    Dispatch,
    /// Combine AlltoAll / SAA (a2a *and* its overlapped MP-AllGather
    /// forwards ride together), forward and backward.
    Combine,
    /// The plain MP/ESP AllGather / ReduceScatter / AllReduce epilogues.
    AllGather,
    /// The backward expert weight-gradient AllReduce over ESP groups.
    Wgrad,
}

impl WireLeg {
    /// All legs, in canonical (JSON key) order.
    pub const ALL: [WireLeg; 4] =
        [WireLeg::Dispatch, WireLeg::Combine, WireLeg::AllGather, WireLeg::Wgrad];

    /// Canonical lowercase name (JSON key).
    pub fn name(self) -> &'static str {
        match self {
            WireLeg::Dispatch => "dispatch",
            WireLeg::Combine => "combine",
            WireLeg::AllGather => "allgather",
            WireLeg::Wgrad => "wgrad",
        }
    }
}

/// Per-leg wire dtype policy. `Default` is all-f32 (today's behaviour);
/// a policy only appears in config JSON / ids when it is non-default, so
/// every existing cache key, golden, and plan artifact is unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WirePrecision {
    pub dispatch: WireDtype,
    pub combine: WireDtype,
    pub allgather: WireDtype,
    pub wgrad: WireDtype,
}

impl Default for WirePrecision {
    fn default() -> WirePrecision {
        WirePrecision::uniform(WireDtype::F32)
    }
}

impl WirePrecision {
    /// Every leg at the same dtype.
    pub fn uniform(d: WireDtype) -> WirePrecision {
        WirePrecision { dispatch: d, combine: d, allgather: d, wgrad: d }
    }

    /// True for the all-f32 policy (the one that never serializes).
    pub fn is_default(&self) -> bool {
        *self == WirePrecision::default()
    }

    /// The wire dtype of `leg`.
    pub fn dtype(&self, leg: WireLeg) -> WireDtype {
        match leg {
            WireLeg::Dispatch => self.dispatch,
            WireLeg::Combine => self.combine,
            WireLeg::AllGather => self.allgather,
            WireLeg::Wgrad => self.wgrad,
        }
    }

    /// Replace `leg`'s dtype (builder-style, for CLI per-leg overrides).
    pub fn with_leg(mut self, leg: WireLeg, d: WireDtype) -> WirePrecision {
        match leg {
            WireLeg::Dispatch => self.dispatch = d,
            WireLeg::Combine => self.combine = d,
            WireLeg::AllGather => self.allgather = d,
            WireLeg::Wgrad => self.wgrad = d,
        }
        self
    }

    /// Compact id fragment for non-default policies: `bf16` when uniform,
    /// `d<..>-c<..>-g<..>-r<..>` otherwise. Callers prepend `_w`.
    pub fn id_suffix(&self) -> String {
        let u = self.dispatch;
        if *self == WirePrecision::uniform(u) {
            return u.name().to_string();
        }
        format!(
            "d{}-c{}-g{}-r{}",
            self.dispatch.name(),
            self.combine.name(),
            self.allgather.name(),
            self.wgrad.name()
        )
    }

    /// Canonical JSON: the full per-leg object (keys sort alphabetically
    /// in the canonical writer, so the form is stable for hashing).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dispatch", Json::str(self.dispatch.name())),
            ("combine", Json::str(self.combine.name())),
            ("allgather", Json::str(self.allgather.name())),
            ("wgrad", Json::str(self.wgrad.name())),
        ])
    }

    /// Parse either spelling: a bare string (`"bf16"` — uniform) or a
    /// per-leg object with any subset of the four keys (missing legs stay
    /// f32). Unknown keys and malformed values error loudly — this feeds
    /// sweep-cache keys.
    pub fn from_json(j: &Json) -> Result<WirePrecision> {
        match j {
            Json::Str(s) => Ok(WirePrecision::uniform(WireDtype::parse(s)?)),
            Json::Obj(map) => {
                let mut w = WirePrecision::default();
                for (k, v) in map {
                    let leg = match WireLeg::ALL.iter().find(|l| l.name() == k) {
                        Some(&l) => l,
                        None => bail!("unknown wire leg {k:?} (expected one of dispatch, combine, allgather, wgrad)"),
                    };
                    let s = v
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("wire leg {k:?} must be a dtype string"))?;
                    w = w.with_leg(leg, WireDtype::parse(s)?);
                }
                Ok(w)
            }
            other => bail!("wire precision must be a dtype string or per-leg object, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_names_round_trip() {
        for d in [WireDtype::F32, WireDtype::Bf16, WireDtype::Fp8] {
            assert_eq!(WireDtype::parse(d.name()).unwrap(), d);
        }
        assert!(WireDtype::parse("f16").is_err());
        assert_eq!(WireDtype::F32.bytes(), 4);
        assert_eq!(WireDtype::Bf16.bytes(), 2);
        assert_eq!(WireDtype::Fp8.bytes(), 1);
    }

    #[test]
    fn f32_quantize_is_identity() {
        for v in [0.0f32, -0.0, 1.0, -3.5e-20, 7.25e18, f32::INFINITY] {
            assert_eq!(WireDtype::F32.quantize(v).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn bf16_quantize_rounds_to_nearest_even() {
        // Exactly representable values survive.
        for v in [0.0f32, 1.0, -2.5, 448.0, 2.0f32.powi(-126)] {
            assert_eq!(WireDtype::Bf16.quantize(v), v);
        }
        // bf16 stores 7 mantissa bits, so the ulp at 1.0 is 2^-7 and
        // 1 + 2^-8 is the tie between 1.0 and 1 + 2^-7: ties to even → 1.0.
        let half = 1.0f32 + 2.0f32.powi(-8);
        assert_eq!(WireDtype::Bf16.quantize(half), 1.0);
        // Just above the tie rounds up to the next bf16 value.
        let above = 1.0f32 + 2.0f32.powi(-8) + 2.0f32.powi(-11);
        assert_eq!(WireDtype::Bf16.quantize(above), 1.0 + 2.0f32.powi(-7));
        // Relative error is bounded by 2^-8 across magnitudes.
        for v in [3.14159f32, -271.828, 6.022e8, -1.6e-12] {
            let q = WireDtype::Bf16.quantize(v);
            assert!(((q - v) / v).abs() <= 2.0f32.powi(-8), "{v} -> {q}");
        }
        // NaN stays NaN (the carry trick must not walk it to ±inf).
        assert!(WireDtype::Bf16.quantize(f32::NAN).is_nan());
        assert_eq!(WireDtype::Bf16.quantize(f32::INFINITY), f32::INFINITY);
    }

    #[test]
    fn fp8_quantize_clamps_and_rounds() {
        // Representable e4m3 values survive.
        for v in [0.0f32, 1.0, -1.75, 448.0, 0.015625] {
            assert_eq!(WireDtype::Fp8.quantize(v), v);
        }
        // Saturation to ±448.
        assert_eq!(WireDtype::Fp8.quantize(1.0e9), 448.0);
        assert_eq!(WireDtype::Fp8.quantize(-4.9e4), -448.0);
        // Relative error in the normal range is bounded by 2^-4.
        for v in [3.14159f32, -0.1, 417.0, 0.02] {
            let q = WireDtype::Fp8.quantize(v);
            assert!(((q - v) / v).abs() <= 2.0f32.powi(-4), "{v} -> {q}");
        }
        // Subnormals snap to the 2^-9 grid; tiny magnitudes flush to 0.
        assert_eq!(WireDtype::Fp8.quantize(0.003), 2.0 / 512.0);
        assert_eq!(WireDtype::Fp8.quantize(1.0e-4), 0.0);
        assert!(WireDtype::Fp8.quantize(f32::NAN).is_nan());
    }

    #[test]
    fn default_policy_is_all_f32_and_stays_out_of_ids() {
        let w = WirePrecision::default();
        assert!(w.is_default());
        for leg in WireLeg::ALL {
            assert_eq!(w.dtype(leg), WireDtype::F32);
        }
        assert!(!WirePrecision::uniform(WireDtype::Bf16).is_default());
    }

    #[test]
    fn id_suffix_compact_for_uniform_and_explicit_for_mixed() {
        assert_eq!(WirePrecision::uniform(WireDtype::Bf16).id_suffix(), "bf16");
        let mixed = WirePrecision::uniform(WireDtype::Bf16)
            .with_leg(WireLeg::Wgrad, WireDtype::F32);
        assert_eq!(mixed.id_suffix(), "dbf16-cbf16-gbf16-rf32");
    }

    #[test]
    fn json_round_trips_both_spellings() {
        let uniform = WirePrecision::uniform(WireDtype::Fp8);
        assert_eq!(WirePrecision::from_json(&uniform.to_json()).unwrap(), uniform);
        assert_eq!(
            WirePrecision::from_json(&Json::str("bf16")).unwrap(),
            WirePrecision::uniform(WireDtype::Bf16)
        );
        // Partial object: unnamed legs stay f32.
        let j = Json::obj(vec![("dispatch", Json::str("bf16"))]);
        let w = WirePrecision::from_json(&j).unwrap();
        assert_eq!(w.dispatch, WireDtype::Bf16);
        assert_eq!(w.combine, WireDtype::F32);
        // Malformed input errors loudly.
        assert!(WirePrecision::from_json(&Json::obj(vec![("disp", Json::str("bf16"))])).is_err());
        assert!(WirePrecision::from_json(&Json::obj(vec![("wgrad", Json::Num(2.0))])).is_err());
        assert!(WirePrecision::from_json(&Json::Num(16.0)).is_err());
    }
}
