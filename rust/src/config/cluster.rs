//! Cluster profiles: the hardware description the simulator runs against.
//!
//! The paper's testbeds are reduced to link-class α-β parameters — exactly
//! the reduction the paper itself applies for Algorithm 1 (§V-A, Fig 6).
//! Built-in profiles `testbed_a` / `testbed_b` are calibrated from the
//! constants the paper publishes (and PCIe/IB nominal bandwidths for the
//! classes it does not).

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Static description of a homogeneous GPU cluster (paper §IV assumptions:
/// homogeneous nodes, homogeneous devices, β_intra > β_inter).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterProfile {
    pub name: String,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Startup latency of an intra-node p2p transfer (seconds).
    pub alpha_intra: f64,
    /// Per-byte time of an intra-node p2p transfer (seconds/byte).
    pub beta_intra: f64,
    /// Startup latency of an inter-node p2p transfer (seconds).
    pub alpha_inter: f64,
    /// Per-byte time of an inter-node p2p transfer (seconds/byte).
    pub beta_inter: f64,
    /// Dense fp32 throughput of one GPU (FLOP/s) — times expert compute.
    pub gpu_flops: f64,
    /// Device memory (bytes) — drives the sweep feasibility filter.
    pub gpu_mem_bytes: usize,
}

impl ClusterProfile {
    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Node index hosting `rank`.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.gpus_per_node == 0 {
            bail!("cluster must have at least one node and one GPU");
        }
        if self.beta_intra <= 0.0 || self.beta_inter <= 0.0 {
            bail!("β must be positive");
        }
        if self.alpha_intra < 0.0 || self.alpha_inter < 0.0 {
            bail!("α must be non-negative");
        }
        if self.beta_intra > self.beta_inter {
            // Paper §IV: β_intra > β_inter refers to SPEED; our fields are
            // per-byte TIME, so intra must be <= inter.
            bail!(
                "intra-node per-byte time ({}) must not exceed inter-node ({})",
                self.beta_intra,
                self.beta_inter
            );
        }
        if self.gpu_flops <= 0.0 || self.gpu_mem_bytes == 0 {
            bail!("GPU compute/memory must be positive");
        }
        Ok(())
    }

    /// Testbed A (paper Table II): one node, 8× RTX 4090 on PCIe 4.0 x16.
    ///
    /// The paper's published AG_MP fit on this machine is collective-level
    /// (α = 6.64e-4 s, β = 5.38e-10 s/B). Our simulator composes
    /// collectives from point-to-point messages, so the per-message α is
    /// the collective α divided by the ring steps of the fitted group
    /// (8-GPU ring ⇒ 7 steps): α_msg ≈ 9.5e-5. β is per byte on the wire
    /// and carries over directly. There is no inter-node fabric; we keep a
    /// virtual inter class (unused at P=8) equal to PCIe for robustness.
    pub fn testbed_a() -> ClusterProfile {
        ClusterProfile {
            name: "testbed_a".into(),
            nodes: 1,
            gpus_per_node: 8,
            alpha_intra: 9.5e-5,
            beta_intra: 5.38e-10,
            alpha_inter: 9.5e-5,
            beta_inter: 5.38e-10,
            gpu_flops: 82.6e12 * 0.35, // RTX4090 peak fp32, derated to achievable GEMM
            gpu_mem_bytes: 24 * (1 << 30),
        }
    }

    /// Testbed B (paper Table II): 8 nodes × 4× RTX 2080Ti, PCIe 3.0 x16
    /// intra-node, 100 Gb/s ConnectX-5 inter-node.
    ///
    /// Intra α/β from the paper's 32-GPU AG_MP fit (collective α =
    /// 1.09e-4 over a 4-GPU ring ⇒ α_msg ≈ 3.6e-5; β = 7.14e-10). Inter β
    /// from 100 Gb/s ≈ 12.5 GB/s line rate derated to ~9 GB/s effective;
    /// inter α_msg ≈ 5e-5 (IB verbs + NCCL proxy per message).
    pub fn testbed_b() -> ClusterProfile {
        ClusterProfile {
            name: "testbed_b".into(),
            nodes: 8,
            gpus_per_node: 4,
            alpha_intra: 3.6e-5,
            beta_intra: 7.14e-10,
            alpha_inter: 5.0e-5,
            beta_inter: 1.11e-9,
            gpu_flops: 13.4e12 * 0.35, // RTX2080Ti peak fp32, derated
            gpu_mem_bytes: 11 * (1 << 30),
        }
    }

    /// Testbed B truncated to `gpus` total GPUs (the paper reports 8-, 16-
    /// and 32-GPU columns for testbed B in Table IV).
    pub fn testbed_b_subset(gpus: usize) -> Result<ClusterProfile> {
        let full = Self::testbed_b();
        if gpus % full.gpus_per_node != 0 || gpus > full.total_gpus() || gpus == 0 {
            bail!(
                "testbed B subset must be a positive multiple of {} ≤ {}",
                full.gpus_per_node,
                full.total_gpus()
            );
        }
        Ok(ClusterProfile {
            name: format!("testbed_b_{gpus}gpu"),
            nodes: gpus / full.gpus_per_node,
            ..full
        })
    }

    /// Look up a built-in profile by name.
    pub fn builtin(name: &str) -> Result<ClusterProfile> {
        match name {
            "testbed_a" => Ok(Self::testbed_a()),
            "testbed_b" | "testbed_b_32gpu" => Ok(Self::testbed_b()),
            "testbed_b_8gpu" => Self::testbed_b_subset(8),
            "testbed_b_16gpu" => Self::testbed_b_subset(16),
            other => bail!(
                "unknown cluster profile `{other}` (builtins: testbed_a, testbed_b, \
                 testbed_b_8gpu, testbed_b_16gpu); or pass a JSON file path"
            ),
        }
    }

    /// Load from a JSON file or fall back to a builtin name.
    pub fn load(name_or_path: &str) -> Result<ClusterProfile> {
        if name_or_path.ends_with(".json") {
            let text = std::fs::read_to_string(name_or_path)
                .with_context(|| format!("reading cluster profile {name_or_path}"))?;
            let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
            Self::from_json(&j)
        } else {
            Self::builtin(name_or_path)
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("nodes", Json::num(self.nodes as f64)),
            ("gpus_per_node", Json::num(self.gpus_per_node as f64)),
            ("alpha_intra", Json::num(self.alpha_intra)),
            ("beta_intra", Json::num(self.beta_intra)),
            ("alpha_inter", Json::num(self.alpha_inter)),
            ("beta_inter", Json::num(self.beta_inter)),
            ("gpu_flops", Json::num(self.gpu_flops)),
            ("gpu_mem_bytes", Json::num(self.gpu_mem_bytes as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ClusterProfile> {
        let p = ClusterProfile {
            name: j.req_str("name")?.to_string(),
            nodes: j.req_usize("nodes")?,
            gpus_per_node: j.req_usize("gpus_per_node")?,
            alpha_intra: j.req_f64("alpha_intra")?,
            beta_intra: j.req_f64("beta_intra")?,
            alpha_inter: j.req_f64("alpha_inter")?,
            beta_inter: j.req_f64("beta_inter")?,
            gpu_flops: j.req_f64("gpu_flops")?,
            gpu_mem_bytes: j.req_f64("gpu_mem_bytes")? as usize,
        };
        p.validate()?;
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_valid() {
        for name in ["testbed_a", "testbed_b", "testbed_b_8gpu", "testbed_b_16gpu"] {
            let p = ClusterProfile::builtin(name).unwrap();
            p.validate().unwrap();
        }
        assert!(ClusterProfile::builtin("nope").is_err());
    }

    #[test]
    fn topology_helpers() {
        let b = ClusterProfile::testbed_b();
        assert_eq!(b.total_gpus(), 32);
        assert_eq!(b.node_of(0), 0);
        assert_eq!(b.node_of(4), 1);
        assert!(b.same_node(0, 3));
        assert!(!b.same_node(3, 4));
    }

    #[test]
    fn subset_bounds() {
        assert!(ClusterProfile::testbed_b_subset(16).is_ok());
        assert!(ClusterProfile::testbed_b_subset(6).is_err());
        assert!(ClusterProfile::testbed_b_subset(64).is_err());
        assert_eq!(ClusterProfile::testbed_b_subset(8).unwrap().nodes, 2);
    }

    #[test]
    fn intra_faster_than_inter_enforced() {
        let mut p = ClusterProfile::testbed_b();
        p.beta_intra = p.beta_inter * 2.0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let p = ClusterProfile::testbed_b();
        let back = ClusterProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
    }
}
