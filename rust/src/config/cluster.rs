//! Cluster topology: the hardware description the simulator, the closed
//! forms and the fitted performance models all run against.
//!
//! The paper's testbeds are homogeneous, and the old API hard-coded that
//! assumption as one `(α_intra, β_intra, α_inter, β_inter, gpu_flops)`
//! tuple for the whole fleet. Production MoE fleets are not homogeneous —
//! they mix node generations, NIC speeds and GPU bins — so the cluster is
//! now a **topology object**:
//!
//! * [`NodeSpec`] — one node's hardware: GPU count, per-GPU dense
//!   throughput and memory, the intra-node link's [`AlphaBeta`] and the
//!   node's NIC [`AlphaBeta`].
//! * [`ClusterTopology`] — an ordered list of `NodeSpec`s (ranks are
//!   placed contiguously, node by node) with the per-link lookup
//!   [`ClusterTopology::link`]`(src, dst) -> AlphaBeta`. A cross-node
//!   transfer is priced by the element-wise bottleneck of the two ends'
//!   NICs (the slower end dominates both latency and bandwidth).
//! * [`LinkClass`] — the stable identity of a link's cost class
//!   (`intra` of one node class, `inter` between two node classes), so
//!   per-class α-β fitting and sweep/report ids survive re-shaping of the
//!   node list.
//!
//! [`ClusterTopology::homogeneous`] reproduces the old scalar profiles
//! exactly (same link costs for every pair, same flops on every rank), so
//! testbed A/B timings — and the golden sweep CSV — are bit-identical to
//! the pre-topology API. Mixed fleets load from JSON
//! ([`ClusterTopology::from_json`], CLI `--cluster-json`); see
//! `examples/cluster_hetero.json`.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One point-to-point link cost model: `seconds(x) = α + x·β`.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct AlphaBeta {
    /// Startup latency of one transfer (seconds).
    pub alpha: f64,
    /// Per-byte time (seconds/byte).
    pub beta: f64,
}

impl AlphaBeta {
    pub const fn new(alpha: f64, beta: f64) -> AlphaBeta {
        AlphaBeta { alpha, beta }
    }

    /// A free link (device-local copies).
    pub const ZERO: AlphaBeta = AlphaBeta::new(0.0, 0.0);

    /// Seconds to move `bytes` over this link.
    pub fn seconds(&self, bytes: f64) -> f64 {
        self.alpha + bytes * self.beta
    }

    /// Element-wise bottleneck of two link models. Used for cross-node
    /// transfers: the slower NIC end dominates both the per-message
    /// latency and the per-byte time.
    pub fn bottleneck(a: AlphaBeta, b: AlphaBeta) -> AlphaBeta {
        AlphaBeta { alpha: a.alpha.max(b.alpha), beta: a.beta.max(b.beta) }
    }
}

/// Hardware description of one node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeSpec {
    /// GPUs hosted by this node (ranks are placed contiguously).
    pub gpus: usize,
    /// Dense fp32 throughput of one GPU on this node (FLOP/s).
    pub gpu_flops: f64,
    /// Device memory per GPU (bytes) — drives the sweep feasibility filter.
    pub gpu_mem_bytes: usize,
    /// Intra-node p2p link (PCIe/NVLink) α-β.
    pub intra: AlphaBeta,
    /// This node's NIC α-β; a cross-node transfer is bottlenecked by the
    /// slower of the two endpoint NICs.
    pub inter: AlphaBeta,
}

impl NodeSpec {
    pub fn validate(&self) -> Result<()> {
        if self.gpus == 0 {
            bail!("node must host at least one GPU");
        }
        if self.intra.beta <= 0.0 || self.inter.beta <= 0.0 {
            bail!("β must be positive");
        }
        if self.intra.alpha < 0.0 || self.inter.alpha < 0.0 {
            bail!("α must be non-negative");
        }
        if self.intra.beta > self.inter.beta {
            // Paper §IV: β_intra > β_inter refers to SPEED; our fields are
            // per-byte TIME, so intra must be <= inter.
            bail!(
                "intra-node per-byte time ({}) must not exceed inter-node ({})",
                self.intra.beta,
                self.inter.beta
            );
        }
        if self.gpu_flops <= 0.0 || self.gpu_mem_bytes == 0 {
            bail!("GPU compute/memory must be positive");
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("gpus", Json::num(self.gpus as f64)),
            ("gpu_flops", Json::num(self.gpu_flops)),
            ("gpu_mem_bytes", Json::num(self.gpu_mem_bytes as f64)),
            ("alpha_intra", Json::num(self.intra.alpha)),
            ("beta_intra", Json::num(self.intra.beta)),
            ("alpha_inter", Json::num(self.inter.alpha)),
            ("beta_inter", Json::num(self.inter.beta)),
        ])
    }

    fn from_json(j: &Json) -> Result<NodeSpec> {
        Ok(NodeSpec {
            gpus: j.req_usize("gpus")?,
            gpu_flops: j.req_f64("gpu_flops")?,
            gpu_mem_bytes: j.req_f64("gpu_mem_bytes")? as usize,
            intra: AlphaBeta::new(j.req_f64("alpha_intra")?, j.req_f64("beta_intra")?),
            inter: AlphaBeta::new(j.req_f64("alpha_inter")?, j.req_f64("beta_inter")?),
        })
    }
}

/// Stable identity of a link's cost class inside one topology. Node
/// *classes* are deduplicated [`NodeSpec`]s (the class id is the index of
/// the first node carrying that spec), so ids do not change when a fleet
/// adds more nodes of an existing kind — which keeps per-class α-β fit
/// keys and sweep/report ids stable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LinkClass {
    /// Intra-node link of node class `c`.
    Intra(usize),
    /// Inter-node link between node classes `(a, b)`, normalized `a ≤ b`
    /// (the bottleneck combination is symmetric).
    Inter(usize, usize),
}

impl LinkClass {
    /// Stable string id, e.g. `intra.c0` / `inter.c0.c1` — used as fit-map
    /// and JSON keys.
    pub fn id(&self) -> String {
        match self {
            LinkClass::Intra(c) => format!("intra.c{c}"),
            LinkClass::Inter(a, b) => format!("inter.c{a}.c{b}"),
        }
    }

    /// Inverse of [`LinkClass::id`] — used when deserializing per-class
    /// α-β fits out of a plan artifact.
    pub fn parse(id: &str) -> Option<LinkClass> {
        if let Some(c) = id.strip_prefix("intra.c") {
            return c.parse().ok().map(LinkClass::Intra);
        }
        let rest = id.strip_prefix("inter.c")?;
        let (a, b) = rest.split_once(".c")?;
        Some(LinkClass::Inter(a.parse().ok()?, b.parse().ok()?))
    }
}

/// Static description of a (possibly heterogeneous) GPU cluster: the
/// ordered node list plus derived rank→node and node→class tables.
///
/// Ranks `0..total_gpus()` map onto nodes contiguously: node 0 hosts
/// ranks `0..nodes[0].gpus`, node 1 the next block, and so on (DeepSpeed-
/// MoE's contiguous placement, which the paper's observations assume).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterTopology {
    pub name: String,
    nodes: Vec<NodeSpec>,
    /// rank → hosting node (derived; kept so the engine's hot path is a
    /// table lookup, not a scan over node extents).
    node_of_rank: Vec<usize>,
    /// node → node-class id (index of the first node with an identical
    /// spec).
    class_of_node: Vec<usize>,
}

impl ClusterTopology {
    /// Build a topology from an explicit node list.
    pub fn new(name: &str, nodes: Vec<NodeSpec>) -> Result<ClusterTopology> {
        if name.is_empty() {
            bail!("cluster needs a name");
        }
        if nodes.is_empty() {
            bail!("cluster must have at least one node");
        }
        for (i, n) in nodes.iter().enumerate() {
            n.validate().with_context(|| format!("node {i} of cluster `{name}`"))?;
        }
        let mut node_of_rank = Vec::with_capacity(nodes.iter().map(|n| n.gpus).sum());
        for (i, n) in nodes.iter().enumerate() {
            node_of_rank.resize(node_of_rank.len() + n.gpus, i);
        }
        let mut class_of_node = Vec::with_capacity(nodes.len());
        for (i, n) in nodes.iter().enumerate() {
            let class = nodes[..i].iter().position(|m| m == n).unwrap_or(i);
            class_of_node.push(class);
        }
        Ok(ClusterTopology { name: name.to_string(), nodes, node_of_rank, class_of_node })
    }

    /// A uniform fleet: `node_count` identical nodes of `gpus_per_node`
    /// GPUs each. Reproduces the old scalar `ClusterProfile` semantics
    /// exactly: every intra-node pair costs `intra`, every cross-node pair
    /// `inter`, every rank computes at `gpu_flops`.
    ///
    /// Panics on invalid constants (the arguments are programmer-supplied
    /// literals, as the old struct literals were); use [`Self::new`] for
    /// data-driven construction.
    pub fn homogeneous(
        name: &str,
        node_count: usize,
        gpus_per_node: usize,
        intra: AlphaBeta,
        inter: AlphaBeta,
        gpu_flops: f64,
        gpu_mem_bytes: usize,
    ) -> ClusterTopology {
        let spec = NodeSpec { gpus: gpus_per_node, gpu_flops, gpu_mem_bytes, intra, inter };
        Self::new(name, vec![spec; node_count]).expect("homogeneous topology constants")
    }

    // ---- built-in testbeds ------------------------------------------------

    /// Testbed A (paper Table II): one node, 8× RTX 4090 on PCIe 4.0 x16.
    ///
    /// The paper's published AG_MP fit on this machine is collective-level
    /// (α = 6.64e-4 s, β = 5.38e-10 s/B). Our simulator composes
    /// collectives from point-to-point messages, so the per-message α is
    /// the collective α divided by the ring steps of the fitted group
    /// (8-GPU ring ⇒ 7 steps): α_msg ≈ 9.5e-5. β is per byte on the wire
    /// and carries over directly. There is no inter-node fabric; we keep a
    /// virtual inter class (unused at P=8) equal to PCIe for robustness.
    pub fn testbed_a() -> ClusterTopology {
        Self::homogeneous(
            "testbed_a",
            1,
            8,
            AlphaBeta::new(9.5e-5, 5.38e-10),
            AlphaBeta::new(9.5e-5, 5.38e-10),
            82.6e12 * 0.35, // RTX4090 peak fp32, derated to achievable GEMM
            24 * (1 << 30),
        )
    }

    /// Testbed B (paper Table II): 8 nodes × 4× RTX 2080Ti, PCIe 3.0 x16
    /// intra-node, 100 Gb/s ConnectX-5 inter-node.
    ///
    /// Intra α/β from the paper's 32-GPU AG_MP fit (collective α =
    /// 1.09e-4 over a 4-GPU ring ⇒ α_msg ≈ 3.6e-5; β = 7.14e-10). Inter β
    /// from 100 Gb/s ≈ 12.5 GB/s line rate derated to ~9 GB/s effective;
    /// inter α_msg ≈ 5e-5 (IB verbs + NCCL proxy per message).
    pub fn testbed_b() -> ClusterTopology {
        Self::homogeneous(
            "testbed_b",
            8,
            4,
            AlphaBeta::new(3.6e-5, 7.14e-10),
            AlphaBeta::new(5.0e-5, 1.11e-9),
            13.4e12 * 0.35, // RTX2080Ti peak fp32, derated
            11 * (1 << 30),
        )
    }

    /// Testbed B truncated to `gpus` total GPUs (the paper reports 8-, 16-
    /// and 32-GPU columns for testbed B in Table IV).
    pub fn testbed_b_subset(gpus: usize) -> Result<ClusterTopology> {
        let full = Self::testbed_b();
        let gpn = full.nodes[0].gpus;
        if gpus % gpn != 0 || gpus > full.total_gpus() || gpus == 0 {
            bail!("testbed B subset must be a positive multiple of {gpn} ≤ {}", full.total_gpus());
        }
        Self::new(&format!("testbed_b_{gpus}gpu"), full.nodes[..gpus / gpn].to_vec())
    }

    /// Look up a built-in topology by name.
    pub fn builtin(name: &str) -> Result<ClusterTopology> {
        match name {
            "testbed_a" => Ok(Self::testbed_a()),
            "testbed_b" | "testbed_b_32gpu" => Ok(Self::testbed_b()),
            "testbed_b_8gpu" => Self::testbed_b_subset(8),
            "testbed_b_16gpu" => Self::testbed_b_subset(16),
            other => bail!(
                "unknown cluster `{other}` (builtins: testbed_a, testbed_b, \
                 testbed_b_8gpu, testbed_b_16gpu); or pass a JSON file path"
            ),
        }
    }

    /// Load from a JSON file (`*.json`, either format — see
    /// [`Self::from_json`]) or fall back to a builtin name.
    pub fn load(name_or_path: &str) -> Result<ClusterTopology> {
        if name_or_path.ends_with(".json") {
            Self::from_json_file(name_or_path)
        } else {
            Self::builtin(name_or_path)
        }
    }

    /// Load a topology JSON document from `path` (used by `--cluster-json`,
    /// which accepts any path, suffixed or not).
    pub fn from_json_file(path: &str) -> Result<ClusterTopology> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading cluster topology {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j).with_context(|| format!("parsing cluster topology {path}"))
    }

    // ---- shape ------------------------------------------------------------

    pub fn total_gpus(&self) -> usize {
        self.node_of_rank.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// The node list (ordered; ranks are placed contiguously over it).
    pub fn node_specs(&self) -> &[NodeSpec] {
        &self.nodes
    }

    pub fn node(&self, node: usize) -> &NodeSpec {
        &self.nodes[node]
    }

    /// Node index hosting `rank`.
    pub fn node_of(&self, rank: usize) -> usize {
        self.node_of_rank[rank]
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Nodes hosting ranks `0..p` (contiguous placement ⇒ a prefix).
    pub fn nodes_for(&self, p: usize) -> std::ops::Range<usize> {
        assert!(
            (1..=self.total_gpus()).contains(&p),
            "layer of {p} ranks on this cluster of {}",
            self.total_gpus()
        );
        let end = self.node_of(p - 1) + 1;
        0..end
    }

    /// True when every node carries an identical spec (the paper's §IV
    /// assumption; [`Self::homogeneous`] always satisfies it).
    pub fn is_homogeneous(&self) -> bool {
        self.class_of_node.iter().all(|&c| c == 0)
    }

    /// Smallest per-node GPU count — the coarse placement bound examples
    /// and planners use for intra-node group sizing.
    pub fn min_gpus_per_node(&self) -> usize {
        self.nodes.iter().map(|n| n.gpus).min().unwrap_or(0)
    }

    // ---- per-rank hardware ------------------------------------------------

    /// Dense throughput of `rank`'s GPU (FLOP/s).
    pub fn flops_of(&self, rank: usize) -> f64 {
        self.nodes[self.node_of(rank)].gpu_flops
    }

    /// Device memory of `rank`'s GPU (bytes).
    pub fn mem_of(&self, rank: usize) -> usize {
        self.nodes[self.node_of(rank)].gpu_mem_bytes
    }

    /// Bottleneck (slowest) per-GPU throughput over ranks `0..p` — what a
    /// synchronous collective step effectively computes at.
    pub fn min_flops(&self, p: usize) -> f64 {
        self.nodes_for(p).map(|n| self.nodes[n].gpu_flops).fold(f64::INFINITY, f64::min)
    }

    /// Smallest per-GPU memory over ranks `0..p`.
    pub fn min_mem(&self, p: usize) -> usize {
        self.nodes_for(p).map(|n| self.nodes[n].gpu_mem_bytes).min().unwrap_or(0)
    }

    // ---- links ------------------------------------------------------------

    /// The α-β cost of a `src → dst` transfer: free for device-local
    /// copies, the hosting node's intra link within a node, and the
    /// element-wise bottleneck of the two endpoint NICs across nodes.
    pub fn link(&self, src: usize, dst: usize) -> AlphaBeta {
        if src == dst {
            return AlphaBeta::ZERO;
        }
        let (sn, dn) = (self.node_of(src), self.node_of(dst));
        if sn == dn {
            self.nodes[sn].intra
        } else {
            AlphaBeta::bottleneck(self.nodes[sn].inter, self.nodes[dn].inter)
        }
    }

    /// Node-class id of `node` (index of the first node with an identical
    /// spec).
    pub fn node_class(&self, node: usize) -> usize {
        self.class_of_node[node]
    }

    /// The [`LinkClass`] of a `src → dst` pair (src ≠ dst, non-local).
    pub fn link_class(&self, src: usize, dst: usize) -> LinkClass {
        let (sn, dn) = (self.node_of(src), self.node_of(dst));
        if sn == dn {
            LinkClass::Intra(self.class_of_node[sn])
        } else {
            let (a, b) = (self.class_of_node[sn], self.class_of_node[dn]);
            LinkClass::Inter(a.min(b), b.max(a))
        }
    }

    /// Every distinct link class realizable in this topology, sorted.
    /// `Intra(c)` appears only when some class-`c` node hosts ≥ 2 GPUs;
    /// `Inter(a, b)` only when distinct nodes of classes `a` and `b`
    /// exist.
    pub fn link_classes(&self) -> Vec<LinkClass> {
        let mut out = std::collections::BTreeSet::new();
        for (i, n) in self.nodes.iter().enumerate() {
            if n.gpus >= 2 {
                out.insert(LinkClass::Intra(self.class_of_node[i]));
            }
        }
        for i in 0..self.nodes.len() {
            for j in 0..self.nodes.len() {
                if i != j {
                    let (a, b) = (self.class_of_node[i], self.class_of_node[j]);
                    out.insert(LinkClass::Inter(a.min(b), b.max(a)));
                }
            }
        }
        out.into_iter().collect()
    }

    /// The α-β model of one link class (what [`Self::link`] returns for
    /// any representative pair of the class).
    pub fn link_of_class(&self, class: LinkClass) -> Option<AlphaBeta> {
        self.representative_pair(class).map(|(s, d)| self.link(s, d))
    }

    /// A concrete `(src, dst)` rank pair whose link belongs to `class`,
    /// if the class is realizable here — used to fit one α-β per class.
    pub fn representative_pair(&self, class: LinkClass) -> Option<(usize, usize)> {
        let first_rank = |node: usize| self.node_of_rank.iter().position(|&n| n == node);
        match class {
            LinkClass::Intra(c) => {
                let node = (0..self.nodes.len())
                    .find(|&n| self.class_of_node[n] == c && self.nodes[n].gpus >= 2)?;
                let r = first_rank(node)?;
                Some((r, r + 1))
            }
            LinkClass::Inter(a, b) => {
                for i in 0..self.nodes.len() {
                    for j in 0..self.nodes.len() {
                        if i == j {
                            continue;
                        }
                        let (ca, cb) = (self.class_of_node[i], self.class_of_node[j]);
                        if (ca.min(cb), cb.max(ca)) == (a, b) {
                            return Some((first_rank(i)?, first_rank(j)?));
                        }
                    }
                }
                None
            }
        }
    }

    // ---- validation & serialization ---------------------------------------

    pub fn validate(&self) -> Result<()> {
        // `new` validates on construction; re-validate for callers that
        // deserialized or cloned-and-patched a topology.
        Self::new(&self.name, self.nodes.clone()).map(|_| ())
    }

    /// Serialize as the per-node topology document. Runs of identical
    /// consecutive nodes are compressed with a `count` field.
    pub fn to_json(&self) -> Json {
        let mut entries: Vec<Json> = Vec::new();
        let mut i = 0;
        while i < self.nodes.len() {
            let mut run = 1;
            while i + run < self.nodes.len() && self.nodes[i + run] == self.nodes[i] {
                run += 1;
            }
            let mut obj = self.nodes[i].to_json();
            if run > 1 {
                if let Json::Obj(map) = &mut obj {
                    map.insert("count".to_string(), Json::num(run as f64));
                }
            }
            entries.push(obj);
            i += run;
        }
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("nodes", Json::Arr(entries)),
        ])
    }

    /// Stable content hash of the topology: FNV-1a over the canonical
    /// compact JSON encoding ([`Self::to_json`]), so two topologies hash
    /// equal iff their documents are identical (name, node list, link and
    /// compute constants). This is the hash plan artifacts and the sweep's
    /// content-addressed case cache key on — editing any node spec (or
    /// renaming the fleet) invalidates both.
    pub fn content_hash(&self) -> String {
        crate::util::hash::fnv64_hex(&["cluster", &self.to_json().to_string()])
    }

    /// Parse either topology format:
    ///
    /// * **Per-node** (the native form): `{"name", "nodes": [{"gpus",
    ///   "gpu_flops", "gpu_mem_bytes", "alpha_intra", "beta_intra",
    ///   "alpha_inter", "beta_inter", "count"?}, ...]}` — `count` repeats
    ///   a node spec.
    /// * **Legacy flat** (the pre-topology `ClusterProfile` document):
    ///   `{"name", "nodes": N, "gpus_per_node", "alpha_intra", ...,
    ///   "gpu_flops", "gpu_mem_bytes"}` — expanded to `N` identical
    ///   nodes, so existing profile files keep loading.
    pub fn from_json(j: &Json) -> Result<ClusterTopology> {
        let name = j.req_str("name")?.to_string();
        if j.get("nodes").as_arr().is_some() {
            let mut nodes = Vec::new();
            for entry in j.req_arr("nodes")? {
                let spec = NodeSpec::from_json(entry)?;
                let count = match entry.get("count") {
                    Json::Null => 1,
                    v => v
                        .as_usize()
                        .filter(|&n| n >= 1)
                        .ok_or_else(|| anyhow::anyhow!("node `count` must be an integer ≥ 1"))?,
                };
                nodes.resize(nodes.len() + count, spec);
            }
            Self::new(&name, nodes)
        } else {
            // Legacy flat profile document.
            let spec = NodeSpec {
                gpus: j.req_usize("gpus_per_node")?,
                gpu_flops: j.req_f64("gpu_flops")?,
                gpu_mem_bytes: j.req_f64("gpu_mem_bytes")? as usize,
                intra: AlphaBeta::new(j.req_f64("alpha_intra")?, j.req_f64("beta_intra")?),
                inter: AlphaBeta::new(j.req_f64("alpha_inter")?, j.req_f64("beta_inter")?),
            };
            Self::new(&name, vec![spec; j.req_usize("nodes")?])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hetero_two_class() -> ClusterTopology {
        let fast = NodeSpec {
            gpus: 4,
            gpu_flops: 4.0e12,
            gpu_mem_bytes: 16 << 30,
            intra: AlphaBeta::new(1e-5, 1e-9),
            inter: AlphaBeta::new(1e-4, 1e-8),
        };
        let slow = NodeSpec {
            gpus: 4,
            gpu_flops: 1.0e12,
            gpu_mem_bytes: 8 << 30,
            intra: AlphaBeta::new(2e-5, 2e-9),
            inter: AlphaBeta::new(2e-4, 2e-8),
        };
        ClusterTopology::new("mixed", vec![fast, slow]).unwrap()
    }

    #[test]
    fn builtins_valid() {
        for name in ["testbed_a", "testbed_b", "testbed_b_8gpu", "testbed_b_16gpu"] {
            let t = ClusterTopology::builtin(name).unwrap();
            t.validate().unwrap();
            assert!(t.is_homogeneous());
        }
        assert!(ClusterTopology::builtin("nope").is_err());
    }

    #[test]
    fn topology_helpers() {
        let b = ClusterTopology::testbed_b();
        assert_eq!(b.total_gpus(), 32);
        assert_eq!(b.num_nodes(), 8);
        assert_eq!(b.node_of(0), 0);
        assert_eq!(b.node_of(4), 1);
        assert!(b.same_node(0, 3));
        assert!(!b.same_node(3, 4));
        assert_eq!(b.nodes_for(8), 0..2);
        assert_eq!(b.nodes_for(9), 0..3);
        assert_eq!(b.min_gpus_per_node(), 4);
    }

    #[test]
    fn subset_bounds() {
        assert!(ClusterTopology::testbed_b_subset(16).is_ok());
        assert!(ClusterTopology::testbed_b_subset(6).is_err());
        assert!(ClusterTopology::testbed_b_subset(64).is_err());
        assert_eq!(ClusterTopology::testbed_b_subset(8).unwrap().num_nodes(), 2);
    }

    #[test]
    fn homogeneous_links_match_scalars() {
        // The old scalar rule: α_intra/β_intra within a node,
        // α_inter/β_inter across — reproduced exactly by link().
        let b = ClusterTopology::testbed_b();
        let intra = AlphaBeta::new(3.6e-5, 7.14e-10);
        let inter = AlphaBeta::new(5.0e-5, 1.11e-9);
        assert_eq!(b.link(0, 1), intra);
        assert_eq!(b.link(3, 4), inter);
        assert_eq!(b.link(2, 2), AlphaBeta::ZERO);
        assert_eq!(b.link(0, 1).seconds(1e6), 3.6e-5 + 1e6 * 7.14e-10);
    }

    #[test]
    fn intra_faster_than_inter_enforced() {
        let mut spec = ClusterTopology::testbed_b().node_specs()[0];
        spec.intra = AlphaBeta::new(spec.intra.alpha, spec.inter.beta * 2.0);
        assert!(ClusterTopology::new("bad", vec![spec]).is_err());
    }

    #[test]
    fn json_roundtrip_topology() {
        for t in [ClusterTopology::testbed_b(), hetero_two_class()] {
            let back = ClusterTopology::from_json(&t.to_json()).unwrap();
            assert_eq!(t, back);
        }
    }

    #[test]
    fn legacy_flat_json_loads_as_homogeneous() {
        let doc = Json::parse(
            r#"{"name":"legacy","nodes":2,"gpus_per_node":4,
                "alpha_intra":1e-5,"beta_intra":1e-9,
                "alpha_inter":1e-4,"beta_inter":1e-8,
                "gpu_flops":1e12,"gpu_mem_bytes":1073741824}"#,
        )
        .unwrap();
        let t = ClusterTopology::from_json(&doc).unwrap();
        let want = ClusterTopology::homogeneous(
            "legacy",
            2,
            4,
            AlphaBeta::new(1e-5, 1e-9),
            AlphaBeta::new(1e-4, 1e-8),
            1e12,
            1 << 30,
        );
        assert_eq!(t, want);
    }

    #[test]
    fn link_classes_homogeneous() {
        let b = ClusterTopology::testbed_b();
        assert_eq!(
            b.link_classes(),
            vec![LinkClass::Intra(0), LinkClass::Inter(0, 0)]
        );
        // Single-node testbed A has no inter class at all.
        assert_eq!(ClusterTopology::testbed_a().link_classes(), vec![LinkClass::Intra(0)]);
    }

    #[test]
    fn link_classes_heterogeneous() {
        let t = hetero_two_class();
        assert_eq!(t.node_class(0), 0);
        assert_eq!(t.node_class(1), 1);
        assert_eq!(
            t.link_classes(),
            vec![
                LinkClass::Intra(0),
                LinkClass::Intra(1),
                LinkClass::Inter(0, 1),
            ]
        );
        // Cross-node link is the element-wise NIC bottleneck (slow end).
        assert_eq!(t.link(0, 4), AlphaBeta::new(2e-4, 2e-8));
        assert_eq!(t.link(4, 0), t.link(0, 4));
        // Each class has a representative pair whose link matches.
        for class in t.link_classes() {
            let (s, d) = t.representative_pair(class).unwrap();
            assert_eq!(t.link_class(s, d), class);
            assert_eq!(t.link_of_class(class).unwrap(), t.link(s, d));
        }
        assert_eq!(LinkClass::Inter(0, 1).id(), "inter.c0.c1");
    }

    #[test]
    fn per_rank_hardware_lookup() {
        let t = hetero_two_class();
        assert_eq!(t.flops_of(0), 4.0e12);
        assert_eq!(t.flops_of(7), 1.0e12);
        assert_eq!(t.mem_of(5), 8 << 30);
        assert_eq!(t.min_flops(4), 4.0e12);
        assert_eq!(t.min_flops(8), 1.0e12);
        assert_eq!(t.min_mem(8), 8 << 30);
        assert!(!t.is_homogeneous());
    }

    #[test]
    fn count_field_repeats_nodes() {
        let doc = Json::parse(
            r#"{"name":"fleet","nodes":[
                {"gpus":4,"gpu_flops":1e12,"gpu_mem_bytes":1073741824,
                 "alpha_intra":1e-5,"beta_intra":1e-9,
                 "alpha_inter":1e-4,"beta_inter":1e-8,"count":3}]}"#,
        )
        .unwrap();
        let t = ClusterTopology::from_json(&doc).unwrap();
        assert_eq!(t.num_nodes(), 3);
        assert_eq!(t.total_gpus(), 12);
        assert!(t.is_homogeneous());
    }

    #[test]
    fn link_class_id_roundtrips() {
        let classes = [
            LinkClass::Intra(0),
            LinkClass::Intra(7),
            LinkClass::Inter(0, 1),
            LinkClass::Inter(3, 12),
        ];
        for class in classes {
            assert_eq!(LinkClass::parse(&class.id()), Some(class));
        }
        for bad in ["intra", "intra.cX", "inter.c1", "inter.c1.cX", "nvlink.c0", ""] {
            assert_eq!(LinkClass::parse(bad), None, "{bad}");
        }
    }

    #[test]
    fn content_hash_tracks_topology_edits() {
        let b = ClusterTopology::testbed_b();
        assert_eq!(b.content_hash(), ClusterTopology::testbed_b().content_hash());
        assert_eq!(b.content_hash().len(), 16);
        // Any node-spec edit — or a rename — changes the hash.
        assert_ne!(b.content_hash(), hetero_two_class().content_hash());
        let mut slow = b.node_specs().to_vec();
        slow[0].gpu_flops /= 2.0;
        let edited = ClusterTopology::new("testbed_b", slow).unwrap();
        assert_ne!(b.content_hash(), edited.content_hash());
        let renamed = ClusterTopology::new("testbed_c", b.node_specs().to_vec()).unwrap();
        assert_ne!(b.content_hash(), renamed.content_hash());
    }

    #[test]
    fn malformed_count_rejected() {
        // count must be an integer ≥ 1 — a string or fractional value is
        // an error, not a silent single node.
        for bad in [r#""8""#, "8.5", "0"] {
            let doc = Json::parse(&format!(
                r#"{{"name":"fleet","nodes":[
                    {{"gpus":4,"gpu_flops":1e12,"gpu_mem_bytes":1073741824,
                     "alpha_intra":1e-5,"beta_intra":1e-9,
                     "alpha_inter":1e-4,"beta_inter":1e-8,"count":{bad}}}]}}"#,
            ))
            .unwrap();
            assert!(ClusterTopology::from_json(&doc).is_err(), "count {bad} must error");
        }
    }
}
