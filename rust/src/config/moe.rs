//! MoE layer configuration (paper Table I notation) and derived quantities.

use anyhow::{bail, Result};

use crate::config::precision::WirePrecision;
use crate::util::json::Json;

/// Degrees of the hybrid parallelism MP+EP+ESP (paper §II-B).
///
/// The world of `P = n_ep × n_esp` ranks is laid out as consecutive ESP
/// blocks (placed intra-node whenever `n_esp ≤ gpus_per_node`), with EP
/// groups strided across the blocks and MP groups of `n_mp` consecutive
/// ranks. Ranks inside an MP group carry *duplicated* activations at the
/// MoE layer boundary — the redundancy Parm's PauseMP removes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelDegrees {
    /// Total ranks (GPUs) participating in the MoE layer.
    pub p: usize,
    /// Model-parallel (tensor-parallel) group size, `N_MP`.
    pub n_mp: usize,
    /// Expert-sharding group size, `N_ESP`.
    pub n_esp: usize,
}

impl ParallelDegrees {
    /// Expert-parallel group size `N_EP = P / N_ESP`.
    pub fn n_ep(&self) -> usize {
        self.p / self.n_esp
    }

    pub fn validate(&self) -> Result<()> {
        if self.p == 0 || self.n_mp == 0 || self.n_esp == 0 {
            bail!("parallel degrees must be positive: {self:?}");
        }
        if self.p % self.n_esp != 0 {
            bail!("P={} not divisible by N_ESP={}", self.p, self.n_esp);
        }
        if self.p % self.n_mp != 0 {
            bail!("P={} not divisible by N_MP={}", self.p, self.n_mp);
        }
        if !self.p.is_power_of_two() || !self.n_mp.is_power_of_two() || !self.n_esp.is_power_of_two()
        {
            bail!("degrees must be powers of two (ring/pairwise collectives): {self:?}");
        }
        Ok(())
    }
}

/// One MoE layer's hyper-parameters (paper Table I) plus its parallel
/// placement. All sizes are in *elements*; `dtype_bytes` converts to bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct MoeLayerConfig {
    pub par: ParallelDegrees,
    /// Local mini-batch size per GPU, `B`.
    pub b: usize,
    /// Sequence length per sample, `L`.
    pub l: usize,
    /// Total number of experts, `E`.
    pub e: usize,
    /// Token embedding size, `M`.
    pub m: usize,
    /// Expert FFN hidden size, `H` (sharded `H/N_ESP` per ESP rank).
    pub h: usize,
    /// top-k experts per token.
    pub k: usize,
    /// Capacity factor `f`.
    pub f: f64,
    /// Bytes per element (4 = fp32; the paper trains fp32 on 2080Ti/4090).
    pub dtype_bytes: usize,
    /// Zipf-style routing-skew exponent: `0.0` = the uniform router the
    /// paper assumes; `s > 0` biases the gate's logits by `-s·ln(j+1)` for
    /// expert `j`, so expert popularity follows a Zipf law (expert 0
    /// hottest). Drives the load-aware SP chunk spans and the skewed
    /// sweep family (`parm sweep --skew`).
    pub skew: f64,
    /// Per-leg wire dtype policy for the layer's collectives. The default
    /// (all-f32) matches `dtype_bytes: 4` exactly, so volumes, sims, and
    /// ids are unchanged unless a leg is narrowed (`parm ... --wire`).
    pub wire: WirePrecision,
}

impl MoeLayerConfig {
    /// A small config used pervasively in tests.
    pub fn test_default() -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
            b: 2,
            l: 64,
            e: 4,
            m: 32,
            h: 64,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
            skew: 0.0,
            wire: WirePrecision::default(),
        }
    }

    /// Max tokens per expert per source GPU: `T = k·f·B·L/E` (paper Table I),
    /// rounded up to at least 1.
    pub fn t(&self) -> usize {
        let t = (self.k as f64 * self.f * (self.b * self.l) as f64 / self.e as f64).ceil();
        (t as usize).max(1)
    }

    /// Tokens per gate invocation under PauseMP: the local `1/N_MP` slice.
    /// `T` shrinks proportionally (S1 gates on split tokens).
    pub fn t_pausemp(&self) -> usize {
        let tokens = (self.b * self.l) / self.par.n_mp;
        let t = (self.k as f64 * self.f * tokens as f64 / self.e as f64).ceil();
        (t as usize).max(1)
    }

    /// Local token count `B·L`.
    pub fn tokens(&self) -> usize {
        self.b * self.l
    }

    /// Experts hosted per EP slot (`E / N_EP`), ≥ 1.
    pub fn experts_per_rank(&self) -> usize {
        (self.e / self.par.n_ep()).max(1)
    }

    /// Elements in the (B, L, M) input tensor.
    pub fn input_elems(&self) -> usize {
        self.b * self.l * self.m
    }

    /// Elements in the dispatched (E, T, M) tensor.
    pub fn dispatch_elems(&self) -> usize {
        self.e * self.t() * self.m
    }

    pub fn validate(&self) -> Result<()> {
        self.par.validate()?;
        if self.b == 0 || self.l == 0 || self.e == 0 || self.m == 0 || self.h == 0 || self.k == 0 {
            bail!("all MoE dimensions must be positive: {self:?}");
        }
        if self.k > self.e {
            bail!("top-k ({}) exceeds number of experts ({})", self.k, self.e);
        }
        if self.f <= 0.0 {
            bail!("capacity factor must be positive, got {}", self.f);
        }
        if self.dtype_bytes == 0 {
            bail!("dtype_bytes must be positive");
        }
        if self.h % self.par.n_esp != 0 {
            bail!("H={} not divisible by N_ESP={}", self.h, self.par.n_esp);
        }
        if self.e % self.par.n_ep() != 0 && self.par.n_ep() % self.e != 0 {
            bail!(
                "E={} and N_EP={} must divide one another",
                self.e,
                self.par.n_ep()
            );
        }
        if (self.b * self.l) % self.par.n_mp != 0 {
            bail!("B·L={} not divisible by N_MP={}", self.b * self.l, self.par.n_mp);
        }
        if !self.skew.is_finite() || self.skew < 0.0 {
            bail!("routing skew must be finite and ≥ 0, got {}", self.skew);
        }
        Ok(())
    }

    /// Estimated per-GPU memory (bytes) for this layer when training:
    /// expert weight shards (+grad +Adam moments = ×4), the gathered input
    /// activations, dispatch buffers, and expert activations. Used by the
    /// sweep filter to exclude configurations that could not run on the
    /// testbeds (paper: "some cases that require memory larger than the
    /// capacity of GPU memory cannot run ... are excluded").
    pub fn memory_bytes_per_gpu(&self) -> usize {
        let d = self.dtype_bytes;
        let experts_local = self.experts_per_rank();
        let weight = experts_local * 2 * self.m * (self.h / self.par.n_esp);
        let states = weight * 4; // weight + grad + 2 Adam moments
        // Baseline schedule materializes the ESP-gathered input and the
        // dispatched tensor on every rank (the worst case across schedules).
        let gathered_input = self.input_elems() * self.par.n_esp;
        let dispatched = self.dispatch_elems() * self.par.n_esp;
        // Expert activations: inputs + hidden per token processed locally.
        let expert_tokens = self.e * self.t() * self.par.n_esp / self.par.n_ep().max(1);
        let expert_act = expert_tokens * (self.m + self.h / self.par.n_esp);
        // Activations are held for the backward pass plus comm/workspace
        // copies (×3, the empirical PyTorch training footprint the paper's
        // "cannot run on our testbeds" exclusions reflect).
        (states + 3 * (gathered_input + 2 * dispatched + expert_act)) * d
    }

    /// Expert FLOPs per rank per forward pass (2 matmuls; ×2 MAC→FLOP).
    /// `dup` accounts for the baseline's N_MP-duplicated compute.
    pub fn expert_flops_per_rank(&self, duplicated: bool) -> f64 {
        let tokens = (self.e * self.t()) as f64 * self.par.n_esp as f64 / self.par.n_ep() as f64;
        let tokens = if duplicated { tokens } else { tokens / self.par.n_mp as f64 };
        let per_token = 2.0 * 2.0 * self.m as f64 * (self.h / self.par.n_esp) as f64;
        tokens * per_token
    }

    /// Short human id, e.g. `p8_mp2_esp2_b2_l64_e4_m32_h64_k2_f1.2`
    /// (suffixed `_s{skew}` only for skewed-routing configs and `_w{wire}`
    /// only for compressed-wire configs, so default ids — and the golden
    /// sweep CSV built from them — are unchanged).
    pub fn id(&self) -> String {
        let mut base = format!(
            "p{}_mp{}_esp{}_b{}_l{}_e{}_m{}_h{}_k{}_f{}",
            self.par.p, self.par.n_mp, self.par.n_esp, self.b, self.l, self.e, self.m, self.h,
            self.k, self.f
        );
        if self.skew > 0.0 {
            base = format!("{base}_s{}", self.skew);
        }
        if !self.wire.is_default() {
            base = format!("{base}_w{}", self.wire.id_suffix());
        }
        base
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("p", Json::num(self.par.p as f64)),
            ("n_mp", Json::num(self.par.n_mp as f64)),
            ("n_esp", Json::num(self.par.n_esp as f64)),
            ("b", Json::num(self.b as f64)),
            ("l", Json::num(self.l as f64)),
            ("e", Json::num(self.e as f64)),
            ("m", Json::num(self.m as f64)),
            ("h", Json::num(self.h as f64)),
            ("k", Json::num(self.k as f64)),
            ("f", Json::num(self.f)),
            ("dtype_bytes", Json::num(self.dtype_bytes as f64)),
        ];
        if self.skew > 0.0 {
            fields.push(("skew", Json::num(self.skew)));
        }
        if !self.wire.is_default() {
            fields.push(("wire", self.wire.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<MoeLayerConfig> {
        // `dtype_bytes` feeds every volume helper AND the sweep-cache key:
        // a present-but-malformed value must error loudly, never silently
        // coerce to the default. Only a genuinely absent key defaults to 4.
        let dtype_bytes = match j.get("dtype_bytes") {
            Json::Null => 4,
            v => v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("dtype_bytes must be a non-negative integer, got {v:?}"))?,
        };
        let wire = match j.get("wire") {
            Json::Null => WirePrecision::default(),
            v => WirePrecision::from_json(v)?,
        };
        let cfg = MoeLayerConfig {
            par: ParallelDegrees {
                p: j.req_usize("p")?,
                n_mp: j.req_usize("n_mp")?,
                n_esp: j.req_usize("n_esp")?,
            },
            b: j.req_usize("b")?,
            l: j.req_usize("l")?,
            e: j.req_usize("e")?,
            m: j.req_usize("m")?,
            h: j.req_usize("h")?,
            k: j.req_usize("k")?,
            f: j.req_f64("f")?,
            dtype_bytes,
            skew: j.get("skew").as_f64().unwrap_or(0.0),
            wire,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_quantities() {
        let c = MoeLayerConfig::test_default();
        // T = ceil(2 * 1.2 * 128 / 4) = 77
        assert_eq!(c.t(), 77);
        assert_eq!(c.tokens(), 128);
        assert_eq!(c.par.n_ep(), 4);
        assert_eq!(c.experts_per_rank(), 1);
        assert_eq!(c.input_elems(), 2 * 64 * 32);
    }

    #[test]
    fn validates_divisibility() {
        let mut c = MoeLayerConfig::test_default();
        assert!(c.validate().is_ok());
        c.h = 65;
        assert!(c.validate().is_err());
        c = MoeLayerConfig::test_default();
        c.k = 99;
        assert!(c.validate().is_err());
        c = MoeLayerConfig::test_default();
        c.par.n_esp = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn pausemp_t_shrinks() {
        let c = MoeLayerConfig::test_default();
        assert!(c.t_pausemp() <= c.t());
        // With n_mp=2: ceil(2*1.2*64/4) = 39
        assert_eq!(c.t_pausemp(), 39);
    }

    #[test]
    fn duplicated_flops_ratio() {
        let c = MoeLayerConfig::test_default();
        let dup = c.expert_flops_per_rank(true);
        let dedup = c.expert_flops_per_rank(false);
        assert!((dup / dedup - c.par.n_mp as f64).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let c = MoeLayerConfig::test_default();
        let j = c.to_json();
        let back = MoeLayerConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn json_roundtrip_with_wire_policy() {
        use crate::config::precision::{WireDtype, WireLeg};
        let mut c = MoeLayerConfig::test_default();
        c.wire = WirePrecision::uniform(WireDtype::Bf16).with_leg(WireLeg::Wgrad, WireDtype::F32);
        let back = MoeLayerConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
        // Default wire stays out of the serialized form and the id.
        let d = MoeLayerConfig::test_default();
        assert!(!d.to_json().to_string().contains("wire"));
        assert!(!d.id().contains("_w"));
        assert!(c.to_json().to_string().contains("wire"));
        assert!(c.id().ends_with("_wdbf16-cbf16-gbf16-rf32"));
    }

    #[test]
    fn malformed_dtype_bytes_errors_loudly() {
        let c = MoeLayerConfig::test_default();
        // Missing key still defaults to 4.
        let j = c.to_json();
        let mut without = match j.clone() {
            Json::Obj(mut m) => {
                m.remove("dtype_bytes");
                Json::Obj(m)
            }
            _ => unreachable!(),
        };
        assert_eq!(MoeLayerConfig::from_json(&without).unwrap().dtype_bytes, 4);
        // Present-but-malformed values must error, not coerce to 4.
        for bad in [Json::str("four"), Json::num(2.5), Json::num(-4.0), Json::Bool(true)] {
            without = match j.clone() {
                Json::Obj(mut m) => {
                    m.insert("dtype_bytes".to_string(), bad);
                    Json::Obj(m)
                }
                _ => unreachable!(),
            };
            let err = MoeLayerConfig::from_json(&without).unwrap_err().to_string();
            assert!(err.contains("dtype_bytes"), "{err}");
        }
    }

    #[test]
    fn memory_positive_and_monotone_in_h() {
        let c = MoeLayerConfig::test_default();
        let mut big = c.clone();
        big.h *= 4;
        assert!(big.memory_bytes_per_gpu() > c.memory_bytes_per_gpu());
    }
}
