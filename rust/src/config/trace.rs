//! Drifting-traffic trace specs: the JSON schema behind `--trace`.
//!
//! A [`TraceSpec`] declares how routing statistics evolve over an
//! N-iteration run — a Zipf skew ramp ([`Drift`]), a diurnal sinusoid
//! ([`Diurnal`]), periodic hot-expert flips ([`Bursty`]), multiplicative
//! per-expert noise, and straggler/jitter injection on nodes and links
//! ([`Jitter`]). The spec is pure data: the `traffic` scenario engine
//! turns it into per-step expert-load vectors and per-step clusters,
//! deterministically under [`crate::util::prng`] from the spec's `seed`
//! (CLI `--seed` overrides it). Committed examples live in
//! `examples/trace_*.json`.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Sinusoidal load modulation: `skew += amplitude · sin(2π·(step/period) +
/// phase)` — the "daytime concentrates traffic on popular experts" shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Diurnal {
    pub amplitude: f64,
    /// Period in steps (one full day); must be positive.
    pub period: f64,
    /// Phase offset in radians.
    pub phase: f64,
}

/// Linear Zipf-skew ramp from `from` at step 0 to `to` at the last step —
/// the sustained regime change the hysteresis must converge after. When
/// present it replaces `base_skew` as the carrier the diurnal term rides.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Drift {
    pub from: f64,
    pub to: f64,
}

/// Periodic hot-expert flips: every `every` steps the hot seat rotates to
/// the next expert and holds it for `hold` steps, boosting that expert's
/// routing weight by `boost`×.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bursty {
    pub every: usize,
    pub hold: usize,
    pub boost: f64,
}

/// Straggler injection: per step, each node's FLOPs are divided by
/// `1 + node·u` and each link's α/β multiplied by `1 + link·u` for
/// fresh uniform draws `u ∈ [0,1)` — node 0 is never slowed so the
/// bottleneck can move.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Jitter {
    pub node: f64,
    pub link: f64,
}

/// A drifting-traffic scenario: see the module docs for the composition
/// order. Loaded from JSON with [`TraceSpec::load`].
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    pub name: String,
    /// Number of iterations the drive loop runs.
    pub steps: usize,
    /// PRNG seed for noise/jitter streams. Defaults to 42 when the
    /// document omits it; 0 is a valid seed (not "pick one for me") —
    /// reproducibility always wins over entropy here.
    pub seed: u64,
    /// Carrier Zipf skew when no `drift` ramp is present.
    pub base_skew: f64,
    pub diurnal: Option<Diurnal>,
    pub drift: Option<Drift>,
    pub bursty: Option<Bursty>,
    /// Multiplicative per-expert weight noise amplitude in [0,1): each
    /// weight is scaled by `1 + noise·(2u−1)`.
    pub noise: f64,
    pub jitter: Option<Jitter>,
    /// Steps whose routed-token count is forced to zero (router collapse /
    /// empty micro-batch) — exercises the all-zero→expected fallback.
    pub zero_steps: Vec<usize>,
}

impl TraceSpec {
    /// The Zipf skew in effect at `step`: drift ramp (or `base_skew`)
    /// plus the diurnal term, clamped at 0.
    pub fn skew_at(&self, step: usize) -> f64 {
        let frac = if self.steps > 1 { step as f64 / (self.steps - 1) as f64 } else { 0.0 };
        let mut s = match self.drift {
            Some(d) => d.from + (d.to - d.from) * frac,
            None => self.base_skew,
        };
        if let Some(d) = self.diurnal {
            s += d.amplitude * (std::f64::consts::TAU * step as f64 / d.period + d.phase).sin();
        }
        s.max(0.0)
    }

    /// Whether `step` sits inside a burst window, and if so which expert
    /// seat (mod the expert count, applied by the scenario engine) holds
    /// the boost.
    pub fn burst_at(&self, step: usize) -> Option<(usize, f64)> {
        let b = self.bursty?;
        if step % b.every < b.hold {
            Some((step / b.every, b.boost))
        } else {
            None
        }
    }

    /// Load a trace spec document from `path`.
    pub fn load(path: &str) -> Result<TraceSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace spec {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j).with_context(|| format!("parsing trace spec {path}"))
    }

    /// Parse and validate a trace spec document.
    pub fn from_json(j: &Json) -> Result<TraceSpec> {
        let name = j.req_str("name")?.to_string();
        let steps = j.req_usize("steps")?;
        let seed = match j.get("seed") {
            Json::Null => 42,
            v => v.as_usize().map(|n| n as u64).ok_or_else(|| {
                anyhow::anyhow!("`seed` must be a non-negative integer")
            })?,
        };
        let opt_f64 = |key: &str, default: f64| -> Result<f64> {
            match j.get(key) {
                Json::Null => Ok(default),
                v => v.as_f64().ok_or_else(|| anyhow::anyhow!("`{key}` must be a number")),
            }
        };
        let base_skew = opt_f64("base_skew", 0.0)?;
        let noise = opt_f64("noise", 0.0)?;
        let diurnal = match j.get("diurnal") {
            Json::Null => None,
            d => Some(Diurnal {
                amplitude: d.req_f64("amplitude")?,
                period: d.req_f64("period")?,
                phase: d.get("phase").as_f64().unwrap_or(0.0),
            }),
        };
        let drift = match j.get("drift") {
            Json::Null => None,
            d => Some(Drift { from: d.req_f64("from")?, to: d.req_f64("to")? }),
        };
        let bursty = match j.get("bursty") {
            Json::Null => None,
            b => Some(Bursty {
                every: b.req_usize("every")?,
                hold: b.req_usize("hold")?,
                boost: b.req_f64("boost")?,
            }),
        };
        let jitter = match j.get("jitter") {
            Json::Null => None,
            v => Some(Jitter {
                node: v.get("node").as_f64().unwrap_or(0.0),
                link: v.get("link").as_f64().unwrap_or(0.0),
            }),
        };
        let mut zero_steps = Vec::new();
        if let Some(arr) = j.get("zero_steps").as_arr() {
            for v in arr {
                let idx = v
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("`zero_steps` entries must be step indices"))?;
                zero_steps.push(idx);
            }
        }
        let spec = TraceSpec {
            name,
            steps,
            seed,
            base_skew,
            diurnal,
            drift,
            bursty,
            noise,
            jitter,
            zero_steps,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Reject ill-formed scenarios with messages naming the bad field.
    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            bail!("trace `steps` must be ≥ 1");
        }
        if self.base_skew < 0.0 {
            bail!("`base_skew` must be ≥ 0");
        }
        if !(0.0..1.0).contains(&self.noise) {
            bail!("`noise` must lie in [0, 1)");
        }
        if let Some(d) = self.diurnal {
            if d.period <= 0.0 {
                bail!("diurnal `period` must be positive");
            }
            if d.amplitude < 0.0 {
                bail!("diurnal `amplitude` must be ≥ 0");
            }
        }
        if let Some(d) = self.drift {
            if d.from < 0.0 || d.to < 0.0 {
                bail!("drift endpoints must be ≥ 0");
            }
        }
        if let Some(b) = self.bursty {
            if b.every == 0 {
                bail!("bursty `every` must be ≥ 1");
            }
            if b.hold > b.every {
                bail!("bursty `hold` must not exceed `every`");
            }
            if b.boost < 1.0 {
                bail!("bursty `boost` must be ≥ 1");
            }
        }
        if let Some(jit) = self.jitter {
            if jit.node < 0.0 || jit.link < 0.0 {
                bail!("jitter factors must be ≥ 0");
            }
        }
        for &s in &self.zero_steps {
            if s >= self.steps {
                bail!("zero_steps entry {s} out of range (steps = {})", self.steps);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<TraceSpec> {
        TraceSpec::from_json(&Json::parse(text).unwrap())
    }

    #[test]
    fn parses_full_spec_and_defaults() {
        let spec = parse(
            r#"{"name": "t", "steps": 8, "drift": {"from": 1.0, "to": 2.0},
                "diurnal": {"amplitude": 0.2, "period": 4},
                "bursty": {"every": 4, "hold": 2, "boost": 3.0},
                "noise": 0.05, "jitter": {"node": 0.1, "link": 0.2},
                "zero_steps": [3]}"#,
        )
        .unwrap();
        assert_eq!(spec.seed, 42, "omitted seed defaults to 42");
        assert_eq!(spec.diurnal.unwrap().phase, 0.0);
        assert_eq!(spec.burst_at(1), Some((0, 3.0)));
        assert_eq!(spec.burst_at(2), None);
        assert_eq!(spec.burst_at(5), Some((1, 3.0)));
        // Drift ramp hits its endpoints and the diurnal term perturbs the
        // interior symmetrically around it.
        assert!((spec.skew_at(0) - 1.0).abs() < 1e-12);
        assert!((spec.skew_at(7) - 2.0).abs() < 1e-12);
        let minimal = parse(r#"{"name": "m", "steps": 1}"#).unwrap();
        assert_eq!(minimal.base_skew, 0.0);
        assert_eq!(minimal.noise, 0.0);
        assert_eq!(minimal.skew_at(0), 0.0);
        assert!(minimal.bursty.is_none() && minimal.jitter.is_none());
    }

    #[test]
    fn seed_zero_is_a_valid_seed() {
        let spec = parse(r#"{"name": "z", "steps": 2, "seed": 0}"#).unwrap();
        assert_eq!(spec.seed, 0);
    }

    #[test]
    fn skew_never_goes_negative() {
        let spec = parse(
            r#"{"name": "n", "steps": 16, "base_skew": 0.1,
                "diurnal": {"amplitude": 5.0, "period": 8}}"#,
        )
        .unwrap();
        for step in 0..spec.steps {
            assert!(spec.skew_at(step) >= 0.0, "step {step}");
        }
    }

    #[test]
    fn rejects_ill_formed_specs() {
        assert!(parse(r#"{"name": "x", "steps": 0}"#).is_err());
        assert!(parse(r#"{"name": "x", "steps": 4, "noise": 1.0}"#).is_err());
        assert!(parse(r#"{"name": "x", "steps": 4, "noise": -0.1}"#).is_err());
        assert!(
            parse(r#"{"name": "x", "steps": 4, "bursty": {"every": 2, "hold": 3, "boost": 2}}"#)
                .is_err()
        );
        assert!(
            parse(r#"{"name": "x", "steps": 4, "bursty": {"every": 2, "hold": 1, "boost": 0.5}}"#)
                .is_err()
        );
        assert!(parse(r#"{"name": "x", "steps": 4, "zero_steps": [4]}"#).is_err());
        assert!(parse(r#"{"name": "x", "steps": 4, "diurnal": {"amplitude": 1, "period": 0}}"#)
            .is_err());
        assert!(parse(r#"{"steps": 4}"#).is_err(), "name is required");
    }
}
