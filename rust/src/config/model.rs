//! Real-world model descriptions (paper §VI-D, Table V): MoE variants of
//! BERT-Base and GPT-2, plus the small LM used by the end-to-end training
//! example. A model is a stack of transformer blocks where every other FFN
//! is replaced by an MoE layer (the common "MoE-every-2" recipe used by
//! GShard/DeepSpeed-MoE).

use anyhow::{bail, Result};

use super::moe::{MoeLayerConfig, ParallelDegrees};
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Transformer blocks.
    pub layers: usize,
    /// Every `moe_every`-th block uses an MoE FFN (1 = all blocks).
    pub moe_every: usize,
    /// Hidden/embedding size `M`.
    pub m: usize,
    /// FFN hidden size `H` (typically 4·M).
    pub h: usize,
    pub vocab: usize,
    pub heads: usize,
    pub seq_len: usize,
    pub batch_per_gpu: usize,
    pub experts: usize,
    pub top_k: usize,
    pub capacity_factor: f64,
}

impl ModelConfig {
    /// BERT-Base MoE (paper §VI-D): 12 layers, M=768, H=3072; experts per
    /// the paper (2 on testbed A, 8 on testbed B).
    pub fn bert_base_moe(experts: usize) -> ModelConfig {
        ModelConfig {
            name: format!("bert_base_moe_e{experts}"),
            layers: 12,
            moe_every: 2,
            m: 768,
            h: 3072,
            vocab: 30522,
            heads: 12,
            seq_len: 512,
            batch_per_gpu: 8,
            experts,
            top_k: 2,
            capacity_factor: 1.2,
        }
    }

    /// GPT-2 (117M-class) MoE: 12 layers, M=768, H=3072, seq 1024.
    pub fn gpt2_moe(experts: usize) -> ModelConfig {
        ModelConfig {
            name: format!("gpt2_moe_e{experts}"),
            layers: 12,
            moe_every: 2,
            m: 768,
            h: 3072,
            vocab: 50257,
            heads: 12,
            seq_len: 1024,
            batch_per_gpu: 4,
            experts,
            top_k: 2,
            capacity_factor: 1.2,
        }
    }

    /// The ~100M-parameter MoE LM trained end-to-end by
    /// `examples/train_moe_lm.rs` (compute per step is that of a much
    /// smaller dense model thanks to sparse activation).
    pub fn tiny_moe_lm() -> ModelConfig {
        ModelConfig {
            name: "tiny_moe_lm".into(),
            layers: 4,
            moe_every: 2,
            m: 512,
            h: 2048,
            vocab: 8192,
            heads: 8,
            seq_len: 128,
            batch_per_gpu: 2,
            experts: 32,
            top_k: 2,
            capacity_factor: 1.5,
        }
    }

    pub fn builtin(name: &str) -> Result<ModelConfig> {
        match name {
            "bert_base_moe_a" => Ok(Self::bert_base_moe(2)),
            "bert_base_moe_b" => Ok(Self::bert_base_moe(8)),
            "gpt2_moe_a" => Ok(Self::gpt2_moe(2)),
            "gpt2_moe_b" => Ok(Self::gpt2_moe(8)),
            "tiny_moe_lm" => Ok(Self::tiny_moe_lm()),
            other => bail!(
                "unknown model `{other}` (builtins: bert_base_moe_a/b, gpt2_moe_a/b, tiny_moe_lm)"
            ),
        }
    }

    pub fn n_moe_layers(&self) -> usize {
        self.layers / self.moe_every
    }

    pub fn n_dense_ffn_layers(&self) -> usize {
        self.layers - self.n_moe_layers()
    }

    /// Total parameter count (embeddings + blocks + experts).
    pub fn param_count(&self) -> usize {
        let emb = self.vocab * self.m + self.seq_len * self.m;
        let attn = self.layers * 4 * self.m * self.m;
        let dense_ffn = self.n_dense_ffn_layers() * 2 * self.m * self.h;
        let gate = self.n_moe_layers() * self.m * self.experts;
        let experts = self.n_moe_layers() * self.experts * 2 * self.m * self.h;
        let norms = self.layers * 2 * 2 * self.m + self.m;
        emb + attn + dense_ffn + gate + experts + norms
    }

    /// The per-MoE-layer config this model induces under given degrees.
    pub fn moe_layer(&self, par: ParallelDegrees) -> MoeLayerConfig {
        MoeLayerConfig {
            par,
            b: self.batch_per_gpu,
            l: self.seq_len,
            e: self.experts,
            m: self.m,
            h: self.h,
            k: self.top_k,
            f: self.capacity_factor,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        }
    }

    /// FLOPs per GPU per training iteration for the *dense* (non-MoE)
    /// portion: attention + dense FFN + LM head, forward + backward (≈3×
    /// forward), under `n_mp`-way tensor parallelism.
    pub fn dense_flops_per_gpu(&self, n_mp: usize) -> f64 {
        let tokens = (self.batch_per_gpu * self.seq_len) as f64;
        let m = self.m as f64;
        let h = self.h as f64;
        // Per-token forward MACs: attention projections (4·M²) + scores
        // (2·L·M) + dense FFN (2·M·H on dense layers) + LM head (V·M).
        let attn = self.layers as f64 * (4.0 * m * m + 2.0 * self.seq_len as f64 * m);
        let ffn = self.n_dense_ffn_layers() as f64 * 2.0 * m * h;
        let head = self.vocab as f64 * m;
        let fwd_macs = tokens * (attn + ffn + head);
        // fwd+bwd ≈ 3× forward, 2 FLOP per MAC, split across MP ranks.
        3.0 * 2.0 * fwd_macs / n_mp as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("layers", Json::num(self.layers as f64)),
            ("moe_every", Json::num(self.moe_every as f64)),
            ("m", Json::num(self.m as f64)),
            ("h", Json::num(self.h as f64)),
            ("vocab", Json::num(self.vocab as f64)),
            ("heads", Json::num(self.heads as f64)),
            ("seq_len", Json::num(self.seq_len as f64)),
            ("batch_per_gpu", Json::num(self.batch_per_gpu as f64)),
            ("experts", Json::num(self.experts as f64)),
            ("top_k", Json::num(self.top_k as f64)),
            ("capacity_factor", Json::num(self.capacity_factor)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.req_str("name")?.to_string(),
            layers: j.req_usize("layers")?,
            moe_every: j.req_usize("moe_every")?,
            m: j.req_usize("m")?,
            h: j.req_usize("h")?,
            vocab: j.req_usize("vocab")?,
            heads: j.req_usize("heads")?,
            seq_len: j.req_usize("seq_len")?,
            batch_per_gpu: j.req_usize("batch_per_gpu")?,
            experts: j.req_usize("experts")?,
            top_k: j.req_usize("top_k")?,
            capacity_factor: j.req_f64("capacity_factor")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_models() {
        for n in ["bert_base_moe_a", "bert_base_moe_b", "gpt2_moe_a", "gpt2_moe_b", "tiny_moe_lm"] {
            let m = ModelConfig::builtin(n).unwrap();
            assert!(m.param_count() > 0);
        }
        assert!(ModelConfig::builtin("gpt5").is_err());
    }

    #[test]
    fn tiny_lm_is_about_100m_params() {
        let m = ModelConfig::tiny_moe_lm();
        let p = m.param_count();
        assert!(
            (80_000_000..160_000_000).contains(&p),
            "tiny_moe_lm should be ~100M params, got {p}"
        );
    }

    #[test]
    fn moe_layer_inherits_dims() {
        let m = ModelConfig::bert_base_moe(8);
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let layer = m.moe_layer(par);
        assert_eq!(layer.m, 768);
        assert_eq!(layer.e, 8);
        layer.validate().unwrap();
    }

    #[test]
    fn moe_layer_counts() {
        let m = ModelConfig::gpt2_moe(8);
        assert_eq!(m.n_moe_layers(), 6);
        assert_eq!(m.n_dense_ffn_layers(), 6);
    }

    #[test]
    fn dense_flops_scale_with_mp() {
        let m = ModelConfig::bert_base_moe(8);
        assert!((m.dense_flops_per_gpu(1) / m.dense_flops_per_gpu(4) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let m = ModelConfig::gpt2_moe(2);
        assert_eq!(ModelConfig::from_json(&m.to_json()).unwrap(), m);
    }
}
