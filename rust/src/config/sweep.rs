//! The paper's Table III configuration grid and its validity filter.
//!
//! Table III candidate values:
//!   P ∈ {8, 16, 32};  N_MP, N_ESP ∈ {1, 2, 4};  B ∈ {2, 4, 8};
//!   L ∈ {512, 1024, 2048};  M, H ∈ {1024, 2048, 4096};  f ∈ {1.2, 2.4}.
//!
//! The paper excludes configurations that exceed GPU memory and reports
//! "1296 valid runnable cases" across its testbeds. We reproduce the grid
//! exactly and apply the analogous feasibility filter against the target
//! cluster profile (memory capacity + placement constraints); the bench
//! harness prints the retained count so the filter is auditable.
//!
//! Beyond the paper's grid, [`sweep_table3_scaled`] densifies the
//! hyper-parameter axes (B, L, M, H, f — the layout axes are pinned by
//! the hardware) by repeatedly inserting midpoints of the widest value
//! gaps, multiplying the per-layout row count by `scale` — the
//! `parm sweep --scale K` axis that drives the planner to 10⁵+ cases
//! while keeping the number of distinct α-β fits unchanged.

use super::cluster::ClusterTopology;
use super::moe::{MoeLayerConfig, ParallelDegrees};

/// Which rows of the grid survive for a given cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepFilter {
    /// Keep every syntactically valid config (used by unit tests).
    All,
    /// Paper behaviour: drop configs whose per-GPU memory estimate exceeds
    /// the profile's device memory, and require the parallel degrees to be
    /// placeable on the profile (P ≤ total GPUs, groups within nodes where
    /// the paper's observations assume so).
    Feasible,
}

pub const TABLE3_P: [usize; 3] = [8, 16, 32];
pub const TABLE3_NMP: [usize; 3] = [1, 2, 4];
pub const TABLE3_NESP: [usize; 3] = [1, 2, 4];
pub const TABLE3_B: [usize; 3] = [2, 4, 8];
pub const TABLE3_L: [usize; 3] = [512, 1024, 2048];
pub const TABLE3_MH: [usize; 3] = [1024, 2048, 4096];
pub const TABLE3_F: [f64; 2] = [1.2, 2.4];

/// The per-layer hyper-parameter axes of a (possibly densified) Table III
/// grid. The parallel-layout axes (P, N_MP, N_ESP) are not part of this:
/// they are pinned by the hardware, which also pins the number of α-β
/// fits a sweep needs.
#[derive(Debug, Clone, PartialEq)]
pub struct GridAxes {
    pub b: Vec<usize>,
    pub l: Vec<usize>,
    pub m: Vec<usize>,
    pub h: Vec<usize>,
    pub f: Vec<f64>,
}

impl GridAxes {
    /// The paper's own candidate values.
    pub fn table3() -> GridAxes {
        GridAxes {
            b: TABLE3_B.to_vec(),
            l: TABLE3_L.to_vec(),
            m: TABLE3_MH.to_vec(),
            h: TABLE3_MH.to_vec(),
            f: TABLE3_F.to_vec(),
        }
    }

    /// Candidate rows per parallel layout (before validity filtering).
    pub fn rows(&self) -> usize {
        self.b.len() * self.l.len() * self.m.len() * self.h.len() * self.f.len()
    }

    /// Densify the axes until the per-layout row count reaches `scale`
    /// times Table III's, inserting the midpoint of each axis's widest
    /// value gap round-robin. Every Table III value stays in the grid, so
    /// a scaled sweep is a superset of the paper's; `scale <= 1` returns
    /// the paper's axes unchanged.
    pub fn densified(scale: usize) -> GridAxes {
        let mut axes = GridAxes::table3();
        if scale <= 1 {
            return axes;
        }
        let target = axes.rows().saturating_mul(scale);
        let mut stalled = 0;
        let mut turn = 0usize;
        while axes.rows() < target && stalled < 5 {
            let grown = match turn % 5 {
                0 => grow_usize(&mut axes.b),
                1 => grow_usize(&mut axes.l),
                2 => grow_usize(&mut axes.m),
                3 => grow_usize(&mut axes.h),
                _ => grow_f64(&mut axes.f),
            };
            stalled = if grown { 0 } else { stalled + 1 };
            turn += 1;
        }
        axes
    }
}

/// Insert the integer midpoint of the widest gap (ties: the leftmost).
/// Returns false when no gap admits a new distinct value.
fn grow_usize(axis: &mut Vec<usize>) -> bool {
    let mut best: Option<(usize, usize)> = None;
    for (i, w) in axis.windows(2).enumerate() {
        let gap = w[1] - w[0];
        let wider = match best {
            Some((_, g)) => gap > g,
            None => true,
        };
        if gap >= 2 && wider {
            best = Some((i, gap));
        }
    }
    if let Some((i, gap)) = best {
        axis.insert(i + 1, axis[i] + gap / 2);
        true
    } else {
        false
    }
}

/// Insert the widest gap's midpoint, rounded to 4 decimals so config ids
/// stay readable (`f` prints via `Display` in [`MoeLayerConfig::id`]).
fn grow_f64(axis: &mut Vec<f64>) -> bool {
    let mut best: Option<(usize, f64)> = None;
    for (i, w) in axis.windows(2).enumerate() {
        let gap = w[1] - w[0];
        let wider = match best {
            Some((_, g)) => gap > g,
            None => true,
        };
        if wider {
            best = Some((i, gap));
        }
    }
    if let Some((i, _)) = best {
        let mid = ((axis[i] + axis[i + 1]) / 2.0 * 1e4).round() / 1e4;
        if mid <= axis[i] || mid >= axis[i + 1] {
            return false;
        }
        axis.insert(i + 1, mid);
        true
    } else {
        false
    }
}

/// Enumerate the Table III grid for one cluster, in deterministic order.
///
/// The number of experts is not in Table III; as in DeepSpeed-MoE's layer
/// benchmarks we place one expert per EP slot (`E = N_EP = P / N_ESP`) and
/// use top-2 gating (the GShard/Switch default the paper's models use).
pub fn sweep_table3(cluster: &ClusterTopology, filter: SweepFilter) -> Vec<MoeLayerConfig> {
    enumerate_grid(cluster, filter, &GridAxes::table3())
}

/// [`sweep_table3`] over [`GridAxes::densified`]`(scale)` — the
/// `--scale K` grid multiplier. `scale == 1` is bit-identical to the
/// paper's grid.
pub fn sweep_table3_scaled(
    cluster: &ClusterTopology,
    filter: SweepFilter,
    scale: usize,
) -> Vec<MoeLayerConfig> {
    enumerate_grid(cluster, filter, &GridAxes::densified(scale))
}

fn enumerate_grid(
    cluster: &ClusterTopology,
    filter: SweepFilter,
    axes: &GridAxes,
) -> Vec<MoeLayerConfig> {
    let mut out = Vec::new();
    for &p in &TABLE3_P {
        for &n_mp in &TABLE3_NMP {
            for &n_esp in &TABLE3_NESP {
                for &b in &axes.b {
                    for &l in &axes.l {
                        for &m in &axes.m {
                            for &h in &axes.h {
                                for &f in &axes.f {
                                    let par = ParallelDegrees { p, n_mp, n_esp };
                                    let cfg = MoeLayerConfig {
                                        par,
                                        b,
                                        l,
                                        e: p / n_esp,
                                        m,
                                        h,
                                        k: 2,
                                        f,
                                        dtype_bytes: 4,
                                        skew: 0.0,
                                        wire: Default::default(),
                                    };
                                    if cfg.validate().is_err() {
                                        continue;
                                    }
                                    if filter == SweepFilter::Feasible
                                        && !is_feasible(&cfg, cluster)
                                    {
                                        continue;
                                    }
                                    out.push(cfg);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Feasibility on a concrete cluster: fits on the machine and respects the
/// placement assumptions of §IV (ESP and MP groups intra-node).
pub fn is_feasible(cfg: &MoeLayerConfig, cluster: &ClusterTopology) -> bool {
    let p = cfg.par.p;
    if p > cluster.total_gpus() {
        return false;
    }
    // ESP groups (and MP groups, which the schedules treat as intra-node
    // collectives) must fit within a node — paper §IV Case 2/Case 4 place
    // them intra-node; larger groups would violate Observation 1's premise.
    // Both kinds are contiguous rank blocks, so a block is intra-node iff
    // its first and last member share a node — checked against the actual
    // topology, which under mixed per-node GPU counts is stricter than the
    // old uniform `size ≤ gpus_per_node` bound.
    for size in [cfg.par.n_esp, cfg.par.n_mp] {
        for start in (0..p).step_by(size) {
            if !cluster.same_node(start, start + size - 1) {
                return false;
            }
        }
    }
    // k ≤ E (top-2 gating needs at least 2 experts).
    if cfg.k > cfg.e {
        return false;
    }
    // Every hosting GPU must fit the layer (on a mixed fleet the smallest
    // node gates feasibility).
    cfg.memory_bytes_per_gpu() <= cluster.min_mem(p)
}

/// The Fig 1 slice: all grid rows at a fixed `P` on the given cluster.
pub fn sweep_at_p(cluster: &ClusterTopology, p: usize, filter: SweepFilter) -> Vec<MoeLayerConfig> {
    sweep_table3(cluster, filter)
        .into_iter()
        .filter(|c| c.par.p == p)
        .collect()
}

/// The Table IV slices: rows grouped by (N_MP, N_ESP) ∈ {2,4} × {2,4}.
pub fn table4_cells() -> Vec<(usize, usize)> {
    vec![(2, 2), (2, 4), (4, 2), (4, 4)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_size_unfiltered() {
        // 3 P × 3 N_MP × 3 N_ESP × 3 B × 3 L × 3 M × 3 H × 2 f = 4374 rows
        // before validity; syntactic validity keeps those with divisibility
        // and k ≤ E.
        let all = sweep_table3(&ClusterTopology::testbed_b(), SweepFilter::All);
        assert!(!all.is_empty());
        assert!(all.len() <= 4374);
        for c in &all {
            c.validate().unwrap();
            assert_eq!(c.e, c.par.n_ep());
        }
    }

    #[test]
    fn feasible_subset_smaller_and_within_memory() {
        let cluster = ClusterTopology::testbed_b();
        let all = sweep_table3(&cluster, SweepFilter::All);
        let feasible = sweep_table3(&cluster, SweepFilter::Feasible);
        assert!(feasible.len() < all.len());
        assert!(!feasible.is_empty());
        for c in &feasible {
            assert!(c.memory_bytes_per_gpu() <= cluster.min_mem(c.par.p));
            assert!(c.par.p <= cluster.total_gpus());
        }
    }

    #[test]
    fn heterogeneous_feasibility_uses_hosting_nodes() {
        use super::super::cluster::{AlphaBeta, NodeSpec};
        // Node 0 roomy, node 1 tiny: a P=8 layer is gated by the tiny
        // node's memory, a P=4 layer only by node 0's.
        let roomy = NodeSpec {
            gpus: 4,
            gpu_flops: 1e12,
            gpu_mem_bytes: 64 << 30,
            intra: AlphaBeta::new(1e-5, 1e-9),
            inter: AlphaBeta::new(1e-4, 1e-8),
        };
        let tiny = NodeSpec { gpu_mem_bytes: 1 << 10, ..roomy };
        let t = ClusterTopology::new("mixed", vec![roomy, tiny]).unwrap();
        let mut cfg = MoeLayerConfig::test_default();
        cfg.par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        assert!(!is_feasible(&cfg, &t), "tiny node must gate the P=8 layer");
        cfg.par = ParallelDegrees { p: 4, n_mp: 2, n_esp: 2 };
        assert!(is_feasible(&cfg, &t), "P=4 stays on the roomy node");
    }

    #[test]
    fn testbed_a_caps_p_at_8() {
        let feasible = sweep_table3(&ClusterTopology::testbed_a(), SweepFilter::Feasible);
        assert!(feasible.iter().all(|c| c.par.p <= 8));
    }

    #[test]
    fn p_slice() {
        let cluster = ClusterTopology::testbed_b();
        let s = sweep_at_p(&cluster, 32, SweepFilter::Feasible);
        assert!(!s.is_empty());
        assert!(s.iter().all(|c| c.par.p == 32));
    }

    #[test]
    fn deterministic_order() {
        let cluster = ClusterTopology::testbed_b();
        let a = sweep_table3(&cluster, SweepFilter::Feasible);
        let b = sweep_table3(&cluster, SweepFilter::Feasible);
        assert_eq!(a, b);
    }

    #[test]
    fn scale_one_is_the_paper_grid() {
        let cluster = ClusterTopology::testbed_b();
        assert_eq!(
            sweep_table3_scaled(&cluster, SweepFilter::Feasible, 1),
            sweep_table3(&cluster, SweepFilter::Feasible)
        );
        assert_eq!(GridAxes::densified(0), GridAxes::table3());
    }

    #[test]
    fn densified_axes_reach_the_target_and_keep_the_originals() {
        let base = GridAxes::table3();
        for scale in [2usize, 8, 64] {
            let axes = GridAxes::densified(scale);
            assert!(
                axes.rows() >= base.rows() * scale,
                "scale {scale}: {} rows < {}",
                axes.rows(),
                base.rows() * scale
            );
            for (dense, orig) in [
                (&axes.b, &base.b),
                (&axes.l, &base.l),
                (&axes.m, &base.m),
                (&axes.h, &base.h),
            ] {
                assert!(dense.windows(2).all(|w| w[0] < w[1]), "axis must stay sorted");
                assert!(orig.iter().all(|v| dense.contains(v)), "paper values must survive");
            }
            assert!(axes.f.windows(2).all(|w| w[0] < w[1]));
            assert!(base.f.iter().all(|v| axes.f.contains(v)));
        }
    }

    #[test]
    fn scaled_grid_is_valid_and_larger() {
        let cluster = ClusterTopology::testbed_b();
        let base = sweep_table3(&cluster, SweepFilter::Feasible);
        let scaled = sweep_table3_scaled(&cluster, SweepFilter::Feasible, 2);
        assert!(scaled.len() > base.len());
        for c in &scaled {
            c.validate().unwrap();
        }
        // Same layout axes ⇒ the α-β fit count is unchanged.
        let layouts = |cs: &[MoeLayerConfig]| {
            let mut set: Vec<_> = cs.iter().map(|c| (c.par.p, c.par.n_mp, c.par.n_esp)).collect();
            set.sort_unstable();
            set.dedup();
            set
        };
        assert_eq!(layouts(&base), layouts(&scaled));
    }
}
