//! Static schedule-program verifier: proves an [`Op`] program well-formed
//! WITHOUT executing it.
//!
//! Every schedule family is "just" an op program fed to the one shared
//! interpreter, so every invariant the schedules rest on — chunk volumes
//! conserving the monolithic collective, backward legs transposing the
//! forward ones, completion joins never detaching, MP groups partitioning
//! the a2a group — can be checked once, over the IR, for all families ×
//! forward/backward × every config. This module is that check: a single
//! linear walk that mirrors the interpreter's frontier semantics
//! symbolically and reports typed [`VerifyError`]s instead of running (or
//! panicking) anything.
//!
//! # Rule set
//!
//! | rule id               | proves |
//! |-----------------------|--------|
//! | `volume-conservation` | monolithic collectives carry their closed-form volumes; a region's chunked dispatch/combine bytes sum to the monolithic fused AlltoAll; combine chunk k transposes dispatch chunk k; chunk FFN flops are positive and bounded by the dense capacity FFN |
//! | `span-discipline`     | dispatch bytes decode to an integral row count; chunk spans partition the capacity; dispatch chunk indices are strictly increasing; every chunk op agrees on the region's chunk count `of` |
//! | `frontier-safety`     | chunk ops only appear inside an open pipelined region; FFN/dgrad/wgrad k follow dispatch k; combine k joins an FFN completion; no chunk combines twice; the region closes; every op's completion is reachable from the program's final join and the dependency graph is acyclic |
//! | `tag-discipline`      | chunk `index`/`of` fit the [`tags`] vocabulary bounds; dispatch chunk indices are dense `0..of`; every emitted tag exists in [`tags::all`]; the wire-leg classification matches the op kind |
//! | `plane-capability`    | a data-plane program contains no backward/training-only ops (`Bwd*`, the ReduceScatter adjoints) |
//! | `group-validity`      | the parallel degrees validate; MP/EP/ESP groups partition the world (same logic the SAA lowering uses); the layout fits the cluster |
//!
//! # How to add a rule
//!
//! 1. Add a variant to [`Rule`] (and its id in [`Rule::id`]).
//! 2. Emit findings from the symbolic walk in [`Verifier::step`] (per-op
//!    rules), [`Verifier::close_region`] (whole-region rules), or
//!    [`verify_program`] (whole-program/config rules) via
//!    `self.flag(rule, Some(op_index), message)`.
//! 3. Pin the rule with a seeded corruption in `tests/verify_mutations.rs`
//!    — every rule must have at least one mutation only it catches.
//!
//! Structural rules (everything not needing a config) also run under
//! [`verify_structure`], which the interpreter calls on every program in
//! debug builds — so the whole test suite transitively exercises them.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use crate::cluster::{GroupKind, ProcessGroups};
use crate::comm::tags;
use crate::config::{ClusterTopology, MoeLayerConfig, WireLeg};

use super::interp;
use super::ops::{self, Op};

/// Relative tolerance for volume conservation.
const VOL_TOL: f64 = 1e-9;
/// Absolute tolerance for "bytes decode to an integral row count".
const ROW_TOL: f64 = 1e-6;

/// The verifier's rule set. Each finding cites exactly one rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    VolumeConservation,
    SpanDiscipline,
    FrontierSafety,
    TagDiscipline,
    PlaneCapability,
    GroupValidity,
}

impl Rule {
    /// All rules, in reporting order.
    pub const ALL: [Rule; 6] = [
        Rule::VolumeConservation,
        Rule::SpanDiscipline,
        Rule::FrontierSafety,
        Rule::TagDiscipline,
        Rule::PlaneCapability,
        Rule::GroupValidity,
    ];

    /// Stable kebab-case rule id (JSON reports, CI grep).
    pub fn id(self) -> &'static str {
        match self {
            Rule::VolumeConservation => "volume-conservation",
            Rule::SpanDiscipline => "span-discipline",
            Rule::FrontierSafety => "frontier-safety",
            Rule::TagDiscipline => "tag-discipline",
            Rule::PlaneCapability => "plane-capability",
            Rule::GroupValidity => "group-validity",
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One typed finding: which rule, where in the program, and why.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyError {
    pub rule: Rule,
    /// Index into the op program, when the finding is op-local.
    pub op_index: Option<usize>,
    pub message: String,
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.op_index {
            Some(i) => write!(f, "[{}] op {}: {}", self.rule, i, self.message),
            None => write!(f, "[{}] {}", self.rule, self.message),
        }
    }
}

impl std::error::Error for VerifyError {}

/// Which interpreter a program targets: the DAG timing plane runs every op;
/// the data plane executes forward numerics only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plane {
    Timing,
    Data,
}

/// Structural verification: every rule that needs only the op program
/// (tag + span ordering discipline, frontier safety, leg consistency).
/// This is the debug-assertion hook the interpreter runs on EVERY program.
pub fn verify_structure(program: &[Op]) -> Vec<VerifyError> {
    let mut v = Verifier::new(None);
    v.walk(program);
    v.findings
}

/// [`verify_structure`], first finding as an `Err` (the interpreter hook).
pub fn check_structure(program: &[Op]) -> Result<(), VerifyError> {
    match verify_structure(program).into_iter().next() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Full static verification of `program` against its config, cluster, and
/// target plane: structure + volume conservation + span capacity + group
/// validity + plane capability. Returns ALL findings, in discovery order.
pub fn verify_program(
    program: &[Op],
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
    plane: Plane,
) -> Vec<VerifyError> {
    let mut v = Verifier::new(Some(cfg));
    v.walk(program);
    let mut findings = v.findings;
    findings.extend(group_findings(cfg, cluster));
    findings.extend(plane_findings(program, plane));
    findings
}

/// [`verify_program`], first finding as an `Err` (the lowering hook).
pub fn check_program(
    program: &[Op],
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
    plane: Plane,
) -> Result<(), VerifyError> {
    match verify_program(program, cfg, cluster, plane).into_iter().next() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Findings per rule id, for the lint report / bench JSON merge. Every
/// rule appears (zero-filled) so reports have a stable shape.
pub fn rule_counts(findings: &[VerifyError]) -> BTreeMap<&'static str, usize> {
    let mut counts: BTreeMap<&'static str, usize> = Rule::ALL.iter().map(|r| (r.id(), 0)).collect();
    for f in findings {
        *counts.entry(f.rule.id()).or_insert(0) += 1;
    }
    counts
}

/// The `plane-capability` rule: ops a data-plane program must not contain.
/// The data plane executes forward numerics; backward programs exist for
/// the timing plane only, as do the backward collective adjoints.
pub fn plane_findings(program: &[Op], plane: Plane) -> Vec<VerifyError> {
    if plane == Plane::Timing {
        return Vec::new();
    }
    program
        .iter()
        .enumerate()
        .filter(|(_, op)| data_plane_incapable(op))
        .map(|(i, op)| VerifyError {
            rule: Rule::PlaneCapability,
            op_index: Some(i),
            message: format!(
                "`{}` is a {} op: the data plane executes forward numerics only \
                 (use the timing plane for backward programs)",
                op_tag_lossy(op),
                op_family(op),
            ),
        })
        .collect()
}

/// True when the data-plane machine cannot execute `op` (mirrors the
/// rejection arms of `moe::exec`'s `DataMachine`).
pub fn data_plane_incapable(op: &Op) -> bool {
    matches!(
        op,
        Op::EspReduceScatter { .. }
            | Op::MpReduceScatter { .. }
            | Op::BwdEpAlltoAll { .. }
            | Op::BwdFusedAlltoAll { .. }
            | Op::BwdWgradAllReduce { .. }
            | Op::BwdExpertDgrad { .. }
            | Op::BwdExpertWgrad { .. }
            | Op::BwdSpDispatch { .. }
            | Op::BwdSpCombine { .. }
            | Op::BwdSpDgrad { .. }
            | Op::BwdSpWgrad { .. }
            | Op::BwdSp2Dispatch { .. }
            | Op::BwdSp2Combine { .. }
            | Op::BwdSp2Dgrad { .. }
            | Op::BwdSp2Wgrad { .. }
    )
}

/// Short family name for diagnostics.
pub fn op_family(op: &Op) -> &'static str {
    match op {
        Op::EspReduceScatter { .. } | Op::MpReduceScatter { .. } => "backward collective adjoint",
        Op::BwdEpAlltoAll { .. } | Op::BwdFusedAlltoAll { .. } => "backward AlltoAll",
        Op::BwdWgradAllReduce { .. } => "backward wgrad AllReduce",
        Op::BwdExpertDgrad { .. } | Op::BwdExpertWgrad { .. } => "backward expert compute",
        Op::BwdSpDispatch { .. }
        | Op::BwdSpCombine { .. }
        | Op::BwdSpDgrad { .. }
        | Op::BwdSpWgrad { .. } => "backward SP chunk",
        Op::BwdSp2Dispatch { .. }
        | Op::BwdSp2Combine { .. }
        | Op::BwdSp2Dgrad { .. }
        | Op::BwdSp2Wgrad { .. } => "backward SP2 chunk",
        Op::SpDispatch { .. } | Op::SpCombine { .. } | Op::SpExpertFfn { .. } => "SP chunk",
        Op::Sp2Dispatch { .. } | Op::Sp2Saa { .. } | Op::Sp2ExpertFfn { .. } => "SP2 chunk",
        _ => "forward",
    }
}

/// The partition check shared with the SAA/AAS lowering
/// (`comm::saa::validate_mp_partition` delegates here): `mp_groups` must
/// partition `a2a_group` — no foreign ranks, no overlaps, no gaps.
/// Messages are kept stable; callers match on them in tests.
pub fn validate_partition(
    a2a_group: &[usize],
    mp_groups: &[Vec<usize>],
) -> Result<(), VerifyError> {
    let group_err =
        |msg: String| VerifyError { rule: Rule::GroupValidity, op_index: None, message: msg };
    let mut seen: BTreeSet<usize> = BTreeSet::new();
    for g in mp_groups {
        for &r in g {
            if !a2a_group.contains(&r) {
                return Err(group_err(format!(
                    "mp group member {r} is not in the a2a group — mp_groups must partition it"
                )));
            }
            if !seen.insert(r) {
                return Err(group_err(format!(
                    "rank {r} appears in more than one mp group — overlapping partition"
                )));
            }
        }
    }
    for &r in a2a_group {
        if !seen.contains(&r) {
            return Err(group_err(format!(
                "a2a group member {r} is missing from the mp partition — incomplete partition"
            )));
        }
    }
    Ok(())
}

/// The `group-validity` rule: parallel degrees validate, every group kind
/// partitions the world, and the layout fits the cluster.
fn group_findings(cfg: &MoeLayerConfig, cluster: &ClusterTopology) -> Vec<VerifyError> {
    let mut out = Vec::new();
    match cfg.par.validate() {
        Err(e) => out.push(VerifyError {
            rule: Rule::GroupValidity,
            op_index: None,
            message: format!("parallel degrees invalid: {e:#}"),
        }),
        Ok(()) => {
            let groups = ProcessGroups { par: cfg.par };
            let world = groups.world();
            for kind in [GroupKind::Mp, GroupKind::Ep, GroupKind::Esp] {
                if let Err(e) = validate_partition(&world, &groups.all_groups(kind)) {
                    out.push(VerifyError {
                        rule: Rule::GroupValidity,
                        op_index: None,
                        message: format!(
                            "{kind:?} groups do not partition the world: {}",
                            e.message
                        ),
                    });
                }
            }
        }
    }
    if cfg.par.p > cluster.total_gpus() {
        out.push(VerifyError {
            rule: Rule::GroupValidity,
            op_index: None,
            message: format!(
                "layout needs {} GPUs but cluster `{}` has {}",
                cfg.par.p,
                cluster.name,
                cluster.total_gpus()
            ),
        });
    }
    out
}

/// Role an op plays inside a pipelined region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ChunkRole {
    Dispatch,
    Ffn,
    Wgrad,
    Combine,
}

/// `(role, index, of)` for the chunked (pipelined-region) ops.
fn chunk_op(op: &Op) -> Option<(ChunkRole, usize, usize)> {
    match *op {
        Op::SpDispatch { index, of, .. }
        | Op::Sp2Dispatch { index, of, .. }
        | Op::BwdSpDispatch { index, of, .. }
        | Op::BwdSp2Dispatch { index, of, .. } => Some((ChunkRole::Dispatch, index, of)),
        Op::SpExpertFfn { index, of, .. }
        | Op::Sp2ExpertFfn { index, of, .. }
        | Op::BwdSpDgrad { index, of, .. }
        | Op::BwdSp2Dgrad { index, of, .. } => Some((ChunkRole::Ffn, index, of)),
        Op::BwdSpWgrad { index, of, .. } | Op::BwdSp2Wgrad { index, of, .. } => {
            Some((ChunkRole::Wgrad, index, of))
        }
        Op::SpCombine { index, of, .. }
        | Op::Sp2Saa { index, of, .. }
        | Op::BwdSpCombine { index, of, .. }
        | Op::BwdSp2Combine { index, of, .. } => Some((ChunkRole::Combine, index, of)),
        _ => None,
    }
}

/// The op's single magnitude field (bytes or flops).
fn op_scalar(op: &Op) -> f64 {
    match *op {
        Op::EspAllGather { bytes_per_rank }
        | Op::EspSplit { bytes_per_rank }
        | Op::MpSplit { bytes_per_rank }
        | Op::MpAllGather { bytes_per_rank } => bytes_per_rank,
        Op::BwdWgradAllReduce { bytes_per_rank, .. } => bytes_per_rank,
        Op::EspAllReduce { total_bytes }
        | Op::EspReduceScatter { total_bytes }
        | Op::MpReduceScatter { total_bytes } => total_bytes,
        Op::EpAlltoAll { bytes_per_pair }
        | Op::FusedAlltoAll { bytes_per_pair }
        | Op::SaaCombine { bytes_per_pair }
        | Op::AasCombine { bytes_per_pair } => bytes_per_pair,
        Op::BwdEpAlltoAll { bytes_per_pair, .. } | Op::BwdFusedAlltoAll { bytes_per_pair, .. } => {
            bytes_per_pair
        }
        Op::SpDispatch { bytes_per_pair, .. }
        | Op::SpCombine { bytes_per_pair, .. }
        | Op::Sp2Dispatch { bytes_per_pair, .. }
        | Op::Sp2Saa { bytes_per_pair, .. }
        | Op::BwdSpDispatch { bytes_per_pair, .. }
        | Op::BwdSpCombine { bytes_per_pair, .. }
        | Op::BwdSp2Dispatch { bytes_per_pair, .. }
        | Op::BwdSp2Combine { bytes_per_pair, .. } => bytes_per_pair,
        Op::Gate { flops_per_rank }
        | Op::ExpertFfn { flops_per_rank }
        | Op::LocalCombine { flops_per_rank }
        | Op::Ungate { flops_per_rank }
        | Op::BwdExpertDgrad { flops_per_rank }
        | Op::BwdExpertWgrad { flops_per_rank } => flops_per_rank,
        Op::SpExpertFfn { flops_per_rank, .. }
        | Op::Sp2ExpertFfn { flops_per_rank, .. }
        | Op::BwdSpDgrad { flops_per_rank, .. }
        | Op::BwdSpWgrad { flops_per_rank, .. }
        | Op::BwdSp2Dgrad { flops_per_rank, .. }
        | Op::BwdSp2Wgrad { flops_per_rank, .. } => flops_per_rank,
    }
}

/// `op.tag()` where safe; a description otherwise (`Op::tag` indexes the
/// per-chunk tag arrays, so out-of-vocabulary chunk indices would panic).
fn op_tag_lossy(op: &Op) -> String {
    match chunk_op(op) {
        Some((_, index, _)) if index >= tags::SP_MAX_CHUNKS => format!("chunk op index {index}"),
        _ => op.tag().to_string(),
    }
}

/// The wire leg each op kind must classify to. Forward `EpAlltoAll` /
/// `FusedAlltoAll` are positional (first = dispatch, later = combine), so
/// they accept either AlltoAll leg.
enum LegExpect {
    Fixed(Option<WireLeg>),
    FwdA2A,
}

fn expected_leg(op: &Op) -> LegExpect {
    match op {
        Op::EpAlltoAll { .. } | Op::FusedAlltoAll { .. } => LegExpect::FwdA2A,
        Op::SpDispatch { .. }
        | Op::Sp2Dispatch { .. }
        | Op::BwdSpDispatch { .. }
        | Op::BwdSp2Dispatch { .. } => LegExpect::Fixed(Some(WireLeg::Dispatch)),
        Op::BwdEpAlltoAll { combine, .. } | Op::BwdFusedAlltoAll { combine, .. } => {
            LegExpect::Fixed(Some(if *combine { WireLeg::Combine } else { WireLeg::Dispatch }))
        }
        Op::SaaCombine { .. }
        | Op::AasCombine { .. }
        | Op::SpCombine { .. }
        | Op::Sp2Saa { .. }
        | Op::BwdSpCombine { .. }
        | Op::BwdSp2Combine { .. } => LegExpect::Fixed(Some(WireLeg::Combine)),
        Op::EspAllGather { .. }
        | Op::MpAllGather { .. }
        | Op::EspReduceScatter { .. }
        | Op::MpReduceScatter { .. }
        | Op::EspAllReduce { .. } => LegExpect::Fixed(Some(WireLeg::AllGather)),
        Op::BwdWgradAllReduce { .. } => LegExpect::Fixed(Some(WireLeg::Wgrad)),
        _ => LegExpect::Fixed(None),
    }
}

/// Symbolic state of one open pipelined region (mirrors the interpreter's
/// `PipeState`, with dependency-graph node ids instead of transport
/// handles).
struct Region {
    of: usize,
    /// Op index of the dispatch that opened the region.
    opened_at: usize,
    /// Comm-stream frontier (node ids).
    comm: Vec<usize>,
    /// Compute-stream frontier (node ids).
    comp: Vec<usize>,
    /// Chunk index → dispatch node.
    dispatched: BTreeMap<usize, usize>,
    /// Chunk index → last FFN/dgrad node (what the combine joins).
    ffn_slot: BTreeMap<usize, usize>,
    /// Chunk indices already combined (protocol: each exactly once).
    combined: BTreeSet<usize>,
    combines_done: usize,
    last_dispatch: Option<usize>,
    /// Byte accumulators. The sums include every chunk op (even ones with
    /// out-of-range indices), so a pure index corruption does not cascade
    /// into a volume finding; the per-index maps hold only well-indexed
    /// ops.
    dispatch_sum: f64,
    combine_sum: f64,
    dispatch_bytes: BTreeMap<usize, f64>,
    combine_bytes: BTreeMap<usize, f64>,
    ffn_flops: f64,
}

impl Region {
    fn new(of: usize, opened_at: usize, frontier: &[usize]) -> Region {
        Region {
            of,
            opened_at,
            comm: frontier.to_vec(),
            comp: frontier.to_vec(),
            dispatched: BTreeMap::new(),
            ffn_slot: BTreeMap::new(),
            combined: BTreeSet::new(),
            combines_done: 0,
            last_dispatch: None,
            dispatch_sum: 0.0,
            combine_sum: 0.0,
            dispatch_bytes: BTreeMap::new(),
            combine_bytes: BTreeMap::new(),
            ffn_flops: 0.0,
        }
    }
}

/// `|got - want|` within the relative volume tolerance.
fn vol_close(got: f64, want: f64) -> bool {
    (got - want).abs() <= VOL_TOL * want.abs().max(1.0)
}

/// The symbolic walker: one pass over the program, mirroring the
/// interpreter's frontier/region/deferred semantics on a dependency graph
/// whose nodes are op indices.
struct Verifier<'a> {
    cfg: Option<&'a MoeLayerConfig>,
    findings: Vec<VerifyError>,
    /// `deps[i]` = graph dependencies (node ids) of op `i`.
    deps: Vec<Vec<usize>>,
    /// Ops whose completion must be reachable from the final join (all but
    /// the free splits).
    needs_reach: Vec<bool>,
    /// Ops that already carry a finding — exempt from the reachability
    /// backstop so one corruption yields one finding, not a cascade.
    flagged: Vec<bool>,
    frontier: Vec<usize>,
    deferred: Vec<usize>,
    region: Option<Region>,
    fwd_a2a_seen: usize,
    vocab: Vec<&'static str>,
}

impl<'a> Verifier<'a> {
    fn new(cfg: Option<&'a MoeLayerConfig>) -> Verifier<'a> {
        Verifier {
            cfg,
            findings: Vec::new(),
            deps: Vec::new(),
            needs_reach: Vec::new(),
            flagged: Vec::new(),
            frontier: Vec::new(),
            deferred: Vec::new(),
            region: None,
            fwd_a2a_seen: 0,
            vocab: tags::all(),
        }
    }

    fn flag(&mut self, rule: Rule, op_index: Option<usize>, message: String) {
        if let Some(i) = op_index {
            if let Some(slot) = self.flagged.get_mut(i) {
                *slot = true;
            }
        }
        self.findings.push(VerifyError { rule, op_index, message });
    }

    fn walk(&mut self, program: &[Op]) {
        let n = program.len();
        self.deps = vec![Vec::new(); n];
        self.needs_reach = vec![true; n];
        self.flagged = vec![false; n];
        for (i, op) in program.iter().enumerate() {
            self.step(i, op);
        }
        self.finish(n);
    }

    /// Per-op rules + symbolic interpretation of op `i`.
    fn step(&mut self, i: usize, op: &Op) {
        // Magnitudes must be finite and non-negative before any sum is
        // meaningful.
        let scalar = op_scalar(op);
        if !scalar.is_finite() || scalar < 0.0 {
            self.flag(
                Rule::VolumeConservation,
                Some(i),
                format!("op magnitude {scalar} is negative or non-finite"),
            );
        }

        // Tag-discipline bounds come FIRST: `Op::tag()` indexes the
        // per-chunk tag arrays, so an out-of-vocabulary index would panic
        // the very accessor every later rule uses.
        if let Some((role, index, of)) = chunk_op(op) {
            if of == 0 || of > tags::SP_MAX_CHUNKS || index >= of {
                self.flag(
                    Rule::TagDiscipline,
                    Some(i),
                    format!(
                        "chunk index {index} of {of} is outside the tag vocabulary \
                         (need 1 <= of <= {} and index < of)",
                        tags::SP_MAX_CHUNKS
                    ),
                );
                // Mirror the interpreter's region accounting just enough to
                // avoid cascading findings: combines still count toward the
                // region's close, and chunked bytes toward its volume sums.
                let mut close = false;
                if let Some(reg) = self.region.as_mut() {
                    match role {
                        ChunkRole::Dispatch => reg.dispatch_sum += scalar,
                        ChunkRole::Combine => {
                            reg.combine_sum += scalar;
                            reg.combines_done += 1;
                            close = reg.combines_done == reg.of;
                        }
                        _ => {}
                    }
                }
                if close {
                    self.close_region(i);
                }
                return;
            }
        }

        let tag = op.tag();
        if !self.vocab.contains(&tag) {
            self.flag(
                Rule::TagDiscipline,
                Some(i),
                format!("tag `{tag}` is not in the comm/tags.rs vocabulary"),
            );
        }

        // Wire-leg classification must agree with the op kind.
        let got = interp::wire_leg_of(op, &mut self.fwd_a2a_seen);
        match expected_leg(op) {
            LegExpect::FwdA2A => {
                if !matches!(got, Some(WireLeg::Dispatch) | Some(WireLeg::Combine)) {
                    self.flag(
                        Rule::TagDiscipline,
                        Some(i),
                        format!(
                            "forward AlltoAll classified to wire leg {got:?}, \
                             want an AlltoAll leg"
                        ),
                    );
                }
            }
            LegExpect::Fixed(want) => {
                if got != want {
                    self.flag(
                        Rule::TagDiscipline,
                        Some(i),
                        format!("`{tag}` classified to wire leg {got:?}, want {want:?}"),
                    );
                }
            }
        }

        // Monolithic per-op volume pins (the backward AlltoAlls carry the
        // SAME closed-form volume as their forward legs — this pin IS the
        // transposition check for the monolithic families).
        if let Some(c) = self.cfg {
            let want = match op {
                Op::EpAlltoAll { .. } | Op::BwdEpAlltoAll { .. } => {
                    Some(("EP AlltoAll", ops::bytes_ep_a2a_per_pair(c)))
                }
                Op::FusedAlltoAll { .. }
                | Op::BwdFusedAlltoAll { .. }
                | Op::SaaCombine { .. }
                | Op::AasCombine { .. } => {
                    Some(("fused EP×ESP AlltoAll", ops::bytes_fused_a2a_per_pair(c)))
                }
                Op::EspAllReduce { .. } => Some(("ESP AllReduce", ops::bytes_esp_ar_total(c))),
                Op::BwdWgradAllReduce { .. } => {
                    Some(("wgrad AllReduce", ops::bytes_wgrad_per_rank(c)))
                }
                _ => None,
            };
            if let Some((what, want)) = want {
                if !vol_close(scalar, want) {
                    self.flag(
                        Rule::VolumeConservation,
                        Some(i),
                        format!(
                            "`{tag}` carries {scalar} bytes, closed-form {what} volume is {want}"
                        ),
                    );
                }
            }
        }

        match chunk_op(op) {
            Some((role, index, of)) => self.step_chunk(i, role, index, of, scalar),
            None => match op {
                Op::EspSplit { .. } | Op::MpSplit { .. } => {
                    // Free local view change: no completion event.
                    self.needs_reach[i] = false;
                }
                Op::BwdWgradAllReduce { overlap, .. } => {
                    self.deps[i] = self.frontier.clone();
                    if *overlap {
                        // Deferred completion: joined at program end.
                        self.deferred.push(i);
                    } else {
                        self.frontier = vec![i];
                    }
                }
                _ => {
                    // Plain op on the main frontier (the interpreter runs
                    // these outside the region streams).
                    self.deps[i] = self.frontier.clone();
                    self.frontier = vec![i];
                }
            },
        }
    }

    /// Symbolic interpretation of a chunked (pipelined-region) op.
    fn step_chunk(&mut self, i: usize, role: ChunkRole, index: usize, of: usize, scalar: f64) {
        if role == ChunkRole::Dispatch && self.region.is_none() {
            self.region = Some(Region::new(of, i, &self.frontier));
        }
        let (reg_of, reg_opened) = match self.region.as_ref() {
            Some(reg) => (reg.of, reg.opened_at),
            None => {
                self.flag(
                    Rule::FrontierSafety,
                    Some(i),
                    format!("{role:?} chunk {index} appears outside an open pipelined region"),
                );
                return;
            }
        };
        if of != reg_of {
            self.flag(
                Rule::SpanDiscipline,
                Some(i),
                format!(
                    "chunk op claims {of} chunks but the region opened at op {reg_opened} \
                     has {reg_of}"
                ),
            );
        }
        match role {
            ChunkRole::Dispatch => {
                let reg = self.region.as_mut().expect("region open");
                let prev = reg.last_dispatch;
                reg.last_dispatch = Some(prev.map_or(index, |l| l.max(index)));
                self.deps[i] = std::mem::replace(&mut reg.comm, vec![i]);
                reg.dispatched.insert(index, i);
                reg.dispatch_sum += scalar;
                reg.dispatch_bytes.insert(index, scalar);
                if let Some(last) = prev {
                    if index <= last {
                        self.flag(
                            Rule::SpanDiscipline,
                            Some(i),
                            format!(
                                "dispatch chunk {index} after chunk {last}: \
                                 dispatch indices must be strictly increasing"
                            ),
                        );
                    }
                }
                // Span discipline: dispatch bytes must decode to an
                // integral number of capacity rows.
                if let Some(c) = self.cfg {
                    let row = ops::bytes_sp_chunk_per_pair(c, 1);
                    let rows = scalar / row;
                    if (rows - rows.round()).abs() > ROW_TOL {
                        self.flag(
                            Rule::SpanDiscipline,
                            Some(i),
                            format!(
                                "dispatch chunk {index} carries {scalar} bytes = {rows} \
                                 capacity rows of {row} bytes — spans must cover whole rows"
                            ),
                        );
                    }
                }
            }
            ChunkRole::Ffn | ChunkRole::Wgrad => {
                let reg = self.region.as_mut().expect("region open");
                let mut deps = std::mem::replace(&mut reg.comp, vec![i]);
                let missing_dispatch = match reg.dispatched.get(&index) {
                    Some(&d) => {
                        deps.push(d);
                        false
                    }
                    None => true,
                };
                if role == ChunkRole::Ffn {
                    reg.ffn_slot.insert(index, i);
                    reg.ffn_flops += scalar;
                }
                self.deps[i] = deps;
                if missing_dispatch {
                    let what = if role == ChunkRole::Ffn { "FFN/dgrad" } else { "wgrad" };
                    self.flag(
                        Rule::FrontierSafety,
                        Some(i),
                        format!("{what} for chunk {index} precedes that chunk's dispatch"),
                    );
                }
            }
            ChunkRole::Combine => {
                let reg = self.region.as_mut().expect("region open");
                let mut deps = std::mem::replace(&mut reg.comm, vec![i]);
                let missing_ffn = match reg.ffn_slot.get(&index) {
                    Some(&f) => {
                        deps.push(f);
                        false
                    }
                    None => true,
                };
                let duplicate = !reg.combined.insert(index);
                reg.combine_sum += scalar;
                reg.combine_bytes.insert(index, scalar);
                reg.combines_done += 1;
                let close = reg.combines_done == reg.of;
                self.deps[i] = deps;
                if missing_ffn {
                    self.flag(
                        Rule::FrontierSafety,
                        Some(i),
                        format!(
                            "combine for chunk {index} has no FFN/dgrad completion to join \
                             — its compute would detach from the final frontier"
                        ),
                    );
                }
                if duplicate {
                    self.flag(
                        Rule::FrontierSafety,
                        Some(i),
                        format!("chunk {index} combined twice — the region would close early"),
                    );
                }
                if close {
                    self.close_region(i);
                }
            }
        }
    }

    /// Region close: join both streams back into the main frontier (the
    /// interpreter's `merge_region`) and run the whole-region rules.
    fn close_region(&mut self, close_op: usize) {
        let reg = self.region.take().expect("close_region with region open");
        self.frontier = reg.comm.iter().chain(reg.comp.iter()).copied().collect();

        // Tag discipline: dispatch chunk indices must be dense 0..of.
        let want: BTreeSet<usize> = (0..reg.of).collect();
        let got: BTreeSet<usize> = reg.dispatched.keys().copied().collect();
        if got != want {
            self.flag(
                Rule::TagDiscipline,
                Some(close_op),
                format!(
                    "region dispatch chunk indices {:?} are not dense 0..{}",
                    got.iter().collect::<Vec<_>>(),
                    reg.of
                ),
            );
        }

        let Some(c) = self.cfg else { return };
        let fused = ops::bytes_fused_a2a_per_pair(c);
        if !vol_close(reg.dispatch_sum, fused) {
            self.flag(
                Rule::VolumeConservation,
                Some(close_op),
                format!(
                    "region dispatch bytes sum to {} but the monolithic fused AlltoAll \
                     moves {}",
                    reg.dispatch_sum, fused
                ),
            );
        }
        if !vol_close(reg.combine_sum, fused) {
            self.flag(
                Rule::VolumeConservation,
                Some(close_op),
                format!(
                    "region combine bytes sum to {} but the monolithic fused AlltoAll \
                     moves {}",
                    reg.combine_sum, fused
                ),
            );
        }
        // Per-chunk transposition: combine k moves exactly dispatch k's
        // bytes (forward: same span; backward: the transposed leg).
        for (k, &db) in &reg.dispatch_bytes {
            if let Some(&cb) = reg.combine_bytes.get(k) {
                if !vol_close(cb, db) {
                    self.findings.push(VerifyError {
                        rule: Rule::VolumeConservation,
                        op_index: Some(close_op),
                        message: format!(
                            "chunk {k} combine moves {cb} bytes, its dispatch moved {db}"
                        ),
                    });
                }
            }
        }
        // Span discipline: the spans partition the capacity.
        let row = ops::bytes_sp_chunk_per_pair(c, 1);
        let rows = reg.dispatch_sum / row;
        let cap = c.t_pausemp() as f64;
        if (rows - cap).abs() > ROW_TOL {
            self.flag(
                Rule::SpanDiscipline,
                Some(close_op),
                format!("region spans cover {rows} capacity rows, capacity is {cap}"),
            );
        }
        // FFN conservation: positive total, bounded by the dense capacity
        // FFN (load scaling only ever removes work).
        let dense = ops::sp_chunk_flops(c, c.t_pausemp());
        if reg.ffn_flops <= 0.0 {
            self.flag(
                Rule::VolumeConservation,
                Some(close_op),
                format!("region expert FFN flops sum to {} — no expert compute", reg.ffn_flops),
            );
        } else if reg.ffn_flops > dense * (1.0 + VOL_TOL) {
            self.flag(
                Rule::VolumeConservation,
                Some(close_op),
                format!(
                    "region expert FFN flops {} exceed the dense capacity FFN {}",
                    reg.ffn_flops, dense
                ),
            );
        }
    }

    /// End of program: the region must have closed, and every completion
    /// must be reachable from the final join.
    fn finish(&mut self, n: usize) {
        if let Some(reg) = self.region.take() {
            self.flag(
                Rule::FrontierSafety,
                None,
                format!(
                    "pipelined region opened at op {} did not complete: {}/{} combines \
                     (a chunk's combine is missing)",
                    reg.opened_at, reg.combines_done, reg.of
                ),
            );
            // Join the streams anyway so the one finding above does not
            // cascade into per-op reachability findings.
            self.frontier.extend(reg.comm);
            self.frontier.extend(reg.comp);
        }

        // Acyclicity: the graph is built with every edge pointing to an
        // earlier op, so a forward edge is a structural impossibility —
        // checked anyway as the backstop the reachability walk rests on.
        for i in 0..n {
            if self.deps[i].iter().any(|&d| d >= i) {
                self.findings.push(VerifyError {
                    rule: Rule::FrontierSafety,
                    op_index: Some(i),
                    message: "dependency graph has a forward edge (cycle)".to_string(),
                });
            }
        }

        // Reachability: reverse walk from the final join (frontier +
        // deferred completions) over the dependency edges.
        let mut reached = vec![false; n];
        let mut stack: Vec<usize> =
            self.frontier.iter().chain(self.deferred.iter()).copied().collect();
        while let Some(i) = stack.pop() {
            if reached[i] {
                continue;
            }
            reached[i] = true;
            stack.extend(self.deps[i].iter().copied());
        }
        for i in 0..n {
            if self.needs_reach[i] && !reached[i] && !self.flagged[i] {
                self.findings.push(VerifyError {
                    rule: Rule::FrontierSafety,
                    op_index: Some(i),
                    message: "op completion is not reachable from the program's final join \
                              (detached completion)"
                        .to_string(),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::builders;
    use crate::schedule::ops::ScheduleKind;

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig::test_default()
    }

    fn kinds() -> Vec<ScheduleKind> {
        vec![
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::S2Aas,
            ScheduleKind::Pipelined { chunks: 2 },
            ScheduleKind::PipelinedUniform { chunks: 3 },
            ScheduleKind::PipelinedS2 { chunks: 2 },
        ]
    }

    #[test]
    fn rule_ids_are_stable() {
        let ids: Vec<&str> = Rule::ALL.iter().map(|r| r.id()).collect();
        assert_eq!(
            ids,
            vec![
                "volume-conservation",
                "span-discipline",
                "frontier-safety",
                "tag-discipline",
                "plane-capability",
                "group-validity"
            ]
        );
    }

    #[test]
    fn all_builder_programs_verify_clean() {
        let c = cfg();
        let cluster = ClusterTopology::testbed_a();
        for kind in kinds() {
            for program in [
                builders::forward_ops(kind, &c),
                builders::backward_ops(kind, &c),
                builders::iteration_ops(kind, &c),
            ] {
                let findings = verify_program(&program, &c, &cluster, Plane::Timing);
                assert!(findings.is_empty(), "{kind:?}: {findings:?}");
            }
        }
    }

    #[test]
    fn forward_programs_verify_clean_on_the_data_plane() {
        let c = cfg();
        let cluster = ClusterTopology::testbed_a();
        for kind in kinds() {
            let program = builders::forward_ops(kind, &c);
            let findings = verify_program(&program, &c, &cluster, Plane::Data);
            assert!(findings.is_empty(), "{kind:?}: {findings:?}");
        }
    }

    #[test]
    fn backward_on_the_data_plane_is_a_plane_capability_finding() {
        let c = cfg();
        let cluster = ClusterTopology::testbed_a();
        let program = builders::backward_ops(ScheduleKind::S1, &c);
        let findings = verify_program(&program, &c, &cluster, Plane::Data);
        assert!(!findings.is_empty());
        assert!(findings.iter().all(|f| f.rule == Rule::PlaneCapability), "{findings:?}");
        assert!(findings.iter().all(|f| f.op_index.is_some()));
    }

    #[test]
    fn partition_validation_reports_typed_errors() {
        let world = vec![0, 1, 2, 3];
        assert!(validate_partition(&world, &[vec![0, 1], vec![2, 3]]).is_ok());
        let overlap = validate_partition(&world, &[vec![0, 1], vec![1, 2, 3]]).unwrap_err();
        assert_eq!(overlap.rule, Rule::GroupValidity);
        assert!(overlap.message.contains("overlapping partition"), "{overlap}");
        let foreign = validate_partition(&world, &[vec![0, 7]]).unwrap_err();
        assert!(foreign.message.contains("not in the a2a group"), "{foreign}");
        let gap = validate_partition(&world, &[vec![0, 1]]).unwrap_err();
        assert!(gap.message.contains("incomplete partition"), "{gap}");
    }

    #[test]
    fn display_cites_rule_and_op() {
        let e = VerifyError {
            rule: Rule::SpanDiscipline,
            op_index: Some(3),
            message: "m".to_string(),
        };
        assert_eq!(e.to_string(), "[span-discipline] op 3: m");
    }

    #[test]
    fn rule_counts_are_zero_filled() {
        let counts = rule_counts(&[]);
        assert_eq!(counts.len(), Rule::ALL.len());
        assert!(counts.values().all(|&v| v == 0));
    }
}
