//! Lowering of a schedule's op program to a [`SimDag`] for the
//! discrete-event engine.
//!
//! Ranks `0..P` of the MoE layer map to GPUs `0..P` of the cluster
//! (contiguous placement, as DeepSpeed-MoE). Each rank carries a frontier
//! task; collectives join the frontiers of their group members, compute
//! chains per rank.

use anyhow::{ensure, Result};

use crate::cluster::{GroupKind, ProcessGroups};
use crate::comm::{lower, saa};
use crate::config::{ClusterProfile, MoeLayerConfig};
use crate::sim::dag::{SimDag, TaskId};
use crate::sim::engine::{SimReport, Simulator};

use super::builders;
use super::ops::{Op, ScheduleKind};

/// Lower `ops` for `cfg` onto `cluster`; returns the DAG (makespan = the
/// program's iteration time once simulated).
pub fn lower_ops(
    ops: &[Op],
    cfg: &MoeLayerConfig,
    cluster: &ClusterProfile,
) -> Result<SimDag> {
    let p = cfg.par.p;
    ensure!(
        p <= cluster.total_gpus(),
        "layer needs {} GPUs but cluster {} has {}",
        p,
        cluster.name,
        cluster.total_gpus()
    );
    let groups = ProcessGroups::new(cfg.par)?;
    let mut dag = SimDag::new();
    // Current frontier (last task) per rank; None = start of program.
    let mut frontier: Vec<Option<TaskId>> = vec![None; p];

    // Join the frontiers of a set of ranks into a dep list.
    let deps_of = |frontier: &[Option<TaskId>], ranks: &[usize]| -> Vec<TaskId> {
        ranks.iter().filter_map(|&r| frontier[r]).collect()
    };

    for op in ops {
        let tag = op.tag();
        match *op {
            Op::EspSplit { .. } | Op::MpSplit { .. } => {
                // Free in forward (local view change).
            }
            Op::Gate { flops_per_rank }
            | Op::ExpertFfn { flops_per_rank }
            | Op::LocalCombine { flops_per_rank }
            | Op::Ungate { flops_per_rank } => {
                for r in 0..p {
                    let dep: Vec<TaskId> = frontier[r].into_iter().collect();
                    let t = dag.compute(r, flops_per_rank, &dep, tag);
                    frontier[r] = Some(t);
                }
            }
            Op::EspAllGather { bytes_per_rank } => {
                lower_groups(&mut dag, &groups, GroupKind::Esp, &mut frontier, |dag, grp, deps| {
                    lower::ring_allgather(dag, grp, bytes_per_rank, deps, tag)
                });
            }
            Op::EspReduceScatter { total_bytes } => {
                lower_groups(&mut dag, &groups, GroupKind::Esp, &mut frontier, |dag, grp, deps| {
                    let chunk = total_bytes / grp.len() as f64;
                    lower::ring_reduce_scatter(dag, grp, chunk, deps, tag)
                });
            }
            Op::EspAllReduce { total_bytes } => {
                lower_groups(&mut dag, &groups, GroupKind::Esp, &mut frontier, |dag, grp, deps| {
                    lower::ring_allreduce(dag, grp, total_bytes, deps, tag)
                });
            }
            Op::MpAllGather { bytes_per_rank } => {
                lower_groups(&mut dag, &groups, GroupKind::Mp, &mut frontier, |dag, grp, deps| {
                    lower::ring_allgather(dag, grp, bytes_per_rank, deps, tag)
                });
            }
            Op::MpReduceScatter { total_bytes } => {
                lower_groups(&mut dag, &groups, GroupKind::Mp, &mut frontier, |dag, grp, deps| {
                    let chunk = total_bytes / grp.len() as f64;
                    lower::ring_reduce_scatter(dag, grp, chunk, deps, tag)
                });
            }
            Op::EpAlltoAll { bytes_per_pair } => {
                lower_groups(&mut dag, &groups, GroupKind::Ep, &mut frontier, |dag, grp, deps| {
                    lower::pairwise_alltoall(dag, cluster, grp, bytes_per_pair, deps, tag)
                });
            }
            Op::FusedAlltoAll { bytes_per_pair } => {
                lower_groups(
                    &mut dag,
                    &groups,
                    GroupKind::EpEsp,
                    &mut frontier,
                    |dag, grp, deps| {
                        lower::pairwise_alltoall(dag, cluster, grp, bytes_per_pair, deps, tag)
                    },
                );
            }
            Op::SaaCombine { bytes_per_pair } => {
                let world: Vec<usize> = groups.world();
                let mp_groups = groups.all_groups(GroupKind::Mp);
                let deps = deps_of(&frontier, &world);
                let ends = saa::saa_lower(
                    &mut dag,
                    cluster,
                    &world,
                    &mp_groups,
                    bytes_per_pair,
                    &deps,
                    "saa.combine",
                    "mp.allgather",
                );
                for (i, &r) in world.iter().enumerate() {
                    frontier[r] = Some(ends[i]);
                }
            }
            Op::AasCombine { bytes_per_pair } => {
                let world: Vec<usize> = groups.world();
                let mp_groups = groups.all_groups(GroupKind::Mp);
                let deps = deps_of(&frontier, &world);
                let ends = saa::aas_lower(
                    &mut dag,
                    cluster,
                    &world,
                    &mp_groups,
                    bytes_per_pair,
                    &deps,
                    "aas.combine",
                    "mp.allgather",
                );
                for (i, &r) in world.iter().enumerate() {
                    frontier[r] = Some(ends[i]);
                }
            }
        }
    }
    Ok(dag)
}

/// Lower one collective over every group of `kind`, updating frontiers.
fn lower_groups<F>(
    dag: &mut SimDag,
    groups: &ProcessGroups,
    kind: GroupKind,
    frontier: &mut [Option<TaskId>],
    mut f: F,
) where
    F: FnMut(&mut SimDag, &[usize], &[TaskId]) -> Vec<TaskId>,
{
    for grp in groups.all_groups(kind) {
        let deps: Vec<TaskId> = grp.iter().filter_map(|&r| frontier[r]).collect();
        let ends = f(dag, &grp, &deps);
        for (i, &r) in grp.iter().enumerate() {
            frontier[r] = Some(ends[i]);
        }
    }
}

/// Simulate one full training iteration (fwd+bwd) of a MoE layer under a
/// concrete schedule; returns the engine report.
pub fn simulate_iteration(
    kind: ScheduleKind,
    cfg: &MoeLayerConfig,
    cluster: &ClusterProfile,
) -> Result<SimReport> {
    let ops = builders::iteration_ops(kind, cfg);
    let dag = lower_ops(&ops, cfg, cluster)?;
    Ok(Simulator::new(cluster).run(&dag))
}

/// Simulate the forward pass only.
pub fn simulate_forward(
    kind: ScheduleKind,
    cfg: &MoeLayerConfig,
    cluster: &ClusterProfile,
) -> Result<SimReport> {
    let ops = builders::forward_ops(kind, cfg);
    let dag = lower_ops(&ops, cfg, cluster)?;
    Ok(Simulator::new(cluster).run(&dag))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::moe::ParallelDegrees;

    fn cfg(p: usize, n_mp: usize, n_esp: usize) -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p, n_mp, n_esp },
            b: 2,
            l: 512,
            e: p / n_esp,
            m: 1024,
            h: 1024,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
        }
    }

    fn testbed_b() -> ClusterProfile {
        ClusterProfile::testbed_b()
    }

    #[test]
    fn all_schedules_lower_and_run() {
        let c = cfg(8, 2, 2);
        let cluster = testbed_b();
        for kind in [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::S2Aas,
        ] {
            let r = simulate_iteration(kind, &c, &cluster).unwrap();
            assert!(r.makespan > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn s1_and_s2_beat_baseline() {
        // The paper's §IV-B conclusion: both dedicated schedules are always
        // faster than the baseline (here on testbed B shapes).
        let cluster = testbed_b();
        for (p, n_mp, n_esp) in [(8, 2, 2), (16, 2, 4), (32, 4, 4), (8, 1, 2), (16, 4, 2)] {
            let c = cfg(p, n_mp, n_esp);
            let tb = simulate_iteration(ScheduleKind::Baseline, &c, &cluster)
                .unwrap()
                .makespan;
            let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
            let t2 = simulate_iteration(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
            assert!(t1 < tb, "S1 {t1} !< baseline {tb} at p={p} mp={n_mp} esp={n_esp}");
            assert!(t2 < tb, "S2 {t2} !< baseline {tb} at p={p} mp={n_mp} esp={n_esp}");
        }
    }

    #[test]
    fn speedup_grows_with_nmp() {
        let cluster = testbed_b();
        let speedup = |n_mp: usize| {
            let c = cfg(16, n_mp, 2);
            let tb = simulate_iteration(ScheduleKind::Baseline, &c, &cluster)
                .unwrap()
                .makespan;
            let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
            tb / t1
        };
        assert!(speedup(4) > speedup(2), "larger N_MP ⇒ larger S1 speedup");
    }

    #[test]
    fn nmp1_still_benefits_from_fusion() {
        // §IV-B N_MP = 1 case: PauseMP degenerates but the fused collective
        // still beats {AllGather; AlltoAll} sequencing.
        let cluster = testbed_b();
        let c = cfg(8, 1, 2);
        let tb = simulate_iteration(ScheduleKind::Baseline, &c, &cluster)
            .unwrap()
            .makespan;
        let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
        assert!(t1 < tb);
    }

    #[test]
    fn forward_cheaper_than_iteration() {
        let cluster = testbed_b();
        let c = cfg(8, 2, 2);
        let f = simulate_forward(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
        let it = simulate_iteration(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
        assert!(f < it);
    }

    #[test]
    fn rejects_oversized_layer() {
        let cluster = ClusterProfile::testbed_a(); // 8 GPUs
        let c = cfg(16, 2, 2);
        assert!(simulate_iteration(ScheduleKind::Baseline, &c, &cluster).is_err());
    }

    #[test]
    fn comm_dominates_on_testbed_b() {
        // Fig 1's observation: communication dominates MoE layer time.
        let cluster = testbed_b();
        let c = cfg(32, 2, 2);
        let r = simulate_iteration(ScheduleKind::Baseline, &c, &cluster).unwrap();
        assert!(
            r.comm_ratio() > 0.5,
            "comm ratio {} should dominate",
            r.comm_ratio()
        );
    }
}
