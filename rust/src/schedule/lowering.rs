//! Timing plane of the unified interpreter: lower a schedule's op program
//! to a [`SimDag`] for the discrete-event engine.
//!
//! Ranks `0..P` of the MoE layer map to GPUs `0..P` of the cluster
//! (contiguous placement, as DeepSpeed-MoE). The walking itself — group
//! selection, algorithm choice, per-rank frontier chaining — lives in
//! [`crate::schedule::interp`] and is shared verbatim with the data-plane
//! executor; this module only supplies the byte-lump payloads read off the
//! op fields ([`DagMachine`]).

use anyhow::{bail, ensure, Result};

use crate::cluster::ProcessGroups;
use crate::comm::transport::{DagTransport, Lump};
use crate::config::{ClusterTopology, MoeLayerConfig};
use crate::sim::dag::SimDag;
use crate::sim::engine::{SimReport, Simulator};

use super::builders;
use super::interp::{run_program, Machine};
use super::ops::{Op, ScheduleKind};

/// The timing plane's [`Machine`]: chunk sizes come straight from the op's
/// byte fields; payload contents and local transforms are irrelevant.
struct DagMachine;

impl Machine<DagTransport<'_>> for DagMachine {
    fn inputs(&mut self, op: &Op, grp: &[usize]) -> Result<Vec<Vec<Lump>>> {
        let g = grp.len();
        Ok(match *op {
            // AllGathers: each member contributes one chunk of its input.
            Op::EspAllGather { bytes_per_rank } | Op::MpAllGather { bytes_per_rank } => {
                vec![vec![Lump(bytes_per_rank)]; g]
            }
            // Reductions: each member's buffer splits into g ring chunks.
            Op::EspReduceScatter { total_bytes }
            | Op::MpReduceScatter { total_bytes }
            | Op::EspAllReduce { total_bytes } => {
                vec![vec![Lump(total_bytes / g as f64); g]; g]
            }
            // The wgrad AllReduce carries each member's expert-weight
            // gradient shard; same ring chunking as the reductions above.
            Op::BwdWgradAllReduce { bytes_per_rank, .. } => {
                vec![vec![Lump(bytes_per_rank / g as f64); g]; g]
            }
            // AlltoAll-likes: one chunk per (src, dst) pair. The backward
            // legs are transposes of their forward counterparts — identical
            // per-pair volumes, reversed direction.
            Op::EpAlltoAll { bytes_per_pair }
            | Op::FusedAlltoAll { bytes_per_pair }
            | Op::SaaCombine { bytes_per_pair }
            | Op::AasCombine { bytes_per_pair }
            | Op::SpDispatch { bytes_per_pair, .. }
            | Op::SpCombine { bytes_per_pair, .. }
            | Op::Sp2Dispatch { bytes_per_pair, .. }
            | Op::Sp2Saa { bytes_per_pair, .. }
            | Op::BwdEpAlltoAll { bytes_per_pair, .. }
            | Op::BwdFusedAlltoAll { bytes_per_pair, .. }
            | Op::BwdSpDispatch { bytes_per_pair, .. }
            | Op::BwdSpCombine { bytes_per_pair, .. }
            | Op::BwdSp2Dispatch { bytes_per_pair, .. }
            | Op::BwdSp2Combine { bytes_per_pair, .. } => {
                vec![vec![Lump(bytes_per_pair); g]; g]
            }
            _ => bail!("non-communication op has no chunk inputs: {op:?}"),
        })
    }

    fn accept(&mut self, _op: &Op, _grp: &[usize], _outputs: Vec<Vec<Lump>>) -> Result<()> {
        Ok(()) // the timing plane drops payloads
    }

    fn apply_local(&mut self, _op: &Op) -> Result<()> {
        Ok(())
    }
}

/// Lower `ops` for `cfg` onto `cluster`; returns the DAG (makespan = the
/// program's iteration time once simulated).
pub fn lower_ops(ops: &[Op], cfg: &MoeLayerConfig, cluster: &ClusterTopology) -> Result<SimDag> {
    let p = cfg.par.p;
    ensure!(
        p <= cluster.total_gpus(),
        "layer needs {} GPUs but cluster {} has {}",
        p,
        cluster.name,
        cluster.total_gpus()
    );
    let groups = ProcessGroups::new(cfg.par)?;
    // Debug builds run the FULL static verifier (structure + volume
    // conservation + span capacity + group validity) here, where the
    // config is known — every simulated program in the test suite is
    // proved well-formed before it is lowered.
    #[cfg(debug_assertions)]
    {
        let findings = super::verify::verify_program(ops, cfg, cluster, super::verify::Plane::Timing);
        ensure!(
            findings.is_empty(),
            "schedule program failed static verification:\n{}",
            findings.iter().map(|f| format!("  {f}")).collect::<Vec<_>>().join("\n")
        );
    }
    let mut dag = SimDag::new();
    // Op byte fields are model-width; the transport prices each leg at the
    // config's wire dtype (a no-op scale of 1.0 under the default policy).
    let mut transport = DagTransport::with_wire(&mut dag, cluster, cfg.wire, cfg.dtype_bytes);
    run_program(ops, &groups, &mut transport, &mut DagMachine)?;
    Ok(dag)
}

/// Simulate one full training iteration (fwd+bwd) of a MoE layer under a
/// concrete schedule; returns the engine report.
pub fn simulate_iteration(
    kind: ScheduleKind,
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
) -> Result<SimReport> {
    Ok(simulate_iteration_with_dag(kind, cfg, cluster)?.0)
}

/// [`simulate_iteration`], also returning the lowered DAG for per-task
/// inspection (overlap accounting, Chrome traces).
pub fn simulate_iteration_with_dag(
    kind: ScheduleKind,
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
) -> Result<(SimReport, SimDag)> {
    simulate_iteration_measured_with_dag(kind, cfg, cluster, None)
}

/// [`simulate_iteration_with_dag`] under an optional **measured**
/// per-expert load profile: the SP family's chunk spans are re-balanced
/// from the measurement (two-pass span selection — see
/// [`crate::schedule::builders::forward_ops_measured`]) before lowering.
pub fn simulate_iteration_measured_with_dag(
    kind: ScheduleKind,
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
    measured: Option<&[usize]>,
) -> Result<(SimReport, SimDag)> {
    simulate_iteration_traffic_with_dag(kind, cfg, cluster, measured, measured)
}

/// Two-profile iteration timing (see
/// [`crate::schedule::builders::forward_ops_traffic`]): spans planned from
/// the stale `span_loads` (an online controller can only know the previous
/// step's measurement), expert compute priced at the actual `flop_loads`.
/// The online/static fairness contract of `parm drive` rests here: both
/// sides pass the same `flop_loads`, and only the span source differs.
pub fn simulate_iteration_traffic_with_dag(
    kind: ScheduleKind,
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
    span_loads: Option<&[usize]>,
    flop_loads: Option<&[usize]>,
) -> Result<(SimReport, SimDag)> {
    let ops = builders::iteration_ops_traffic(kind, cfg, span_loads, flop_loads);
    let dag = lower_ops(&ops, cfg, cluster)?;
    let report = Simulator::new(cluster).run(&dag);
    Ok((report, dag))
}

/// Simulate the forward pass only.
pub fn simulate_forward(
    kind: ScheduleKind,
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
) -> Result<SimReport> {
    let ops = builders::forward_ops(kind, cfg);
    let dag = lower_ops(&ops, cfg, cluster)?;
    Ok(Simulator::new(cluster).run(&dag))
}

/// Simulate the backward pass only, with the wgrad-AllReduce either
/// overlapping the remaining backward ops (the production lowering) or
/// serialized before them (the ablation).
pub fn simulate_backward_overlap(
    kind: ScheduleKind,
    cfg: &MoeLayerConfig,
    cluster: &ClusterTopology,
    overlap: bool,
) -> Result<(SimReport, SimDag)> {
    let ops = builders::backward_ops_overlap(kind, cfg, None, overlap);
    let dag = lower_ops(&ops, cfg, cluster)?;
    let report = Simulator::new(cluster).run(&dag);
    Ok((report, dag))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::moe::ParallelDegrees;

    fn cfg(p: usize, n_mp: usize, n_esp: usize) -> MoeLayerConfig {
        MoeLayerConfig {
            par: ParallelDegrees { p, n_mp, n_esp },
            b: 2,
            l: 512,
            e: p / n_esp,
            m: 1024,
            h: 1024,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        }
    }

    fn testbed_b() -> ClusterTopology {
        ClusterTopology::testbed_b()
    }

    #[test]
    fn all_schedules_lower_and_run() {
        let c = cfg(8, 2, 2);
        let cluster = testbed_b();
        for kind in [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::S2Aas,
            ScheduleKind::Pipelined { chunks: 2 },
            ScheduleKind::Pipelined { chunks: 8 },
            ScheduleKind::PipelinedS2 { chunks: 2 },
            ScheduleKind::PipelinedS2 { chunks: 8 },
        ] {
            let r = simulate_iteration(kind, &c, &cluster).unwrap();
            assert!(r.makespan > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn sp2_with_one_chunk_times_like_s2() {
        // SP2(1)'s forward is S2's op structure with a fork/join around the
        // middle — the single chunk's SAA is the monolithic SAA, so the
        // forward makespan must match S2's exactly. The backward lowerings
        // legitimately differ (the region form overlaps the chunk's wgrad
        // with its combine AlltoAll, and the wgrad-AllReduce defers from a
        // different frontier), so the full iteration only matches loosely —
        // and never from above by more than round-off.
        let cluster = testbed_b();
        for (p, n_mp, n_esp) in [(8usize, 2usize, 2usize), (16, 4, 2)] {
            let c = cfg(p, n_mp, n_esp);
            let f2 = simulate_forward(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
            let fsp2 = simulate_forward(ScheduleKind::PipelinedS2 { chunks: 1 }, &c, &cluster)
                .unwrap()
                .makespan;
            let rel = (f2 - fsp2).abs() / f2;
            assert!(rel < 1e-9, "fwd SP2(1) {fsp2} vs S2 {f2} at p={p}");
            let t2 = simulate_iteration(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
            let tsp2 = simulate_iteration(ScheduleKind::PipelinedS2 { chunks: 1 }, &c, &cluster)
                .unwrap()
                .makespan;
            let rel = (t2 - tsp2).abs() / t2;
            assert!(rel < 0.05, "iter SP2(1) {tsp2} vs S2 {t2} at p={p}");
        }
    }

    #[test]
    fn measured_zero_loads_fall_back_to_expected_spans() {
        // Regression for the degenerate-gate case of `--spans measured`:
        // an all-zero measured load vector must be ignored (uniform /
        // expected-profile spans), not turned into NaN span weights — the
        // measured run then times identically to the plain one.
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let c = cfg(8, 2, 2);
        let zeros = vec![0usize; c.e];
        for kind in [
            ScheduleKind::Pipelined { chunks: 3 },
            ScheduleKind::PipelinedS2 { chunks: 3 },
        ] {
            let (plain, _) =
                simulate_iteration_measured_with_dag(kind, &c, &cluster, None).unwrap();
            let (zeroed, _) =
                simulate_iteration_measured_with_dag(kind, &c, &cluster, Some(&zeros)).unwrap();
            assert!(zeroed.makespan.is_finite() && zeroed.makespan > 0.0, "{kind:?}");
            assert_eq!(plain.makespan, zeroed.makespan, "{kind:?}");
        }
    }

    #[test]
    fn sp_with_one_chunk_times_like_s1() {
        // SP(1)'s forward is S1's op structure with a fork/join around the
        // middle — no overlap to exploit, so the forward makespan must
        // match S1's exactly. The backward lowerings legitimately differ
        // (see `sp2_with_one_chunk_times_like_s2`), so the full iteration
        // only matches loosely.
        let cluster = testbed_b();
        for (p, n_mp, n_esp) in [(8usize, 2usize, 2usize), (16, 4, 2)] {
            let c = cfg(p, n_mp, n_esp);
            let f1 = simulate_forward(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
            let fsp = simulate_forward(ScheduleKind::Pipelined { chunks: 1 }, &c, &cluster)
                .unwrap()
                .makespan;
            let rel = (f1 - fsp).abs() / f1;
            assert!(rel < 1e-9, "fwd SP(1) {fsp} vs S1 {f1} at p={p}");
            let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
            let tsp = simulate_iteration(ScheduleKind::Pipelined { chunks: 1 }, &c, &cluster)
                .unwrap()
                .makespan;
            let rel = (t1 - tsp).abs() / t1;
            assert!(rel < 0.05, "iter SP(1) {tsp} vs S1 {t1} at p={p}");
        }
    }

    #[test]
    fn sp_beats_s1_and_s2_on_compute_heavy_config() {
        // The SP acceptance case: when expert compute is comparable to (or
        // larger than) the fused-AlltoAll time, pipelining hides most of
        // the dispatch/combine communication behind the FFN chunks.
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let c = MoeLayerConfig {
            par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
            b: 8,
            l: 2048,
            e: 4,
            m: 1024,
            h: 32768,
            k: 2,
            f: 1.2,
            dtype_bytes: 4,
            skew: 0.0,
            wire: Default::default(),
        };
        let (r, _) = crate::perfmodel::closedform::optimal_chunks(&cluster, &c);
        assert!(r > 1, "closed form should pick pipelining here, got r={r}");
        let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
        let t2 = simulate_iteration(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
        let tsp = simulate_iteration(ScheduleKind::Pipelined { chunks: r }, &c, &cluster)
            .unwrap()
            .makespan;
        assert!(tsp < t1, "SP(r={r}) {tsp} !< S1 {t1}");
        assert!(tsp < t2, "SP(r={r}) {tsp} !< S2 {t2}");
    }

    #[test]
    fn sp2_beats_s1_s2_and_sp_on_inter_dominant_bracket() {
        // The SP2 acceptance case: on an inter-dominant fleet (slow NIC,
        // ~15-40× slower than intra) with MP > 1 and a SMALL capacity
        // factor (T below the token count — the §IV-B regime that favors
        // S2's capacity-based AG over S1's token-based one), the chunk
        // pipeline hides the FFN behind the NIC-bound AlltoAll chain
        // (beating S1/S2) AND each chunk's SAA hides its smaller
        // MP-AllGather inside the NIC gaps (beating SP, whose full
        // token-based AG epilogue stays exposed). Sweep a small pinned
        // bracket of that regime and require a strict simulated win —
        // with the fitted Algorithm 1 picking SP2 at the same
        // configuration. (At generous capacity factors the SAA forwards
        // instead contend with the intra-node a2a traffic and plain SP
        // stays ahead — that is expected, and the selection property
        // keeps those near-ties within tolerance.)
        use crate::config::AlphaBeta;
        use crate::perfmodel::{selection, PerfModel};

        let mut best: Option<(String, String, f64)> = None;
        let links = [(7.14e-10f64, 1.0e-8f64), (7.14e-10, 3.0e-8)];
        for (beta_intra, beta_inter) in links {
            let cluster = ClusterTopology::homogeneous(
                "slow_nic_2node",
                2,
                4,
                AlphaBeta::new(3.6e-5, beta_intra),
                AlphaBeta::new(5.0e-5, beta_inter),
                13.4e12 * 0.35,
                11 * (1 << 30),
            );
            for n_mp in [2usize, 4] {
                let mut model: Option<PerfModel> = None;
                for h in [16384usize, 49152] {
                    let c = MoeLayerConfig {
                        par: ParallelDegrees { p: 8, n_mp, n_esp: 2 },
                        b: 8,
                        l: 2048,
                        e: 4,
                        m: 1024,
                        h,
                        k: 2,
                        f: 0.6,
                        dtype_bytes: 4,
                        skew: 0.0,
                        wire: Default::default(),
                    };
                    let m = match &model {
                        Some(m) => m.clone(),
                        None => {
                            let fitted = PerfModel::fit(&cluster, c.par).unwrap();
                            model = Some(fitted.clone());
                            fitted
                        }
                    };
                    let pred = selection::predict(&m, &c);
                    if pred.sp2_chunks <= 1 {
                        continue;
                    }
                    let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
                    let t2 = simulate_iteration(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
                    let tsp = simulate_iteration(
                        ScheduleKind::Pipelined { chunks: pred.sp_chunks },
                        &c,
                        &cluster,
                    )
                    .unwrap()
                    .makespan;
                    let tsp2 = simulate_iteration(
                        ScheduleKind::PipelinedS2 { chunks: pred.sp2_chunks },
                        &c,
                        &cluster,
                    )
                    .unwrap()
                    .makespan;
                    let others = t1.min(t2).min(tsp);
                    let picked_sp2 = matches!(pred.best(), ScheduleKind::PipelinedS2 { .. });
                    if tsp2 < others && picked_sp2 {
                        let gain = others / tsp2;
                        if best.as_ref().map(|b| gain > b.2).unwrap_or(true) {
                            let link = format!("bi={beta_intra:e} be={beta_inter:e}");
                            best = Some((c.id(), link, gain));
                        }
                    }
                }
            }
        }
        let (id, link, gain) = best.expect(
            "no pinned inter-dominant config where SP2 strictly beats S1, S2 and SP \
             with Algorithm 1 selecting it",
        );
        eprintln!("SP2 wins at {id} ({link}): {gain:.4}× over best of {{S1,S2,SP}}");
        assert!(gain > 1.0, "SP2 win at {id} must be strict, got {gain:.6}×");
    }

    #[test]
    fn load_aware_spans_beat_uniform_spans_under_skew() {
        // The acceptance case for load-aware chunking: under skewed
        // routing, uniform capacity spans front-load the FFN (the hot
        // rows sit at the head of every expert block), stalling the
        // combine pipeline; FLOPs-balanced spans restore the overlap. The
        // effect peaks where chunk comm ≈ chunk compute, so sweep a small
        // pinned bracket around that parity point and require a strict,
        // measurable win at the same chunk count.
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let mut best: Option<(String, usize, f64)> = None;
        for (e, h, skew) in [
            (4usize, 32768usize, 2.0f64),
            (8, 16384, 2.0),
            (8, 32768, 1.2),
            (8, 32768, 2.0),
            (8, 49152, 2.0),
        ] {
            let c = MoeLayerConfig {
                par: ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 },
                b: 8,
                l: 2048,
                e,
                m: 1024,
                h,
                k: 2,
                f: 1.2,
                dtype_bytes: 4,
                skew,
                wire: Default::default(),
            };
            for r in [4usize, 8] {
                let tw = simulate_iteration(ScheduleKind::Pipelined { chunks: r }, &c, &cluster)
                    .unwrap()
                    .makespan;
                let tu =
                    simulate_iteration(ScheduleKind::PipelinedUniform { chunks: r }, &c, &cluster)
                        .unwrap()
                        .makespan;
                let gain = tu / tw;
                if tw < tu && best.as_ref().map(|b| gain > b.2).unwrap_or(true) {
                    best = Some((c.id(), r, gain));
                }
            }
        }
        let (id, r, gain) = best.expect(
            "no pinned skewed config where load-aware spans beat uniform spans strictly",
        );
        eprintln!("weighted spans win at {id} r={r}: {gain:.4}× over uniform");
        assert!(
            gain > 1.002,
            "weighted-span win at {id} r={r} should be measurable, got {gain:.5}×"
        );
    }

    #[test]
    fn uniform_and_weighted_spans_agree_without_skew() {
        // With the skew knob off the two SP variants emit identical
        // programs — the ablation column is exactly zero-cost then.
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let c = cfg(8, 2, 2);
        for r in [2usize, 4] {
            let tw = simulate_iteration(ScheduleKind::Pipelined { chunks: r }, &c, &cluster)
                .unwrap()
                .makespan;
            let tu = simulate_iteration(ScheduleKind::PipelinedUniform { chunks: r }, &c, &cluster)
                .unwrap()
                .makespan;
            assert_eq!(tw, tu, "r={r}");
        }
    }

    #[test]
    fn sp_chunks_overlap_compute_with_communication() {
        // The overlap the pipeline exists to create is visible in the
        // engine: compute and network transfers in flight simultaneously.
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let c = cfg(8, 2, 2);
        let ops = builders::forward_ops(ScheduleKind::Pipelined { chunks: 4 }, &c);
        let dag = lower_ops(&ops, &c, &cluster).unwrap();
        let report = Simulator::new(&cluster).run(&dag);
        assert!(
            report.overlap_seconds(&dag) > 0.0,
            "SP forward shows no compute/comm overlap"
        );
    }

    #[test]
    fn wgrad_allreduce_overlap_beats_serialized_backward() {
        // The whole-iteration acceptance case: deferring the expert
        // wgrad-AllReduce's completion lets the remaining backward ops
        // (combine AlltoAll, gate backward, the MP/ESP restore) run
        // concurrently with the reduction, so the overlapped lowering
        // strictly beats the serialized one at equal config — and the
        // engine sees the concurrency as nonzero compute/comm overlap in
        // the backward region.
        let cluster = ClusterTopology::testbed_b_subset(8).unwrap();
        let mut c = cfg(8, 2, 2);
        c.h = 16384; // sizable expert shards → a wgrad AllReduce worth hiding
        for kind in [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::Pipelined { chunks: 4 },
            ScheduleKind::PipelinedS2 { chunks: 4 },
        ] {
            let (ov, dag) = simulate_backward_overlap(kind, &c, &cluster, true).unwrap();
            let (seq, _) = simulate_backward_overlap(kind, &c, &cluster, false).unwrap();
            assert!(
                ov.makespan < seq.makespan,
                "{kind:?}: overlapped bwd {} !< serialized {}",
                ov.makespan,
                seq.makespan
            );
            assert!(
                ov.overlap_seconds(&dag) > 0.0,
                "{kind:?}: overlapped backward shows no compute/comm overlap"
            );
        }
    }

    #[test]
    fn backward_comm_log_uses_bwd_tags() {
        use crate::comm::tags;
        let cluster = testbed_b();
        let c = cfg(8, 2, 2);
        let ops = builders::backward_ops(ScheduleKind::S1, &c);
        let dag = lower_ops(&ops, &c, &cluster).unwrap();
        let log = dag.comm_log();
        let tags_seen: Vec<&str> = log.iter().map(|(t, _)| *t).collect();
        assert!(tags_seen.contains(&tags::BWD_FUSED_DISPATCH));
        assert!(tags_seen.contains(&tags::BWD_FUSED_COMBINE));
        assert!(tags_seen.contains(&tags::BWD_WGRAD_ALLREDUCE));
        assert!(tags_seen.contains(&tags::MP_REDUCESCATTER));
    }

    #[test]
    fn s1_and_s2_beat_baseline() {
        // The paper's §IV-B conclusion: both dedicated schedules are always
        // faster than the baseline (here on testbed B shapes).
        let cluster = testbed_b();
        for (p, n_mp, n_esp) in [(8, 2, 2), (16, 2, 4), (32, 4, 4), (8, 1, 2), (16, 4, 2)] {
            let c = cfg(p, n_mp, n_esp);
            let tb = simulate_iteration(ScheduleKind::Baseline, &c, &cluster)
                .unwrap()
                .makespan;
            let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
            let t2 = simulate_iteration(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
            assert!(t1 < tb, "S1 {t1} !< baseline {tb} at p={p} mp={n_mp} esp={n_esp}");
            assert!(t2 < tb, "S2 {t2} !< baseline {tb} at p={p} mp={n_mp} esp={n_esp}");
        }
    }

    #[test]
    fn speedup_grows_with_nmp() {
        let cluster = testbed_b();
        let speedup = |n_mp: usize| {
            let c = cfg(16, n_mp, 2);
            let tb = simulate_iteration(ScheduleKind::Baseline, &c, &cluster)
                .unwrap()
                .makespan;
            let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
            tb / t1
        };
        assert!(speedup(4) > speedup(2), "larger N_MP ⇒ larger S1 speedup");
    }

    #[test]
    fn nmp1_still_benefits_from_fusion() {
        // §IV-B N_MP = 1 case: PauseMP degenerates but the fused collective
        // still beats {AllGather; AlltoAll} sequencing.
        let cluster = testbed_b();
        let c = cfg(8, 1, 2);
        let tb = simulate_iteration(ScheduleKind::Baseline, &c, &cluster)
            .unwrap()
            .makespan;
        let t1 = simulate_iteration(ScheduleKind::S1, &c, &cluster).unwrap().makespan;
        assert!(t1 < tb);
    }

    #[test]
    fn forward_cheaper_than_iteration() {
        let cluster = testbed_b();
        let c = cfg(8, 2, 2);
        let f = simulate_forward(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
        let it = simulate_iteration(ScheduleKind::S2, &c, &cluster).unwrap().makespan;
        assert!(f < it);
    }

    #[test]
    fn rejects_oversized_layer() {
        let cluster = ClusterTopology::testbed_a(); // 8 GPUs
        let c = cfg(16, 2, 2);
        assert!(simulate_iteration(ScheduleKind::Baseline, &c, &cluster).is_err());
    }

    #[test]
    fn comm_dominates_on_testbed_b() {
        // Fig 1's observation: communication dominates MoE layer time.
        let cluster = testbed_b();
        let c = cfg(32, 2, 2);
        let r = simulate_iteration(ScheduleKind::Baseline, &c, &cluster).unwrap();
        assert!(
            r.comm_ratio() > 0.5,
            "comm ratio {} should dominate",
            r.comm_ratio()
        );
    }

    #[test]
    fn dag_comm_log_uses_canonical_tags() {
        use crate::comm::tags;
        let cluster = testbed_b();
        let c = cfg(8, 2, 2);
        let ops = builders::forward_ops(ScheduleKind::S2, &c);
        let dag = lower_ops(&ops, &c, &cluster).unwrap();
        let log = dag.comm_log();
        let tags_seen: Vec<&str> = log.iter().map(|(t, _)| *t).collect();
        assert!(tags_seen.contains(&tags::FUSED_ALLTOALL));
        assert!(tags_seen.contains(&tags::SAA_COMBINE));
        assert!(tags_seen.contains(&tags::MP_ALLGATHER));
    }
}
