//! The transport-generic Op-program interpreter — ONE walker for the
//! schedule IR, shared by the timing plane and the data plane.
//!
//! `run_program` walks a schedule's [`Op`] program once: communication ops
//! dispatch to the one-source collective algorithms of
//! [`crate::comm::algo`] over the layout's process groups, compute ops
//! charge per-rank FLOPs, and per-rank dependency frontiers chain it all
//! without global barriers. What varies between the planes is factored
//! into two small traits:
//!
//! * the [`Transport`] (how a message/compute/join materializes — DAG task
//!   or real `f32` movement), and
//! * the [`Machine`] (how a plane marshals an op's chunk payloads and what
//!   rank-local work accompanies the non-communication ops).
//!
//! The timing plane's machine ([`crate::schedule::lowering`]) reads chunk
//! sizes straight off the op's byte fields and ignores payloads; the data
//! plane's machine ([`crate::moe::exec`]) slices real rank buffers and
//! applies gating/expert/combine semantics. Neither re-states which
//! collective an op is, over which groups it runs, or how its messages
//! chain — that exists only here and in `comm::algo`.

use anyhow::{bail, ensure, Result};

use crate::cluster::{GroupKind, ProcessGroups};
use crate::comm::algo;
use crate::comm::tags;
use crate::comm::transport::{Chunk, Transport};
use crate::config::{WireDtype, WireLeg};

use super::ops::Op;

/// Plane-specific semantics around the shared interpreter.
pub trait Machine<T: Transport> {
    /// Marshal the chunks each member of `grp` contributes to `op`.
    /// Shape contract per member: AllGather ops → 1 chunk; ReduceScatter /
    /// AllReduce / AlltoAll / SAA ops → one chunk per group member
    /// (pair-addressed for the AlltoAll-likes, equal partition for the
    /// reductions).
    fn inputs(&mut self, op: &Op, grp: &[usize]) -> Result<Vec<Vec<T::Chunk>>>;

    /// Accept a collective's result; `outputs[k]` is member `grp[k]`'s
    /// chunk list: the gathered chunks (AllGather, group order), its
    /// reduced chunk (ReduceScatter), all reduced chunks (AllReduce), the
    /// received chunks in source order (AlltoAll), or the MP-peer-major
    /// flattening of the SAA AllGather result.
    fn accept(&mut self, op: &Op, grp: &[usize], outputs: Vec<Vec<T::Chunk>>) -> Result<()>;

    /// Apply the rank-local semantics of a non-communication op (gate,
    /// expert FFN, local combine, un-gate, and the free splits).
    fn apply_local(&mut self, op: &Op) -> Result<()>;

    /// Called once after ALL groups of a communication op have been
    /// accepted — the place for whole-op state transitions (a machine must
    /// not change what `inputs` returns while sibling groups of the same
    /// op are still being marshalled).
    fn finish(&mut self, _op: &Op) -> Result<()> {
        Ok(())
    }
}

/// In-flight state of a pipelined region (a maximal run of
/// `SpDispatch`/`SpExpertFfn`/`SpCombine` ops, or their SP2 counterparts
/// `Sp2Dispatch`/`Sp2ExpertFfn`/`Sp2Saa`). Instead of the single
/// per-rank frontier, the region runs TWO per-rank streams — chunked
/// AlltoAlls chain on the comm stream in emission order, chunked FFNs on
/// the compute stream — so chunk k's combine overlaps chunk k+1's compute
/// exactly as the builder's emission order intends. For SP2 the combine
/// is a chunked SAA whose MP-AllGather forwards additionally overlap the
/// inter-node AlltoAll on the second link class. Entry forks both
/// streams from the main frontier; the region's last combine joins them
/// back.
struct PipeState<H> {
    /// Per-rank comm-stream frontier.
    comm: Vec<Option<H>>,
    /// Per-rank compute-stream frontier.
    comp: Vec<Option<H>>,
    /// Per-chunk per-rank dispatch completion (feeds that chunk's FFN).
    dispatched: Vec<Vec<Option<H>>>,
    /// Per-chunk per-rank FFN completion (feeds that chunk's combine).
    ffn: Vec<Vec<Option<H>>>,
    /// Combines finished; when it reaches the chunk count the region ends.
    combines_done: usize,
}

impl<H: Clone> PipeState<H> {
    fn new(frontier: &[Option<H>], chunks: usize) -> PipeState<H> {
        PipeState {
            comm: frontier.to_vec(),
            comp: frontier.to_vec(),
            dispatched: vec![vec![None; frontier.len()]; chunks],
            ffn: vec![vec![None; frontier.len()]; chunks],
            combines_done: 0,
        }
    }
}

/// Close a pipelined region: join each rank's comm and compute stream
/// frontiers back into the main frontier. The ONE merge epilogue shared by
/// the last `SpCombine` and the last `Sp2Saa` of a region.
fn merge_region<T: Transport>(
    st: PipeState<T::Handle>,
    frontier: &mut [Option<T::Handle>],
    transport: &mut T,
    tag: &'static str,
) {
    for (r, slot) in frontier.iter_mut().enumerate() {
        let dep: Vec<T::Handle> = st.comm[r].iter().chain(st.comp[r].iter()).cloned().collect();
        *slot = Some(transport.join(&dep, tag));
    }
}

/// Marshal the chunks each member of `grp` contributes to `op`, rounded
/// to the transport's current wire dtype. This is the data plane's
/// quantize-on-send: the narrowing happens once, on the marshalled
/// inputs, before the collective algorithm moves them — so ReduceScatter
/// / AllReduce / SAA reduce steps still accumulate in f32 (partials are
/// never re-rounded). On the timing plane chunks are byte counts and
/// `quantize` is a no-op; the transport prices the compression instead.
fn marshal<T, M>(
    machine: &mut M,
    transport: &T,
    op: &Op,
    grp: &[usize],
) -> Result<Vec<Vec<T::Chunk>>>
where
    T: Transport,
    M: Machine<T>,
{
    let mut ins = machine.inputs(op, grp)?;
    let wd = transport.wire_dtype();
    if wd != WireDtype::F32 {
        for per_member in &mut ins {
            for chunk in per_member {
                chunk.quantize(wd);
            }
        }
    }
    Ok(ins)
}

/// Which wire leg `op`'s sends ride. The forward dispatch and combine
/// AlltoAlls share one op variant, so the interpreter disambiguates them
/// positionally: the FIRST forward AlltoAll of a program is the dispatch,
/// every later one is a combine (`fwd_a2a_seen` counts them). Backward
/// AlltoAlls carry an explicit `combine` flag; SAA rides the Combine leg
/// end to end (its MP-AllGather forwards included, on both planes); the
/// plain MP/ESP epilogues ride AllGather; the wgrad AllReduce has its own
/// leg. Compute/local ops return `None` (no sends to price).
pub(crate) fn wire_leg_of(op: &Op, fwd_a2a_seen: &mut usize) -> Option<WireLeg> {
    match op {
        Op::EpAlltoAll { .. } | Op::FusedAlltoAll { .. } => {
            let leg = if *fwd_a2a_seen == 0 { WireLeg::Dispatch } else { WireLeg::Combine };
            *fwd_a2a_seen += 1;
            Some(leg)
        }
        Op::SpDispatch { .. } | Op::Sp2Dispatch { .. } => Some(WireLeg::Dispatch),
        Op::BwdEpAlltoAll { combine, .. } | Op::BwdFusedAlltoAll { combine, .. } => {
            Some(if *combine { WireLeg::Combine } else { WireLeg::Dispatch })
        }
        Op::BwdSpDispatch { .. } | Op::BwdSp2Dispatch { .. } => Some(WireLeg::Dispatch),
        Op::SaaCombine { .. }
        | Op::AasCombine { .. }
        | Op::SpCombine { .. }
        | Op::Sp2Saa { .. }
        | Op::BwdSpCombine { .. }
        | Op::BwdSp2Combine { .. } => Some(WireLeg::Combine),
        Op::EspAllGather { .. }
        | Op::MpAllGather { .. }
        | Op::EspReduceScatter { .. }
        | Op::MpReduceScatter { .. }
        | Op::EspAllReduce { .. } => Some(WireLeg::AllGather),
        Op::BwdWgradAllReduce { .. } => Some(WireLeg::Wgrad),
        _ => None,
    }
}

/// Run one SAA/AAS collective over the whole world: marshal the machine's
/// inputs, execute [`algo::saa`] (AlltoAll tagged with the op's tag, the
/// MP-AllGather forwards with the canonical [`tags::MP_ALLGATHER`]), hand
/// the MP-peer-major flattening of the result to the machine, and return
/// the per-member completion handles in world order. The ONE invocation
/// shared by the monolithic S2 combine and SP2's per-chunk SAA — only the
/// dependency source and the frontier the completions land on differ
/// between the two call sites.
fn run_saa<T, M>(
    op: &Op,
    groups: &ProcessGroups,
    transport: &mut T,
    machine: &mut M,
    deps: &[T::Handle],
    overlap: bool,
) -> Result<Vec<T::Handle>>
where
    T: Transport,
    M: Machine<T>,
{
    let world = groups.world();
    let mp_groups = groups.all_groups(GroupKind::Mp);
    let ins = marshal(machine, transport, op, &world)?;
    ensure!(ins.len() == world.len(), "one chunk list per member");
    let (outs, ends) = algo::saa(
        transport,
        &world,
        &mp_groups,
        &ins,
        deps,
        op.tag(),
        tags::MP_ALLGATHER,
        overlap,
    );
    let flat: Vec<Vec<T::Chunk>> = outs
        .into_iter()
        .map(|per_peer| per_peer.into_iter().flatten().collect())
        .collect();
    machine.accept(op, &world, flat)?;
    Ok(ends)
}

/// Which process-group kind an op's collective runs over.
fn group_kind(op: &Op) -> Option<GroupKind> {
    match op {
        Op::EspAllGather { .. } | Op::EspReduceScatter { .. } | Op::EspAllReduce { .. } => {
            Some(GroupKind::Esp)
        }
        Op::MpAllGather { .. } | Op::MpReduceScatter { .. } => Some(GroupKind::Mp),
        Op::EpAlltoAll { .. } | Op::BwdEpAlltoAll { .. } => Some(GroupKind::Ep),
        Op::FusedAlltoAll { .. } | Op::BwdFusedAlltoAll { .. } => Some(GroupKind::EpEsp),
        // SAA/AAS span the product group plus the MP partition, and the
        // wgrad AllReduce carries its own deferred-completion scheduling —
        // both handled separately by the interpreter.
        _ => None,
    }
}

/// Walk `ops` once over `groups`, executing every op through `transport`
/// and `machine`. Returns the final per-rank frontier handles (the layer's
/// completion events on the timing plane).
pub fn run_program<T, M>(
    ops: &[Op],
    groups: &ProcessGroups,
    transport: &mut T,
    machine: &mut M,
) -> Result<Vec<Option<T::Handle>>>
where
    T: Transport,
    M: Machine<T>,
{
    // Debug builds statically verify every program before walking it, so
    // the whole test suite transitively exercises the structural rules of
    // `schedule::verify` (tag/span/frontier discipline; the config-aware
    // volume rules run in the lowering, which knows the config).
    #[cfg(debug_assertions)]
    if let Err(e) = super::verify::check_structure(ops) {
        bail!("malformed op program: {e}");
    }

    let p = groups.par.p;
    let mut frontier: Vec<Option<T::Handle>> = vec![None; p];
    let mut pipe: Option<PipeState<T::Handle>> = None;
    // Forward AlltoAlls seen so far — disambiguates dispatch vs combine
    // for the wire-precision leg (see `wire_leg_of`).
    let mut fwd_a2a_seen = 0usize;
    // Completions of overlap-scheduled collectives (the backward wgrad
    // AllReduce): the ops that follow proceed from the pre-collective
    // frontier, and the deferred handles are joined back in at program
    // end — so the reduction rides under the remaining backward ops.
    let mut deferred: Vec<Vec<T::Handle>> = vec![Vec::new(); p];

    let deps_of = |frontier: &[Option<T::Handle>], ranks: &[usize]| -> Vec<T::Handle> {
        ranks.iter().filter_map(|&r| frontier[r].clone()).collect()
    };

    for op in ops {
        let tag = op.tag();
        if let Some(leg) = wire_leg_of(op, &mut fwd_a2a_seen) {
            transport.set_wire_leg(leg);
        }
        match *op {
            Op::EspSplit { .. } | Op::MpSplit { .. } => {
                // Free on the wire (local view change); the frontier does
                // not move.
                machine.apply_local(op)?;
            }
            Op::Gate { flops_per_rank }
            | Op::ExpertFfn { flops_per_rank }
            | Op::LocalCombine { flops_per_rank }
            | Op::Ungate { flops_per_rank }
            | Op::BwdExpertDgrad { flops_per_rank }
            | Op::BwdExpertWgrad { flops_per_rank } => {
                machine.apply_local(op)?;
                for r in 0..p {
                    let dep: Vec<T::Handle> = frontier[r].iter().cloned().collect();
                    frontier[r] = Some(transport.compute(r, flops_per_rank, &dep, tag));
                }
            }
            Op::SpDispatch { index, of, .. }
            | Op::Sp2Dispatch { index, of, .. }
            | Op::BwdSpDispatch { index, of, .. }
            | Op::BwdSp2Dispatch { index, of, .. } => {
                let st = pipe.get_or_insert_with(|| PipeState::new(&frontier, of));
                ensure!(
                    index < of && st.dispatched.len() == of,
                    "sp dispatch chunk {index} of {of} does not fit the pipelined region"
                );
                for grp in groups.all_groups(GroupKind::EpEsp) {
                    let ins = marshal(machine, transport, op, &grp)?;
                    ensure!(ins.len() == grp.len(), "one chunk list per member");
                    let deps = deps_of(&st.comm, &grp);
                    let (outs, ends) = algo::pairwise_alltoall(transport, &grp, &ins, &deps, tag);
                    machine.accept(op, &grp, outs)?;
                    for (k, &r) in grp.iter().enumerate() {
                        st.comm[r] = Some(ends[k].clone());
                        st.dispatched[index][r] = Some(ends[k].clone());
                    }
                }
                machine.finish(op)?;
            }
            Op::SpExpertFfn { flops_per_rank, index, .. }
            | Op::Sp2ExpertFfn { flops_per_rank, index, .. }
            | Op::BwdSpDgrad { flops_per_rank, index, .. }
            | Op::BwdSp2Dgrad { flops_per_rank, index, .. } => {
                machine.apply_local(op)?;
                let st = pipe
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("sp.ffn outside a pipelined region"))?;
                ensure!(index < st.dispatched.len(), "sp.ffn chunk {index} out of range");
                for r in 0..p {
                    let mut dep: Vec<T::Handle> =
                        st.dispatched[index][r].iter().cloned().collect();
                    dep.extend(st.comp[r].iter().cloned());
                    let h = transport.compute(r, flops_per_rank, &dep, tag);
                    st.ffn[index][r] = Some(h.clone());
                    st.comp[r] = Some(h);
                }
            }
            Op::BwdSpWgrad { flops_per_rank, index, .. }
            | Op::BwdSp2Wgrad { flops_per_rank, index, .. } => {
                // Weight-gradient compute chains the COMPUTE stream only:
                // it does not write the chunk's ffn slot, so the chunk's
                // backward combine (which reads the dgrad completion)
                // overlaps it on the comm stream.
                machine.apply_local(op)?;
                let st = pipe
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("bwd wgrad outside a pipelined region"))?;
                ensure!(index < st.dispatched.len(), "bwd wgrad chunk {index} out of range");
                for r in 0..p {
                    let mut dep: Vec<T::Handle> =
                        st.dispatched[index][r].iter().cloned().collect();
                    dep.extend(st.comp[r].iter().cloned());
                    st.comp[r] = Some(transport.compute(r, flops_per_rank, &dep, tag));
                }
            }
            Op::SpCombine { index, of, .. }
            | Op::BwdSpCombine { index, of, .. }
            | Op::BwdSp2Combine { index, of, .. } => {
                let merge = {
                    let st = pipe
                        .as_mut()
                        .ok_or_else(|| anyhow::anyhow!("sp.combine outside a pipelined region"))?;
                    ensure!(index < st.ffn.len(), "sp.combine chunk {index} out of range");
                    for grp in groups.all_groups(GroupKind::EpEsp) {
                        let ins = marshal(machine, transport, op, &grp)?;
                        ensure!(ins.len() == grp.len(), "one chunk list per member");
                        let mut deps = deps_of(&st.comm, &grp);
                        deps.extend(deps_of(&st.ffn[index], &grp));
                        let (outs, ends) =
                            algo::pairwise_alltoall(transport, &grp, &ins, &deps, tag);
                        machine.accept(op, &grp, outs)?;
                        for (k, &r) in grp.iter().enumerate() {
                            st.comm[r] = Some(ends[k].clone());
                        }
                    }
                    machine.finish(op)?;
                    st.combines_done += 1;
                    st.combines_done == of
                };
                if merge {
                    let st = pipe.take().expect("pipeline state present at merge");
                    merge_region(st, &mut frontier, transport, tag);
                }
            }
            Op::Sp2Saa { index, of, .. } => {
                // A chunk's combine as a chunked SAA: the AlltoAll runs on
                // the comm-stream frontier (after the chunk's FFN), and its
                // phases forward into the MP-AllGather — same dual-stream
                // region as SpCombine, with the second link-class overlap
                // inside each chunk.
                let merge = {
                    let st = pipe
                        .as_mut()
                        .ok_or_else(|| anyhow::anyhow!("sp2.saa outside a pipelined region"))?;
                    ensure!(index < st.ffn.len(), "sp2.saa chunk {index} out of range");
                    let world = groups.world();
                    let mut deps = deps_of(&st.comm, &world);
                    deps.extend(deps_of(&st.ffn[index], &world));
                    let ends = run_saa(op, groups, transport, machine, &deps, true)?;
                    for (k, &r) in world.iter().enumerate() {
                        st.comm[r] = Some(ends[k].clone());
                    }
                    machine.finish(op)?;
                    st.combines_done += 1;
                    st.combines_done == of
                };
                if merge {
                    let st = pipe.take().expect("pipeline state present at merge");
                    merge_region(st, &mut frontier, transport, tag);
                }
            }
            Op::SaaCombine { .. } | Op::AasCombine { .. } => {
                let world = groups.world();
                let deps = deps_of(&frontier, &world);
                let overlap = matches!(*op, Op::SaaCombine { .. });
                let ends = run_saa(op, groups, transport, machine, &deps, overlap)?;
                for (k, &r) in world.iter().enumerate() {
                    frontier[r] = Some(ends[k].clone());
                }
                machine.finish(op)?;
            }
            Op::BwdWgradAllReduce { overlap, .. } => {
                // The expert wgrad AllReduce over each ESP group. With
                // `overlap` the completions are DEFERRED: subsequent ops
                // chain from the pre-AllReduce frontier, so the reduction
                // overlaps the remaining backward ops; the deferred
                // handles join the frontier once the walk finishes.
                // Without it the completions chain the main frontier —
                // the non-overlapped ablation lowering.
                for grp in groups.all_groups(GroupKind::Esp) {
                    let ins = marshal(machine, transport, op, &grp)?;
                    ensure!(ins.len() == grp.len(), "one chunk list per member");
                    let deps = deps_of(&frontier, &grp);
                    let (outs, ends) = algo::ring_allreduce(transport, &grp, &ins, &deps, tag);
                    machine.accept(op, &grp, outs)?;
                    for (k, &r) in grp.iter().enumerate() {
                        if overlap {
                            deferred[r].push(ends[k].clone());
                        } else {
                            frontier[r] = Some(ends[k].clone());
                        }
                    }
                }
                machine.finish(op)?;
            }
            _ => {
                let kind = group_kind(op)
                    .ok_or_else(|| anyhow::anyhow!("op {op:?} has no interpretation"))?;
                for grp in groups.all_groups(kind) {
                    let ins = marshal(machine, transport, op, &grp)?;
                    ensure!(ins.len() == grp.len(), "one chunk list per member");
                    let deps = deps_of(&frontier, &grp);
                    let (outs, ends) = match *op {
                        Op::EspAllGather { .. } | Op::MpAllGather { .. } => {
                            let mut flat = Vec::with_capacity(grp.len());
                            for mut chunks in ins {
                                ensure!(
                                    chunks.len() == 1,
                                    "AllGather takes one chunk per member"
                                );
                                flat.push(chunks.pop().expect("checked non-empty"));
                            }
                            algo::ring_allgather(transport, &grp, &flat, &deps, tag)
                        }
                        Op::EspReduceScatter { .. } | Op::MpReduceScatter { .. } => {
                            let (reduced, ends) =
                                algo::ring_reduce_scatter(transport, &grp, &ins, &deps, tag);
                            (reduced.into_iter().map(|c| vec![c]).collect(), ends)
                        }
                        Op::EspAllReduce { .. } => {
                            algo::ring_allreduce(transport, &grp, &ins, &deps, tag)
                        }
                        Op::EpAlltoAll { .. }
                        | Op::FusedAlltoAll { .. }
                        | Op::BwdEpAlltoAll { .. }
                        | Op::BwdFusedAlltoAll { .. } => {
                            algo::pairwise_alltoall(transport, &grp, &ins, &deps, tag)
                        }
                        _ => bail!("unreachable: {op:?} classified as group collective"),
                    };
                    machine.accept(op, &grp, outs)?;
                    for (k, &r) in grp.iter().enumerate() {
                        frontier[r] = Some(ends[k].clone());
                    }
                }
                machine.finish(op)?;
            }
        }
    }
    ensure!(
        pipe.is_none(),
        "SP pipelined region did not complete (a chunk's combine is missing)"
    );
    // Join any deferred (overlap-scheduled) completions back into the
    // frontier: the program is not done until the wgrad AllReduce is.
    for (r, slot) in frontier.iter_mut().enumerate() {
        if deferred[r].is_empty() {
            continue;
        }
        let mut dep: Vec<T::Handle> = slot.iter().cloned().collect();
        dep.append(&mut deferred[r]);
        *slot = Some(transport.join(&dep, tags::BWD_WGRAD_ALLREDUCE));
    }
    Ok(frontier)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::DataTransport;
    use crate::config::moe::ParallelDegrees;

    /// A machine that feeds fixed-size lumps and counts op dispatches —
    /// enough to pin the interpreter's walking order.
    struct CountingMachine {
        comm_ops: Vec<&'static str>,
        local_ops: Vec<&'static str>,
    }

    impl Machine<DataTransport> for CountingMachine {
        fn inputs(&mut self, op: &Op, grp: &[usize]) -> Result<Vec<Vec<Vec<f32>>>> {
            let per = match op {
                Op::EspAllGather { .. } | Op::MpAllGather { .. } => 1,
                _ => grp.len(),
            };
            // Chunked SP ops honor their byte fields so tests can drive
            // ragged (and zero-width) capacity spans through the region.
            let elems = match op {
                Op::SpDispatch { bytes_per_pair, .. }
                | Op::SpCombine { bytes_per_pair, .. }
                | Op::Sp2Dispatch { bytes_per_pair, .. }
                | Op::Sp2Saa { bytes_per_pair, .. }
                | Op::BwdSpDispatch { bytes_per_pair, .. }
                | Op::BwdSpCombine { bytes_per_pair, .. }
                | Op::BwdSp2Dispatch { bytes_per_pair, .. }
                | Op::BwdSp2Combine { bytes_per_pair, .. } => (*bytes_per_pair / 4.0) as usize,
                _ => 2,
            };
            Ok(vec![vec![vec![1.0f32; elems]; per]; grp.len()])
        }

        fn accept(&mut self, op: &Op, _grp: &[usize], _outputs: Vec<Vec<Vec<f32>>>) -> Result<()> {
            self.comm_ops.push(op.tag());
            Ok(())
        }

        fn apply_local(&mut self, op: &Op) -> Result<()> {
            self.local_ops.push(op.tag());
            Ok(())
        }
    }

    #[test]
    fn interpreter_visits_every_op_once_per_group() {
        let groups = ProcessGroups::new(ParallelDegrees { p: 4, n_mp: 2, n_esp: 2 }).unwrap();
        let ops = vec![
            Op::MpSplit { bytes_per_rank: 0.0 },
            Op::Gate { flops_per_rank: 1.0 },
            Op::EspAllGather { bytes_per_rank: 8.0 },
            Op::FusedAlltoAll { bytes_per_pair: 8.0 },
            Op::SaaCombine { bytes_per_pair: 8.0 },
        ];
        let mut t = DataTransport::new();
        let mut m = CountingMachine { comm_ops: Vec::new(), local_ops: Vec::new() };
        run_program(&ops, &groups, &mut t, &mut m).unwrap();
        assert_eq!(m.local_ops, vec!["mp.split", "gate"]);
        // ESP-AllGather runs once per ESP group (2), the fused AlltoAll and
        // SAA once over the whole world.
        assert_eq!(
            m.comm_ops,
            vec!["esp.allgather", "esp.allgather", "fused.alltoall", "saa.combine"]
        );
        // Wire log covers both the a2a and its overlapped AllGather.
        let tags: Vec<&str> = t.log().iter().map(|(t, _)| *t).collect();
        assert!(tags.contains(&"saa.combine"));
        assert!(tags.contains(&"mp.allgather"));
    }

    #[test]
    fn sp_region_runs_all_chunks_and_merges() {
        let groups = ProcessGroups::new(ParallelDegrees { p: 4, n_mp: 2, n_esp: 2 }).unwrap();
        let ops = vec![
            Op::Gate { flops_per_rank: 1.0 },
            Op::SpDispatch { bytes_per_pair: 8.0, index: 0, of: 2 },
            Op::SpDispatch { bytes_per_pair: 8.0, index: 1, of: 2 },
            Op::SpExpertFfn { flops_per_rank: 1.0, index: 0, of: 2 },
            Op::SpCombine { bytes_per_pair: 8.0, index: 0, of: 2 },
            Op::SpExpertFfn { flops_per_rank: 1.0, index: 1, of: 2 },
            Op::SpCombine { bytes_per_pair: 8.0, index: 1, of: 2 },
            Op::Ungate { flops_per_rank: 1.0 },
        ];
        let mut t = DataTransport::new();
        let mut m = CountingMachine { comm_ops: Vec::new(), local_ops: Vec::new() };
        let frontier = run_program(&ops, &groups, &mut t, &mut m).unwrap();
        assert!(frontier.iter().all(|h| h.is_some()), "region merged back");
        assert_eq!(
            m.comm_ops,
            vec!["sp.dispatch.0", "sp.dispatch.1", "sp.combine.0", "sp.combine.1"]
        );
        assert_eq!(m.local_ops, vec!["gate", "sp.ffn.0", "sp.ffn.1", "ungate"]);
        // Per-chunk wire-log entries, each a full product-group AlltoAll of
        // 8-byte pair chunks over 4 ranks (12 off-diagonal sends).
        let log = t.log().to_vec();
        for tag in ["sp.dispatch.0", "sp.dispatch.1", "sp.combine.0", "sp.combine.1"] {
            let bytes: f64 = log.iter().filter(|(t, _)| *t == tag).map(|(_, b)| *b).sum();
            assert_eq!(bytes, 12.0 * 8.0, "{tag}");
        }
    }

    #[test]
    fn sp_region_supports_ragged_and_empty_chunks() {
        // Load-aware spans make the chunked AlltoAlls unequal — and a
        // capacity clamp can make a tail chunk empty. The interpreter
        // walks both unchanged: per-chunk volumes land under per-chunk
        // tags, and an empty chunk's AlltoAll puts nothing on the wire
        // while the region still merges.
        let groups = ProcessGroups::new(ParallelDegrees { p: 4, n_mp: 2, n_esp: 2 }).unwrap();
        let ops = vec![
            Op::SpDispatch { bytes_per_pair: 8.0, index: 0, of: 3 },
            Op::SpDispatch { bytes_per_pair: 16.0, index: 1, of: 3 },
            Op::SpExpertFfn { flops_per_rank: 1.0, index: 0, of: 3 },
            Op::SpCombine { bytes_per_pair: 8.0, index: 0, of: 3 },
            Op::SpDispatch { bytes_per_pair: 0.0, index: 2, of: 3 },
            Op::SpExpertFfn { flops_per_rank: 1.0, index: 1, of: 3 },
            Op::SpCombine { bytes_per_pair: 16.0, index: 1, of: 3 },
            Op::SpExpertFfn { flops_per_rank: 0.0, index: 2, of: 3 },
            Op::SpCombine { bytes_per_pair: 0.0, index: 2, of: 3 },
        ];
        let mut t = DataTransport::new();
        let mut m = CountingMachine { comm_ops: Vec::new(), local_ops: Vec::new() };
        let frontier = run_program(&ops, &groups, &mut t, &mut m).unwrap();
        assert!(frontier.iter().all(|h| h.is_some()), "region merged back");
        let log = t.log().to_vec();
        let vol = |tag: &str| -> f64 {
            log.iter().filter(|(t, _)| *t == tag).map(|(_, b)| *b).sum()
        };
        // 12 off-diagonal pairs over the 4-rank product group.
        assert_eq!(vol("sp.dispatch.0"), 12.0 * 8.0);
        assert_eq!(vol("sp.dispatch.1"), 12.0 * 16.0);
        assert_eq!(vol("sp.combine.1"), 12.0 * 16.0);
        let tags: Vec<&str> = log.iter().map(|(t, _)| *t).collect();
        assert!(!tags.contains(&"sp.dispatch.2"), "empty chunk on the wire: {tags:?}");
        assert!(!tags.contains(&"sp.combine.2"), "empty combine on the wire: {tags:?}");
    }

    #[test]
    fn sp2_region_runs_chunked_saa_and_merges() {
        // The SP×SAA region: each chunk's combine is a chunked SAA whose
        // MP-AllGather forwards share the canonical mp.allgather tag; the
        // region still merges both streams at the last SAA.
        let groups = ProcessGroups::new(ParallelDegrees { p: 4, n_mp: 2, n_esp: 2 }).unwrap();
        let ops = vec![
            Op::Gate { flops_per_rank: 1.0 },
            Op::Sp2Dispatch { bytes_per_pair: 8.0, index: 0, of: 2 },
            Op::Sp2Dispatch { bytes_per_pair: 16.0, index: 1, of: 2 },
            Op::Sp2ExpertFfn { flops_per_rank: 1.0, index: 0, of: 2 },
            Op::Sp2Saa { bytes_per_pair: 8.0, index: 0, of: 2 },
            Op::Sp2ExpertFfn { flops_per_rank: 1.0, index: 1, of: 2 },
            Op::Sp2Saa { bytes_per_pair: 16.0, index: 1, of: 2 },
            Op::Ungate { flops_per_rank: 1.0 },
        ];
        let mut t = DataTransport::new();
        let mut m = CountingMachine { comm_ops: Vec::new(), local_ops: Vec::new() };
        let frontier = run_program(&ops, &groups, &mut t, &mut m).unwrap();
        assert!(frontier.iter().all(|h| h.is_some()), "region merged back");
        assert_eq!(
            m.comm_ops,
            vec!["sp2.dispatch.0", "sp2.dispatch.1", "sp2.saa.0", "sp2.saa.1"]
        );
        assert_eq!(m.local_ops, vec!["gate", "sp2.ffn.0", "sp2.ffn.1", "ungate"]);
        let log = t.log().to_vec();
        // Per-chunk a2a volume: 12 off-diagonal pairs over the 4-rank
        // product group.
        let vol = |tag: &str| -> f64 {
            log.iter().filter(|(t, _)| *t == tag).map(|(_, b)| *b).sum()
        };
        assert_eq!(vol("sp2.saa.0"), 12.0 * 8.0);
        assert_eq!(vol("sp2.saa.1"), 12.0 * 16.0);
        // The chunked SAAs' MP forwards all land under mp.allgather: each
        // member forwards its 4-chunk AlltoAll output to 1 MP peer, per
        // chunk — 4·4·(2 + 4) f32.
        assert_eq!(vol(tags::MP_ALLGATHER), (4 * 4 * (2 + 4) * 4) as f64);
    }

    #[test]
    fn wgrad_allreduce_runs_on_both_scheduling_paths() {
        // The deferred (overlap) path must still complete the frontier —
        // the program is not done until the reduction is — and the
        // non-overlapped path chains it like any other collective. Either
        // way the AllReduce runs once per ESP group and lands on the wire
        // under its canonical tag.
        let groups = ProcessGroups::new(ParallelDegrees { p: 4, n_mp: 2, n_esp: 2 }).unwrap();
        for overlap in [true, false] {
            let ops = vec![
                Op::Gate { flops_per_rank: 1.0 },
                Op::BwdWgradAllReduce { bytes_per_rank: 8.0, overlap },
                Op::Ungate { flops_per_rank: 1.0 },
            ];
            let mut t = DataTransport::new();
            let mut m = CountingMachine { comm_ops: Vec::new(), local_ops: Vec::new() };
            let frontier = run_program(&ops, &groups, &mut t, &mut m).unwrap();
            assert!(frontier.iter().all(|h| h.is_some()), "overlap={overlap}");
            // One accept per ESP group (two groups of two ranks).
            assert_eq!(
                m.comm_ops,
                vec!["bwd.wgrad.allreduce", "bwd.wgrad.allreduce"],
                "overlap={overlap}"
            );
            let tags: Vec<&str> = t.log().iter().map(|(t, _)| *t).collect();
            assert!(tags.contains(&"bwd.wgrad.allreduce"), "overlap={overlap}");
        }
    }

    #[test]
    fn bwd_sp_region_wgrad_chains_compute_only() {
        // A backward SP region: per chunk, dispatch → dgrad → wgrad →
        // combine. The region must merge even though the wgrads never
        // touch the per-chunk ffn slots, and per-chunk volumes land under
        // the bwd.* tags.
        let groups = ProcessGroups::new(ParallelDegrees { p: 4, n_mp: 2, n_esp: 2 }).unwrap();
        let ops = vec![
            Op::BwdSpDispatch { bytes_per_pair: 8.0, index: 0, of: 2 },
            Op::BwdSpDispatch { bytes_per_pair: 16.0, index: 1, of: 2 },
            Op::BwdSpDgrad { flops_per_rank: 1.0, index: 0, of: 2 },
            Op::BwdSpWgrad { flops_per_rank: 1.0, index: 0, of: 2 },
            Op::BwdSpCombine { bytes_per_pair: 8.0, index: 0, of: 2 },
            Op::BwdSpDgrad { flops_per_rank: 1.0, index: 1, of: 2 },
            Op::BwdSpWgrad { flops_per_rank: 1.0, index: 1, of: 2 },
            Op::BwdSpCombine { bytes_per_pair: 16.0, index: 1, of: 2 },
        ];
        let mut t = DataTransport::new();
        let mut m = CountingMachine { comm_ops: Vec::new(), local_ops: Vec::new() };
        let frontier = run_program(&ops, &groups, &mut t, &mut m).unwrap();
        assert!(frontier.iter().all(|h| h.is_some()), "region merged back");
        assert_eq!(
            m.local_ops,
            vec!["bwd.sp.dgrad.0", "bwd.sp.wgrad.0", "bwd.sp.dgrad.1", "bwd.sp.wgrad.1"]
        );
        let log = t.log().to_vec();
        let vol = |tag: &str| -> f64 {
            log.iter().filter(|(t, _)| *t == tag).map(|(_, b)| *b).sum()
        };
        assert_eq!(vol("bwd.sp.dispatch.1"), 12.0 * 16.0);
        assert_eq!(vol("bwd.sp.combine.0"), 12.0 * 8.0);
    }

    #[test]
    fn sp_region_must_complete() {
        let groups = ProcessGroups::new(ParallelDegrees { p: 4, n_mp: 2, n_esp: 2 }).unwrap();
        let ops = vec![
            Op::SpDispatch { bytes_per_pair: 8.0, index: 0, of: 2 },
            Op::SpExpertFfn { flops_per_rank: 1.0, index: 0, of: 2 },
            Op::SpCombine { bytes_per_pair: 8.0, index: 0, of: 2 },
            // chunk 1 never runs
        ];
        let mut t = DataTransport::new();
        let mut m = CountingMachine { comm_ops: Vec::new(), local_ops: Vec::new() };
        assert!(run_program(&ops, &groups, &mut t, &mut m).is_err());
    }
}
