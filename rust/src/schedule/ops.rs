//! The schedule IR: a MoE layer's execution under one schedule is a short
//! program of [`Op`]s. The same program drives BOTH the discrete-event
//! lowering (timing, [`crate::schedule::lowering`]) and the data-plane
//! executor (numerics, [`crate::moe::exec`]) — so the schedule we time is
//! exactly the schedule whose correctness the tests establish.

use crate::config::MoeLayerConfig;

/// One step of a schedule. Communication sizes are in **bytes** and are
/// per the unit noted on each variant; compute is in FLOPs per rank.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// AllGather within each ESP group; `bytes_per_rank` = each member's
    /// contribution (ring AG).
    EspAllGather { bytes_per_rank: f64 },
    /// AlltoAll within each EP group; `bytes_per_pair` = one (src,dst)
    /// chunk.
    EpAlltoAll { bytes_per_pair: f64 },
    /// AllReduce within each ESP group over `total_bytes` per member.
    EspAllReduce { total_bytes: f64 },
    /// ReduceScatter within each ESP group (backward of ESP-AllGather).
    EspReduceScatter { total_bytes: f64 },
    /// ReduceScatter within each MP group (backward of MP-AllGather).
    MpReduceScatter { total_bytes: f64 },
    /// Local ESP split (free forward; AllGather of `bytes_per_rank` per
    /// member in backward — paper Fig 3 note).
    EspSplit { bytes_per_rank: f64 },
    /// Local MP split (free forward; AllGather in backward).
    MpSplit { bytes_per_rank: f64 },
    /// AllGather within each MP group; `bytes_per_rank` = contribution.
    MpAllGather { bytes_per_rank: f64 },
    /// Parm's fused EP&ESP-AlltoAll over the whole layer (product group);
    /// includes the local Dump (free) before / local Combine cost after is
    /// a separate op.
    FusedAlltoAll { bytes_per_pair: f64 },
    /// S2's overlapped combine: fused AlltoAll + MP-AllGather via SAA.
    SaaCombine { bytes_per_pair: f64 },
    /// Non-overlapped variant of [`Op::SaaCombine`] (AAS ablation).
    AasCombine { bytes_per_pair: f64 },
    /// Gating network + top-k routing.
    Gate { flops_per_rank: f64 },
    /// Expert FFN shards.
    ExpertFfn { flops_per_rank: f64 },
    /// Local partial-sum combine of N_ESP returned copies (PauseMP path).
    LocalCombine { flops_per_rank: f64 },
    /// Scatter combined outputs back into token order (un-gate).
    Ungate { flops_per_rank: f64 },
    /// SP dispatch: chunk `index` of `of` of the fused EP&ESP-AlltoAll,
    /// restricted to one capacity span (see [`chunk_spans`]). Chunked ops
    /// run on a dedicated per-rank comm stream so later dispatch chunks
    /// overlap earlier chunks' expert compute.
    SpDispatch { bytes_per_pair: f64, index: usize, of: usize },
    /// SP expert FFN over chunk `index`'s received capacity span; chains
    /// on the per-rank compute stream, concurrent with the comm stream.
    SpExpertFfn { flops_per_rank: f64, index: usize, of: usize },
    /// SP combine: chunk `index`'s expert outputs returned through the
    /// fused AlltoAll, overlapping chunk `index+1`'s compute. The last
    /// combine of the region joins the comm and compute streams back into
    /// the main frontier.
    SpCombine { bytes_per_pair: f64, index: usize, of: usize },
}

impl Op {
    /// Canonical tag for trace/report/comm-log accounting — the constants
    /// of [`crate::comm::tags`], shared verbatim by the simulator's
    /// per-tag accounting and the data plane's wire log.
    pub fn tag(&self) -> &'static str {
        use crate::comm::tags;
        match self {
            Op::EspAllGather { .. } => tags::ESP_ALLGATHER,
            Op::EpAlltoAll { .. } => tags::EP_ALLTOALL,
            Op::EspAllReduce { .. } => tags::ESP_ALLREDUCE,
            Op::EspReduceScatter { .. } => tags::ESP_REDUCESCATTER,
            Op::MpReduceScatter { .. } => tags::MP_REDUCESCATTER,
            Op::EspSplit { .. } => tags::ESP_SPLIT,
            Op::MpSplit { .. } => tags::MP_SPLIT,
            Op::MpAllGather { .. } => tags::MP_ALLGATHER,
            Op::FusedAlltoAll { .. } => tags::FUSED_ALLTOALL,
            Op::SaaCombine { .. } => tags::SAA_COMBINE,
            Op::AasCombine { .. } => tags::AAS_COMBINE,
            Op::Gate { .. } => tags::GATE,
            Op::ExpertFfn { .. } => tags::EXPERT_FFN,
            Op::LocalCombine { .. } => tags::LOCAL_COMBINE,
            Op::Ungate { .. } => tags::UNGATE,
            // Direct indexing: an index past SP_MAX_CHUNKS is an invariant
            // violation (builders clamp via `sp_clamp_chunks`) — panic at
            // the source rather than aliasing chunks in the wire log.
            Op::SpDispatch { index, .. } => tags::SP_DISPATCH[*index],
            Op::SpExpertFfn { index, .. } => tags::SP_FFN[*index],
            Op::SpCombine { index, .. } => tags::SP_COMBINE[*index],
        }
    }

    pub fn is_communication(&self) -> bool {
        matches!(
            self,
            Op::EspAllGather { .. }
                | Op::EpAlltoAll { .. }
                | Op::EspAllReduce { .. }
                | Op::EspReduceScatter { .. }
                | Op::MpReduceScatter { .. }
                | Op::MpAllGather { .. }
                | Op::FusedAlltoAll { .. }
                | Op::SaaCombine { .. }
                | Op::AasCombine { .. }
                | Op::SpDispatch { .. }
                | Op::SpCombine { .. }
        )
    }
}

/// Which schedule to run (paper Fig 3 + §V).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    /// DeepSpeed-MoE's default schedule (Fig 3a).
    Baseline,
    /// PauseMP before the gate (Fig 3b).
    S1,
    /// PauseMP after the gate, SAA-overlapped combine (Fig 3c).
    S2,
    /// S2 without SAA (sequential AlltoAll + AllGather) — §VI-C ablation.
    S2Aas,
    /// Chunk-pipelined dispatch/compute/combine (SP): S1's op structure
    /// with the fused AlltoAlls and the expert FFN split into `chunks`
    /// capacity chunks so chunk k's combine overlaps chunk k+1's compute
    /// (FSMoE-style intra-layer pipelining). `chunks == 0` is the
    /// unresolved "auto" form — resolve r* via
    /// [`crate::perfmodel::closedform::optimal_chunks`] or the fitted
    /// prediction first.
    Pipelined { chunks: usize },
    /// Automatic selection among S1, S2 and SP(r*) (Algorithm 1,
    /// generalized).
    Parm,
}

impl ScheduleKind {
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Baseline => "baseline",
            ScheduleKind::S1 => "s1",
            ScheduleKind::S2 => "s2",
            ScheduleKind::S2Aas => "s2-aas",
            ScheduleKind::Pipelined { .. } => "sp",
            ScheduleKind::Parm => "parm",
        }
    }

    /// Human-readable form carrying the schedule family's parameter.
    pub fn label(&self) -> String {
        match self {
            ScheduleKind::Pipelined { chunks } if *chunks > 0 => format!("sp(r={chunks})"),
            k => k.name().to_string(),
        }
    }

    pub fn parse(s: &str) -> Option<ScheduleKind> {
        match s {
            "baseline" | "deepspeed" => Some(ScheduleKind::Baseline),
            "s1" => Some(ScheduleKind::S1),
            "s2" => Some(ScheduleKind::S2),
            "s2-aas" | "aas" => Some(ScheduleKind::S2Aas),
            "sp" | "pipelined" => Some(ScheduleKind::Pipelined { chunks: 0 }),
            "parm" | "auto" => Some(ScheduleKind::Parm),
            _ => s
                .strip_prefix("sp")
                .and_then(|n| n.parse::<usize>().ok())
                .map(|chunks| ScheduleKind::Pipelined { chunks }),
        }
    }
}

// ---- communication volumes (bytes), shared by schedule builders and the
// ---- α-β predictions so both sides use identical sizes -----------------

/// Baseline ESP-AllGather: each rank contributes its (B,L,M) input.
pub fn bytes_esp_ag_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.input_elems() * c.dtype_bytes) as f64
}

/// Baseline EP-AlltoAll per-pair chunk: experts-per-slot × gathered
/// capacity (T·N_ESP) × M.
pub fn bytes_ep_a2a_per_pair(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * c.t() * c.par.n_esp * c.m * c.dtype_bytes) as f64
}

/// Baseline ESP-AllReduce total per member: local experts × tokens-per-
/// expert (T·P, one T per source rank in the EP group ⇒ T·N_ESP·N_EP) × M.
pub fn bytes_esp_ar_total(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * c.t() * c.par.p * c.m * c.dtype_bytes) as f64
}

/// PauseMP fused EP&ESP-AlltoAll per-pair chunk (S1/S2): experts-per-slot ×
/// split capacity (T/N_MP) × M. Per-rank total = ETM·N_ESP/N_MP — the
/// paper's Eq. (13)/(14) argument.
pub fn bytes_fused_a2a_per_pair(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * c.t_pausemp() * c.m * c.dtype_bytes) as f64
}

/// S1's final MP-AllGather contribution per rank: the 1/N_MP token slice.
pub fn bytes_mp_ag_s1_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.input_elems() / c.par.n_mp * c.dtype_bytes) as f64
}

/// S2's final MP-AllGather contribution per rank: the 1/N_MP capacity
/// slice (E, T/N_MP, M) — the AG_MP(ETM) of Eq. (14).
pub fn bytes_mp_ag_s2_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.e * c.t_pausemp() * c.m * c.dtype_bytes) as f64
}

// ---- SP chunking (capacity spans shared by builder and data plane) -----

/// Split `cap` capacity rows into exactly `chunks` contiguous spans of
/// `(start, rows)` whose sizes differ by at most one row (the first
/// `cap % chunks` spans are one longer; tail spans are empty when
/// `cap < chunks`). The SAME split is applied to the builder's capacity
/// estimate `T` and to the data plane's actual gate capacity, so per-chunk
/// volumes agree wherever the capacity estimate is exact.
pub fn chunk_spans(cap: usize, chunks: usize) -> Vec<(usize, usize)> {
    let r = chunks.max(1);
    let base = cap / r;
    let rem = cap % r;
    let mut out = Vec::with_capacity(r);
    let mut start = 0;
    for j in 0..r {
        let len = base + usize::from(j < rem);
        out.push((start, len));
        start += len;
    }
    out
}

/// Clamp an SP chunk count to the representable range: at least 1, at most
/// [`crate::comm::tags::SP_MAX_CHUNKS`], and at most one chunk per
/// capacity row so no chunk is empty.
pub fn sp_clamp_chunks(c: &MoeLayerConfig, chunks: usize) -> usize {
    chunks
        .clamp(1, crate::comm::tags::SP_MAX_CHUNKS)
        .min(c.t_pausemp().max(1))
}

/// SP per-chunk fused-AlltoAll pair chunk: experts-per-slot × span rows ×
/// M (the [`bytes_fused_a2a_per_pair`] volume restricted to one span).
pub fn bytes_sp_chunk_per_pair(c: &MoeLayerConfig, span_rows: usize) -> f64 {
    (c.experts_per_rank() * span_rows * c.m * c.dtype_bytes) as f64
}

/// SP per-chunk expert FLOPs per rank: the PauseMP FFN restricted to one
/// capacity span (experts-per-slot × span rows × P source blocks).
pub fn sp_chunk_flops(c: &MoeLayerConfig, span_rows: usize) -> f64 {
    expert_flops(c, (c.experts_per_rank() * span_rows * c.par.p) as f64)
}

// ---- compute volumes (FLOPs per rank) ----------------------------------

/// Gate FLOPs: tokens × M × E MACs (×2), on however many tokens this
/// schedule gates per rank.
pub fn gate_flops(c: &MoeLayerConfig, tokens: usize) -> f64 {
    2.0 * tokens as f64 * (c.m * c.e) as f64
}

/// Expert FLOPs per rank: two matmuls over the local H-shard, for
/// `tokens_per_rank` tokens routed to this rank.
pub fn expert_flops(c: &MoeLayerConfig, tokens_per_rank: f64) -> f64 {
    tokens_per_rank * 2.0 * 2.0 * (c.m * (c.h / c.par.n_esp)) as f64
}

/// Tokens each rank's expert shards process per step. Baseline duplicates
/// the work N_MP times (`pause_mp = false`).
pub fn expert_tokens_per_rank(c: &MoeLayerConfig, pause_mp: bool) -> f64 {
    let t = if pause_mp { c.t_pausemp() } else { c.t() * c.par.n_esp } as f64;
    // Each rank hosts E/N_EP expert slots and receives `t` tokens per
    // expert from each source in its dispatch group (EP group for the
    // baseline, the whole world for PauseMP).
    let sources = if pause_mp { c.par.p } else { c.par.n_ep() } as f64;
    c.experts_per_rank() as f64 * t * sources
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig::test_default()
    }

    #[test]
    fn pausemp_reduces_a2a_volume_by_nmp() {
        let c = cfg();
        let baseline_total = bytes_ep_a2a_per_pair(&c) * c.par.n_ep() as f64;
        let fused_total = bytes_fused_a2a_per_pair(&c) * c.par.p as f64;
        // ETM·N_ESP vs ETM·N_ESP/N_MP (up to capacity rounding).
        let ratio = baseline_total / fused_total;
        assert!(
            (ratio - c.par.n_mp as f64).abs() / (c.par.n_mp as f64) < 0.05,
            "ratio {ratio} ≈ n_mp {}",
            c.par.n_mp
        );
    }

    #[test]
    fn pausemp_reduces_expert_tokens_by_nmp() {
        let c = cfg();
        let dup = expert_tokens_per_rank(&c, false);
        let dedup = expert_tokens_per_rank(&c, true);
        let ratio = dup / dedup;
        assert!((ratio - c.par.n_mp as f64).abs() / (c.par.n_mp as f64) < 0.05);
    }

    #[test]
    fn s2_ag_scales_with_capacity_s1_with_tokens() {
        let mut c = cfg();
        let s1_before = bytes_mp_ag_s1_per_rank(&c);
        let s2_before = bytes_mp_ag_s2_per_rank(&c);
        c.f *= 2.0; // double capacity factor → T doubles
        assert_eq!(bytes_mp_ag_s1_per_rank(&c), s1_before);
        assert!(bytes_mp_ag_s2_per_rank(&c) > 1.9 * s2_before);
    }

    #[test]
    fn schedule_kind_parse() {
        assert_eq!(ScheduleKind::parse("parm"), Some(ScheduleKind::Parm));
        assert_eq!(ScheduleKind::parse("deepspeed"), Some(ScheduleKind::Baseline));
        assert_eq!(ScheduleKind::parse("nope"), None);
        for k in [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::S2Aas,
            ScheduleKind::Pipelined { chunks: 0 },
            ScheduleKind::Parm,
        ] {
            assert_eq!(ScheduleKind::parse(k.name()), Some(k));
        }
        // The parameterized family: `spN` pins the chunk count.
        assert_eq!(
            ScheduleKind::parse("sp4"),
            Some(ScheduleKind::Pipelined { chunks: 4 })
        );
        assert_eq!(ScheduleKind::parse("spx"), None);
        assert_eq!(ScheduleKind::Pipelined { chunks: 4 }.label(), "sp(r=4)");
        assert_eq!(ScheduleKind::S1.label(), "s1");
    }

    #[test]
    fn op_tags_and_comm_flags() {
        assert!(Op::FusedAlltoAll { bytes_per_pair: 1.0 }.is_communication());
        assert!(!Op::Gate { flops_per_rank: 1.0 }.is_communication());
        assert_eq!(Op::MpSplit { bytes_per_rank: 0.0 }.tag(), "mp.split");
        assert!(Op::SpDispatch { bytes_per_pair: 1.0, index: 0, of: 2 }.is_communication());
        assert!(Op::SpCombine { bytes_per_pair: 1.0, index: 1, of: 2 }.is_communication());
        assert!(!Op::SpExpertFfn { flops_per_rank: 1.0, index: 0, of: 2 }.is_communication());
        assert_eq!(
            Op::SpDispatch { bytes_per_pair: 1.0, index: 1, of: 4 }.tag(),
            "sp.dispatch.1"
        );
        assert_eq!(
            Op::SpCombine { bytes_per_pair: 1.0, index: 3, of: 4 }.tag(),
            "sp.combine.3"
        );
    }

    #[test]
    fn chunk_spans_partition_exactly() {
        // Even split.
        assert_eq!(chunk_spans(8, 4), vec![(0, 2), (2, 2), (4, 2), (6, 2)]);
        // Ragged: first `cap % r` spans are one longer.
        assert_eq!(chunk_spans(7, 3), vec![(0, 3), (3, 2), (5, 2)]);
        // Degenerate: more chunks than rows ⇒ empty tails, still `chunks`
        // spans so op counts and span counts agree.
        assert_eq!(chunk_spans(2, 4), vec![(0, 1), (1, 1), (2, 0), (2, 0)]);
        // Spans always tile [0, cap).
        for (cap, r) in [(17usize, 5usize), (64, 8), (3, 3), (1, 1)] {
            let spans = chunk_spans(cap, r);
            assert_eq!(spans.len(), r);
            assert_eq!(spans.iter().map(|s| s.1).sum::<usize>(), cap);
            let mut pos = 0;
            for (start, len) in spans {
                assert_eq!(start, pos);
                pos += len;
            }
        }
    }

    #[test]
    fn sp_chunk_volumes_sum_to_fused_totals() {
        let c = cfg();
        let t = c.t_pausemp();
        for r in [1usize, 2, 3, 4] {
            let spans = chunk_spans(t, r);
            let bytes: f64 = spans.iter().map(|s| bytes_sp_chunk_per_pair(&c, s.1)).sum();
            assert!((bytes - bytes_fused_a2a_per_pair(&c)).abs() < 1e-9, "r={r}");
            let flops: f64 = spans.iter().map(|s| sp_chunk_flops(&c, s.1)).sum();
            let full = expert_flops(&c, expert_tokens_per_rank(&c, true));
            assert!((flops - full).abs() / full < 1e-12, "r={r}");
        }
        assert_eq!(sp_clamp_chunks(&c, 0), 1);
        assert_eq!(sp_clamp_chunks(&c, 100), crate::comm::tags::SP_MAX_CHUNKS);
    }
}
