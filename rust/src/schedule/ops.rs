//! The schedule IR: a MoE layer's execution under one schedule is a short
//! program of [`Op`]s. The same program drives BOTH the discrete-event
//! lowering (timing, [`crate::schedule::lowering`]) and the data-plane
//! executor (numerics, [`crate::moe::exec`]) — so the schedule we time is
//! exactly the schedule whose correctness the tests establish.

use crate::config::MoeLayerConfig;

/// One step of a schedule. Communication sizes are in **bytes** and are
/// per the unit noted on each variant; compute is in FLOPs per rank.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// AllGather within each ESP group; `bytes_per_rank` = each member's
    /// contribution (ring AG).
    EspAllGather { bytes_per_rank: f64 },
    /// AlltoAll within each EP group; `bytes_per_pair` = one (src,dst)
    /// chunk.
    EpAlltoAll { bytes_per_pair: f64 },
    /// AllReduce within each ESP group over `total_bytes` per member.
    EspAllReduce { total_bytes: f64 },
    /// ReduceScatter within each ESP group (backward of ESP-AllGather).
    EspReduceScatter { total_bytes: f64 },
    /// ReduceScatter within each MP group (backward of MP-AllGather).
    MpReduceScatter { total_bytes: f64 },
    /// Local ESP split (free forward; AllGather of `bytes_per_rank` per
    /// member in backward — paper Fig 3 note).
    EspSplit { bytes_per_rank: f64 },
    /// Local MP split (free forward; AllGather in backward).
    MpSplit { bytes_per_rank: f64 },
    /// AllGather within each MP group; `bytes_per_rank` = contribution.
    MpAllGather { bytes_per_rank: f64 },
    /// Parm's fused EP&ESP-AlltoAll over the whole layer (product group);
    /// includes the local Dump (free) before / local Combine cost after is
    /// a separate op.
    FusedAlltoAll { bytes_per_pair: f64 },
    /// S2's overlapped combine: fused AlltoAll + MP-AllGather via SAA.
    SaaCombine { bytes_per_pair: f64 },
    /// Non-overlapped variant of [`Op::SaaCombine`] (AAS ablation).
    AasCombine { bytes_per_pair: f64 },
    /// Gating network + top-k routing.
    Gate { flops_per_rank: f64 },
    /// Expert FFN shards.
    ExpertFfn { flops_per_rank: f64 },
    /// Local partial-sum combine of N_ESP returned copies (PauseMP path).
    LocalCombine { flops_per_rank: f64 },
    /// Scatter combined outputs back into token order (un-gate).
    Ungate { flops_per_rank: f64 },
}

impl Op {
    /// Canonical tag for trace/report/comm-log accounting — the constants
    /// of [`crate::comm::tags`], shared verbatim by the simulator's
    /// per-tag accounting and the data plane's wire log.
    pub fn tag(&self) -> &'static str {
        use crate::comm::tags;
        match self {
            Op::EspAllGather { .. } => tags::ESP_ALLGATHER,
            Op::EpAlltoAll { .. } => tags::EP_ALLTOALL,
            Op::EspAllReduce { .. } => tags::ESP_ALLREDUCE,
            Op::EspReduceScatter { .. } => tags::ESP_REDUCESCATTER,
            Op::MpReduceScatter { .. } => tags::MP_REDUCESCATTER,
            Op::EspSplit { .. } => tags::ESP_SPLIT,
            Op::MpSplit { .. } => tags::MP_SPLIT,
            Op::MpAllGather { .. } => tags::MP_ALLGATHER,
            Op::FusedAlltoAll { .. } => tags::FUSED_ALLTOALL,
            Op::SaaCombine { .. } => tags::SAA_COMBINE,
            Op::AasCombine { .. } => tags::AAS_COMBINE,
            Op::Gate { .. } => tags::GATE,
            Op::ExpertFfn { .. } => tags::EXPERT_FFN,
            Op::LocalCombine { .. } => tags::LOCAL_COMBINE,
            Op::Ungate { .. } => tags::UNGATE,
        }
    }

    pub fn is_communication(&self) -> bool {
        matches!(
            self,
            Op::EspAllGather { .. }
                | Op::EpAlltoAll { .. }
                | Op::EspAllReduce { .. }
                | Op::EspReduceScatter { .. }
                | Op::MpReduceScatter { .. }
                | Op::MpAllGather { .. }
                | Op::FusedAlltoAll { .. }
                | Op::SaaCombine { .. }
                | Op::AasCombine { .. }
        )
    }
}

/// Which schedule to run (paper Fig 3 + §V).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    /// DeepSpeed-MoE's default schedule (Fig 3a).
    Baseline,
    /// PauseMP before the gate (Fig 3b).
    S1,
    /// PauseMP after the gate, SAA-overlapped combine (Fig 3c).
    S2,
    /// S2 without SAA (sequential AlltoAll + AllGather) — §VI-C ablation.
    S2Aas,
    /// Automatic selection between S1 and S2 (Algorithm 1).
    Parm,
}

impl ScheduleKind {
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Baseline => "baseline",
            ScheduleKind::S1 => "s1",
            ScheduleKind::S2 => "s2",
            ScheduleKind::S2Aas => "s2-aas",
            ScheduleKind::Parm => "parm",
        }
    }

    pub fn parse(s: &str) -> Option<ScheduleKind> {
        match s {
            "baseline" | "deepspeed" => Some(ScheduleKind::Baseline),
            "s1" => Some(ScheduleKind::S1),
            "s2" => Some(ScheduleKind::S2),
            "s2-aas" | "aas" => Some(ScheduleKind::S2Aas),
            "parm" | "auto" => Some(ScheduleKind::Parm),
            _ => None,
        }
    }
}

// ---- communication volumes (bytes), shared by schedule builders and the
// ---- α-β predictions so both sides use identical sizes -----------------

/// Baseline ESP-AllGather: each rank contributes its (B,L,M) input.
pub fn bytes_esp_ag_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.input_elems() * c.dtype_bytes) as f64
}

/// Baseline EP-AlltoAll per-pair chunk: experts-per-slot × gathered
/// capacity (T·N_ESP) × M.
pub fn bytes_ep_a2a_per_pair(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * c.t() * c.par.n_esp * c.m * c.dtype_bytes) as f64
}

/// Baseline ESP-AllReduce total per member: local experts × tokens-per-
/// expert (T·P, one T per source rank in the EP group ⇒ T·N_ESP·N_EP) × M.
pub fn bytes_esp_ar_total(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * c.t() * c.par.p * c.m * c.dtype_bytes) as f64
}

/// PauseMP fused EP&ESP-AlltoAll per-pair chunk (S1/S2): experts-per-slot ×
/// split capacity (T/N_MP) × M. Per-rank total = ETM·N_ESP/N_MP — the
/// paper's Eq. (13)/(14) argument.
pub fn bytes_fused_a2a_per_pair(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * c.t_pausemp() * c.m * c.dtype_bytes) as f64
}

/// S1's final MP-AllGather contribution per rank: the 1/N_MP token slice.
pub fn bytes_mp_ag_s1_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.input_elems() / c.par.n_mp * c.dtype_bytes) as f64
}

/// S2's final MP-AllGather contribution per rank: the 1/N_MP capacity
/// slice (E, T/N_MP, M) — the AG_MP(ETM) of Eq. (14).
pub fn bytes_mp_ag_s2_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.e * c.t_pausemp() * c.m * c.dtype_bytes) as f64
}

// ---- compute volumes (FLOPs per rank) ----------------------------------

/// Gate FLOPs: tokens × M × E MACs (×2), on however many tokens this
/// schedule gates per rank.
pub fn gate_flops(c: &MoeLayerConfig, tokens: usize) -> f64 {
    2.0 * tokens as f64 * (c.m * c.e) as f64
}

/// Expert FLOPs per rank: two matmuls over the local H-shard, for
/// `tokens_per_rank` tokens routed to this rank.
pub fn expert_flops(c: &MoeLayerConfig, tokens_per_rank: f64) -> f64 {
    tokens_per_rank * 2.0 * 2.0 * (c.m * (c.h / c.par.n_esp)) as f64
}

/// Tokens each rank's expert shards process per step. Baseline duplicates
/// the work N_MP times (`pause_mp = false`).
pub fn expert_tokens_per_rank(c: &MoeLayerConfig, pause_mp: bool) -> f64 {
    let t = if pause_mp { c.t_pausemp() } else { c.t() * c.par.n_esp } as f64;
    // Each rank hosts E/N_EP expert slots and receives `t` tokens per
    // expert from each source in its dispatch group (EP group for the
    // baseline, the whole world for PauseMP).
    let sources = if pause_mp { c.par.p } else { c.par.n_ep() } as f64;
    c.experts_per_rank() as f64 * t * sources
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig::test_default()
    }

    #[test]
    fn pausemp_reduces_a2a_volume_by_nmp() {
        let c = cfg();
        let baseline_total = bytes_ep_a2a_per_pair(&c) * c.par.n_ep() as f64;
        let fused_total = bytes_fused_a2a_per_pair(&c) * c.par.p as f64;
        // ETM·N_ESP vs ETM·N_ESP/N_MP (up to capacity rounding).
        let ratio = baseline_total / fused_total;
        assert!(
            (ratio - c.par.n_mp as f64).abs() / (c.par.n_mp as f64) < 0.05,
            "ratio {ratio} ≈ n_mp {}",
            c.par.n_mp
        );
    }

    #[test]
    fn pausemp_reduces_expert_tokens_by_nmp() {
        let c = cfg();
        let dup = expert_tokens_per_rank(&c, false);
        let dedup = expert_tokens_per_rank(&c, true);
        let ratio = dup / dedup;
        assert!((ratio - c.par.n_mp as f64).abs() / (c.par.n_mp as f64) < 0.05);
    }

    #[test]
    fn s2_ag_scales_with_capacity_s1_with_tokens() {
        let mut c = cfg();
        let s1_before = bytes_mp_ag_s1_per_rank(&c);
        let s2_before = bytes_mp_ag_s2_per_rank(&c);
        c.f *= 2.0; // double capacity factor → T doubles
        assert_eq!(bytes_mp_ag_s1_per_rank(&c), s1_before);
        assert!(bytes_mp_ag_s2_per_rank(&c) > 1.9 * s2_before);
    }

    #[test]
    fn schedule_kind_parse() {
        assert_eq!(ScheduleKind::parse("parm"), Some(ScheduleKind::Parm));
        assert_eq!(ScheduleKind::parse("deepspeed"), Some(ScheduleKind::Baseline));
        assert_eq!(ScheduleKind::parse("nope"), None);
        for k in [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::S2Aas,
            ScheduleKind::Parm,
        ] {
            assert_eq!(ScheduleKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn op_tags_and_comm_flags() {
        assert!(Op::FusedAlltoAll { bytes_per_pair: 1.0 }.is_communication());
        assert!(!Op::Gate { flops_per_rank: 1.0 }.is_communication());
        assert_eq!(Op::MpSplit { bytes_per_rank: 0.0 }.tag(), "mp.split");
    }
}
