//! The schedule IR: a MoE layer's execution under one schedule is a short
//! program of [`Op`]s. The same program drives BOTH the discrete-event
//! lowering (timing, [`crate::schedule::lowering`]) and the data-plane
//! executor (numerics, [`crate::moe::exec`]) — so the schedule we time is
//! exactly the schedule whose correctness the tests establish.

use crate::config::{MoeLayerConfig, WireLeg};

/// One step of a schedule. Communication sizes are in **bytes** and are
/// per the unit noted on each variant; compute is in FLOPs per rank.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// AllGather within each ESP group; `bytes_per_rank` = each member's
    /// contribution (ring AG).
    EspAllGather { bytes_per_rank: f64 },
    /// AlltoAll within each EP group; `bytes_per_pair` = one (src,dst)
    /// chunk.
    EpAlltoAll { bytes_per_pair: f64 },
    /// AllReduce within each ESP group over `total_bytes` per member.
    EspAllReduce { total_bytes: f64 },
    /// ReduceScatter within each ESP group (backward of ESP-AllGather).
    EspReduceScatter { total_bytes: f64 },
    /// ReduceScatter within each MP group (backward of MP-AllGather).
    MpReduceScatter { total_bytes: f64 },
    /// Local ESP split (free forward; AllGather of `bytes_per_rank` per
    /// member in backward — paper Fig 3 note).
    EspSplit { bytes_per_rank: f64 },
    /// Local MP split (free forward; AllGather in backward).
    MpSplit { bytes_per_rank: f64 },
    /// AllGather within each MP group; `bytes_per_rank` = contribution.
    MpAllGather { bytes_per_rank: f64 },
    /// Parm's fused EP&ESP-AlltoAll over the whole layer (product group);
    /// includes the local Dump (free) before / local Combine cost after is
    /// a separate op.
    FusedAlltoAll { bytes_per_pair: f64 },
    /// S2's overlapped combine: fused AlltoAll + MP-AllGather via SAA.
    SaaCombine { bytes_per_pair: f64 },
    /// Non-overlapped variant of [`Op::SaaCombine`] (AAS ablation).
    AasCombine { bytes_per_pair: f64 },
    /// Gating network + top-k routing.
    Gate { flops_per_rank: f64 },
    /// Expert FFN shards.
    ExpertFfn { flops_per_rank: f64 },
    /// Local partial-sum combine of N_ESP returned copies (PauseMP path).
    LocalCombine { flops_per_rank: f64 },
    /// Scatter combined outputs back into token order (un-gate).
    Ungate { flops_per_rank: f64 },
    /// SP dispatch: chunk `index` of `of` of the fused EP&ESP-AlltoAll,
    /// restricted to one capacity span (see [`chunk_spans`]). Chunked ops
    /// run on a dedicated per-rank comm stream so later dispatch chunks
    /// overlap earlier chunks' expert compute.
    SpDispatch { bytes_per_pair: f64, index: usize, of: usize },
    /// SP expert FFN over chunk `index`'s received capacity span; chains
    /// on the per-rank compute stream, concurrent with the comm stream.
    SpExpertFfn { flops_per_rank: f64, index: usize, of: usize },
    /// SP combine: chunk `index`'s expert outputs returned through the
    /// fused AlltoAll, overlapping chunk `index+1`'s compute. The last
    /// combine of the region joins the comm and compute streams back into
    /// the main frontier.
    SpCombine { bytes_per_pair: f64, index: usize, of: usize },
    /// SP2 dispatch: chunk `index` of `of` of S2's capacity-split fused
    /// EP&ESP-AlltoAll, restricted to one capacity span — the pipelined-S2
    /// (SP × SAA) region's comm-stream dispatch.
    Sp2Dispatch { bytes_per_pair: f64, index: usize, of: usize },
    /// SP2 expert FFN over chunk `index`'s received capacity span; chains
    /// on the per-rank compute stream like [`Op::SpExpertFfn`].
    Sp2ExpertFfn { flops_per_rank: f64, index: usize, of: usize },
    /// SP2 combine: chunk `index`'s expert outputs returned through a
    /// *chunked SAA* — the chunk's combine AlltoAll phases forward into
    /// the MP-AllGather on the second link class while chunk `index+1`'s
    /// FFN computes, composing SP's compute/comm overlap with S2's
    /// intra/inter link-class overlap. The last SAA of the region joins
    /// the comm and compute streams back into the main frontier.
    Sp2Saa { bytes_per_pair: f64, index: usize, of: usize },
    /// Backward EP-group AlltoAll (baseline family): `combine == false` is
    /// the backward *dispatch* (transpose of the forward combine AlltoAll,
    /// carrying dY to the experts), `combine == true` the backward
    /// *combine* (transpose of the forward dispatch, returning dX). Same
    /// per-pair volume as the forward counterpart it transposes.
    BwdEpAlltoAll { bytes_per_pair: f64, combine: bool },
    /// Backward fused EP&ESP-AlltoAll (PauseMP families) — transposition
    /// semantics as [`Op::BwdEpAlltoAll`], over the product group.
    BwdFusedAlltoAll { bytes_per_pair: f64, combine: bool },
    /// Expert FFN activation gradient (dgrad): dL/dX through both expert
    /// matmuls — same FLOPs as the forward FFN it differentiates.
    BwdExpertDgrad { flops_per_rank: f64 },
    /// Expert FFN weight gradient (wgrad): dL/dW through both expert
    /// matmuls — same FLOPs as the forward FFN. Produces the gradients
    /// the wgrad AllReduce synchronizes.
    BwdExpertWgrad { flops_per_rank: f64 },
    /// ESP-group AllReduce of the expert weight gradients
    /// (`bytes_per_rank` = each member's wgrad buffer). With
    /// `overlap == true` the interpreter defers its completion to the end
    /// of the program so the reduction rides the comm stream under the
    /// remaining backward ops; `overlap == false` chains it on the main
    /// frontier (the non-overlapped ablation lowering).
    BwdWgradAllReduce { bytes_per_rank: f64, overlap: bool },
    /// Backward SP dispatch: transpose of forward `sp.combine.index`,
    /// carrying chunk `index`'s dY — chains on the region's comm stream
    /// exactly like [`Op::SpDispatch`].
    BwdSpDispatch { bytes_per_pair: f64, index: usize, of: usize },
    /// Backward SP dgrad over chunk `index`: compute-stream FFN gradient
    /// whose completion feeds that chunk's backward combine.
    BwdSpDgrad { flops_per_rank: f64, index: usize, of: usize },
    /// Backward SP wgrad over chunk `index`: chains the compute stream
    /// ONLY — the chunk's backward combine does not wait on it, so the
    /// combine AlltoAll overlaps the weight-gradient compute.
    BwdSpWgrad { flops_per_rank: f64, index: usize, of: usize },
    /// Backward SP combine: transpose of forward `sp.dispatch.index`,
    /// returning chunk `index`'s dX; the region's last combine joins the
    /// comm and compute streams like [`Op::SpCombine`].
    BwdSpCombine { bytes_per_pair: f64, index: usize, of: usize },
    /// Backward SP2 dispatch: transpose of the AlltoAll phase of forward
    /// `sp2.saa.index` (the SAA's MP-AllGather adjoint runs once up front
    /// as an MP-ReduceScatter, not per chunk).
    BwdSp2Dispatch { bytes_per_pair: f64, index: usize, of: usize },
    /// Backward SP2 dgrad over chunk `index` (see [`Op::BwdSpDgrad`]).
    BwdSp2Dgrad { flops_per_rank: f64, index: usize, of: usize },
    /// Backward SP2 wgrad over chunk `index` (see [`Op::BwdSpWgrad`]).
    BwdSp2Wgrad { flops_per_rank: f64, index: usize, of: usize },
    /// Backward SP2 combine: transpose of forward `sp2.dispatch.index`.
    BwdSp2Combine { bytes_per_pair: f64, index: usize, of: usize },
}

impl Op {
    /// Canonical tag for trace/report/comm-log accounting — the constants
    /// of [`crate::comm::tags`], shared verbatim by the simulator's
    /// per-tag accounting and the data plane's wire log.
    pub fn tag(&self) -> &'static str {
        use crate::comm::tags;
        match self {
            Op::EspAllGather { .. } => tags::ESP_ALLGATHER,
            Op::EpAlltoAll { .. } => tags::EP_ALLTOALL,
            Op::EspAllReduce { .. } => tags::ESP_ALLREDUCE,
            Op::EspReduceScatter { .. } => tags::ESP_REDUCESCATTER,
            Op::MpReduceScatter { .. } => tags::MP_REDUCESCATTER,
            Op::EspSplit { .. } => tags::ESP_SPLIT,
            Op::MpSplit { .. } => tags::MP_SPLIT,
            Op::MpAllGather { .. } => tags::MP_ALLGATHER,
            Op::FusedAlltoAll { .. } => tags::FUSED_ALLTOALL,
            Op::SaaCombine { .. } => tags::SAA_COMBINE,
            Op::AasCombine { .. } => tags::AAS_COMBINE,
            Op::Gate { .. } => tags::GATE,
            Op::ExpertFfn { .. } => tags::EXPERT_FFN,
            Op::LocalCombine { .. } => tags::LOCAL_COMBINE,
            Op::Ungate { .. } => tags::UNGATE,
            // Direct indexing: an index past SP_MAX_CHUNKS is an invariant
            // violation (builders clamp via `sp_clamp_chunks`) — panic at
            // the source rather than aliasing chunks in the wire log.
            Op::SpDispatch { index, .. } => tags::SP_DISPATCH[*index],
            Op::SpExpertFfn { index, .. } => tags::SP_FFN[*index],
            Op::SpCombine { index, .. } => tags::SP_COMBINE[*index],
            Op::Sp2Dispatch { index, .. } => tags::SP2_DISPATCH[*index],
            Op::Sp2ExpertFfn { index, .. } => tags::SP2_FFN[*index],
            Op::Sp2Saa { index, .. } => tags::SP2_SAA[*index],
            Op::BwdEpAlltoAll { combine: false, .. } => tags::BWD_EP_DISPATCH,
            Op::BwdEpAlltoAll { combine: true, .. } => tags::BWD_EP_COMBINE,
            Op::BwdFusedAlltoAll { combine: false, .. } => tags::BWD_FUSED_DISPATCH,
            Op::BwdFusedAlltoAll { combine: true, .. } => tags::BWD_FUSED_COMBINE,
            Op::BwdExpertDgrad { .. } => tags::BWD_EXPERT_DGRAD,
            Op::BwdExpertWgrad { .. } => tags::BWD_EXPERT_WGRAD,
            Op::BwdWgradAllReduce { .. } => tags::BWD_WGRAD_ALLREDUCE,
            Op::BwdSpDispatch { index, .. } => tags::BWD_SP_DISPATCH[*index],
            Op::BwdSpDgrad { index, .. } => tags::BWD_SP_DGRAD[*index],
            Op::BwdSpWgrad { index, .. } => tags::BWD_SP_WGRAD[*index],
            Op::BwdSpCombine { index, .. } => tags::BWD_SP_COMBINE[*index],
            Op::BwdSp2Dispatch { index, .. } => tags::BWD_SP2_DISPATCH[*index],
            Op::BwdSp2Dgrad { index, .. } => tags::BWD_SP2_DGRAD[*index],
            Op::BwdSp2Wgrad { index, .. } => tags::BWD_SP2_WGRAD[*index],
            Op::BwdSp2Combine { index, .. } => tags::BWD_SP2_COMBINE[*index],
        }
    }

    pub fn is_communication(&self) -> bool {
        matches!(
            self,
            Op::EspAllGather { .. }
                | Op::EpAlltoAll { .. }
                | Op::EspAllReduce { .. }
                | Op::EspReduceScatter { .. }
                | Op::MpReduceScatter { .. }
                | Op::MpAllGather { .. }
                | Op::FusedAlltoAll { .. }
                | Op::SaaCombine { .. }
                | Op::AasCombine { .. }
                | Op::SpDispatch { .. }
                | Op::SpCombine { .. }
                | Op::Sp2Dispatch { .. }
                | Op::Sp2Saa { .. }
                | Op::BwdEpAlltoAll { .. }
                | Op::BwdFusedAlltoAll { .. }
                | Op::BwdWgradAllReduce { .. }
                | Op::BwdSpDispatch { .. }
                | Op::BwdSpCombine { .. }
                | Op::BwdSp2Dispatch { .. }
                | Op::BwdSp2Combine { .. }
        )
    }
}

/// Which schedule to run (paper Fig 3 + §V).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    /// DeepSpeed-MoE's default schedule (Fig 3a).
    Baseline,
    /// PauseMP before the gate (Fig 3b).
    S1,
    /// PauseMP after the gate, SAA-overlapped combine (Fig 3c).
    S2,
    /// S2 without SAA (sequential AlltoAll + AllGather) — §VI-C ablation.
    S2Aas,
    /// Chunk-pipelined dispatch/compute/combine (SP): S1's op structure
    /// with the fused AlltoAlls and the expert FFN split into `chunks`
    /// capacity chunks so chunk k's combine overlaps chunk k+1's compute
    /// (FSMoE-style intra-layer pipelining). Spans are **load-aware**: with
    /// a routing-skew knob set ([`crate::config::MoeLayerConfig::skew`]),
    /// chunk boundaries balance estimated per-chunk FLOPs from the gate's
    /// expected expert loads ([`chunk_spans_weighted`]) rather than raw
    /// capacity rows. `chunks == 0` is the unresolved "auto" form —
    /// resolve r* via [`crate::perfmodel::closedform::optimal_chunks`] or
    /// the fitted prediction first.
    Pipelined { chunks: usize },
    /// SP with **uniform** capacity spans regardless of routing skew — the
    /// ablation column for the load-aware spans (identical to
    /// [`ScheduleKind::Pipelined`] when `skew == 0`).
    PipelinedUniform { chunks: usize },
    /// Chunk-pipelined S2 (`sp2`/`sp2N`): S2's op structure with the
    /// capacity-split dispatch AlltoAll, the expert FFN and the
    /// SAA-overlapped combine split into `chunks` capacity chunks — each
    /// chunk's combine runs as a *chunked SAA* whose EP&ESP-AlltoAll
    /// phases forward into the MP-AllGather while the next chunk's FFN
    /// computes. The first schedule composing two overlap mechanisms:
    /// SP's compute/comm pipeline and S2's intra/inter link-class
    /// overlap (the ROADMAP's "SP × SAA" item). Spans follow the same
    /// load-aware policy as [`ScheduleKind::Pipelined`]. `chunks == 0`
    /// is the unresolved "auto" form — resolve r* via
    /// [`crate::perfmodel::closedform::optimal_chunks_sp2`] first.
    PipelinedS2 { chunks: usize },
    /// Automatic selection among S1, S2, SP(r*) and SP2(r*) (Algorithm 1,
    /// generalized).
    Parm,
}

impl ScheduleKind {
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Baseline => "baseline",
            ScheduleKind::S1 => "s1",
            ScheduleKind::S2 => "s2",
            ScheduleKind::S2Aas => "s2-aas",
            ScheduleKind::Pipelined { .. } => "sp",
            ScheduleKind::PipelinedUniform { .. } => "sp-uniform",
            ScheduleKind::PipelinedS2 { .. } => "sp2",
            ScheduleKind::Parm => "parm",
        }
    }

    /// Human-readable form carrying the schedule family's parameter.
    pub fn label(&self) -> String {
        match self {
            ScheduleKind::Pipelined { chunks } if *chunks > 0 => format!("sp(r={chunks})"),
            ScheduleKind::PipelinedUniform { chunks } if *chunks > 0 => {
                format!("sp-uniform(r={chunks})")
            }
            ScheduleKind::PipelinedS2 { chunks } if *chunks > 0 => format!("sp2(r={chunks})"),
            k => k.name().to_string(),
        }
    }

    pub fn parse(s: &str) -> Option<ScheduleKind> {
        match s {
            "baseline" | "deepspeed" => Some(ScheduleKind::Baseline),
            "s1" => Some(ScheduleKind::S1),
            "s2" => Some(ScheduleKind::S2),
            "s2-aas" | "aas" => Some(ScheduleKind::S2Aas),
            "sp" | "pipelined" => Some(ScheduleKind::Pipelined { chunks: 0 }),
            "sp-uniform" | "spu" => Some(ScheduleKind::PipelinedUniform { chunks: 0 }),
            // NOTE: `sp2` is the pipelined-S2 FAMILY, not SP at r = 2 —
            // SP with a pinned chunk count of 2 is spelled `pipelined2`
            // (the `pipelinedN` form pins any SP chunk count).
            "sp2" | "pipelined-s2" => Some(ScheduleKind::PipelinedS2 { chunks: 0 }),
            "parm" | "auto" => Some(ScheduleKind::Parm),
            _ => {
                if let Some(n) = s.strip_prefix("spu").and_then(|n| n.parse::<usize>().ok()) {
                    return Some(ScheduleKind::PipelinedUniform { chunks: n });
                }
                if let Some(n) = s.strip_prefix("pipelined").and_then(|n| n.parse::<usize>().ok())
                {
                    return Some(ScheduleKind::Pipelined { chunks: n });
                }
                if let Some(n) = s.strip_prefix("sp2").and_then(|n| n.parse::<usize>().ok()) {
                    return Some(ScheduleKind::PipelinedS2 { chunks: n });
                }
                s.strip_prefix("sp")
                    .and_then(|n| n.parse::<usize>().ok())
                    .map(|chunks| ScheduleKind::Pipelined { chunks })
            }
        }
    }
}

// ---- communication volumes (bytes), shared by schedule builders and the
// ---- α-β predictions so both sides use identical sizes -----------------

/// Baseline ESP-AllGather: each rank contributes its (B,L,M) input.
pub fn bytes_esp_ag_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.input_elems() * c.dtype_bytes) as f64
}

/// Baseline EP-AlltoAll per-pair chunk: experts-per-slot × gathered
/// capacity (T·N_ESP) × M.
pub fn bytes_ep_a2a_per_pair(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * c.t() * c.par.n_esp * c.m * c.dtype_bytes) as f64
}

/// Baseline ESP-AllReduce total per member: local experts × tokens-per-
/// expert (T·P, one T per source rank in the EP group ⇒ T·N_ESP·N_EP) × M.
pub fn bytes_esp_ar_total(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * c.t() * c.par.p * c.m * c.dtype_bytes) as f64
}

/// PauseMP fused EP&ESP-AlltoAll per-pair chunk (S1/S2): experts-per-slot ×
/// split capacity (T/N_MP) × M. Per-rank total = ETM·N_ESP/N_MP — the
/// paper's Eq. (13)/(14) argument.
pub fn bytes_fused_a2a_per_pair(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * c.t_pausemp() * c.m * c.dtype_bytes) as f64
}

/// S1's final MP-AllGather contribution per rank: the 1/N_MP token slice.
pub fn bytes_mp_ag_s1_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.input_elems() / c.par.n_mp * c.dtype_bytes) as f64
}

/// S2's final MP-AllGather contribution per rank: the 1/N_MP capacity
/// slice (E, T/N_MP, M) — the AG_MP(ETM) of Eq. (14).
pub fn bytes_mp_ag_s2_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.e * c.t_pausemp() * c.m * c.dtype_bytes) as f64
}

/// Per-rank expert weight-gradient buffer: the two FFN matmul weights of
/// each locally hosted expert slot, H-sharded over ESP —
/// experts-per-slot × 2 × M × (H/N_ESP) elements. This is the per-member
/// buffer the backward wgrad AllReduce synchronizes (the ESP replicas
/// computed partial weight gradients from different token shards).
pub fn bytes_wgrad_per_rank(c: &MoeLayerConfig) -> f64 {
    (c.experts_per_rank() * 2 * c.m * (c.h / c.par.n_esp) * c.dtype_bytes) as f64
}

/// THE one place compressed-wire volumes are derived: the fraction of an
/// op's model-width bytes that actually crosses the wire on `leg` under
/// the config's [`crate::config::WirePrecision`] policy. Every `bytes_*`
/// helper above stays in model width (elements × `dtype_bytes`) — the
/// closed forms, the fitted predictions, and the timing transport all
/// multiply by this factor instead of re-deriving per-leg widths locally.
/// 1.0 under the default policy (f32 wire over a 4-byte model dtype).
pub fn wire_factor(c: &MoeLayerConfig, leg: WireLeg) -> f64 {
    c.wire.dtype(leg).bytes() as f64 / c.dtype_bytes as f64
}

// ---- SP chunking (capacity spans shared by builder and data plane) -----

/// Split `cap` capacity rows into exactly `chunks` contiguous spans of
/// `(start, rows)` whose sizes differ by at most one row (the first
/// `cap % chunks` spans are one longer; tail spans are empty when
/// `cap < chunks`). The SAME split is applied to the builder's capacity
/// estimate `T` and to the data plane's actual gate capacity, so per-chunk
/// volumes agree wherever the capacity estimate is exact.
pub fn chunk_spans(cap: usize, chunks: usize) -> Vec<(usize, usize)> {
    let r = chunks.max(1);
    let base = cap / r;
    let rem = cap % r;
    let mut out = Vec::with_capacity(r);
    let mut start = 0;
    for j in 0..r {
        let len = base + usize::from(j < rem);
        out.push((start, len));
        start += len;
    }
    out
}

/// Clamp an SP chunk count to the representable range: at least 1, at most
/// [`crate::comm::tags::SP_MAX_CHUNKS`], and at most one chunk per
/// capacity row so no chunk is empty.
pub fn sp_clamp_chunks(c: &MoeLayerConfig, chunks: usize) -> usize {
    chunks
        .clamp(1, crate::comm::tags::SP_MAX_CHUNKS)
        .min(c.t_pausemp().max(1))
}

/// SP per-chunk fused-AlltoAll pair chunk: experts-per-slot × span rows ×
/// M (the [`bytes_fused_a2a_per_pair`] volume restricted to one span).
/// Volumes stay **dense** under skew: the dispatch ships each expert's
/// zero-padded capacity rows either way — only compute is load-dependent.
pub fn bytes_sp_chunk_per_pair(c: &MoeLayerConfig, span_rows: usize) -> f64 {
    (c.experts_per_rank() * span_rows * c.m * c.dtype_bytes) as f64
}

/// SP per-chunk expert FLOPs per rank: the PauseMP FFN restricted to one
/// capacity span (experts-per-slot × span rows × P source blocks).
pub fn sp_chunk_flops(c: &MoeLayerConfig, span_rows: usize) -> f64 {
    expert_flops(c, (c.experts_per_rank() * span_rows * c.par.p) as f64)
}

// ---- routing-skew load model (gate statistics → span weights) ----------

/// Expected per-expert load as a fraction of the hottest expert's load,
/// derived from the Zipf router bias (`MoeLayerConfig::skew`): expert `j`
/// carries Zipf weight `(j+1)^{-skew}`, each expert's fill is capped at
/// its capacity, and the vector is normalized so the hottest expert reads
/// 1.0 (skew → 0 degrades continuously to all-ones). `None` when the knob
/// is off — the uniform model the rest of the IR assumed before
/// load-aware chunking.
pub fn expert_load_fractions(c: &MoeLayerConfig) -> Option<Vec<f64>> {
    if c.skew <= 0.0 {
        return None;
    }
    let w: Vec<f64> = (0..c.e).map(|j| ((j + 1) as f64).powf(-c.skew)).collect();
    // Expected pick mass per expert over the gate's k without-replacement
    // rounds, by iterative renormalization: each round distributes one
    // pick per token in proportion to the weight mass earlier rounds have
    // not yet retired (`w_j·(1 - inc_j)`). Exact at k = 1; for k ≥ 2 it
    // captures what independent Zipf shares would miss — a token cannot
    // take the same expert twice, so under strong skew the k hottest
    // experts ALL saturate (the gate's top-k max-scan does exactly that).
    let mut inc = vec![0.0f64; c.e];
    for _ in 0..c.k {
        let denom: f64 = w.iter().zip(&inc).map(|(wj, ij)| wj * (1.0 - ij)).sum();
        if denom <= 0.0 {
            break;
        }
        for (ij, wj) in inc.iter_mut().zip(&w) {
            *ij = (*ij + wj * (1.0 - *ij) / denom).min(1.0);
        }
    }
    // Fill fraction of expert j's capacity rows: expected picks `n·inc_j`
    // over the capacity budget ceil(n·k·f/E) ≈ inc_j·E/(k·f), saturating
    // at a full block.
    let kf = c.k as f64 * c.f;
    let fills: Vec<f64> = inc.iter().map(|i| (i * c.e as f64 / kf).min(1.0)).collect();
    let max = fills.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
    Some(fills.iter().map(|f| f / max).collect())
}

/// Expected filled rows per expert at capacity `cap` (the integer loads
/// the weighted spans and the per-chunk FLOPs model share — deterministic
/// rounding, so the builders, the perf-model evaluators and the data plane
/// all see the same profile). `None` when `skew == 0`.
pub fn expected_loads(c: &MoeLayerConfig, cap: usize) -> Option<Vec<usize>> {
    expert_load_fractions(c)
        .map(|fr| fr.iter().map(|f| (f * cap as f64 + 0.5).floor() as usize).collect())
}

/// Filled rows across ALL experts within capacity rows
/// `[start, start + rows)` — the tokens a span actually carries under the
/// load profile (each expert fills the prefix of its capacity block).
fn total_filled(loads: &[usize], start: usize, rows: usize) -> usize {
    loads.iter().map(|&l| l.saturating_sub(start).min(rows)).sum()
}

/// Split `cap` capacity rows into `chunks` contiguous spans whose
/// **estimated FLOPs** (not raw rows) are balanced: row `j`'s weight is
/// the number of experts whose filled prefix extends past row `j`, so
/// under a skewed load profile the hot head rows get short spans and the
/// sparse tail gets long ones — equalizing per-chunk FFN time, which is
/// what keeps chunk k's combine hidden behind chunk k+1's compute. With a
/// flat (or empty) profile — every row carrying the same weight — this
/// reduces exactly to [`chunk_spans`].
pub fn chunk_spans_weighted(cap: usize, chunks: usize, loads: &[usize]) -> Vec<(usize, usize)> {
    let r = chunks.max(1);
    // Prefix weights: pre[i] = Σ_{row < i} (#experts with load > row).
    let mut pre = Vec::with_capacity(cap + 1);
    pre.push(0.0f64);
    for row in 0..cap {
        let w = loads.iter().filter(|&&l| l > row).count() as f64;
        pre.push(pre[row] + w);
    }
    let total = *pre.last().unwrap_or(&0.0);
    // Zero total estimated load (an all-zero `loads` vector — e.g. measured
    // spans on a degenerate gate that routed nothing) would make every
    // span-boundary target NaN/meaningless; fall back to the uniform split
    // instead of dividing by it.
    if cap == 0 || total <= 0.0 {
        return chunk_spans(cap, r);
    }
    // Flat profile (all loads saturate the capacity): every row weighs the
    // same, so defer to chunk_spans' exact front-loaded-remainder split
    // rather than the target walk (which rounds boundaries differently).
    if (1..=cap).all(|i| pre[i] - pre[i - 1] == pre[1]) {
        return chunk_spans(cap, r);
    }
    let mut out = Vec::with_capacity(r);
    let mut start = 0usize;
    for k in 0..r {
        if k + 1 == r {
            out.push((start, cap - start));
            break;
        }
        if start >= cap {
            out.push((cap, 0));
            continue;
        }
        let left = r - 1 - k; // spans still owed after this one
        let rows_left = cap - start;
        // Give this span at least one row; keep one row per later span
        // while rows remain (the degenerate cap < chunks case tails off
        // with zero-width spans exactly like `chunk_spans`).
        let max_end = if rows_left > left { cap - left } else { start + 1 };
        let target = total * (k + 1) as f64 / r as f64;
        let mut end = start + 1;
        while end < max_end && pre[end] < target {
            end += 1;
        }
        out.push((start, end - start));
        start = end;
    }
    out
}

/// The spans one SP region pipelines over: FLOPs-balanced from the
/// expected gate loads when the routing-skew knob is on, the uniform
/// [`chunk_spans`] otherwise. The ONE span policy shared by the schedule
/// builder (capacity estimate) and the data plane (actual gate capacity),
/// so both transports stage identical chunks.
pub fn sp_spans(c: &MoeLayerConfig, cap: usize, chunks: usize) -> Vec<(usize, usize)> {
    match expected_loads(c, cap) {
        Some(loads) => chunk_spans_weighted(cap, chunks, &loads),
        None => chunk_spans(cap, chunks),
    }
}

/// Two-pass span selection: FLOPs-balance the chunk spans from the gate's
/// **measured** per-expert loads
/// ([`crate::moe::gating::DispatchInfo::expert_loads`], max-aggregated
/// over ranks) instead of the expected Zipf profile — this covers
/// organic, non-Zipf imbalance the skew knob cannot model (hot experts
/// that emerge from the data, not from a configured bias). Loads are
/// clamped to `cap`; an empty or all-zero measurement falls back to the
/// uniform split. Exposed on the CLI as `parm sim --spans measured`.
pub fn sp_spans_measured(cap: usize, chunks: usize, measured: &[usize]) -> Vec<(usize, usize)> {
    let clamped: Vec<usize> = measured.iter().map(|&l| l.min(cap)).collect();
    if clamped.iter().all(|&l| l == 0) {
        return chunk_spans(cap, chunks);
    }
    chunk_spans_weighted(cap, chunks, &clamped)
}

/// [`sp_chunk_flops_span`]'s pricing under a **measured** load profile:
/// only the measured filled rows of a span do FFN work, priced at the
/// mean per-rank share exactly like the expected-profile variant — so a
/// two-pass program's per-chunk FFN ops sum to the measured total over
/// any span partition.
pub fn sp_chunk_flops_measured(
    c: &MoeLayerConfig,
    cap: usize,
    span: (usize, usize),
    measured: &[usize],
) -> f64 {
    let (start, rows) = span;
    let clamped: Vec<usize> = measured.iter().map(|&l| l.min(cap)).collect();
    let mean_rows = total_filled(&clamped, start, rows) as f64 / c.par.n_ep() as f64;
    expert_flops(c, mean_rows * c.par.p as f64)
}

/// Load-aware per-chunk expert FLOPs per rank: only the *filled* rows of
/// a span do useful FFN work (a load-aware kernel skips the zero
/// padding). The engine charges ONE flops-per-rank scalar per op, so the
/// chunk is priced at the mean per-rank share of its filled rows — note
/// that pricing by the *busiest* slot instead would make capacity-span
/// chunking blind to skew (the hottest expert fills every span evenly);
/// it is the aggregate token mass per span that is front-loaded, and that
/// is what the weighted spans rebalance. Reduces to [`sp_chunk_flops`]
/// when `skew == 0`.
pub fn sp_chunk_flops_span(c: &MoeLayerConfig, cap: usize, span: (usize, usize)) -> f64 {
    let (start, rows) = span;
    match expected_loads(c, cap) {
        Some(loads) => {
            let mean_rows = total_filled(&loads, start, rows) as f64 / c.par.n_ep() as f64;
            expert_flops(c, mean_rows * c.par.p as f64)
        }
        None => sp_chunk_flops(c, rows),
    }
}

/// Fraction of the dense expert FFN actually computed under the load
/// profile (1.0 with the skew knob off). Scales every schedule's
/// monolithic `ExpertFfn` term so S1/S2/baseline and the SP chunks price
/// compute consistently: by linearity the scaled monolithic FFN equals
/// the sum of [`sp_chunk_flops_span`] over ANY span partition, exactly.
pub fn ffn_load_scale(c: &MoeLayerConfig, cap: usize) -> f64 {
    match expected_loads(c, cap) {
        Some(loads) => {
            let dense = c.par.n_ep() * c.experts_per_rank() * cap;
            if dense == 0 {
                return 1.0;
            }
            total_filled(&loads, 0, cap) as f64 / dense as f64
        }
        None => 1.0,
    }
}

/// [`ffn_load_scale`]'s pricing under a **measured** (or trace-supplied)
/// per-expert load vector: the fraction of the dense expert FFN the
/// measured fill actually computes. Loads are clamped to `cap`; an empty
/// or all-zero measurement falls back to the expected-profile scale (the
/// same uniform fallback [`sp_spans_measured`] applies), so a degenerate
/// gate step never zeroes out the FFN. By linearity the scaled monolithic
/// FFN equals the sum of [`sp_chunk_flops_measured`] over ANY span
/// partition — monolithic and chunked schedules price the same profile.
pub fn ffn_load_scale_measured(c: &MoeLayerConfig, cap: usize, measured: &[usize]) -> f64 {
    let clamped: Vec<usize> = measured.iter().map(|&l| l.min(cap)).collect();
    if clamped.iter().all(|&l| l == 0) {
        return ffn_load_scale(c, cap);
    }
    let dense = c.par.n_ep() * c.experts_per_rank() * cap;
    if dense == 0 {
        return 1.0;
    }
    total_filled(&clamped, 0, cap) as f64 / dense as f64
}

/// Integer per-expert loads at capacity `cap` from an arbitrary per-expert
/// **weight** vector (a traffic scenario's instantaneous routing bias) —
/// the same k-round without-replacement renormalization as
/// [`expert_load_fractions`], but over supplied weights instead of the
/// static Zipf curve, and WITHOUT the hottest-expert normalization: the
/// absolute fill tracks how concentrated the weights are, so total
/// routed-token mass (and therefore FFN cost) responds to drift, not just
/// its shape. All-zero weights yield all-zero loads (the degenerate-gate
/// case downstream fallbacks handle); uniform weights fill every expert to
/// `cap/f` — the uniform router's expected occupancy.
pub fn loads_from_weights(c: &MoeLayerConfig, cap: usize, weights: &[f64]) -> Vec<usize> {
    let w: Vec<f64> = weights.iter().map(|&x| x.max(0.0)).collect();
    if w.is_empty() || w.iter().sum::<f64>() <= 0.0 {
        return vec![0; w.len()];
    }
    let mut inc = vec![0.0f64; w.len()];
    for _ in 0..c.k {
        let denom: f64 = w.iter().zip(&inc).map(|(wj, ij)| wj * (1.0 - ij)).sum();
        if denom <= 0.0 {
            break;
        }
        for (ij, wj) in inc.iter_mut().zip(&w) {
            *ij = (*ij + wj * (1.0 - *ij) / denom).min(1.0);
        }
    }
    let kf = c.k as f64 * c.f;
    inc.iter()
        .map(|i| {
            let fill = (i * w.len() as f64 / kf).min(1.0);
            (fill * cap as f64 + 0.5).floor() as usize
        })
        .collect()
}

// ---- compute volumes (FLOPs per rank) ----------------------------------

/// Gate FLOPs: tokens × M × E MACs (×2), on however many tokens this
/// schedule gates per rank.
pub fn gate_flops(c: &MoeLayerConfig, tokens: usize) -> f64 {
    2.0 * tokens as f64 * (c.m * c.e) as f64
}

/// Expert FLOPs per rank: two matmuls over the local H-shard, for
/// `tokens_per_rank` tokens routed to this rank.
pub fn expert_flops(c: &MoeLayerConfig, tokens_per_rank: f64) -> f64 {
    tokens_per_rank * 2.0 * 2.0 * (c.m * (c.h / c.par.n_esp)) as f64
}

/// Tokens each rank's expert shards process per step. Baseline duplicates
/// the work N_MP times (`pause_mp = false`).
pub fn expert_tokens_per_rank(c: &MoeLayerConfig, pause_mp: bool) -> f64 {
    let t = if pause_mp { c.t_pausemp() } else { c.t() * c.par.n_esp } as f64;
    // Each rank hosts E/N_EP expert slots and receives `t` tokens per
    // expert from each source in its dispatch group (EP group for the
    // baseline, the whole world for PauseMP).
    let sources = if pause_mp { c.par.p } else { c.par.n_ep() } as f64;
    c.experts_per_rank() as f64 * t * sources
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig::test_default()
    }

    #[test]
    fn pausemp_reduces_a2a_volume_by_nmp() {
        let c = cfg();
        let baseline_total = bytes_ep_a2a_per_pair(&c) * c.par.n_ep() as f64;
        let fused_total = bytes_fused_a2a_per_pair(&c) * c.par.p as f64;
        // ETM·N_ESP vs ETM·N_ESP/N_MP (up to capacity rounding).
        let ratio = baseline_total / fused_total;
        assert!(
            (ratio - c.par.n_mp as f64).abs() / (c.par.n_mp as f64) < 0.05,
            "ratio {ratio} ≈ n_mp {}",
            c.par.n_mp
        );
    }

    #[test]
    fn pausemp_reduces_expert_tokens_by_nmp() {
        let c = cfg();
        let dup = expert_tokens_per_rank(&c, false);
        let dedup = expert_tokens_per_rank(&c, true);
        let ratio = dup / dedup;
        assert!((ratio - c.par.n_mp as f64).abs() / (c.par.n_mp as f64) < 0.05);
    }

    #[test]
    fn s2_ag_scales_with_capacity_s1_with_tokens() {
        let mut c = cfg();
        let s1_before = bytes_mp_ag_s1_per_rank(&c);
        let s2_before = bytes_mp_ag_s2_per_rank(&c);
        c.f *= 2.0; // double capacity factor → T doubles
        assert_eq!(bytes_mp_ag_s1_per_rank(&c), s1_before);
        assert!(bytes_mp_ag_s2_per_rank(&c) > 1.9 * s2_before);
    }

    #[test]
    fn schedule_kind_parse() {
        assert_eq!(ScheduleKind::parse("parm"), Some(ScheduleKind::Parm));
        assert_eq!(ScheduleKind::parse("deepspeed"), Some(ScheduleKind::Baseline));
        assert_eq!(ScheduleKind::parse("nope"), None);
        for k in [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::S2Aas,
            ScheduleKind::Pipelined { chunks: 0 },
            ScheduleKind::Parm,
        ] {
            assert_eq!(ScheduleKind::parse(k.name()), Some(k));
        }
        // The parameterized family: `spN` pins the chunk count.
        assert_eq!(
            ScheduleKind::parse("sp4"),
            Some(ScheduleKind::Pipelined { chunks: 4 })
        );
        assert_eq!(ScheduleKind::parse("spx"), None);
        assert_eq!(ScheduleKind::Pipelined { chunks: 4 }.label(), "sp(r=4)");
        assert_eq!(ScheduleKind::S1.label(), "s1");
        // The pipelined-S2 family: `sp2` is SP×SAA, NOT SP at r = 2.
        assert_eq!(
            ScheduleKind::parse("sp2"),
            Some(ScheduleKind::PipelinedS2 { chunks: 0 })
        );
        assert_eq!(
            ScheduleKind::parse("sp24"),
            Some(ScheduleKind::PipelinedS2 { chunks: 4 })
        );
        assert_eq!(
            ScheduleKind::parse(ScheduleKind::PipelinedS2 { chunks: 0 }.name()),
            Some(ScheduleKind::PipelinedS2 { chunks: 0 })
        );
        assert_eq!(ScheduleKind::PipelinedS2 { chunks: 3 }.label(), "sp2(r=3)");
        assert_eq!(ScheduleKind::parse("sp2x"), None);
        // SP at a pinned r = 2 remains spellable via the pipelinedN form.
        assert_eq!(
            ScheduleKind::parse("pipelined2"),
            Some(ScheduleKind::Pipelined { chunks: 2 })
        );
        assert_eq!(
            ScheduleKind::parse("pipelined5"),
            Some(ScheduleKind::Pipelined { chunks: 5 })
        );
        // The uniform-span ablation variant.
        assert_eq!(
            ScheduleKind::parse("spu3"),
            Some(ScheduleKind::PipelinedUniform { chunks: 3 })
        );
        assert_eq!(
            ScheduleKind::parse("sp-uniform"),
            Some(ScheduleKind::PipelinedUniform { chunks: 0 })
        );
        assert_eq!(
            ScheduleKind::parse(ScheduleKind::PipelinedUniform { chunks: 0 }.name()),
            Some(ScheduleKind::PipelinedUniform { chunks: 0 })
        );
        assert_eq!(
            ScheduleKind::PipelinedUniform { chunks: 2 }.label(),
            "sp-uniform(r=2)"
        );
    }

    #[test]
    fn op_tags_and_comm_flags() {
        assert!(Op::FusedAlltoAll { bytes_per_pair: 1.0 }.is_communication());
        assert!(!Op::Gate { flops_per_rank: 1.0 }.is_communication());
        assert_eq!(Op::MpSplit { bytes_per_rank: 0.0 }.tag(), "mp.split");
        assert!(Op::SpDispatch { bytes_per_pair: 1.0, index: 0, of: 2 }.is_communication());
        assert!(Op::SpCombine { bytes_per_pair: 1.0, index: 1, of: 2 }.is_communication());
        assert!(!Op::SpExpertFfn { flops_per_rank: 1.0, index: 0, of: 2 }.is_communication());
        assert_eq!(
            Op::SpDispatch { bytes_per_pair: 1.0, index: 1, of: 4 }.tag(),
            "sp.dispatch.1"
        );
        assert_eq!(
            Op::SpCombine { bytes_per_pair: 1.0, index: 3, of: 4 }.tag(),
            "sp.combine.3"
        );
        // The SP2 (chunked-SAA) family.
        assert!(Op::Sp2Dispatch { bytes_per_pair: 1.0, index: 0, of: 2 }.is_communication());
        assert!(Op::Sp2Saa { bytes_per_pair: 1.0, index: 1, of: 2 }.is_communication());
        assert!(!Op::Sp2ExpertFfn { flops_per_rank: 1.0, index: 0, of: 2 }.is_communication());
        assert_eq!(
            Op::Sp2Dispatch { bytes_per_pair: 1.0, index: 1, of: 4 }.tag(),
            "sp2.dispatch.1"
        );
        assert_eq!(Op::Sp2Saa { bytes_per_pair: 1.0, index: 3, of: 4 }.tag(), "sp2.saa.3");
        assert_eq!(
            Op::Sp2ExpertFfn { flops_per_rank: 1.0, index: 2, of: 4 }.tag(),
            "sp2.ffn.2"
        );
        // The backward vocabulary.
        assert_eq!(
            Op::BwdEpAlltoAll { bytes_per_pair: 1.0, combine: false }.tag(),
            "bwd.ep.dispatch"
        );
        assert_eq!(
            Op::BwdEpAlltoAll { bytes_per_pair: 1.0, combine: true }.tag(),
            "bwd.ep.combine"
        );
        assert_eq!(
            Op::BwdFusedAlltoAll { bytes_per_pair: 1.0, combine: false }.tag(),
            "bwd.fused.dispatch"
        );
        assert_eq!(
            Op::BwdFusedAlltoAll { bytes_per_pair: 1.0, combine: true }.tag(),
            "bwd.fused.combine"
        );
        assert!(Op::BwdEpAlltoAll { bytes_per_pair: 1.0, combine: false }.is_communication());
        assert!(Op::BwdFusedAlltoAll { bytes_per_pair: 1.0, combine: true }.is_communication());
        assert_eq!(
            Op::BwdWgradAllReduce { bytes_per_rank: 1.0, overlap: true }.tag(),
            "bwd.wgrad.allreduce"
        );
        assert!(Op::BwdWgradAllReduce { bytes_per_rank: 1.0, overlap: false }.is_communication());
        assert!(!Op::BwdExpertDgrad { flops_per_rank: 1.0 }.is_communication());
        assert!(!Op::BwdExpertWgrad { flops_per_rank: 1.0 }.is_communication());
        assert_eq!(Op::BwdExpertDgrad { flops_per_rank: 1.0 }.tag(), "bwd.expert.dgrad");
        assert_eq!(Op::BwdExpertWgrad { flops_per_rank: 1.0 }.tag(), "bwd.expert.wgrad");
        assert_eq!(
            Op::BwdSpDispatch { bytes_per_pair: 1.0, index: 1, of: 4 }.tag(),
            "bwd.sp.dispatch.1"
        );
        assert_eq!(
            Op::BwdSpCombine { bytes_per_pair: 1.0, index: 3, of: 4 }.tag(),
            "bwd.sp.combine.3"
        );
        assert_eq!(
            Op::BwdSpDgrad { flops_per_rank: 1.0, index: 0, of: 2 }.tag(),
            "bwd.sp.dgrad.0"
        );
        assert_eq!(
            Op::BwdSpWgrad { flops_per_rank: 1.0, index: 1, of: 2 }.tag(),
            "bwd.sp.wgrad.1"
        );
        assert!(Op::BwdSpDispatch { bytes_per_pair: 1.0, index: 0, of: 2 }.is_communication());
        assert!(Op::BwdSpCombine { bytes_per_pair: 1.0, index: 0, of: 2 }.is_communication());
        assert!(!Op::BwdSpDgrad { flops_per_rank: 1.0, index: 0, of: 2 }.is_communication());
        assert!(!Op::BwdSpWgrad { flops_per_rank: 1.0, index: 0, of: 2 }.is_communication());
        assert_eq!(
            Op::BwdSp2Dispatch { bytes_per_pair: 1.0, index: 2, of: 4 }.tag(),
            "bwd.sp2.dispatch.2"
        );
        assert_eq!(
            Op::BwdSp2Combine { bytes_per_pair: 1.0, index: 0, of: 4 }.tag(),
            "bwd.sp2.combine.0"
        );
        assert_eq!(
            Op::BwdSp2Dgrad { flops_per_rank: 1.0, index: 1, of: 2 }.tag(),
            "bwd.sp2.dgrad.1"
        );
        assert_eq!(
            Op::BwdSp2Wgrad { flops_per_rank: 1.0, index: 1, of: 2 }.tag(),
            "bwd.sp2.wgrad.1"
        );
    }

    #[test]
    fn wgrad_bytes_track_the_expert_shard() {
        // The wgrad AllReduce buffer is the H-sharded expert weights: it
        // must shrink with N_ESP and scale with the hidden sizes, and it
        // is independent of the batch geometry (weights, not activations).
        let c = cfg();
        let w = bytes_wgrad_per_rank(&c);
        assert!(w > 0.0);
        assert_eq!(
            w,
            (c.experts_per_rank() * 2 * c.m * (c.h / c.par.n_esp) * c.dtype_bytes) as f64
        );
        let mut bigger = cfg();
        bigger.b *= 2;
        assert_eq!(bytes_wgrad_per_rank(&bigger), w, "batch-independent");
    }

    #[test]
    fn chunk_spans_partition_exactly() {
        // Even split.
        assert_eq!(chunk_spans(8, 4), vec![(0, 2), (2, 2), (4, 2), (6, 2)]);
        // Ragged: first `cap % r` spans are one longer.
        assert_eq!(chunk_spans(7, 3), vec![(0, 3), (3, 2), (5, 2)]);
        // Degenerate: more chunks than rows ⇒ empty tails, still `chunks`
        // spans so op counts and span counts agree.
        assert_eq!(chunk_spans(2, 4), vec![(0, 1), (1, 1), (2, 0), (2, 0)]);
        // Spans always tile [0, cap).
        for (cap, r) in [(17usize, 5usize), (64, 8), (3, 3), (1, 1)] {
            let spans = chunk_spans(cap, r);
            assert_eq!(spans.len(), r);
            assert_eq!(spans.iter().map(|s| s.1).sum::<usize>(), cap);
            let mut pos = 0;
            for (start, len) in spans {
                assert_eq!(start, pos);
                pos += len;
            }
        }
    }

    #[test]
    fn weighted_spans_reduce_to_uniform_without_skew() {
        // Full (or equal) loads make every row weigh the same, so the
        // weighted split must reproduce chunk_spans exactly — including
        // the ragged and degenerate cases.
        for (cap, r) in [(8usize, 4usize), (7, 3), (17, 5), (2, 4), (1, 1)] {
            let full = vec![cap; 6];
            assert_eq!(chunk_spans_weighted(cap, r, &full), chunk_spans(cap, r), "cap={cap} r={r}");
        }
        // And sp_spans dispatches on the knob.
        let c = cfg();
        assert_eq!(sp_spans(&c, 10, 3), chunk_spans(10, 3));
        let mut skewed = cfg();
        skewed.skew = 1.5;
        assert_ne!(sp_spans(&skewed, 64, 4), chunk_spans(64, 4));
    }

    #[test]
    fn weighted_spans_balance_flops_not_rows() {
        // Loads concentrated on the head rows: the first span must be
        // short (hot rows) and the tail span long (cold rows), while all
        // spans still tile [0, cap).
        let loads = vec![16usize, 8, 4, 2]; // Zipf-ish, cap 16
        let spans = chunk_spans_weighted(16, 4, &loads);
        assert_eq!(spans.len(), 4);
        assert_eq!(spans.iter().map(|s| s.1).sum::<usize>(), 16);
        let mut pos = 0;
        for &(start, len) in &spans {
            assert_eq!(start, pos);
            assert!(len >= 1);
            pos += len;
        }
        assert!(
            spans[0].1 < spans[3].1,
            "head span {spans:?} should be shorter than the tail span"
        );
        // Per-span weights are balanced within one max row weight.
        let weight = |(start, len): (usize, usize)| -> usize {
            (start..start + len).map(|row| loads.iter().filter(|&&l| l > row).count()).sum()
        };
        let ws: Vec<usize> = spans.iter().map(|&s| weight(s)).collect();
        let (lo, hi) = (ws.iter().min().unwrap(), ws.iter().max().unwrap());
        assert!(hi - lo <= loads.len(), "span weights {ws:?} unbalanced");
    }

    #[test]
    fn weighted_spans_keep_chunk_count_when_cap_small() {
        // cap < chunks: zero-width tails, same shape contract as
        // chunk_spans so op counts and span counts agree.
        let spans = chunk_spans_weighted(2, 4, &[2, 1]);
        assert_eq!(spans.len(), 4);
        assert_eq!(spans.iter().map(|s| s.1).sum::<usize>(), 2);
        assert_eq!(&spans[2..], &[(2, 0), (2, 0)]);
    }

    #[test]
    fn loaded_chunk_flops_conserve_the_scaled_ffn() {
        // Σ_k flops(span_k) must equal ffn_load_scale · dense FFN for any
        // span partition (exact, by linearity of the mean-share pricing).
        let mut c = cfg();
        c.skew = 1.2;
        let cap = c.t_pausemp();
        let full = expert_flops(&c, expert_tokens_per_rank(&c, true)) * ffn_load_scale(&c, cap);
        for r in [1usize, 2, 3, 5] {
            for spans in [sp_spans(&c, cap, r), chunk_spans(cap, r)] {
                let sum: f64 =
                    spans.iter().map(|&s| sp_chunk_flops_span(&c, cap, s)).sum();
                assert!(
                    (sum - full).abs() / full < 1e-9,
                    "r={r}: per-chunk sum {sum} vs scaled dense {full}"
                );
            }
        }
        // Without skew the scale is 1 and the span model is the old one.
        let u = cfg();
        assert_eq!(ffn_load_scale(&u, u.t_pausemp()), 1.0);
        assert_eq!(sp_chunk_flops_span(&u, 10, (3, 4)), sp_chunk_flops(&u, 4));
    }

    #[test]
    fn load_fractions_follow_zipf_and_degrade_continuously() {
        let mut c = cfg();
        c.skew = 2.0;
        let fr = expert_load_fractions(&c).unwrap();
        assert_eq!(fr.len(), c.e);
        assert!((fr[0] - 1.0).abs() < 1e-12, "hottest expert normalized to 1");
        assert!(fr.windows(2).all(|w| w[0] >= w[1]), "monotone loads {fr:?}");
        assert!(fr[c.e - 1] < 0.5, "tail expert should be cold: {fr:?}");
        // skew → 0+: every expert approaches the head's load.
        c.skew = 1e-6;
        let fr = expert_load_fractions(&c).unwrap();
        assert!(fr.iter().all(|&f| f > 0.999), "near-uniform at tiny skew: {fr:?}");
        c.skew = 0.0;
        assert!(expert_load_fractions(&c).is_none());
    }

    #[test]
    fn all_zero_loads_fall_back_to_uniform_spans_without_nan() {
        // Regression: an all-zero expert-load vector (degenerate gate under
        // `--spans measured`) must not produce NaN span weights — the
        // weighted split falls back to the uniform one, and every span is
        // a well-formed (start, rows) pair tiling [0, cap).
        for (cap, r) in [(16usize, 4usize), (7, 3), (2, 4), (1, 1)] {
            let zeros = vec![0usize; 6];
            let spans = chunk_spans_weighted(cap, r, &zeros);
            assert_eq!(spans, chunk_spans(cap, r), "cap={cap} r={r}");
            assert_eq!(spans.iter().map(|s| s.1).sum::<usize>(), cap);
            // Empty load vector behaves identically.
            assert_eq!(chunk_spans_weighted(cap, r, &[]), chunk_spans(cap, r));
            assert_eq!(sp_spans_measured(cap, r, &zeros), chunk_spans(cap, r));
        }
    }

    #[test]
    fn measured_spans_balance_on_measured_loads() {
        // A head-heavy measured profile (organic imbalance, skew knob off)
        // must shorten the head span exactly like the expected-profile
        // weighted split would; flat or empty measurements reduce to the
        // uniform split; overhanging loads clamp to the capacity.
        let loads = vec![16usize, 8, 4, 2];
        assert_eq!(
            sp_spans_measured(16, 4, &loads),
            chunk_spans_weighted(16, 4, &loads)
        );
        assert_eq!(sp_spans_measured(16, 4, &[0, 0, 0]), chunk_spans(16, 4));
        assert_eq!(sp_spans_measured(16, 4, &[]), chunk_spans(16, 4));
        // Loads beyond cap behave like saturated experts.
        assert_eq!(
            sp_spans_measured(8, 2, &[100, 100]),
            chunk_spans(8, 2),
            "uniformly saturated loads are a flat profile"
        );
        let spans = sp_spans_measured(16, 4, &loads);
        assert_eq!(spans.iter().map(|s| s.1).sum::<usize>(), 16);
        assert!(spans[0].1 < spans[3].1, "{spans:?}");
    }

    #[test]
    fn measured_chunk_flops_conserve_the_measured_total() {
        // Σ_k flops(span_k) over ANY partition equals the flops of the
        // full measured fill — the same linearity contract the expected
        // profile keeps.
        let c = cfg();
        let cap = c.t_pausemp();
        let measured: Vec<usize> = (0..c.e).map(|j| cap / (j + 1)).collect();
        let full = sp_chunk_flops_measured(&c, cap, (0, cap), &measured);
        assert!(full > 0.0);
        for r in [1usize, 2, 3, 5] {
            for spans in [sp_spans_measured(cap, r, &measured), chunk_spans(cap, r)] {
                let sum: f64 = spans
                    .iter()
                    .map(|&s| sp_chunk_flops_measured(&c, cap, s, &measured))
                    .sum();
                assert!(
                    (sum - full).abs() / full < 1e-9,
                    "r={r}: per-chunk sum {sum} vs full {full}"
                );
            }
        }
    }

    #[test]
    fn measured_ffn_scale_matches_fill_and_falls_back() {
        let c = cfg();
        let cap = c.t_pausemp();
        // A fully saturated measurement prices the dense FFN.
        let full = vec![cap; c.e];
        assert!((ffn_load_scale_measured(&c, cap, &full) - 1.0).abs() < 1e-12);
        // Half-filled experts price half the dense FFN.
        let half: Vec<usize> = vec![cap / 2; c.e];
        let got = ffn_load_scale_measured(&c, cap, &half);
        let want = (cap / 2) as f64 / cap as f64;
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        // All-zero (degenerate gate) falls back to the expected profile —
        // 1.0 with the skew knob off, the Zipf scale with it on.
        assert_eq!(ffn_load_scale_measured(&c, cap, &[0, 0, 0]), 1.0);
        assert_eq!(ffn_load_scale_measured(&c, cap, &[]), 1.0);
        let mut skewed = cfg();
        skewed.skew = 1.5;
        assert_eq!(
            ffn_load_scale_measured(&skewed, cap, &[0; 4]),
            ffn_load_scale(&skewed, cap)
        );
        // Conservation: scaled monolithic FFN == Σ per-chunk measured flops.
        let measured: Vec<usize> = (0..c.e).map(|j| cap / (j + 1)).collect();
        let scaled = expert_flops(&c, expert_tokens_per_rank(&c, true))
            * ffn_load_scale_measured(&c, cap, &measured);
        for r in [1usize, 2, 4] {
            let sum: f64 = chunk_spans(cap, r)
                .iter()
                .map(|&s| sp_chunk_flops_measured(&c, cap, s, &measured))
                .sum();
            assert!((sum - scaled).abs() / scaled < 1e-9, "r={r}: {sum} vs {scaled}");
        }
    }

    #[test]
    fn loads_from_weights_track_concentration() {
        let c = cfg();
        let cap = 64;
        // Uniform weights: every expert filled to cap/f (the uniform
        // router's expected occupancy), all equal.
        let uni = loads_from_weights(&c, cap, &vec![1.0; c.e]);
        assert_eq!(uni.len(), c.e);
        assert!(uni.windows(2).all(|w| w[0] == w[1]), "{uni:?}");
        let want = (cap as f64 / c.f + 0.5).floor() as usize;
        assert_eq!(uni[0], want, "{uni:?}");
        // Zipf-shaped weights reproduce the expected-profile SHAPE:
        // monotone nonincreasing, hottest expert saturating under strong
        // concentration.
        let zipf: Vec<f64> = (0..c.e).map(|j| ((j + 1) as f64).powf(-2.0)).collect();
        let skewed = loads_from_weights(&c, cap, &zipf);
        assert!(skewed.windows(2).all(|w| w[0] >= w[1]), "{skewed:?}");
        assert!(skewed[0] > skewed[c.e - 1], "{skewed:?}");
        assert_eq!(skewed[0], cap, "hot expert saturates its capacity block");
        // Total mass responds to concentration: the skewed profile routes
        // less aggregate fill than the uniform one (hot expert clipped at
        // capacity, tail starved).
        assert!(
            skewed.iter().sum::<usize>() < uni.iter().sum::<usize>(),
            "{skewed:?} vs {uni:?}"
        );
        // All-zero weights → all-zero loads (degenerate gate step).
        assert_eq!(loads_from_weights(&c, cap, &[0.0; 4]), vec![0; 4]);
        assert_eq!(loads_from_weights(&c, cap, &[]), Vec::<usize>::new());
    }

    #[test]
    fn sp_chunk_volumes_sum_to_fused_totals() {
        let c = cfg();
        let t = c.t_pausemp();
        for r in [1usize, 2, 3, 4] {
            let spans = chunk_spans(t, r);
            let bytes: f64 = spans.iter().map(|s| bytes_sp_chunk_per_pair(&c, s.1)).sum();
            assert!((bytes - bytes_fused_a2a_per_pair(&c)).abs() < 1e-9, "r={r}");
            let flops: f64 = spans.iter().map(|s| sp_chunk_flops(&c, s.1)).sum();
            let full = expert_flops(&c, expert_tokens_per_rank(&c, true));
            assert!((flops - full).abs() / full < 1e-12, "r={r}");
        }
        assert_eq!(sp_clamp_chunks(&c, 0), 1);
        assert_eq!(sp_clamp_chunks(&c, 100), crate::comm::tags::SP_MAX_CHUNKS);
    }

    #[test]
    fn bytes_helpers_scale_linearly_in_element_width() {
        // Every volume helper is elements × dtype_bytes: doubling the
        // element width must exactly double the bytes, at every width.
        // Guards the volume-module refactor — a helper that baked in a
        // width (or the wire policy) would break this linearity.
        let helpers: [(&str, fn(&MoeLayerConfig) -> f64); 6] = [
            ("esp_ag", bytes_esp_ag_per_rank),
            ("ep_a2a", bytes_ep_a2a_per_pair),
            ("esp_ar", bytes_esp_ar_total),
            ("fused_a2a", bytes_fused_a2a_per_pair),
            ("mp_ag_s1", bytes_mp_ag_s1_per_rank),
            ("mp_ag_s2", bytes_mp_ag_s2_per_rank),
        ];
        let unit = {
            let mut c = cfg();
            c.dtype_bytes = 1;
            c
        };
        for width in [1usize, 2, 4, 8] {
            let mut c = cfg();
            c.dtype_bytes = width;
            for (name, h) in helpers {
                assert_eq!(h(&c), h(&unit) * width as f64, "{name} at width {width}");
            }
            assert_eq!(
                bytes_wgrad_per_rank(&c),
                bytes_wgrad_per_rank(&unit) * width as f64,
                "wgrad at width {width}"
            );
            assert_eq!(
                bytes_sp_chunk_per_pair(&c, 5),
                bytes_sp_chunk_per_pair(&unit, 5) * width as f64,
                "sp_chunk at width {width}"
            );
        }
    }

    #[test]
    fn sp_chunk_volumes_conserve_totals_at_every_width() {
        // Per-chunk SP/SP2 volumes must partition the monolithic fused
        // total regardless of the element width and span policy — the
        // conservation law that keeps chunked and monolithic schedules
        // pricing the same traffic.
        for width in [1usize, 2, 4, 8] {
            let mut c = cfg();
            c.dtype_bytes = width;
            let t = c.t_pausemp();
            for r in [1usize, 2, 3, 4, 7] {
                for spans in [chunk_spans(t, r), sp_spans(&c, t, r)] {
                    let sum: f64 = spans.iter().map(|s| bytes_sp_chunk_per_pair(&c, s.1)).sum();
                    assert_eq!(sum, bytes_fused_a2a_per_pair(&c), "width={width} r={r}");
                }
            }
            // And under a skewed (load-aware) span policy.
            let mut skewed = c.clone();
            skewed.skew = 1.5;
            let cap = skewed.t_pausemp();
            for r in [2usize, 4] {
                let sum: f64 = sp_spans(&skewed, cap, r)
                    .iter()
                    .map(|s| bytes_sp_chunk_per_pair(&skewed, s.1))
                    .sum();
                assert_eq!(sum, bytes_fused_a2a_per_pair(&skewed), "skewed width={width} r={r}");
            }
        }
    }

    #[test]
    fn wire_factor_is_per_leg_and_unit_by_default() {
        use crate::config::{WireDtype, WirePrecision};
        let c = cfg();
        for leg in WireLeg::ALL {
            assert_eq!(wire_factor(&c, leg), 1.0, "{leg:?} default");
        }
        let mut w = cfg();
        w.wire = WirePrecision::uniform(WireDtype::Bf16).with_leg(WireLeg::Wgrad, WireDtype::F32);
        assert_eq!(wire_factor(&w, WireLeg::Dispatch), 0.5);
        assert_eq!(wire_factor(&w, WireLeg::Combine), 0.5);
        assert_eq!(wire_factor(&w, WireLeg::AllGather), 0.5);
        assert_eq!(wire_factor(&w, WireLeg::Wgrad), 1.0);
        // The factor is relative to the MODEL width: a bf16 model dtype
        // with an f32 wire prices 2× the op bytes.
        let mut narrow = cfg();
        narrow.dtype_bytes = 2;
        assert_eq!(wire_factor(&narrow, WireLeg::Dispatch), 2.0);
        narrow.wire = WirePrecision::uniform(WireDtype::Bf16);
        assert_eq!(wire_factor(&narrow, WireLeg::Dispatch), 1.0);
    }
}
