//! The paper's contribution: dedicated MoE-layer schedules.
//!
//! * [`ops`] — the schedule IR shared by timing and numerics.
//! * [`builders`] — Baseline (Fig 3a), S1 (Fig 3b), S2 (Fig 3c, with SAA
//!   or AAS combine) forward/backward programs.
//! * [`lowering`] — programs → transfer/compute DAGs → simulated time.

pub mod builders;
pub mod lowering;
pub mod ops;

pub use builders::{backward_ops, forward_ops, iteration_ops};
pub use lowering::{lower_ops, simulate_forward, simulate_iteration};
pub use ops::{Op, ScheduleKind};
