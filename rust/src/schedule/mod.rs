//! The paper's contribution: dedicated MoE-layer schedules.
//!
//! * [`ops`] — the schedule IR: one MoE layer's execution under one
//!   schedule is a short program of [`ops::Op`]s, and this IR is the ONLY
//!   place communication structure is defined.
//! * [`builders`] — Baseline (Fig 3a), S1 (Fig 3b), S2 (Fig 3c, with SAA
//!   or AAS combine) forward/backward programs.
//! * [`interp`] — the transport-generic interpreter: ONE walker over the
//!   op program, shared by the timing plane and the data plane. Which
//!   collective an op is, over which process groups it runs, and how its
//!   messages chain exists exactly once (here and in
//!   [`crate::comm::algo`]).
//! * [`lowering`] — the timing plane: programs → transfer/compute DAGs →
//!   simulated time, via the interpreter over a
//!   [`crate::comm::transport::DagTransport`]. (The data plane lives in
//!   [`crate::moe::exec`], via the same interpreter over a
//!   [`crate::comm::transport::DataTransport`].)

pub mod builders;
pub mod interp;
pub mod lowering;
pub mod ops;

pub use builders::{backward_ops, forward_ops, iteration_ops};
pub use interp::{run_program, Machine};
pub use lowering::{lower_ops, simulate_forward, simulate_iteration};
pub use ops::{Op, ScheduleKind};
