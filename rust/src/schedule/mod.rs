//! The paper's contribution: dedicated MoE-layer schedules.
//!
//! * [`ops`] — the schedule IR: one MoE layer's execution under one
//!   schedule is a short program of [`ops::Op`]s, and this IR is the ONLY
//!   place communication structure is defined.
//! * [`builders`] — Baseline (Fig 3a), S1 (Fig 3b), S2 (Fig 3c, with SAA
//!   or AAS combine) and SP forward/backward programs.
//! * [`interp`] — the transport-generic interpreter: ONE walker over the
//!   op program, shared by the timing plane and the data plane. Which
//!   collective an op is, over which process groups it runs, and how its
//!   messages chain exists exactly once (here and in
//!   [`crate::comm::algo`]).
//! * [`lowering`] — the timing plane: programs → transfer/compute DAGs →
//!   simulated time, via the interpreter over a
//!   [`crate::comm::transport::DagTransport`]. (The data plane lives in
//!   [`crate::moe::exec`], via the same interpreter over a
//!   [`crate::comm::transport::DataTransport`].)
//!
//! # SP — the chunk-pipelined schedule
//!
//! [`ops::ScheduleKind::Pipelined`] (`sp` / `spN` on the CLI) is the first
//! schedule *family*: S1's op structure with the fused dispatch AlltoAll,
//! the expert FFN and the combine AlltoAll split into `r` capacity chunks
//! (FSMoE-style). The builder emits `D_0, [D_{k+1}], F_k, C_k` per chunk
//! with per-chunk tags (`sp.dispatch.k` / `sp.ffn.k` / `sp.combine.k`);
//! the interpreter runs the region on two per-rank streams — chunked
//! AlltoAlls chain on a comm stream, chunked FFNs on a compute stream, so
//! chunk k's combine overlaps chunk k+1's compute — and joins them back at
//! the region's last combine. Both planes inherit the pipelining from the
//! interpreter: the timing plane sees interleaved transfer/compute tasks,
//! the data plane stages chunk-indexed tensors and reassembles the full
//! returned block before the local combine. Because the cost of SP depends
//! on a knob, `r` is chosen in closed form
//! ([`crate::perfmodel::closedform::optimal_chunks`], fitted variant in
//! [`crate::perfmodel::selection`]) and Algorithm 1 generalizes to the
//! argmin over {S1, S2, SP(r*), SP2(r*)}.
//!
//! # SP2 — the chunk-pipelined S2 (SP × SAA)
//!
//! [`ops::ScheduleKind::PipelinedS2`] (`sp2` / `sp2N` on the CLI) is the
//! fourth family member and the first schedule composing TWO overlap
//! mechanisms. It is S2's op structure (gate on full tokens, MpSplit of
//! the capacity dimension, no trailing MP-AllGather) with the dispatch
//! AlltoAll, the expert FFN and the SAA-overlapped combine split into `r`
//! capacity chunks (per-chunk tags `sp2.dispatch.k` / `sp2.ffn.k` /
//! `sp2.saa.k`). Each chunk's combine runs as a **chunked SAA**
//! ([`crate::comm::algo::saa`] with a chunk-sized payload): the chunk's
//! EP&ESP-AlltoAll phases forward its combine output into the
//! MP-AllGather on the intra-node link class (S2's overlap) while the
//! next chunk's FFN computes on the pipelined region's compute stream
//! (SP's overlap). The interpreter runs the region on the same dual
//! per-rank streams as SP; the data plane stages per-chunk gathered
//! blocks and reassembles the MP-peer-major buffer S2's LocalCombine
//! expects, so SP2's numerics equal the dense reference exactly like the
//! monolithic S2. `r` is chosen by
//! [`crate::perfmodel::closedform::optimal_chunks_sp2`] (fitted variant
//! priced per chunk by the `SaaS2` collective model). SP2 wins where the
//! fleet is inter-dominant (slow NIC) with MP > 1 and compute comparable
//! to the per-chunk communication — there SP's exposed AG epilogue and
//! S2's unhidden FFN both cost more than the composed overlap.
//!
//! # Load-aware spans (skewed routing)
//!
//! Real gates route unevenly. The routing-skew knob
//! ([`crate::config::MoeLayerConfig::skew`], `--skew` on the CLI) biases
//! the router's logits by `-s·ln(j+1)` so expert popularity follows a Zipf
//! law, and the span policy becomes **load-aware**: instead of splitting
//! capacity rows uniformly, [`ops::chunk_spans_weighted`] balances
//! *estimated per-chunk FLOPs* from the gate's expected per-expert loads
//! ([`ops::expected_loads`]) — hot head rows get short spans, the sparse
//! tail long ones, so per-chunk FFN times equalize and chunk k's combine
//! stays hidden behind chunk k+1's compute. [`ops::sp_spans`] is the ONE
//! policy shared by the builder, both perf-model evaluators (the pipeline
//! recurrence takes full `(start, rows)` spans) and — by decoding the op
//! byte fields, clamped against the gate's actual capacity — the data
//! plane. [`ops::ScheduleKind::PipelinedUniform`] (`spu` / `spuN`) keeps
//! uniform spans as the ablation: identical to SP at `skew == 0`, the
//! contrast column (`SP-uni`) in skewed sweeps. Every schedule's
//! monolithic FFN term is scaled by the same load model
//! ([`ops::ffn_load_scale`]) so S1/S2/baseline and the SP chunks price
//! compute consistently.
//!
//! # The backward program (whole-iteration schedules)
//!
//! Every family's backward pass is a first-class op program
//! ([`builders::backward_ops`]), not a scalar heuristic: the adjoint of
//! each forward op, emitted in reverse. Dispatch and combine swap roles
//! under transposition — the backward *dispatch* AlltoAll carries dY along
//! the forward combine's pairs and the backward *combine* carries dX along
//! the forward dispatch's pairs, with per-pair volumes identical to the
//! forward ones (tags `bwd.ep.*` / `bwd.fused.*` / `bwd.sp.*` /
//! `bwd.sp2.*` in [`crate::comm::tags`]). The expert FFN splits into
//! **dgrad** (feeds the backward combine) and **wgrad** (a pure
//! compute-stream sink), and the forward's free MpSplit/EspSplit ops
//! become real AllGathers in reverse — which is why a family's backward
//! is strictly more than a mirrored forward. The expert **wgrad
//! AllReduce** ([`ops::Op::BwdWgradAllReduce`], sized by
//! [`ops::bytes_wgrad_per_rank`]) is scheduled onto the same dual
//! comm/compute stream frontiers the SP/SP2 regions use: with
//! `overlap == true` (the default) the interpreter defers its completion
//! handles so the reduction rides under the remaining backward ops and
//! only its *exposed* tail (if any) extends the makespan;
//! [`builders::backward_ops_overlap`] exposes the serialized ablation.
//! The perf model mirrors all of this in closed form (`t_bwd_*`,
//! `t_iter_*` in [`crate::perfmodel::closedform`]) and Algorithm 1's
//! argmin compares **whole iterations**, not forward passes.
//!
//! Besides the expected-profile policy there is a **two-pass** variant:
//! [`ops::sp_spans_measured`] re-balances the spans from the gate's
//! *measured* per-expert loads (max-aggregated over ranks —
//! [`crate::moe::exec::measure_expert_loads`]), covering organic,
//! non-Zipf imbalance the skew knob cannot model. The builders take the
//! measurement through [`builders::forward_ops_measured`]; on the CLI it
//! is `parm sim --spans measured`, and on the data plane
//! [`crate::moe::exec::run_schedule_measured`].
//!
//! # The static verifier
//!
//! [`verify`] proves an op program well-formed WITHOUT executing it — a
//! single symbolic walk that mirrors the interpreter's frontier semantics
//! over a dependency graph and reports typed [`verify::VerifyError`]s,
//! one per violated rule. Six rules cover the invariant classes the
//! schedules rest on:
//!
//! * `volume-conservation` — monolithic collectives carry their
//!   closed-form volumes; a pipelined region's chunked dispatch/combine
//!   bytes sum to the monolithic fused AlltoAll; combine chunk k
//!   transposes dispatch chunk k; chunk FFN flops are positive and
//!   bounded by the dense capacity FFN.
//! * `span-discipline` — chunk spans cover whole capacity rows, are
//!   emitted in order, and partition the capacity.
//! * `frontier-safety` — every op's completion is reachable from the
//!   program's final join (no detached completions, even for zero-byte
//!   chunks) and the dependency graph is acyclic.
//! * `tag-discipline` — every tag exists in [`crate::comm::tags::all`],
//!   chunk indices are dense `0..r`, and the wire-leg classification
//!   matches the op kind.
//! * `plane-capability` — backward ops in a data-plane program are a
//!   structured diagnostic, not a runtime bail.
//! * `group-validity` — MP/EP/ESP groups partition the world (the same
//!   logic [`crate::comm::saa::validate_mp_partition`] delegates to).
//!
//! Three wiring points keep the verifier honest: debug builds run the
//! structural rules inside [`interp::run_program`] on EVERY program (so
//! the whole test suite transitively exercises them) and the full
//! config-aware pass inside [`lowering::lower_ops`]; `parm lint` sweeps
//! builders × families × a config grid from the CLI; and
//! `tests/verify_mutations.rs` pins each rule with seeded IR corruptions.
//! To add a rule, see the "How to add a rule" section of [`verify`].

pub mod builders;
pub mod interp;
pub mod lowering;
pub mod ops;
pub mod verify;

pub use builders::{backward_ops, backward_ops_overlap, forward_ops, iteration_ops};
pub use interp::{run_program, Machine};
pub use lowering::{lower_ops, simulate_backward_overlap, simulate_forward, simulate_iteration};
pub use ops::{Op, ScheduleKind};
pub use verify::{
    check_program, check_structure, rule_counts, verify_program, verify_structure, Plane, Rule,
    VerifyError,
};
