//! Schedule builders: the forward and backward op programs for the
//! Baseline (Fig 3a), S1 (Fig 3b), S2 (Fig 3c) and the chunk-pipelined
//! SP and SP2 (SP × SAA) schedules. The backward pass is a first-class
//! program per family — transposed dispatch/combine AlltoAlls, split
//! dgrad/wgrad expert compute, and the wgrad AllReduce scheduled to
//! overlap the remaining backward ops (see [`backward_ops`]).

use crate::config::MoeLayerConfig;

use super::ops::{self, Op, ScheduleKind};

/// Forward op program for one MoE layer under `kind`.
///
/// `kind` must be concrete (not [`ScheduleKind::Parm`]) — resolve Parm via
/// [`crate::perfmodel::PerfModel::choose`] first.
pub fn forward_ops(kind: ScheduleKind, c: &MoeLayerConfig) -> Vec<Op> {
    forward_ops_measured(kind, c, None)
}

/// The ONE load-aware span policy shared by the SP and SP2 builder arms:
/// FLOPs-balanced from the gate's **measured** loads when a two-pass
/// measurement is present, from the expected profile otherwise
/// ([`ops::sp_spans`]). `chunks` is clamped here so callers cannot
/// desynchronize span counts from op counts.
fn sp_policy_spans(
    c: &MoeLayerConfig,
    chunks: usize,
    measured: Option<&[usize]>,
) -> Vec<(usize, usize)> {
    let cap = c.t_pausemp();
    let clamped = ops::sp_clamp_chunks(c, chunks);
    match measured {
        Some(loads) => ops::sp_spans_measured(cap, clamped, loads),
        None => ops::sp_spans(c, cap, clamped),
    }
}

/// The matching per-chunk FFN pricing: measured filled rows when the
/// two-pass profile is present, the expected load model otherwise.
fn sp_policy_flops(c: &MoeLayerConfig, span: (usize, usize), measured: Option<&[usize]>) -> f64 {
    let cap = c.t_pausemp();
    match measured {
        Some(loads) => ops::sp_chunk_flops_measured(c, cap, span, loads),
        None => ops::sp_chunk_flops_span(c, cap, span),
    }
}

/// The matching monolithic-FFN pricing: the measured load scale when a
/// profile is supplied ([`ops::ffn_load_scale_measured`]), the expected
/// one otherwise — so Baseline/S1/S2 and the SP chunks price compute from
/// the same profile whichever source it came from.
fn ffn_scale_policy(c: &MoeLayerConfig, cap: usize, flop_loads: Option<&[usize]>) -> f64 {
    match flop_loads {
        Some(loads) => ops::ffn_load_scale_measured(c, cap, loads),
        None => ops::ffn_load_scale(c, cap),
    }
}

/// [`forward_ops`] with an optional **measured** per-expert load profile
/// (the two-pass span mode, `--spans measured`): when provided and the
/// schedule is the load-aware SP family, chunk spans are FLOPs-balanced
/// from the measurement ([`ops::sp_spans_measured`]) and ALL expert
/// compute — chunk FFNs and the monolithic schedules' FFN alike — priced
/// by it, covering organic imbalance the expected Zipf profile cannot
/// see. All-zero measurements are ignored (expected-profile behaviour).
pub fn forward_ops_measured(
    kind: ScheduleKind,
    c: &MoeLayerConfig,
    measured: Option<&[usize]>,
) -> Vec<Op> {
    forward_ops_traffic(kind, c, measured, measured)
}

/// The two-profile core behind [`forward_ops_measured`] — the online
/// controller's view of one step: `span_loads` is the *stale* profile the
/// chunk spans were planned from (the previous step's measurement — the
/// only thing an online re-span can know), `flop_loads` the profile the
/// step *actually* routes, pricing every expert-FFN op. Communication
/// volumes stay dense either way (zero-padded capacity slabs move
/// regardless of fill), so the two profiles only differ in pipeline
/// balance — exactly the gap an adaptive re-span closes. Passing the same
/// profile for both recovers the two-pass measured mode.
pub fn forward_ops_traffic(
    kind: ScheduleKind,
    c: &MoeLayerConfig,
    span_loads: Option<&[usize]>,
    flop_loads: Option<&[usize]>,
) -> Vec<Op> {
    let measured = span_loads.filter(|l| l.iter().sum::<usize>() > 0);
    let flop_loads = flop_loads.filter(|l| l.iter().sum::<usize>() > 0);
    let d = c.dtype_bytes as f64;
    match kind {
        ScheduleKind::Parm => panic!("resolve Parm to S1/S2 via the perf model first"),
        ScheduleKind::Baseline => {
            let gathered_tokens = c.tokens() * c.par.n_esp;
            // Expert outputs returned to this rank before the split:
            // gathered tokens' combined outputs (the A2A-combine result).
            let split_bytes = (gathered_tokens * c.m) as f64 * d / c.par.n_esp as f64;
            vec![
                Op::EspAllGather { bytes_per_rank: ops::bytes_esp_ag_per_rank(c) },
                Op::Gate { flops_per_rank: ops::gate_flops(c, gathered_tokens) },
                Op::EpAlltoAll { bytes_per_pair: ops::bytes_ep_a2a_per_pair(c) },
                Op::ExpertFfn {
                    flops_per_rank: ops::expert_flops(c, ops::expert_tokens_per_rank(c, false))
                        * ffn_scale_policy(c, c.t(), flop_loads),
                },
                Op::EspAllReduce { total_bytes: ops::bytes_esp_ar_total(c) },
                Op::EpAlltoAll { bytes_per_pair: ops::bytes_ep_a2a_per_pair(c) },
                // Un-gate back to gathered-token order, THEN the ESP-Split
                // keeps each rank's own token rows — the order the data
                // plane actually executes (both are rank-local; the free
                // split does not move the timing frontier either way).
                Op::Ungate {
                    flops_per_rank: (c.tokens() * c.k * c.m) as f64,
                },
                Op::EspSplit { bytes_per_rank: split_bytes },
            ]
        }
        ScheduleKind::S1 => {
            let local_tokens = c.tokens() / c.par.n_mp;
            // Returned partial copies to combine: (E, T/N_MP, M) × N_ESP.
            let combine_elems =
                (c.e * c.t_pausemp() * c.m) as f64 * (c.par.n_esp.saturating_sub(1)) as f64;
            vec![
                Op::MpSplit {
                    bytes_per_rank: (c.input_elems() / c.par.n_mp) as f64 * d,
                },
                Op::Gate { flops_per_rank: ops::gate_flops(c, local_tokens) },
                Op::FusedAlltoAll { bytes_per_pair: ops::bytes_fused_a2a_per_pair(c) },
                Op::ExpertFfn {
                    flops_per_rank: ops::expert_flops(c, ops::expert_tokens_per_rank(c, true))
                        * ffn_scale_policy(c, c.t_pausemp(), flop_loads),
                },
                Op::FusedAlltoAll { bytes_per_pair: ops::bytes_fused_a2a_per_pair(c) },
                Op::LocalCombine { flops_per_rank: combine_elems },
                Op::Ungate { flops_per_rank: (local_tokens * c.k * c.m) as f64 },
                Op::MpAllGather { bytes_per_rank: ops::bytes_mp_ag_s1_per_rank(c) },
            ]
        }
        ScheduleKind::Pipelined { chunks } | ScheduleKind::PipelinedUniform { chunks } => {
            if chunks == 0 {
                panic!("resolve SP's chunk count r via the perf model first");
            }
            let local_tokens = c.tokens() / c.par.n_mp;
            let combine_elems =
                (c.e * c.t_pausemp() * c.m) as f64 * (c.par.n_esp.saturating_sub(1)) as f64;
            // Load-aware spans for the Pipelined family (FLOPs-balanced
            // from the gate's expected loads when the skew knob is on);
            // the PipelinedUniform ablation keeps raw-row spans but still
            // prices compute by the load model, so the two variants differ
            // only in where the chunk boundaries fall.
            let spans = if matches!(kind, ScheduleKind::Pipelined { .. }) {
                sp_policy_spans(c, chunks, measured)
            } else {
                ops::chunk_spans(c.t_pausemp(), ops::sp_clamp_chunks(c, chunks))
            };
            let chunk_flops = |span: (usize, usize)| sp_policy_flops(c, span, flop_loads);
            let r = spans.len();
            // S1's prologue/epilogue with the dispatch→FFN→combine middle
            // split into r capacity chunks. Emission order D_0, then per
            // chunk k: [D_{k+1}], F_k, C_k — the comm stream chains the
            // chunked AlltoAlls in this order while F_k only waits on its
            // own chunk's dispatch, so C_k overlaps F_{k+1}'s compute and
            // D_{k+1} overlaps F_k's.
            let mut v = vec![
                Op::MpSplit {
                    bytes_per_rank: (c.input_elems() / c.par.n_mp) as f64 * d,
                },
                Op::Gate { flops_per_rank: ops::gate_flops(c, local_tokens) },
                Op::SpDispatch {
                    bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[0].1),
                    index: 0,
                    of: r,
                },
            ];
            for k in 0..r {
                if k + 1 < r {
                    v.push(Op::SpDispatch {
                        bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[k + 1].1),
                        index: k + 1,
                        of: r,
                    });
                }
                v.push(Op::SpExpertFfn {
                    flops_per_rank: chunk_flops(spans[k]),
                    index: k,
                    of: r,
                });
                v.push(Op::SpCombine {
                    bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[k].1),
                    index: k,
                    of: r,
                });
            }
            v.push(Op::LocalCombine { flops_per_rank: combine_elems });
            v.push(Op::Ungate { flops_per_rank: (local_tokens * c.k * c.m) as f64 });
            v.push(Op::MpAllGather { bytes_per_rank: ops::bytes_mp_ag_s1_per_rank(c) });
            v
        }
        ScheduleKind::PipelinedS2 { chunks } => {
            if chunks == 0 {
                panic!("resolve SP2's chunk count r via the perf model first");
            }
            // S2's prologue/epilogue (gate on the full MP-duplicated token
            // set, MpSplit of the capacity dimension, no trailing
            // MP-AllGather — each chunk's SAA already gathers) with the
            // dispatch→FFN→combine middle split into r capacity chunks.
            // Emission order mirrors SP: D_0, then per chunk k:
            // [D_{k+1}], F_k, SAA_k — the chunked AlltoAlls chain on the
            // comm stream while each chunk's SAA forwards its combine
            // output into the MP-AllGather on the intra-node class.
            let combine_elems =
                (c.e * c.t_pausemp() * c.m) as f64 * (c.par.n_esp.saturating_sub(1)) as f64;
            let spans = sp_policy_spans(c, chunks, measured);
            let chunk_flops = |span: (usize, usize)| sp_policy_flops(c, span, flop_loads);
            let r = spans.len();
            let mut v = vec![
                Op::Gate { flops_per_rank: ops::gate_flops(c, c.tokens()) },
                Op::MpSplit { bytes_per_rank: ops::bytes_mp_ag_s2_per_rank(c) },
                Op::Sp2Dispatch {
                    bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[0].1),
                    index: 0,
                    of: r,
                },
            ];
            for k in 0..r {
                if k + 1 < r {
                    v.push(Op::Sp2Dispatch {
                        bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[k + 1].1),
                        index: k + 1,
                        of: r,
                    });
                }
                v.push(Op::Sp2ExpertFfn {
                    flops_per_rank: chunk_flops(spans[k]),
                    index: k,
                    of: r,
                });
                v.push(Op::Sp2Saa {
                    bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[k].1),
                    index: k,
                    of: r,
                });
            }
            v.push(Op::LocalCombine { flops_per_rank: combine_elems });
            v.push(Op::Ungate { flops_per_rank: (c.tokens() * c.k * c.m) as f64 });
            v
        }
        ScheduleKind::S2 | ScheduleKind::S2Aas => {
            let combine_elems =
                (c.e * c.t_pausemp() * c.m) as f64 * (c.par.n_esp.saturating_sub(1)) as f64;
            let combine = if kind == ScheduleKind::S2 {
                Op::SaaCombine { bytes_per_pair: ops::bytes_fused_a2a_per_pair(c) }
            } else {
                Op::AasCombine { bytes_per_pair: ops::bytes_fused_a2a_per_pair(c) }
            };
            vec![
                // Gate runs on the full (MP-duplicated) token set.
                Op::Gate { flops_per_rank: ops::gate_flops(c, c.tokens()) },
                Op::MpSplit {
                    bytes_per_rank: ops::bytes_mp_ag_s2_per_rank(c),
                },
                Op::FusedAlltoAll { bytes_per_pair: ops::bytes_fused_a2a_per_pair(c) },
                Op::ExpertFfn {
                    flops_per_rank: ops::expert_flops(c, ops::expert_tokens_per_rank(c, true))
                        * ffn_scale_policy(c, c.t_pausemp(), flop_loads),
                },
                // Second fused AlltoAll overlapped with the MP-AllGather of
                // the (E, T/N_MP, M) combine output — AG_MP(ETM) in Eq. 14.
                combine,
                Op::LocalCombine { flops_per_rank: combine_elems },
                Op::Ungate { flops_per_rank: (c.tokens() * c.k * c.m) as f64 },
            ]
        }
    }
}

/// Backward op program: a first-class per-family program (NOT a mechanical
/// reversal of the forward). Each forward collective appears as its
/// adjoint, in reverse program order, under the `bwd.*` tag vocabulary of
/// [`crate::comm::tags`]:
///
/// | forward                  | backward                                  |
/// |--------------------------|-------------------------------------------|
/// | AllGather(x)             | ReduceScatter(x)                          |
/// | Split (free)             | AllGather (Fig 3 note)                    |
/// | dispatch AlltoAll        | `bwd.*.combine` AlltoAll (returns dX)     |
/// | combine AlltoAll / SAA   | `bwd.*.dispatch` AlltoAll (carries dY)    |
/// | AllReduce                | AllReduce (same volume)                   |
/// | expert FFN f             | dgrad f + wgrad f + wgrad-AllReduce       |
/// | other compute f          | 2·f (adjoint of the local op)             |
///
/// The expert weight gradients the ESP replicas compute from different
/// token shards are synchronized by a dedicated
/// [`Op::BwdWgradAllReduce`], emitted right after the wgrad compute and
/// **overlapped** with the remaining backward ops (the epilogue's
/// transposed combine AlltoAll, gate adjoint and MP collectives) via the
/// interpreter's deferred-completion path — the FSMoE-style backward win.
/// The SP/SP2 regions additionally split the gradient FFN per chunk into
/// dgrad (feeds that chunk's combine) and wgrad (compute-stream only, so
/// the combine AlltoAll overlaps it).
pub fn backward_ops(kind: ScheduleKind, c: &MoeLayerConfig) -> Vec<Op> {
    backward_ops_measured(kind, c, None)
}

/// [`backward_ops`] under an optional measured load profile (see
/// [`forward_ops_measured`]).
pub fn backward_ops_measured(
    kind: ScheduleKind,
    c: &MoeLayerConfig,
    measured: Option<&[usize]>,
) -> Vec<Op> {
    backward_ops_overlap(kind, c, measured, true)
}

/// [`backward_ops_measured`] with an explicit wgrad-AllReduce scheduling
/// knob: `overlap == true` (the default everywhere) defers the
/// reduction's completion so it rides under the remaining backward ops;
/// `overlap == false` chains it on the main frontier — the non-overlapped
/// ablation lowering the acceptance tests compare against.
pub fn backward_ops_overlap(
    kind: ScheduleKind,
    c: &MoeLayerConfig,
    measured: Option<&[usize]>,
    overlap: bool,
) -> Vec<Op> {
    backward_ops_traffic_overlap(kind, c, measured, measured, overlap)
}

/// Two-profile backward program (see [`forward_ops_traffic`]): spans from
/// the stale `span_loads`, all gradient FFN compute priced at the actual
/// `flop_loads`.
pub fn backward_ops_traffic(
    kind: ScheduleKind,
    c: &MoeLayerConfig,
    span_loads: Option<&[usize]>,
    flop_loads: Option<&[usize]>,
) -> Vec<Op> {
    backward_ops_traffic_overlap(kind, c, span_loads, flop_loads, true)
}

/// [`backward_ops_traffic`] with the wgrad-AllReduce overlap knob.
pub fn backward_ops_traffic_overlap(
    kind: ScheduleKind,
    c: &MoeLayerConfig,
    span_loads: Option<&[usize]>,
    flop_loads: Option<&[usize]>,
    overlap: bool,
) -> Vec<Op> {
    let measured = span_loads.filter(|l| l.iter().sum::<usize>() > 0);
    let flop_loads = flop_loads.filter(|l| l.iter().sum::<usize>() > 0);
    let d = c.dtype_bytes as f64;
    let wgrad_ar = Op::BwdWgradAllReduce { bytes_per_rank: ops::bytes_wgrad_per_rank(c), overlap };
    match kind {
        ScheduleKind::Parm => panic!("resolve Parm to S1/S2 via the perf model first"),
        ScheduleKind::Baseline => {
            let gathered_tokens = c.tokens() * c.par.n_esp;
            let split_bytes = (gathered_tokens * c.m) as f64 * d / c.par.n_esp as f64;
            let ffn = ops::expert_flops(c, ops::expert_tokens_per_rank(c, false))
                * ffn_scale_policy(c, c.t(), flop_loads);
            vec![
                // Adjoint of the ESP-Split: gather the output-gradient
                // slices back to the gathered-token view (Fig 3 note).
                Op::EspAllGather { bytes_per_rank: split_bytes },
                Op::Ungate { flops_per_rank: 2.0 * (c.tokens() * c.k * c.m) as f64 },
                // Transpose of the forward combine AlltoAll: dY to the
                // expert-hosting ranks.
                Op::BwdEpAlltoAll {
                    bytes_per_pair: ops::bytes_ep_a2a_per_pair(c),
                    combine: false,
                },
                Op::EspAllReduce { total_bytes: ops::bytes_esp_ar_total(c) },
                Op::BwdExpertDgrad { flops_per_rank: ffn },
                Op::BwdExpertWgrad { flops_per_rank: ffn },
                wgrad_ar,
                // Transpose of the forward dispatch AlltoAll: dX back to
                // the token-owning ranks — overlapped by the wgrad AR.
                Op::BwdEpAlltoAll {
                    bytes_per_pair: ops::bytes_ep_a2a_per_pair(c),
                    combine: true,
                },
                Op::Gate { flops_per_rank: 2.0 * ops::gate_flops(c, gathered_tokens) },
                Op::EspReduceScatter {
                    total_bytes: ops::bytes_esp_ag_per_rank(c) * c.par.n_esp as f64,
                },
            ]
        }
        ScheduleKind::S1 => {
            let local_tokens = c.tokens() / c.par.n_mp;
            let combine_elems =
                (c.e * c.t_pausemp() * c.m) as f64 * (c.par.n_esp.saturating_sub(1)) as f64;
            let ffn = ops::expert_flops(c, ops::expert_tokens_per_rank(c, true))
                * ffn_scale_policy(c, c.t_pausemp(), flop_loads);
            vec![
                Op::MpReduceScatter {
                    total_bytes: ops::bytes_mp_ag_s1_per_rank(c) * c.par.n_mp as f64,
                },
                Op::Ungate { flops_per_rank: 2.0 * (local_tokens * c.k * c.m) as f64 },
                Op::LocalCombine { flops_per_rank: 2.0 * combine_elems },
                Op::BwdFusedAlltoAll {
                    bytes_per_pair: ops::bytes_fused_a2a_per_pair(c),
                    combine: false,
                },
                Op::BwdExpertDgrad { flops_per_rank: ffn },
                Op::BwdExpertWgrad { flops_per_rank: ffn },
                wgrad_ar,
                Op::BwdFusedAlltoAll {
                    bytes_per_pair: ops::bytes_fused_a2a_per_pair(c),
                    combine: true,
                },
                Op::Gate { flops_per_rank: 2.0 * ops::gate_flops(c, local_tokens) },
                // Adjoint of the MpSplit: gather the input gradients.
                Op::MpAllGather { bytes_per_rank: (c.input_elems() / c.par.n_mp) as f64 * d },
            ]
        }
        ScheduleKind::S2 | ScheduleKind::S2Aas => {
            let combine_elems =
                (c.e * c.t_pausemp() * c.m) as f64 * (c.par.n_esp.saturating_sub(1)) as f64;
            let ffn = ops::expert_flops(c, ops::expert_tokens_per_rank(c, true))
                * ffn_scale_policy(c, c.t_pausemp(), flop_loads);
            vec![
                Op::Ungate { flops_per_rank: 2.0 * (c.tokens() * c.k * c.m) as f64 },
                Op::LocalCombine { flops_per_rank: 2.0 * combine_elems },
                // Adjoint of the SAA/AAS combine: ReduceScatter of the
                // MP-AllGather leg, then the transposed fused AlltoAll
                // carrying dY to the experts.
                Op::MpReduceScatter {
                    total_bytes: ops::bytes_mp_ag_s2_per_rank(c) * c.par.n_mp as f64,
                },
                Op::BwdFusedAlltoAll {
                    bytes_per_pair: ops::bytes_fused_a2a_per_pair(c),
                    combine: false,
                },
                Op::BwdExpertDgrad { flops_per_rank: ffn },
                Op::BwdExpertWgrad { flops_per_rank: ffn },
                wgrad_ar,
                Op::BwdFusedAlltoAll {
                    bytes_per_pair: ops::bytes_fused_a2a_per_pair(c),
                    combine: true,
                },
                // Adjoint of the MpSplit (capacity restore), then the gate
                // adjoint on the full token set — S2 gates before the
                // split, so its adjoint closes the program.
                Op::MpAllGather { bytes_per_rank: ops::bytes_mp_ag_s2_per_rank(c) },
                Op::Gate { flops_per_rank: 2.0 * ops::gate_flops(c, c.tokens()) },
            ]
        }
        ScheduleKind::Pipelined { chunks } | ScheduleKind::PipelinedUniform { chunks } => {
            if chunks == 0 {
                panic!("resolve SP's chunk count r via the perf model first");
            }
            let local_tokens = c.tokens() / c.par.n_mp;
            let combine_elems =
                (c.e * c.t_pausemp() * c.m) as f64 * (c.par.n_esp.saturating_sub(1)) as f64;
            let spans = if matches!(kind, ScheduleKind::Pipelined { .. }) {
                sp_policy_spans(c, chunks, measured)
            } else {
                ops::chunk_spans(c.t_pausemp(), ops::sp_clamp_chunks(c, chunks))
            };
            let chunk_flops = |span: (usize, usize)| sp_policy_flops(c, span, flop_loads);
            let r = spans.len();
            // The region transposed: backward dispatch k moves the bytes of
            // forward combine k (dY in), backward combine k the bytes of
            // forward dispatch k (dX out) — identical per-chunk volumes,
            // mirrored direction. Per chunk the gradient FFN splits into
            // dgrad (feeds the chunk's combine) and wgrad (compute stream
            // only), so the combine AlltoAll overlaps the wgrad compute.
            let mut v = vec![
                Op::MpReduceScatter {
                    total_bytes: ops::bytes_mp_ag_s1_per_rank(c) * c.par.n_mp as f64,
                },
                Op::Ungate { flops_per_rank: 2.0 * (local_tokens * c.k * c.m) as f64 },
                Op::LocalCombine { flops_per_rank: 2.0 * combine_elems },
                Op::BwdSpDispatch {
                    bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[0].1),
                    index: 0,
                    of: r,
                },
            ];
            for k in 0..r {
                if k + 1 < r {
                    v.push(Op::BwdSpDispatch {
                        bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[k + 1].1),
                        index: k + 1,
                        of: r,
                    });
                }
                v.push(Op::BwdSpDgrad { flops_per_rank: chunk_flops(spans[k]), index: k, of: r });
                v.push(Op::BwdSpWgrad { flops_per_rank: chunk_flops(spans[k]), index: k, of: r });
                v.push(Op::BwdSpCombine {
                    bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[k].1),
                    index: k,
                    of: r,
                });
            }
            v.push(wgrad_ar);
            v.push(Op::Gate { flops_per_rank: 2.0 * ops::gate_flops(c, local_tokens) });
            v.push(Op::MpAllGather { bytes_per_rank: (c.input_elems() / c.par.n_mp) as f64 * d });
            v
        }
        ScheduleKind::PipelinedS2 { chunks } => {
            if chunks == 0 {
                panic!("resolve SP2's chunk count r via the perf model first");
            }
            let combine_elems =
                (c.e * c.t_pausemp() * c.m) as f64 * (c.par.n_esp.saturating_sub(1)) as f64;
            let spans = sp_policy_spans(c, chunks, measured);
            let chunk_flops = |span: (usize, usize)| sp_policy_flops(c, span, flop_loads);
            let r = spans.len();
            // Adjoint of the chunked SAA: ONE up-front MP-ReduceScatter
            // (the aggregate of the per-chunk MP-AllGather forwards), then
            // the region with plain transposed AlltoAlls per chunk —
            // backward dispatch k moves forward sp2.saa.k's AlltoAll
            // bytes, backward combine k forward sp2.dispatch.k's.
            let mut v = vec![
                Op::Ungate { flops_per_rank: 2.0 * (c.tokens() * c.k * c.m) as f64 },
                Op::LocalCombine { flops_per_rank: 2.0 * combine_elems },
                Op::MpReduceScatter {
                    total_bytes: ops::bytes_mp_ag_s2_per_rank(c) * c.par.n_mp as f64,
                },
                Op::BwdSp2Dispatch {
                    bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[0].1),
                    index: 0,
                    of: r,
                },
            ];
            for k in 0..r {
                if k + 1 < r {
                    v.push(Op::BwdSp2Dispatch {
                        bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[k + 1].1),
                        index: k + 1,
                        of: r,
                    });
                }
                v.push(Op::BwdSp2Dgrad { flops_per_rank: chunk_flops(spans[k]), index: k, of: r });
                v.push(Op::BwdSp2Wgrad { flops_per_rank: chunk_flops(spans[k]), index: k, of: r });
                v.push(Op::BwdSp2Combine {
                    bytes_per_pair: ops::bytes_sp_chunk_per_pair(c, spans[k].1),
                    index: k,
                    of: r,
                });
            }
            v.push(wgrad_ar);
            v.push(Op::MpAllGather { bytes_per_rank: ops::bytes_mp_ag_s2_per_rank(c) });
            v.push(Op::Gate { flops_per_rank: 2.0 * ops::gate_flops(c, c.tokens()) });
            v
        }
    }
}

/// Full training-iteration program (forward + backward). Gradient
/// all-reduce of parameters is excluded, matching the paper's measurement
/// protocol ("the time for the allreduce of gradients is excluded").
pub fn iteration_ops(kind: ScheduleKind, c: &MoeLayerConfig) -> Vec<Op> {
    iteration_ops_measured(kind, c, None)
}

/// [`iteration_ops`] under an optional measured load profile (see
/// [`forward_ops_measured`]).
pub fn iteration_ops_measured(
    kind: ScheduleKind,
    c: &MoeLayerConfig,
    measured: Option<&[usize]>,
) -> Vec<Op> {
    iteration_ops_traffic(kind, c, measured, measured)
}

/// Two-profile training-iteration program (see [`forward_ops_traffic`]):
/// the online controller's step — spans planned from the stale
/// `span_loads`, compute priced at the actual `flop_loads`.
pub fn iteration_ops_traffic(
    kind: ScheduleKind,
    c: &MoeLayerConfig,
    span_loads: Option<&[usize]>,
    flop_loads: Option<&[usize]>,
) -> Vec<Op> {
    let mut v = forward_ops_traffic(kind, c, span_loads, flop_loads);
    v.extend(backward_ops_traffic(kind, c, span_loads, flop_loads));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MoeLayerConfig {
        MoeLayerConfig::test_default()
    }

    #[test]
    fn baseline_structure() {
        let ops = forward_ops(ScheduleKind::Baseline, &cfg());
        let tags: Vec<&str> = ops.iter().map(|o| o.tag()).collect();
        assert_eq!(
            tags,
            vec![
                "esp.allgather",
                "gate",
                "ep.alltoall",
                "expert.ffn",
                "esp.allreduce",
                "ep.alltoall",
                "ungate",
                "esp.split"
            ]
        );
    }

    #[test]
    fn s1_structure() {
        let tags: Vec<&str> = forward_ops(ScheduleKind::S1, &cfg())
            .iter()
            .map(|o| o.tag())
            .collect();
        assert_eq!(
            tags,
            vec![
                "mp.split",
                "gate",
                "fused.alltoall",
                "expert.ffn",
                "fused.alltoall",
                "local.combine",
                "ungate",
                "mp.allgather"
            ]
        );
    }

    #[test]
    fn s2_uses_saa_and_gates_before_split() {
        let tags: Vec<&str> = forward_ops(ScheduleKind::S2, &cfg())
            .iter()
            .map(|o| o.tag())
            .collect();
        assert_eq!(tags[0], "gate");
        assert_eq!(tags[1], "mp.split");
        assert!(tags.contains(&"saa.combine"));
        let tags_aas: Vec<&str> = forward_ops(ScheduleKind::S2Aas, &cfg())
            .iter()
            .map(|o| o.tag())
            .collect();
        assert!(tags_aas.contains(&"aas.combine"));
    }

    #[test]
    fn s1_eliminates_duplicate_compute() {
        let base = forward_ops(ScheduleKind::Baseline, &cfg());
        let s1 = forward_ops(ScheduleKind::S1, &cfg());
        let flops = |ops: &[Op]| {
            ops.iter()
                .map(|o| match o {
                    Op::ExpertFfn { flops_per_rank } => *flops_per_rank,
                    _ => 0.0,
                })
                .sum::<f64>()
        };
        let ratio = flops(&base) / flops(&s1);
        let n_mp = cfg().par.n_mp as f64;
        assert!((ratio - n_mp).abs() / n_mp < 0.06, "ratio {ratio}");
    }

    #[test]
    fn s1_backward_structure() {
        let c = cfg();
        let bwd = backward_ops(ScheduleKind::S1, &c);
        let bwd_tags: Vec<&str> = bwd.iter().map(|o| o.tag()).collect();
        assert_eq!(
            bwd_tags,
            vec![
                "mp.reducescatter",
                "ungate",
                "local.combine",
                "bwd.fused.dispatch",
                "bwd.expert.dgrad",
                "bwd.expert.wgrad",
                "bwd.wgrad.allreduce",
                "bwd.fused.combine",
                "gate",
                "mp.allgather"
            ]
        );
        // The transposed AlltoAlls move exactly the forward legs' volumes.
        let fused = ops::bytes_fused_a2a_per_pair(&c);
        for o in &bwd {
            if let Op::BwdFusedAlltoAll { bytes_per_pair, .. } = *o {
                assert_eq!(bytes_per_pair, fused);
            }
        }
        // dgrad + wgrad together double the forward expert FFN.
        let fwd_ffn: f64 = forward_ops(ScheduleKind::S1, &c)
            .iter()
            .map(|o| match *o {
                Op::ExpertFfn { flops_per_rank } => flops_per_rank,
                _ => 0.0,
            })
            .sum();
        let grad_ffn: f64 = bwd
            .iter()
            .map(|o| match *o {
                Op::BwdExpertDgrad { flops_per_rank } | Op::BwdExpertWgrad { flops_per_rank } => {
                    flops_per_rank
                }
                _ => 0.0,
            })
            .sum();
        assert!((grad_ffn - 2.0 * fwd_ffn).abs() / grad_ffn < 1e-12);
    }

    #[test]
    fn every_family_reduces_wgrad_once() {
        let c = cfg();
        for kind in [
            ScheduleKind::Baseline,
            ScheduleKind::S1,
            ScheduleKind::S2,
            ScheduleKind::S2Aas,
            ScheduleKind::Pipelined { chunks: 2 },
            ScheduleKind::PipelinedUniform { chunks: 2 },
            ScheduleKind::PipelinedS2 { chunks: 2 },
        ] {
            let bwd = backward_ops(kind, &c);
            let ars: Vec<&Op> = bwd
                .iter()
                .filter(|o| matches!(o, Op::BwdWgradAllReduce { .. }))
                .collect();
            assert_eq!(ars.len(), 1, "{kind:?}");
            match ars[0] {
                Op::BwdWgradAllReduce { bytes_per_rank, overlap } => {
                    assert_eq!(*bytes_per_rank, ops::bytes_wgrad_per_rank(&c), "{kind:?}");
                    assert!(*overlap, "{kind:?}: overlap is the default");
                }
                _ => unreachable!(),
            }
            // The ablation knob turns the overlap off without touching
            // anything else in the program.
            let flat = backward_ops_overlap(kind, &c, None, false);
            assert_eq!(flat.len(), bwd.len(), "{kind:?}");
            assert!(
                flat.iter()
                    .any(|o| matches!(o, Op::BwdWgradAllReduce { overlap: false, .. })),
                "{kind:?}"
            );
        }
    }

    #[test]
    fn iteration_concatenates() {
        let c = cfg();
        let it = iteration_ops(ScheduleKind::Baseline, &c);
        let fwd_len = forward_ops(ScheduleKind::Baseline, &c).len();
        assert_eq!(it.len(), fwd_len + backward_ops(ScheduleKind::Baseline, &c).len());
        // Baseline backward contains the ESP-AllGather from the ESP-Split.
        assert!(it[fwd_len..].iter().any(|o| o.tag() == "esp.allgather"));
        // ... and ends on the adjoint of the forward's opening AllGather.
        assert_eq!(it.last().unwrap().tag(), "esp.reducescatter");
    }

    #[test]
    #[should_panic(expected = "resolve Parm")]
    fn parm_must_be_resolved() {
        forward_ops(ScheduleKind::Parm, &cfg());
    }

    #[test]
    #[should_panic(expected = "resolve SP")]
    fn sp_auto_must_be_resolved() {
        forward_ops(ScheduleKind::Pipelined { chunks: 0 }, &cfg());
    }

    #[test]
    fn sp_structure_interleaves_chunks() {
        let tags: Vec<&str> = forward_ops(ScheduleKind::Pipelined { chunks: 2 }, &cfg())
            .iter()
            .map(|o| o.tag())
            .collect();
        assert_eq!(
            tags,
            vec![
                "mp.split",
                "gate",
                "sp.dispatch.0",
                "sp.dispatch.1",
                "sp.ffn.0",
                "sp.combine.0",
                "sp.ffn.1",
                "sp.combine.1",
                "local.combine",
                "ungate",
                "mp.allgather"
            ]
        );
    }

    #[test]
    fn sp_conserves_s1_volumes_and_flops() {
        // Chunking must not change what moves or what is computed — only
        // when. Compare against S1's totals per op family.
        let c = cfg();
        let s1 = forward_ops(ScheduleKind::S1, &c);
        let sp = forward_ops(ScheduleKind::Pipelined { chunks: 3 }, &c);
        let a2a_total = |ops: &[Op]| {
            ops.iter()
                .map(|o| match *o {
                    Op::FusedAlltoAll { bytes_per_pair } => bytes_per_pair,
                    Op::SpDispatch { bytes_per_pair, .. }
                    | Op::SpCombine { bytes_per_pair, .. } => bytes_per_pair,
                    _ => 0.0,
                })
                .sum::<f64>()
        };
        let ffn_total = |ops: &[Op]| {
            ops.iter()
                .map(|o| match *o {
                    Op::ExpertFfn { flops_per_rank } => flops_per_rank,
                    Op::SpExpertFfn { flops_per_rank, .. } => flops_per_rank,
                    _ => 0.0,
                })
                .sum::<f64>()
        };
        assert!((a2a_total(&s1) - a2a_total(&sp)).abs() < 1e-9);
        let (f1, fp) = (ffn_total(&s1), ffn_total(&sp));
        assert!((f1 - fp).abs() / f1 < 1e-12, "{f1} vs {fp}");
    }

    #[test]
    fn skewed_sp_conserves_scaled_volumes_and_flops() {
        // Under the routing-skew knob, chunking must still move exactly
        // the fused-AlltoAll bytes (dense slabs) and compute exactly the
        // load-scaled FFN — for BOTH the weighted and the uniform span
        // variants (they differ only in where the boundaries fall).
        let mut c = cfg();
        c.skew = 1.3;
        let s1 = forward_ops(ScheduleKind::S1, &c);
        let a2a_total = |ops: &[Op]| {
            ops.iter()
                .map(|o| match *o {
                    Op::FusedAlltoAll { bytes_per_pair } => bytes_per_pair,
                    Op::SpDispatch { bytes_per_pair, .. }
                    | Op::SpCombine { bytes_per_pair, .. } => bytes_per_pair,
                    _ => 0.0,
                })
                .sum::<f64>()
        };
        let ffn_total = |ops: &[Op]| {
            ops.iter()
                .map(|o| match *o {
                    Op::ExpertFfn { flops_per_rank } => flops_per_rank,
                    Op::SpExpertFfn { flops_per_rank, .. } => flops_per_rank,
                    _ => 0.0,
                })
                .sum::<f64>()
        };
        // The load scale strictly discounts the dense FFN under skew.
        let dense = ops::expert_flops(&c, ops::expert_tokens_per_rank(&c, true));
        assert!(ffn_total(&s1) < dense, "skew must discount the dense FFN");
        for kind in [
            ScheduleKind::Pipelined { chunks: 3 },
            ScheduleKind::PipelinedUniform { chunks: 3 },
        ] {
            let sp = forward_ops(kind, &c);
            assert!((a2a_total(&s1) - a2a_total(&sp)).abs() < 1e-9, "{kind:?}");
            let (f1, fp) = (ffn_total(&s1), ffn_total(&sp));
            assert!((f1 - fp).abs() / f1 < 1e-9, "{kind:?}: {f1} vs {fp}");
        }
        // The two variants place boundaries differently under skew.
        let dispatch_bytes = |kind| -> Vec<f64> {
            forward_ops(kind, &c)
                .iter()
                .filter_map(|o| match *o {
                    Op::SpDispatch { bytes_per_pair, .. } => Some(bytes_per_pair),
                    _ => None,
                })
                .collect()
        };
        assert_ne!(
            dispatch_bytes(ScheduleKind::Pipelined { chunks: 3 }),
            dispatch_bytes(ScheduleKind::PipelinedUniform { chunks: 3 }),
            "weighted spans should differ from uniform under skew"
        );
    }

    #[test]
    fn measured_loads_reshape_sp_spans() {
        // Two-pass mode: a head-heavy measured profile moves the chunk
        // boundaries (and FFN pricing) even with the skew knob off —
        // that's the organic-imbalance coverage. An all-zero measurement
        // is ignored.
        let c = cfg();
        assert_eq!(c.skew, 0.0);
        let cap = c.t_pausemp();
        let loads: Vec<usize> = (0..c.e).map(|j| cap / (j + 1)).collect();
        let kind = ScheduleKind::Pipelined { chunks: 3 };
        let plain = forward_ops(kind, &c);
        let measured = forward_ops_measured(kind, &c, Some(&loads[..]));
        let dispatch_bytes = |ops: &[Op]| -> Vec<f64> {
            ops.iter()
                .filter_map(|o| match *o {
                    Op::SpDispatch { bytes_per_pair, .. } => Some(bytes_per_pair),
                    _ => None,
                })
                .collect()
        };
        assert_ne!(dispatch_bytes(&plain), dispatch_bytes(&measured));
        let zeros = vec![0usize; c.e];
        assert_eq!(plain, forward_ops_measured(kind, &c, Some(&zeros[..])));
        // The measured iteration program concatenates like the plain one.
        let it = iteration_ops_measured(kind, &c, Some(&loads[..]));
        assert_eq!(
            it.len(),
            measured.len() + backward_ops_measured(kind, &c, Some(&loads[..])).len()
        );
    }

    #[test]
    fn traffic_profiles_split_spans_from_pricing() {
        // The online controller's step: spans planned from a STALE profile,
        // compute priced at the ACTUAL one. Spans must follow span_loads
        // only; total FFN flops must follow flop_loads only.
        let c = cfg();
        let cap = c.t_pausemp();
        let stale: Vec<usize> = (0..c.e).map(|j| cap / (j + 1)).collect();
        let actual: Vec<usize> = (0..c.e).map(|j| cap / (c.e - j)).collect();
        let kind = ScheduleKind::Pipelined { chunks: 3 };
        let dispatch_bytes = |ops: &[Op]| -> Vec<f64> {
            ops.iter()
                .filter_map(|o| match *o {
                    Op::SpDispatch { bytes_per_pair, .. } => Some(bytes_per_pair),
                    _ => None,
                })
                .collect()
        };
        let ffn_total = |ops: &[Op]| -> f64 {
            ops.iter()
                .map(|o| match *o {
                    Op::SpExpertFfn { flops_per_rank, .. } => flops_per_rank,
                    _ => 0.0,
                })
                .sum()
        };
        let two = forward_ops_traffic(kind, &c, Some(&stale), Some(&actual));
        // Spans track the stale profile.
        assert_eq!(
            dispatch_bytes(&two),
            dispatch_bytes(&forward_ops_measured(kind, &c, Some(&stale))),
        );
        // FFN totals track the actual profile (linearity: span-independent).
        let measured_actual = forward_ops_measured(kind, &c, Some(&actual));
        assert!(
            (ffn_total(&two) - ffn_total(&measured_actual)).abs() / ffn_total(&two) < 1e-9,
        );
        // Same profile on both sides IS the measured mode.
        assert_eq!(
            forward_ops_traffic(kind, &c, Some(&stale), Some(&stale)),
            forward_ops_measured(kind, &c, Some(&stale)),
        );
        // Monolithic schedules price their FFN from flop_loads too.
        let s1 = forward_ops_traffic(ScheduleKind::S1, &c, None, Some(&actual));
        let s1_ffn: f64 = s1
            .iter()
            .map(|o| match *o {
                Op::ExpertFfn { flops_per_rank } => flops_per_rank,
                _ => 0.0,
            })
            .sum();
        let want = ops::expert_flops(&c, ops::expert_tokens_per_rank(&c, true))
            * ops::ffn_load_scale_measured(&c, cap, &actual);
        assert!((s1_ffn - want).abs() / want < 1e-12, "{s1_ffn} vs {want}");
        // And the iteration program concatenates forward + backward.
        let it = iteration_ops_traffic(kind, &c, Some(&stale), Some(&actual));
        assert_eq!(
            it.len(),
            two.len() + backward_ops_traffic(kind, &c, Some(&stale), Some(&actual)).len()
        );
    }

    #[test]
    fn sp2_structure_interleaves_chunks_with_saa_combines() {
        let tags: Vec<&str> = forward_ops(ScheduleKind::PipelinedS2 { chunks: 2 }, &cfg())
            .iter()
            .map(|o| o.tag())
            .collect();
        assert_eq!(
            tags,
            vec![
                "gate",
                "mp.split",
                "sp2.dispatch.0",
                "sp2.dispatch.1",
                "sp2.ffn.0",
                "sp2.saa.0",
                "sp2.ffn.1",
                "sp2.saa.1",
                "local.combine",
                "ungate"
            ]
        );
    }

    #[test]
    fn sp2_conserves_s2_volumes_and_flops() {
        // Chunking the SAA combine must not change what moves or what is
        // computed — per op family, SP2's totals equal S2's.
        let c = cfg();
        let s2 = forward_ops(ScheduleKind::S2, &c);
        let sp2 = forward_ops(ScheduleKind::PipelinedS2 { chunks: 3 }, &c);
        let a2a_total = |ops: &[Op]| {
            ops.iter()
                .map(|o| match *o {
                    Op::FusedAlltoAll { bytes_per_pair } | Op::SaaCombine { bytes_per_pair } => {
                        bytes_per_pair
                    }
                    Op::Sp2Dispatch { bytes_per_pair, .. }
                    | Op::Sp2Saa { bytes_per_pair, .. } => bytes_per_pair,
                    _ => 0.0,
                })
                .sum::<f64>()
        };
        let ffn_total = |ops: &[Op]| {
            ops.iter()
                .map(|o| match *o {
                    Op::ExpertFfn { flops_per_rank } => flops_per_rank,
                    Op::Sp2ExpertFfn { flops_per_rank, .. } => flops_per_rank,
                    _ => 0.0,
                })
                .sum::<f64>()
        };
        assert!((a2a_total(&s2) - a2a_total(&sp2)).abs() < 1e-9);
        let (f2, fp) = (ffn_total(&s2), ffn_total(&sp2));
        assert!((f2 - fp).abs() / f2 < 1e-12, "{f2} vs {fp}");
    }

    #[test]
    fn sp2_backward_stays_a_pipeline() {
        let c = cfg();
        let bwd = backward_ops(ScheduleKind::PipelinedS2 { chunks: 2 }, &c);
        // Starts with the adjoint of the Ungate (S2 has no trailing AG —
        // the SAA chunks carried it; its adjoint is the one up-front
        // MP-ReduceScatter before the region).
        assert_eq!(bwd[0].tag(), "ungate");
        assert!(bwd.iter().any(|o| o.tag() == "mp.reducescatter"));
        // Every chunk keeps dispatch-before-dgrad-before-combine order,
        // with the wgrad emitted between dgrad and combine (compute
        // stream only — the combine does not wait on it).
        for k in 0..2usize {
            let pos = |pred: &dyn Fn(&Op) -> bool| bwd.iter().position(|o| pred(o)).unwrap();
            let di = pos(&|o| matches!(*o, Op::BwdSp2Dispatch { index, .. } if index == k));
            let dg = pos(&|o| matches!(*o, Op::BwdSp2Dgrad { index, .. } if index == k));
            let wg = pos(&|o| matches!(*o, Op::BwdSp2Wgrad { index, .. } if index == k));
            let cb = pos(&|o| matches!(*o, Op::BwdSp2Combine { index, .. } if index == k));
            assert!(di < dg && dg < wg && wg < cb, "chunk {k}: d={di} g={dg} w={wg} c={cb}");
        }
        // MpSplit's adjoint (MP-AllGather) is still present, and the wgrad
        // AllReduce lands after the region.
        assert!(bwd.iter().any(|o| o.tag() == "mp.allgather"));
        let ar = bwd.iter().position(|o| matches!(o, Op::BwdWgradAllReduce { .. })).unwrap();
        let last_cb = bwd
            .iter()
            .rposition(|o| matches!(o, Op::BwdSp2Combine { .. }))
            .unwrap();
        assert!(ar > last_cb, "wgrad AR after the region: ar={ar} last_combine={last_cb}");
    }

    #[test]
    #[should_panic(expected = "resolve SP2")]
    fn sp2_auto_must_be_resolved() {
        forward_ops(ScheduleKind::PipelinedS2 { chunks: 0 }, &cfg());
    }

    #[test]
    fn sp_backward_stays_a_pipeline() {
        let c = cfg();
        let bwd = backward_ops(ScheduleKind::Pipelined { chunks: 2 }, &c);
        // Starts with the adjoint of the MP-AllGather.
        assert_eq!(bwd[0].tag(), "mp.reducescatter");
        // Every chunk keeps dispatch-before-dgrad-before-combine order,
        // with the wgrad between dgrad and combine (compute stream only).
        for k in 0..2usize {
            let pos = |pred: &dyn Fn(&Op) -> bool| bwd.iter().position(|o| pred(o)).unwrap();
            let di = pos(&|o| matches!(*o, Op::BwdSpDispatch { index, .. } if index == k));
            let dg = pos(&|o| matches!(*o, Op::BwdSpDgrad { index, .. } if index == k));
            let wg = pos(&|o| matches!(*o, Op::BwdSpWgrad { index, .. } if index == k));
            let cb = pos(&|o| matches!(*o, Op::BwdSpCombine { index, .. } if index == k));
            assert!(di < dg && dg < wg && wg < cb, "chunk {k}: d={di} g={dg} w={wg} c={cb}");
        }
        // dgrad + wgrad together double the forward chunk FFN.
        let fwd_ffn: f64 = forward_ops(ScheduleKind::Pipelined { chunks: 2 }, &c)
            .iter()
            .map(|o| match *o {
                Op::SpExpertFfn { flops_per_rank, .. } => flops_per_rank,
                _ => 0.0,
            })
            .sum();
        let bwd_ffn: f64 = bwd
            .iter()
            .map(|o| match *o {
                Op::BwdSpDgrad { flops_per_rank, .. }
                | Op::BwdSpWgrad { flops_per_rank, .. } => flops_per_rank,
                _ => 0.0,
            })
            .sum();
        assert!((bwd_ffn - 2.0 * fwd_ffn).abs() / bwd_ffn < 1e-12);
        // Per-chunk transposition: backward dispatch k moves forward
        // combine k's bytes, backward combine k forward dispatch k's.
        let fwd = forward_ops(ScheduleKind::Pipelined { chunks: 2 }, &c);
        for k in 0..2usize {
            let fwd_dispatch = fwd
                .iter()
                .find_map(|o| match *o {
                    Op::SpDispatch { bytes_per_pair, index, .. } if index == k => {
                        Some(bytes_per_pair)
                    }
                    _ => None,
                })
                .unwrap();
            let bwd_combine = bwd
                .iter()
                .find_map(|o| match *o {
                    Op::BwdSpCombine { bytes_per_pair, index, .. } if index == k => {
                        Some(bytes_per_pair)
                    }
                    _ => None,
                })
                .unwrap();
            assert_eq!(fwd_dispatch, bwd_combine, "chunk {k}");
        }
    }
}
