//! Synthetic corpus for the end-to-end example: a noisy affine bigram
//! language. Token t+1 = (a·t + b + ε) mod V with ε uniform over a small
//! branch set, so the optimal next-token cross-entropy is ln(branches) —
//! a visible, known target for the loss curve.

use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    pub vocab: usize,
    pub branches: usize,
    a: usize,
    b: usize,
    rng: Rng,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> SyntheticCorpus {
        SyntheticCorpus {
            vocab,
            branches: 4,
            a: 7,
            b: 31,
            rng: Rng::new(seed),
        }
    }

    /// Ideal achievable loss: ln(branches).
    pub fn entropy_floor(&self) -> f64 {
        (self.branches as f64).ln()
    }

    /// One sequence of `len` token ids.
    pub fn sequence(&mut self, len: usize) -> Vec<usize> {
        let mut t = self.rng.usize(self.vocab);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(t);
            let eps = self.rng.usize(self.branches);
            t = (self.a * t + self.b + eps) % self.vocab;
        }
        out
    }

    /// A batch of shape (b, len) as f32 ids (the artifact input dtype).
    pub fn batch_f32(&mut self, b: usize, len: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(b * len);
        for _ in 0..b {
            out.extend(self.sequence(len).into_iter().map(|id| id as f32));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_in_range() {
        let mut c = SyntheticCorpus::new(64, 1);
        let batch = c.batch_f32(3, 10);
        assert_eq!(batch.len(), 30);
        assert!(batch.iter().all(|&v| v >= 0.0 && v < 64.0 && v.fract() == 0.0));
    }

    #[test]
    fn transitions_follow_the_chain() {
        let mut c = SyntheticCorpus::new(97, 2);
        let seq = c.sequence(50);
        for w in seq.windows(2) {
            let (cur, next) = (w[0], w[1]);
            let base = (7 * cur + 31) % 97;
            let diff = (next + 97 - base) % 97;
            assert!(diff < c.branches, "{cur} → {next} (diff {diff})");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SyntheticCorpus::new(64, 9).batch_f32(2, 8);
        let b = SyntheticCorpus::new(64, 9).batch_f32(2, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn entropy_floor_value() {
        let c = SyntheticCorpus::new(64, 1);
        assert!((c.entropy_floor() - 4.0f64.ln()).abs() < 1e-12);
    }
}
