//! Training driver: the end-to-end loop that executes the AOT
//! `lm_train_step` artifact via PJRT (real numerics, real loss curve) and
//! reports the *simulated* distributed iteration time of the same model
//! under a chosen schedule and cluster (the timing the paper measures).

pub mod data;
pub mod simtime;
pub mod trainer;

pub use data::SyntheticCorpus;
pub use simtime::{model_iteration_time, ModelTiming};
pub use trainer::{train_lm, TrainOptions, TrainReport};
