//! Whole-model iteration timing under a schedule: the per-MoE-layer
//! simulated time (the paper's contribution) plus the dense transformer
//! compute the MoE layers are embedded in. This is what Table V measures.

use anyhow::Result;

use crate::config::moe::ParallelDegrees;
use crate::config::{ClusterTopology, ModelConfig};
use crate::schedule::{lowering, ScheduleKind};

/// Breakdown of one training iteration of a full model.
#[derive(Debug, Clone, Copy)]
pub struct ModelTiming {
    /// Simulated seconds in MoE layers (all of them, fwd+bwd).
    pub moe_seconds: f64,
    /// Dense (attention + dense FFN + head) compute seconds per iteration.
    pub dense_seconds: f64,
    /// Communication-ratio of a single MoE layer (Fig 1 style).
    pub moe_comm_ratio: f64,
}

impl ModelTiming {
    pub fn total(&self) -> f64 {
        self.moe_seconds + self.dense_seconds
    }
}

/// Simulate one training iteration of `model` under `kind`.
///
/// Gradient all-reduce is excluded (paper §VI-A measurement protocol).
pub fn model_iteration_time(
    model: &ModelConfig,
    par: ParallelDegrees,
    cluster: &ClusterTopology,
    kind: ScheduleKind,
) -> Result<ModelTiming> {
    let layer = model.moe_layer(par);
    layer.validate()?;
    let report = lowering::simulate_iteration(kind, &layer, cluster)?;
    let moe_seconds = report.makespan * model.n_moe_layers() as f64;
    // Synchronous data parallelism paces the dense blocks at the slowest
    // participating GPU (the bottleneck node of a mixed fleet).
    let dense_seconds = model.dense_flops_per_gpu(par.n_mp) / cluster.min_flops(par.p);
    Ok(ModelTiming {
        moe_seconds,
        dense_seconds,
        moe_comm_ratio: report.comm_ratio(),
    })
}

/// [`model_iteration_time`] under the two-profile traffic contract of the
/// online control plane: the SP family's chunk spans are planned from the
/// (stale) `span_loads` measurement while expert compute is priced at the
/// actual `flop_loads` — see
/// [`crate::schedule::lowering::simulate_iteration_traffic_with_dag`].
/// `(None, None)` reproduces [`model_iteration_time`] exactly.
pub fn model_iteration_time_measured(
    model: &ModelConfig,
    par: ParallelDegrees,
    cluster: &ClusterTopology,
    kind: ScheduleKind,
    span_loads: Option<&[usize]>,
    flop_loads: Option<&[usize]>,
) -> Result<ModelTiming> {
    let layer = model.moe_layer(par);
    layer.validate()?;
    let (report, _) = lowering::simulate_iteration_traffic_with_dag(
        kind,
        &layer,
        cluster,
        span_loads,
        flop_loads,
    )?;
    let moe_seconds = report.makespan * model.n_moe_layers() as f64;
    let dense_seconds = model.dense_flops_per_gpu(par.n_mp) / cluster.min_flops(par.p);
    Ok(ModelTiming {
        moe_seconds,
        dense_seconds,
        moe_comm_ratio: report.comm_ratio(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_on_testbed_b_speedup_shape() {
        // Table V shape: Parm ≈ 3× over DeepSpeed-MoE on BERT/GPT-2 with
        // N_MP = N_ESP = 4. We assert the direction and a sane magnitude
        // (1.5×–8×); the bench prints the exact numbers.
        let cluster = ClusterTopology::testbed_b();
        let model = ModelConfig::bert_base_moe(8);
        let par = ParallelDegrees { p: 32, n_mp: 4, n_esp: 4 };
        let base = model_iteration_time(&model, par, &cluster, ScheduleKind::Baseline).unwrap();
        let s1 = model_iteration_time(&model, par, &cluster, ScheduleKind::S1).unwrap();
        let speedup = base.total() / s1.total();
        assert!(
            (1.5..8.0).contains(&speedup),
            "speedup {speedup} out of plausible Table V range"
        );
    }

    #[test]
    fn moe_layers_dominate_baseline() {
        // Fig 1: communication (in the MoE layers) dominates iteration
        // time under the baseline schedule on the cluster testbed.
        let cluster = ClusterTopology::testbed_b();
        let model = ModelConfig::gpt2_moe(8);
        let par = ParallelDegrees { p: 32, n_mp: 4, n_esp: 4 };
        let t = model_iteration_time(&model, par, &cluster, ScheduleKind::Baseline).unwrap();
        assert!(t.moe_seconds > t.dense_seconds);
        assert!(t.moe_comm_ratio > 0.5);
    }

    #[test]
    fn measured_variant_matches_unmeasured_without_loads_and_reacts_to_skew() {
        let cluster = ClusterTopology::testbed_a();
        let model = ModelConfig::bert_base_moe(8);
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        let kind = ScheduleKind::Pipelined { chunks: 4 };
        let base = model_iteration_time(&model, par, &cluster, kind).unwrap();
        let warm =
            model_iteration_time_measured(&model, par, &cluster, kind, None, None).unwrap();
        assert_eq!(base.total(), warm.total());
        assert_eq!(base.moe_comm_ratio, warm.moe_comm_ratio);
        // A measured hot-expert profile changes the MoE timing but leaves
        // the dense blocks (which don't route) untouched.
        let layer = model.moe_layer(par);
        let mut loads = vec![layer.t_pausemp() / 8; layer.e];
        loads[0] = layer.t_pausemp();
        let skewed =
            model_iteration_time_measured(&model, par, &cluster, kind, Some(&loads), Some(&loads))
                .unwrap();
        assert!(skewed.moe_seconds > 0.0);
        assert_ne!(skewed.moe_seconds, base.moe_seconds);
        assert_eq!(skewed.dense_seconds, base.dense_seconds);
    }

    #[test]
    fn invalid_layout_rejected() {
        let cluster = ClusterTopology::testbed_a();
        let model = ModelConfig::bert_base_moe(7); // 7 experts won't divide slots
        let par = ParallelDegrees { p: 8, n_mp: 2, n_esp: 2 };
        assert!(model_iteration_time(&model, par, &cluster, ScheduleKind::S1).is_err());
    }
}
