//! The end-to-end trainer: drives the `lm_train_step` PJRT artifact.
//!
//! Parameters live in Rust (initialized from the manifest's schema with
//! the library PRNG) and round-trip through the artifact each step; the
//! loss comes back as output 0. Python is never imported — the artifact
//! is the only trace of it.

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::runtime::{HostTensor, Runtime};
use crate::train::data::SyntheticCorpus;
use crate::util::json::Json;
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub artifacts_dir: PathBuf,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub log_every: usize,
    /// Optional JSONL loss log path.
    pub log_path: Option<PathBuf>,
    /// Recreate the PJRT client every N steps (0 = never). The XLA CPU
    /// client retains ~params-sized arena memory per execution of the
    /// 151M-param train step (observed ≈600 MB/step RSS growth with all
    /// Rust-side buffers provably dropped); recycling the client caps the
    /// footprint at `reset_every × step-size` for a ~13 s recompile each
    /// time. See EXPERIMENTS.md §Known issues.
    pub reset_every: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            artifacts_dir: PathBuf::from("artifacts"),
            steps: 200,
            lr: 0.05,
            seed: 42,
            log_every: 10,
            log_path: None,
            reset_every: 12,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<(usize, f64)>,
    pub param_count: usize,
    pub steps: usize,
    pub wall_seconds: f64,
    pub entropy_floor: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f64 {
        self.losses.first().map(|&(_, l)| l).unwrap_or(f64::NAN)
    }

    pub fn last_loss(&self) -> f64 {
        self.losses.last().map(|&(_, l)| l).unwrap_or(f64::NAN)
    }
}

/// Train the tiny MoE LM end-to-end through PJRT.
pub fn train_lm(opts: &TrainOptions) -> Result<TrainReport> {
    let mut rt = Runtime::load(&opts.artifacts_dir)?;
    let spec = rt.manifest().get("lm_train_step")?.clone();
    let meta = &spec.meta;
    let vocab = meta.get("vocab").as_usize().context("manifest meta.vocab")?;
    let seq_len = meta.get("seq_len").as_usize().context("manifest meta.seq_len")?;
    let batch = meta.get("batch").as_usize().context("manifest meta.batch")?;
    let param_count = meta.get("param_count").as_usize().unwrap_or(0);
    let schema = meta.get("params").as_arr().context("manifest meta.params")?;
    ensure!(!schema.is_empty(), "empty param schema");

    // Initialize parameters per the schema (normal · scale).
    let mut rng = Rng::new(opts.seed);
    let mut params: Vec<HostTensor> = Vec::with_capacity(schema.len());
    for p in schema {
        let dims: Vec<usize> = p
            .get("shape")
            .as_arr()
            .context("param shape")?
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        let scale = p.get("scale").as_f64().unwrap_or(0.02) as f32;
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * scale).collect();
        params.push(HostTensor::new(dims, data)?);
    }

    let mut corpus = SyntheticCorpus::new(vocab, opts.seed ^ 0xC0FFEE);
    let mut log_file = match &opts.log_path {
        Some(p) => Some(std::fs::File::create(p)?),
        None => None,
    };

    let mut losses = Vec::new();
    let start = Instant::now();
    for step in 0..opts.steps {
        if opts.reset_every > 0 && step > 0 && step % opts.reset_every == 0 {
            // Cap the PJRT CPU client's per-execution arena growth.
            rt = Runtime::load(&opts.artifacts_dir)?;
        }
        let batch_data = corpus.batch_f32(batch, seq_len + 1);
        let mut inputs = Vec::with_capacity(2 + params.len());
        inputs.push(HostTensor::new(vec![batch, seq_len + 1], batch_data)?);
        inputs.push(HostTensor::scalar(opts.lr));
        inputs.extend(params.iter().cloned());
        let mut out = rt.exec("lm_train_step", &inputs)?;
        let loss = out[0].data[0] as f64;
        ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        params = out.split_off(1);
        losses.push((step, loss));
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            println!(
                "step {step:>5}  loss {loss:.4}  ({:.2}s elapsed)",
                start.elapsed().as_secs_f64()
            );
        }
        if let Some(f) = log_file.as_mut() {
            let row = Json::obj(vec![
                ("step", Json::num(step as f64)),
                ("loss", Json::num(loss)),
                ("elapsed_s", Json::num(start.elapsed().as_secs_f64())),
            ]);
            writeln!(f, "{}", row.to_string())?;
        }
    }

    Ok(TrainReport {
        losses,
        param_count,
        steps: opts.steps,
        wall_seconds: start.elapsed().as_secs_f64(),
        entropy_floor: corpus.entropy_floor(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_sane() {
        let o = TrainOptions::default();
        assert!(o.steps > 0 && o.lr > 0.0);
    }

    #[test]
    fn report_accessors() {
        let r = TrainReport {
            losses: vec![(0, 5.0), (1, 4.0)],
            param_count: 10,
            steps: 2,
            wall_seconds: 1.0,
            entropy_floor: 1.38,
        };
        assert_eq!(r.first_loss(), 5.0);
        assert_eq!(r.last_loss(), 4.0);
    }

    // Full train-loop integration (needs artifacts) lives in
    // rust/tests/trainer_e2e.rs and examples/train_moe_lm.rs.
}
