//! Greedy list-scheduling engine over contended resources.
//!
//! Resource model (per [`crate::config::ClusterTopology`]):
//! * `gpu_tx[r]` / `gpu_rx[r]` — each GPU's local fabric port (PCIe),
//!   carrying **intra-node** transfers.
//! * `nic_tx[n]` / `nic_rx[n]` — each node's NIC, carrying **inter-node**
//!   transfers; GPUs of one node *share* inter-node bandwidth (testbed B:
//!   4 GPUs per ConnectX-5). Inter-node transfers do NOT occupy the GPU
//!   ports: the intra-node connect and the inter-node connect are
//!   independent channels — the paper's Observation 1/2 premise ("either
//!   the intra-node connect or the inter-node connect is idle"), realized
//!   by NCCL's separate channels and GPUDirect-style DMA.
//! * `gpu_compute[r]` — one compute stream per GPU.
//!
//! A transfer src→dst (src ≠ dst) starts when its dependencies are done
//! and every required resource is free, then holds all of them for
//! `α + bytes·β` **of the actual endpoint pair's link**
//! ([`ClusterTopology::link`]): the hosting node's intra link within a
//! node, the bottleneck of the two endpoint NICs across nodes — so mixed
//! fleets (slow straggler nodes, asymmetric NICs) are priced per link, not
//! by two global scalars. Compute likewise runs at the *hosting node's*
//! per-GPU throughput. This is the standard α-β/LogP-style list-scheduling
//! approximation (cf. ASTRA-sim's analytical mode): deterministic, and it
//! exposes exactly the two properties the paper exploits — serialization
//! on a shared link class, and overlap across link classes.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::ClusterTopology;
use crate::sim::dag::{SimDag, TaskKind};

/// Timing of one scheduled task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskTiming {
    pub start: f64,
    pub end: f64,
}

/// Result of simulating a DAG.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub makespan: f64,
    pub timings: Vec<TaskTiming>,
    /// Busy seconds per GPU compute unit.
    pub compute_busy: Vec<f64>,
    /// Busy seconds per GPU port (max of tx/rx), intra-node class.
    pub intra_busy: Vec<f64>,
    /// Busy seconds per node NIC (max of tx/rx).
    pub inter_busy: Vec<f64>,
    /// Aggregated transfer seconds per tag (tags are 'static, so this is
    /// a small alloc-free association list, not a per-task log). Tags are
    /// the canonical constants of [`crate::comm::tags`] — the same strings
    /// the data plane's comm log uses, so sweep reports and executor logs
    /// diff mechanically (compare with
    /// [`crate::sim::dag::SimDag::comm_log`] for volumes).
    pub tag_seconds: Vec<(&'static str, f64)>,
}

impl SimReport {
    /// Fraction of the makespan not covered by the busiest rank's compute —
    /// the "communication time ratio" of Fig 1 (communication + exposed
    /// idle waiting on communication).
    pub fn comm_ratio(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        let max_compute = self.compute_busy.iter().cloned().fold(0.0, f64::max);
        (1.0 - max_compute / self.makespan).clamp(0.0, 1.0)
    }

    /// Total seconds attributed to a tag (sum over tasks).
    pub fn seconds_for_tag(&self, tag: &str) -> f64 {
        self.tag_seconds
            .iter()
            .filter(|(t, _)| *t == tag)
            .map(|(_, s)| *s)
            .sum()
    }

    /// Seconds during which at least one compute task and at least one
    /// network transfer were simultaneously in flight — the quantity the
    /// chunk-pipelined (SP) schedule exists to maximize. `dag` must be the
    /// DAG this report was produced from (task ids index `timings`).
    pub fn overlap_seconds(&self, dag: &SimDag) -> f64 {
        assert_eq!(dag.len(), self.timings.len(), "report/DAG mismatch");
        // Interval sweep over (time, Δcompute, Δtransfer) events.
        let mut events: Vec<(f64, i32, i32)> = Vec::new();
        for (id, task) in dag.tasks.iter().enumerate() {
            let TaskTiming { start, end } = self.timings[id];
            if end <= start {
                continue;
            }
            match task.kind {
                TaskKind::Compute { .. } => {
                    events.push((start, 1, 0));
                    events.push((end, -1, 0));
                }
                TaskKind::Transfer { src, dst, .. } if src != dst => {
                    events.push((start, 0, 1));
                    events.push((end, 0, -1));
                }
                _ => {}
            }
        }
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite times"));
        let (mut n_compute, mut n_comm) = (0i32, 0i32);
        let mut prev = 0.0f64;
        let mut overlap = 0.0f64;
        for (t, dc, dx) in events {
            if n_compute > 0 && n_comm > 0 {
                overlap += t - prev;
            }
            n_compute += dc;
            n_comm += dx;
            prev = t;
        }
        overlap
    }
}

/// The engine. Holds mutable resource availability during a run.
pub struct Simulator<'a> {
    cluster: &'a ClusterTopology,
}

impl<'a> Simulator<'a> {
    pub fn new(cluster: &'a ClusterTopology) -> Simulator<'a> {
        Simulator { cluster }
    }

    /// Schedule the DAG; returns per-task timings and aggregate stats.
    pub fn run(&self, dag: &SimDag) -> SimReport {
        let p = self.cluster.total_gpus();
        let nodes = self.cluster.num_nodes();
        let mut gpu_tx = vec![0.0f64; p];
        let mut gpu_rx = vec![0.0f64; p];
        let mut nic_tx = vec![0.0f64; nodes];
        let mut nic_rx = vec![0.0f64; nodes];
        let mut compute = vec![0.0f64; p];

        let mut compute_busy = vec![0.0f64; p];
        let mut intra_busy = vec![0.0f64; p];
        let mut inter_busy = vec![0.0f64; nodes];

        let n = dag.tasks.len();
        let mut timings = vec![TaskTiming { start: 0.0, end: 0.0 }; n];
        let mut indeg = vec![0usize; n];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (id, t) in dag.tasks.iter().enumerate() {
            indeg[id] = t.deps.len();
            for &d in &t.deps {
                children[d].push(id);
            }
        }

        // Ready queue ordered by (ready_time, id) — deterministic FIFO per
        // resource among equally-ready tasks.
        #[derive(PartialEq)]
        struct Ready {
            time: f64,
            id: usize,
        }
        impl Eq for Ready {}
        impl PartialOrd for Ready {
            fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(other))
            }
        }
        impl Ord for Ready {
            fn cmp(&self, other: &Self) -> std::cmp::Ordering {
                self.time
                    .partial_cmp(&other.time)
                    .unwrap()
                    .then(self.id.cmp(&other.id))
            }
        }

        let mut heap: BinaryHeap<Reverse<Ready>> = BinaryHeap::new();
        let mut ready_time = vec![0.0f64; n];
        for id in 0..n {
            if indeg[id] == 0 {
                heap.push(Reverse(Ready { time: 0.0, id }));
            }
        }

        let mut tag_seconds: Vec<(&'static str, f64)> = Vec::new();
        let charge_tag = move |tag_seconds: &mut Vec<(&'static str, f64)>,
                                   tag: &'static str,
                                   dur: f64| {
            if tag.is_empty() {
                return;
            }
            match tag_seconds.iter_mut().find(|(t, _)| *t == tag) {
                Some((_, s)) => *s += dur,
                None => tag_seconds.push((tag, dur)),
            }
        };
        let mut done = 0usize;
        let mut makespan = 0.0f64;

        while let Some(Reverse(Ready { time, id })) = heap.pop() {
            let task = &dag.tasks[id];
            let (start, end) = match task.kind {
                TaskKind::Noop => (time, time),
                TaskKind::Compute { rank, flops } => {
                    assert!(rank < p, "compute rank {rank} outside cluster of {p}");
                    let start = time.max(compute[rank]);
                    // Per-node throughput: a straggler node's chunks take
                    // proportionally longer than a fast node's.
                    let dur = flops / self.cluster.flops_of(rank);
                    let end = start + dur;
                    compute[rank] = end;
                    compute_busy[rank] += dur;
                    (start, end)
                }
                TaskKind::Transfer { src, dst, bytes } => {
                    assert!(src < p && dst < p, "transfer endpoints outside cluster");
                    if src == dst {
                        (time, time) // device-local: free in the network model
                    } else if self.cluster.same_node(src, dst) {
                        let start = time.max(gpu_tx[src]).max(gpu_rx[dst]);
                        let dur = self.cluster.link(src, dst).seconds(bytes);
                        let end = start + dur;
                        gpu_tx[src] = end;
                        gpu_rx[dst] = end;
                        intra_busy[src] += dur;
                        intra_busy[dst] += dur;
                        charge_tag(&mut tag_seconds, task.tag, dur);
                        (start, end)
                    } else {
                        let sn = self.cluster.node_of(src);
                        let dn = self.cluster.node_of(dst);
                        let start = time.max(nic_tx[sn]).max(nic_rx[dn]);
                        // Cross-node: the endpoint pair's bottleneck link
                        // (slower NIC end dominates α and β).
                        let dur = self.cluster.link(src, dst).seconds(bytes);
                        let end = start + dur;
                        nic_tx[sn] = end;
                        nic_rx[dn] = end;
                        inter_busy[sn] += dur;
                        inter_busy[dn] += dur;
                        charge_tag(&mut tag_seconds, task.tag, dur);
                        (start, end)
                    }
                }
            };
            timings[id] = TaskTiming { start, end };
            makespan = makespan.max(end);
            done += 1;
            for &c in &children[id] {
                ready_time[c] = ready_time[c].max(end);
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    heap.push(Reverse(Ready { time: ready_time[c], id: c }));
                }
            }
        }
        assert_eq!(done, n, "DAG contains unreachable tasks (cycle?)");

        SimReport {
            makespan,
            timings,
            compute_busy,
            intra_busy,
            inter_busy,
            tag_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlphaBeta, NodeSpec};
    use crate::sim::dag::SimDag;

    fn tiny_cluster() -> ClusterTopology {
        ClusterTopology::homogeneous(
            "tiny",
            2,
            2,
            AlphaBeta::new(1e-5, 1e-9),
            AlphaBeta::new(1e-4, 1e-8),
            1e12,
            1 << 30,
        )
    }

    fn tiny_cluster_nodes(nodes: usize) -> ClusterTopology {
        ClusterTopology::homogeneous(
            "tiny_n",
            nodes,
            2,
            AlphaBeta::new(1e-5, 1e-9),
            AlphaBeta::new(1e-4, 1e-8),
            1e12,
            1 << 30,
        )
    }

    /// Node 0 fast, node 1 half the flops and a 10× slower NIC.
    fn hetero_cluster() -> ClusterTopology {
        let fast = NodeSpec {
            gpus: 2,
            gpu_flops: 1e12,
            gpu_mem_bytes: 1 << 30,
            intra: AlphaBeta::new(1e-5, 1e-9),
            inter: AlphaBeta::new(1e-4, 1e-8),
        };
        let slow = NodeSpec {
            gpu_flops: 5e11,
            inter: AlphaBeta::new(1e-3, 1e-7),
            ..fast
        };
        ClusterTopology::new("hetero", vec![fast, slow]).unwrap()
    }

    #[test]
    fn single_transfer_alpha_beta() {
        let c = tiny_cluster();
        let mut d = SimDag::new();
        d.transfer(0, 1, 1e6, &[], "t"); // intra-node
        let r = Simulator::new(&c).run(&d);
        assert!((r.makespan - (1e-5 + 1e6 * 1e-9)).abs() < 1e-12);
    }

    #[test]
    fn inter_node_uses_inter_class() {
        let c = tiny_cluster();
        let mut d = SimDag::new();
        d.transfer(0, 2, 1e6, &[], "t"); // node 0 → node 1
        let r = Simulator::new(&c).run(&d);
        assert!((r.makespan - (1e-4 + 1e6 * 1e-8)).abs() < 1e-12);
    }

    #[test]
    fn local_copy_is_free() {
        let c = tiny_cluster();
        let mut d = SimDag::new();
        d.transfer(1, 1, 1e9, &[], "local");
        let r = Simulator::new(&c).run(&d);
        assert_eq!(r.makespan, 0.0);
    }

    #[test]
    fn shared_port_serializes() {
        // Two transfers out of GPU 0 must serialize on gpu_tx[0].
        let c = tiny_cluster();
        let mut d = SimDag::new();
        d.transfer(0, 1, 1e6, &[], "a");
        d.transfer(0, 1, 1e6, &[], "b");
        let r = Simulator::new(&c).run(&d);
        let one = 1e-5 + 1e6 * 1e-9;
        assert!((r.makespan - 2.0 * one).abs() < 1e-12);
    }

    #[test]
    fn disjoint_ports_overlap() {
        // 0→1 and 2→3 share nothing: same makespan as one transfer.
        let c = tiny_cluster();
        let mut d = SimDag::new();
        d.transfer(0, 1, 1e6, &[], "a");
        d.transfer(2, 3, 1e6, &[], "b");
        let r = Simulator::new(&c).run(&d);
        let one = 1e-5 + 1e6 * 1e-9;
        assert!((r.makespan - one).abs() < 1e-12);
    }

    #[test]
    fn nic_shared_per_node() {
        // 0→2 and 1→3 are distinct GPU ports but share both NICs.
        let c = tiny_cluster();
        let mut d = SimDag::new();
        d.transfer(0, 2, 1e6, &[], "a");
        d.transfer(1, 3, 1e6, &[], "b");
        let r = Simulator::new(&c).run(&d);
        let one = 1e-4 + 1e6 * 1e-8;
        assert!((r.makespan - 2.0 * one).abs() < 1e-12, "{}", r.makespan);
    }

    #[test]
    fn intra_and_inter_overlap() {
        // An intra-node transfer and an inter-node transfer touching
        // disjoint nodes run fully overlapped: intra 0→1 on node 0, inter
        // 2→4 from node 1 to node 2.
        let c = tiny_cluster_nodes(3);
        let mut d = SimDag::new();
        d.transfer(0, 1, 1e6, &[], "intra"); // node0 internal
        d.transfer(2, 4, 1e6, &[], "inter"); // node1 → node2
        let r = Simulator::new(&c).run(&d);
        let expect = (1e-5 + 1e6 * 1e-9f64).max(1e-4 + 1e6 * 1e-8);
        assert!((r.makespan - expect).abs() < 1e-12);
    }

    #[test]
    fn dependencies_chain() {
        let c = tiny_cluster();
        let mut d = SimDag::new();
        let a = d.compute(0, 1e9, &[], "c1"); // 1ms
        let b = d.transfer(0, 1, 1e6, &[a], "t");
        d.compute(1, 1e9, &[b], "c2");
        let r = Simulator::new(&c).run(&d);
        let expect = 1e-3 + (1e-5 + 1e6 * 1e-9) + 1e-3;
        assert!((r.makespan - expect).abs() < 1e-9);
        // Timings are monotone along the chain.
        assert!(r.timings[1].start >= r.timings[0].end);
        assert!(r.timings[2].start >= r.timings[1].end);
    }

    #[test]
    fn comm_ratio_bounds() {
        let c = tiny_cluster();
        let mut d = SimDag::new();
        d.compute(0, 1e9, &[], "c");
        let r = Simulator::new(&c).run(&d);
        assert!(r.comm_ratio() < 1e-9); // pure compute
        let mut d2 = SimDag::new();
        d2.transfer(0, 1, 1e6, &[], "t");
        let r2 = Simulator::new(&c).run(&d2);
        assert!((r2.comm_ratio() - 1.0).abs() < 1e-9); // pure comm
    }

    #[test]
    fn makespan_at_least_critical_path_and_bottleneck() {
        let c = tiny_cluster();
        let mut d = SimDag::new();
        // Fan of 4 transfers out of GPU 0 + a dependent compute.
        let mut last = Vec::new();
        for i in 0..4 {
            last.push(d.transfer(0, 1 + (i % 1), 1e6, &[], "t"));
        }
        let j = d.join(&last, "j");
        d.compute(1, 1e9, &[j], "c");
        let r = Simulator::new(&c).run(&d);
        let bottleneck = 4.0 * (1e-5 + 1e6 * 1e-9);
        assert!(r.makespan >= bottleneck);
        assert!(r.makespan >= 1e-3);
    }

    #[test]
    fn overlap_accounting() {
        let c = tiny_cluster();
        // Independent compute and transfer: full overlap of the shorter.
        let mut d = SimDag::new();
        d.compute(0, 1e9, &[], "c"); // 1 ms
        d.transfer(0, 1, 1e5, &[], "t"); // 1e-5 + 1e-4 ≈ 0.11 ms
        let r = Simulator::new(&c).run(&d);
        let t_xfer = 1e-5 + 1e5 * 1e-9;
        assert!((r.overlap_seconds(&d) - t_xfer).abs() < 1e-12);

        // Chained compute → transfer: zero overlap.
        let mut d2 = SimDag::new();
        let a = d2.compute(0, 1e9, &[], "c");
        d2.transfer(0, 1, 1e5, &[a], "t");
        let r2 = Simulator::new(&c).run(&d2);
        assert_eq!(r2.overlap_seconds(&d2), 0.0);

        // Local copies (free) never count as communication.
        let mut d3 = SimDag::new();
        d3.compute(0, 1e9, &[], "c");
        d3.transfer(1, 1, 1e9, &[], "local");
        let r3 = Simulator::new(&c).run(&d3);
        assert_eq!(r3.overlap_seconds(&d3), 0.0);
    }

    #[test]
    fn tag_accounting() {
        let c = tiny_cluster();
        let mut d = SimDag::new();
        d.transfer(0, 1, 1e6, &[], "x");
        d.transfer(0, 1, 1e6, &[], "x");
        let r = Simulator::new(&c).run(&d);
        let x = r.seconds_for_tag("x");
        assert!((x - 2.0 * (1e-5 + 1e6 * 1e-9)).abs() < 1e-12);
        assert_eq!(r.seconds_for_tag("y"), 0.0);
    }

    #[test]
    fn straggler_node_slows_its_own_compute_only() {
        let c = hetero_cluster();
        let mut d = SimDag::new();
        d.compute(0, 1e9, &[], "fast"); // node 0: 1 ms
        d.compute(2, 1e9, &[], "slow"); // node 1: 2 ms (half the flops)
        let r = Simulator::new(&c).run(&d);
        assert!((r.timings[0].end - 1e-3).abs() < 1e-12);
        assert!((r.timings[1].end - 2e-3).abs() < 1e-12);
        assert!((r.makespan - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn asymmetric_nic_prices_by_bottleneck_end() {
        // Cross-node transfers in BOTH directions are bottlenecked by the
        // slow node's NIC (α=1e-3, β=1e-7) — not the fast sender's.
        let c = hetero_cluster();
        let expect = 1e-3 + 1e6 * 1e-7;
        for (src, dst) in [(0usize, 2usize), (2, 0)] {
            let mut d = SimDag::new();
            d.transfer(src, dst, 1e6, &[], "x");
            let r = Simulator::new(&c).run(&d);
            assert!(
                (r.makespan - expect).abs() < 1e-12,
                "{src}→{dst}: {} vs {expect}",
                r.makespan
            );
        }
        // Intra-node transfers on the slow node still use its intra link.
        let mut d = SimDag::new();
        d.transfer(2, 3, 1e6, &[], "x");
        let r = Simulator::new(&c).run(&d);
        assert!((r.makespan - (1e-5 + 1e6 * 1e-9)).abs() < 1e-12);
    }
}
