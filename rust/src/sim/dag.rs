//! Task DAG construction for the simulator.

/// Index of a task within a [`SimDag`].
pub type TaskId = usize;

/// What a task does. Times are derived by the engine from the cluster
/// profile; the DAG itself is hardware-independent.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskKind {
    /// Move `bytes` from GPU `src` to GPU `dst`. `src == dst` is a local
    /// copy and costs zero network time (device-local memcpy is folded
    /// into compute in this model).
    Transfer { src: usize, dst: usize, bytes: f64 },
    /// Run `flops` of dense compute on GPU `rank`.
    Compute { rank: usize, flops: f64 },
    /// Synchronization/join point with no cost of its own.
    Noop,
}

/// One node of the DAG.
#[derive(Debug, Clone)]
pub struct SimTask {
    pub kind: TaskKind,
    pub deps: Vec<TaskId>,
    /// Free-form label, used for tracing and for per-phase accounting
    /// (e.g. "a2a.dispatch", "expert.ffn", "mp.allgather").
    pub tag: &'static str,
}

/// Append-only DAG builder. Dependencies must point to already-added tasks
/// (enforced), which guarantees acyclicity by construction.
#[derive(Debug, Default, Clone)]
pub struct SimDag {
    pub tasks: Vec<SimTask>,
}

impl SimDag {
    pub fn new() -> SimDag {
        SimDag { tasks: Vec::new() }
    }

    pub fn add(&mut self, kind: TaskKind, deps: &[TaskId], tag: &'static str) -> TaskId {
        let id = self.tasks.len();
        for &d in deps {
            assert!(d < id, "dependency {d} must precede task {id} (acyclic by construction)");
        }
        self.tasks.push(SimTask { kind, deps: deps.to_vec(), tag });
        id
    }

    pub fn transfer(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        deps: &[TaskId],
        tag: &'static str,
    ) -> TaskId {
        self.add(TaskKind::Transfer { src, dst, bytes }, deps, tag)
    }

    pub fn compute(&mut self, rank: usize, flops: f64, deps: &[TaskId], tag: &'static str) -> TaskId {
        self.add(TaskKind::Compute { rank, flops }, deps, tag)
    }

    /// Join point over `deps` (useful to fan in a whole collective).
    pub fn join(&mut self, deps: &[TaskId], tag: &'static str) -> TaskId {
        self.add(TaskKind::Noop, deps, tag)
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Total bytes moved over the network (src ≠ dst transfers).
    pub fn total_network_bytes(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| match t.kind {
                TaskKind::Transfer { src, dst, bytes } if src != dst => bytes,
                _ => 0.0,
            })
            .sum()
    }

    /// The DAG's wire log: aggregated `(tag, total bytes)` over network
    /// transfers (src ≠ dst), in first-touch order — the same shape the
    /// data plane's [`crate::comm::transport::DataTransport`] records, so
    /// the two planes' logs can be compared directly (they use the same
    /// tag constants from [`crate::comm::tags`]).
    pub fn comm_log(&self) -> Vec<(&'static str, f64)> {
        let mut log: Vec<(&'static str, f64)> = Vec::new();
        for t in &self.tasks {
            if let TaskKind::Transfer { src, dst, bytes } = t.kind {
                if src != dst {
                    match log.iter_mut().find(|(tag, _)| *tag == t.tag) {
                        Some((_, b)) => *b += bytes,
                        None => log.push((t.tag, bytes)),
                    }
                }
            }
        }
        log
    }

    /// Total network bytes under all tags starting with `prefix` — sums a
    /// per-chunk tag family (e.g. `sp.dispatch.`) into one figure, the
    /// chunked-schedule counterpart of a single [`Self::comm_log`] entry.
    pub fn comm_bytes_with_prefix(&self, prefix: &str) -> f64 {
        self.comm_log()
            .iter()
            .filter(|(tag, _)| tag.starts_with(prefix))
            .map(|(_, b)| *b)
            .sum()
    }

    /// Total compute FLOPs in the DAG.
    pub fn total_flops(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| match t.kind {
                TaskKind::Compute { flops, .. } => flops,
                _ => 0.0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_counts() {
        let mut d = SimDag::new();
        let a = d.transfer(0, 1, 100.0, &[], "t");
        let b = d.compute(1, 500.0, &[a], "c");
        let local = d.transfer(2, 2, 999.0, &[], "local");
        d.join(&[b, local], "j");
        assert_eq!(d.len(), 4);
        assert_eq!(d.total_network_bytes(), 100.0); // local copy excluded
        assert_eq!(d.total_flops(), 500.0);
        assert_eq!(d.comm_log(), vec![("t", 100.0)]); // local copy excluded
        assert_eq!(d.comm_bytes_with_prefix("t"), 100.0);
        assert_eq!(d.comm_bytes_with_prefix("nope."), 0.0);
    }

    #[test]
    #[should_panic(expected = "must precede")]
    fn forward_deps_rejected() {
        let mut d = SimDag::new();
        d.add(TaskKind::Noop, &[3], "bad");
    }
}
