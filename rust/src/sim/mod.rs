//! Discrete-event cluster simulator.
//!
//! The paper's performance claims are statements about *which transfers
//! occupy which link class, and whether transfers on different classes
//! overlap*. This module models exactly that: a task DAG of point-to-point
//! transfers and per-GPU compute, scheduled greedily over contended
//! resources (per-GPU intra-node tx/rx ports, per-node NIC tx/rx, per-GPU
//! compute units) with α-β transfer costs — the same cost model the paper's
//! §IV analysis and Algorithm 1 use.

pub mod dag;
pub mod engine;
pub mod trace;

pub use dag::{SimDag, TaskId, TaskKind};
pub use engine::{SimReport, Simulator};
