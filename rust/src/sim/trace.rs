//! Chrome-trace (about://tracing / Perfetto) export of a simulated run,
//! for eyeballing overlap structure (e.g. that SAA really interleaves the
//! AlltoAll phases with the AllGather forwards).

use crate::sim::dag::{SimDag, TaskKind};
use crate::sim::engine::SimReport;
use crate::util::json::Json;

/// Render a simulated run as a Chrome trace JSON document. Rows (tids) are
/// GPUs; compute and transfers are duration events; transfers are placed on
/// the source GPU's row.
pub fn chrome_trace(dag: &SimDag, report: &SimReport) -> Json {
    let mut events = Vec::new();
    for (id, task) in dag.tasks.iter().enumerate() {
        let t = report.timings[id];
        if t.end <= t.start {
            continue; // zero-duration: noop/local copy
        }
        let (name, tid) = match task.kind {
            TaskKind::Compute { rank, .. } => (format!("compute:{}", task.tag), rank),
            TaskKind::Transfer { src, dst, .. } => (format!("xfer:{}→{dst}:{}", src, task.tag), src),
            TaskKind::Noop => continue,
        };
        events.push(Json::obj(vec![
            ("name", Json::str(&name)),
            ("ph", Json::str("X")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(tid as f64)),
            // Chrome traces use microseconds.
            ("ts", Json::num(t.start * 1e6)),
            ("dur", Json::num((t.end - t.start) * 1e6)),
        ]));
    }
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterTopology;
    use crate::sim::engine::Simulator;

    #[test]
    fn trace_has_events_with_positive_durations() {
        let c = ClusterTopology::testbed_a();
        let mut d = SimDag::new();
        let a = d.transfer(0, 1, 1e6, &[], "ag");
        d.compute(1, 1e9, &[a], "ffn");
        d.join(&[a], "sync");
        let r = Simulator::new(&c).run(&d);
        let trace = chrome_trace(&d, &r);
        let events = trace.get("traceEvents").as_arr().unwrap();
        assert_eq!(events.len(), 2); // join excluded
        for e in events {
            assert!(e.get("dur").as_f64().unwrap() > 0.0);
        }
    }
}
